"""Serving CI smoke: a seeded overload scenario, reproduced bitwise.

Drives the request-level serving simulator with an arrival rate well above
the server's service capacity so every robustness policy actually fires —
admission control sheds, deadlines expire, clients retry with seeded
backoff, and the scheduler degrades batches under queue pressure. The same
scenario is then run a second time from a fresh memory system and the two
``ServingResult``s must be **bitwise identical** (``diff() == {}``), p99
latency and the full latency/queue/service arrays included. A steady-state
all-policies-off scenario rides along as the identity-surface check: its
``batch_stats`` must equal the plain fixed-trace ``simulate_embedding``
path for the same lowered ``ConcatTrace``.

Scenario summaries land in ``BENCH_serving.json`` (repo root + the
gitignored results/bench copy) — the artifact the serving-smoke CI job
uploads per run.
"""
from __future__ import annotations

import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO_ROOT)                           # benchmarks.common
sys.path.insert(0, os.path.join(_REPO_ROOT, "tests"))    # differential.py

from benchmarks.common import save_rows                  # noqa: E402
from differential import assert_bitwise_equal_results    # noqa: E402
from repro.core import TrafficConfig, tpuv6e             # noqa: E402
from repro.core.memory.system import (                   # noqa: E402
    EmbeddingTrace,
    MultiCoreMemorySystem,
)
from repro.core.requests import generate_requests, lower_batch  # noqa: E402
from repro.core.trace import ConcatTrace                 # noqa: E402
from repro.core.workload import EmbeddingOpSpec          # noqa: E402
from repro.serving import (                              # noqa: E402
    RobustnessPolicy,
    ServingScenario,
    simulate_serving,
)

SPEC = EmbeddingOpSpec(num_tables=4, rows_per_table=2000, dim=64,
                       lookups_per_sample=8, dtype_bytes=4)
BATCH_SLOTS = 8

STEADY = TrafficConfig(pattern="poisson", mean_gap_cycles=1_500.0,
                       num_requests=64, seed=7, zipf_s=0.9)
# Bursty arrivals at a fraction of the mean service gap: the queue grows
# past every watermark, so shed/timeout/retry/degrade all trigger.
OVERLOAD = TrafficConfig(pattern="bursty", mean_gap_cycles=60.0,
                         num_requests=96, seed=23, burst_len=12,
                         zipf_s=0.9, zipf_drift=0.25, drift_period=24)
STORM = RobustnessPolicy(admission_watermark=14, deadline_cycles=4_000,
                         max_retries=2, retry_backoff_cycles=3_000.0,
                         degrade_mode="hot_rows_only", degrade_watermark=4,
                         hot_fraction=0.1)
# Deadline+retry pressure: a deadline far shorter than the queueing delay
# plus a small backoff, so expired attempts reschedule from timestamps the
# clock has already passed — the retry-rewind regression shape. The smoke
# asserts the event timeline stays monotonic.
DDL_RETRY = RobustnessPolicy(deadline_cycles=500, max_retries=3,
                             retry_backoff_cycles=100.0)

SCENARIOS = (
    ServingScenario(name="steady_off", traffic=STEADY,
                    batch_slots=BATCH_SLOTS),
    ServingScenario(name="overload_storm", traffic=OVERLOAD, policy=STORM,
                    batch_slots=BATCH_SLOTS),
    ServingScenario(name="deadline_retry", traffic=OVERLOAD,
                    policy=DDL_RETRY, batch_slots=4),
)


def _identity_check(ms, res) -> None:
    """All-policies-off serving batch_stats == plain fixed-trace path."""
    reqs = generate_requests(SPEC, STEADY)
    chunks = [reqs[i:i + BATCH_SLOTS]
              for i in range(0, len(reqs), BATCH_SLOTS)]
    fulls = [lower_batch(chunk, SPEC).full for chunk in chunks]
    plain = ms.simulate_embedding(EmbeddingTrace.from_concat(
        SPEC, ConcatTrace.from_traces(fulls)))
    assert_bitwise_equal_results(plain, res.batch_stats,
                                 "steady_off identity surface")


def main() -> int:
    hw = tpuv6e()
    rows = []
    for sc in SCENARIOS:
        event_log: list = []
        first = simulate_serving(
            MultiCoreMemorySystem.from_hardware(hw), SPEC, sc,
            event_log=event_log)
        second = simulate_serving(
            MultiCoreMemorySystem.from_hardware(hw), SPEC, sc)
        delta = first.diff(second)
        assert delta == {}, f"[{sc.name}] run-to-run drift: {delta}"
        assert first.p99_cycles == second.p99_cycles
        # Clock monotonicity: retries scheduled from expired deadlines must
        # never rewind the event timeline (the deadline_retry scenario is
        # shaped to hit exactly that path).
        assert all(a <= b for a, b in zip(event_log, event_log[1:])), \
            f"[{sc.name}] event timeline rewound"
        rows.append(first.summary())
        if sc.name == "steady_off":
            assert sc.policy.all_off
            assert first.shed == 0 and first.timed_out == 0
            assert first.completed == first.offered
            _identity_check(MultiCoreMemorySystem.from_hardware(hw), first)
        elif sc.name == "deadline_retry":
            assert first.timed_out > 0, first.summary()
            assert first.retries > 0, first.summary()
            assert first.shed + first.timed_out \
                == first.retries + first.abandoned, first.summary()
        else:
            # Overload must actually overload — and the failed-attempt
            # ledger must balance: every shed/timeout either retried or
            # exhausted its budget.
            assert first.shed > 0, first.summary()
            assert first.timed_out > 0, first.summary()
            assert first.retries > 0, first.summary()
            assert first.degraded_batches > 0, first.summary()
            assert first.shed + first.timed_out \
                == first.retries + first.abandoned, first.summary()
        print(f"[{sc.name:14s}] offered {first.offered:3d}  "
              f"completed {first.completed:3d}  shed {first.shed:3d}  "
              f"timeout {first.timed_out:3d}  retries {first.retries:3d}  "
              f"degraded {first.degraded_batches:2d}  "
              f"p99 {first.p99_cycles:,.0f} cyc  "
              f"goodput {first.goodput:.3f}")

    path = save_rows("BENCH_serving", rows, repo_root=True)
    print(f"serving smoke OK: {len(SCENARIOS)} scenarios bitwise-"
          f"reproducible (shed/timeout counts + p99 + latency arrays), "
          f"steady-state identity surface verified -> {path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
