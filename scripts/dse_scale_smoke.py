"""CI smoke for the DSE scaling layer, run under 8 forced host devices.

Launch with ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
dse-scale CI job does): tier-1 tests deliberately see the real single
device, so the genuinely multi-device paths — the shard mesh, the
``shard_map_compat`` psum gather check, per-shard ``jax.default_device``
pinning — are exercised here.

Three gates, every one an acceptance criterion of the scaling PR:

  1. **Sharded == unsharded, bitwise**, through ``tests/differential.py``'s
     exact recursive comparator (not a tolerance check).
  2. **Kill-and-resume == uninterrupted, bitwise**: a sweep preempted
     mid-journal resumes from its ``SweepCheckpoint`` and matches; a
     torn journal tail is re-evaluated, not skipped.
  3. **Search front == exhaustive front** on the 24-config reference grid
     shape, within <=50% of the exhaustive full-fidelity evaluations.

The checkpoint files land in ``--ckpt-dir`` (default results/ckpt_smoke) so
CI can upload them as an artifact when the job fails.
"""
from __future__ import annotations

import argparse
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO_ROOT, "tests"))   # differential.py

import jax                                           # noqa: E402

from differential import assert_bitwise_equal_results   # noqa: E402
from repro.core import (                                # noqa: E402
    SweepCheckpoint,
    dlrm_rmc2_small,
    search,
    sweep,
    tpuv6e,
)
from repro.core.search import pareto_front              # noqa: E402

POLICIES = ("spm", "lru", "srrip", "pinning")
GRID = dict(policies=POLICIES, capacities=(1 << 16, 1 << 17, 1 << 18),
            ways=(4, 8), zipf_s=(0.8, 1.0), num_cores=(1, 2), seed=0)
SEARCH_GRID = dict(policies=POLICIES, capacities=(1 << 16, 1 << 17, 1 << 18),
                   ways=(4, 8), zipf_s=0.9, seed=0)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--ckpt-dir", default=os.path.join(_REPO_ROOT, "results",
                                                       "ckpt_smoke"))
    args = ap.parse_args()
    os.makedirs(args.ckpt_dir, exist_ok=True)

    ndev = len(jax.devices())
    if ndev < 2:
        print("dse_scale_smoke needs multiple devices — launch under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8",
              file=sys.stderr)
        return 1
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                         lookups=4, batch_size=8, num_batches=2)
    hw = tpuv6e()

    # 1. Sharded over all host devices == single-device path, bitwise.
    ref = sweep(wl, hw, **GRID)
    sharded = sweep(wl, hw, devices=ndev, **GRID)
    assert sharded.sharded and sharded.device_count == ndev
    assert_bitwise_equal_results(ref, sharded, "sharded vs unsharded")
    # The fault-free production path must report zero fault telemetry:
    # spurious retries/failovers here are a supervision bug (and would make
    # perf trajectories incomparable).
    assert not sharded.telemetry.any_faults, sharded.telemetry.to_dict()
    print(f"sharded smoke OK: {ref.num_configs} configs "
          f"({ref.distinct_memo_keys} memo keys) on {ndev} host devices, "
          "bitwise identical to the single-device sweep, zero fault "
          "telemetry")

    # 2. Kill-and-resume (sharded, journaled): preempt after 2 rounds, then
    #    resume — bitwise; then tear the journal tail and resume again.
    ckpt_path = os.path.join(args.ckpt_dir, "smoke.ckpt")
    if os.path.exists(ckpt_path):
        os.unlink(ckpt_path)

    class KillAfter(SweepCheckpoint):
        def __init__(self, path, cadence, rounds):
            super().__init__(path, cadence=cadence)
            self.rounds = rounds

        def record(self, slice_id, results):
            if self.rounds <= 0:
                raise KeyboardInterrupt("simulated preemption")
            self.rounds -= 1
            super().record(slice_id, results)

    ck = KillAfter(ckpt_path, cadence=4, rounds=2)
    try:
        sweep(wl, hw, devices=ndev, checkpoint=ck, **GRID)
        raise AssertionError("expected the simulated preemption to fire")
    except KeyboardInterrupt:
        pass
    finally:
        ck.close()
    resumed = sweep(wl, hw, devices=ndev, checkpoint=ckpt_path, **GRID)
    assert 0 < resumed.resumed_keys < resumed.distinct_memo_keys
    assert_bitwise_equal_results(ref, resumed, "kill+resume")
    # Torn tail: chop the last journal line mid-record.
    raw = open(ckpt_path, "rb").read()
    open(ckpt_path, "wb").write(raw[: len(raw) - len(raw.splitlines(True)[-1]) // 2 - 1])
    torn = sweep(wl, hw, devices=ndev, checkpoint=ckpt_path, **GRID)
    assert_bitwise_equal_results(ref, torn, "torn-tail resume")
    print(f"checkpoint smoke OK: resumed {resumed.resumed_keys}/"
          f"{resumed.distinct_memo_keys} keys after simulated kill, "
          "bitwise identical; torn journal tail re-evaluated")

    # 3. Search: exact exhaustive front, <=50% of full evaluations, sharded.
    exhaustive = sweep(wl, hw, **SEARCH_GRID)
    res = search(wl, hw, devices=ndev,
                 checkpoint_dir=os.path.join(args.ckpt_dir, "search"),
                 **SEARCH_GRID)
    want = sorted(e.config.label for e in pareto_front(exhaustive.entries))
    assert res.front_labels() == want, (res.front_labels(), want)
    by_cfg = {e.config: e for e in exhaustive.entries}
    for e in res.pareto:
        mism = e.result.diff(by_cfg[e.config].result)
        assert not mism, (e.config.label, mism)
    assert res.full_evals <= 0.5 * exhaustive.distinct_memo_keys, (
        res.full_evals, exhaustive.distinct_memo_keys)
    print(f"search smoke OK: exact Pareto front ({len(want)} configs) in "
          f"{res.full_evals}/{exhaustive.distinct_memo_keys} full "
          f"evaluations ({res.low_fidelity_evals} low-fidelity)")
    print("dse scale smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
