"""Chaos CI smoke: the sharded sweep under an injected fault schedule.

Launch under ``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (the
chaos CI job does). One checkpointed sweep is hit with every fault kind the
harness schedules — a double transient (retried in place), a worker crash
(failed over), a hung shard (watchdog-abandoned, failed over) and a torn
journal append (process "dies" mid-write) — then resumed, and the final
result must be **bitwise identical** to the fault-free sweep through
``tests/differential.py``'s exact comparator. Fault telemetry is written to
``--out-dir`` (default results/chaos) on every run, pass or fail, so a CI
failure uploads the counters that explain it.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, os.path.join(_REPO_ROOT, "tests"))   # differential.py

import jax                                              # noqa: E402

from differential import assert_bitwise_equal_results   # noqa: E402
from repro.core import (                                # noqa: E402
    FaultEvent,
    FaultPlan,
    FaultTelemetry,
    FaultTolerance,
    SweepCheckpoint,
    dlrm_rmc2_small,
    sweep,
    tpuv6e,
)
from repro.core.faults import InjectedKill              # noqa: E402

GRID = dict(policies=("spm", "lru", "srrip", "pinning"),
            capacities=(1 << 16, 1 << 17, 1 << 18), ways=(4, 8),
            zipf_s=0.9, seed=0)
SHARDS = 4
CADENCE = 8          # 14 memo keys -> 2 evaluation rounds
# Generous vs the warm per-wave evaluation time: a too-tight bound marks
# legitimately-busy shards hung (bitwise-safe but noisy on slow runners).
HANG_TIMEOUT_S = 15.0

# The full schedule: every fault kind, across both rounds. Round 1 both
# hangs a shard AND tears the journal append, so the resume starts from a
# journal written mid-failover.
PLAN = FaultPlan(events=(
    FaultEvent("transient", shard=0, round=0, count=2),
    FaultEvent("crash", shard=1, round=0),
    FaultEvent("hang", shard=2, round=1),
    FaultEvent("torn_write", round=1),
))
TOLERANCE = FaultTolerance(max_retries=2, backoff_base_s=0.02,
                           shard_timeout_s=HANG_TIMEOUT_S)


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir",
                    default=os.path.join(_REPO_ROOT, "results", "chaos"))
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    tele_path = os.path.join(args.out_dir, "fault_telemetry.json")

    ndev = len(jax.devices())
    if ndev < 2:
        print("chaos_smoke needs multiple devices — launch under "
              "XLA_FLAGS=--xla_force_host_platform_device_count=8",
              file=sys.stderr)
        return 1

    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                         lookups=4, batch_size=8, num_batches=2)
    hw = tpuv6e()

    ref = sweep(wl, hw, **GRID)
    warm = sweep(wl, hw, devices=SHARDS, **GRID)   # compile per-device paths
    assert_bitwise_equal_results(ref, warm, "fault-free sharded")

    ckpt_path = os.path.join(args.out_dir, "chaos.ckpt")
    if os.path.exists(ckpt_path):
        os.unlink(ckpt_path)
    tele = FaultTelemetry()
    outcome = {"plan": [vars(e) for e in PLAN.events], "killed": False,
               "bitwise_identical": False}
    try:
        ck = SweepCheckpoint(ckpt_path, cadence=CADENCE)
        try:
            sweep(wl, hw, devices=SHARDS, checkpoint=ck, fault_plan=PLAN,
                  fault_tolerance=TOLERANCE, fault_telemetry=tele, **GRID)
            raise AssertionError(
                "the torn-write InjectedKill never fired — the schedule did "
                "not reach round 1")
        except InjectedKill:
            outcome["killed"] = True
        finally:
            ck.close()

        resumed = sweep(wl, hw, devices=SHARDS, checkpoint=ckpt_path, **GRID)
        assert_bitwise_equal_results(ref, resumed, "chaos resume")
        outcome["bitwise_identical"] = True
        outcome["resumed_keys"] = resumed.resumed_keys
        outcome["distinct_memo_keys"] = resumed.distinct_memo_keys

        b = tele.brief()
        assert b["retries"] == 2, b
        assert b["worker_crashes"] == 1, b
        assert b["hung_shards"] == 1, b
        assert b["failovers"] == 2, b
        assert b["torn_writes"] == 1, b
        assert 0 < resumed.resumed_keys < resumed.distinct_memo_keys
    finally:
        # Telemetry lands on disk pass or fail — CI uploads it on failure.
        outcome["fault_telemetry"] = tele.to_dict()
        with open(tele_path, "w") as f:
            json.dump(outcome, f, indent=2)

    print(f"chaos smoke OK: transient x2 retried, 1 crash + 1 hang failed "
          f"over, torn journal killed + resumed "
          f"({resumed.resumed_keys}/{resumed.distinct_memo_keys} keys "
          f"restored) — bitwise identical to the fault-free sweep; "
          f"telemetry -> {tele_path}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
