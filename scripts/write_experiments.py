"""Assemble EXPERIMENTS.md from the dry-run / benchmark artifacts.

    PYTHONPATH=src:. python scripts/write_experiments.py
"""
import glob
import json
import os
import sys

sys.path.insert(0, ".")
from benchmarks import roofline as RL

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DRY = os.path.join(ROOT, "results", "dryrun")
BENCH = os.path.join(ROOT, "results", "bench")


def load(path):
    with open(path) as f:
        return json.load(f)


def dryrun_table(mesh):
    rows = []
    for p in sorted(glob.glob(os.path.join(DRY, f"*__{mesh}.json"))):
        rows.append(load(p))
    lines = [
        "| arch | shape | status | compile (s) | args/dev (GB) | temp/dev (GB) "
        "| HLO GF/dev | coll GB/dev |",
        "|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        mem = r.get("memory", {}) or {}
        h = r.get("hlo", {}) or {}
        coll = sum((h.get("collective_bytes_per_device") or {}).values())
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['status']} | "
            f"{r.get('compile_s', 0):.1f} | "
            f"{(mem.get('argument_bytes') or 0)/1e9:.2f} | "
            f"{(mem.get('temp_bytes') or 0)/1e9:.2f} | "
            f"{h.get('flops_per_device', 0)/1e9:,.0f} | {coll/1e9:.1f} |"
        )
    ok = sum(1 for r in rows if r["status"] == "ok")
    return "\n".join(lines), ok, len(rows)


def bench_section():
    out = []
    p3 = os.path.join(BENCH, "fig3_dlrm_validation.json")
    if os.path.exists(p3):
        rows = load(p3)
        a = [r for r in rows if r["figure"] == "3a"]
        b = [r for r in rows if r["figure"] == "3b"]
        c = [r for r in rows if r["figure"] == "3c"]
        gap = [r["oracle_gap_pct"] for r in rows if "oracle_gap_pct" in r]
        out.append("### Fig. 3 — DLRM validation (EONSim vs event-granular reference)\n")
        out.append("| sweep | points | avg time err | max time err |")
        out.append("|---|---|---|---|")
        for name, rs in (("3a tables 30-60", a), ("3b batch 32-512", b)):
            errs = [r["time_err_pct"] for r in rs]
            out.append(f"| {name} | {len(rs)} | {sum(errs)/len(errs):.2f}% | {max(errs):.2f}% |")
        on = [r["onchip_err_pct"] for r in c]
        off = [r["offchip_err_pct"] for r in c]
        out.append(f"\nAccess counts (Fig. 3c): on-chip err {sum(on)/len(on):.2f}%, "
                   f"off-chip err {sum(off)/len(off):.2f}% (paper: 2.2% / 2.8%).")
        out.append(f"\nClosed-form analytical oracle gap: {sum(gap)/len(gap):.1f}% — "
                   "the paper's thesis quantified: analytical models miss "
                   "data-dependent memory behavior; detailed simulation is required.\n")
    p4 = os.path.join(BENCH, "fig4_onchip_policies.json")
    if os.path.exists(p4):
        rows = load(p4)
        ident = all(r["identical"] for r in rows if r["figure"] == "4a")
        out.append(f"### Fig. 4a — cache model vs ChampSim-semantics golden: "
                   f"**identical = {ident}** (paper: identical)\n")
        out.append("### Fig. 4b/4c — on-chip policy case study\n")
        out.append("| dataset | policy | speedup vs SPM | on-chip ratio | hit rate |")
        out.append("|---|---|---|---|---|")
        for r in rows:
            if r["figure"] == "4b/4c":
                out.append(f"| {r['dataset']} | {r['policy']} | "
                           f"{r['speedup_vs_spm']:.2f}x | {r['onchip_ratio']:.3f} | "
                           f"{r['cache_hit_rate']:.3f} |")
        out.append("\nPaper claims reproduced: LRU/SRRIP >1.5x on Reuse-High/Mid, "
                   "limited gain on Reuse-Low; Profiling-pinning best everywhere; "
                   "SRRIP edges LRU's on-chip ratio.\n")
    pa = os.path.join(BENCH, "assoc_study.json")
    if os.path.exists(pa):
        rows = load(pa)
        out.append("### Beyond-paper — cache geometry exploration (LRU, "
                   "reuse-mid trace)\n")
        out.append("| sweep | ways | capacity | hit rate |")
        out.append("|---|---|---|---|")
        for r in rows:
            out.append(f"| {r['sweep']} | {r['ways']} | {r['capacity_mb']} MB | "
                       f"{r['hit_rate']:.3f} |")
        out.append("")
    pi = os.path.join(BENCH, "interleave_study.json")
    if os.path.exists(pi):
        rows = load(pi)
        out.append("### Beyond-paper — DRAM interleave granularity vs 512 B "
                   "vector gathers\n")
        out.append("| interleave | row-hit rate | achieved GB/s | speedup vs 64 B |")
        out.append("|---|---|---|---|")
        for r in rows:
            out.append(f"| {r['interleave_bytes']} B | {r['row_hit_rate']:.3f} | "
                       f"{r['achieved_gbps']:.0f} | {r['speedup_vs_64B']:.2f}x |")
        out.append("\nCoarse interleave keeps one embedding vector in one row "
                   "(1 activate vs 8) — an address-mapping design point the "
                   "detailed DRAM model exposes.\n")
    pl = os.path.join(BENCH, "lm_npu_study.json")
    if os.path.exists(pl):
        rows = load(pl)
        out.append("### Beyond-paper — LM token-embedding study (decode_32k, 8 steps)\n")
        out.append("| arch | policy | embed speedup vs SPM | on-chip ratio |")
        out.append("|---|---|---|---|")
        for r in rows:
            out.append(f"| {r['arch']} | {r['policy']} | "
                       f"{r['embed_speedup_vs_spm']:.2f}x | {r['onchip_ratio']:.3f} |")
        out.append("")
    return "\n".join(out)


def roofline_section():
    rows = RL.load_all("pod")
    txt = [RL.markdown_table(rows), ""]
    txt.append("Per-cell mitigation notes (dominant-term):\n")
    for r in rows:
        txt.append(f"* **{r['arch']}/{r['shape']}** — {r['bottleneck']}-bound; "
                   f"{r['mitigation']}.")
    return "\n".join(txt)


def main():
    tpl_path = os.path.join(ROOT, "scripts", "experiments_template.md")
    with open(tpl_path) as f:
        tpl = f.read()
    pod_tbl, pod_ok, pod_n = dryrun_table("pod")
    mp_tbl, mp_ok, mp_n = dryrun_table("multipod")
    out = tpl.format(
        pod_ok=pod_ok, pod_n=pod_n, mp_ok=mp_ok, mp_n=mp_n,
        pod_table=pod_tbl, mp_table=mp_tbl,
        bench=bench_section(), roofline=roofline_section(),
    )
    with open(os.path.join(ROOT, "EXPERIMENTS.md"), "w") as f:
        f.write(out)
    print("wrote EXPERIMENTS.md")


if __name__ == "__main__":
    main()
