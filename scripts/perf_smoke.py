"""CI perf smoke: guard the DSE sweep hot path against regressions.

Runs the standard 24-config sweep grid (the same one ``benchmarks/dse_sweep``
measures), compares steady-state ``per_config_ms`` against the checked-in
baseline, and fails when it regresses more than the allowed factor (1.5x —
wide enough to absorb runner variance, tight enough to catch a lost
optimization). The baseline also carries the per-stage breakdown
(trace_gen / classify / stack_distance / cache_scan / dram / host_sync) from
a profiled pass, and the smoke prints per-stage deltas so a regression is
attributable to a stage, not just visible in the total.

Also runs small sweeps under every non-default cache backend ("pallas",
"stack", "stack_pallas"; Pallas variants in interpret mode on CPU) and
asserts bit-exact agreement with the scan backend in the same job, plus a
NUMA placement-axes sweep smoke (channel_affinities x placements memo keys
bit-exact vs independent simulate(), symmetric/interleave vs the axes-free
sweep) so the 1.5x gate and the exactness checks cover the placement layer.
The benchmark's placement-axes slice is additionally gated as a RATIO: its
per-config wall must stay within 2x of the base grid's (both best-of-3), so
the batched placement dispatch can't silently decay back toward the old
per-config path. A fault-tolerance overhead gate runs the base grid sharded
under a fully armed ``FaultTolerance`` (retry budget + heartbeat watchdog,
nothing firing) and asserts <5% extra wall vs the minimal policy — recovery
machinery must be free when nothing fails. A serving overhead gate does the
same for the request-level scheduler: steady-state all-policies-off serving
must stay within 10% of the equivalent plain fixed-trace wall.

Usage:  PYTHONPATH=src python scripts/perf_smoke.py [--update-baseline]
Baseline: benchmarks/perf_baseline.json (checked in; results/ is gitignored).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO_ROOT)     # for the benchmarks package

from benchmarks import dse_sweep as _bench          # noqa: E402
from repro.core import (                            # noqa: E402
    FaultTolerance,
    OnChipPolicy,
    TrafficConfig,
    dlrm_rmc2_small,
    profiling,
    simulate,
    sweep,
    tpuv6e,
)
from repro.core.memory.system import (              # noqa: E402
    EmbeddingTrace,
    MultiCoreMemorySystem,
)
from repro.core.requests import generate_requests, lower_batch  # noqa: E402
from repro.core.trace import ConcatTrace            # noqa: E402
from repro.core.workload import EmbeddingOpSpec     # noqa: E402
from repro.serving import ServingScenario, simulate_serving     # noqa: E402

BASELINE_PATH = os.path.join(_REPO_ROOT, "benchmarks", "perf_baseline.json")
REGRESSION_FACTOR = 1.5
# The placement-axes slice pays two structural costs the base grid does not
# (multi-source contended timing + the placement transform), but the batched
# dispatch keeps it within 2x of the base grid's per-config wall. An absolute
# ratio gate (not a baseline delta) so the two slices can't drift apart.
PLACEMENT_RATIO_LIMIT = 2.0

# The guarded grid IS the dse_sweep benchmark grid — imported, not copied,
# so the gate can never drift from what the benchmark measures.
GRID = dict(
    policies=_bench.POLICIES,
    capacities=_bench.CAPACITIES,
    ways=_bench.WAYS,
    zipf_s=_bench.ZIPF,
    seed=0,
)


def measure() -> "tuple[float, int, dict]":
    """Steady-state per_config_ms (best of 3, absorbing shared-runner noise)
    + a per-stage breakdown from a separate profiled pass."""
    wl = dlrm_rmc2_small(num_tables=_bench.TABLES, rows_per_table=_bench.ROWS,
                         batch_size=_bench.BATCH, num_batches=2)
    hw = tpuv6e()
    sweep(wl, hw, **GRID)                       # warm: compile every shape
    best = float("inf")
    num_configs = 0
    for _ in range(3):
        t0 = time.perf_counter()
        sr = sweep(wl, hw, **GRID)
        wall = time.perf_counter() - t0
        num_configs = sr.num_configs
        best = min(best, wall / sr.num_configs * 1e3)
    # Profiled pass (adds per-stage sync, so it is NOT the headline number).
    with profiling.collect() as prof:
        t0 = time.perf_counter()
        sweep(wl, hw, **GRID)
        profiled_wall = time.perf_counter() - t0
    stages = {
        k: round(v / num_configs * 1e3, 3)
        for k, v in prof.breakdown(total_seconds=profiled_wall).items()
    }
    return best, num_configs, stages


def measure_placement() -> "tuple[float, int]":
    """Steady-state per_config_ms of the placement-axes slice (best of 3) —
    the grid is imported from the benchmark, never copied."""
    wl = dlrm_rmc2_small(num_tables=_bench.PLACEMENT_TABLES,
                         rows_per_table=_bench.ROWS,
                         batch_size=_bench.BATCH, num_batches=2)
    hw = tpuv6e().with_cluster(2, "private", "table_hash")
    sweep(wl, hw, **_bench.PLACEMENT_AXES)      # warm
    best = float("inf")
    num_configs = 0
    for _ in range(3):
        t0 = time.perf_counter()
        sr = sweep(wl, hw, **_bench.PLACEMENT_AXES)
        wall = time.perf_counter() - t0
        num_configs = sr.num_configs
        best = min(best, wall / sr.num_configs * 1e3)
    return best, num_configs


def backend_smoke() -> None:
    """Every cache backend must run the sweep end to end (Pallas variants in
    interpret mode on CPU) and agree with the scan backend bit for bit."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=300, batch_size=2,
                         num_batches=2)
    grids = dict(policies=("lru", "srrip"), capacities=(1 << 14,), ways=(4,),
                 zipf_s=0.9, seed=0)
    ref = sweep(wl, tpuv6e().with_cache_backend("scan"), **grids)
    for backend in ("pallas", "stack", "stack_pallas"):
        got = sweep(wl, tpuv6e().with_cache_backend(backend), **grids)
        for a, b in zip(ref.entries, got.entries):
            mism = a.result.diff(b.result)
            assert not mism, (backend, a.config.label, mism)
        print(f"{backend} backend smoke: {got.num_configs} configs "
              "bit-exact vs scan")


def placement_smoke() -> None:
    """The NUMA placement axes sweep through distinct memo keys and stay
    exact: symmetric/interleave grid points equal the axes-free sweep bit for
    bit, every other point equals an independent ``simulate()`` run."""
    wl = dlrm_rmc2_small(num_tables=6, rows_per_table=1000, batch_size=4,
                         num_batches=2)
    base = tpuv6e().with_cluster(2, "private", "table_hash")
    grids = dict(policies=("spm", "lru"), capacities=(1 << 14,), ways=(4,),
                 zipf_s=1.0, seed=0)
    got = sweep(wl, base, channel_affinities=("symmetric", "per_core"),
                placements=("interleave", "table_rank"), **grids)
    assert got.num_configs == 2 * 2 * 2
    ref_by = {e.config.policy: e.result for e in sweep(wl, base, **grids).entries}
    for e in got.entries:
        c = e.config
        if c.channel_affinity == "symmetric" and c.placement == "interleave":
            mism = e.result.diff(ref_by[c.policy])
        else:
            hw = base.with_policy(
                OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes,
                ways=c.ways,
            ).with_placement(c.channel_affinity, c.placement)
            mism = e.result.diff(simulate(wl, hw, seed=0, zipf_s=c.zipf_s))
        assert not mism, (c.label, mism)
    print(f"placement axes smoke: {got.num_configs} configs (2 affinities x "
          "2 placements) bit-exact vs simulate(); symmetric/interleave "
          "bit-exact vs the axes-free sweep")


def sharded_smoke() -> None:
    """The sharded sweep path must stay bitwise identical to the plain pass
    even on this job's single real device (4 oversubscribed shards — the
    full 8-device run lives in the dse-scale job)."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=300, batch_size=2,
                         num_batches=2)
    grids = dict(policies=("spm", "lru", "pinning"), capacities=(1 << 14,),
                 ways=(4, 8), zipf_s=0.9, seed=0)
    ref = sweep(wl, tpuv6e(), **grids)
    got = sweep(wl, tpuv6e(), devices=4, **grids)
    assert got.sharded
    for a, b in zip(ref.entries, got.entries):
        mism = a.result.diff(b.result)
        assert not mism, (a.config.label, mism)
    print(f"sharded smoke: {got.num_configs} configs over 4 shards "
          f"({got.device_count} device) bit-exact vs unsharded")


# Fault-tolerance overhead gate: a fully armed recovery policy (retry budget
# + heartbeat watchdog polling, none of it firing) must cost <5% extra wall
# on the fault-free base grid vs the minimal policy. The absolute floor
# absorbs scheduler noise on sub-second walls without hiding a structural
# cost (a busy watchdog would blow through both bounds).
FAULT_OVERHEAD_FRAC = 0.05
FAULT_OVERHEAD_FLOOR_S = 0.015


def fault_overhead_smoke() -> None:
    """The fault-tolerance wrapper must be ~free when nothing fails: the
    base grid sharded under a fully armed ``FaultTolerance`` (watchdog
    polling, retry budget live) stays within 5% of the minimal policy
    (no retries, no watchdog). The unsharded 1.5x baseline gate in
    ``measure()`` separately pins the headline per-config number."""
    wl = dlrm_rmc2_small(num_tables=_bench.TABLES, rows_per_table=_bench.ROWS,
                         batch_size=_bench.BATCH, num_batches=2)
    hw = tpuv6e()
    minimal = FaultTolerance(max_retries=0, shard_timeout_s=None)
    armed = FaultTolerance(shard_timeout_s=30.0)   # armed, never fires

    def timed(tol):
        best = float("inf")
        for _ in range(3):
            sr = sweep(wl, hw, devices=2, fault_tolerance=tol, **GRID)
            assert not sr.telemetry.any_faults, sr.telemetry.to_dict()
            best = min(best, sr.wall_seconds)
        return best

    sweep(wl, hw, devices=2, **GRID)               # warm per-device compiles
    base_s = timed(minimal)
    armed_s = timed(armed)
    limit = base_s * (1 + FAULT_OVERHEAD_FRAC) + FAULT_OVERHEAD_FLOOR_S
    print(f"fault-tolerance overhead smoke: minimal={base_s * 1e3:.1f} ms "
          f"armed={armed_s * 1e3:.1f} ms "
          f"limit={limit * 1e3:.1f} ms (+{FAULT_OVERHEAD_FRAC:.0%} "
          f"+ {FAULT_OVERHEAD_FLOOR_S * 1e3:.0f} ms floor)")
    assert armed_s <= limit, (
        f"fault-tolerance wrapper costs {armed_s - base_s:.3f}s on the "
        f"fault-free base grid (>{FAULT_OVERHEAD_FRAC:.0%} + floor): the "
        "watchdog/retry machinery is no longer free when idle")


# Serving-simulator overhead gate: with every robustness policy off the
# closed-loop scheduler collapses to ONE plain fixed-trace simulation, so a
# steady-state serving run must cost within 10% of the equivalent plain path
# (request generation + batch lowering + one simulate_embedding over the
# same lowered ConcatTrace). The absolute floor absorbs scheduler noise on
# sub-second walls without hiding a structural cost (a per-batch re-sim
# would blow through both bounds).
SERVING_OVERHEAD_FRAC = 0.10
SERVING_OVERHEAD_FLOOR_S = 0.015


def serving_overhead_smoke() -> None:
    """Steady-state all-policies-off serving must stay a thin wrapper over
    the plain fixed-trace path: same request stream, same lowered concat,
    one ``simulate_embedding`` call — the event loop, latency bookkeeping
    and result assembly together cost <10% extra wall (+ floor)."""
    spec = EmbeddingOpSpec(num_tables=4, rows_per_table=2000, dim=64,
                           lookups_per_sample=8, dtype_bytes=4)
    traffic = TrafficConfig(pattern="poisson", mean_gap_cycles=1_500.0,
                            num_requests=96, seed=7, zipf_s=0.9)
    sc = ServingScenario(name="steady_off", traffic=traffic, batch_slots=8)
    assert sc.policy.all_off
    ms = MultiCoreMemorySystem.from_hardware(tpuv6e())

    def plain():
        reqs = generate_requests(spec, traffic)
        fulls = [lower_batch(reqs[i:i + sc.batch_slots], spec).full
                 for i in range(0, len(reqs), sc.batch_slots)]
        return ms.simulate_embedding(EmbeddingTrace.from_concat(
            spec, ConcatTrace.from_traces(fulls)))

    def serve():
        return simulate_serving(ms, spec, sc)

    def best_of(fn):
        best = float("inf")
        for _ in range(3):
            t0 = time.perf_counter()
            fn()
            best = min(best, time.perf_counter() - t0)
        return best

    plain(), serve()                               # warm: compile the shapes
    plain_s = best_of(plain)
    serve_s = best_of(serve)
    limit = plain_s * (1 + SERVING_OVERHEAD_FRAC) + SERVING_OVERHEAD_FLOOR_S
    print(f"serving overhead smoke: plain={plain_s * 1e3:.1f} ms "
          f"serving={serve_s * 1e3:.1f} ms "
          f"limit={limit * 1e3:.1f} ms (+{SERVING_OVERHEAD_FRAC:.0%} "
          f"+ {SERVING_OVERHEAD_FLOOR_S * 1e3:.0f} ms floor)")
    assert serve_s <= limit, (
        f"steady-state serving costs {serve_s - plain_s:.3f}s over the "
        f"equivalent plain path (>{SERVING_OVERHEAD_FRAC:.0%} + floor): the "
        "all-policies-off fast path is no longer a single plain simulation")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the measured numbers as the new baseline")
    args = ap.parse_args()

    backend_smoke()
    placement_smoke()
    sharded_smoke()
    fault_overhead_smoke()
    serving_overhead_smoke()
    per_config_ms, num_configs, stages = measure()
    placement_ms, placement_configs = measure_placement()
    ratio = placement_ms / per_config_ms

    if args.update_baseline or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump({"per_config_ms": round(per_config_ms, 3),
                       "grid_configs": num_configs,
                       "placement_per_config_ms": round(placement_ms, 3),
                       "placement_configs": placement_configs,
                       "stage_ms_per_config": stages}, f, indent=2)
        print(f"baseline written: {per_config_ms:.1f} ms/config (placement "
              f"{placement_ms:.1f}, ratio {ratio:.2f}x) -> {BASELINE_PATH}")
        return 0

    with open(BASELINE_PATH) as f:
        baseline_rec = json.load(f)
    baseline = baseline_rec["per_config_ms"]
    if baseline_rec.get("grid_configs") != num_configs:
        print(f"STALE BASELINE: grid now has {num_configs} configs, baseline "
              f"recorded {baseline_rec.get('grid_configs')} — rerun with "
              "--update-baseline", file=sys.stderr)
        return 1

    # Per-stage visibility: which stage moved, not just the total.
    base_stages = baseline_rec.get("stage_ms_per_config", {})
    for name in sorted(set(stages) | set(base_stages)):
        now = stages.get(name, 0.0)
        was = base_stages.get(name, 0.0)
        flag = ""
        if was > 0.05 and now > was * REGRESSION_FACTOR:
            flag = "  <-- regressed vs baseline"
        print(f"  stage {name:<15s} {now:8.2f} ms/config "
              f"(baseline {was:.2f}){flag}")

    limit = baseline * REGRESSION_FACTOR
    print(f"per_config_ms={per_config_ms:.1f} baseline={baseline:.1f} "
          f"limit={limit:.1f} ({REGRESSION_FACTOR}x)")
    if per_config_ms > limit:
        print("PERF REGRESSION: sweep per-config time exceeds the allowed "
              "factor over the checked-in baseline", file=sys.stderr)
        return 1
    print(f"placement_per_config_ms={placement_ms:.1f} "
          f"(baseline {baseline_rec.get('placement_per_config_ms', 0.0):.1f}) "
          f"ratio={ratio:.2f}x limit={PLACEMENT_RATIO_LIMIT}x")
    if ratio > PLACEMENT_RATIO_LIMIT:
        print("PERF REGRESSION: placement-axes slice exceeds "
              f"{PLACEMENT_RATIO_LIMIT}x the base grid's per-config time",
              file=sys.stderr)
        return 1
    print("perf smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
