"""CI perf smoke: guard the DSE sweep hot path against regressions.

Runs the standard 24-config sweep grid (the same one ``benchmarks/dse_sweep``
measures), compares steady-state ``per_config_ms`` against the checked-in
baseline, and fails when it regresses more than the allowed factor (2x — wide
enough to absorb runner variance, tight enough to catch a lost optimization).
Also runs a small sweep with ``cache_backend="pallas"`` so the Pallas kernel
path executes end to end (interpret mode on CPU) in the same job.

Usage:  PYTHONPATH=src python scripts/perf_smoke.py [--update-baseline]
Baseline: benchmarks/perf_baseline.json (checked in; results/ is gitignored).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
sys.path.insert(0, _REPO_ROOT)     # for the benchmarks package

from benchmarks import dse_sweep as _bench          # noqa: E402
from repro.core import dlrm_rmc2_small, sweep, tpuv6e  # noqa: E402

BASELINE_PATH = os.path.join(_REPO_ROOT, "benchmarks", "perf_baseline.json")
REGRESSION_FACTOR = 2.0

# The guarded grid IS the dse_sweep benchmark grid — imported, not copied,
# so the gate can never drift from what the benchmark measures.
GRID = dict(
    policies=_bench.POLICIES,
    capacities=_bench.CAPACITIES,
    ways=_bench.WAYS,
    zipf_s=_bench.ZIPF,
    seed=0,
)


def measure() -> "tuple[float, int]":
    wl = dlrm_rmc2_small(num_tables=_bench.TABLES, rows_per_table=_bench.ROWS,
                         batch_size=_bench.BATCH, num_batches=2)
    hw = tpuv6e()
    sweep(wl, hw, **GRID)                       # warm: compile every shape
    t0 = time.perf_counter()
    sr = sweep(wl, hw, **GRID)
    wall = time.perf_counter() - t0
    return wall / sr.num_configs * 1e3, sr.num_configs


def pallas_smoke() -> None:
    """The Pallas backend must run the sweep end to end (interpret on CPU)
    and agree with the scan backend bit for bit."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=300, batch_size=2,
                         num_batches=2)
    grids = dict(policies=("lru", "srrip"), capacities=(1 << 14,), ways=(4,),
                 zipf_s=0.9, seed=0)
    ref = sweep(wl, tpuv6e(), **grids)
    got = sweep(wl, tpuv6e().with_cache_backend("pallas"), **grids)
    for a, b in zip(ref.entries, got.entries):
        mism = a.result.diff(b.result)
        assert not mism, (a.config.label, mism)
    print(f"pallas backend smoke: {got.num_configs} configs bit-exact vs scan")


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--update-baseline", action="store_true",
                    help="write the measured per_config_ms as the new baseline")
    args = ap.parse_args()

    pallas_smoke()
    per_config_ms, num_configs = measure()

    if args.update_baseline or not os.path.exists(BASELINE_PATH):
        with open(BASELINE_PATH, "w") as f:
            json.dump({"per_config_ms": round(per_config_ms, 3),
                       "grid_configs": num_configs}, f, indent=2)
        print(f"baseline written: {per_config_ms:.1f} ms/config -> {BASELINE_PATH}")
        return 0

    with open(BASELINE_PATH) as f:
        baseline_rec = json.load(f)
    baseline = baseline_rec["per_config_ms"]
    if baseline_rec.get("grid_configs") != num_configs:
        print(f"STALE BASELINE: grid now has {num_configs} configs, baseline "
              f"recorded {baseline_rec.get('grid_configs')} — rerun with "
              "--update-baseline", file=sys.stderr)
        return 1
    limit = baseline * REGRESSION_FACTOR
    print(f"per_config_ms={per_config_ms:.1f} baseline={baseline:.1f} "
          f"limit={limit:.1f} ({REGRESSION_FACTOR}x)")
    if per_config_ms > limit:
        print("PERF REGRESSION: sweep per-config time exceeds the allowed "
              "factor over the checked-in baseline", file=sys.stderr)
        return 1
    print("perf smoke OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
