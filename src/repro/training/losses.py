"""Cross-entropy without materializing (B, S, V) logits.

At command-r-plus scale (vocab 256k) full logits for train_4k would be
(256, 4096, 256000) — ~1 TB in fp32. The loss is computed in sequence chunks
inside a lax.scan; within a chunk, logits stay (B, chunk, V[sharded]) and only
the per-token logsumexp and the label logit survive. With the LM head sharded
over the model axis, XLA turns the reductions into all-reduces over vocab
shards (vocab-parallel CE).
"""
from __future__ import annotations

import functools
from typing import Any, Tuple

import jax
import jax.numpy as jnp


def chunked_softmax_xent(
    hidden: jax.Array,       # (B, S, D) final hidden states
    head_w: jax.Array,       # (D, V) lm head (possibly vocab-sharded)
    labels: jax.Array,       # (B, S) int32
    *,
    chunk: int = 512,
    label_mask: jax.Array | None = None,   # (B, S) 1 = count this token
) -> jax.Array:
    B, S, D = hidden.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    h = hidden.reshape(B, n_chunks, chunk, D).transpose(1, 0, 2, 3)
    y = labels.reshape(B, n_chunks, chunk).transpose(1, 0, 2)
    if label_mask is None:
        m = jnp.ones((n_chunks, B, chunk), dtype=jnp.float32)
    else:
        m = label_mask.reshape(B, n_chunks, chunk).transpose(1, 0, 2).astype(jnp.float32)

    def body(acc, inp):
        h_c, y_c, m_c = inp
        logits = (h_c.astype(jnp.float32) @ head_w.astype(jnp.float32))
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y_c[..., None], axis=-1)[..., 0]
        loss = (lse - gold) * m_c
        return (acc[0] + loss.sum(), acc[1] + m_c.sum()), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0), jnp.float32(0)), (h, y, m)
    )
    return total / jnp.maximum(count, 1.0)


def full_softmax_xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    """Reference (small-model) path."""
    lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
    gold = jnp.take_along_axis(
        logits.astype(jnp.float32), labels[..., None], axis=-1
    )[..., 0]
    return jnp.mean(lse - gold)
