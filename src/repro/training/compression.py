"""Gradient compression with error feedback — for cross-pod data parallelism.

On a multi-pod mesh the ``pod`` axis rides the slow inter-pod links; the
standard mitigation is to compress the DP gradient exchange. Two compressors:

  * int8 blockwise (absmax scales) — ~4x traffic reduction, near-lossless
    with error feedback;
  * top-k magnitude sparsification — ~(1/density)x, for extreme cases.

Error feedback (Karimireddy et al.): the compression residual is added back
into the next step's gradient, making biased compressors convergent. The
compressor runs *before* the (simulated) cross-pod all-reduce; tests verify
convergence parity on a quadratic problem and a tiny LM.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, Tuple

import jax
import jax.numpy as jnp

CBLOCK = 256


@dataclass(frozen=True)
class CompressionConfig:
    kind: str = "none"              # none | int8 | topk
    topk_density: float = 0.01
    error_feedback: bool = True

    @property
    def enabled(self) -> bool:
        return self.kind != "none"


def init_error(params: Any) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def _int8_roundtrip(g: jax.Array) -> jax.Array:
    flat = g.reshape(-1)
    pad = (-flat.size) % CBLOCK
    fp = jnp.pad(flat, (0, pad)).reshape(-1, CBLOCK)
    scale = jnp.max(jnp.abs(fp), axis=1, keepdims=True) / 127.0
    q = jnp.round(fp / jnp.maximum(scale, 1e-12)).astype(jnp.int8)
    deq = (q.astype(jnp.float32) * scale).reshape(-1)[: flat.size]
    return deq.reshape(g.shape)


def _topk_roundtrip(g: jax.Array, density: float) -> jax.Array:
    flat = g.reshape(-1)
    k = max(1, int(flat.size * density))
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(g.shape)


def compress_grads(
    grads: Any, error: Any, cfg: CompressionConfig
) -> Tuple[Any, Any, dict]:
    """Returns (decompressed grads as they arrive after the wire, new error
    state, metrics). Identity when disabled."""
    if not cfg.enabled:
        return grads, error, {"compression_ratio": 1.0}

    def one(g, e):
        gf = g.astype(jnp.float32) + (e if cfg.error_feedback else 0.0)
        if cfg.kind == "int8":
            sent = _int8_roundtrip(gf)
            ratio = 4.0 * CBLOCK / (CBLOCK + 4)      # int8 payload + fp32 scale
        elif cfg.kind == "topk":
            sent = _topk_roundtrip(gf, cfg.topk_density)
            ratio = 1.0 / (2 * cfg.topk_density)     # value+index per entry
        else:
            raise ValueError(cfg.kind)
        new_e = gf - sent if cfg.error_feedback else jnp.zeros_like(gf)
        return sent.astype(g.dtype), new_e, ratio

    flat_g, treedef = jax.tree.flatten(grads)
    flat_e = treedef.flatten_up_to(error)
    outs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = treedef.unflatten([o[0] for o in outs])
    new_e = treedef.unflatten([o[1] for o in outs])
    return new_g, new_e, {"compression_ratio": outs[0][2] if outs else 1.0}
