from .optimizer import AdamWConfig, AdamWState, init as adamw_init, apply as adamw_apply
from .losses import chunked_softmax_xent, full_softmax_xent
from .compression import CompressionConfig, compress_grads, init_error
from .train_step import TrainConfig, build_loss_fn, build_train_step, init_state

__all__ = [
    "AdamWConfig",
    "AdamWState",
    "adamw_init",
    "adamw_apply",
    "chunked_softmax_xent",
    "full_softmax_xent",
    "CompressionConfig",
    "compress_grads",
    "init_error",
    "TrainConfig",
    "build_loss_fn",
    "build_train_step",
    "init_state",
]
