"""Train-step builder: loss (chunked CE) -> grads -> compression -> AdamW.

One builder covers every assigned arch family; the returned function is pure
and jit/pjit-able (the launcher supplies in/out shardings). Gradient
accumulation (microbatching) wraps the same loss via lax.scan.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..models import family_module
from ..models.config import ArchConfig
from . import compression, losses, optimizer


@dataclass(frozen=True)
class TrainConfig:
    adamw: optimizer.AdamWConfig = field(default_factory=optimizer.AdamWConfig)
    compression: compression.CompressionConfig = field(
        default_factory=compression.CompressionConfig
    )
    loss_chunk: int = 512
    remat: bool = True
    use_pallas: bool = False
    microbatches: int = 1


class TrainState(dict):
    """params / opt / err(optional) / step — a plain dict for easy pytree IO."""


def init_state(key, cfg: ArchConfig, tcfg: TrainConfig) -> Dict[str, Any]:
    mod = family_module(cfg)
    if cfg.family == "audio":
        params = mod.init_model(key, cfg)
    else:
        params = mod.init_lm(key, cfg)
    state = {
        "params": params,
        "opt": optimizer.init(params, tcfg.adamw),
        "step": jnp.zeros((), jnp.int32),
    }
    if tcfg.compression.enabled and tcfg.compression.error_feedback:
        state["err"] = compression.init_error(params)
    return state


def _head_weight(params, cfg: ArchConfig):
    from ..distributed.sharding import fsdp_unshard

    if cfg.tie_embeddings or "head" not in params:
        return fsdp_unshard(params["embed"])["table"].T
    return fsdp_unshard({"head": params["head"]})["head"]["w"]


def build_loss_fn(cfg: ArchConfig, tcfg: TrainConfig) -> Callable:
    mod = family_module(cfg)

    def loss_fn(params, batch):
        if cfg.family == "audio":
            enc = mod.encode(params, batch["frames"], cfg, use_pallas=tcfg.use_pallas)
            hidden, _ = mod.decode_hidden(
                params, batch["tokens"], enc, cfg, use_pallas=tcfg.use_pallas
            )
        else:
            hidden = mod.final_hidden(
                params, batch["tokens"], cfg,
                use_pallas=tcfg.use_pallas, remat=tcfg.remat,
            )
        chunk = min(tcfg.loss_chunk, hidden.shape[1])
        while hidden.shape[1] % chunk:
            chunk -= 1
        return losses.chunked_softmax_xent(
            hidden, _head_weight(params, cfg), batch["labels"], chunk=chunk
        )

    return loss_fn


def build_train_step(cfg: ArchConfig, tcfg: TrainConfig) -> Callable:
    loss_fn = build_loss_fn(cfg, tcfg)
    grad_fn = jax.value_and_grad(loss_fn)

    def train_step(state: Dict[str, Any], batch: Dict[str, jax.Array]):
        params = state["params"]

        if tcfg.microbatches > 1:
            def micro(carry, mb):
                loss_acc, grad_acc = carry
                loss, grads = grad_fn(params, mb)
                grad_acc = jax.tree.map(jnp.add, grad_acc, grads)
                return (loss_acc + loss, grad_acc), None

            mb_batch = jax.tree.map(
                lambda x: x.reshape(tcfg.microbatches, -1, *x.shape[1:]), batch
            )
            zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(micro, (jnp.float32(0), zeros), mb_batch)
            loss = loss / tcfg.microbatches
            grads = jax.tree.map(lambda g: g / tcfg.microbatches, grads)
        else:
            loss, grads = grad_fn(params, batch)

        metrics = {"loss": loss}
        new_state = dict(state)
        if tcfg.compression.enabled:
            grads, new_err, cm = compression.compress_grads(
                grads, state.get("err"), tcfg.compression
            )
            new_state["err"] = new_err
            metrics.update(cm)

        new_params, new_opt, om = optimizer.apply(params, grads, state["opt"], tcfg.adamw)
        metrics.update(om)
        new_state.update(params=new_params, opt=new_opt, step=state["step"] + 1)
        return new_state, metrics

    return train_step
