"""AdamW with optional 8-bit (block-quantized) first/second moments.

The 8-bit option is a distributed-optimization feature for the largest
assigned archs (arctic-480b): moment tensors are stored int8 with per-block
fp32 scales (blockwise absmax quantization, Dettmers-style), cutting optimizer
state from 8 bytes/param to ~2.06 bytes/param so the 480B model's state fits
the 256-chip pod (EXPERIMENTS.md §Dry-run shows the per-device numbers).
"""
from __future__ import annotations

import functools
from dataclasses import dataclass
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

QBLOCK = 256


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    quantize_state: bool = False      # int8 moments + fp32 block scales
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    prog = jnp.clip(
        (step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
        0.0, 1.0,
    )
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * cos)


# ---- blockwise int8 quantization ------------------------------------------
# Blocks run along the LAST dim so the int8 moment keeps the parameter's
# shape (and therefore its PartitionSpec); scales get shape[:-1] + (nb,).

def _quantize(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    last = x.shape[-1] if x.ndim else 1
    xr = x.reshape(*x.shape[:-1], last) if x.ndim else x.reshape(1)
    nb = -(-last // QBLOCK)
    pad = nb * QBLOCK - last
    xp = jnp.pad(xr, [(0, 0)] * (xr.ndim - 1) + [(0, pad)])
    blocks = xp.reshape(*xr.shape[:-1], nb, QBLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=-1) / 127.0          # (..., nb)
    q = jnp.round(
        blocks / jnp.maximum(scale[..., None], 1e-12)
    ).astype(jnp.int8).reshape(*xr.shape[:-1], nb * QBLOCK)[..., :last]
    return q.reshape(x.shape), scale.astype(jnp.float32)


def _dequantize(q: jax.Array, scale: jax.Array, shape, size) -> jax.Array:
    last = q.shape[-1] if q.ndim else 1
    nb = scale.shape[-1]
    pad = nb * QBLOCK - last
    qp = jnp.pad(q.reshape(*q.shape[:-1], last), [(0, 0)] * (q.ndim - 1) + [(0, pad)])
    blocks = qp.reshape(*q.shape[:-1], nb, QBLOCK).astype(jnp.float32)
    return (blocks * scale[..., None]).reshape(*q.shape[:-1], nb * QBLOCK)[
        ..., :last
    ].reshape(shape)


class MomentState(NamedTuple):
    value: Any          # fp32 tensor OR (int8 blocks, fp32 scales)


def _init_moment(p: jax.Array, quantize: bool):
    if quantize:
        q, s = _quantize(jnp.zeros_like(p, dtype=jnp.float32))
        return (q, s)
    return jnp.zeros_like(p, dtype=jnp.float32)


def _read_moment(m, p: jax.Array, quantize: bool, kind: str = "mu") -> jax.Array:
    if not quantize:
        return m
    q, s = m
    if kind == "nu":
        # second moment stored in sqrt domain with a half-step floor:
        # linear absmax int8 rounds small v to 0 and m/(sqrt(0)+eps)
        # explodes (measured: loss climbs within 15 steps). The floor makes
        # tiny-v params UNDER-step instead.
        root = jnp.maximum(
            _dequantize(q, s, p.shape, p.size),
            0.5 * _broadcast_scale(s, p.shape),
        )
        return root * root
    return _dequantize(q, s, p.shape, p.size)


def _broadcast_scale(scale: jax.Array, shape) -> jax.Array:
    last = shape[-1] if shape else 1
    nb = scale.shape[-1]
    rep = jnp.repeat(scale, QBLOCK, axis=-1)[..., :last]
    return rep.reshape(shape)


def _write_moment(val: jax.Array, quantize: bool, kind: str = "mu"):
    if not quantize:
        return val
    if kind == "nu":
        return _quantize(jnp.sqrt(jnp.maximum(val, 0.0)))
    return _quantize(val)


class AdamWState(NamedTuple):
    step: jax.Array
    mu: Any
    nu: Any


def init(params: Any, cfg: AdamWConfig) -> AdamWState:
    mu = jax.tree.map(lambda p: _init_moment(p, cfg.quantize_state), params)
    nu = jax.tree.map(lambda p: _init_moment(p, cfg.quantize_state), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), mu=mu, nu=nu)


def global_norm(grads: Any) -> jax.Array:
    leaves = jax.tree.leaves(grads)
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32))) for g in leaves))


def apply(
    params: Any,
    grads: Any,
    state: AdamWState,
    cfg: AdamWConfig,
) -> Tuple[Any, AdamWState, dict]:
    step = state.step + 1
    lr = lr_schedule(cfg, step)
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1 - b1 ** step.astype(jnp.float32)
    bc2 = 1 - b2 ** step.astype(jnp.float32)

    is_q_leaf = lambda x: isinstance(x, tuple) and len(x) == 2 and not isinstance(x[0], dict)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m_f = _read_moment(m, p, cfg.quantize_state, "mu")
        v_f = _read_moment(v, p, cfg.quantize_state, "nu")
        m_f = b1 * m_f + (1 - b1) * g
        v_f = b2 * v_f + (1 - b2) * g * g
        upd = (m_f / bc1) / (jnp.sqrt(v_f / bc2) + cfg.eps)
        new_p = p.astype(jnp.float32) - lr * (upd + cfg.weight_decay * p.astype(jnp.float32))
        return (
            new_p.astype(p.dtype),
            _write_moment(m_f, cfg.quantize_state, "mu"),
            _write_moment(v_f, cfg.quantize_state, "nu"),
        )

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    metrics = {"lr": lr, "grad_norm": gnorm}
    return new_p, AdamWState(step=step, mu=new_m, nu=new_v), metrics
