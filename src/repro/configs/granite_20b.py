"""granite-20b [dense] — granite-34b geometry at 52 layers. [arXiv:2405.04324; hf]"""
from repro.models.config import ArchConfig
from . import granite_34b


def config() -> ArchConfig:
    return granite_34b.config().replace(name="granite-20b", n_layers=52)


def smoke() -> ArchConfig:
    return granite_34b.smoke().replace(name="granite-20b", n_layers=2)
