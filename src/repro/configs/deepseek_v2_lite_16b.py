"""deepseek-v2-lite-16b [moe] — MLA (kv_lora=512), 2 shared + 64 routed
experts top-6. [arXiv:2405.04434; hf]"""
from repro.models.config import ArchConfig, MLAConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="deepseek-v2-lite-16b",
        family="moe",
        n_layers=27,
        d_model=2048,
        n_heads=16,
        n_kv_heads=16,
        d_ff=0,                          # FFN is fully MoE (shared + routed)
        vocab=102400,
        mla=MLAConfig(
            kv_lora_rank=512,
            qk_nope_head_dim=128,
            qk_rope_head_dim=64,
            v_head_dim=128,
        ),
        moe=MoEConfig(
            num_experts=64, top_k=6, d_ff_expert=1408,
            num_shared_experts=2, d_ff_shared=2816,
        ),
        notes="MLA latent-KV attention; serving caches the 512+64-wide latent "
              "instead of full per-head KV",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, vocab=256, n_kv_heads=4,
        mla=MLAConfig(kv_lora_rank=32, qk_nope_head_dim=16,
                      qk_rope_head_dim=8, v_head_dim=16),
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=48,
                      num_shared_experts=1, d_ff_shared=48,
                      capacity_factor=4.0, dispatch_groups=2),
    )
