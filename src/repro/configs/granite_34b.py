"""granite-34b [dense] — gpt-bigcode-style MQA (kv=1), 2-matrix GELU MLP
(param math: 88 x (attn 77M + mlp 302M) + embeddings = 34B). [arXiv:2405.04324; hf]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="granite-34b",
        family="dense",
        n_layers=88,
        d_model=6144,
        n_heads=48,
        n_kv_heads=1,
        head_dim=128,
        d_ff=24576,
        vocab=49152,
        mlp_type="gelu",
        notes="MQA code model; 2-matrix MLP matches the 34B total "
              "(a SwiGLU MLP would give 47B)",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, head_dim=16,
        d_ff=128, vocab=256,
    )
