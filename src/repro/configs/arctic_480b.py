"""arctic-480b [moe] — 128 experts top-2 + dense residual MLP per layer.
[hf:Snowflake/snowflake-arctic-base; hf]"""
from repro.models.config import ArchConfig, MoEConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="arctic-480b",
        family="moe",
        n_layers=35,
        d_model=7168,
        n_heads=56,
        n_kv_heads=8,
        head_dim=128,
        d_ff=4864,                       # dense residual MLP
        vocab=32000,
        moe=MoEConfig(num_experts=128, top_k=2, d_ff_expert=4864),
        notes="dense-MoE hybrid: every layer has a dense SwiGLU residual in "
              "parallel with the 128-expert top-2 MoE FFN",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=96, vocab=256,
        # dropless at smoke scale so serve-vs-forward is exact
        moe=MoEConfig(num_experts=4, top_k=2, d_ff_expert=96,
                      capacity_factor=4.0, dispatch_groups=2),
    )
