"""One module per assigned architecture; each exports config() and smoke().

Config sources are cited per file ([source; verified-tier] from the brief).
``smoke()`` returns a reduced same-family config for CPU tests.
"""
