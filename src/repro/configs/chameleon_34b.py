"""chameleon-34b [vlm] — early-fusion: VQ image tokens share the text vocab;
the VQ tokenizer frontend is a stub (tokens arrive pre-quantized).
[arXiv:2405.09818; unverified]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="chameleon-34b",
        family="vlm",
        n_layers=48,
        d_model=8192,
        n_heads=64,
        n_kv_heads=8,
        head_dim=128,
        d_ff=22016,
        vocab=65536,
        notes="early-fusion VLM == decoder LM over a mixed text+VQ-code vocab; "
              "the skewed-code reuse story maps directly onto EONSim's "
              "embedding traces",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
    )
