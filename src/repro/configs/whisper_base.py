"""whisper-base [audio] — enc-dec; conv/mel frontend STUBBED (input_specs
provides precomputed frame embeddings). [arXiv:2212.04356; unverified]"""
from repro.models.config import ArchConfig, EncDecConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="whisper-base",
        family="audio",
        n_layers=6,                      # decoder layers
        d_model=512,
        n_heads=8,
        n_kv_heads=8,
        head_dim=64,
        d_ff=2048,
        vocab=51865,
        tie_embeddings=True,
        encdec=EncDecConfig(encoder_layers=6, encoder_seq=1500),
        notes="frontend stub per brief: encoder consumes precomputed "
              "(B, 1500, 512) frame embeddings",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        encdec=EncDecConfig(encoder_layers=2, encoder_seq=64),
    )
