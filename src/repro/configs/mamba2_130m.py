"""mamba2-130m [ssm] — attention-free SSD. [arXiv:2405.21060; unverified]"""
from repro.models.config import ArchConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="mamba2-130m",
        family="ssm",
        n_layers=24,
        d_model=768,
        n_heads=0,                       # attention-free
        n_kv_heads=0,
        d_ff=0,
        vocab=50280,
        tie_embeddings=True,
        ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4),
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, vocab=256,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=32),
    )
