"""zamba2-2.7b [hybrid] — Mamba2 backbone + shared attention block.
[arXiv:2411.15242; hf]"""
from repro.models.config import ArchConfig, HybridConfig, SSMConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="zamba2-2.7b",
        family="hybrid",
        n_layers=54,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=10240,
        vocab=32000,
        ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, conv_width=4),
        hybrid=HybridConfig(attn_every=6, shared_d_ff=10240),
        notes="54 Mamba2 layers; ONE shared attention+MLP block applied every "
              "6 layers (per-application LoRA deltas omitted; ~2.4B of the "
              "2.7B captured)",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=4, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab=256,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4, chunk=32),
        hybrid=HybridConfig(attn_every=2, shared_d_ff=128),
    )
