"""stablelm-3b [dense]. [hf:stabilityai/stablelm-2-1_6b; unverified]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="stablelm-3b",
        family="dense",
        n_layers=32,
        d_model=2560,
        n_heads=32,
        n_kv_heads=32,
        head_dim=80,
        d_ff=6912,
        vocab=50304,
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=96, vocab=256,
    )
