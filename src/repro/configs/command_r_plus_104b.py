"""command-r-plus-104b [dense] — GQA, no-bias, 256k vocab (the largest
embedding surface of the pool: 3.1 GB table -> prime hot-pinning target).
[hf:CohereForAI/c4ai-command-r-v01; unverified]"""
from repro.models.config import ArchConfig


def config() -> ArchConfig:
    return ArchConfig(
        name="command-r-plus-104b",
        family="dense",
        n_layers=64,
        d_model=12288,
        n_heads=96,
        n_kv_heads=8,
        head_dim=128,
        d_ff=33792,
        vocab=256000,
        notes="256k vocab: vocab-parallel embedding + chunked CE are "
              "mandatory at this scale",
    )


def smoke() -> ArchConfig:
    return config().replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab=512,
    )
