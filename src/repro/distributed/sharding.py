"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

2D "FSDP x TP" layout (MaxText-style) on mesh axes (data, model), optionally
with a leading pod axis for multi-pod runs:

  * (in, out) projections:   P(data, model)   — out-dim TP, in-dim FSDP
  * (in, out) down/out proj: P(model, data)   — in-dim TP (contracting)
  * embedding (V, D):        P(model, data)   — vocab-parallel
  * lm head (D, V):          P(data, model)   — vocab-parallel logits
  * MoE expert stacks (E, D, F): P(model, data, None) — EP on the model axis
  * vectors / norms: replicated

Every rule is divisibility-checked against the mesh; a non-divisible dim
falls back to replication for that axis (never fails to lower). Stacked layer
leaves (leading scan dim) get a leading None.
"""
from __future__ import annotations

import re
from typing import Any, Optional, Sequence, Tuple

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ArchConfig, ShapeConfig

# params stacked under these keys carry leading scan dims
_STACK_DEPTH = {"layers": 1, "groups": 2, "enc_layers": 1, "dec_layers": 1}

_OUT_TP = {"wq", "wk", "wv", "wg", "wu", "w1", "in_z", "in_xbc", "in_dt",
           "w_dkv", "w_uk", "w_uv", "router"}
_IN_TP = {"wo", "wd", "w2", "out_proj"}


def _axis_size(mesh_axes: dict, name) -> int:
    if name is None:
        return 1
    if isinstance(name, tuple):
        return int(np.prod([mesh_axes[n] for n in name]))
    return mesh_axes[name]


def _fit(dim: int, ax, mesh_axes: dict):
    """Return ax if dim divides evenly on it, else None (replicate)."""
    return ax if ax is not None and dim % _axis_size(mesh_axes, ax) == 0 else None


def _leaf_spec(path_keys, shape, mesh_axes, data_ax, model_ax) -> P:
    name = path_keys[-1]
    # quantized optimizer moments: tuple (int8 q, fp32 scales) under the
    # weight's path — q keeps the weight's spec; scales drop the last axis
    if name in ("0", "1") and len(path_keys) >= 2 and any(
        k in ("mu", "nu") for k in path_keys
    ):
        base = _leaf_spec(path_keys[:-1], shape, mesh_axes, data_ax, model_ax)
        if name == "1" and len(base) >= 1:
            return P(*base[:-1], None)
        return base
    stack = 0
    in_moe = False
    for k in path_keys:
        if k in _STACK_DEPTH:
            stack = _STACK_DEPTH[k]
        if k == "moe":
            in_moe = True
    core_rank = len(shape) - stack
    lead = (None,) * stack

    def spec(*axes):
        fitted = tuple(
            _fit(shape[stack + i], ax, mesh_axes) for i, ax in enumerate(axes)
        )
        return P(*lead, *fitted)

    if core_rank <= 1:
        return P(*lead, *(None,) * max(core_rank, 0))

    if in_moe and core_rank == 3 and name in ("wg", "wu"):
        return spec(model_ax, data_ax, None)        # (E, D, F)
    if in_moe and core_rank == 3 and name == "wd":
        return spec(model_ax, None, data_ax)        # (E, F, D)
    if name == "table":                              # embedding (V, D)
        return spec(model_ax, data_ax)
    if name == "w" and "head" in path_keys:          # lm head (D, V)
        return spec(data_ax, model_ax)
    if name == "pos_dec":
        return spec(None, data_ax)
    if name == "conv_w":                             # (W, Ch)
        return spec(None, model_ax)
    if name in _OUT_TP and core_rank == 2:
        return spec(data_ax, model_ax)
    if name in _IN_TP and core_rank == 2:
        return spec(model_ax, data_ax)
    if name in ("w", "w1", "w2") and core_rank == 2:  # dlrm mlps etc.
        return spec(data_ax, model_ax)
    return P(*lead, *(None,) * core_rank)


def _path_names(path) -> Tuple[str, ...]:
    out = []
    for p in path:
        if hasattr(p, "key"):
            out.append(str(p.key))
        elif hasattr(p, "idx"):
            out.append(str(p.idx))
        elif hasattr(p, "name"):           # GetAttrKey (NamedTuple fields)
            out.append(str(p.name))
        else:
            out.append(str(p))
    return tuple(out)


def param_specs(
    params_shape: Any,
    mesh: Mesh,
    *,
    data_ax="data",
    model_ax="model",
    fsdp_over_pod: bool = True,
) -> Any:
    """PartitionSpec pytree for a params (shape) pytree.

    On multi-pod meshes, FSDP additionally spans the pod axis
    (``fsdp_over_pod``) so optimizer state divides across all chips.
    """
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    d_ax = data_ax
    if fsdp_over_pod and "pod" in mesh_axes and mesh_axes["pod"] > 1:
        d_ax = ("pod", data_ax)

    def fn(path, leaf):
        return _leaf_spec(_path_names(path), leaf.shape, mesh_axes, d_ax, model_ax)

    return jax.tree_util.tree_map_with_path(fn, params_shape)


def batch_spec(shape: ShapeConfig, mesh: Mesh) -> P:
    """Token batches: batch over (pod, data); seq replicated — except
    long_500k (batch=1) where the sequence shards over data (SP)."""
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = ("pod", "data") if "pod" in mesh_axes else ("data",)
    if shape.global_batch % _axis_size(mesh_axes, tuple(dp)) == 0:
        return P(dp if len(dp) > 1 else dp[0], None)
    if shape.seq_len % mesh_axes["data"] == 0:
        return P(None, "data")                      # sequence parallelism
    return P(None, None)


def kv_cache_spec(cfg: ArchConfig, shape: ShapeConfig, mesh: Mesh) -> Any:
    """Spec for (L, B, Hkv, S, dh) caches (or MLA latent (L, B, S, w))."""
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = ("pod", "data") if "pod" in mesh_axes else ("data",)
    dp_name = dp if len(dp) > 1 else dp[0]
    b_ok = shape.global_batch % _axis_size(mesh_axes, tuple(dp)) == 0
    b_ax = dp_name if b_ok else None

    if cfg.mla is not None:
        width = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim
        s_ax = "model" if shape.seq_len % mesh_axes["model"] == 0 else None
        return P(None, b_ax, s_ax, None)

    hkv, dh = cfg.n_kv_heads, cfg.attn_head_dim
    if hkv and hkv % mesh_axes["model"] == 0:
        return P(None, b_ax, "model", None, None)
    if not b_ok and shape.seq_len % mesh_axes["data"] == 0:
        # long-context single-batch: shard the KV sequence (ring/LSE decode)
        return P(None, None, None, "data", None)
    if dh and dh % mesh_axes["model"] == 0:
        return P(None, b_ax, None, None, "model")
    return P(None, b_ax, None, None, None)


def fsdp_unshard(params: Any) -> Any:
    """Constrain parameters to their TP-only (data-axis-gathered) layout.

    2D "FSDP x TP" weight sharding leaves the contraction dim of every matmul
    sharded over the data axis; without guidance GSPMD partial-sums the
    matmul and ALL-REDUCES THE ACTIVATIONS (measured: 5.3 TB/device/step on
    command-r train — f32 (B,S,F/TP) reduces per layer per microbatch).
    Constraining the weights to P(None, model) at point of use turns that
    into a per-layer weight all-gather (W/TP bytes — 30x less traffic) that
    the scheduler can prefetch. Called inside the layer-scan body, so only
    one layer's gathered weights are live at a time (ZeRO-3 semantics).

    No-op when tracing without a mesh (CPU tests) — detected via the
    abstract mesh.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return params
        axes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return params

    def fn(path, leaf):
        if not hasattr(leaf, "shape") or leaf.ndim < 2:
            return leaf
        spec = _leaf_spec(_path_names(path), leaf.shape, axes, None, "model")
        return jax.lax.with_sharding_constraint(leaf, spec)

    return jax.tree_util.tree_map_with_path(fn, params)


def activation_constraint(x: Any, batch_dim: int = 0) -> Any:
    """Pin activations to the canonical batch-sharded layout.

    The embedding table is (vocab x d_model) sharded (model, data); without a
    constraint its D-over-data sharding propagates into the residual stream,
    and every subsequent matmul contracts a data-sharded dim -> GSPMD emits
    full-activation all-reduces over the data axis (measured 5.3 TB/device on
    command-r train). Constraining x to P(dp, None, ...) right after embed
    keeps the stream batch-sharded. Falls back to sequence sharding when the
    batch doesn't divide (long_500k), no-op without a mesh.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "data" not in mesh.axis_names:
            return x
        axes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return x
    dp = ("pod", "data") if axes.get("pod", 1) > 1 else ("data",)
    dp_size = int(np.prod([axes[a] for a in dp]))
    dp_ax = dp if len(dp) > 1 else dp[0]
    spec = [None] * x.ndim
    if x.shape[batch_dim] % dp_size == 0:
        spec[batch_dim] = dp_ax
    elif x.ndim > batch_dim + 1 and x.shape[batch_dim + 1] % axes["data"] == 0:
        spec[batch_dim + 1] = "data"       # sequence parallelism
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain(x: Any, *axes) -> Any:
    """Guarded with_sharding_constraint: 'dp' expands to the data(+pod) axes;
    non-divisible or absent axes fall back to None; no-op without a mesh."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return x
    dp = ("pod", "data") if sizes.get("pod", 1) > 1 else ("data",)
    dp_ax = dp if len(dp) > 1 else dp[0]
    dp_size = int(np.prod([sizes.get(a, 1) for a in dp]))
    spec = []
    for dim, ax in zip(x.shape, axes):
        if ax == "dp":
            spec.append(dp_ax if (dim % dp_size == 0 and "data" in sizes) else None)
        elif ax is not None and ax in sizes and dim % sizes[ax] == 0:
            spec.append(ax)
        else:
            spec.append(None)
    return jax.lax.with_sharding_constraint(x, P(*spec))


def shard_attention_q(q: Any) -> Any:
    """Keep attention score compute sharded when heads don't divide TP.

    q: (B, H, S, dh). With H % model != 0 (arctic: 56 heads on a 16-way
    axis), GSPMD replicates the (S, S) score computation on every model
    shard — measured 10x compute bloat. Sharding the QUERY sequence over the
    model axis instead balances the scores for any head count (kv stays
    whole, as every q block needs it). Heads are preferred when divisible.
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or mesh.empty or "model" not in mesh.axis_names:
            return q
        axes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return q
    m = axes["model"]
    dp = ("pod", "data") if axes.get("pod", 1) > 1 else ("data",)
    dp_size = int(np.prod([axes.get(a, 1) for a in dp]))
    dp_ax = dp if len(dp) > 1 else dp[0]
    b_ax = dp_ax if q.shape[0] % dp_size == 0 else None
    if q.shape[1] % m == 0:
        return jax.lax.with_sharding_constraint(q, P(b_ax, "model", None, None))
    if q.shape[2] % m == 0:
        return jax.lax.with_sharding_constraint(q, P(b_ax, None, "model", None))
    return q


def greedy_spec(shape: Sequence[int], mesh: Mesh, priorities) -> P:
    """Assign mesh axes to dims by priority, respecting divisibility.

    ``priorities``: iterable of (dim_index, axis_name); first fit wins, each
    axis used at most once. Used for serve-time caches (SSM states, conv
    states) whose best layout varies by arch geometry.
    """
    mesh_axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    assigned = {}
    used = set()
    for dim, ax in priorities:
        if dim in assigned or ax in used or ax not in mesh_axes:
            continue
        if 0 <= dim < len(shape) and shape[dim] % mesh_axes[ax] == 0:
            assigned[dim] = ax
            used.add(ax)
    return P(*[assigned.get(i) for i in range(len(shape))])


def make_sharding(mesh: Mesh, spec: P) -> NamedSharding:
    return NamedSharding(mesh, spec)


def tree_shardings(mesh: Mesh, specs: Any) -> Any:
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s),
        specs,
        is_leaf=lambda x: isinstance(x, P),
    )
