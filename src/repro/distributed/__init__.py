from .sharding import (
    batch_spec,
    kv_cache_spec,
    make_sharding,
    param_specs,
    tree_shardings,
)

__all__ = [
    "batch_spec",
    "kv_cache_spec",
    "make_sharding",
    "param_specs",
    "tree_shardings",
]
