"""Device sharding for the DSE sweep's memo-key space.

The sweep engine reduces a config grid to a set of memo keys (distinct
classification + DRAM-timing evaluations). Those keys are embarrassingly
parallel — every batching layer underneath (`classify_embedding_many`, the
stack/rrip analytic passes, ``dram_timing_many``) is bit-exact regardless of
batch composition — so scaling out is a pure partitioning problem:

  * **Partition by class-key group**, not by key: placement siblings share
    ONE classification with their class key, so splitting a group across
    shards would re-classify it per shard. Whole groups round-robin across
    shards by size (largest first) for balance, deterministically.
  * **One supervised worker thread per shard**, each evaluating its key
    subset through the regular engine with jit dispatch pinned to its
    device via ``jax.default_device`` (thread-local in jax, so shards
    target distinct devices concurrently; the GIL releases inside XLA
    executions). The per-shard stats dicts merge back into the single memo
    table — bitwise identical to the unsharded pass,
    differential-enforced.
  * **Fault tolerance** (see ``core/faults.py`` for the taxonomy): each
    worker retries transient failures in place with seeded exponential
    backoff; a heartbeat watchdog (armed via
    ``FaultTolerance.shard_timeout_s``) abandons hung shards; crashed or
    hung shards have their memo keys re-partitioned onto the survivors
    (the plan shrinks, the sweep completes — ``strict=True`` raises
    instead). Because the batching layers are composition-invariant, every
    recovery path is bitwise identical to the fault-free run. Fatal errors
    (bugs, not infrastructure) raise ``ShardEvaluationError`` with shard/
    device/key-group context, carrying all completed sibling-shard results
    so surviving work is never discarded.
  * **Cross-device gather check** through the ``shard_map_compat`` version
    shim (the same one the collective matmul uses): each shard contributes
    its key count on its mesh position and a psum must see every shard —
    a cheap end-to-end assertion that the mesh actually spans the devices
    the plan claims (validated on CPU CI under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``sweep(devices=8)`` is the user surface; this module only plans and
executes the partition.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from ..core import profiling
from ..core.faults import (
    FaultInjector,
    FaultTelemetry,
    FaultTolerance,
    FaultToleranceExhausted,
    ShardEvaluationError,
    backoff_seconds,
    classify_exception,
)
from .collective_matmul import shard_map_compat

__all__ = [
    "ShardPlan",
    "resolve_shard_plan",
    "partition_by_class_key",
    "evaluate_sharded",
    "shard_key_totals",
]


@dataclass(frozen=True)
class ShardPlan:
    """How to split one evaluation round: ``devices[i]`` hosts shard i."""

    devices: tuple            # one jax.Device per shard (may repeat)

    @property
    def num_shards(self) -> int:
        return len(self.devices)

    @property
    def distinct_devices(self) -> int:
        return len({id(d) for d in self.devices})


def resolve_shard_plan(devices) -> ShardPlan:
    """``devices`` as an int takes that many shards cycled over the local
    jax devices (oversubscribing when fewer exist — still bit-exact, just
    less parallel); a device sequence pins one shard per device."""
    if isinstance(devices, int):
        if devices < 1:
            raise ValueError(f"need >= 1 shard, got {devices}")
        local = jax.devices()
        devs = tuple(itertools.islice(itertools.cycle(local), devices))
    else:
        devs = tuple(devices)
        if not devs:
            raise ValueError("empty device sequence")
    return ShardPlan(devices=devs)


def partition_by_class_key(
    items: Dict[tuple, tuple], num_shards: int
) -> List[Dict[tuple, tuple]]:
    """Split ``{key: (ms, class_key)}`` into per-shard dicts, keeping every
    class-key group whole (placement siblings share one classification) and
    balancing by group size, largest first. Deterministic in the input
    order, so resumed/re-run sweeps partition identically."""
    groups: Dict[tuple, List[tuple]] = {}
    for key, (_, ck) in items.items():
        groups.setdefault(ck, []).append(key)
    # Stable balance: largest groups first (ties keep insertion order), each
    # onto the currently lightest shard (ties -> lowest index).
    order = sorted(groups, key=lambda ck: -len(groups[ck]))
    loads = [0] * num_shards
    parts: List[Dict[tuple, tuple]] = [dict() for _ in range(num_shards)]
    for ck in order:
        i = loads.index(min(loads))
        for key in groups[ck]:
            parts[i][key] = items[key]
        loads[i] += len(groups[ck])
    return parts


class _ShardWorker:
    """Per-shard supervision state for one wave of workers."""

    __slots__ = (
        "index", "device", "part", "thread", "result", "error", "ok",
        "hung", "retries", "wall", "heartbeat", "done", "cancel",
    )

    def __init__(self, index: int, device, part: Dict[tuple, tuple]):
        self.index = index
        self.device = device
        self.part = part
        self.thread: Optional[threading.Thread] = None
        self.result: Dict[tuple, list] = {}
        self.error: Optional[BaseException] = None
        self.ok = False
        self.hung = False
        self.retries = 0
        self.wall = 0.0
        self.heartbeat = time.monotonic()
        self.done = threading.Event()
        self.cancel = threading.Event()


def _shard_worker_main(
    w: _ShardWorker,
    eval_fn: Callable[[Dict[tuple, tuple]], Dict[tuple, list]],
    tol: FaultTolerance,
    injector: Optional[FaultInjector],
    tele: FaultTelemetry,
) -> None:
    """Worker body: pin jit dispatch to the shard's device, retry transient
    failures in place with seeded backoff, surface everything else to the
    supervisor via ``w.error``. Never raises — the supervisor classifies."""
    t0 = time.monotonic()
    try:
        with jax.default_device(w.device):
            attempt = 0
            while True:
                w.heartbeat = time.monotonic()
                try:
                    if injector is not None:
                        injector.fire(w.index, w.cancel)
                    w.result = eval_fn(w.part) if w.part else {}
                    w.ok = True
                    return
                except Exception as exc:  # noqa: BLE001 — classified below
                    if classify_exception(exc) != "transient":
                        raise
                    tele.note_transient(w.index)
                    if attempt >= tol.max_retries or w.cancel.is_set():
                        raise
                    last_exc = exc
                attempt += 1
                w.retries += 1
                tele.note_retry(w.index)
                # Backoff between attempts; a watchdog cancel interrupts the
                # wait (the shard is being abandoned, stop burning time).
                with profiling.stage("fault_wait"):
                    if w.cancel.wait(backoff_seconds(tol, w.index, attempt)):
                        raise last_exc
    except BaseException as exc:  # noqa: BLE001 — handed to the supervisor
        w.error = exc
    finally:
        w.wall = time.monotonic() - t0
        w.done.set()


def _run_wave(
    workers: List[_ShardWorker],
    eval_fn: Callable[[Dict[tuple, tuple]], Dict[tuple, list]],
    tol: FaultTolerance,
    injector: Optional[FaultInjector],
    tele: FaultTelemetry,
) -> None:
    """Run one wave of shard workers to completion (or abandonment).

    Threads are daemonic because a hung worker cannot be force-killed in
    Python: the watchdog marks it ``hung``, sets its cancel event (so
    cooperative waits — backoff sleeps, injected hangs — exit promptly),
    and stops waiting for it. With no timeout armed the supervisor is a
    plain zero-poll join, so the fault-free path pays no watchdog tax."""
    for w in workers:
        w.thread = threading.Thread(
            target=_shard_worker_main,
            args=(w, eval_fn, tol, injector, tele),
            name=f"sweep-shard-{w.index}",
            daemon=True,
        )
        w.thread.start()
    if tol.shard_timeout_s is None:
        for w in workers:
            w.done.wait()
        return
    pending = list(workers)
    while pending:
        pending[0].done.wait(tol.watchdog_poll_s)
        now = time.monotonic()
        still: List[_ShardWorker] = []
        for w in pending:
            if w.done.is_set():
                continue
            if now - w.heartbeat > tol.shard_timeout_s:
                w.hung = True
                w.cancel.set()  # abandoned; thread may finish later, ignored
                continue
            still.append(w)
        pending = still


def _shard_error(
    w: _ShardWorker,
    merged: Dict[tuple, list],
    prefix: Optional[str] = None,
) -> ShardEvaluationError:
    groups = sorted({str(ck) for (_ms, ck) in w.part.values()})
    return ShardEvaluationError(
        shard=w.index,
        device=str(w.device),
        keys=list(w.part),
        class_groups=groups,
        completed=merged,
        cause=w.error,
        prefix=prefix,
    )


def evaluate_sharded(
    items: Dict[tuple, tuple],
    plan: ShardPlan,
    eval_fn: Callable[[Dict[tuple, tuple]], Dict[tuple, list]],
    *,
    tolerance: Optional[FaultTolerance] = None,
    injector: Optional[FaultInjector] = None,
    telemetry: Optional[FaultTelemetry] = None,
) -> Dict[tuple, list]:
    """Partition ``items``, evaluate each shard on its device under
    supervision, and merge the per-key stats back (original key order
    preserved).

    Recovery semantics (``tolerance``, default ``FaultTolerance()``):
    transient worker errors retry in place with seeded backoff; crashed,
    hung (watchdog-abandoned), or retry-exhausted shards are dropped and
    their memo keys re-partitioned onto the surviving shards — the plan
    shrinks, the call completes, and the merged result is bitwise identical
    because every batching layer is composition-invariant. ``strict=True``
    raises ``ShardEvaluationError`` instead of degrading. Fatal errors
    always raise it, carrying every completed sibling shard's results as
    ``.completed``. Kills (``KeyboardInterrupt``/``SystemExit``) propagate
    untouched. ``injector`` threads a test-only fault schedule into the
    workers; ``telemetry`` accumulates retry/failover/degradation counts.
    """
    tol = tolerance if tolerance is not None else FaultTolerance()
    tele = telemetry if telemetry is not None else FaultTelemetry()
    parts = partition_by_class_key(items, plan.num_shards)
    # Shard ids are indices into plan.devices and stay stable across
    # failover waves, so a FaultPlan's (shard, round) coordinates keep
    # meaning the same worker even after other shards died.
    alive: Dict[int, object] = dict(enumerate(plan.devices))
    assignments: List[Tuple[int, Dict[tuple, tuple]]] = [
        (i, parts[i]) for i in range(plan.num_shards) if parts[i]
    ]
    merged: Dict[tuple, list] = {}
    completed_counts = [0] * plan.num_shards
    max_failovers = (
        tol.max_failover_rounds
        if tol.max_failover_rounds is not None
        else plan.num_shards
    )
    failover_round = 0

    while assignments:
        workers = [_ShardWorker(i, alive[i], part) for i, part in assignments]
        _run_wave(workers, eval_fn, tol, injector, tele)

        failed: List[_ShardWorker] = []
        for w in workers:
            # A worker that finished after the watchdog abandoned it stays
            # failed: its keys are already earmarked for failover and the
            # completed-count bookkeeping must see each key exactly once.
            if w.ok and not w.hung:
                merged.update(w.result)
                completed_counts[w.index] += len(w.part)
                tele.note_shard(w.index, device=str(w.device),
                                keys=len(w.part), wall_s=w.wall)
            else:
                failed.append(w)
        if not failed:
            break

        # Process-level kills propagate untouched (Ctrl-C, injected kill).
        for w in failed:
            if w.error is not None and classify_exception(w.error) == "kill":
                raise w.error
        # Fatal = a bug, not infrastructure: never failed over. Wrap with
        # shard context; completed sibling results ride along.
        for w in failed:
            if not w.hung and classify_exception(w.error) == "fatal":
                raise _shard_error(w, merged) from w.error

        for w in failed:
            kind = "hang" if w.hung else classify_exception(w.error)
            tele.note_shard_failure(w.index, kind, device=str(w.device))
        if tol.strict:
            w = failed[0]
            raise _shard_error(
                w, merged,
                prefix="strict fault tolerance (no failover): shard "
                       + ("hung" if w.hung else "failed"),
            ) from w.error

        # Graceful degradation: drop the failed shards, re-partition their
        # keys onto the survivors, and run another wave over the shrunken
        # plan. partition_by_class_key is deterministic, and the batching
        # layers are composition-invariant, so the failover result is
        # bitwise identical to the fault-free evaluation.
        failed_keys: Dict[tuple, tuple] = {}
        for w in failed:
            alive.pop(w.index, None)
            failed_keys.update(w.part)
        live_dev_ids = {id(d) for d in alive.values()}
        lost = len({id(w.device) for w in failed} - live_dev_ids)
        if lost:
            tele.note_lost_devices(lost)
        if not alive:
            hung_n = sum(1 for w in failed if w.hung)
            hint = (
                " (all failures are watchdog timeouts: if the shards were "
                "making progress, FaultTolerance.shard_timeout_s is below "
                "the legitimate per-round evaluation time — raise it)"
                if hung_n == len(failed) else ""
            )
            raise FaultToleranceExhausted(
                f"every shard failed; {len(failed_keys)} memo keys have no "
                f"surviving device{hint}"
            ) from failed[0].error
        failover_round += 1
        if failover_round > max_failovers:
            raise FaultToleranceExhausted(
                f"failover depth {failover_round} exceeds "
                f"max_failover_rounds={max_failovers}"
            ) from failed[0].error
        survivors = sorted(alive)
        tele.note_failover(keys=len(failed_keys), survivors=len(survivors))
        sub = partition_by_class_key(failed_keys, len(survivors))
        assignments = [(i, p) for i, p in zip(survivors, sub) if p]

    # Cross-device participation check: every completed shard's key count
    # must arrive in the psum-ed total. Cheap, and it exercises the real
    # collective (shard_map over the live device mesh) rather than trusting
    # the supervisor's bookkeeping.
    total = shard_key_totals(completed_counts, plan)
    if total != len(items) or len(merged) != len(items):
        raise RuntimeError(
            f"sharded gather dropped keys: psum saw {total}, merged "
            f"{len(merged)}, expected {len(items)}"
        )
    return {k: merged[k] for k in items}


def shard_key_totals(counts: Sequence[int], plan: ShardPlan) -> int:
    """psum the per-shard key counts across the plan's devices through the
    ``shard_map_compat`` shim. With repeated devices (oversubscribed
    shards) the mesh would alias, so the collective runs over the distinct
    device set with per-device subtotals — the returned total is the same
    either way. Devices that contributed zero keys are left out of the
    mesh: after a failover their hardware may be the thing that died."""
    # Fold shard counts onto their distinct devices (a mesh needs unique
    # devices; oversubscribed plans stack their counts per device).
    dev_ids: Dict[int, int] = {}
    dev_list = []
    per_dev: List[int] = []
    for dev, n in zip(plan.devices, counts):
        i = dev_ids.get(id(dev))
        if i is None:
            i = dev_ids[id(dev)] = len(dev_list)
            dev_list.append(dev)
            per_dev.append(0)
        per_dev[i] += int(n)
    live = [(d, n) for d, n in zip(dev_list, per_dev) if n > 0]
    dev_list = [d for d, _ in live]
    per_dev = [n for _, n in live]
    if len(dev_list) < 2:
        return int(sum(per_dev))

    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(dev_list, dtype=object), ("shard",))

    def body(x):
        return jax.lax.psum(x, "shard")

    fn = shard_map_compat(body, mesh, in_specs=P("shard"), out_specs=P())
    arr = np.asarray(per_dev, dtype=np.int64)
    # body returns the (1,)-shaped replicated total per device.
    return int(np.asarray(fn(arr)).ravel()[0])
