"""Device sharding for the DSE sweep's memo-key space.

The sweep engine reduces a config grid to a set of memo keys (distinct
classification + DRAM-timing evaluations). Those keys are embarrassingly
parallel — every batching layer underneath (`classify_embedding_many`, the
stack/rrip analytic passes, ``dram_timing_many``) is bit-exact regardless of
batch composition — so scaling out is a pure partitioning problem:

  * **Partition by class-key group**, not by key: placement siblings share
    ONE classification with their class key, so splitting a group across
    shards would re-classify it per shard. Whole groups round-robin across
    shards by size (largest first) for balance, deterministically.
  * **One worker thread per shard**, each evaluating its key subset through
    the regular engine with jit dispatch pinned to its device via
    ``jax.default_device`` (thread-local in jax, so shards target distinct
    devices concurrently; the GIL releases inside XLA executions). The
    per-shard stats dicts merge back into the single memo table — bitwise
    identical to the unsharded pass, differential-enforced.
  * **Cross-device gather check** through the ``shard_map_compat`` version
    shim (the same one the collective matmul uses): each shard contributes
    its key count on its mesh position and a psum must see every shard —
    a cheap end-to-end assertion that the mesh actually spans the devices
    the plan claims (validated on CPU CI under
    ``XLA_FLAGS=--xla_force_host_platform_device_count=8``).

``sweep(devices=8)`` is the user surface; this module only plans and
executes the partition.
"""
from __future__ import annotations

import itertools
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from typing import Callable, Dict, List, Sequence

import jax
import numpy as np

from .collective_matmul import shard_map_compat

__all__ = [
    "ShardPlan",
    "resolve_shard_plan",
    "partition_by_class_key",
    "evaluate_sharded",
    "shard_key_totals",
]


@dataclass(frozen=True)
class ShardPlan:
    """How to split one evaluation round: ``devices[i]`` hosts shard i."""

    devices: tuple            # one jax.Device per shard (may repeat)

    @property
    def num_shards(self) -> int:
        return len(self.devices)

    @property
    def distinct_devices(self) -> int:
        return len({id(d) for d in self.devices})


def resolve_shard_plan(devices) -> ShardPlan:
    """``devices`` as an int takes that many shards cycled over the local
    jax devices (oversubscribing when fewer exist — still bit-exact, just
    less parallel); a device sequence pins one shard per device."""
    if isinstance(devices, int):
        if devices < 1:
            raise ValueError(f"need >= 1 shard, got {devices}")
        local = jax.devices()
        devs = tuple(itertools.islice(itertools.cycle(local), devices))
    else:
        devs = tuple(devices)
        if not devs:
            raise ValueError("empty device sequence")
    return ShardPlan(devices=devs)


def partition_by_class_key(
    items: Dict[tuple, tuple], num_shards: int
) -> List[Dict[tuple, tuple]]:
    """Split ``{key: (ms, class_key)}`` into per-shard dicts, keeping every
    class-key group whole (placement siblings share one classification) and
    balancing by group size, largest first. Deterministic in the input
    order, so resumed/re-run sweeps partition identically."""
    groups: Dict[tuple, List[tuple]] = {}
    for key, (_, ck) in items.items():
        groups.setdefault(ck, []).append(key)
    # Stable balance: largest groups first (ties keep insertion order), each
    # onto the currently lightest shard (ties -> lowest index).
    order = sorted(groups, key=lambda ck: -len(groups[ck]))
    loads = [0] * num_shards
    parts: List[Dict[tuple, tuple]] = [dict() for _ in range(num_shards)]
    for ck in order:
        i = loads.index(min(loads))
        for key in groups[ck]:
            parts[i][key] = items[key]
        loads[i] += len(groups[ck])
    return parts


def evaluate_sharded(
    items: Dict[tuple, tuple],
    plan: ShardPlan,
    eval_fn: Callable[[Dict[tuple, tuple]], Dict[tuple, list]],
) -> Dict[tuple, list]:
    """Partition ``items``, evaluate each shard on its device concurrently,
    and merge the per-key stats back (original key order preserved)."""
    parts = partition_by_class_key(items, plan.num_shards)

    def run(part, dev):
        if not part:
            return {}
        with jax.default_device(dev):
            return eval_fn(part)

    with ThreadPoolExecutor(max_workers=plan.num_shards) as pool:
        shard_results = list(pool.map(run, parts, plan.devices))

    # Cross-device participation check: every shard's key count must arrive
    # in the psum-ed total. Cheap, and it exercises the real collective
    # (shard_map over the plan's device mesh) rather than trusting the
    # thread pool.
    counts = [len(p) for p in parts]
    total = shard_key_totals(counts, plan)
    if total != len(items):
        raise RuntimeError(
            f"sharded gather dropped keys: psum saw {total}, "
            f"expected {len(items)}"
        )

    merged: Dict[tuple, list] = {}
    for res in shard_results:
        merged.update(res)
    return {k: merged[k] for k in items}


def shard_key_totals(counts: Sequence[int], plan: ShardPlan) -> int:
    """psum the per-shard key counts across the plan's devices through the
    ``shard_map_compat`` shim. With repeated devices (oversubscribed
    shards) the mesh would alias, so the collective runs over the distinct
    device set with per-device subtotals — the returned total is the same
    either way."""
    # Fold shard counts onto their distinct devices (a mesh needs unique
    # devices; oversubscribed plans stack their counts per device).
    dev_ids: Dict[int, int] = {}
    dev_list = []
    per_dev: List[int] = []
    for dev, n in zip(plan.devices, counts):
        i = dev_ids.get(id(dev))
        if i is None:
            i = dev_ids[id(dev)] = len(dev_list)
            dev_list.append(dev)
            per_dev.append(0)
        per_dev[i] += int(n)
    if len(dev_list) < 2:
        return int(sum(per_dev))

    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(dev_list, dtype=object), ("shard",))

    def body(x):
        return jax.lax.psum(x, "shard")

    fn = shard_map_compat(body, mesh, in_specs=P("shard"), out_specs=P())
    arr = np.asarray(per_dev, dtype=np.int64)
    # body returns the (1,)-shaped replicated total per device.
    return int(np.asarray(fn(arr)).ravel()[0])
