"""Overlapped collective matmul (shard_map ring, reduce-scatter style).

The beyond-paper §Perf lever for collective-bound cells. Row-parallel TP
(``y = psum(x_loc @ w_loc)``) exposes one big all-reduce after the dot. The
ring version splits the output into ``n`` chunks and interleaves
collective-permutes with per-chunk dots, so each hop's ICI transfer hides
behind the next chunk's MXU work:

  at step t, device d sends its partial sum for chunk (d - t) mod n and
  folds in its own partial for the incoming chunk; after n-1 hops device d
  holds the fully-reduced chunk (d+1) mod n (reduce-scatter), which a final
  all-gather (or the next layer's sharding) reassembles.

In the lowered HLO the all-reduce disappears in favor of n-1
collective-permutes interleaved with dots (asserted by tests and inspected in
the dry-run HLO).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

# jax >= 0.6 exposes shard_map at the top level (replication check spelled
# `check_vma`); older releases keep it in jax.experimental with `check_rep`.
# Exported as ``shard_map_compat`` so other distributed layers (the sharded
# DSE sweep's cross-device gather) reuse ONE version shim.
if hasattr(jax, "shard_map"):
    def shard_map_compat(body, mesh, in_specs, out_specs):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
else:
    from jax.experimental.shard_map import shard_map as _experimental_shard_map

    def shard_map_compat(body, mesh, in_specs, out_specs):
        return _experimental_shard_map(body, mesh=mesh, in_specs=in_specs,
                                       out_specs=out_specs, check_rep=False)

_shard_map = shard_map_compat    # internal alias (tests patch/import this)


def _own_chunk(x_loc, w_loc, c, n_chunks):
    nc = w_loc.shape[-1] // n_chunks
    w_c = jax.lax.dynamic_slice_in_dim(w_loc, c * nc, nc, axis=-1)
    return x_loc @ w_c


def ring_matmul(
    x: jax.Array,        # (..., M, K) sharded on K over `axis`
    w: jax.Array,        # (K, N) sharded on K over `axis`; N % axis_size == 0
    mesh: Mesh,
    axis: str = "model",
) -> jax.Array:          # (..., M, N) fully reduced, replicated on `axis`
    n = mesh.shape[axis]

    def body(x_loc, w_loc):
        idx = jax.lax.axis_index(axis)
        perm = [(j, (j + 1) % n) for j in range(n)]

        def step(t, msg):
            msg = jax.lax.ppermute(msg, axis, perm)
            c = (idx - t - 1) % n
            return msg + _own_chunk(x_loc, w_loc, c, n)

        msg = _own_chunk(x_loc, w_loc, idx % n, n)
        msg = jax.lax.fori_loop(0, n - 1, step, msg)
        # device d now holds fully-reduced chunk (d+1) % n
        gathered = jax.lax.all_gather(msg, axis)          # (n, ..., M, Nc)
        order = (jnp.arange(n) - 1) % n                   # chunk j lives at (j-1)%n
        gathered = jnp.take(gathered, order, axis=0)
        return jnp.concatenate(jnp.split(gathered, n, axis=0), axis=-1)[0]

    # replication is established by the final gather (check disabled in shim)
    return _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(*(None,) * (x.ndim - 1), axis), P(axis, None)),
        out_specs=P(*(None,) * (x.ndim - 1), None),
    )(x, w)


def psum_matmul(x, w, mesh, axis="model"):
    """Baseline: local partial matmul + one all-reduce (no overlap)."""

    def body(x_loc, w_loc):
        return jax.lax.psum(x_loc @ w_loc, axis)

    # psum output is replicated by construction (check disabled in shim)
    return _shard_map(
        body,
        mesh=mesh,
        in_specs=(P(*(None,) * (x.ndim - 1), axis), P(axis, None)),
        out_specs=P(*(None,) * (x.ndim - 1), None),
    )(x, w)
