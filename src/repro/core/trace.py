"""Index-trace handling (paper Sec. III, "Simulation flow").

EONSim operates on *hardware-agnostic embedding index traces*:

  1. a single-table index-level trace (from a file or a synthetic generator),
  2. expanded to a full multi-table trace per the workload configuration,
  3. translated into memory *line addresses* using the memory-system
     configuration (vector dim, dtype, line granularity, contiguous layout).

Synthetic traces use a Zipf distribution, the standard model for the skewed
reuse the paper describes (Reuse High ~4% of vectors dominate, Low ~46%).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .workload import EmbeddingOpSpec


# --------------------------------------------------------------------------
# Synthetic index-trace generation
# --------------------------------------------------------------------------

def zipf_probs(num_rows: int, s: float) -> np.ndarray:
    """p(rank r) ∝ 1 / r^s over ``num_rows`` ranks."""
    ranks = np.arange(1, num_rows + 1, dtype=np.float64)
    p = 1.0 / np.power(ranks, s)
    return p / p.sum()


def generate_zipf_trace(
    num_accesses: int,
    num_rows: int,
    s: float,
    seed: int = 0,
    shuffle_ids: bool = True,
) -> np.ndarray:
    """Sample ``num_accesses`` row indices with Zipf(s) popularity.

    ``shuffle_ids`` decorrelates popularity rank from row id (hot rows are
    spread over the table, as in real embedding tables).
    """
    rng = np.random.default_rng(seed)
    p = zipf_probs(num_rows, s)
    # Inverse-CDF sampling (vectorized, reproducible).
    cdf = np.cumsum(p)
    u = rng.random(num_accesses)
    ranks = np.searchsorted(cdf, u, side="right")
    if shuffle_ids:
        perm = rng.permutation(num_rows)
        return perm[ranks].astype(np.int64)
    return ranks.astype(np.int64)


def generate_uniform_trace(num_accesses: int, num_rows: int, seed: int = 0) -> np.ndarray:
    rng = np.random.default_rng(seed)
    return rng.integers(0, num_rows, size=num_accesses, dtype=np.int64)


def dominance_fraction(trace: np.ndarray, num_rows: int, coverage: float = 0.8) -> float:
    """Fraction of *distinct accessed rows* that carry ``coverage`` of accesses.

    The paper: "In Reuse High, about 4% of vectors dominate accesses, while
    Reuse Low distributes them across 46%".
    """
    counts = np.bincount(trace, minlength=num_rows)
    counts = np.sort(counts[counts > 0])[::-1]
    if counts.size == 0:
        return 0.0
    csum = np.cumsum(counts)
    k = int(np.searchsorted(csum, coverage * csum[-1])) + 1
    return k / counts.size


# Zipf exponents calibrated (tests pin these) so that the top slice of rows
# covering 80% of accesses matches the paper's reuse levels on the DLRM table
# geometry (1M accesses over 1M rows):  High ≈ 4%, Mid ≈ 20%, Low ≈ 46%.
REUSE_LEVELS = {
    "reuse_high": 1.10,
    "reuse_mid": 1.00,
    "reuse_low": 0.81,
}


def reuse_trace(level: str, num_accesses: int, num_rows: int, seed: int = 0) -> np.ndarray:
    return generate_zipf_trace(num_accesses, num_rows, REUSE_LEVELS[level], seed=seed)


# --------------------------------------------------------------------------
# Trace expansion: single table -> full workload trace
# --------------------------------------------------------------------------

def validate_indices(
    indices: np.ndarray, upper: int, what: str = "embedding index"
) -> None:
    """Reject out-of-range / negative indices with a clear error at trace
    construction. Historically an out-of-range index wrapped modulo the
    table size at translate time — simulating a *valid but wrong* row, which
    corrupts hit rates silently. Raise early instead."""
    arr = np.asarray(indices)
    if arr.size == 0:
        return
    lo, hi = int(arr.min()), int(arr.max())
    if lo < 0:
        raise ValueError(
            f"negative {what} {lo} (valid range [0, {upper})): embedding "
            "indices must be non-negative — fix the trace generator rather "
            "than relying on wrap-around")
    if hi >= upper:
        raise ValueError(
            f"{what} {hi} out of range [0, {upper}): the trace references "
            "rows past the end of the table — fix the trace (or the "
            "spec's rows_per_table) rather than relying on wrap-around")


@dataclass(frozen=True)
class FullTrace:
    """Expanded trace: one row per lookup, in execution order.

    ``table_ids[i]``/``row_ids[i]`` identify lookup i. Execution order is
    batch-major: sample 0 table 0 lookups, sample 0 table 1, ... (the order
    an embedding-bag kernel walks the indices).
    """

    table_ids: np.ndarray   # int32 (N,)
    row_ids: np.ndarray     # int64 (N,)
    batch_size: int
    num_tables: int
    lookups_per_sample: int

    def __len__(self) -> int:
        return self.row_ids.shape[0]


def expand_trace(
    single_table_trace: np.ndarray,
    spec: EmbeddingOpSpec,
    batch_size: int,
    seed: int = 1,
) -> FullTrace:
    """Paper: "processes an embedding vector index-level access trace for a
    single table to a full access trace, based on the workload configuration".

    Each table reuses the same index stream through a per-table permutation of
    the row space — preserving the skew profile while decorrelating *which*
    rows are hot across tables (real tables have independent hot sets).

    Indices must lie in ``[0, spec.rows_per_table)``; out-of-range or
    negative indices raise ``ValueError`` here rather than silently wrapping
    into valid rows (a wrapped index simulates the wrong row — and the wrong
    hit rate — with no error anywhere downstream).
    """
    validate_indices(single_table_trace, spec.rows_per_table,
                     what="single_table_trace index")
    n_needed = batch_size * spec.num_tables * spec.lookups_per_sample
    reps = int(np.ceil(n_needed / max(len(single_table_trace), 1)))
    base = np.tile(single_table_trace, reps)[:n_needed]
    base = base.reshape(batch_size, spec.num_tables, spec.lookups_per_sample)

    rng = np.random.default_rng(seed)
    rows = np.empty_like(base)
    for t in range(spec.num_tables):
        perm = rng.permutation(spec.rows_per_table)
        rows[:, t, :] = perm[base[:, t, :]]

    table_ids = np.broadcast_to(
        np.arange(spec.num_tables, dtype=np.int32)[None, :, None], base.shape
    )
    return FullTrace(
        table_ids=table_ids.reshape(-1).copy(),
        row_ids=rows.reshape(-1).astype(np.int64),
        batch_size=batch_size,
        num_tables=spec.num_tables,
        lookups_per_sample=spec.lookups_per_sample,
    )


@dataclass(frozen=True)
class ConcatTrace:
    """Concatenation of per-batch FullTraces with *true* per-batch boundaries.

    The on-chip policy simulation runs once over the concatenated multi-batch
    stream (state persists across inference batches); timing and counts are
    attributed per batch afterwards via ``boundaries`` — which carries the
    real per-batch lookup offsets, so heterogeneous per-batch trace lengths
    are attributed exactly (a derived uniform batch_size would be silently
    wrong there).
    """

    table_ids: np.ndarray        # int32 (N,) over all batches, batch-major
    row_ids: np.ndarray          # int64 (N,)
    boundaries: np.ndarray       # int64 (num_batches + 1,) lookup offsets
    batch_sizes: Tuple[int, ...]  # samples per batch (workload batching)
    num_tables: int
    lookups_per_sample: int

    def __len__(self) -> int:
        return self.row_ids.shape[0]

    @property
    def num_batches(self) -> int:
        return len(self.boundaries) - 1

    @property
    def lookups_per_batch(self) -> np.ndarray:
        return np.diff(self.boundaries)

    @property
    def lookup_batch(self) -> np.ndarray:
        """int64 (N,) batch index of every lookup."""
        return np.repeat(
            np.arange(self.num_batches, dtype=np.int64), self.lookups_per_batch
        )

    @staticmethod
    def from_traces(traces: Sequence[FullTrace]) -> "ConcatTrace":
        if not traces:
            raise ValueError("need at least one batch trace")
        lens = np.array([len(t) for t in traces], dtype=np.int64)
        boundaries = np.concatenate(([0], np.cumsum(lens)))
        return ConcatTrace(
            table_ids=np.concatenate([t.table_ids for t in traces]),
            row_ids=np.concatenate([t.row_ids for t in traces]),
            boundaries=boundaries,
            batch_sizes=tuple(t.batch_size for t in traces),
            num_tables=traces[0].num_tables,
            lookups_per_sample=traces[0].lookups_per_sample,
        )


# --------------------------------------------------------------------------
# Per-core trace sharding (multi-core CoreCluster topology)
# --------------------------------------------------------------------------

# Knuth multiplicative hash constant — decorrelates the table->core mapping
# from table-id parity/stride patterns while staying fully deterministic.
_TABLE_HASH_MULT = 2654435761


def _div_fast(x: np.ndarray, d: int) -> np.ndarray:
    """``x // d`` for non-negative ints; power-of-two divisors use a shift
    (int64 division is the hot op in per-line address transforms)."""
    if d & (d - 1) == 0:
        return x >> (d.bit_length() - 1)
    return x // d


def _divmod_fast(x: np.ndarray, d: int):
    """``(x // d, x % d)`` for non-negative ints; pow2 uses shift/mask."""
    if d & (d - 1) == 0:
        return x >> (d.bit_length() - 1), x & (d - 1)
    return x // d, x % d


def table_core_of(table_ids: np.ndarray, num_cores: int) -> np.ndarray:
    """Deterministic table_id -> core hash (model-parallel table sharding)."""
    t = np.asarray(table_ids, dtype=np.uint64)
    return (((t * np.uint64(_TABLE_HASH_MULT)) >> np.uint64(16))
            % np.uint64(num_cores)).astype(np.int32)


def shard_lookup_cores(
    concat: ConcatTrace, num_cores: int, mode: str = "batch"
) -> np.ndarray:
    """int32 (N,) core id per lookup — deterministic in (trace, num_cores, mode).

    ``batch``       round-robin over batch *samples*: sample s of every batch
                    runs on core ``s % num_cores`` (data-parallel inference,
                    each core pools full samples).
    ``table_hash``  hash of ``table_id`` -> core: each embedding table lives
                    on exactly one core (model-parallel table sharding, the
                    TensorDIMM/RecNMP placement for giant tables).
    """
    if num_cores < 1:
        raise ValueError(f"num_cores must be >= 1, got {num_cores}")
    n = len(concat)
    if num_cores == 1:
        return np.zeros(n, dtype=np.int32)
    if mode == "batch":
        per_sample = concat.num_tables * concat.lookups_per_sample
        starts = np.repeat(concat.boundaries[:-1], concat.lookups_per_batch)
        pos_in_batch = np.arange(n, dtype=np.int64) - starts
        sample = pos_in_batch // max(per_sample, 1)
        return (sample % num_cores).astype(np.int32)
    if mode == "table_hash":
        return table_core_of(concat.table_ids, num_cores)
    raise ValueError(f"unknown sharding mode {mode!r}; options: batch, table_hash")


@dataclass(frozen=True)
class TraceShard:
    """One core's slice of a ConcatTrace, with true per-batch boundaries.

    ``lookup_index`` maps each shard lookup back to its global position in the
    parent trace — the key to deterministic cross-core interleaving when the
    cores' miss bursts are merged for shared-DRAM timing.
    """

    core_id: int
    concat: ConcatTrace
    lookup_index: np.ndarray     # int64 (n_i,) global lookup positions

    def __len__(self) -> int:
        return len(self.concat)


def shard_lookup_cores_jnp(
    concat: ConcatTrace, num_cores: int, mode: str = "batch"
) -> jax.Array:
    """Device-resident port of ``shard_lookup_cores`` (numpy stays golden).

    Same deterministic lookup->core mapping expressed in jnp so a device-
    resident pipeline can shard without leaving the accelerator; equality
    with the numpy version is test-enforced. ``table_hash`` reproduces the
    64-bit Knuth hash with 32-bit arithmetic (split multiplier), exact for
    ``table_id < 2**15`` — beyond that it falls back to the host mapping.
    """
    if num_cores < 1:
        raise ValueError(f"num_cores must be >= 1, got {num_cores}")
    n = len(concat)
    if num_cores == 1:
        return jnp.zeros(n, dtype=jnp.int32)
    if mode == "batch":
        per_sample = concat.num_tables * concat.lookups_per_sample
        starts = jnp.repeat(
            jnp.asarray(concat.boundaries[:-1].astype(np.int32)),
            jnp.asarray(concat.lookups_per_batch.astype(np.int32)),
            total_repeat_length=n,
        )
        pos_in_batch = jnp.arange(n, dtype=jnp.int32) - starts
        sample = pos_in_batch // max(per_sample, 1)
        return (sample % num_cores).astype(jnp.int32)
    if mode == "table_hash":
        if concat.num_tables >= (1 << 15):
            return jnp.asarray(table_core_of(concat.table_ids, num_cores))
        t = jnp.asarray(concat.table_ids).astype(jnp.int32)
        m_hi = _TABLE_HASH_MULT >> 16
        m_lo = _TABLE_HASH_MULT & 0xFFFF
        # (t * M) >> 16 == t * m_hi + ((t * m_lo) >> 16), exact in 32 bits
        # for t < 2**15 (t * m_hi < 2**31).
        h = t * m_hi + ((t * m_lo) >> 16)
        return (h % num_cores).astype(jnp.int32)
    raise ValueError(f"unknown sharding mode {mode!r}; options: batch, table_hash")


def shard_trace(
    concat: ConcatTrace,
    num_cores: int,
    mode: str = "batch",
    core_of: Optional[np.ndarray] = None,
) -> "list[TraceShard]":
    """Partition a ConcatTrace into ``num_cores`` per-core shards.

    Each shard preserves the parent's per-batch structure: shard batch b holds
    exactly the core's lookups from parent batch b, in parent order, so
    heterogeneous per-batch lengths survive sharding and per-core per-batch
    attribution stays exact. Shards may be empty (e.g. table_hash with fewer
    tables than cores). ``core_of`` lets a caller that already computed
    ``shard_lookup_cores`` reuse it.
    """
    core = core_of if core_of is not None else shard_lookup_cores(concat, num_cores, mode)
    lb = concat.lookup_batch
    shards = []
    for c in range(num_cores):
        idx = np.nonzero(core == c)[0].astype(np.int64)
        counts = np.bincount(lb[idx], minlength=concat.num_batches)
        sub = ConcatTrace(
            table_ids=concat.table_ids[idx],
            row_ids=concat.row_ids[idx],
            boundaries=np.concatenate(([0], np.cumsum(counts))),
            batch_sizes=concat.batch_sizes,
            num_tables=concat.num_tables,
            lookups_per_sample=concat.lookups_per_sample,
        )
        shards.append(TraceShard(core_id=c, concat=sub, lookup_index=idx))
    return shards


# --------------------------------------------------------------------------
# NUMA placement: embedding row -> (channel-group, rank) home
# --------------------------------------------------------------------------

# Fraction of distinct vectors (by access frequency) replicated across the
# whole channel group under ``placement="hot_replicate"`` — TensorDIMM
# replicates the hottest embeddings across ranks so any rank can serve them.
HOT_REPLICATE_FRACTION = 0.05


def profile_hot_vectors(
    vec_ids: np.ndarray, fraction: float = HOT_REPLICATE_FRACTION
) -> np.ndarray:
    """The hottest distinct vector ids of a trace, sorted — deterministic in
    the trace (frequency desc, vector id asc on ties)."""
    uniq, counts = np.unique(np.asarray(vec_ids, dtype=np.int64), return_counts=True)
    if uniq.size == 0:
        return uniq
    k = max(1, int(uniq.size * fraction))
    order = np.argsort(-counts, kind="stable")
    return np.sort(uniq[order[:k]])


@dataclass(frozen=True, eq=False)
class PlacementMap:
    """Maps embedding line addresses to their NUMA (channel-group, rank) home.

    The map is a pure address transform applied to miss traces *before* DRAM
    timing: a placed line decomposes (``DramModel.decompose``) to a channel
    inside the request's affine channel group, with the bank ("rank") and row
    chosen by the placement mode. Routing therefore rides through the
    existing contended/batched DRAM engines untouched — they already scan
    channels independently, so disjoint channel groups simply stop contending.

    Channel groups are strided: group ``g`` of ``G`` owns channels
    ``{g, g + G, g + 2G, ...}``. The degenerate ``symmetric``/``interleave``
    configuration is the *identity* transform (``place`` returns its input),
    which is what makes the placement layer bitwise invisible by default
    (test-enforced).

    ``per_core`` routes by REQUESTER, not by data home: a line accessed from
    two cores places at two distinct addresses (one per group), modeling
    per-core-private replicas of shared rows at zero storage/coherence cost.
    That is the intended TensorDIMM pairing with ``table_hash`` sharding
    (requester == table owner, nothing shared); under ``batch`` sharding use
    ``per_table`` for a single-copy data home.

    Placement modes within the group (see ``hardware.PLACEMENTS``):

    * ``interleave``    — blocks stripe across the group's channels, then
      banks, then rows: exactly the symmetric layout restricted to the group.
    * ``table_rank``    — TensorDIMM-style: each table is homed to ONE rank
      (bank index = ``hash(table) % banks``); its blocks stripe across the
      group's channels but stay in that rank, in a per-table private row
      range (no cross-table row aliasing by construction).
    * ``hot_replicate`` — ``table_rank`` for cold rows; vectors in
      ``hot_vecs`` stripe across every (channel, rank) of the group at full
      width, in a row range disjoint from every cold table's.

    The transform is injective (distinct lines never merge), so run
    compression, chunking, and row-hit accounting downstream stay exact.
    """

    channels: int
    banks: int
    lines_per_block: int
    blocks_per_row: int
    line_bytes: int
    num_groups: int
    affinity: str
    placement: str
    table_bytes: int
    vector_bytes: int
    num_tables: int
    hot_vecs: Optional[np.ndarray] = None    # sorted global vector ids

    @staticmethod
    def from_model(
        model,
        hw,
        spec,
        hot_vecs: Optional[np.ndarray] = None,
    ) -> "PlacementMap":
        """Build from a ``DramModel``-like object (single source of the
        channel/bank/row derivations), the hardware config, and the op spec."""
        affinity = hw.channel_affinity
        num_groups = 1 if affinity == "symmetric" else int(hw.num_cores)
        if num_groups > 1 and model.channels % num_groups != 0:
            raise ValueError(
                f"channel affinity {affinity!r} needs channels "
                f"({model.channels}) divisible by num_cores ({num_groups})"
            )
        return PlacementMap(
            channels=model.channels,
            banks=model.banks_per_channel,
            lines_per_block=model.lines_per_block,
            blocks_per_row=max(1, model.lines_per_row // model.lines_per_block),
            line_bytes=model.line_bytes,
            num_groups=num_groups,
            affinity=affinity,
            placement=hw.placement,
            table_bytes=spec.table_bytes,
            vector_bytes=spec.vector_bytes,
            num_tables=spec.num_tables,
            hot_vecs=hot_vecs,
        )

    @property
    def group_size(self) -> int:
        """Channels per group."""
        return self.channels // self.num_groups

    @property
    def effective_placement(self) -> str:
        """The placement mode after degeneracy collapse.

        Modes whose address transform provably equals a simpler mode's for
        this topology canonicalize to that mode, so memo layers (the sweep)
        can collapse such configs onto one entry instead of re-simulating:

        * ``hot_replicate`` with no hot vectors is exactly ``table_rank``
          (the replica branch can never fire).
        * ``table_rank`` (and hot-set-free ``hot_replicate``) with a single
          rank AND a single table is exactly ``interleave``: the rank home
          degenerates to the only bank, table 0's private block range starts
          at q == 0, and ``pack`` reproduces the plain group striping.

        ``place`` dispatches on this property, so the collapse is bitwise by
        construction, not merely approximate.
        """
        plc = self.placement
        if plc == "hot_replicate" and (
            self.hot_vecs is None or self.hot_vecs.size == 0
        ):
            plc = "table_rank"
        if plc == "table_rank" and self.banks == 1 and self.num_tables == 1:
            plc = "interleave"
        return plc

    @property
    def is_identity(self) -> bool:
        """True when ``place`` is the exact identity (the degenerate config)."""
        return self.num_groups == 1 and self.effective_placement == "interleave"

    # q-space spans: each table owns a private range of block-sequence ids so
    # tables (and the replicated hot set) can never alias rows of each other.
    # The span is rounded up to a whole number of rows — otherwise two tables
    # homed to the same rank could share the row straddling their boundary,
    # counting a spurious cross-table row hit per boundary.
    @property
    def _table_span(self) -> int:
        ib = self.lines_per_block * self.line_bytes
        span = self.table_bytes // ib + 2
        bpr = self.blocks_per_row
        return -(-span // bpr) * bpr

    @property
    def _hot_q_base(self) -> int:
        return self._table_span * (self.num_tables + 1)

    def affine_channels(self, group: int) -> np.ndarray:
        """The channel ids group ``group`` may route to (strided grouping)."""
        return np.arange(self.group_size, dtype=np.int64) * self.num_groups + int(group)

    def table_of(self, lines: np.ndarray) -> np.ndarray:
        """Table id of each line (from its start byte; contiguous layout)."""
        return (np.asarray(lines, dtype=np.int64) * self.line_bytes) // self.table_bytes

    def rank_of_table(self, table_ids: np.ndarray) -> np.ndarray:
        """Deterministic table -> rank (bank index) home, TensorDIMM-style."""
        return table_core_of(table_ids, self.banks).astype(np.int64)

    def group_of(
        self,
        lines: np.ndarray,
        src: Optional[np.ndarray] = None,
        table_ids: Optional[np.ndarray] = None,
    ) -> np.ndarray:
        """Affine channel-group of each request (total: every line maps).

        ``table_ids`` optionally passes precomputed ``table_of(lines)`` so
        hot-path callers (``place``) don't rederive the per-line division.
        """
        lines = np.asarray(lines, dtype=np.int64).reshape(-1)
        if self.num_groups == 1:
            return np.zeros(lines.size, dtype=np.int64)
        if self.affinity == "per_core":
            if src is None:
                # Silently homing everything to group 0 would quietly inflate
                # finish cycles; per_core routing REQUIRES source-core tags.
                raise ValueError(
                    "per_core channel affinity needs per-request source-core "
                    "tags; route through the multi-core pipeline "
                    "(memory_system_for) instead of a bare MemorySystem"
                )
            return np.asarray(src, dtype=np.int64).reshape(-1) % self.num_groups
        # per_table: the table's home group, independent of the issuing core
        # (same hash as table_hash lookup sharding, so a table's core and its
        # channel group coincide under model-parallel sharding). The hash is
        # a function of the (few) table ids — gathered, not rederived.
        t = self.table_of(lines) if table_ids is None else table_ids
        tmap = table_core_of(
            np.arange(self.num_tables + 1), self.num_groups
        ).astype(np.int64)
        return tmap[t]

    def place(
        self,
        lines: np.ndarray,
        src: Optional[np.ndarray] = None,
        cache: Optional[dict] = None,
    ) -> np.ndarray:
        """Placed line addresses: ``DramModel.decompose`` of the result lands
        on the request's affine channels with the mode's (rank, row) home.
        Identity (input returned unchanged) for ``symmetric``/``interleave``.

        ``cache`` (optional dict) memoizes the group-independent half of the
        transform across placement siblings that share one classified miss
        stream: for a fixed (effective placement, num_groups) the placed
        address is ``base(lines) + g*lines_per_block`` and only ``g`` reads
        the channel affinity, so siblings reuse ``base`` (and the per-line
        table ids) verbatim. Callers own the cache's lifetime — it must be
        scoped to ONE ``lines`` array.
        """
        lines = np.asarray(lines, dtype=np.int64).reshape(-1)
        if self.is_identity or lines.size == 0:
            return lines
        G = self.num_groups
        plc = self.effective_placement
        t = None
        if plc != "interleave" or self.affinity == "per_table":
            if cache is not None:
                t = cache.get("t")
            if t is None:
                t = self.table_of(lines)
                if cache is not None:
                    cache["t"] = t
        base = cache.get((plc, G)) if cache is not None else None
        if base is None:
            base = self._place_base(lines, plc, t)
            if cache is not None:
                cache[(plc, G)] = base
        if G == 1:
            return base                   # g == 0 everywhere
        g = self.group_of(lines, src, table_ids=t)
        return base + g * self.lines_per_block

    def _place_base(
        self, lines: np.ndarray, plc: str, t: Optional[np.ndarray]
    ) -> np.ndarray:
        """The group-independent part of ``place``: the placed address with
        ``g == 0`` (adding ``g*lines_per_block`` yields the full transform).
        """
        lpb = self.lines_per_block
        C, B, G = self.channels, self.banks, self.num_groups
        Cg = self.group_size
        blk, off = _divmod_fast(lines, lpb)

        # The canonical layout is new_blk = (q*B + bk)*C + (ch*G + g), with
        # q the block-sequence id within (channel, bank) — the exact inverse
        # of decompose_blocks.  Because C == Cg*G the (q, bk, ch) splits fold
        # algebraically; each branch notes its fold from the canonical form,
        # so what remains is a handful of per-line vector ops.

        if plc == "interleave":
            # q, ch = divmod(blk, Cg); qb, bk = divmod(q, B):
            #   (qb*B + bk)*C + ch*G + g == q*C + ch*G + g == blk*G + g.
            return blk * (G * lpb) + off

        # Table homes (private q span, rank) are functions of the few table
        # ids — the per-table head (span*B + rank)*C is gathered; only the
        # within-table remainder is per-line arithmetic.
        tab = np.arange(self.num_tables + 1, dtype=np.int64)
        tstart = ((tab * self.table_bytes) // (lpb * self.line_bytes))[t]
        blk_local = blk - tstart
        if Cg & (Cg - 1) == 0:
            ch_idx = blk_local & (Cg - 1)
        else:
            ch_idx = blk_local % Cg
        # ql, ch = divmod(blk_local, Cg); q = span_t + ql:
        #   (q*B + rank_t)*C + ch*G + g
        #     == (span_t*B + rank_t)*C + (ql*Cg*B + ch)*G + g,
        # and ql*Cg == blk_local - ch.
        head = ((tab * self._table_span) * B + self.rank_of_table(tab)) * C
        base = (
            head[t] + ((blk_local - ch_idx) * B + ch_idx) * G
        ) * lpb + off
        if (
            plc == "hot_replicate"
            and self.hot_vecs is not None
            and self.hot_vecs.size
        ):
            lpv = self.vector_bytes // self.line_bytes
            if lpv * self.line_bytes == self.vector_bytes:
                vec = _div_fast(lines, lpv)
            else:
                vec = (lines * self.line_bytes) // self.vector_bytes
            mask = self._hot_mask
            hot = mask[np.minimum(vec, mask.size - 1)]
            if np.any(hot):
                # qh, ch = divmod(blk, Cg); qhb, bk = divmod(qh, B):
                #   ((hot_q_base + qhb)*B + bk)*C + ch*G + g
                #     == hot_q_base*B*C + blk*G + g.
                base = np.where(
                    hot,
                    (blk * G + self._hot_q_base * B * C) * lpb + off,
                    base,
                )
        return base

    @property
    def _hot_mask(self) -> np.ndarray:
        """Membership mask over vector ids for the (sorted) hot set.

        One boolean gather per ``place`` call instead of a searchsorted;
        built lazily and cached on the instance (frozen dataclass, so via
        ``object.__setattr__``)."""
        cached = self.__dict__.get("_hot_mask_cache")
        if cached is None:
            cached = np.zeros(int(self.hot_vecs.max()) + 2, dtype=bool)
            cached[np.asarray(self.hot_vecs, dtype=np.int64)] = True
            object.__setattr__(self, "_hot_mask_cache", cached)
        return cached


# --------------------------------------------------------------------------
# Address translation: index trace -> line-address trace
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class AddressTrace:
    """Line-granular address trace (one entry per on-chip-line access)."""

    lines: np.ndarray        # int64 (M,) line numbers (byte_addr // line_bytes)
    line_bytes: int
    lines_per_vector: int
    vector_of_line: np.ndarray  # int64 (M,) index into the FullTrace lookup

    def __len__(self) -> int:
        return self.lines.shape[0]


def translate(
    full: Union[FullTrace, ConcatTrace],
    spec: EmbeddingOpSpec,
    line_bytes: int,
    base_address: int = 0,
) -> AddressTrace:
    """Index-level -> address-level trace.

    EONSim "assumes that an NPU stores embedding vectors in consecutive
    virtual memory addresses": table t, row r starts at
      base + t * table_bytes + r * vector_bytes
    and a vector touches ceil(vector_bytes / line_bytes) consecutive lines.
    """
    vb = spec.vector_bytes
    lines_per_vec = -(-vb // line_bytes)
    start = (
        base_address
        + full.table_ids.astype(np.int64) * spec.table_bytes
        + full.row_ids * vb
    )
    start_line = start // line_bytes
    offsets = np.arange(lines_per_vec, dtype=np.int64)
    lines = (start_line[:, None] + offsets[None, :]).reshape(-1)
    vector_of_line = np.repeat(np.arange(len(full), dtype=np.int64), lines_per_vec)
    return AddressTrace(
        lines=lines,
        line_bytes=line_bytes,
        lines_per_vector=lines_per_vec,
        vector_of_line=vector_of_line,
    )


def translate_jnp(
    table_ids: jax.Array,
    row_ids: jax.Array,
    spec: EmbeddingOpSpec,
    line_bytes: int,
    base_address: int = 0,
) -> jax.Array:
    """Device-resident port of ``translate``'s address arithmetic.

    Returns the flattened ``(N * lines_per_vector,)`` line-number stream for
    the given lookups (the ``AddressTrace.lines`` layout); the numpy
    ``translate`` stays the golden reference (equality test-enforced).
    Integer arithmetic is int32 (jnp default without x64), which covers byte
    addresses up to 2 GB of embedding state; larger address spaces keep the
    int64 host path (the cache engine itself is int32-bounded on *line*
    numbers, a far looser limit).
    """
    vb = spec.vector_bytes
    lines_per_vec = -(-vb // line_bytes)
    max_addr = base_address + spec.num_tables * spec.table_bytes
    if max_addr >= np.iinfo(np.int32).max:
        raise ValueError(
            f"translate_jnp covers int32 byte addresses only; this spec spans "
            f"{max_addr} bytes — use the int64 host `translate` instead"
        )
    start = (
        base_address
        + table_ids.astype(jnp.int32) * spec.table_bytes
        + row_ids.astype(jnp.int32) * vb
    )
    start_line = start // line_bytes
    offsets = jnp.arange(lines_per_vec, dtype=jnp.int32)
    return (start_line[:, None] + offsets[None, :]).reshape(-1)


def load_index_trace(path: str) -> np.ndarray:
    """Load an index trace from .npy or whitespace/newline-separated text."""
    if path.endswith(".npy"):
        return np.load(path).astype(np.int64).reshape(-1)
    return np.loadtxt(path, dtype=np.int64).reshape(-1)
