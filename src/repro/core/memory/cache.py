"""JAX set-associative cache engine (the paper's cycle-level memory sim core).

The paper validates EONSim's on-chip cache model against ChampSim and reports
*identical* hit/miss counts under LRU and SRRIP (Fig. 4a). We reproduce that
bar: this engine is bit-exact against ``golden.GoldenCache`` (a sequential
Python model written to ChampSim's replacement semantics), enforced by tests.

TPU-native design: the sequential C++ cache loop becomes a ``jax.lax.scan``
over the address trace with carry ``(tags, meta)``. Two structural
optimizations keep it fast while remaining bit-exact (both tested):

  1. **Set-group partitioning.** Accesses interact only within a cache set,
     so the set space is split into groups of ``_GROUP_SETS`` sets; each
     group's sub-trace runs through its own scan with a tiny carry
     (group_sets x ways). A monolithic carry (e.g. 16384x16) forces XLA to
     copy megabytes per scan step (~11 K acc/s measured); the grouped carry
     runs at ~1.2 M acc/s.
  2. **Length-bucketed padding.** Group sub-traces are padded to power-of-two
     lengths with masked no-op accesses so only O(log N) distinct shapes are
     ever compiled.

Replacement semantics (matching ChampSim):
  * LRU   — victim = first invalid way, else least-recently-used way.
  * SRRIP — 2-bit RRPV, init 3 (= maxRRPV, so invalid lines are immediate
            victims); hit -> RRPV=0; fill -> RRPV=maxRRPV-1; victim = first
            way with RRPV==maxRRPV, aging all ways up when none qualifies
            (the aging persists).
  * FIFO  — victim = first invalid way, else oldest fill.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

MAX_RRPV = 3  # 2-bit SRRIP

_POLICY_IDS = {"lru": 0, "srrip": 1, "fifo": 2}

# Line numbers fit int32 for any device-attached memory (2^31 lines x 64 B =
# 128 GB); guarded in simulate_cache. Avoids requiring jax_enable_x64.
ITYPE = jnp.int32

_GROUP_SETS = 32        # sets per scan group (carry = 32 x ways ints x 2)
_MIN_BUCKET = 1024      # smallest padded sub-trace length


@dataclass(frozen=True)
class CacheGeometry:
    num_sets: int
    ways: int
    line_bytes: int

    @property
    def capacity_bytes(self) -> int:
        return self.num_sets * self.ways * self.line_bytes

    @staticmethod
    def from_capacity(capacity_bytes: int, line_bytes: int, ways: int) -> "CacheGeometry":
        num_lines = capacity_bytes // line_bytes
        num_sets = max(1, num_lines // ways)
        return CacheGeometry(num_sets=num_sets, ways=ways, line_bytes=line_bytes)


@dataclass
class CacheResult:
    hits: np.ndarray          # bool (N,) per-access hit flag
    num_hits: int
    num_misses: int
    num_evictions: int

    @property
    def accesses(self) -> int:
        return self.num_hits + self.num_misses

    @property
    def hit_rate(self) -> float:
        return self.num_hits / max(self.accesses, 1)


def _step(policy_id: int, ways: int, carry, x):
    """One cache access. carry = (tags, meta, t).

    x = (set_idx, tag, valid). Padded (invalid) accesses leave the state
    untouched and report miss (filtered by the caller).

    tags: (S, W) ITYPE, -1 = invalid line.
    meta: (S, W) int32 — LRU/FIFO: last-use / fill timestamp (-1 invalid);
                          SRRIP: RRPV.
    """
    tags, meta, t = carry
    s, tag, valid = x
    row_tags = tags[s]
    row_meta = meta[s]

    hit_vec = row_tags == tag
    hit = jnp.any(hit_vec)
    hit_way = jnp.argmax(hit_vec)

    invalid_vec = row_tags < 0

    if policy_id == _POLICY_IDS["srrip"]:
        # Age the set until some way reaches MAX_RRPV (persists, ChampSim-style).
        inc = jnp.maximum(0, MAX_RRPV - jnp.max(row_meta))
        aged = row_meta + inc
        victim = jnp.argmax(aged == MAX_RRPV)  # first way at maxRRPV
        new_meta_hit = row_meta.at[hit_way].set(0)
        new_meta_miss = aged.at[victim].set(MAX_RRPV - 1)
    else:
        # Timestamp metadata. Invalid ways get -1 < any timestamp, so argmin
        # picks the first invalid way first (ChampSim behaviour), then ties
        # break to the lowest way index.
        victim = jnp.argmin(jnp.where(invalid_vec, -1, row_meta))
        if policy_id == _POLICY_IDS["lru"]:
            new_meta_hit = row_meta.at[hit_way].set(t)
        else:  # fifo: hits do not touch metadata
            new_meta_hit = row_meta
        new_meta_miss = row_meta.at[victim].set(t)

    evict = jnp.logical_and(valid, jnp.logical_and(~hit, row_tags[victim] >= 0))
    new_row_meta = jnp.where(hit, new_meta_hit, new_meta_miss)
    new_row_tags = jnp.where(hit, row_tags, row_tags.at[victim].set(tag))

    # Masked (padding) accesses leave state untouched.
    new_row_tags = jnp.where(valid, new_row_tags, row_tags)
    new_row_meta = jnp.where(valid, new_row_meta, row_meta)

    tags = tags.at[s].set(new_row_tags)
    meta = meta.at[s].set(new_row_meta)
    return (tags, meta, t + jnp.int32(1)), (jnp.logical_and(hit, valid), evict)


@functools.partial(jax.jit, static_argnames=("num_sets", "ways", "policy"))
def _simulate(sets: jax.Array, tags_in: jax.Array, valid: jax.Array,
              num_sets: int, ways: int, policy: str):
    tags0 = jnp.full((num_sets, ways), -1, dtype=ITYPE)
    if policy == "srrip":
        meta0 = jnp.full((num_sets, ways), MAX_RRPV, dtype=jnp.int32)
    else:
        meta0 = jnp.full((num_sets, ways), -1, dtype=jnp.int32)
    step = functools.partial(_step, _POLICY_IDS[policy], ways)
    (_, _, _), (hits, evicts) = jax.lax.scan(
        step, (tags0, meta0, jnp.int32(0)), (sets, tags_in, valid)
    )
    return hits, evicts


def _bucket_len(n: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return b


def simulate_cache(
    lines: np.ndarray | jax.Array,
    geometry: CacheGeometry,
    policy: str = "lru",
) -> CacheResult:
    """Run the trace through the cache; returns per-access hits + counts."""
    if policy not in _POLICY_IDS:
        raise ValueError(f"unknown policy {policy!r}; options: {sorted(_POLICY_IDS)}")
    lines_np = np.asarray(lines, dtype=np.int64).reshape(-1)
    n = lines_np.size
    if n == 0:
        return CacheResult(np.zeros(0, dtype=bool), 0, 0, 0)
    if int(lines_np.max()) >= np.iinfo(np.int32).max:
        raise ValueError("line numbers exceed int32 range; rebase the trace")

    S, W = geometry.num_sets, geometry.ways
    set_idx = (lines_np % S).astype(np.int32)
    tag = lines_np.astype(np.int32)

    hits = np.zeros(n, dtype=bool)
    evict_total = 0

    if S <= _GROUP_SETS:
        pad = _bucket_len(n) - n
        s_p = np.pad(set_idx, (0, pad))
        t_p = np.pad(tag, (0, pad), constant_values=-2)
        v_p = np.pad(np.ones(n, dtype=bool), (0, pad))
        h, e = _simulate(jnp.asarray(s_p), jnp.asarray(t_p), jnp.asarray(v_p), S, W, policy)
        hits = np.asarray(h)[:n]
        evict_total = int(np.asarray(e).sum())
    else:
        group = set_idx // _GROUP_SETS
        order = np.argsort(group, kind="stable")  # time order kept within group
        g_sorted = group[order]
        bounds = np.searchsorted(g_sorted, np.arange(group.max() + 2))
        for g in range(int(group.max()) + 1):
            lo, hi = bounds[g], bounds[g + 1]
            if lo == hi:
                continue
            idx = order[lo:hi]
            m = hi - lo
            pad = _bucket_len(m) - m
            s_p = np.pad(set_idx[idx] - g * _GROUP_SETS, (0, pad))
            t_p = np.pad(tag[idx], (0, pad), constant_values=-2)
            v_p = np.pad(np.ones(m, dtype=bool), (0, pad))
            n_sets_g = min(_GROUP_SETS, S - g * _GROUP_SETS)
            h, e = _simulate(
                jnp.asarray(s_p), jnp.asarray(t_p), jnp.asarray(v_p),
                n_sets_g, W, policy,
            )
            hits[idx] = np.asarray(h)[:m]
            evict_total += int(np.asarray(e).sum())

    n_hit = int(hits.sum())
    return CacheResult(
        hits=hits,
        num_hits=n_hit,
        num_misses=n - n_hit,
        num_evictions=evict_total,
    )
