"""JAX set-associative cache engine (the paper's cycle-level memory sim core).

The paper validates EONSim's on-chip cache model against ChampSim and reports
*identical* hit/miss counts under LRU and SRRIP (Fig. 4a). We reproduce that
bar: this engine is bit-exact against ``golden.GoldenCache`` (a sequential
Python model written to ChampSim's replacement semantics), enforced by tests.

TPU-native design: the sequential C++ cache loop becomes a ``jax.lax.scan``
over the address trace with carry ``(tags, meta)``. Two structural
optimizations keep it fast while remaining bit-exact (both tested):

  1. **Set-group partitioning.** Accesses interact only within a cache set,
     so the set space is split into groups of ``_GROUP_SETS`` sets; each
     group's sub-trace runs through its own scan with a tiny carry
     (group_sets x ways). A monolithic carry (e.g. 16384x16) forces XLA to
     copy megabytes per scan step (~11 K acc/s measured); the grouped carry
     runs ~100x faster, and the scan body is unrolled (``_SCAN_UNROLL``) to
     amortize CPU loop overhead (BENCH_cache_kernel.json tracks acc/s).
  2. **Length-bucketed padding.** Group sub-traces are padded to power-of-two
     lengths with masked no-op accesses so only O(log N) distinct shapes are
     ever compiled. The floor is ``_MIN_BUCKET = 64``: small enough that a
     short sub-trace (large-capacity configs split into many set groups)
     wastes at most ~2x in padding, while the power-of-two rule keeps the
     compiled-shape count logarithmic (test-enforced).

Backends: the scan engine above (``cache_backend="scan"``), a Pallas kernel
(``cache_backend="pallas"``, ``kernels/cache_scan.py``) that keeps the
(tags, meta) set-group state in VMEM and walks the padded sub-trace
in-kernel, and the analytic engines (``cache_backend="stack"``, the
default). Under ``"stack"``/``"stack_pallas"`` every policy classifies
without a full-trace sequential scan: LRU through shared Mattson
stack-distance passes (``memory/stack.py``; one sort-based pass per
(stream, num_sets) classifies every associativity; ``"stack_pallas"``
swaps in the Pallas distance kernel, ``kernels/stack_distance.py``), and
srrip/fifo through the compressed per-set engines (``memory/rrip.py``:
shared presort per (stream, num_sets), short batched per-set scans instead
of one O(n) scan per config). Scan and pallas run through the same
set-group partitioning and length bucketing; ALL backends are bit-exact
against ``golden.GoldenCache`` (test-enforced); the Pallas paths fall back
to interpret mode off-TPU so CPU CI exercises them end to end.

Replacement semantics (matching ChampSim):
  * LRU   — victim = first invalid way, else least-recently-used way.
  * SRRIP — 2-bit RRPV, init 3 (= maxRRPV, so invalid lines are immediate
            victims); hit -> RRPV=0; fill -> RRPV=maxRRPV-1; victim = first
            way with RRPV==maxRRPV, aging all ways up when none qualifies
            (the aging persists).
  * FIFO  — victim = first invalid way, else oldest fill.
"""
from __future__ import annotations

import functools
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from ..hardware import CACHE_BACKENDS
from ..profiling import is_active as _profiling_active, stage

MAX_RRPV = 3  # 2-bit SRRIP

_POLICY_IDS = {"lru": 0, "srrip": 1, "fifo": 2}

# Line numbers fit int32 for any device-attached memory (2^31 lines x 64 B =
# 128 GB); guarded in simulate_cache. Avoids requiring jax_enable_x64.
ITYPE = jnp.int32

_GROUP_SETS = 16        # sets per scan group (carry = 16 x ways ints x 2).
                        # Halving from 32 halves the sequential step count per
                        # bucket (sub-traces split finer) at the cost of twice
                        # the vmapped rows — a measured ~25% win on CPU where
                        # per-step overhead dominates (BENCH_cache_kernel).
_MIN_BUCKET = 64        # smallest padded sub-trace length (<= ~2x padding)
_SCAN_UNROLL = 8        # loop unroll for the tiny per-access scan body


@dataclass(frozen=True)
class CacheGeometry:
    num_sets: int
    ways: int
    line_bytes: int

    @property
    def capacity_bytes(self) -> int:
        return self.num_sets * self.ways * self.line_bytes

    @staticmethod
    def from_capacity(capacity_bytes: int, line_bytes: int, ways: int) -> "CacheGeometry":
        num_lines = capacity_bytes // line_bytes
        num_sets = max(1, num_lines // ways)
        return CacheGeometry(num_sets=num_sets, ways=ways, line_bytes=line_bytes)


@dataclass
class CacheResult:
    hits: np.ndarray          # bool (N,) per-access hit flag
    num_hits: int
    num_misses: int
    num_evictions: int

    @property
    def accesses(self) -> int:
        return self.num_hits + self.num_misses

    @property
    def hit_rate(self) -> float:
        return self.num_hits / max(self.accesses, 1)


def _step(policy_id: int, ways: int, carry, x):
    """One cache access. carry = (tags, meta, t).

    x = (set_idx, tag, valid). Padded (invalid) accesses leave the state
    untouched and report miss (filtered by the caller).

    tags: (S, W) ITYPE, -1 = invalid line.
    meta: (S, W) int32 — LRU/FIFO: last-use / fill timestamp (-1 invalid);
                          SRRIP: RRPV.
    """
    tags, meta, t = carry
    s, tag, valid = x
    row_tags = tags[s]
    row_meta = meta[s]

    hit_vec = row_tags == tag
    hit = jnp.any(hit_vec)
    hit_way = jnp.argmax(hit_vec)

    invalid_vec = row_tags < 0

    if policy_id == _POLICY_IDS["srrip"]:
        # Age the set until some way reaches MAX_RRPV (persists, ChampSim-style).
        inc = jnp.maximum(0, MAX_RRPV - jnp.max(row_meta))
        aged = row_meta + inc
        victim = jnp.argmax(aged == MAX_RRPV)  # first way at maxRRPV
        new_meta_hit = row_meta.at[hit_way].set(0)
        new_meta_miss = aged.at[victim].set(MAX_RRPV - 1)
    else:
        # Timestamp metadata. Invalid ways get -1 < any timestamp, so argmin
        # picks the first invalid way first (ChampSim behaviour), then ties
        # break to the lowest way index.
        victim = jnp.argmin(jnp.where(invalid_vec, -1, row_meta))
        if policy_id == _POLICY_IDS["lru"]:
            new_meta_hit = row_meta.at[hit_way].set(t)
        else:  # fifo: hits do not touch metadata
            new_meta_hit = row_meta
        new_meta_miss = row_meta.at[victim].set(t)

    evict = jnp.logical_and(valid, jnp.logical_and(~hit, row_tags[victim] >= 0))
    new_row_meta = jnp.where(hit, new_meta_hit, new_meta_miss)
    new_row_tags = jnp.where(hit, row_tags, row_tags.at[victim].set(tag))

    # Masked (padding) accesses leave state untouched.
    new_row_tags = jnp.where(valid, new_row_tags, row_tags)
    new_row_meta = jnp.where(valid, new_row_meta, row_meta)

    tags = tags.at[s].set(new_row_tags)
    meta = meta.at[s].set(new_row_meta)
    return (tags, meta, t + jnp.int32(1)), (jnp.logical_and(hit, valid), evict)


def _scan_trace(sets: jax.Array, tags_in: jax.Array, valid: jax.Array,
                num_sets: int, ways: int, policy: str):
    tags0 = jnp.full((num_sets, ways), -1, dtype=ITYPE)
    if policy == "srrip":
        meta0 = jnp.full((num_sets, ways), MAX_RRPV, dtype=jnp.int32)
    else:
        meta0 = jnp.full((num_sets, ways), -1, dtype=jnp.int32)
    step = functools.partial(_step, _POLICY_IDS[policy], ways)
    (_, _, _), (hits, evicts) = jax.lax.scan(
        step, (tags0, meta0, jnp.int32(0)), (sets, tags_in, valid),
        unroll=_SCAN_UNROLL,
    )
    return hits, evicts


@functools.partial(jax.jit, static_argnames=("num_sets", "ways", "policy"))
def _simulate_many(sets: jax.Array, tags_in: jax.Array, valid: jax.Array,
                   num_sets: int, ways: int, policy: str):
    """Vmapped ``_scan_trace`` over a leading batch axis of same-shape scans.

    Per-row results are bit-exact with the unbatched scan (pure integer/bool
    carry), so fusing many grid points' group scans into one dispatch never
    changes classification — only dispatch count.
    """
    return jax.vmap(
        lambda s, t, v: _scan_trace(s, t, v, num_sets, ways, policy)
    )(sets, tags_in, valid)


def _bucket_len(n: int) -> int:
    b = _MIN_BUCKET
    while b < n:
        b *= 2
    return b


def _validate(policy: str, backend: str) -> None:
    if policy not in _POLICY_IDS:
        raise ValueError(f"unknown policy {policy!r}; options: {sorted(_POLICY_IDS)}")
    if backend not in CACHE_BACKENDS:
        raise ValueError(
            f"unknown cache backend {backend!r}; options: {CACHE_BACKENDS}"
        )


def _effective_backend(policy: str, backend: str) -> str:
    """Resolve the stack variants per policy.

    Every policy has an analytic engine, so ``"stack"`` resolves to
    ``"stack"`` for all of them: LRU classifies through Mattson
    stack-distance passes and srrip/fifo through the compressed per-set
    engines (``rrip.py``). Only the LRU *distance pass* has a Pallas
    variant, so ``"stack_pallas"`` differs from ``"stack"`` for LRU alone
    and resolves to ``"stack"`` otherwise. The backend knob can never
    change results — all engines are bit-exact (test-enforced).
    """
    if backend == "stack_pallas" and policy != "lru":
        return "stack"
    return backend


def simulate_cache(
    lines: np.ndarray | jax.Array,
    geometry: CacheGeometry,
    policy: str = "lru",
    backend: str = "scan",
) -> CacheResult:
    """Run the trace through the cache; returns per-access hits + counts.

    Thin wrapper over ``simulate_cache_many`` with a single pair, so the
    single-config and batched paths are equivalent by construction.
    """
    return simulate_cache_many([lines], [geometry], policy, backend=backend)[0]


def _build_tasks(lines_list, geometries):
    """Set-group scan tasks for independent (trace, geometry) pairs.

    Each task is ``(cfg, idx-or-None, local_sets, tags, n_sets_g, ways)`` —
    one sub-trace confined to a group of ``_GROUP_SETS`` sets, exactly
    mirroring the per-config set-group partitioning of ``simulate_cache``.
    """
    tasks = []
    for cfg, (lines_np, geom) in enumerate(zip(lines_list, geometries)):
        n = lines_np.size
        if n == 0:
            continue
        if int(lines_np.max()) >= np.iinfo(np.int32).max:
            raise ValueError("line numbers exceed int32 range; rebase the trace")
        S, W = geom.num_sets, geom.ways
        set_idx = (lines_np % S).astype(np.int32)
        tag = lines_np.astype(np.int32)
        if S <= _GROUP_SETS:
            tasks.append((cfg, None, set_idx, tag, S, W))
        else:
            group = set_idx // _GROUP_SETS
            order = np.argsort(group, kind="stable")
            g_sorted = group[order]
            bounds = np.searchsorted(g_sorted, np.arange(group.max() + 2))
            for g in range(int(group.max()) + 1):
                lo, hi = bounds[g], bounds[g + 1]
                if lo == hi:
                    continue
                idx = order[lo:hi]
                n_sets_g = min(_GROUP_SETS, S - g * _GROUP_SETS)
                tasks.append(
                    (cfg, idx, set_idx[idx] - g * _GROUP_SETS, tag[idx], n_sets_g, W)
                )
    return tasks


def _run_buckets(lines_list, geometries, policy: str, backend: str):
    """Bucket set-group tasks by padded shape and run each bucket as ONE
    device dispatch of the selected backend.

    Yields ``(tasks, hits, evicts)`` per bucket with hits/evicts still
    DEVICE-resident ``(B, L)`` arrays — callers decide when to sync.
    ``backend`` must already be resolved (scan | pallas | stack_pallas).
    """
    tasks = _build_tasks(lines_list, geometries)
    buckets: "dict[tuple, list]" = {}
    for t in tasks:
        m = t[2].size
        buckets.setdefault((_bucket_len(m), t[4], t[5]), []).append(t)

    out = []
    for (L, S_g, W), ts in buckets.items():
        B = len(ts)
        s_b = np.zeros((B, L), dtype=np.int32)
        t_b = np.full((B, L), -2, dtype=np.int32)
        v_b = np.zeros((B, L), dtype=bool)
        for row, (_, _, s_loc, tags, _, _) in enumerate(ts):
            m = s_loc.size
            s_b[row, :m] = s_loc
            t_b[row, :m] = tags
            v_b[row, :m] = True
        with stage("cache_scan"):
            if backend == "pallas":
                from ...kernels.cache_scan import cache_scan_groups

                h, e = cache_scan_groups(
                    jnp.asarray(s_b), jnp.asarray(t_b), jnp.asarray(v_b),
                    S_g, W, policy,
                )
            elif backend == "stack_pallas":
                from ...kernels.stack_distance import stack_distance_groups

                d, e = stack_distance_groups(
                    jnp.asarray(s_b), jnp.asarray(t_b), jnp.asarray(v_b),
                    S_g, W,
                )
                h = d < W
            else:
                h, e = _simulate_many(
                    jnp.asarray(s_b), jnp.asarray(t_b), jnp.asarray(v_b),
                    S_g, W, policy,
                )
            if _profiling_active():
                # Attribute async device compute to "cache_scan", not to the
                # extraction in the caller (profiling sessions only).
                jax.block_until_ready((h, e))
        out.append((ts, h, e))
    return out


def _classify_analytic(lines_list, geometries, policy):
    """(hits, evictions) pairs from the policy's analytic engine: Mattson
    stack distances for LRU, compressed per-set engines for srrip/fifo."""
    if policy == "lru":
        from .stack import classify_lru_stack_many

        return classify_lru_stack_many(lines_list, geometries)
    from .rrip import classify_analytic_many

    return classify_analytic_many(
        lines_list, [(g.num_sets, g.ways) for g in geometries], policy
    )


def simulate_cache_many(
    streams: "list[np.ndarray]",
    geometries: "list[CacheGeometry]",
    policy: str = "lru",
    backend: str = "scan",
) -> "list[CacheResult]":
    """Run several independent (trace, geometry) pairs under one policy.

    Semantically identical to ``[simulate_cache(s, g, policy) ...]`` (tests
    enforce bit-exactness), but every set-group sub-scan across ALL pairs is
    bucketed by its padded (length, sets, ways) shape and each bucket runs as
    ONE vmapped dispatch (``_simulate_many``, or the Pallas kernel under
    ``backend="pallas"``). A DSE sweep evaluating many same-(ways, policy)
    capacities therefore pays per *shape*, not per config.
    """
    _validate(policy, backend)
    lines_list = [np.asarray(s, dtype=np.int64).reshape(-1) for s in streams]
    if len(lines_list) != len(geometries):
        raise ValueError("streams and geometries length mismatch")
    backend = _effective_backend(policy, backend)
    if backend == "stack":
        pairs = _classify_analytic(lines_list, geometries, policy)
        return [
            CacheResult(
                hits=h,
                num_hits=int(h.sum()),
                num_misses=h.size - int(h.sum()),
                num_evictions=ev,
            )
            for h, ev in pairs
        ]

    hits_out = [np.zeros(l.size, dtype=bool) for l in lines_list]
    evict_out = [0] * len(lines_list)

    for ts, h_d, e_d in _run_buckets(lines_list, geometries, policy, backend):
        with stage("host_sync"):
            h = np.asarray(h_d)
            e = np.asarray(e_d)
        for row, (cfg, idx, s_loc, _, _, _) in enumerate(ts):
            m = s_loc.size
            if idx is None:
                hits_out[cfg] = h[row, :m].copy()
            else:
                hits_out[cfg][idx] = h[row, :m]
            evict_out[cfg] += int(e[row].sum())  # padded slots never evict

    return [
        CacheResult(
            hits=hits,
            num_hits=int(hits.sum()),
            num_misses=hits.size - int(hits.sum()),
            num_evictions=ev,
        )
        for hits, ev in zip(hits_out, evict_out)
    ]


def classify_streams(
    streams: "list[np.ndarray]",
    geometries: "list[CacheGeometry]",
    policy: str = "lru",
    backend: str = "scan",
) -> "list[np.ndarray]":
    """Per-access hit arrays for several (trace, geometry) pairs.

    The classification-only surface the MemorySystem hot path consumes: the
    same bucketed device dispatches as ``simulate_cache_many``, but skips
    eviction accounting and performs exactly ONE blocking device->host
    extraction per bucket — the single sync point of the classify stage.
    Under the ``stack`` backend every policy classifies through its shared
    analytic passes instead (stack distances for LRU, compressed per-set
    engines for srrip/fifo — one presort per (stream, num_sets)).
    """
    _validate(policy, backend)
    lines_list = [np.asarray(s, dtype=np.int64).reshape(-1) for s in streams]
    if len(lines_list) != len(geometries):
        raise ValueError("streams and geometries length mismatch")
    backend = _effective_backend(policy, backend)
    if backend == "stack":
        return [h for h, _ in _classify_analytic(lines_list, geometries, policy)]
    hits_out = [np.zeros(l.size, dtype=bool) for l in lines_list]
    for ts, h_d, _ in _run_buckets(lines_list, geometries, policy, backend):
        with stage("host_sync"):
            h = np.asarray(h_d)
        for row, (cfg, idx, s_loc, _, _, _) in enumerate(ts):
            m = s_loc.size
            if idx is None:
                hits_out[cfg] = h[row, :m].copy()
            else:
                hits_out[cfg][idx] = h[row, :m]
    return hits_out
