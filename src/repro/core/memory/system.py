"""Unified MemorySystem: the classify -> miss-trace -> DRAM-timing pipeline.

This is the layer the paper's Fig. 2 "Simulation" stage describes for
embedding operations, extracted behind one owner so every on-chip policy and
memory geometry goes through the same path:

  ConcatTrace (lookups, true per-batch boundaries)
      |  [lane transform, when exact]    vector-granular stream
      |  [otherwise]                     line-granular stream (translate)
      v
  MemoryPolicy.run  — pluggable registry (policies.py), shared accounting
      v
  miss line trace + per-batch attribution     (ClassifiedStream)
      v
  dram_timing_segmented — ONE batched event scan for all batches
      v
  per-batch EmbeddingBatchStats (cycles, access counts, DRAM row stats)

Lane-decomposition transform (the paper stresses *fast and accurate*): when
the cache geometry satisfies ``num_sets % lines_per_vector == 0`` and vectors
are line-aligned, the line-level set-associative cache decomposes into
``lines_per_vector`` independent "lane" sub-caches that each observe the same
vector-granular stream. Simulating ONE lane at vector granularity and scaling
counts is then *bit-exact* vs line-level simulation (tests enforce this) and
cuts scan length by lines_per_vector (8x for DLRM's 512 B vectors / 64 B
lines). Here the transform is applied *transparently* to any policy that
declares ``supports_lane_transform`` — the policy classifies whatever stream
it is handed; hit/miss/read/write accounting is shared between both paths.

Per-batch DRAM timing semantics match the historical engine: each batch's
miss burst is timed against fresh DRAM state (double-buffered streaming, the
memory-bound regime), but all batches now run as one segmented scan instead
of a Python loop of independent JAX dispatches.

Multi-core CoreCluster topology (``MultiCoreMemorySystem``): the same
classify pipeline runs N times over deterministic per-core trace shards
(PRIVATE topology — each core owns an on-chip memory) or once over the
interleaved stream (SHARED last-level topology), and all cores' miss bursts
are then timed against ONE shared DRAM with cross-core channel contention
(``dram_timing_contended``) instead of fresh DRAM state per core. The
degenerate ``num_cores=1, private`` configuration delegates to the
single-core path and is bit-exact with it (test-enforced).

Per-table policy mixes (``hw.onchip.policy_mix``): tables are partitioned
into policy groups (hot tables pinned, cold tables cached, ...); each group
classifies its sub-stream under a set-proportional slice of the on-chip
capacity (``PolicyContext.scaled``), and the groups' miss streams merge back
in global trace order for DRAM timing.

NUMA placement (``hw.channel_affinity`` / ``hw.placement``): before a miss
trace becomes a ``DramRequest``, ``PlacementMap.place`` (trace.py) maps each
line to its (channel-group, rank) home — per-core private channel groups
under ``per_core``, per-table groups under ``per_table``, TensorDIMM-style
per-rank table homes under ``table_rank``/``hot_replicate``. The transform
is pure address remapping, so the contended/batched DRAM engines are reused
untouched; the degenerate ``symmetric``/``interleave`` pair skips the map
entirely and is bitwise identical to the historical engine (test-enforced).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from ..hardware import HardwareConfig, Topology
from ..profiling import stage
from ..trace import (
    AddressTrace,
    ConcatTrace,
    FullTrace,
    PlacementMap,
    profile_hot_vectors,
    shard_lookup_cores,
    shard_trace,
    translate,
    validate_indices,
)
from ..workload import EmbeddingOpSpec
from .cache import CacheGeometry
from .dram import (
    DramModel,
    DramRequest,
    dram_timing_many,
    dram_timing_single,
)
from .policies import (
    MemoryPolicy,
    PolicyContext,
    PolicyOutcome,
    get_policy,
    resolve_policy_mix,
)
from .tlb import charge_cache_lookup


# --------------------------------------------------------------------------
# Lane-decomposition transform
# --------------------------------------------------------------------------

def lane_geometry(hw: HardwareConfig, spec: EmbeddingOpSpec) -> Optional[CacheGeometry]:
    """Vector-granular lane geometry when the decomposition is exact."""
    line = hw.onchip.line_bytes
    if spec.vector_bytes % line != 0:
        return None
    lpv = spec.vector_bytes // line
    full_geom = CacheGeometry.from_capacity(hw.onchip.capacity_bytes, line, hw.onchip.ways)
    if lpv <= 1 or full_geom.num_sets % lpv != 0:
        return None
    return CacheGeometry(
        num_sets=full_geom.num_sets // lpv,
        ways=full_geom.ways,
        line_bytes=spec.vector_bytes,
    )


# --------------------------------------------------------------------------
# Per-batch stats (the MemorySystem accounting contract)
# --------------------------------------------------------------------------

@dataclass
class CoreBatchStats:
    """Per-core detail for one batch under a multi-core topology."""

    core_id: int
    lookups: int = 0
    onchip_reads: int = 0
    cache_misses: int = 0
    onchip_cycles: float = 0.0
    vector_cycles: float = 0.0
    dram_finish_cycles: float = 0.0   # this core's last miss completion
                                      # under shared-DRAM contention


@dataclass
class EmbeddingBatchStats:
    cycles: float = 0.0
    vector_cycles: float = 0.0
    dram_cycles: float = 0.0
    onchip_cycles: float = 0.0
    onchip_reads: int = 0
    onchip_writes: int = 0
    offchip_reads: int = 0
    cache_hits: int = 0          # line-granular
    cache_misses: int = 0
    dram_row_hits: int = 0
    dram_row_misses: int = 0
    # Address-translation detail (all zero when hw.translation is None —
    # the exact-identity default; see memory/tlb.py).
    tlb_hits: int = 0            # L1 TLB hits (free, pipelined)
    tlb_misses: int = 0          # L1 TLB misses
    tlb_walks: int = 0           # full page-table walks
    translation_cycles: float = 0.0   # stall added to the DRAM path
    per_core: Optional[List[CoreBatchStats]] = None   # multi-core detail


def _vector_compute_cycles(spec: EmbeddingOpSpec, batch_size: int, hw: HardwareConfig) -> float:
    """Stage-3 vector arithmetic (Fig. 1): pooling on the VPU."""
    flops = spec.reduction_flops(batch_size)
    return flops / max(hw.vector_unit.throughput, 1)


# --------------------------------------------------------------------------
# Shared trace bundle (reused across sweep configurations)
# --------------------------------------------------------------------------

class EmbeddingTrace:
    """One embedding op's concatenated multi-batch trace + cached streams.

    The expensive derived streams (vector-id stream, line-address trace) are
    independent of the on-chip policy/capacity/associativity, so a DSE sweep
    builds one ``EmbeddingTrace`` per op and shares it across every
    configuration instead of regenerating per ``simulate()`` call.
    """

    def __init__(self, spec: EmbeddingOpSpec, traces: Sequence[FullTrace]):
        self.spec = spec
        self.concat = ConcatTrace.from_traces(traces)
        validate_indices(self.concat.row_ids, spec.rows_per_table,
                         what="row index")
        validate_indices(self.concat.table_ids, spec.num_tables,
                         what="table id")
        self._vec_ids: Optional[np.ndarray] = None
        self._lookup_batch: Optional[np.ndarray] = None
        self._atraces: Dict[int, AddressTrace] = {}
        self._hot_vecs: Optional[np.ndarray] = None
        self._unique_lines: Dict[int, int] = {}
        self._unique_pages: Dict[Tuple[int, int], np.ndarray] = {}

    @classmethod
    def from_concat(cls, spec: EmbeddingOpSpec, concat: ConcatTrace) -> "EmbeddingTrace":
        """Wrap an existing ConcatTrace (e.g. one core's shard) directly."""
        et = cls.__new__(cls)
        et.spec = spec
        et.concat = concat
        validate_indices(concat.row_ids, spec.rows_per_table,
                         what="row index")
        validate_indices(concat.table_ids, spec.num_tables,
                         what="table id")
        et._vec_ids = None
        et._lookup_batch = None
        et._atraces = {}
        et._hot_vecs = None
        et._unique_lines = {}
        return et

    @property
    def num_batches(self) -> int:
        return self.concat.num_batches

    @property
    def lookup_batch(self) -> np.ndarray:
        if self._lookup_batch is None:
            self._lookup_batch = self.concat.lookup_batch
        return self._lookup_batch

    @property
    def vec_ids(self) -> np.ndarray:
        """Globally unique vector id per lookup (lane-transform stream)."""
        if self._vec_ids is None:
            with stage("trace_gen"):
                self._vec_ids = (
                    self.concat.table_ids.astype(np.int64) * self.spec.rows_per_table
                    + self.concat.row_ids
                )
        return self._vec_ids

    def address_trace(self, line_bytes: int) -> AddressTrace:
        at = self._atraces.get(line_bytes)
        if at is None:
            with stage("trace_gen"):
                at = translate(self.concat, self.spec, line_bytes)
            self._atraces[line_bytes] = at
        return at

    def unique_line_count(self, line_bytes: int) -> int:
        """Distinct on-chip lines this op's whole trace touches — the line
        footprint. The sweep's memo-key canonicalization compares it against
        a ``capacity_saturates`` policy's capacity: any capacity at or above
        the footprint classifies identically (e.g. PINNING pins every line).
        Hardware-independent apart from the line geometry, so cached."""
        n = self._unique_lines.get(line_bytes)
        if n is None:
            n = int(np.unique(self.address_trace(line_bytes).lines).size)
            self._unique_lines[line_bytes] = n
        return n

    def unique_pages(self, line_bytes: int, page_bytes: int) -> np.ndarray:
        """Distinct translation pages this op's whole trace touches — the
        page footprint, sorted. The sweep's TLB memo-key canonicalization
        feeds it to ``tlb.translation_saturated``: every miss stream is a
        subsequence of this trace, so a TLB the footprint provably never
        evicts from classifies every config identically (first-touch-only
        walks). Hardware-independent apart from the line/page geometry, so
        cached like the line footprint."""
        key = (line_bytes, page_bytes)
        up = self._unique_pages.get(key)
        if up is None:
            from .tlb import tlb_pages

            up = np.unique(
                tlb_pages(self.address_trace(line_bytes).lines,
                          line_bytes, page_bytes))
            self._unique_pages[key] = up
        return up

    @property
    def hot_vec_ids(self) -> np.ndarray:
        """Profiled hot vector set (sorted ids) for ``hot_replicate``
        placement — deterministic in the trace, hardware-independent, so it
        is computed once and shared across every sweep configuration."""
        if self._hot_vecs is None:
            with stage("trace_gen"):
                self._hot_vecs = profile_hot_vectors(self.vec_ids)
        return self._hot_vecs


# --------------------------------------------------------------------------
# Classification result (decoupled from DRAM timing for multi-core reuse)
# --------------------------------------------------------------------------

@dataclass
class ClassifiedStream:
    """Per-batch accounting + the miss line trace of one classify pipeline.

    ``miss_pos`` (optional) is the global line-slot of each miss —
    ``global_lookup * lines_per_vector + line_offset`` — unique per line
    access, so independently classified sub-streams (per-core shards, policy
    groups) merge back into ONE deterministic interleaved stream for
    shared-DRAM timing by sorting on it.
    """

    num_batches: int
    hit_lines: np.ndarray            # (B,) line-granular hits per batch
    miss_count: np.ndarray           # (B,) line-granular misses per batch
    reads: np.ndarray                # (B,) line-granular on-chip reads per batch
    setup_writes: int
    miss_lines: np.ndarray           # (M,) line addresses, stream order
    miss_batch: np.ndarray           # (M,) batch of each miss line
    miss_pos: Optional[np.ndarray] = None   # (M,) global line-slot
    # Shared memo for the group-independent half of the placement transform
    # (PlacementMap.place), reused across placement siblings of this stream.
    place_cache: dict = field(default_factory=dict)
    # Memoized translation charges keyed by TranslationConfig.key —
    # translation observes the VIRTUAL miss stream (pre-placement), so
    # placement/topology siblings sharing this stream share each TLB
    # configuration's charge too (memory/tlb.py).
    tlb_cache: dict = field(default_factory=dict)


def _lane_context(
    hw: HardwareConfig,
    lane: CacheGeometry,
    lpv: int,
    pinned_lines: Optional[np.ndarray],
) -> PolicyContext:
    """Policy context for the vector-granular lane sub-cache."""
    return PolicyContext(
        geometry=lane,
        capacity_units=hw.onchip.num_lines // lpv,
        pinned_lines=pinned_lines,
        backend=hw.cache_backend,
    )


def _expand_lane_misses(
    concat: ConcatTrace,
    spec: EmbeddingOpSpec,
    mi: np.ndarray,
    line: int,
    lpv: int,
    lookup_index: Optional[np.ndarray],
):
    """Expand vector-granular miss lookups ``mi`` to line addresses (+ global
    line-slot positions when ``lookup_index`` is given) — the single owner of
    the contiguous-layout address arithmetic for the lane path."""
    miss_base = (
        concat.table_ids.astype(np.int64)[mi] * spec.table_bytes
        + concat.row_ids[mi] * spec.vector_bytes
    ) // line
    offs = np.arange(lpv, dtype=np.int64)
    miss_lines = (miss_base[:, None] + offs[None, :]).reshape(-1)
    miss_pos = None
    if lookup_index is not None:
        miss_pos = (lookup_index[mi][:, None] * lpv + offs[None, :]).reshape(-1)
    return miss_lines, miss_pos


def _merge_miss_streams(m_lines, m_batch, m_pos, m_src=None):
    """Merge independently classified miss streams into global trace order.

    Positions are unique line slots (``global_lookup * lpv + offset``), so a
    stable argsort reconstructs the exact order the merged bursts reach the
    shared memory controller. Returns ``(lines, batch, pos, src)``; ``src``
    is ``None`` unless per-stream source tags were given.
    """
    empty = np.zeros(0, dtype=np.int64)
    lines = np.concatenate(m_lines) if m_lines else empty
    batch = np.concatenate(m_batch) if m_batch else empty
    pos = np.concatenate(m_pos) if m_pos else empty
    order = np.argsort(pos, kind="stable")
    src = None
    if m_src is not None:
        src = (np.concatenate(m_src) if m_src else empty)[order]
    return lines[order], batch[order], pos[order], src


@dataclass
class _PreparedStream:
    """Stream + context resolved for one (etrace, hardware) pair."""

    stream: np.ndarray
    ctx: PolicyContext
    unit: int                        # lines represented by one stream access
    acc_batch: np.ndarray            # batch of each stream access
    use_lane: bool
    at: Optional[AddressTrace]       # line trace (line-granular path only)


@dataclass
class _ClusterClassified:
    """Placement-invariant classification of a multi-core cluster.

    Everything ``MultiCoreMemorySystem.pending_from`` needs to fan out into
    placement-specific DRAM requests: the merged miss stream, the per-miss
    source-core tags, and the stats-assembly closure (which reads only
    placement-invariant hardware fields, so it is shared verbatim across
    placement siblings)."""

    merged: "ClassifiedStream"
    miss_src: np.ndarray
    finalize: Callable
    # Shared memo for the group-independent half of the placement transform
    # (PlacementMap.place) — scoped to this classification's miss stream, so
    # placement siblings reuse the per-line base instead of recomputing it.
    place_cache: dict = field(default_factory=dict)
    # Memoized translation charges (see ClassifiedStream.tlb_cache): the
    # central MMU observes the merged virtual miss stream, so the charge is
    # shared across placement siblings of this classification.
    tlb_cache: dict = field(default_factory=dict)


@dataclass
class PendingEmbedding:
    """A classified embedding op whose DRAM timing has not yet run.

    ``request`` is the deferred ``dram_timing_contended`` dispatch; the sweep
    engine collects requests across every memoized configuration and times
    them through ONE batched ``dram_timing_many`` call, then ``finalize``
    assembles per-batch stats from the request's results. Classification and
    stats assembly are thereby decoupled from when (and with whom) DRAM
    timing executes — results are bit-exact either way (segments are
    independent; test-enforced).
    """

    request: DramRequest
    _finalize: Callable

    def finalize(self, drams, finish) -> "List[EmbeddingBatchStats]":
        return self._finalize(drams, finish)


# --------------------------------------------------------------------------
# MemorySystem (single core / shared-LLC pipeline)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MemorySystem:
    """Owns the whole on-chip + off-chip memory pipeline for one hardware
    configuration: policy classification, lane transform, miss-trace
    construction, and segmented DRAM timing with per-batch attribution."""

    hw: HardwareConfig
    policy: MemoryPolicy
    dram: DramModel

    @staticmethod
    def from_hardware(hw: HardwareConfig) -> "MemorySystem":
        return MemorySystem(
            hw=hw,
            policy=get_policy(hw.onchip.policy),
            dram=DramModel.from_hardware(hw),
        )

    # -- line-trace entry point (run_policy equivalent) ---------------------
    def classify(
        self, atrace: AddressTrace, pinned_lines: Optional[np.ndarray] = None
    ) -> PolicyOutcome:
        return self.policy.run(
            atrace.lines, PolicyContext.from_hardware(self.hw, pinned_lines)
        )

    # -- stream preparation -------------------------------------------------
    def _prepare_stream(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray],
        allow_lane: bool,
    ) -> _PreparedStream:
        spec = etrace.spec
        hw = self.hw
        line = hw.onchip.line_bytes
        lpv = max(1, -(-spec.vector_bytes // line))
        lookup_batch = etrace.lookup_batch

        lane = lane_geometry(hw, spec) if allow_lane else None
        use_lane = lane is not None and self.policy.supports_lane_transform

        if use_lane:
            # Transparent transform: hand the policy the vector-granular
            # stream under the lane sub-cache geometry; every access stands
            # for ``lpv`` line accesses.
            return _PreparedStream(
                stream=etrace.vec_ids,
                ctx=_lane_context(hw, lane, lpv, pinned_lines),
                unit=lpv,
                acc_batch=lookup_batch,
                use_lane=True,
                at=None,
            )
        at = etrace.address_trace(line)
        return _PreparedStream(
            stream=at.lines,
            ctx=PolicyContext.from_hardware(hw, pinned_lines),
            unit=1,
            acc_batch=np.repeat(lookup_batch, at.lines_per_vector),
            use_lane=False,
            at=at,
        )

    # -- per-batch accounting ------------------------------------------------
    def _account(
        self,
        etrace: EmbeddingTrace,
        prep: _PreparedStream,
        out: PolicyOutcome,
        lookup_index: Optional[np.ndarray],
    ) -> ClassifiedStream:
        """Shared accounting contract, per batch: reads = every consumed
        line, writes = fills/stages (+ one-time setup on batch 0), offchip =
        miss fetches. ``unit`` scales vector-granular counts back to lines."""
        spec = etrace.spec
        line = self.hw.onchip.line_bytes
        lpv = max(1, -(-spec.vector_bytes // line))
        num_batches = etrace.num_batches
        unit, acc_batch = prep.unit, prep.acc_batch
        hits = out.hits
        misses = ~hits

        hit_lines = np.bincount(acc_batch[hits], minlength=num_batches) * unit
        miss_count = np.bincount(acc_batch[misses], minlength=num_batches) * unit
        reads = np.bincount(acc_batch, minlength=num_batches) * unit

        miss_pos = None
        if prep.use_lane:
            # Expand vector-granular misses to line addresses for DRAM timing.
            mi = np.nonzero(misses)[0]
            miss_lines, miss_pos = _expand_lane_misses(
                etrace.concat, spec, mi, line, lpv, lookup_index
            )
            miss_batch = np.repeat(acc_batch[misses], unit)
        else:
            miss_lines = out.miss_lines
            miss_batch = acc_batch[misses]
            if lookup_index is not None:
                midx = np.nonzero(misses)[0]
                vec = prep.at.vector_of_line[midx]
                miss_pos = lookup_index[vec] * lpv + midx % lpv

        return ClassifiedStream(
            num_batches=num_batches,
            hit_lines=hit_lines,
            miss_count=miss_count,
            reads=reads,
            setup_writes=out.setup_writes,
            miss_lines=miss_lines,
            miss_batch=miss_batch,
            miss_pos=miss_pos,
        )

    # -- classification (mix-aware) -----------------------------------------
    def classify_embedding(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
        lookup_index: Optional[np.ndarray] = None,
    ) -> ClassifiedStream:
        """Run the on-chip classification pipeline over all batches.

        ``lookup_index`` maps this trace's lookups to global positions (per-
        core shards); when given, the result carries ``miss_pos`` so several
        classified streams can merge deterministically for shared-DRAM timing.
        """
        if self.hw.onchip.policy_mix:
            return self._classify_mixed(etrace, pinned_lines, allow_lane, lookup_index)
        prep = self._prepare_stream(etrace, pinned_lines, allow_lane)
        out = self.policy.run(prep.stream, prep.ctx)
        return self._account(etrace, prep, out, lookup_index)

    def _classify_mixed(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray],
        allow_lane: bool,
        lookup_index: Optional[np.ndarray],
    ) -> ClassifiedStream:
        """Per-table policy mix: classify each policy group's sub-stream under
        a capacity partition, then merge miss streams in global trace order."""
        spec = etrace.spec
        hw = self.hw
        concat = etrace.concat
        line = hw.onchip.line_bytes
        lpv = max(1, -(-spec.vector_bytes // line))
        num_batches = etrace.num_batches
        lookup_batch = etrace.lookup_batch
        if lookup_index is None:
            # Positions are needed regardless: the merged miss stream must be
            # in trace order for DRAM timing.
            lookup_index = np.arange(len(concat), dtype=np.int64)

        groups = resolve_policy_mix(
            hw.onchip.policy_mix, hw.onchip.policy, spec.num_tables
        )
        gid_of_table = np.empty(spec.num_tables, dtype=np.int32)
        for gi, g in enumerate(groups):
            gid_of_table[list(g.table_ids)] = gi
        gid = gid_of_table[concat.table_ids]

        lane = lane_geometry(hw, spec) if allow_lane else None
        hit_lines = np.zeros(num_batches, dtype=np.int64)
        miss_count = np.zeros(num_batches, dtype=np.int64)
        reads = np.zeros(num_batches, dtype=np.int64)
        setup = 0
        m_lines, m_batch, m_pos = [], [], []
        at: Optional[AddressTrace] = None
        offs = np.arange(lpv, dtype=np.int64)

        for gi, g in enumerate(groups):
            lidx = np.nonzero(gid == gi)[0].astype(np.int64)
            if lidx.size == 0:
                continue
            use_lane = lane is not None and g.policy.supports_lane_transform
            if use_lane:
                stream = etrace.vec_ids[lidx]
                ctx = _lane_context(hw, lane, lpv, pinned_lines).scaled(g.fraction)
                unit = lpv
                acc_batch = lookup_batch[lidx]
            else:
                if at is None:
                    at = etrace.address_trace(line)
                line_idx = (lidx[:, None] * lpv + offs[None, :]).reshape(-1)
                stream = at.lines[line_idx]
                ctx = PolicyContext.from_hardware(hw, pinned_lines).scaled(g.fraction)
                unit = 1
                acc_batch = np.repeat(lookup_batch[lidx], lpv)

            out = g.policy.run(stream, ctx)
            hits = out.hits
            misses = ~hits
            hit_lines += np.bincount(acc_batch[hits], minlength=num_batches) * unit
            miss_count += np.bincount(acc_batch[misses], minlength=num_batches) * unit
            reads += np.bincount(acc_batch, minlength=num_batches) * unit
            setup += out.setup_writes

            if use_lane:
                mi = lidx[np.nonzero(misses)[0]]
                g_lines, g_pos = _expand_lane_misses(
                    concat, spec, mi, line, lpv, lookup_index
                )
                m_lines.append(g_lines)
                m_batch.append(np.repeat(acc_batch[misses], unit))
                m_pos.append(g_pos)
            else:
                midx = line_idx[np.nonzero(misses)[0]]
                m_lines.append(at.lines[midx])
                m_batch.append(acc_batch[misses])
                m_pos.append(lookup_index[at.vector_of_line[midx]] * lpv + midx % lpv)

        all_lines, all_batch, all_pos, _ = _merge_miss_streams(m_lines, m_batch, m_pos)
        return ClassifiedStream(
            num_batches=num_batches,
            hit_lines=hit_lines,
            miss_count=miss_count,
            reads=reads,
            setup_writes=setup,
            miss_lines=all_lines,
            miss_batch=all_batch,
            miss_pos=all_pos,
        )

    # -- stats assembly -----------------------------------------------------
    def _assemble_stats(
        self, etrace: EmbeddingTrace, cs: ClassifiedStream, drams, tlb=None
    ) -> List[EmbeddingBatchStats]:
        hw = self.hw
        line = hw.onchip.line_bytes
        onchip_bw = max(hw.onchip.read_bw_bytes_per_cycle, 1)
        stats: List[EmbeddingBatchStats] = []
        for b in range(cs.num_batches):
            s = EmbeddingBatchStats()
            d = drams[b]
            s.dram_cycles = d.finish_cycle
            s.dram_row_hits = d.row_hits
            s.dram_row_misses = d.row_misses
            s.onchip_reads = int(cs.reads[b])
            s.onchip_writes = int(cs.miss_count[b]) + (cs.setup_writes if b == 0 else 0)
            s.offchip_reads = int(cs.miss_count[b])
            s.cache_hits = int(cs.hit_lines[b])
            s.cache_misses = int(cs.miss_count[b])
            s.onchip_cycles = s.onchip_reads * line / onchip_bw + hw.onchip.latency_cycles
            s.vector_cycles = _vector_compute_cycles(
                etrace.spec, etrace.concat.batch_sizes[b], hw
            )
            # on-chip service, off-chip service and pooling overlap in a
            # double-buffered stream; the slowest stage bounds the batch.
            s.cycles = max(s.onchip_cycles, s.dram_cycles, s.vector_cycles)
            if tlb is not None:
                # Page walks serialize with the off-chip path: a miss line
                # cannot issue to DRAM before its physical address exists.
                s.tlb_hits = int(tlb.hits[b])
                s.tlb_misses = int(tlb.misses[b])
                s.tlb_walks = int(tlb.walks[b])
                s.translation_cycles = float(tlb.cycles[b])
                s.cycles = max(
                    s.onchip_cycles,
                    s.dram_cycles + s.translation_cycles,
                    s.vector_cycles,
                )
            stats.append(s)
        return stats

    def _charge_translation(self, cs: ClassifiedStream):
        """Memoized TLB charge for this stream, or None without translation."""
        tcfg = self.hw.translation
        if tcfg is None:
            return None
        return charge_cache_lookup(
            cs.tlb_cache, cs.miss_lines, cs.miss_batch, cs.num_batches,
            self.hw.onchip.line_bytes, tcfg,
        )

    # -- deferred-DRAM pipeline ---------------------------------------------
    def classify_for_pending(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
    ) -> ClassifiedStream:
        """The placement-invariant half of ``prepare_embedding``.

        Classification never reads the NUMA axes (``channel_affinity`` /
        ``placement``) — those only remap miss-line addresses on the way to
        DRAM — so a sweep shares ONE classified stream across every placement
        variant of a config and fans out with ``pending_from`` per variant.
        """
        return self.classify_embedding(etrace, pinned_lines, allow_lane)

    def pending_from(
        self, etrace: EmbeddingTrace, cs: ClassifiedStream
    ) -> PendingEmbedding:
        """Apply THIS config's placement transform to an already classified
        stream and package the deferred DRAM dispatch. ``cs`` may come from a
        placement sibling (same config up to affinity/placement) — bit-exact
        with classifying under this config directly (test-enforced)."""
        return self._pending(etrace, cs)

    def prepare_embedding(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
    ) -> PendingEmbedding:
        """Classify all batches and package the deferred DRAM dispatch."""
        cs = self.classify_embedding(etrace, pinned_lines, allow_lane)
        return self._pending(etrace, cs)

    # -- NUMA placement (channel affinity + row homes) ----------------------
    def placement_map(self, etrace: EmbeddingTrace) -> Optional[PlacementMap]:
        """The row->(channel-group, rank) map for this config, or ``None``
        for the degenerate ``symmetric``/``interleave`` pair — the miss trace
        then reaches DRAM untransformed, byte for byte the historical path."""
        hw = self.hw
        if hw.channel_affinity == "symmetric" and hw.placement == "interleave":
            return None
        hot = etrace.hot_vec_ids if hw.placement == "hot_replicate" else None
        return PlacementMap.from_model(self.dram, hw, etrace.spec, hot_vecs=hot)

    def _place_misses(
        self,
        etrace: EmbeddingTrace,
        miss_lines: np.ndarray,
        miss_src: Optional[np.ndarray],
        place_cache: Optional[dict] = None,
    ) -> np.ndarray:
        pm = self.placement_map(etrace)
        if pm is None:
            return miss_lines
        return pm.place(miss_lines, miss_src, cache=place_cache)

    def _pending(self, etrace: EmbeddingTrace, cs: ClassifiedStream) -> PendingEmbedding:
        # Translation observes the VIRTUAL miss stream, before PlacementMap
        # relocates lines — the charge is placement-invariant and memoized
        # on the classified stream across translation-sibling configs.
        tlb = self._charge_translation(cs)
        req = DramRequest(
            lines=self._place_misses(
                etrace, cs.miss_lines, None, place_cache=cs.place_cache
            ),
            seg=cs.miss_batch,
            src=np.zeros(cs.miss_lines.size, dtype=np.int64),
            num_segments=cs.num_batches,
            num_sources=1,
            model=self.dram,
        )
        return PendingEmbedding(
            request=req,
            _finalize=lambda drams, finish: self._assemble_stats(
                etrace, cs, drams, tlb
            ),
        )

    # -- multi-batch embedding-op pipeline ----------------------------------
    def simulate_embedding(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
    ) -> List[EmbeddingBatchStats]:
        """Simulate one embedding op over all batches with persistent on-chip
        state; returns per-batch stats.

        ``allow_lane=False`` forces the line-granular path (used by parity
        tests; results are identical when the lane transform applies).
        """
        p = self.prepare_embedding(etrace, pinned_lines, allow_lane)
        return p.finalize(*dram_timing_single(p.request))


def classify_embedding_many(
    systems: Sequence[MemorySystem],
    etrace: EmbeddingTrace,
    allow_lane: bool = True,
) -> List[ClassifiedStream]:
    """Batched classification across configurations of ONE policy — the
    placement-invariant half of ``prepare_embedding_many``.

    All systems must share the same registered policy (and carry no policy
    mix); their classification runs through ``MemoryPolicy.run_many``, which
    fuses same-shape cache scans into single vmapped dispatches and shares
    stack-distance passes (the DSE sweep fast path). Per-system results are
    bit-exact with independent ``classify_embedding`` calls — tests enforce
    this end to end.
    """
    if not systems:
        return []
    policy = systems[0].policy
    if any(ms.policy is not policy for ms in systems):
        raise ValueError("classify_embedding_many requires one shared policy")
    if any(ms.hw.onchip.policy_mix for ms in systems):
        raise ValueError("policy-mix configs must use the unbatched path")
    preps = [ms._prepare_stream(etrace, None, allow_lane) for ms in systems]
    outs = policy.run_many([p.stream for p in preps], [p.ctx for p in preps])
    return [
        ms._account(etrace, prep, out, None)
        for ms, prep, out in zip(systems, preps, outs)
    ]


def prepare_embedding_many(
    systems: Sequence[MemorySystem],
    etrace: EmbeddingTrace,
    allow_lane: bool = True,
) -> List[PendingEmbedding]:
    """Batched classification across configurations of ONE policy, with DRAM
    timing deferred (``classify_embedding_many`` + per-system packaging)."""
    return [
        ms._pending(etrace, cs)
        for ms, cs in zip(
            systems, classify_embedding_many(systems, etrace, allow_lane)
        )
    ]


def simulate_embedding_many(
    systems: Sequence[MemorySystem],
    etrace: EmbeddingTrace,
    allow_lane: bool = True,
) -> List[List[EmbeddingBatchStats]]:
    """Batched ``simulate_embedding`` across configurations of ONE policy:
    ``prepare_embedding_many`` + one batched DRAM dispatch."""
    pending = prepare_embedding_many(systems, etrace, allow_lane)
    return [
        p.finalize(*out)
        for p, out in zip(pending, dram_timing_many([p.request for p in pending]))
    ]


# --------------------------------------------------------------------------
# MultiCoreMemorySystem (CoreCluster topology)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MultiCoreMemorySystem:
    """N-core memory pipeline over one shared DRAM.

    PRIVATE topology: the embedding trace is sharded deterministically across
    cores (``hw.lookup_sharding``); each core's shard runs the standard
    classify pipeline against that core's own on-chip memory (``hw.onchip``
    describes ONE core's memory). SHARED topology: one last-level on-chip
    memory observes the interleaved stream of every core, so classification
    equals the single-core path while vector compute still shards per core.

    Either way, all cores' miss bursts are merged in global trace order and
    timed against ONE shared DRAM with cross-core channel contention
    (``dram_timing_contended``) — per batch, DRAM state is fresh (double-
    buffered streaming) but cores contend within the batch.
    """

    hw: HardwareConfig
    core: MemorySystem

    @staticmethod
    def from_hardware(hw: HardwareConfig) -> "MultiCoreMemorySystem":
        return MultiCoreMemorySystem(hw=hw, core=MemorySystem.from_hardware(hw))

    @property
    def policy(self) -> MemoryPolicy:
        return self.core.policy

    @property
    def dram(self) -> DramModel:
        return self.core.dram

    def classify_for_pending(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
    ) -> Union[ClassifiedStream, "_ClusterClassified"]:
        """Classify every core's shard (or the shared stream) WITHOUT the
        placement transform or DRAM request — the placement-invariant half of
        ``prepare_embedding``, shareable across placement siblings (the
        cluster stats assembly reads only placement-invariant hardware
        fields). Returns a plain ``ClassifiedStream`` for the degenerate
        single-core cluster."""
        hw = self.hw
        n = hw.num_cores
        if n == 1 and hw.topology == Topology.PRIVATE:
            # Degenerate cluster == the single-core path, bit-exact.
            return self.core.classify_for_pending(etrace, pinned_lines, allow_lane)

        spec = etrace.spec
        concat = etrace.concat
        B = etrace.num_batches
        line = hw.onchip.line_bytes
        lpv = max(1, -(-spec.vector_bytes // line))
        core_of = shard_lookup_cores(concat, n, hw.lookup_sharding.value)
        lb = etrace.lookup_batch
        core_lookups = np.bincount(
            core_of.astype(np.int64) * B + lb, minlength=n * B
        ).reshape(n, B)
        total_lookups = np.maximum(core_lookups.sum(axis=0), 1)

        if hw.topology == Topology.SHARED:
            cs = self.core.classify_embedding(
                etrace, pinned_lines, allow_lane,
                lookup_index=np.arange(len(concat), dtype=np.int64),
            )
            miss_core = core_of[cs.miss_pos // lpv].astype(np.int64)
            merged = cs
            core_reads = core_lookups * lpv
            core_miss = np.bincount(
                miss_core * B + cs.miss_batch, minlength=n * B
            ).reshape(n, B)
        else:
            shards = shard_trace(concat, n, hw.lookup_sharding.value, core_of=core_of)
            core_reads = np.zeros((n, B), dtype=np.int64)
            core_miss = np.zeros((n, B), dtype=np.int64)
            hit_lines = np.zeros(B, dtype=np.int64)
            miss_count = np.zeros(B, dtype=np.int64)
            reads = np.zeros(B, dtype=np.int64)
            setup = 0
            m_lines, m_batch, m_pos, m_src = [], [], [], []
            for shard in shards:
                if len(shard) == 0:
                    continue
                et_c = EmbeddingTrace.from_concat(spec, shard.concat)
                c_cs = self.core.classify_embedding(
                    et_c, pinned_lines, allow_lane, lookup_index=shard.lookup_index
                )
                core_reads[shard.core_id] = c_cs.reads
                core_miss[shard.core_id] = c_cs.miss_count
                hit_lines += c_cs.hit_lines
                miss_count += c_cs.miss_count
                reads += c_cs.reads
                setup += c_cs.setup_writes
                m_lines.append(c_cs.miss_lines)
                m_batch.append(c_cs.miss_batch)
                m_pos.append(c_cs.miss_pos)
                m_src.append(
                    np.full(c_cs.miss_lines.size, shard.core_id, dtype=np.int64)
                )
            all_lines, all_batch, all_pos, miss_core = _merge_miss_streams(
                m_lines, m_batch, m_pos, m_src
            )
            merged = ClassifiedStream(
                num_batches=B,
                hit_lines=hit_lines,
                miss_count=miss_count,
                reads=reads,
                setup_writes=setup,
                miss_lines=all_lines,
                miss_batch=all_batch,
                miss_pos=all_pos,
            )

        def finalize(drams, core_finish, tlb=None) -> List[EmbeddingBatchStats]:
            # Counts/DRAM fields follow the single-core accounting contract
            # verbatim; only the cycle model (slowest core bounds the batch)
            # and the per-core detail are cluster-specific overrides below.
            # ``tlb`` is injected per-config by ``pending_from`` (translation
            # is a per-config axis; this closure is shared across siblings).
            stats = self.core._assemble_stats(etrace, merged, drams, tlb)
            onchip_bw = max(hw.onchip.read_bw_bytes_per_cycle, 1)
            lat = hw.onchip.latency_cycles
            for b, s in enumerate(stats):
                full_vector = s.vector_cycles
                per_core: List[CoreBatchStats] = []
                for c in range(n):
                    if hw.topology == Topology.SHARED:
                        # One LLC port streams every core's lines.
                        oc = int(merged.reads[b]) * line / onchip_bw + lat
                    else:
                        oc = int(core_reads[c, b]) * line / onchip_bw + lat
                    vc = full_vector * core_lookups[c, b] / total_lookups[b]
                    per_core.append(CoreBatchStats(
                        core_id=c,
                        lookups=int(core_lookups[c, b]),
                        onchip_reads=int(core_reads[c, b]),
                        cache_misses=int(core_miss[c, b]),
                        onchip_cycles=oc,
                        vector_cycles=vc,
                        dram_finish_cycles=float(core_finish[b, c]),
                    ))
                s.onchip_cycles = max(pc.onchip_cycles for pc in per_core)
                s.vector_cycles = max(pc.vector_cycles for pc in per_core)
                s.per_core = per_core
                s.cycles = max(s.onchip_cycles, s.dram_cycles, s.vector_cycles)
                if tlb is not None:
                    # Central MMU: walks serialize with the shared DRAM path.
                    s.cycles = max(
                        s.onchip_cycles,
                        s.dram_cycles + s.translation_cycles,
                        s.vector_cycles,
                    )
            return stats

        return _ClusterClassified(
            merged=merged,
            miss_src=np.asarray(miss_core, dtype=np.int64),
            finalize=finalize,
        )

    def pending_from(
        self,
        etrace: EmbeddingTrace,
        clas: Union[ClassifiedStream, "_ClusterClassified"],
    ) -> PendingEmbedding:
        """Apply THIS config's placement transform to an already classified
        cluster and package the deferred contended-DRAM dispatch (see
        ``MemorySystem.pending_from``)."""
        if isinstance(clas, ClassifiedStream):
            # Degenerate single-core cluster.
            return self.core.pending_from(etrace, clas)
        # The central MMU translates the merged VIRTUAL miss stream (global
        # interleaved order, pre-placement) — per-config, since siblings
        # sharing the classification can carry different TLBs; memoized on
        # the classification so equal TLB configs translate once.
        tcfg = self.hw.translation
        tlb = None if tcfg is None else charge_cache_lookup(
            clas.tlb_cache, clas.merged.miss_lines, clas.merged.miss_batch,
            etrace.num_batches, self.hw.onchip.line_bytes, tcfg,
        )
        return PendingEmbedding(
            request=DramRequest(
                # Placement routes each core's misses to its affine channel
                # group (per_core) or each table's home group (per_table);
                # the contended scan then only sees cross-core contention
                # where channel groups actually overlap.
                lines=self.core._place_misses(
                    etrace, clas.merged.miss_lines, clas.miss_src,
                    place_cache=clas.place_cache,
                ),
                seg=clas.merged.miss_batch,
                src=clas.miss_src,
                num_segments=etrace.num_batches,
                num_sources=self.hw.num_cores,
                model=self.dram,
            ),
            _finalize=lambda drams, finish: clas.finalize(drams, finish, tlb),
        )

    def prepare_embedding(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
    ) -> PendingEmbedding:
        """Classify every core's shard (or the shared stream) and package the
        deferred contended-DRAM dispatch; ``finalize`` assembles the cluster
        stats including the per-core detail."""
        return self.pending_from(
            etrace, self.classify_for_pending(etrace, pinned_lines, allow_lane)
        )

    def simulate_embedding(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
    ) -> List[EmbeddingBatchStats]:
        p = self.prepare_embedding(etrace, pinned_lines, allow_lane)
        return p.finalize(*dram_timing_single(p.request))


def memory_system_for(
    hw: HardwareConfig,
) -> Union[MemorySystem, MultiCoreMemorySystem]:
    """The memory pipeline for a hardware config: plain single-core
    ``MemorySystem`` for the degenerate cluster, ``MultiCoreMemorySystem``
    otherwise. Both expose the same ``simulate_embedding`` surface."""
    if hw.num_cores == 1 and hw.topology == Topology.PRIVATE:
        return MemorySystem.from_hardware(hw)
    return MultiCoreMemorySystem.from_hardware(hw)
