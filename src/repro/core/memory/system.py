"""Unified MemorySystem: the classify -> miss-trace -> DRAM-timing pipeline.

This is the layer the paper's Fig. 2 "Simulation" stage describes for
embedding operations, extracted behind one owner so every on-chip policy and
memory geometry goes through the same path:

  ConcatTrace (lookups, true per-batch boundaries)
      |  [lane transform, when exact]    vector-granular stream
      |  [otherwise]                     line-granular stream (translate)
      v
  MemoryPolicy.run  — pluggable registry (policies.py), shared accounting
      v
  miss line trace + per-batch attribution
      v
  dram_timing_segmented — ONE batched event scan for all batches
      v
  per-batch EmbeddingBatchStats (cycles, access counts, DRAM row stats)

Lane-decomposition transform (the paper stresses *fast and accurate*): when
the cache geometry satisfies ``num_sets % lines_per_vector == 0`` and vectors
are line-aligned, the line-level set-associative cache decomposes into
``lines_per_vector`` independent "lane" sub-caches that each observe the same
vector-granular stream. Simulating ONE lane at vector granularity and scaling
counts is then *bit-exact* vs line-level simulation (tests enforce this) and
cuts scan length by lines_per_vector (8x for DLRM's 512 B vectors / 64 B
lines). Here the transform is applied *transparently* to any policy that
declares ``supports_lane_transform`` — the policy classifies whatever stream
it is handed; hit/miss/read/write accounting is shared between both paths.

Per-batch DRAM timing semantics match the historical engine: each batch's
miss burst is timed against fresh DRAM state (double-buffered streaming, the
memory-bound regime), but all batches now run as one segmented scan instead
of a Python loop of independent JAX dispatches.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from ..hardware import HardwareConfig
from ..trace import AddressTrace, ConcatTrace, FullTrace, translate
from ..workload import EmbeddingOpSpec
from .cache import CacheGeometry
from .dram import DramModel, dram_timing_segmented
from .policies import (
    MemoryPolicy,
    PolicyContext,
    PolicyOutcome,
    get_policy,
)


# --------------------------------------------------------------------------
# Lane-decomposition transform
# --------------------------------------------------------------------------

def lane_geometry(hw: HardwareConfig, spec: EmbeddingOpSpec) -> Optional[CacheGeometry]:
    """Vector-granular lane geometry when the decomposition is exact."""
    line = hw.onchip.line_bytes
    if spec.vector_bytes % line != 0:
        return None
    lpv = spec.vector_bytes // line
    full_geom = CacheGeometry.from_capacity(hw.onchip.capacity_bytes, line, hw.onchip.ways)
    if lpv <= 1 or full_geom.num_sets % lpv != 0:
        return None
    return CacheGeometry(
        num_sets=full_geom.num_sets // lpv,
        ways=full_geom.ways,
        line_bytes=spec.vector_bytes,
    )


# --------------------------------------------------------------------------
# Per-batch stats (the MemorySystem accounting contract)
# --------------------------------------------------------------------------

@dataclass
class EmbeddingBatchStats:
    cycles: float = 0.0
    vector_cycles: float = 0.0
    dram_cycles: float = 0.0
    onchip_cycles: float = 0.0
    onchip_reads: int = 0
    onchip_writes: int = 0
    offchip_reads: int = 0
    cache_hits: int = 0          # line-granular
    cache_misses: int = 0
    dram_row_hits: int = 0
    dram_row_misses: int = 0


def _vector_compute_cycles(spec: EmbeddingOpSpec, batch_size: int, hw: HardwareConfig) -> float:
    """Stage-3 vector arithmetic (Fig. 1): pooling on the VPU."""
    flops = spec.reduction_flops(batch_size)
    return flops / max(hw.vector_unit.throughput, 1)


# --------------------------------------------------------------------------
# Shared trace bundle (reused across sweep configurations)
# --------------------------------------------------------------------------

class EmbeddingTrace:
    """One embedding op's concatenated multi-batch trace + cached streams.

    The expensive derived streams (vector-id stream, line-address trace) are
    independent of the on-chip policy/capacity/associativity, so a DSE sweep
    builds one ``EmbeddingTrace`` per op and shares it across every
    configuration instead of regenerating per ``simulate()`` call.
    """

    def __init__(self, spec: EmbeddingOpSpec, traces: Sequence[FullTrace]):
        self.spec = spec
        self.concat = ConcatTrace.from_traces(traces)
        self._vec_ids: Optional[np.ndarray] = None
        self._lookup_batch: Optional[np.ndarray] = None
        self._atraces: Dict[int, AddressTrace] = {}

    @property
    def num_batches(self) -> int:
        return self.concat.num_batches

    @property
    def lookup_batch(self) -> np.ndarray:
        if self._lookup_batch is None:
            self._lookup_batch = self.concat.lookup_batch
        return self._lookup_batch

    @property
    def vec_ids(self) -> np.ndarray:
        """Globally unique vector id per lookup (lane-transform stream)."""
        if self._vec_ids is None:
            self._vec_ids = (
                self.concat.table_ids.astype(np.int64) * self.spec.rows_per_table
                + self.concat.row_ids
            )
        return self._vec_ids

    def address_trace(self, line_bytes: int) -> AddressTrace:
        at = self._atraces.get(line_bytes)
        if at is None:
            at = translate(self.concat, self.spec, line_bytes)
            self._atraces[line_bytes] = at
        return at


# --------------------------------------------------------------------------
# MemorySystem
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MemorySystem:
    """Owns the whole on-chip + off-chip memory pipeline for one hardware
    configuration: policy classification, lane transform, miss-trace
    construction, and segmented DRAM timing with per-batch attribution."""

    hw: HardwareConfig
    policy: MemoryPolicy
    dram: DramModel

    @staticmethod
    def from_hardware(hw: HardwareConfig) -> "MemorySystem":
        return MemorySystem(
            hw=hw,
            policy=get_policy(hw.onchip.policy),
            dram=DramModel.from_hardware(hw),
        )

    # -- line-trace entry point (run_policy equivalent) ---------------------
    def classify(
        self, atrace: AddressTrace, pinned_lines: Optional[np.ndarray] = None
    ) -> PolicyOutcome:
        return self.policy.run(
            atrace.lines, PolicyContext.from_hardware(self.hw, pinned_lines)
        )

    # -- multi-batch embedding-op pipeline ----------------------------------
    def simulate_embedding(
        self,
        etrace: EmbeddingTrace,
        pinned_lines: Optional[np.ndarray] = None,
        allow_lane: bool = True,
    ) -> List[EmbeddingBatchStats]:
        """Simulate one embedding op over all batches with persistent on-chip
        state; returns per-batch stats.

        ``allow_lane=False`` forces the line-granular path (used by parity
        tests; results are identical when the lane transform applies).
        """
        spec = etrace.spec
        hw = self.hw
        line = hw.onchip.line_bytes
        lpv = max(1, -(-spec.vector_bytes // line))
        num_batches = etrace.num_batches
        lookup_batch = etrace.lookup_batch

        lane = lane_geometry(hw, spec) if allow_lane else None
        use_lane = lane is not None and self.policy.supports_lane_transform

        if use_lane:
            # Transparent transform: hand the policy the vector-granular
            # stream under the lane sub-cache geometry; every access stands
            # for ``lpv`` line accesses.
            stream = etrace.vec_ids
            ctx = PolicyContext(
                geometry=lane,
                capacity_units=hw.onchip.num_lines // lpv,
                pinned_lines=pinned_lines,
            )
            unit = lpv
            acc_batch = lookup_batch
        else:
            at = etrace.address_trace(line)
            stream = at.lines
            ctx = PolicyContext.from_hardware(hw, pinned_lines)
            unit = 1
            acc_batch = np.repeat(lookup_batch, at.lines_per_vector)

        out = self.policy.run(stream, ctx)
        hits = out.hits
        misses = ~hits

        # Shared accounting contract, per batch: reads = every consumed line,
        # writes = fills/stages (+ one-time setup on batch 0), offchip = miss
        # fetches. ``unit`` scales vector-granular counts back to lines.
        hit_lines = np.bincount(acc_batch[hits], minlength=num_batches) * unit
        miss_lines_ct = np.bincount(acc_batch[misses], minlength=num_batches) * unit
        onchip_reads = np.bincount(acc_batch, minlength=num_batches) * unit

        # Expand misses to line addresses for DRAM timing.
        if use_lane:
            miss_base = (
                etrace.concat.table_ids.astype(np.int64)[misses] * spec.table_bytes
                + etrace.concat.row_ids[misses] * spec.vector_bytes
            ) // line
            miss_lines = (miss_base[:, None] + np.arange(unit)[None, :]).reshape(-1)
            miss_batch = np.repeat(acc_batch[misses], unit)
        else:
            miss_lines = out.miss_lines
            miss_batch = acc_batch[misses]

        drams = dram_timing_segmented(miss_lines, miss_batch, num_batches, self.dram)

        onchip_bw = max(hw.onchip.read_bw_bytes_per_cycle, 1)
        stats: List[EmbeddingBatchStats] = []
        for b in range(num_batches):
            s = EmbeddingBatchStats()
            d = drams[b]
            s.dram_cycles = d.finish_cycle
            s.dram_row_hits = d.row_hits
            s.dram_row_misses = d.row_misses
            s.onchip_reads = int(onchip_reads[b])
            s.onchip_writes = int(miss_lines_ct[b]) + (out.setup_writes if b == 0 else 0)
            s.offchip_reads = int(miss_lines_ct[b])
            s.cache_hits = int(hit_lines[b])
            s.cache_misses = int(miss_lines_ct[b])
            s.onchip_cycles = s.onchip_reads * line / onchip_bw + hw.onchip.latency_cycles
            s.vector_cycles = _vector_compute_cycles(
                spec, etrace.concat.batch_sizes[b], hw
            )
            # on-chip service, off-chip service and pooling overlap in a
            # double-buffered stream; the slowest stage bounds the batch.
            s.cycles = max(s.onchip_cycles, s.dram_cycles, s.vector_cycles)
            stats.append(s)
        return stats
