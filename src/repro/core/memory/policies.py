"""On-chip memory management policies (paper Sec. III/IV) behind a registry.

Four configurations evaluated in the paper's case study (Fig. 4):
  * SPM      — scratchpad staging as on TPUv6e: *every* vector lookup fetches
               from off-chip regardless of hotness; on-chip memory is a
               double-buffered staging area.
  * LRU/SRRIP/FIFO — on-chip memory configured as a set-associative cache
               (MTIA LLC-mode-like); misses go off-chip.
  * PINNING  — "Profiling": track access frequency, pin the hottest vectors
               up to capacity; pinned hits stay on-chip, everything else is
               staged from off-chip like SPM.

Every policy is a ``MemoryPolicy`` subclass registered under its
``OnChipPolicy`` name. Policies only *classify* accesses (hit / miss); the
shared accounting contract lives in ``MemoryPolicy.run``:

  * each line access = 1 on-chip read (the consumer always reads on-chip);
  * each miss       = 1 off-chip read + 1 on-chip fill/stage write;
  * ``setup_writes`` = one-time fills at load time (e.g. pinned-set preload),
    attributed to the first batch by the MemorySystem.

This single contract reproduces the per-policy counts the paper reports
(Fig. 3c/4c). Adding a policy = subclass + ``@register_policy``; the
MemorySystem, sweep engine, and benchmarks pick it up automatically (see
docs/architecture.md).
"""
from __future__ import annotations

import abc
import dataclasses
from dataclasses import dataclass
from typing import ClassVar, Dict, List, Optional, Sequence, Tuple, Type, Union

import jax
import jax.numpy as jnp
import numpy as np

from ..hardware import HardwareConfig, OnChipPolicy
from ..profiling import stage
from ..trace import AddressTrace
from .cache import CacheGeometry, classify_streams


@dataclass
class PolicyOutcome:
    hits: np.ndarray              # bool (N,) on-chip hit per line access
    miss_lines: np.ndarray        # int64 (M,) off-chip line trace, trace order
    onchip_reads: int             # on-chip read accesses (line granular)
    onchip_writes: int            # on-chip write accesses (fills/stages)
    offchip_reads: int            # off-chip line fetches
    policy: OnChipPolicy
    setup_writes: int = 0         # one-time load-time fills (subset of writes)

    @property
    def onchip_accesses(self) -> int:
        return self.onchip_reads + self.onchip_writes

    @property
    def onchip_ratio(self) -> float:
        """On-chip share of all memory accesses (paper Fig. 4c metric)."""
        total = self.onchip_accesses + self.offchip_reads
        return self.onchip_accesses / max(total, 1)

    @property
    def hit_rate(self) -> float:
        return float(self.hits.mean()) if self.hits.size else 0.0


@dataclass(frozen=True)
class PolicyContext:
    """Everything a policy may need to classify an access stream.

    ``geometry`` describes the stream's granularity: the full line-granular
    cache geometry normally, or the lane sub-cache geometry when the
    MemorySystem applies the lane-decomposition transform (the policy itself
    is agnostic — that is what makes the transform transparent).
    """

    geometry: CacheGeometry
    capacity_units: int                       # capacity in stream-granularity units
    pinned_lines: Optional[np.ndarray] = None
    backend: str = "scan"                     # cache-engine backend (hw knob)

    @staticmethod
    def from_hardware(
        hw: HardwareConfig, pinned_lines: Optional[np.ndarray] = None
    ) -> "PolicyContext":
        geom = CacheGeometry.from_capacity(
            hw.onchip.capacity_bytes, hw.onchip.line_bytes, hw.onchip.ways
        )
        return PolicyContext(
            geometry=geom,
            capacity_units=hw.onchip.num_lines,
            pinned_lines=pinned_lines,
            backend=hw.cache_backend,
        )

    def scaled(self, fraction: float) -> "PolicyContext":
        """Context for a capacity partition (per-table policy mixes).

        The on-chip memory is statically partitioned set-wise: a policy group
        owning ``fraction`` of the tables gets ``fraction`` of the sets (and
        capacity units), associativity unchanged. ``fraction=1`` is exact
        identity, so a degenerate one-group mix classifies bit-exactly like
        the unmixed path.
        """
        if fraction >= 1.0:
            return self
        g = self.geometry
        return dataclasses.replace(
            self,
            geometry=CacheGeometry(
                num_sets=max(1, int(g.num_sets * fraction)),
                ways=g.ways,
                line_bytes=g.line_bytes,
            ),
            capacity_units=max(1, int(self.capacity_units * fraction)),
        )


class MemoryPolicy(abc.ABC):
    """A pluggable on-chip memory management policy."""

    name: ClassVar[str]
    enum: ClassVar[OnChipPolicy]
    uses_cache_engine: ClassVar[bool] = False
    # Swept on-chip parameters classification actually depends on. The DSE
    # sweep engine memoizes embedding stats across grid points that agree on
    # these values (e.g. SPM is invariant to both capacity and ways, PINNING
    # only reads capacity), so declaring a narrower set makes sweeps cheaper
    # — never different.
    sensitive_params: ClassVar[Tuple[str, ...]] = ("capacity_bytes", "ways")
    # Classification saturates once capacity covers the trace's whole line
    # footprint: every capacity at or above it is provably identical (e.g.
    # PINNING pins ALL unique lines — all hits, setup writes equal the
    # footprint). The sweep canonicalizes such capacities onto one memo key.
    capacity_saturates: ClassVar[bool] = False
    # Safe to classify at vector granularity through the lane decomposition
    # (bit-exact only when classification is independent of line/vector
    # granularity tie-breaking — true for stateless staging and for
    # set-associative caches with an exact lane split; NOT for pinning,
    # whose frequency top-K can split a vector at the capacity boundary).
    supports_lane_transform: ClassVar[bool] = False

    def prepare(self, lines: np.ndarray, ctx: PolicyContext) -> PolicyContext:
        """Resolve any trace-derived state (e.g. the profiled pinned set)."""
        return ctx

    @abc.abstractmethod
    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        """Return a bool (N,) array: on-chip hit per access."""

    def setup_writes(self, ctx: PolicyContext) -> int:
        """One-time on-chip fills at load time (before the first batch)."""
        return 0

    def classify_many(
        self, streams: Sequence[np.ndarray], ctxs: Sequence[PolicyContext]
    ) -> List[np.ndarray]:
        """Classify several independent (stream, ctx) pairs.

        Default is a plain loop; policies backed by the JAX cache engine
        override this to fuse same-shape scans into one vmapped dispatch
        (the DSE sweep's batched-classification fast path). MUST be
        bit-exact with per-pair ``classify`` — tests enforce it end to end.
        """
        return [self.classify(s, c) for s, c in zip(streams, ctxs)]

    def classify_jnp(self, lines: jax.Array, ctx: PolicyContext) -> jax.Array:
        """Device-resident ``classify``: takes/returns JAX arrays.

        Policies with a native jnp port (SPM, PINNING) override this; the
        numpy ``classify`` stays the golden reference (equality is
        test-enforced). The default round-trips through the host.
        """
        return jnp.asarray(self.classify(np.asarray(lines), ctx))

    def _outcome(
        self, lines: np.ndarray, ctx: PolicyContext, hits: np.ndarray
    ) -> PolicyOutcome:
        """The shared accounting contract applied to a classification."""
        misses = int((~hits).sum())
        setup = self.setup_writes(ctx)
        return PolicyOutcome(
            hits=hits,
            miss_lines=lines[~hits],
            onchip_reads=int(lines.size),
            onchip_writes=misses + setup,
            offchip_reads=misses,
            policy=self.enum,
            setup_writes=setup,
        )

    def run(self, lines: np.ndarray, ctx: PolicyContext) -> PolicyOutcome:
        """Classify + apply the shared accounting contract."""
        with stage("classify"):
            lines = np.asarray(lines, dtype=np.int64).reshape(-1)
            ctx = self.prepare(lines, ctx)
            return self._outcome(lines, ctx, self.classify(lines, ctx))

    def run_many(
        self, streams: Sequence[np.ndarray], ctxs: Sequence[PolicyContext]
    ) -> List[PolicyOutcome]:
        """Batched ``run``: same contract, one ``classify_many`` dispatch."""
        with stage("classify"):
            streams = [np.asarray(s, dtype=np.int64).reshape(-1) for s in streams]
            ctxs = [self.prepare(s, c) for s, c in zip(streams, ctxs)]
            hits_list = self.classify_many(streams, ctxs)
            return [
                self._outcome(s, c, h)
                for s, c, h in zip(streams, ctxs, hits_list)
            ]


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, MemoryPolicy] = {}


def register_policy(cls: Type[MemoryPolicy]) -> Type[MemoryPolicy]:
    """Class decorator: register a MemoryPolicy under ``cls.name``."""
    inst = cls()
    _REGISTRY[inst.name] = inst
    return cls


def get_policy(name) -> MemoryPolicy:
    key = name.value if isinstance(name, OnChipPolicy) else str(name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(
            f"unknown policy {key!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# Built-in policies
# --------------------------------------------------------------------------

@register_policy
class SpmPolicy(MemoryPolicy):
    """TPUv6e baseline: fetch every vector from off-chip regardless of hotness.

    Each access = 1 off-chip read + 1 staging write + 1 on-chip read (contract
    above) — no on-chip reuse, so classification is all-miss and granularity
    independent (lane transform is trivially exact).
    """

    name = "spm"
    enum = OnChipPolicy.SPM
    supports_lane_transform = True
    sensitive_params = ()

    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        return np.zeros(lines.size, dtype=bool)

    def classify_jnp(self, lines: jax.Array, ctx: PolicyContext) -> jax.Array:
        """Device-resident port of ``classify`` (tests pin equality)."""
        return jnp.zeros(lines.shape[0], dtype=bool)


class _CacheModePolicy(MemoryPolicy):
    """Set-associative cache mode (MTIA LLC-like); replacement = ``name``.

    Classification runs on the cache engine selected by ``ctx.backend``
    (lax.scan or the Pallas kernel) through the hits-only device surface
    ``cache.classify_streams`` — the scan state and per-access results stay
    on device until the one bulk extraction per shape bucket.
    """

    uses_cache_engine = True
    supports_lane_transform = True

    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        return classify_streams(
            [lines], [ctx.geometry], policy=self.name, backend=ctx.backend
        )[0]

    def classify_many(
        self, streams: Sequence[np.ndarray], ctxs: Sequence[PolicyContext]
    ) -> List[np.ndarray]:
        out: List[Optional[np.ndarray]] = [None] * len(ctxs)
        by_backend: Dict[str, List[int]] = {}
        for i, c in enumerate(ctxs):
            by_backend.setdefault(c.backend, []).append(i)
        for backend, idxs in by_backend.items():
            hits = classify_streams(
                [streams[i] for i in idxs],
                [ctxs[i].geometry for i in idxs],
                policy=self.name,
                backend=backend,
            )
            for i, h in zip(idxs, hits):
                out[i] = h
        return out  # type: ignore[return-value]


@register_policy
class LruPolicy(_CacheModePolicy):
    name = "lru"
    enum = OnChipPolicy.LRU


@register_policy
class SrripPolicy(_CacheModePolicy):
    name = "srrip"
    enum = OnChipPolicy.SRRIP


@register_policy
class FifoPolicy(_CacheModePolicy):
    name = "fifo"
    enum = OnChipPolicy.FIFO


def profile_hot_lines(lines: np.ndarray, capacity_lines: int) -> np.ndarray:
    """Pick the most frequently accessed lines, up to on-chip capacity.

    The paper's Profiling policy "tracks vector access frequency and pins the
    most frequently accessed vectors in on-chip memory, up to its capacity".
    """
    uniq, counts = np.unique(lines, return_counts=True)
    order = np.argsort(-counts, kind="stable")
    return np.sort(uniq[order[:capacity_lines]])


@register_policy
class PinningPolicy(MemoryPolicy):
    """Profiling: pin the hottest lines up to capacity; the rest stage as SPM.

    Pinned fill happens once at load time (``setup_writes``). Lane transform
    is disabled: a line-granular frequency top-K can split a vector at the
    capacity boundary, so vector-granular classification would not be
    bit-exact.
    """

    name = "pinning"
    enum = OnChipPolicy.PINNING
    sensitive_params = ("capacity_bytes",)
    # profile_hot_lines(lines, cap) with cap >= the unique-line footprint
    # pins every line regardless of cap — classification is capacity-
    # invariant from the footprint up (collapse-is-bitwise test-enforced).
    capacity_saturates = True

    def prepare(self, lines: np.ndarray, ctx: PolicyContext) -> PolicyContext:
        if ctx.pinned_lines is None:
            ctx = dataclasses.replace(
                ctx, pinned_lines=profile_hot_lines(lines, ctx.capacity_units)
            )
        return dataclasses.replace(
            ctx, pinned_lines=np.sort(np.asarray(ctx.pinned_lines))
        )

    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        pinned = ctx.pinned_lines
        if pinned is None or not len(pinned):
            return np.zeros(lines.size, dtype=bool)
        idx = np.searchsorted(pinned, lines)
        idx = np.clip(idx, 0, len(pinned) - 1)
        return pinned[idx] == lines

    def classify_jnp(self, lines: jax.Array, ctx: PolicyContext) -> jax.Array:
        """Device-resident port of ``classify`` (tests pin equality).

        Same sorted-membership test as the numpy golden, expressed with
        ``jnp.searchsorted`` so a device-resident caller (TPU pipeline) can
        keep the lookup stream on device.
        """
        pinned = ctx.pinned_lines
        if pinned is None or not len(pinned):
            return jnp.zeros(lines.shape[0], dtype=bool)
        pinned_d = jnp.asarray(np.asarray(pinned))
        idx = jnp.searchsorted(pinned_d, lines)
        idx = jnp.clip(idx, 0, len(pinned) - 1)
        return pinned_d[idx] == lines

    def setup_writes(self, ctx: PolicyContext) -> int:
        return 0 if ctx.pinned_lines is None else int(len(ctx.pinned_lines))


# --------------------------------------------------------------------------
# Per-table policy mixes
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class PolicyGroup:
    """One partition of a per-table policy mix."""

    policy: MemoryPolicy
    table_ids: Tuple[int, ...]       # tables classified by this policy
    fraction: float                  # share of tables -> share of capacity


def resolve_policy_mix(
    mix: Optional[Tuple[Tuple[int, str], ...]],
    default_policy: Union[str, OnChipPolicy],
    num_tables: int,
) -> List[PolicyGroup]:
    """Expand ``hw.onchip.policy_mix`` into policy groups over all tables.

    Tables not named in the mix fall back to ``default_policy``. Capacity is
    statically partitioned set-wise, proportional to each group's table count
    (``PolicyContext.scaled``); a single-group result keeps fraction 1.0 and
    is bit-exact with the unmixed path.
    """
    assign: Dict[int, str] = {}
    default_name = (
        default_policy.value
        if isinstance(default_policy, OnChipPolicy)
        else str(default_policy)
    )
    for t, p in mix or ():
        if not 0 <= t < num_tables:
            raise ValueError(
                f"policy mix table id {t} out of range [0, {num_tables})"
            )
        if int(t) in assign:
            raise ValueError(f"duplicate table id {t} in policy mix")
        assign[int(t)] = p
    by_policy: Dict[str, List[int]] = {}
    for t in range(num_tables):
        by_policy.setdefault(assign.get(t, default_name), []).append(t)
    return [
        PolicyGroup(
            policy=get_policy(name),
            table_ids=tuple(tables),
            fraction=len(tables) / max(num_tables, 1),
        )
        for name, tables in sorted(by_policy.items())
    ]


# --------------------------------------------------------------------------
# Back-compat functional entry point
# --------------------------------------------------------------------------

def run_policy(
    atrace: AddressTrace,
    hw: HardwareConfig,
    pinned_lines: np.ndarray | None = None,
) -> PolicyOutcome:
    """Classify each line access of ``atrace`` under ``hw``'s policy."""
    policy = get_policy(hw.onchip.policy)
    return policy.run(atrace.lines, PolicyContext.from_hardware(hw, pinned_lines))
