"""On-chip memory management policies (paper Sec. III/IV).

Four configurations evaluated in the paper's case study (Fig. 4):
  * SPM      — scratchpad staging as on TPUv6e: *every* vector lookup fetches
               from off-chip regardless of hotness; on-chip memory is a
               double-buffered staging area.
  * LRU/SRRIP/FIFO — on-chip memory configured as a set-associative cache
               (MTIA LLC-mode-like); misses go off-chip.
  * PINNING  — "Profiling": track access frequency, pin the hottest vectors
               up to capacity; pinned hits stay on-chip, everything else is
               staged from off-chip like SPM.

``run_policy`` classifies each line access of an address trace as on-chip hit
or off-chip miss and returns the access counts the paper reports (Fig. 3c/4c)
plus the miss trace for DRAM timing.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from ..hardware import HardwareConfig, OnChipPolicy
from ..trace import AddressTrace
from .cache import CacheGeometry, simulate_cache


@dataclass
class PolicyOutcome:
    hits: np.ndarray              # bool (N,) on-chip hit per line access
    miss_lines: np.ndarray        # int64 (M,) off-chip line trace, trace order
    onchip_reads: int             # on-chip read accesses (line granular)
    onchip_writes: int            # on-chip write accesses (fills/stages)
    offchip_reads: int            # off-chip line fetches
    policy: OnChipPolicy

    @property
    def onchip_accesses(self) -> int:
        return self.onchip_reads + self.onchip_writes

    @property
    def onchip_ratio(self) -> float:
        """On-chip share of all memory accesses (paper Fig. 4c metric)."""
        total = self.onchip_accesses + self.offchip_reads
        return self.onchip_accesses / max(total, 1)

    @property
    def hit_rate(self) -> float:
        return float(self.hits.mean()) if self.hits.size else 0.0


def _spm(atrace: AddressTrace) -> PolicyOutcome:
    """TPUv6e baseline: fetch every vector from off-chip regardless of hotness.

    Each line access = 1 off-chip read + 1 on-chip write (stage into the
    double buffer) + 1 on-chip read (consumed by the vector unit).
    """
    n = len(atrace)
    return PolicyOutcome(
        hits=np.zeros(n, dtype=bool),
        miss_lines=atrace.lines.copy(),
        onchip_reads=n,
        onchip_writes=n,
        offchip_reads=n,
        policy=OnChipPolicy.SPM,
    )


def _cache(atrace: AddressTrace, hw: HardwareConfig, policy: str) -> PolicyOutcome:
    geom = CacheGeometry.from_capacity(
        hw.onchip.capacity_bytes, hw.onchip.line_bytes, hw.onchip.ways
    )
    res = simulate_cache(atrace.lines, geom, policy=policy)
    miss_lines = atrace.lines[~res.hits]
    return PolicyOutcome(
        hits=res.hits,
        miss_lines=miss_lines,
        onchip_reads=len(atrace),           # every consumed line is read on-chip
        onchip_writes=res.num_misses,       # fills on miss
        offchip_reads=res.num_misses,
        policy=OnChipPolicy(policy),
    )


def profile_hot_lines(lines: np.ndarray, capacity_lines: int) -> np.ndarray:
    """Pick the most frequently accessed lines, up to on-chip capacity.

    The paper's Profiling policy "tracks vector access frequency and pins the
    most frequently accessed vectors in on-chip memory, up to its capacity".
    """
    uniq, counts = np.unique(lines, return_counts=True)
    order = np.argsort(-counts, kind="stable")
    return np.sort(uniq[order[:capacity_lines]])


def _pinning(
    atrace: AddressTrace,
    hw: HardwareConfig,
    pinned_lines: np.ndarray | None,
    pin_fraction: float = 1.0,
) -> PolicyOutcome:
    cap_lines = int(hw.onchip.num_lines * pin_fraction)
    if pinned_lines is None:
        pinned_lines = profile_hot_lines(atrace.lines, cap_lines)
    pinned_lines = np.sort(np.asarray(pinned_lines))
    idx = np.searchsorted(pinned_lines, atrace.lines)
    idx = np.clip(idx, 0, max(len(pinned_lines) - 1, 0))
    hits = (
        pinned_lines[idx] == atrace.lines
        if len(pinned_lines)
        else np.zeros(len(atrace), dtype=bool)
    )
    misses = int((~hits).sum())
    return PolicyOutcome(
        hits=hits,
        miss_lines=atrace.lines[~hits],
        onchip_reads=len(atrace),
        # pinned fill happens once at load time: count one write per pinned
        # line + per-miss staging writes (SPM path for cold vectors)
        onchip_writes=misses + len(pinned_lines),
        offchip_reads=misses,
        policy=OnChipPolicy.PINNING,
    )


def run_policy(
    atrace: AddressTrace,
    hw: HardwareConfig,
    pinned_lines: np.ndarray | None = None,
) -> PolicyOutcome:
    policy = hw.onchip.policy
    if policy == OnChipPolicy.SPM:
        return _spm(atrace)
    if policy in (OnChipPolicy.LRU, OnChipPolicy.SRRIP, OnChipPolicy.FIFO):
        return _cache(atrace, hw, policy.value)
    if policy == OnChipPolicy.PINNING:
        return _pinning(atrace, hw, pinned_lines)
    raise ValueError(f"unknown policy {policy}")
