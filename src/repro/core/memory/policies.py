"""On-chip memory management policies (paper Sec. III/IV) behind a registry.

Four configurations evaluated in the paper's case study (Fig. 4):
  * SPM      — scratchpad staging as on TPUv6e: *every* vector lookup fetches
               from off-chip regardless of hotness; on-chip memory is a
               double-buffered staging area.
  * LRU/SRRIP/FIFO — on-chip memory configured as a set-associative cache
               (MTIA LLC-mode-like); misses go off-chip.
  * PINNING  — "Profiling": track access frequency, pin the hottest vectors
               up to capacity; pinned hits stay on-chip, everything else is
               staged from off-chip like SPM.

Every policy is a ``MemoryPolicy`` subclass registered under its
``OnChipPolicy`` name. Policies only *classify* accesses (hit / miss); the
shared accounting contract lives in ``MemoryPolicy.run``:

  * each line access = 1 on-chip read (the consumer always reads on-chip);
  * each miss       = 1 off-chip read + 1 on-chip fill/stage write;
  * ``setup_writes`` = one-time fills at load time (e.g. pinned-set preload),
    attributed to the first batch by the MemorySystem.

This single contract reproduces the per-policy counts the paper reports
(Fig. 3c/4c). Adding a policy = subclass + ``@register_policy``; the
MemorySystem, sweep engine, and benchmarks pick it up automatically (see
docs/architecture.md).
"""
from __future__ import annotations

import abc
import dataclasses
from dataclasses import dataclass
from typing import ClassVar, Dict, Optional, Tuple, Type

import numpy as np

from ..hardware import HardwareConfig, OnChipPolicy
from ..trace import AddressTrace
from .cache import CacheGeometry, simulate_cache


@dataclass
class PolicyOutcome:
    hits: np.ndarray              # bool (N,) on-chip hit per line access
    miss_lines: np.ndarray        # int64 (M,) off-chip line trace, trace order
    onchip_reads: int             # on-chip read accesses (line granular)
    onchip_writes: int            # on-chip write accesses (fills/stages)
    offchip_reads: int            # off-chip line fetches
    policy: OnChipPolicy
    setup_writes: int = 0         # one-time load-time fills (subset of writes)

    @property
    def onchip_accesses(self) -> int:
        return self.onchip_reads + self.onchip_writes

    @property
    def onchip_ratio(self) -> float:
        """On-chip share of all memory accesses (paper Fig. 4c metric)."""
        total = self.onchip_accesses + self.offchip_reads
        return self.onchip_accesses / max(total, 1)

    @property
    def hit_rate(self) -> float:
        return float(self.hits.mean()) if self.hits.size else 0.0


@dataclass(frozen=True)
class PolicyContext:
    """Everything a policy may need to classify an access stream.

    ``geometry`` describes the stream's granularity: the full line-granular
    cache geometry normally, or the lane sub-cache geometry when the
    MemorySystem applies the lane-decomposition transform (the policy itself
    is agnostic — that is what makes the transform transparent).
    """

    geometry: CacheGeometry
    capacity_units: int                       # capacity in stream-granularity units
    pinned_lines: Optional[np.ndarray] = None

    @staticmethod
    def from_hardware(
        hw: HardwareConfig, pinned_lines: Optional[np.ndarray] = None
    ) -> "PolicyContext":
        geom = CacheGeometry.from_capacity(
            hw.onchip.capacity_bytes, hw.onchip.line_bytes, hw.onchip.ways
        )
        return PolicyContext(
            geometry=geom,
            capacity_units=hw.onchip.num_lines,
            pinned_lines=pinned_lines,
        )


class MemoryPolicy(abc.ABC):
    """A pluggable on-chip memory management policy."""

    name: ClassVar[str]
    enum: ClassVar[OnChipPolicy]
    uses_cache_engine: ClassVar[bool] = False
    # Swept on-chip parameters classification actually depends on. The DSE
    # sweep engine memoizes embedding stats across grid points that agree on
    # these values (e.g. SPM is invariant to both capacity and ways, PINNING
    # only reads capacity), so declaring a narrower set makes sweeps cheaper
    # — never different.
    sensitive_params: ClassVar[Tuple[str, ...]] = ("capacity_bytes", "ways")
    # Safe to classify at vector granularity through the lane decomposition
    # (bit-exact only when classification is independent of line/vector
    # granularity tie-breaking — true for stateless staging and for
    # set-associative caches with an exact lane split; NOT for pinning,
    # whose frequency top-K can split a vector at the capacity boundary).
    supports_lane_transform: ClassVar[bool] = False

    def prepare(self, lines: np.ndarray, ctx: PolicyContext) -> PolicyContext:
        """Resolve any trace-derived state (e.g. the profiled pinned set)."""
        return ctx

    @abc.abstractmethod
    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        """Return a bool (N,) array: on-chip hit per access."""

    def setup_writes(self, ctx: PolicyContext) -> int:
        """One-time on-chip fills at load time (before the first batch)."""
        return 0

    def run(self, lines: np.ndarray, ctx: PolicyContext) -> PolicyOutcome:
        """Classify + apply the shared accounting contract."""
        lines = np.asarray(lines, dtype=np.int64).reshape(-1)
        ctx = self.prepare(lines, ctx)
        hits = self.classify(lines, ctx)
        misses = int((~hits).sum())
        setup = self.setup_writes(ctx)
        return PolicyOutcome(
            hits=hits,
            miss_lines=lines[~hits],
            onchip_reads=int(lines.size),
            onchip_writes=misses + setup,
            offchip_reads=misses,
            policy=self.enum,
            setup_writes=setup,
        )


# --------------------------------------------------------------------------
# Registry
# --------------------------------------------------------------------------

_REGISTRY: Dict[str, MemoryPolicy] = {}


def register_policy(cls: Type[MemoryPolicy]) -> Type[MemoryPolicy]:
    """Class decorator: register a MemoryPolicy under ``cls.name``."""
    inst = cls()
    _REGISTRY[inst.name] = inst
    return cls


def get_policy(name) -> MemoryPolicy:
    key = name.value if isinstance(name, OnChipPolicy) else str(name)
    try:
        return _REGISTRY[key]
    except KeyError:
        raise ValueError(
            f"unknown policy {key!r}; registered: {sorted(_REGISTRY)}"
        ) from None


def available_policies() -> Tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


# --------------------------------------------------------------------------
# Built-in policies
# --------------------------------------------------------------------------

@register_policy
class SpmPolicy(MemoryPolicy):
    """TPUv6e baseline: fetch every vector from off-chip regardless of hotness.

    Each access = 1 off-chip read + 1 staging write + 1 on-chip read (contract
    above) — no on-chip reuse, so classification is all-miss and granularity
    independent (lane transform is trivially exact).
    """

    name = "spm"
    enum = OnChipPolicy.SPM
    supports_lane_transform = True
    sensitive_params = ()

    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        return np.zeros(lines.size, dtype=bool)


class _CacheModePolicy(MemoryPolicy):
    """Set-associative cache mode (MTIA LLC-like); replacement = ``name``."""

    uses_cache_engine = True
    supports_lane_transform = True

    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        return simulate_cache(lines, ctx.geometry, policy=self.name).hits


@register_policy
class LruPolicy(_CacheModePolicy):
    name = "lru"
    enum = OnChipPolicy.LRU


@register_policy
class SrripPolicy(_CacheModePolicy):
    name = "srrip"
    enum = OnChipPolicy.SRRIP


@register_policy
class FifoPolicy(_CacheModePolicy):
    name = "fifo"
    enum = OnChipPolicy.FIFO


def profile_hot_lines(lines: np.ndarray, capacity_lines: int) -> np.ndarray:
    """Pick the most frequently accessed lines, up to on-chip capacity.

    The paper's Profiling policy "tracks vector access frequency and pins the
    most frequently accessed vectors in on-chip memory, up to its capacity".
    """
    uniq, counts = np.unique(lines, return_counts=True)
    order = np.argsort(-counts, kind="stable")
    return np.sort(uniq[order[:capacity_lines]])


@register_policy
class PinningPolicy(MemoryPolicy):
    """Profiling: pin the hottest lines up to capacity; the rest stage as SPM.

    Pinned fill happens once at load time (``setup_writes``). Lane transform
    is disabled: a line-granular frequency top-K can split a vector at the
    capacity boundary, so vector-granular classification would not be
    bit-exact.
    """

    name = "pinning"
    enum = OnChipPolicy.PINNING
    sensitive_params = ("capacity_bytes",)

    def prepare(self, lines: np.ndarray, ctx: PolicyContext) -> PolicyContext:
        if ctx.pinned_lines is None:
            ctx = dataclasses.replace(
                ctx, pinned_lines=profile_hot_lines(lines, ctx.capacity_units)
            )
        return dataclasses.replace(
            ctx, pinned_lines=np.sort(np.asarray(ctx.pinned_lines))
        )

    def classify(self, lines: np.ndarray, ctx: PolicyContext) -> np.ndarray:
        pinned = ctx.pinned_lines
        if pinned is None or not len(pinned):
            return np.zeros(lines.size, dtype=bool)
        idx = np.searchsorted(pinned, lines)
        idx = np.clip(idx, 0, len(pinned) - 1)
        return pinned[idx] == lines

    def setup_writes(self, ctx: PolicyContext) -> int:
        return 0 if ctx.pinned_lines is None else int(len(ctx.pinned_lines))


# --------------------------------------------------------------------------
# Back-compat functional entry point
# --------------------------------------------------------------------------

def run_policy(
    atrace: AddressTrace,
    hw: HardwareConfig,
    pinned_lines: np.ndarray | None = None,
) -> PolicyOutcome:
    """Classify each line access of ``atrace`` under ``hw``'s policy."""
    policy = get_policy(hw.onchip.policy)
    return policy.run(atrace.lines, PolicyContext.from_hardware(hw, pinned_lines))
