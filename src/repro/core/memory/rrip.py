"""Analytic SRRIP/FIFO classification engines (compressed per-set state,
no full-trace sequential scan).

The Mattson stack-distance engine (``stack.py``) classifies LRU for every
associativity from one shared pass per (stream, num_sets), but SRRIP and
FIFO are not stack algorithms: their hit sets are not nested in ``ways``,
so no single distance number classifies all associativities. Until this
module existed they fell back to the sequential ``lax.scan`` engine, which
scans the whole trace once per config and dominated the sweep's
``cache_scan`` stage.

This module retires that fallback. Sets are independent under both
policies, so instead of one O(n)-step scan over the interleaved trace we
run one *short* scan per set, batched across every set of every config in
the call:

* **shared presort** per (stream, num_sets): one stable sort into
  (set, time) order, run-compression of consecutive same-line accesses
  within a set (guaranteed hits: FIFO keeps only the first access of a
  run — FIFO hits never touch state; SRRIP keeps the first two — position
  1 refreshes the key, positions >= 2 are idempotent), and dense per-set
  segment ids. Every ways-variant of the same (stream, num_sets) reuses
  the pass, mirroring ``classify_lru_stack_many``; ``analytic_pass_count``
  exposes the counter so tests can assert sharing.
* **vectorized flat packing**: per-set rows from *all* configs of the call
  are bucketed by (ways, pow2 row length) and scattered into one flat
  buffer with a single vectorized pass per config — no per-row host loop.
  Each bucket dispatches one jitted batched ``lax.scan`` whose step costs
  O(rows x ways); total device work is ~(kept accesses) x ways instead of
  (trace length) x ways per config, and rows from different configs share
  dispatches.
* **compressed per-set state**:
  - FIFO: a ring buffer of ``ways`` tags plus a head pointer. Fills land
    at the head in arrival order, so the head is always the oldest fill —
    exactly ChampSim's min-fill-timestamp victim (invalid ways fill in
    index order during warmup).
  - SRRIP: ``ways`` (tag, key) pairs plus a scalar age ``A`` with
    ``rrpv_w = A - key_w``. Hit: ``key = A``. Miss with an invalid way:
    fill ``key = A - 2`` (rrpv 2). Warm miss: ``m = min(keys)``, evict the
    *first* argmin way (ChampSim's first-rrpv-3-after-aging victim), set
    ``A = m + 3`` (the persistent aging increment) and fill ``key = m +
    1``. ``A`` grows at most 3 per miss, so int32 state is exact for any
    trace that passes the int32 line guard.

Evictions for both policies are ``sum_s max(0, misses_s - ways)``: ways
fill once and never go invalid again, so every warm miss evicts. Both
engines are bit-exact against the ChampSim-semantics golden model
(``golden.py``) and the sequential scan engine (``cache.py``); the
differential suite locks that per PR.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..profiling import is_active as _profiling_active, stage

ITYPE = jnp.int32
_MIN_ROW_BUCKET = 8   # pow-2 floor for compressed per-set row length
_MIN_ROWS = 8         # pow-2 floor for rows per device dispatch
_SCAN_UNROLL = 8
_PAD_TAG = -2         # never matches a real tag (>=0) nor invalid (-1)

_POW2 = 1 << np.arange(31, dtype=np.int64)

_passes = 0


def analytic_pass_count() -> int:
    """Total shared presort passes computed (monotone; tests read deltas)."""
    return _passes


def _check_int32(lines: np.ndarray) -> np.ndarray:
    lines = np.ascontiguousarray(lines).astype(np.int64, copy=False)
    if lines.size and (lines.max() >= 2**31 or lines.min() < 0):
        raise ValueError("line numbers exceed int32 range; rebase the trace")
    return lines


def _pow2_at_least(n: int, floor: int) -> int:
    return max(floor, 1 << (max(1, int(n)) - 1).bit_length())


def _pow2_bucket(lens: np.ndarray, floor: int) -> np.ndarray:
    """Vectorized pow-2 round-up with a floor (exact, no float log)."""
    return _POW2[np.searchsorted(_POW2, np.maximum(lens, floor))]


class _Presort:
    """Shared per-(stream, num_sets, depth) compression of a stream into
    dense per-set segments of kept accesses."""

    __slots__ = ("kept_pos", "kept_tag", "sg", "ps", "seg_len", "n")

    def __init__(self, lines: np.ndarray, num_sets: int, depth: int):
        n = lines.size
        self.n = n
        if n == 0:
            z = np.zeros(0, np.int64)
            self.kept_pos, self.sg, self.ps = z, z, z
            self.kept_tag = z.astype(np.int32)
            self.seg_len = z
            return
        set_idx = lines % num_sets
        ord_set = np.argsort(set_idx, kind="stable")
        ss = set_idx[ord_set]
        lso = lines[ord_set]
        new_set = np.empty(n, bool)
        new_set[0] = True
        np.not_equal(ss[1:], ss[:-1], out=new_set[1:])
        new_run = new_set.copy()
        np.logical_or(new_run[1:], lso[1:] != lso[:-1], out=new_run[1:])
        idx = np.arange(n)
        run_start = np.maximum.accumulate(np.where(new_run, idx, 0))
        keep = (idx - run_start) < depth
        self.kept_pos = ord_set[keep]
        self.kept_tag = lso[keep].astype(np.int32)
        k_new_set = new_set[keep]
        k_idx = np.arange(self.kept_pos.size)
        self.sg = np.cumsum(k_new_set) - 1
        seg_base = np.maximum.accumulate(np.where(k_new_set, k_idx, 0))
        self.ps = k_idx - seg_base
        self.seg_len = np.bincount(self.sg)


# ---------------------------------------------------------------------------
# Batched per-set scans (device)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("ways",))
def _fifo_scan_rows(tags_in, valid, ways: int):
    """FIFO over (B, L) per-set rows: ring buffer of ``ways`` tags whose
    head is always the oldest fill. Returns per-position hit flags."""
    B, _ = tags_in.shape
    iota = jnp.arange(ways, dtype=ITYPE)[None, :]

    def step(carry, x):
        tags, head = carry
        tag, v = x
        hit = jnp.any(tags == tag[:, None], axis=1)
        missb = (~hit) & v
        oh = iota == head[:, None]
        tags = jnp.where(missb[:, None] & oh, tag[:, None], tags)
        nxt = head + 1
        head = jnp.where(missb, jnp.where(nxt == ways, 0, nxt), head)
        return (tags, head), hit & v

    init = (jnp.full((B, ways), -1, ITYPE), jnp.zeros((B,), ITYPE))
    _, hits = jax.lax.scan(
        step, init, (tags_in.T, valid.T), unroll=_SCAN_UNROLL
    )
    return hits.T


@functools.partial(jax.jit, static_argnames=("ways",))
def _srrip_scan_rows(tags_in, valid, ways: int):
    """SRRIP over (B, L) per-set rows (compressed-key state; see module
    docstring). Returns per-position hit flags."""
    B, _ = tags_in.shape
    iota = jnp.arange(ways, dtype=ITYPE)[None, :]

    def step(carry, x):
        tags, keys, A, nf = carry
        tag, v = x
        hv = tags == tag[:, None]
        hit = jnp.any(hv, axis=1)
        m = jnp.min(keys, axis=1)
        warm = nf >= ways
        vic = jnp.where(warm, jnp.argmin(keys, axis=1).astype(ITYPE), nf)
        fill_key = jnp.where(warm, m + 1, A - 2)
        oh = iota == vic[:, None]
        hitb = hit & v
        missb = (~hit) & v
        tags = jnp.where(missb[:, None] & oh, tag[:, None], tags)
        keys = jnp.where(
            hitb[:, None] & hv,
            A[:, None],
            jnp.where(missb[:, None] & oh, fill_key[:, None], keys),
        )
        A = jnp.where(missb & warm, m + 3, A)
        nf = jnp.where(missb & ~warm, nf + 1, nf)
        return (tags, keys, A, nf), hitb

    init = (
        jnp.full((B, ways), -1, ITYPE),
        jnp.zeros((B, ways), ITYPE),
        jnp.zeros((B,), ITYPE),
        jnp.zeros((B,), ITYPE),
    )
    _, hits = jax.lax.scan(
        step, init, (tags_in.T, valid.T), unroll=_SCAN_UNROLL
    )
    return hits.T


_SCANS = {"fifo": (_fifo_scan_rows, 1), "srrip": (_srrip_scan_rows, 2)}


# ---------------------------------------------------------------------------
# Many-stream driver
# ---------------------------------------------------------------------------


def _stream_id(arr: np.ndarray) -> tuple:
    i = arr.__array_interface__
    return (i["data"][0], arr.shape, arr.dtype.str, i.get("strides"))


def _classify_many(
    streams: Sequence[np.ndarray],
    geometries: Sequence[Tuple[int, int]],
    policy: str,
) -> List[Tuple[np.ndarray, int]]:
    global _passes
    scan_fn, depth = _SCANS[policy]
    out: List = [None] * len(streams)

    # unique configs + shared presorts
    presorts: Dict[tuple, _Presort] = {}
    cfg_idx: Dict[tuple, int] = {}
    cfg_sid: List[tuple] = []
    cfg_ways: List[int] = []
    cfg_out: List[List[int]] = []
    with stage("stack_distance"):
        for i, (s, (num_sets, ways)) in enumerate(zip(streams, geometries)):
            lines = _check_int32(s)
            sid = (_stream_id(lines), int(num_sets))
            if sid not in presorts:
                presorts[sid] = _Presort(lines, int(num_sets), depth)
                _passes += 1
            c = cfg_idx.get((sid, int(ways)))
            if c is None:
                c = cfg_idx[(sid, int(ways))] = len(cfg_sid)
                cfg_sid.append(sid)
                cfg_ways.append(int(ways))
                cfg_out.append([])
            cfg_out[c].append(i)

    with stage("cache_scan"):
        # global row table: every per-set segment of every config
        n_cfg = len(cfg_sid)
        seg_counts = [presorts[sid].seg_len.size for sid in cfg_sid]
        row_base = np.cumsum([0] + seg_counts)
        n_rows = int(row_base[-1])
        if n_rows:
            row_len = np.concatenate(
                [presorts[sid].seg_len for sid in cfg_sid]
            )
            row_ways = np.repeat(
                np.asarray(cfg_ways, np.int64), seg_counts
            )
            row_lb = _pow2_bucket(row_len, _MIN_ROW_BUCKET)
            # bucket = (ways, Lb); group rows contiguously per bucket
            kb = row_ways * (np.int64(1) << 40) + row_lb
            order_rows = np.argsort(kb, kind="stable")
            lb_sorted = row_lb[order_rows]
            off_sorted = np.cumsum(lb_sorted) - lb_sorted
            total = int(off_sorted[-1] + lb_sorted[-1])
            off_row = np.empty(n_rows, np.int64)
            off_row[order_rows] = off_sorted
            tags_flat = np.full(total, _PAD_TAG, np.int32)
            valid_flat = np.zeros(total, bool)
            elem_pos: List[np.ndarray] = []
            for c, sid in enumerate(cfg_sid):
                p = presorts[sid]
                pos = off_row[row_base[c] + p.sg] + p.ps
                tags_flat[pos] = p.kept_tag
                valid_flat[pos] = True
                elem_pos.append(pos)
            # dispatch one batched scan per bucket
            kb_sorted = kb[order_rows]
            bnd = np.flatnonzero(
                np.concatenate(([True], kb_sorted[1:] != kb_sorted[:-1]))
            )
            bnd = np.append(bnd, n_rows)
            hits_flat = np.zeros(total, bool)
            for i0, i1 in zip(bnd[:-1], bnd[1:]):
                B = int(i1 - i0)
                Lb = int(lb_sorted[i0])
                ways = int(row_ways[order_rows[i0]])
                e0 = int(off_sorted[i0])
                e1 = e0 + B * Lb
                Bp = _pow2_at_least(B, _MIN_ROWS)
                tags_m = np.full((Bp, Lb), _PAD_TAG, np.int32)
                valid_m = np.zeros((Bp, Lb), bool)
                tags_m[:B] = tags_flat[e0:e1].reshape(B, Lb)
                valid_m[:B] = valid_flat[e0:e1].reshape(B, Lb)
                hits_d = scan_fn(tags_m, valid_m, ways)
                if _profiling_active():
                    hits_d.block_until_ready()
                with stage("host_sync"):
                    hits_h = np.asarray(hits_d)
                hits_flat[e0:e1] = hits_h[:B].reshape(-1)
        # per-config gather + eviction counts
        for c, sid in enumerate(cfg_sid):
            p = presorts[sid]
            ways = cfg_ways[c]
            if p.n == 0:
                res = (np.zeros(0, bool), 0)
            else:
                h_kept = hits_flat[elem_pos[c]]
                hits = np.ones(p.n, bool)   # dropped positions surely hit
                hits[p.kept_pos] = h_kept
                # misses only occur at kept positions; count per segment
                mc = np.bincount(
                    p.sg[~h_kept], minlength=p.seg_len.size or 1
                )
                ev = int(np.maximum(mc - ways, 0).sum())
                res = (hits, ev)
            for i in cfg_out[c]:
                out[i] = res
    return out


def classify_fifo_many(
    streams: Sequence[np.ndarray],
    geometries: Sequence[Tuple[int, int]],
) -> List[Tuple[np.ndarray, int]]:
    """FIFO-classify ``streams[i]`` under ``geometries[i] = (num_sets,
    ways)``; returns ``[(hits bool (n,), evictions int)]``."""
    return _classify_many(streams, geometries, "fifo")


def classify_srrip_many(
    streams: Sequence[np.ndarray],
    geometries: Sequence[Tuple[int, int]],
) -> List[Tuple[np.ndarray, int]]:
    """SRRIP-classify ``streams[i]`` under ``geometries[i]``; see
    ``classify_fifo_many``."""
    return _classify_many(streams, geometries, "srrip")


def classify_analytic_many(
    streams: Sequence[np.ndarray],
    geometries: Sequence[Tuple[int, int]],
    policy: str,
) -> List[Tuple[np.ndarray, int]]:
    """Dispatch to the policy-specific analytic engine."""
    if policy not in _SCANS:
        raise ValueError(f"no analytic engine for policy {policy!r}")
    return _classify_many(streams, geometries, policy)
