from .cache import CacheGeometry, simulate_cache, CacheResult
from .golden import GoldenCache
from .dram import (
    DramModel,
    DramResult,
    dram_timing,
    dram_timing_segmented,
    estimate_dram_fast,
    simulate_dram,
    simulate_dram_segmented,
)
from .policies import (
    MemoryPolicy,
    PolicyContext,
    PolicyOutcome,
    available_policies,
    get_policy,
    profile_hot_lines,
    register_policy,
    run_policy,
)
from .system import EmbeddingBatchStats, EmbeddingTrace, MemorySystem, lane_geometry

__all__ = [
    "CacheGeometry",
    "simulate_cache",
    "CacheResult",
    "GoldenCache",
    "DramModel",
    "DramResult",
    "simulate_dram",
    "simulate_dram_segmented",
    "dram_timing",
    "dram_timing_segmented",
    "estimate_dram_fast",
    "MemoryPolicy",
    "PolicyContext",
    "PolicyOutcome",
    "available_policies",
    "get_policy",
    "profile_hot_lines",
    "register_policy",
    "run_policy",
    "EmbeddingBatchStats",
    "EmbeddingTrace",
    "MemorySystem",
    "lane_geometry",
]
