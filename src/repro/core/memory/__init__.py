from .cache import CacheGeometry, simulate_cache, CacheResult
from .golden import GoldenCache
from .dram import DramModel, simulate_dram, estimate_dram_fast, dram_timing
from .policies import run_policy, PolicyOutcome

__all__ = [
    "CacheGeometry",
    "simulate_cache",
    "CacheResult",
    "GoldenCache",
    "DramModel",
    "simulate_dram",
    "run_policy",
    "PolicyOutcome",
]
