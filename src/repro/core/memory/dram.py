"""DRAMSim-lite: off-chip memory timing model.

The paper adopts mNPUsim's off-chip path (NPU memory controller +
DRAMSim3-based DRAM). Offline we implement the same *interface* — a per-access
event model over (channel, bank, row) with row-buffer hits/misses and
bandwidth occupancy — with a simplified timing core (DESIGN.md §8):

  * address interleave: line -> channel (line-granular striping) -> bank -> row;
  * per access: row hit costs tCAS, row miss tRP+tRCD+tCAS (precharge+activate);
  * each channel's data bus is occupied line_bytes/channel_bw per transfer;
  * banks within a channel overlap row operations, the channel bus serializes
    data transfers.

Channels are fully independent, so the event scan is ``vmap``-ed across
channels (carry per channel: open-row + free-cycle per bank + bus-free
scalar), giving a channels-wide speedup over a monolithic scan.

Hot-path engine (``_scan_channel_chunked``): FR-FCFS keeps a block's lines
consecutive, and within such a run every access after the first is a row hit
whose completion is exactly ``prev_done + bus_cycles`` (the bank and the bus
were both freed by the previous line of the same run, and arrivals are zero
in the memory-bound regime). The scan therefore steps over *chunks* — runs
of up to ``lines_per_block`` same-(bank, block) accesses — carrying the
identical f32 state chain, which cuts the sequential step count ~8x for
vector-granular miss bursts while remaining bit-exact with the per-access
scan. Everything around the scan is run/chunk-granular too: FR-FCFS
ordering argsorts block *runs* (~8x fewer elements) and expands back —
bitwise identical to line-level ordering — and the single host sync per
dispatch extracts per-CHUNK first completions, with in-chunk completions
replayed on the host via the same sequence of IEEE f32 adds. Per-segment
aggregates are reduced on the host in original access order, so they are
independent of padding layout and of which other segments share a dispatch
— which is what makes cross-configuration batching (``DramRequest`` /
``dram_timing_many``) a pure dispatch-count optimization.

``estimate_dram_fast`` is a closed-form vectorized estimate (per-channel bus
occupancy vs per-bank row-op serialization) used by the engine for very long
traces; tests pin it within tolerance of the event scan.
"""
from __future__ import annotations

import functools
import os
from dataclasses import dataclass
from typing import List

import jax
import jax.numpy as jnp
import numpy as np

from ..hardware import HardwareConfig
from ..profiling import is_active as _profiling_active, stage


@dataclass
class DramResult:
    finish_cycle: float          # cycle when the last access completes
    total_latency_cycles: float  # sum of per-access latencies
    row_hits: int
    row_misses: int
    accesses: int
    detailed: bool = True

    @property
    def row_hit_rate(self) -> float:
        return self.row_hits / max(self.accesses, 1)


@dataclass(frozen=True)
class DramModel:
    channels: int
    banks_per_channel: int
    lines_per_row: int
    t_cas: int
    t_rcd: int
    t_rp: int
    base_latency: int
    chan_bytes_per_cycle: float
    line_bytes: int
    lines_per_block: int = 8     # channel-interleave granularity in lines
    queue_depth: int = 32

    @staticmethod
    def from_hardware(hw: HardwareConfig) -> "DramModel":
        off = hw.offchip
        line = hw.onchip.line_bytes
        return DramModel(
            channels=off.channels,
            banks_per_channel=off.banks_per_channel,
            lines_per_row=max(1, off.row_bytes // line),
            t_cas=off.t_cas_cycles,
            t_rcd=off.t_rcd_cycles,
            t_rp=off.t_rp_cycles,
            base_latency=off.base_latency_cycles,
            chan_bytes_per_cycle=off.channel_bytes_per_cycle(hw.clock_ghz),
            line_bytes=line,
            lines_per_block=max(1, off.interleave_bytes // line),
        )

    def decompose(self, lines: np.ndarray):
        """line -> (channel, bank, row) under block-granular interleaving.

        Consecutive ``lines_per_block`` lines form one interleave block living
        in a single (channel, bank, row); blocks stripe across channels, then
        banks. Coarse interleave keeps an embedding vector inside one row
        (one activate per vector), fine interleave spreads it across channels
        (activate per line) — a first-class EONSim config knob.
        """
        return self.decompose_blocks(lines // self.lines_per_block)

    def decompose_blocks(self, blk: np.ndarray):
        """block -> (channel, bank, row); every line of a block shares these,
        so run-compressed paths decompose once per block run, not per line."""
        ch = (blk % self.channels).astype(np.int32)
        in_ch = blk // self.channels
        bk = (in_ch % self.banks_per_channel).astype(np.int32)
        blocks_per_row = max(1, self.lines_per_row // self.lines_per_block)
        row = (in_ch // self.banks_per_channel // blocks_per_row).astype(np.int32)
        return ch, bk, row


def _argsort_stable(key: np.ndarray) -> np.ndarray:
    """Stable argsort of non-negative int64 keys, radix-accelerated.

    numpy's ``kind="stable"`` runs an O(n) radix sort for 16-bit integer
    dtypes but falls back to mergesort (~8x slower at FR-FCFS sizes) for
    wider ones. An LSD radix sort built from stable uint16-digit passes
    produces the *identical* permutation: each pass sorts by one more
    significant digit with ties resolved by the previous pass's order, so
    the composition is exactly the unique stable order by the full key
    (test-enforced against ``np.argsort(key, kind="stable")``).
    """
    kmax = int(key.max()) if key.size else 0
    if kmax < (1 << 16):
        return np.argsort(key.astype(np.uint16), kind="stable")
    order = np.argsort((key & 0xFFFF).astype(np.uint16), kind="stable")
    k = key[order] >> 16
    shift = 16
    while True:
        nxt = np.argsort((k & 0xFFFF).astype(np.uint16), kind="stable")
        order = order[nxt]
        shift += 16
        if (kmax >> shift) == 0:
            return order
        k = k[nxt] >> 16


def _per_key_rank(keys: np.ndarray) -> np.ndarray:
    """Rank of each element within its key group, preserving original order."""
    n = keys.size
    order = np.argsort(keys, kind="stable")
    sk = keys[order]
    starts = np.ones(n, dtype=bool)
    starts[1:] = sk[1:] != sk[:-1]
    grp_start = np.maximum.accumulate(np.where(starts, np.arange(n), 0))
    rank_sorted = np.arange(n) - grp_start
    rank = np.empty(n, dtype=np.int64)
    rank[order] = rank_sorted
    return rank


def _frfcfs_order(
    ch: np.ndarray,
    bk: np.ndarray,
    blk: np.ndarray,
    banks: int,
    channels: int,
    seg: np.ndarray | None = None,
) -> np.ndarray:
    """FR-FCFS-style service order within each channel.

    Real controllers pick ready requests: banks are served round-robin at
    interleave-*block* granularity (one activate per block), while a block's
    lines stay consecutive so an open row streams at burst rate. Per-bank
    request order is preserved, keeping row-buffer locality exact.

    ``seg`` (optional) qualifies every key with a segment id so one call
    orders many independent sub-traces at once: within each segment the
    resulting relative order is identical to an unsegmented call on that
    segment alone (the segmented engine relies on this for bit-exactness).

    Two stable argsorts on composite integer keys; within any fixed
    (channel, bank) the arrival rank increases with the original index, so a
    stable sort on the coarser key already orders per-bank streams by
    arrival — no explicit rank key needed (``_frfcfs_order_ref`` is the
    spelled-out reference; equality is test-enforced).
    """
    n = ch.size
    chq = ch.astype(np.int64)                 # segment-qualified channel id
    if seg is not None:
        chq = seg.astype(np.int64) * channels + chq
    gb = chq * banks + bk
    order0 = _argsort_stable(gb)              # per-bank streams, in order
    gb_s, blk_s = gb[order0], blk[order0]
    first = np.ones(n, dtype=bool)
    first[1:] = gb_s[1:] != gb_s[:-1]
    new_inst = first.copy()
    new_inst[1:] |= blk_s[1:] != blk_s[:-1]
    cs = np.cumsum(new_inst)
    base = np.maximum.accumulate(np.where(first, cs - 1, 0))
    inst_s = cs - 1 - base                    # block-instance index within bank
    # Final service key (chq, inst, bk); ties = arrival order via stability.
    key = np.empty(n, dtype=np.int64)
    key[order0] = (chq[order0] * (n + 1) + inst_s) * banks + bk[order0]
    return _argsort_stable(key)


def _frfcfs_order_ref(
    ch: np.ndarray,
    bk: np.ndarray,
    blk: np.ndarray,
    banks: int,
    channels: int,
    seg: np.ndarray | None = None,
) -> np.ndarray:
    """Reference FR-FCFS ordering (explicit rank + lexsorts) for tests."""
    n = ch.size
    chq = ch.astype(np.int64)
    if seg is not None:
        chq = seg.astype(np.int64) * channels + chq
    gb = chq * banks + bk
    r = _per_key_rank(gb)
    order0 = np.lexsort((r, gb))
    gb_s, blk_s = gb[order0], blk[order0]
    first = np.ones(n, dtype=bool)
    first[1:] = gb_s[1:] != gb_s[:-1]
    new_inst = first.copy()
    new_inst[1:] |= blk_s[1:] != blk_s[:-1]
    cs = np.cumsum(new_inst)
    base = np.maximum.accumulate(np.where(first, cs - 1, 0))
    inst_s = cs - 1 - base
    inst = np.empty(n, dtype=np.int64)
    inst[order0] = inst_s
    return np.lexsort((r, bk, inst, chq))


@functools.partial(jax.jit, static_argnames=("banks",))
def _scan_channel(
    bk: jax.Array,       # (C, L) bank index per slot
    row: jax.Array,      # (C, L) row per slot
    arrive: jax.Array,   # (C, L) arrival cycle
    valid: jax.Array,    # (C, L) real access?
    banks: int,
    t_cas: float,
    t_row_act: float,
    bus_cycles_per_line: float,
):
    """Per-channel event scan, vmapped over the channel axis.

    Reduced view of ``_scan_channel_full`` (one scan implementation): returns
    per-channel (finish, total latency, row hits)."""
    done, lat, hit = _scan_channel_full(
        bk, row, arrive, valid, banks, t_cas, t_row_act, bus_cycles_per_line
    )
    return done.max(axis=-1), lat.sum(axis=-1), hit.sum(axis=-1)


@functools.partial(jax.jit, static_argnames=("banks",))
def _scan_channel_full(
    bk: jax.Array,       # (R, L) bank index per slot
    row: jax.Array,      # (R, L) row per slot
    arrive: jax.Array,   # (R, L) arrival cycle
    valid: jax.Array,    # (R, L) real access?
    banks: int,
    t_cas: float,
    t_row_act: float,
    bus_cycles_per_line: float,
):
    """``_scan_channel`` variant returning PER-ACCESS completion/latency/hit
    arrays instead of per-channel reductions — same step function, identical
    scanned values. The caller attributes completions back to request sources
    (e.g. which core issued each miss) for per-core contention stats."""

    def one_channel(bk_c, row_c, arr_c, val_c):
        def step(carry, x):
            open_row, bank_free, bus_free = carry
            b, r, a, v = x
            row_hit = open_row[b] == r
            occ = jnp.where(row_hit, 0.0, t_row_act)
            bank_avail = jnp.maximum(a, bank_free[b]) + occ
            start_xfer = jnp.maximum(bank_avail, bus_free)
            done = start_xfer + bus_cycles_per_line
            new_open = open_row.at[b].set(r)
            new_bfree = bank_free.at[b].set(done)
            open_row = jnp.where(v, new_open, open_row)
            bank_free = jnp.where(v, new_bfree, bank_free)
            bus_free = jnp.where(v, done, bus_free)
            return (open_row, bank_free, bus_free), (
                jnp.where(v, done + t_cas, 0.0),
                jnp.where(v, done + t_cas - a, 0.0),
                jnp.logical_and(v, row_hit),
            )

        init = (
            jnp.full((banks,), -1, dtype=jnp.int32),
            jnp.zeros((banks,), dtype=jnp.float32),
            jnp.float32(0.0),
        )
        (_, _, _), (done, lat, hit) = jax.lax.scan(
            step, init, (bk_c, row_c, arr_c, val_c)
        )
        return done, lat, hit

    return jax.vmap(one_channel)(bk, row, arrive, valid)


# --------------------------------------------------------------------------
# Chunked event scan (the hot-path engine)
# --------------------------------------------------------------------------

_SCAN_UNROLL = 8    # best CPU throughput for the tiny per-step body (measured)


@functools.partial(jax.jit, static_argnames=("banks", "k_max"))
def _scan_channel_chunked(
    bkc: jax.Array,      # (R, Lc) bank of each chunk
    rowc: jax.Array,     # (R, Lc) row of each chunk
    kc: jax.Array,       # (R, Lc) accesses in each chunk (1..k_max; 0 = pad)
    valid: jax.Array,    # (R, Lc) real chunk?
    banks: int,
    k_max: int,
    t_row_act: float,
    t_cas: float,
    bus_cycles_per_line: float,
):
    """Per-(segment, channel) scan over same-(bank, block) chunks.

    Carries the identical (open_row, bank_free, bus_free) f32 state chain as
    the per-access ``_scan_channel_full`` step: the chunk's first access pays
    the row check; accesses 2..k advance completion by ``bus_cycles_per_line``
    each (reproduced as the same sequence of f32 adds, so state — and every
    derived completion — is bitwise identical). Bank state is updated via
    one-hot masks rather than gather/scatter (faster on small carries, same
    values).

    Device-resident bookkeeping: the carry also folds each row's run
    aggregates as it scans — the f32 latency chain ``sum_chunks sum_j
    (done_j + t_cas)`` accumulated sequentially in service order (padded
    columns add exact 0.0, so the value is layout-independent), the row-hit
    count, and the running max of chunk-last completions (CAS excluded).
    ``simulate_dram_contended`` extracts only these (R,)-sized aggregates
    for single-source requests; the per-chunk ``(done0, row_hit)`` outputs
    remain for per-source finish attribution and the host reference mode.
    """

    def one_row(bk_r, row_r, k_r, v_r):
        def step(carry, x):
            open_row, bank_free, bus_free, lat_acc, hit_acc, dmax = carry
            b, r, k, v = x
            sel = jax.lax.iota(jnp.int32, banks) == b
            row_hit = jnp.any(sel & (open_row == r))
            occ = jnp.where(row_hit, 0.0, t_row_act)
            bank_prev = jnp.max(jnp.where(sel, bank_free, -jnp.inf))
            bank_avail = jnp.maximum(jnp.float32(0.0), bank_prev) + occ
            done0 = jnp.maximum(bank_avail, bus_free) + bus_cycles_per_line
            dlast = done0
            lc = done0 + t_cas
            for j in range(1, k_max):
                live = j < k
                dlast = jnp.where(live, dlast + bus_cycles_per_line, dlast)
                lc = jnp.where(live, lc + (dlast + t_cas), lc)
            upd = sel & v
            open_row = jnp.where(upd, r, open_row)
            bank_free = jnp.where(upd, dlast, bank_free)
            bus_free = jnp.where(v, dlast, bus_free)
            lat_acc = lat_acc + jnp.where(v, lc, 0.0)
            hit_acc = hit_acc + jnp.where(
                v, k - 1 + row_hit.astype(jnp.int32), 0
            )
            dmax = jnp.maximum(dmax, jnp.where(v, dlast, 0.0))
            return (open_row, bank_free, bus_free, lat_acc, hit_acc, dmax), (
                jnp.where(v, done0, 0.0), row_hit & v
            )

        init = (
            jnp.full((banks,), -1, dtype=jnp.int32),
            jnp.zeros((banks,), dtype=jnp.float32),
            jnp.float32(0.0),
            jnp.float32(0.0),
            jnp.int32(0),
            jnp.float32(0.0),
        )
        carry, outs = jax.lax.scan(
            step, init, (bk_r, row_r, k_r, v_r), unroll=_SCAN_UNROLL
        )
        return (carry[3], carry[4], carry[5]), outs

    return jax.vmap(one_row)(bkc, rowc, kc, valid)


def _chunk_bucket_len(n: int) -> int:
    """Bucketed padding for chunk rows (compiled-shape reuse).

    Half-octave steps (64, 96, 128, 192, ...): scan wall time is linear in
    the padded length, so pure powers of two waste up to ~2x sequential
    steps on rows that just cross a boundary; the 1.5x intermediates cap
    the padding overhead at 33% for at most twice the compiled-shape pool.
    """
    b = 64
    while b < n:
        if n <= b + b // 2:
            return b + b // 2
        b *= 2
    return b


def simulate_dram(
    lines: np.ndarray,
    model: DramModel,
    issue_interval_cycles: float = 0.0,
    start_cycle: float = 0.0,
) -> DramResult:
    """Event-scan the (miss) line trace through the DRAM model.

    ``issue_interval_cycles`` models the upstream request rate; 0 means the
    controller queue is always full (memory-bound phase), the usual regime for
    embedding gathers.

    The memory-bound default (zero issue interval, zero start cycle) routes
    through the chunked one-segment engine — the same code path as the
    segmented/contended sweeps, so the two can never drift apart. Non-zero
    arrivals keep the legacy per-access scan (chunk compression assumes the
    bus is the only arrival constraint).
    """
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    n = lines.size
    if n == 0:
        return DramResult(start_cycle, 0.0, 0, 0, 0)
    if issue_interval_cycles == 0.0 and start_cycle == 0.0:
        results, _ = simulate_dram_contended(
            lines,
            np.zeros(n, dtype=np.int64),
            np.zeros(n, dtype=np.int64),
            1,
            1,
            model,
        )
        return results[0]
    ch, bk, row = model.decompose(lines)
    arrive = start_cycle + np.arange(n, dtype=np.float32) * issue_interval_cycles

    C = model.channels
    # FR-FCFS-style controller: banks round-robin at block granularity,
    # block lines consecutive (see _frfcfs_order). In-order service would
    # head-of-line block on activating banks, which real controllers avoid.
    blk = lines // model.lines_per_block
    order = _frfcfs_order(ch, bk, blk, model.banks_per_channel, C)
    ch_s = ch[order]
    bounds = np.searchsorted(ch_s, np.arange(C + 1))
    max_len = int(np.max(bounds[1:] - bounds[:-1])) if n else 0
    L = max(1, max_len)
    bk_m = np.zeros((C, L), dtype=np.int32)
    row_m = np.zeros((C, L), dtype=np.int32)
    ar_m = np.zeros((C, L), dtype=np.float32)
    va_m = np.zeros((C, L), dtype=bool)
    for c in range(C):
        lo, hi = bounds[c], bounds[c + 1]
        idx = order[lo:hi]
        m = hi - lo
        bk_m[c, :m] = bk[idx]
        row_m[c, :m] = row[idx]
        ar_m[c, :m] = arrive[idx]
        va_m[c, :m] = True

    done, lat, hits = _scan_channel(
        jnp.asarray(bk_m),
        jnp.asarray(row_m),
        jnp.asarray(ar_m),
        jnp.asarray(va_m),
        model.banks_per_channel,
        float(model.t_cas),
        float(model.t_rp + model.t_rcd),
        float(model.line_bytes / model.chan_bytes_per_cycle),
    )
    row_hits = int(np.asarray(hits).sum())
    return DramResult(
        finish_cycle=float(np.asarray(done).max()) + model.base_latency,  # done incl. CAS
        total_latency_cycles=float(np.asarray(lat).sum()) + model.base_latency * n,
        row_hits=row_hits,
        row_misses=n - row_hits,
        accesses=n,
    )


def simulate_dram_segmented(
    lines: np.ndarray,
    seg: np.ndarray,
    num_segments: int,
    model: DramModel,
) -> List[DramResult]:
    """One batched event scan over a concatenated multi-segment miss trace.

    Each segment (e.g. one inference batch) is timed against *fresh* DRAM
    state, exactly as if ``simulate_dram`` ran per segment — but all
    (segment, channel) scans execute as a single vmapped JAX dispatch instead
    of ``num_segments`` separate ones. Per-segment results are bit-exact vs
    the per-segment loop (same FR-FCFS order, same f32 accumulation order per
    scan; tests enforce this).

    Implemented as the one-source reduction of the contended multi-core scan,
    so the single-core and cluster DRAM paths cannot drift apart.
    """
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    results, _ = simulate_dram_contended(
        lines,
        seg,
        np.zeros(lines.size, dtype=np.int64),
        num_segments,
        1,
        model,
    )
    return results


def simulate_dram_contended(
    lines: np.ndarray,
    seg: np.ndarray,
    src: np.ndarray,
    num_segments: int,
    num_sources: int,
    model: DramModel,
    aggregate: str = "device",
):
    """Shared-DRAM timing with cross-source contention within each segment.

    The multi-core extension of ``simulate_dram_segmented``: a segment (one
    inference batch) still starts from fresh DRAM state, but WITHIN a segment
    all sources (cores) share one controller/bank/bus state — their
    interleaved miss bursts contend for channels instead of each core seeing
    an empty DRAM. ``src`` tags each access with its source; arrival order is
    the given trace order (callers merge per-core streams deterministically).

    Returns ``(results, finish)``: one ``DramResult`` per segment for the
    shared stream, plus ``finish[num_segments, num_sources]`` — each source's
    last completion cycle (0.0 where a source issued nothing), so per-core
    DRAM stall under contention is directly observable.

    Engine: run-compressed FR-FCFS ordering on the host, then ONE chunked
    device scan over all (segment, channel) rows (``_scan_channel_chunked``).
    All host bookkeeping is RUN-granular — chunks are built directly from
    merged block runs, with no per-access expansion on the default path.
    The scan carries per-row aggregates (latency sum, row-hit count, max
    completion), so for single-source requests the extraction is three
    ``(segments * channels,)``-sized arrays folded to per-segment results by
    pure reshapes. Multi-source requests stay run-granular too: run
    boundaries fold ``src`` (order-preserving — no block instance is added),
    so each run is source-pure and its maximum completion is its last line;
    per-source finish reduces over runs, never per-access.

    ``aggregate`` selects where per-segment totals reduce: ``"device"``
    (default) trusts the in-scan carry aggregates; ``"host"`` ignores them
    and re-derives every total from the per-chunk ``(done0, row_hit)``
    outputs with an independent host implementation of the same IEEE op
    chains. The two modes are bitwise identical (test-enforced) — ``"host"``
    exists as the differential reference, not as a performance path.

    Exactness: every per-access completion (hence ``finish_cycle`` and the
    per-source ``finish`` attribution) and all row-hit counts are bitwise
    identical to the per-access scan. ``total_latency_cycles`` is the f32
    per-(segment, channel) service-order chain summed in f64 across
    channels — sequential adds of ``(completion + t_cas)`` exactly as the
    device scan accumulates them (padding adds exact 0.0, so the value is
    independent of dispatch layout and of which segments share a dispatch).
    Nothing downstream of ``DramResult`` consumes it for timing.
    """
    if aggregate not in ("device", "host"):
        raise ValueError(f"unknown aggregate mode: {aggregate!r}")
    return _contended_finish(
        _contended_start(lines, seg, src, num_segments, num_sources, model),
        aggregate,
    )


def _contended_start(
    lines: np.ndarray,
    seg: np.ndarray,
    src: np.ndarray,
    num_segments: int,
    num_sources: int,
    model: DramModel,
) -> dict:
    """Host prep + async device dispatch for one contended call.

    Returns an opaque state consumed by ``_contended_finish``. The chunked
    scan is dispatched but not blocked on (JAX dispatch is async, also on
    CPU), so a caller that starts several calls before finishing any
    overlaps each call's host bookkeeping with the earlier calls' device
    scans — ``dram_timing_many`` pipelines its batch groups this way.
    """
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    seg = np.asarray(seg, dtype=np.int64).reshape(-1)
    src = np.asarray(src, dtype=np.int64).reshape(-1)
    n = lines.size
    C = model.channels
    if n == 0:
        return dict(
            n=0, num_segments=num_segments, num_sources=num_sources,
            model=model,
        )

    with stage("dram"):
        lpb = model.lines_per_block
        if lpb & (lpb - 1) == 0:
            blk = lines >> (lpb.bit_length() - 1)   # pow2: shift, not divide
        else:
            blk = lines // lpb
        # Run compression: maximal stretches of same-(segment, block) lines
        # in arrival order share one (channel, bank, row) and identical
        # FR-FCFS keys, so ordering operates on RUNS (~8x fewer elements for
        # vector-expanded miss bursts — the argsorts were the host hot spot).
        # Stability keeps a run's lines consecutive and per-bank arrival
        # order intact, and block-instance counting over runs merges adjacent
        # same-block runs exactly like the per-line derivation, so the
        # implied service order is bitwise identical to line-level ordering
        # (test-enforced vs the golden DRAM model).
        new_run0 = np.ones(n, dtype=bool)
        new_run0[1:] = (seg[1:] != seg[:-1]) | (blk[1:] != blk[:-1])
        if num_sources > 1:
            # Source-pure runs: splitting a run at a source boundary adds no
            # block instance (same bank stream, same block), so every
            # FR-FCFS key — and the stable order around the split — is
            # unchanged; the halves stay adjacent and re-merge into the same
            # chunks. Buys run-granular per-source finish attribution below.
            new_run0[1:] |= src[1:] != src[:-1]
        rstart = np.nonzero(new_run0)[0]
        nr = rstart.size
        rlen = np.diff(np.append(rstart, n))
        rblk = blk[rstart]
        rseg = seg[rstart]
        rch, rbk, rrow = model.decompose_blocks(rblk)
        order_r = _frfcfs_order(
            rch, rbk, rblk, model.banks_per_channel, C, seg=rseg
        )
        n_seg = np.bincount(
            rseg, weights=rlen, minlength=num_segments
        ).astype(np.int64)

        rlen_o = rlen[order_r]
        pre_o = np.cumsum(rlen_o) - rlen_o       # line offset of each run

        # Chunking: FR-FCFS keeps a block's accesses consecutive; adjacent
        # ordered runs with the same (segment-qualified channel, block) are
        # one merged service run. Cap chunks at the interleave-block size so
        # the chunk length is a compile-time constant — splitting a longer
        # run is exact (the split point sees bank_free == bus_free == prev
        # done). Chunks are derived from merged runs directly (run-granular;
        # no n-sized intermediates).
        chq_o = rseg[order_r] * C + rch[order_r]
        blk_o = rblk[order_r]
        new_merged = np.ones(nr, dtype=bool)
        new_merged[1:] = (chq_o[1:] != chq_o[:-1]) | (blk_o[1:] != blk_o[:-1])
        mstart_r = np.nonzero(new_merged)[0]     # first ordered run of each
        nm = mstart_r.size
        mlen = np.diff(np.append(pre_o[mstart_r], n))  # lines per merged run
        k_max = max(1, min(model.lines_per_block, 8))
        nchunks_m = -(-mlen // k_max)
        n_chunks = int(nchunks_m.sum())
        chunk_ofs = np.cumsum(nchunks_m) - nchunks_m
        chunk_merged = np.repeat(np.arange(nm), nchunks_m)
        pos_c = np.arange(n_chunks) - chunk_ofs[chunk_merged]
        k_of = np.minimum(
            k_max, mlen[chunk_merged] - pos_c * k_max
        ).astype(np.int32)
        first_run = mstart_r[chunk_merged]
        cchq = chq_o[first_run]

        R = num_segments * C
        chunks_per_row = np.bincount(cchq, minlength=R)
        Lc = _chunk_bucket_len(int(chunks_per_row.max()))
        row_chunk_start = np.concatenate(([0], np.cumsum(chunks_per_row)))
        col_of_chunk = np.arange(n_chunks) - row_chunk_start[cchq]

        bk_m = np.zeros((R, Lc), dtype=np.int32)
        row_m = np.zeros((R, Lc), dtype=np.int32)
        k_m = np.zeros((R, Lc), dtype=np.int32)
        va_m = np.zeros((R, Lc), dtype=bool)
        cflat = cchq * Lc + col_of_chunk
        bk_m.reshape(-1)[cflat] = rbk[order_r][first_run]
        row_m.reshape(-1)[cflat] = rrow[order_r][first_run]
        k_m.reshape(-1)[cflat] = k_of
        va_m.reshape(-1)[cflat] = True

        bus_cyc = float(model.line_bytes / model.chan_bytes_per_cycle)
        (lat_d, hitn_d, dmax_d), (done0_d, hit0_d) = _scan_channel_chunked(
            jnp.asarray(bk_m),
            jnp.asarray(row_m),
            jnp.asarray(k_m),
            jnp.asarray(va_m),
            model.banks_per_channel,
            k_max,
            float(model.t_rp + model.t_rcd),
            float(model.t_cas),
            bus_cyc,
        )
        if _profiling_active():
            # Attribute async device compute to "dram", not to the
            # extraction in ``_contended_finish`` (profiling sessions only;
            # unprofiled runs keep the dispatch async for pipelining).
            jax.block_until_ready((lat_d, hitn_d, dmax_d, done0_d, hit0_d))

    return dict(
        n=n, num_segments=num_segments, num_sources=num_sources, model=model,
        C=C, nr=nr, n_chunks=n_chunks, k_max=k_max, R=R, Lc=Lc,
        bus_cyc=bus_cyc, n_seg=n_seg, cflat=cflat, k_of=k_of, cchq=cchq,
        new_merged=new_merged, pre_o=pre_o, mstart_r=mstart_r,
        chunk_ofs=chunk_ofs, rlen_o=rlen_o, rseg_o=rseg[order_r],
        src_run=src[rstart][order_r] if num_sources > 1 else None,
        rstart_o=rstart[order_r], seg=seg, src=src,
        lat_d=lat_d, hitn_d=hitn_d, dmax_d=dmax_d,
        done0_d=done0_d, hit0_d=hit0_d,
    )


def _contended_finish(st: dict, aggregate: str = "device"):
    """Extraction + per-segment aggregation for a started contended call."""
    num_segments = st["num_segments"]
    num_sources = st["num_sources"]
    model = st["model"]
    empty = DramResult(0.0, 0.0, 0, 0, 0)
    finish = np.zeros((num_segments, num_sources), dtype=np.float64)
    if st["n"] == 0:
        return [empty] * num_segments, finish
    n, C, nr = st["n"], st["C"], st["nr"]
    n_chunks, k_max, R, Lc = st["n_chunks"], st["k_max"], st["R"], st["Lc"]
    n_seg, cflat, k_of, cchq = st["n_seg"], st["cflat"], st["k_of"], st["cchq"]
    new_merged, pre_o = st["new_merged"], st["pre_o"]
    mstart_r, chunk_ofs, rlen_o = st["mstart_r"], st["chunk_ofs"], st["rlen_o"]
    rseg_o, src_run, rstart_o = st["rseg_o"], st["src_run"], st["rstart_o"]
    seg, src = st["seg"], st["src"]
    lat_d, hitn_d, dmax_d = st["lat_d"], st["hitn_d"], st["dmax_d"]
    done0_d, hit0_d = st["done0_d"], st["hit0_d"]
    bus32 = np.float32(st["bus_cyc"])
    cas32 = np.float32(model.t_cas)
    need_chunks = aggregate == "host" or num_sources > 1

    with stage("host_sync"):
        if aggregate == "device":
            # ROW-granular extraction: three (segments * channels,)-sized
            # aggregates — finished per-row sums/maxima straight off the
            # scan carry, independent of trace length.
            lat_row = np.asarray(lat_d).reshape(-1)
            hit_row = np.asarray(hitn_d).reshape(-1)
            dmax_row = np.asarray(dmax_d).reshape(-1)
        if need_chunks:
            # CHUNK-granular extraction — for the host reference mode and
            # for per-source finish attribution (chunk-first completions
            # anchor the run-granular per-source maxima).
            done0_flat = np.asarray(done0_d).reshape(-1)
        if aggregate == "host":
            hit0_flat = np.asarray(hit0_d).reshape(-1)

    with stage("dram"):
        if need_chunks:
            done0_chunk = done0_flat[cflat]                   # f32 per chunk

        if aggregate == "device":
            lat_seg = (
                lat_row.astype(np.float64).reshape(num_segments, C).sum(axis=1)
            )
            hit_seg = (
                hit_row.astype(np.int64).reshape(num_segments, C).sum(axis=1)
            )
            fin_row = np.where(
                dmax_row > 0, (dmax_row + cas32).astype(np.float64), 0.0
            )
            fin_seg = fin_row.reshape(num_segments, C).max(axis=1)
        else:
            # Independent host re-derivation of every aggregate from the
            # per-chunk scan outputs: replay the in-chunk f32 completion /
            # latency chain, then reduce at chunk granularity. Same IEEE op
            # chains as the device carry (sequential f32 adds in service
            # order; 0.0-padding is exact), different implementation — the
            # differential reference for the device aggregates.
            hit0_chunk = hit0_flat[cflat]
            d = done0_chunk
            lc = done0_chunk + cas32
            for step in range(1, k_max):
                live = step < k_of
                d = np.where(live, d + bus32, d)
                lc = np.where(live, lc + (d + cas32), lc)
            lc_m = np.zeros((R, Lc), dtype=np.float32)
            lc_m.reshape(-1)[cflat] = lc
            lat_row_h = np.cumsum(lc_m, axis=1, dtype=np.float32)[:, -1]
            lat_seg = (
                lat_row_h.astype(np.float64)
                .reshape(num_segments, C)
                .sum(axis=1)
            )
            done_last = (d + cas32).astype(np.float64)  # chunk-last + CAS
            hit_chunk = hit0_chunk.astype(np.int64) + (k_of - 1)
            cseg = cchq // C
            hit_seg = np.bincount(
                cseg, weights=hit_chunk, minlength=num_segments
            ).astype(np.int64)
            fin_seg = np.zeros(num_segments, dtype=np.float64)
            np.maximum.at(fin_seg, cseg, done_last)

        if num_sources == 1:
            finish[:, 0] = fin_seg
        elif aggregate == "device":
            # Run-granular per-source finish: runs are source-pure (the run
            # boundary folds ``src``), and within a merged run completions
            # are non-decreasing in service order (each chunk resumes at
            # ``max(dlast, dlast) + bus``, and f32 adds of positive
            # constants are monotone), so a run's maximum completion is its
            # LAST line. Its value is the chunk-first completion plus the
            # same sequential f32 bus adds the scan applied — bitwise equal
            # to the per-access expansion the host mode keeps as reference.
            m_of_run = np.cumsum(new_merged) - 1
            pos_in_m = pre_o - pre_o[mstart_r][m_of_run]
            p_last = pos_in_m + rlen_o - 1
            c_last = chunk_ofs[m_of_run] + p_last // k_max
            j_last = p_last % k_max
            val = done0_chunk[c_last]
            for step in range(1, k_max):
                val = np.where(j_last >= step, val + bus32, val)
            key_run = rseg_o * num_sources + src_run
            np.maximum.at(
                finish.reshape(-1), key_run, (val + cas32).astype(np.float64)
            )
        else:
            # Expand per-access completions: chunk's first completion + j
            # sequential f32 adds of the bus occupancy + t_cas — the exact
            # op chain the device scan applied.
            run_of_line = np.repeat(np.arange(nr), rlen_o)
            within = np.arange(n) - pre_o[run_of_line]
            order = rstart_o[run_of_line] + within
            chunk_of_line = np.repeat(np.arange(n_chunks), k_of)
            j_of = np.arange(n) - np.repeat(
                np.cumsum(k_of) - k_of, k_of
            )
            val = done0_chunk[chunk_of_line]
            for step in range(1, k_max):
                val = np.where(j_of >= step, val + bus32, val)
            done_acc = np.zeros(n, dtype=np.float64)
            done_acc[order] = val + cas32
            key = seg * num_sources + src
            np.maximum.at(finish.reshape(-1), key, done_acc)
        finish[finish > 0] += model.base_latency

        results: List[DramResult] = []
        for s in range(num_segments):
            ns = int(n_seg[s])
            if ns == 0:
                results.append(empty)
                continue
            row_hits = int(hit_seg[s])
            results.append(DramResult(
                finish_cycle=float(fin_seg[s]) + model.base_latency,
                total_latency_cycles=float(lat_seg[s]) + model.base_latency * ns,
                row_hits=row_hits,
                row_misses=ns - row_hits,
                accesses=ns,
            ))
    return results, finish


def estimate_dram_fast(
    lines: np.ndarray,
    model: DramModel,
    start_cycle: float = 0.0,
) -> DramResult:
    """Closed-form estimate for long traces (no event scan).

    finish = max over channels of max(bus occupancy, slowest bank's row-op
    serialization); row transitions counted exactly per bank.
    """
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    n = lines.size
    if n == 0:
        return DramResult(start_cycle, 0.0, 0, 0, 0, detailed=False)
    ch, bk, row = model.decompose(lines)
    C, B = model.channels, model.banks_per_channel
    gb = ch.astype(np.int64) * B + bk
    # row transitions per (channel, bank) in arrival order
    order = np.argsort(gb, kind="stable")
    gb_s, row_s = gb[order], row[order]
    first = np.ones(n, dtype=bool)
    first[1:] = gb_s[1:] != gb_s[:-1]
    trans = first | np.concatenate(([True], row_s[1:] != row_s[:-1]))
    # per-bank counts
    counts = np.bincount(gb_s, minlength=C * B)
    misses = np.bincount(gb_s[trans], minlength=C * B)
    bus_cyc = model.line_bytes / model.chan_bytes_per_cycle
    bank_time = counts * bus_cyc + misses * (model.t_rp + model.t_rcd)
    bank_bound = bank_time.reshape(C, B).max(axis=1)
    bus_bound = np.bincount(ch, minlength=C) * bus_cyc
    finish = (
        float(np.maximum(bank_bound, bus_bound).max())
        + model.base_latency
        + model.t_cas
    )
    row_hits = int(n - trans.sum())
    return DramResult(
        finish_cycle=start_cycle + finish,
        total_latency_cycles=finish * 1.0,
        row_hits=row_hits,
        row_misses=n - row_hits,
        accesses=n,
        detailed=False,
    )


# Engine switches to the fast path above this trace length.
DETAILED_DRAM_MAX = 2_000_000


def dram_timing(lines: np.ndarray, model: DramModel, **kw) -> DramResult:
    if np.asarray(lines).size > DETAILED_DRAM_MAX:
        return estimate_dram_fast(lines, model)
    return simulate_dram(lines, model, **kw)


def dram_timing_segmented(
    lines: np.ndarray,
    seg: np.ndarray,
    num_segments: int,
    model: DramModel,
) -> List[DramResult]:
    """Segmented counterpart of ``dram_timing``.

    Segments longer than ``DETAILED_DRAM_MAX`` use the closed-form estimate
    (matching the per-segment switch in ``dram_timing``); the rest share one
    batched event scan. One-source reduction of ``dram_timing_contended``.
    """
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    out, _ = dram_timing_contended(
        lines, seg, np.zeros(lines.size, dtype=np.int64), num_segments, 1, model
    )
    return out


def dram_timing_contended(
    lines: np.ndarray,
    seg: np.ndarray,
    src: np.ndarray,
    num_segments: int,
    num_sources: int,
    model: DramModel,
):
    """``dram_timing``-style dispatch for the contended shared-DRAM path.

    Segments longer than ``DETAILED_DRAM_MAX`` fall back to the closed-form
    estimate over the merged stream (per-source finish approximated by the
    segment finish — the shared bus bounds every core in that regime).

    NUMA channel affinity needs no special handling here: callers hand in
    *placed* line addresses (``trace.PlacementMap``), whose decompose lands
    only on each request's affine channels, and per-channel state is already
    independent — so disjoint channel groups time exactly as if each group
    were scanned alone (differential-test-enforced).
    """
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    seg = np.asarray(seg, dtype=np.int64).reshape(-1)
    src = np.asarray(src, dtype=np.int64).reshape(-1)
    sizes = np.bincount(seg, minlength=num_segments)
    big_ids = np.nonzero(sizes > DETAILED_DRAM_MAX)[0]
    if big_ids.size == 0:
        return simulate_dram_contended(
            lines, seg, src, num_segments, num_sources, model
        )
    small_ids = np.nonzero(sizes <= DETAILED_DRAM_MAX)[0]
    remap = np.full(num_segments, -1, dtype=np.int64)
    remap[small_ids] = np.arange(small_ids.size)
    keep = remap[seg] >= 0
    small_res, small_fin = simulate_dram_contended(
        lines[keep], remap[seg[keep]], src[keep],
        int(small_ids.size), num_sources, model,
    )
    out: List[DramResult] = [None] * num_segments  # type: ignore[list-item]
    finish = np.zeros((num_segments, num_sources), dtype=np.float64)
    for i, s in enumerate(small_ids):
        out[s] = small_res[i]
        finish[s] = small_fin[i]
    for s in big_ids:
        mask = seg == s
        res = estimate_dram_fast(lines[mask], model)
        out[s] = res
        present = np.bincount(src[mask], minlength=num_sources) > 0
        finish[s][present] = res.finish_cycle
    return out, finish


@dataclass(frozen=True)
class DramRequest:
    """One deferred DRAM-timing dispatch — the unit of cross-config batching.

    A request is exactly the argument tuple of ``dram_timing_contended``;
    the sweep engine collects one per (memo key, embedding op) and pushes
    all of them through ``dram_timing_many`` so same-model requests share
    one event scan instead of one dispatch each.
    """

    lines: np.ndarray
    seg: np.ndarray
    src: np.ndarray
    num_segments: int
    num_sources: int
    model: DramModel


def dram_timing_single(req: DramRequest):
    """Time one request (the unbatched reference path)."""
    return dram_timing_contended(
        req.lines, req.seg, req.src, req.num_segments, req.num_sources,
        req.model,
    )


def _timing_contended_start(lines, seg, src, num_segments, num_sources, model):
    """``dram_timing_contended`` split for pipelined dispatch.

    The common case (no segment above ``DETAILED_DRAM_MAX``) returns a
    pending ``_contended_start`` state; the estimate fallback is evaluated
    eagerly (it has no device phase worth overlapping).
    """
    n_total = np.asarray(lines).size
    if n_total > DETAILED_DRAM_MAX and (np.bincount(
        np.asarray(seg, dtype=np.int64).reshape(-1), minlength=num_segments
    ) > DETAILED_DRAM_MAX).any():
        return ("eager", dram_timing_contended(
            lines, seg, src, num_segments, num_sources, model
        ))
    return ("pending", _contended_start(
        lines, seg, src, num_segments, num_sources, model
    ))


def _timing_contended_finish(started):
    tag, value = started
    if tag == "eager":
        return value
    return _contended_finish(value)


def dram_timing_many(requests: "list[DramRequest]", batch: bool = True):
    """Time many independent requests; same-``DramModel`` requests share ONE
    batched event scan.

    Each request's segments are simply remapped into a disjoint range of one
    concatenated ``dram_timing_contended`` call. Per-segment results are
    independent of which other segments share a dispatch (FR-FCFS ordering is
    segment-qualified, per-segment aggregation runs on the host in original
    access order), so every request's results are bitwise identical to its
    unbatched ``dram_timing_single`` dispatch — tests enforce this, including
    the multi-core contended path. ``batch=False`` is that reference path.

    Returns one ``(results, finish)`` pair per request, where ``finish`` is
    sliced back to the request's own ``num_sources``.
    """
    out = [None] * len(requests)
    if not batch:
        return [dram_timing_single(r) for r in requests]
    groups: "dict[tuple, list[int]]" = {}
    for i, r in enumerate(requests):
        # Group by model AND estimated padded row length: co-dispatching a
        # tiny miss trace with a huge one would pad the tiny one's
        # (segment, channel) rows to the huge one's chunk count. The estimate
        # only shapes the grouping — results are exact for any grouping.
        n_req = np.asarray(r.lines).size
        est_row = max(1, n_req // max(1, r.num_segments * r.model.channels
                                      * max(1, min(r.model.lines_per_block, 8))))
        groups.setdefault((r.model, _chunk_bucket_len(est_row)), []).append(i)
    # Pipelined dispatch: start every group (host prep + async scan) before
    # finishing any, then drain singles, then extract. Each group's host
    # bookkeeping — and the singles — overlaps the earlier groups' device
    # scans (JAX dispatch is async); grouping never changes results, so the
    # pipelining is timing-only. On a single-CPU host there is nothing to
    # overlap with — the extra in-flight state just thrashes the one core —
    # so each group finishes before the next starts.
    pipelined = (os.cpu_count() or 1) > 1
    singles: "list[int]" = []
    started = []
    for (model, _), idxs in groups.items():
        if len(idxs) == 1:
            singles.append(idxs[0])
            continue
        reqs = [requests[i] for i in idxs]
        with stage("dram"):
            offsets = np.cumsum([0] + [r.num_segments for r in reqs])
            lines = np.concatenate([
                np.asarray(r.lines, dtype=np.int64).reshape(-1) for r in reqs
            ])
            seg = np.concatenate([
                np.asarray(r.seg, dtype=np.int64).reshape(-1) for r in reqs
            ])
            # One in-place remap pass instead of per-request temporaries.
            seg += np.repeat(
                offsets[:-1],
                [np.asarray(r.seg).size for r in reqs],
            )
            src = np.concatenate([
                np.asarray(r.src, dtype=np.int64).reshape(-1) for r in reqs
            ])
            num_sources = max(r.num_sources for r in reqs)
        st = _timing_contended_start(
            lines, seg, src, int(offsets[-1]), num_sources, model
        )
        if pipelined:
            started.append((idxs, reqs, offsets, st))
        else:
            started.append((idxs, reqs, offsets, _timing_contended_finish(st)))
    for i in singles:
        out[i] = dram_timing_single(requests[i])
    for idxs, reqs, offsets, st in started:
        results, finish = _timing_contended_finish(st) if pipelined else st
        for i, r, lo, hi in zip(idxs, reqs, offsets[:-1], offsets[1:]):
            out[i] = (results[lo:hi], finish[lo:hi, :r.num_sources].copy())
    return out


def bulk_transfer_cycles(data_bytes: float, hw: HardwareConfig) -> float:
    """Paper's analytical model for large tile transfers: T = D/B + L."""
    off = hw.offchip
    return data_bytes / off.bytes_per_cycle(hw.clock_ghz) + off.base_latency_cycles
