"""Analytic LRU stack-distance engine (Mattson classification, no scan).

LRU is a *stack algorithm*: at any point the cache set holds exactly the
``ways`` most recently used distinct lines mapping to it. An access therefore
hits a W-way LRU cache iff its **stack distance** — the number of distinct
same-set lines touched since the previous access to the same line — is
``< W``. One distance computation over a trace classifies the access for
EVERY associativity at once (Mattson's inclusion property), which is exactly
the amortization a DSE grid sweeping the ways axis wants: the distance pass
depends only on ``(stream, num_sets)``, never on ``ways``.

The pass itself is *analytic* — a handful of argsorts and prefix sums, no
sequential ``lax.scan`` over the trace:

  1. ``prev[i]``: previous access to the same line (one stable argsort by
     (line, time); shared across every geometry of a stream).
  2. ``win[i]``: same-set accesses strictly inside ``(prev[i], i)`` from the
     per-set access rank (one stable argsort by (set, time)).
  3. ``T[i] = #{k < i, same set : prev[k] > prev[i]}`` — the accesses inside
     the window whose own previous access is ALSO inside it (duplicates).
     Then ``distance = win - T``. ``T`` is a segmented per-element inversion
     count of the ``prev`` sequence, computed with a two-level radix
     decomposition over the *rank of last access* (the lexicographic
     (set, prev) rank): a cross-bucket histogram + suffix prefix-sum plus two
     small block-local masked compare-reductions — all O(N * block) work in
     fully vectorized form.

Evictions are analytic too: LRU never invalidates, so a miss evicts iff the
set already holds ``ways`` distinct lines, i.e. iff the number of distinct
same-set lines seen before the access is ``>= ways``.

Three executions of the same math, all bit-exact against ``GoldenCache``
(test-enforced):

  * ``stack_distances_np``   — numpy host twin; the CPU hot path (argsort on
    host is ~4x faster than XLA CPU sort) and the reference the others are
    tested against.
  * ``stack_distances_jnp``  — jitted jnp port, device-resident for TPU-side
    pipelines (padded to a bucketed length; num_sets is a traced scalar so
    one compilation serves every geometry of a length bucket).
  * ``kernels/stack_distance.py`` — Pallas kernel variant of the distance
    pass (``cache_backend="stack_pallas"``), VMEM-resident recency state.

``classify_lru_stack_many`` is the entry the cache engine routes
``cache_backend="stack"`` through: it memoizes distance passes by
``(stream, num_sets)`` within the call, so all same-``num_sets`` geometries
in a sweep grid classify from ONE shared distance computation.
"""
from __future__ import annotations

import functools
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..profiling import is_active as _profiling_active, stage

# Cold (first-ever) accesses get this sentinel distance: larger than any real
# associativity, so they miss for every ways value.
DIST_COLD = np.int32(2**30)

_BS = 128          # minimum radix block size for the inversion count (pow2)
_BIG_I32 = np.int32(np.iinfo(np.int32).max)


def _block_size(n: int) -> int:
    """Radix block size for an n-element inversion count.

    Grows as a power of two >= sqrt(n)/2 (floor ``_BS``) so the cross-bucket
    (chunk, bucket) histogram stays O(n) elements — with a FIXED block the
    table is O((n/bs)^2), which would make million-access traces allocate
    hundreds of MB. Block-local compare work is O(n * bs); at the default
    sweep scales (n ~ 5e4) this resolves to the measured-fastest bs=128.
    """
    b = _BS
    while b * b * 4 < n:
        b *= 2
    return b

# Distance passes actually computed (not served from a memo) — benchmarks and
# tests read this to verify cross-geometry sharing.
_distance_passes = 0


def distance_pass_count() -> int:
    return _distance_passes


# --------------------------------------------------------------------------
# numpy twin (CPU hot path + golden reference for the jnp/Pallas variants)
# --------------------------------------------------------------------------

def _inv_prev_larger_np(rk: np.ndarray, bs: Optional[int] = None) -> np.ndarray:
    """cnt[i] = #{k < i : rk[k] > rk[i]} for a permutation ``rk`` of [0, N).

    Two-level radix decomposition: bucket ranks into blocks of ``bs``; count
    cross-bucket pairs with a chunked histogram + suffix prefix sums, and
    same-bucket / same-chunk pairs with block-local masked compare-reductions
    (each O(N * bs) fully vectorized work; the histogram is O(N) elements by
    the ``_block_size`` scaling).
    """
    N = rk.size
    if N == 0:
        return np.zeros(0, dtype=np.int32)
    if bs is None:
        bs = _block_size(N)
    G = -(-N // bs)
    N_pad = G * bs
    # Padding ranks N..N_pad-1 sit at the END of the time axis: never
    # "previous" to a real element, so they contribute to no count.
    rk_p = np.concatenate([rk, np.arange(N, N_pad, dtype=np.int32)])
    g = rk_p >> int(np.log2(bs))

    # Same value-bucket, earlier time, larger rank.
    ordg = np.argsort(g, kind="stable")            # (bucket, time) order
    V = rk_p[ordg].reshape(G, bs)
    tri = np.arange(bs)[:, None] < np.arange(bs)[None, :]
    cnt = np.zeros(N_pad, dtype=np.int32)
    cnt[ordg] = _prev_larger_in_blocks_np(V, tri).reshape(-1)

    # Strictly higher bucket, earlier time: full earlier chunks via a
    # (chunk, bucket) histogram, the residual chunk via a local compare.
    NC = N_pad // bs
    rowflat = np.repeat(np.arange(NC, dtype=np.int64), bs) * G + g
    hist = np.bincount(rowflat, minlength=NC * G).reshape(NC, G)
    before = np.cumsum(hist, axis=0) - hist
    suf = before[:, ::-1].cumsum(axis=1)[:, ::-1] - before
    cnt += suf.reshape(-1)[rowflat].astype(np.int32)
    Gt = g.reshape(NC, bs)
    cnt += _prev_larger_in_blocks_np(Gt, tri).reshape(-1)
    return cnt[:N]


# Peak transient elements of one block-compare slab (16M bools = 16 MB):
# caps the (slab, bs, bs) boolean tensors regardless of trace length.
_SLAB_ELEMS = 1 << 24


def _prev_larger_in_blocks_np(V: np.ndarray, tri: np.ndarray) -> np.ndarray:
    """Per row of ``V``: count, for each position b, earlier positions a < b
    with V[a] > V[b] — processed in row slabs so the (slab, bs, bs) boolean
    intermediates stay bounded (identical results to one full broadcast)."""
    G, bs = V.shape
    out = np.empty((G, bs), dtype=np.int32)
    slab = max(1, _SLAB_ELEMS // (bs * bs))
    for lo in range(0, G, slab):
        W = V[lo:lo + slab]
        out[lo:lo + slab] = ((W[:, :, None] > W[:, None, :]) & tri).sum(
            axis=1, dtype=np.int32
        )
    return out


def stack_distances_np(
    lines: np.ndarray, num_sets: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Exact per-access LRU stack distance + distinct-lines-seen-before count.

    Returns ``(dist, distinct_before)``; cold accesses report ``DIST_COLD``.
    ``dist[i] < ways``  <=>  the access hits a (num_sets, ways) LRU cache.
    """
    lines = np.ascontiguousarray(lines).reshape(-1)
    N = lines.size
    if N == 0:
        z = np.zeros(0, dtype=np.int32)
        return z, z.copy()
    idx = np.arange(N, dtype=np.int32)
    set_idx = (lines % num_sets).astype(np.int32)

    order = np.argsort(lines, kind="stable")       # (line, time) order
    ls = lines[order]
    same = np.zeros(N, dtype=bool)
    same[1:] = ls[1:] == ls[:-1]
    tmp = np.full(N, -1, dtype=np.int32)
    tmp[1:][same[1:]] = order[:-1][same[1:]].astype(np.int32)
    prev = np.empty(N, dtype=np.int32)
    prev[order] = tmp

    order2 = np.argsort(set_idx, kind="stable")    # (set, time) order
    ss = set_idx[order2]
    start = np.ones(N, dtype=bool)
    start[1:] = ss[1:] != ss[:-1]
    grp = np.maximum.accumulate(np.where(start, idx, 0))
    r = np.empty(N, dtype=np.int32)
    r[order2] = idx - grp

    valid = prev >= 0
    win = np.where(valid, r - r[np.maximum(prev, 0)] - 1, 0)

    # Lexicographic (set, prev) rank — the "rank of last access" — via two
    # stable argsorts; counting inversions in the (set, time) layout keeps
    # smaller-set elements below the composite order (never counted) and
    # compares same-set elements on prev: one pass segments by set for free.
    o1 = np.argsort(prev, kind="stable")
    p = o1[np.argsort(set_idx[o1], kind="stable")]
    rk = np.empty(N, dtype=np.int32)
    rk[p] = idx
    T = np.empty(N, dtype=np.int32)
    T[order2] = _inv_prev_larger_np(rk[order2])
    dist = np.where(valid, (win - T).astype(np.int32), DIST_COLD)

    firsts = (~valid)[order2].astype(np.int32)
    cs = np.cumsum(firsts, dtype=np.int64)
    seg_base = np.maximum.accumulate(np.where(start, cs - firsts, 0))
    distinct_before = np.empty(N, dtype=np.int32)
    distinct_before[order2] = cs - firsts - seg_base
    return dist, distinct_before


# --------------------------------------------------------------------------
# jnp port (device-resident; numpy twin is the test-enforced golden)
# --------------------------------------------------------------------------

def _prev_larger_in_blocks_jnp(V: jax.Array, tri: jax.Array) -> jax.Array:
    """jnp twin of ``_prev_larger_in_blocks_np`` (same slab bound, so the
    (slab, bs, bs) boolean intermediates stay bounded under jit too)."""
    G, bs = V.shape
    slab = max(1, _SLAB_ELEMS // (bs * bs))
    if slab >= G:
        return jnp.sum((V[:, :, None] > V[:, None, :]) & tri, axis=1,
                       dtype=jnp.int32)
    parts = [
        jnp.sum((V[lo:lo + slab, :, None] > V[lo:lo + slab, None, :]) & tri,
                axis=1, dtype=jnp.int32)
        for lo in range(0, G, slab)
    ]
    return jnp.concatenate(parts, axis=0)


def _inv_prev_larger_jnp(rk: jax.Array, bs: int) -> jax.Array:
    N = rk.shape[0]
    G = N // bs
    g = rk // bs
    ordg = jnp.argsort(g)                          # stable: (bucket, time)
    V = rk[ordg].reshape(G, bs)
    tri = jnp.arange(bs)[:, None] < jnp.arange(bs)[None, :]
    cnt = jnp.zeros(N, dtype=jnp.int32).at[ordg].set(
        _prev_larger_in_blocks_jnp(V, tri).reshape(-1)
    )
    NC = N // bs
    rowflat = jnp.repeat(jnp.arange(NC, dtype=jnp.int32), bs) * G + g
    hist = jnp.zeros((NC * G,), dtype=jnp.int32).at[rowflat].add(1)
    hist = hist.reshape(NC, G)
    before = jnp.cumsum(hist, axis=0) - hist
    suf = jnp.cumsum(before[:, ::-1], axis=1)[:, ::-1] - before
    cnt = cnt + suf.reshape(-1)[rowflat]
    Gt = g.reshape(NC, bs)
    cnt = cnt + _prev_larger_in_blocks_jnp(Gt, tri).reshape(-1)
    return cnt


@functools.partial(jax.jit, static_argnames=("bs",))
def _stack_pass_jnp(lines: jax.Array, num_sets: jax.Array, n_real: jax.Array,
                    bs: int):
    """Padded device pass; ``num_sets``/``n_real`` are traced scalars so one
    compilation serves every geometry of a length bucket."""
    N = lines.shape[0]
    idx = jnp.arange(N, dtype=jnp.int32)
    real = idx < n_real
    set_idx = jnp.where(real, lines % num_sets, num_sets)

    order = jnp.argsort(jnp.where(real, lines, _BIG_I32))
    ls = lines[order]
    same = jnp.concatenate(
        [jnp.zeros(1, bool), (ls[1:] == ls[:-1]) & real[order][1:]]
    )
    prev = jnp.full(N, -1, dtype=jnp.int32).at[order].set(
        jnp.where(
            same, jnp.concatenate([jnp.zeros(1, jnp.int32), order[:-1]]), -1
        )
    )

    order2 = jnp.argsort(set_idx)                  # stable: (set, time)
    ss = set_idx[order2]
    start = jnp.concatenate([jnp.ones(1, bool), ss[1:] != ss[:-1]])
    grp = jax.lax.cummax(jnp.where(start, idx, 0))
    r = jnp.empty(N, dtype=jnp.int32).at[order2].set(idx - grp)

    valid = prev >= 0
    win = jnp.where(valid, r - r[jnp.maximum(prev, 0)] - 1, 0)

    o1 = jnp.argsort(prev)
    p = o1[jnp.argsort(set_idx[o1])]
    rk = jnp.empty(N, dtype=jnp.int32).at[p].set(idx)
    T = jnp.empty(N, dtype=jnp.int32).at[order2].set(
        _inv_prev_larger_jnp(rk[order2], bs)
    )
    dist = jnp.where(valid, win - T, jnp.int32(DIST_COLD))

    firsts = (~valid & real)[order2].astype(jnp.int32)
    cs = jnp.cumsum(firsts)
    seg_base = jax.lax.cummax(jnp.where(start, cs - firsts, 0))
    distinct_before = jnp.empty(N, dtype=jnp.int32).at[order2].set(
        cs - firsts - seg_base
    )
    return dist, distinct_before


def _pad_len(n: int) -> int:
    """Power-of-two length bucketing (compiled-shape reuse, as in cache.py)."""
    b = _BS
    while b < n:
        b *= 2
    return b


def stack_distances_jnp(
    lines: np.ndarray, num_sets: int
) -> Tuple[np.ndarray, np.ndarray]:
    """Device-resident ``stack_distances_np`` (equality test-enforced)."""
    lines = np.ascontiguousarray(lines).reshape(-1)
    n = lines.size
    if n == 0:
        z = np.zeros(0, dtype=np.int32)
        return z, z.copy()
    if lines.dtype != np.int32 and int(lines.max()) >= int(_BIG_I32):
        # The device pass is int32 (no x64); silently wrapping here would
        # diverge from the int64-capable numpy twin.
        raise ValueError("line numbers exceed int32 range; rebase the trace")
    N = _pad_len(n)
    lp = np.zeros(N, dtype=np.int32)
    lp[:n] = lines
    d, db = _stack_pass_jnp(
        jnp.asarray(lp), jnp.int32(num_sets), jnp.int32(n), _block_size(N)
    )
    if _profiling_active():
        jax.block_until_ready((d, db))
    with stage("host_sync"):
        return np.asarray(d)[:n], np.asarray(db)[:n]


# --------------------------------------------------------------------------
# Classification entry point (what cache_backend="stack" routes through)
# --------------------------------------------------------------------------

def _default_engine() -> str:
    # Host argsort beats XLA CPU sort ~4x; on TPU the jnp pass stays device-
    # resident. Same results either way (equality test-enforced).
    return "jnp" if jax.default_backend() == "tpu" else "np"


def stack_distances(
    lines: np.ndarray, num_sets: int, engine: Optional[str] = None
) -> Tuple[np.ndarray, np.ndarray]:
    global _distance_passes
    _distance_passes += 1
    engine = engine or _default_engine()
    if engine == "np":
        return stack_distances_np(lines, num_sets)
    if engine == "jnp":
        return stack_distances_jnp(lines, num_sets)
    raise ValueError(f"unknown stack engine {engine!r}; options: np, jnp")


def classify_lru_stack_many(
    streams: Sequence[np.ndarray],
    geometries: Sequence,                      # Sequence[CacheGeometry]
    engine: Optional[str] = None,
) -> List[Tuple[np.ndarray, int]]:
    """Per-access LRU hits + eviction count for several (trace, geometry)
    pairs from shared stack-distance passes.

    The distance pass depends only on ``(stream, num_sets)`` — every ways
    value (and every geometry that degenerates to the same num_sets) of a
    sweep grid classifies from one memoized computation. Bit-exact with the
    scan engine / ``GoldenCache`` (test-enforced).
    """
    # Memoize by the stream's underlying buffer (the sweep hands views of the
    # SAME array to every geometry of a memo group) + num_sets; ``streams``
    # keeps the keyed arrays alive for the whole call, so pointers are stable.
    as_i32: Dict[tuple, np.ndarray] = {}
    memo: Dict[Tuple[tuple, int], Tuple[np.ndarray, np.ndarray]] = {}
    out: List[Tuple[np.ndarray, int]] = []
    for stream, geom in zip(streams, geometries):
        arr = np.asarray(stream)
        # Strides are part of the key: two views can share (pointer, size,
        # dtype) yet read different elements (e.g. a[:500] vs a[::2]).
        sid = (arr.__array_interface__["data"][0], arr.shape, arr.dtype.str,
               arr.strides)
        lines32 = as_i32.get(sid)
        if lines32 is None:
            lines64 = np.asarray(arr, dtype=np.int64).reshape(-1)
            if lines64.size and int(lines64.max()) >= int(_BIG_I32):
                raise ValueError(
                    "line numbers exceed int32 range; rebase the trace"
                )
            lines32 = lines64.astype(np.int32)
            as_i32[sid] = lines32
        key = (sid, geom.num_sets)
        dist_pass = memo.get(key)
        if dist_pass is None:
            with stage("stack_distance"):
                dist_pass = stack_distances(lines32, geom.num_sets, engine)
            memo[key] = dist_pass
        dist, distinct_before = dist_pass
        hits = dist < np.int32(min(geom.ways, int(DIST_COLD) - 1))
        evictions = int(((~hits) & (distinct_before >= geom.ways)).sum())
        out.append((hits, evictions))
    return out
