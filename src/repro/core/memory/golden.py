"""Sequential golden cache model with ChampSim replacement semantics.

This is the validation oracle for ``cache.py`` (paper Fig. 4a compares EONSim
against ChampSim and reports identical hit/miss counts; our JAX engine must be
bit-exact against this model). Deliberately written as a straightforward
per-access loop — a different *shape* of implementation from the lax.scan
engine, so agreement is meaningful.

ChampSim semantics implemented (champsim/replacement/{lru,srrip}):
  * victim search prefers the first invalid way;
  * lru:   hit -> promote to MRU; victim = LRU way.
  * srrip: rrpv init maxRRPV (3); hit -> rrpv=0; victim = first way with
           rrpv==maxRRPV, incrementing all ways' rrpv until one qualifies
           (increments persist); fill -> rrpv=maxRRPV-1.
  * fifo:  victim = oldest fill; hits don't update state.
"""
from __future__ import annotations

from typing import List

import numpy as np

from .cache import MAX_RRPV, CacheGeometry


class GoldenCache:
    def __init__(self, geometry: CacheGeometry, policy: str = "lru"):
        self.g = geometry
        self.policy = policy
        S, W = geometry.num_sets, geometry.ways
        self.tags = [[-1] * W for _ in range(S)]
        if policy == "srrip":
            self.meta = [[MAX_RRPV] * W for _ in range(S)]
        else:
            self.meta = [[-1] * W for _ in range(S)]
        self.t = 0
        self.num_hits = 0
        self.num_misses = 0
        self.num_evictions = 0

    def _find_victim(self, s: int) -> int:
        tags, meta = self.tags[s], self.meta[s]
        for w, tag in enumerate(tags):
            if tag < 0 and self.policy != "srrip":
                return w
        if self.policy == "srrip":
            # invalid lines sit at maxRRPV already (init value)
            while True:
                for w in range(self.g.ways):
                    if meta[w] == MAX_RRPV:
                        return w
                for w in range(self.g.ways):
                    meta[w] += 1
        # lru / fifo: min timestamp (invalid handled above)
        best_w, best_t = 0, None
        for w in range(self.g.ways):
            if best_t is None or meta[w] < best_t:
                best_w, best_t = w, meta[w]
        return best_w

    def access(self, line: int) -> bool:
        s = int(line % self.g.num_sets)
        tags, meta = self.tags[s], self.meta[s]
        hit_way = -1
        for w in range(self.g.ways):
            if tags[w] == line:
                hit_way = w
                break
        if hit_way >= 0:
            self.num_hits += 1
            if self.policy == "lru":
                meta[hit_way] = self.t
            elif self.policy == "srrip":
                meta[hit_way] = 0
            self.t += 1
            return True

        self.num_misses += 1
        victim = self._find_victim(s)
        if tags[victim] >= 0:
            self.num_evictions += 1
        tags[victim] = line
        if self.policy == "srrip":
            meta[victim] = MAX_RRPV - 1
        else:
            meta[victim] = self.t
        self.t += 1
        return False

    def run(self, lines: np.ndarray) -> np.ndarray:
        return np.array([self.access(int(l)) for l in lines], dtype=bool)
