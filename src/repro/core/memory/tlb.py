"""NeuMMU-style address-translation engine (TLB hierarchy + page walks).

Embedding gathers are the pathological case for NPU address translation
(PAPERS.md, arXiv:1911.06859 "NeuMMU"): irregular, data-dependent accesses
whose page working set routinely exceeds any affordable TLB reach. This
module models a central MMU at the memory-controller side of the hierarchy:
the *off-chip miss stream* — every line the on-chip policy could not serve —
is translated virtual->physical through a set-associative L1 TLB, optionally
backed by a unified L2 TLB; L1 misses pay the L2 lookup latency, L2 misses
pay a full page-table walk. On-chip hits never translate (the on-chip memory
is virtually indexed at the simulator's level of abstraction), which is what
lets translation sit *between* row classification and DRAM request
construction as a pure trace transform in the ``trace.PlacementMap`` mold:

  * it observes the VIRTUAL miss-line stream, before ``PlacementMap``
    relocates lines — translation is therefore placement-invariant, and one
    charge is shared across every placement sibling of a sweep memo group;
  * it never adds, drops, or reorders DRAM requests — it only charges stall
    cycles alongside them — so every cache backend, placement policy,
    cluster topology, and the serving scheduler compose with it untouched;
  * ``translation=None`` skips this module entirely and is the exact
    pre-translation engine (differential-enforced).

Classification reuses the analytic cache machinery: LRU TLBs classify
through shared Mattson stack-distance passes (``memory/stack.py``, numpy
golden + jnp engine, one pass per (page stream, num_sets) covers every
associativity), FIFO TLBs through the compressed per-set engine
(``memory/rrip.py``). ``golden_tlb_hits`` is the sequential reference both
are test-pinned against (ChampSim-matching replacement semantics, the same
bar the on-chip cache engine meets).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

import numpy as np

from ..hardware import TranslationConfig
from ..profiling import stage
from .stack import DIST_COLD, stack_distances

__all__ = [
    "TranslationCharge",
    "charge_translation",
    "classify_tlb",
    "golden_tlb_hits",
    "tlb_pages",
    "translation_saturated",
]

_BIG_I32 = np.int32(np.iinfo(np.int32).max)


def tlb_pages(
    lines: np.ndarray, line_bytes: int, page_bytes: int
) -> np.ndarray:
    """int64 page number per line access (the TLB's reference stream).

    A line's translation is keyed by its base address's page; ``page_bytes``
    must cover a whole line so each line access is exactly one translation
    (validated here rather than in ``TranslationConfig`` because
    ``line_bytes`` is an on-chip parameter the config cannot see).
    """
    if page_bytes < line_bytes:
        raise ValueError(
            f"page_bytes ({page_bytes}) must be >= the on-chip line size "
            f"({line_bytes}): a line must not span pages")
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    if line_bytes and page_bytes % line_bytes == 0:
        return lines // (page_bytes // line_bytes)
    return (lines * line_bytes) // page_bytes


def golden_tlb_hits(
    pages: np.ndarray, num_sets: int, ways: int, replacement: str = "lru"
) -> np.ndarray:
    """Sequential set-associative TLB reference — bool (N,) hit per access.

    Replacement semantics match the cache engine's golden model (ChampSim):
    victim = first invalid way, else least-recently-used (``lru``) / oldest
    fill (``fifo``). The analytic ``classify_tlb`` is test-pinned to this.
    """
    pages = np.asarray(pages, dtype=np.int64).reshape(-1)
    tags = [[None] * ways for _ in range(num_sets)]
    meta = [[-1] * ways for _ in range(num_sets)]   # last-use / fill time
    hits = np.zeros(pages.size, dtype=bool)
    for t, p in enumerate(pages):
        s = int(p) % num_sets
        tag = int(p) // num_sets
        row_t, row_m = tags[s], meta[s]
        if tag in row_t:
            w = row_t.index(tag)
            hits[t] = True
            if replacement == "lru":
                row_m[w] = t
            continue
        if None in row_t:
            w = row_t.index(None)
        else:
            w = int(np.argmin(row_m))                # LRU way / oldest fill
        row_t[w] = tag
        row_m[w] = t
    return hits


def classify_tlb(
    pages: np.ndarray,
    num_sets: int,
    ways: int,
    replacement: str = "lru",
    engine: Optional[str] = None,
) -> np.ndarray:
    """Analytic per-access TLB hits — bool (N,).

    LRU runs on the stack-distance engine (``engine`` selects the numpy
    golden or the jnp port, default auto like the on-chip path); FIFO on
    the compressed per-set engine. Both are bit-exact with
    ``golden_tlb_hits`` (test-enforced).
    """
    pages = np.asarray(pages, dtype=np.int64).reshape(-1)
    if pages.size == 0:
        return np.zeros(0, dtype=bool)
    if int(pages.max()) >= int(_BIG_I32):
        raise ValueError("page numbers exceed int32 range; rebase the trace")
    if replacement == "lru":
        dist, _ = stack_distances(
            pages.astype(np.int32), int(num_sets), engine
        )
        return dist < np.int32(min(int(ways), int(DIST_COLD) - 1))
    if replacement == "fifo":
        from .rrip import classify_fifo_many

        hits, _ = classify_fifo_many([pages], [(int(num_sets), int(ways))])[0]
        return hits
    raise ValueError(
        f"unknown TLB replacement {replacement!r}; options: lru, fifo")


@dataclass(frozen=True)
class TranslationCharge:
    """Per-batch translation outcome for one classified miss stream.

    Arrays are indexed by batch. ``hits`` are L1 TLB hits (free — the
    lookup pipelines under the DRAM access), ``misses`` are L1 misses
    (each pays the L2 lookup when an L2 exists), ``walks`` are full
    page-table walks (L2 misses, or every L1 miss without an L2), and
    ``cycles`` is the total stall the memory system adds to the batch's
    DRAM path: ``misses * l2_latency + walks * walk_latency``.
    """

    hits: np.ndarray      # int64 (B,)
    misses: np.ndarray    # int64 (B,)
    walks: np.ndarray     # int64 (B,)
    cycles: np.ndarray    # float64 (B,)


def charge_translation(
    miss_lines: np.ndarray,
    miss_batch: np.ndarray,
    num_batches: int,
    line_bytes: int,
    cfg: TranslationConfig,
    engine: Optional[str] = None,
) -> TranslationCharge:
    """Translate one miss-line stream through the TLB hierarchy.

    ``miss_lines``/``miss_batch`` are the classified off-chip stream in
    trace order (the exact arrays the DRAM request is built from — virtual,
    pre-``PlacementMap``). The L2 TLB, when configured, observes the
    subsequence of L1 misses, exactly like a hardware second-level TLB.
    """
    with stage("translate"):
        pages = tlb_pages(miss_lines, line_bytes, cfg.page_bytes)
        l1_hits = classify_tlb(
            pages, cfg.num_sets, cfg.ways, cfg.replacement, engine
        )
        miss_batch = np.asarray(miss_batch, dtype=np.int64).reshape(-1)
        nb = int(num_batches)
        hits = np.bincount(miss_batch[l1_hits], minlength=nb)
        misses = np.bincount(miss_batch[~l1_hits], minlength=nb)
        if cfg.l2_entries:
            l2_sub = ~l1_hits
            l2_hits = classify_tlb(
                pages[l2_sub], cfg.l2_num_sets, cfg.l2_ways,
                cfg.replacement, engine,
            )
            walk_mask = np.zeros(pages.size, dtype=bool)
            walk_mask[np.flatnonzero(l2_sub)[~l2_hits]] = True
            walks = np.bincount(miss_batch[walk_mask], minlength=nb)
            l2_lat = float(cfg.l2_latency_cycles)
        else:
            walks = misses
            l2_lat = 0.0
        cycles = (misses * l2_lat
                  + walks * float(cfg.walk_latency_cycles)).astype(np.float64)
        return TranslationCharge(
            hits=hits.astype(np.int64),
            misses=misses.astype(np.int64),
            walks=walks.astype(np.int64),
            cycles=cycles,
        )


def translation_saturated(
    unique_pages: np.ndarray, cfg: TranslationConfig
) -> bool:
    """True when the L1 TLB provably never takes a non-compulsory miss.

    Exact condition: no L1 set is ever offered more distinct pages than it
    has ways. Then — for LRU and FIFO alike, since both insert only on miss
    and evict only when the set is full — no entry is ever evicted, so every
    non-first access hits, for ANY subsequence of the trace's accesses.
    Every saturated config's outcome collapses to first-touch-only walks:
    hits/misses/walks depend only on ``page_bytes`` and the charged cycles
    only on ``miss_latency_cycles`` (an L1-cold translation is L2-cold too,
    because the L2 observes only L1 misses), which is what lets the sweep
    canonicalize all such configs onto one memo key — the TLB analogue of
    on-chip capacity saturation.
    """
    up = np.asarray(unique_pages, dtype=np.int64).reshape(-1)
    if up.size == 0:
        return True
    per_set = np.bincount(up % int(cfg.num_sets))
    return int(per_set.max()) <= int(cfg.ways)


def charge_cache_lookup(
    cache: Dict[tuple, TranslationCharge],
    miss_lines: np.ndarray,
    miss_batch: np.ndarray,
    num_batches: int,
    line_bytes: int,
    cfg: TranslationConfig,
    engine: Optional[str] = None,
) -> TranslationCharge:
    """Memoized ``charge_translation`` — keyed by the config's canonical
    tuple, stored on the classified stream so placement/topology siblings
    of a sweep memo group (which share the classified stream, and whose
    translation outcome is identical by placement-invariance) compute each
    TLB configuration once."""
    charge = cache.get(cfg.key)
    if charge is None:
        charge = cache[cfg.key] = charge_translation(
            miss_lines, miss_batch, num_batches, line_bytes, cfg, engine
        )
    return charge
