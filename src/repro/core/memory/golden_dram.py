"""Sequential golden DRAM model — the TPUv6e-proxy reference for Fig. 3.

The paper validates EONSim's timing against real TPUv6e runs. Offline, the
strongest available analogue is an independently-written reference
implementation of the same documented service discipline:

  * block-granular channel interleave (decompose as in DramModel),
  * FR-FCFS-like scheduling: banks served round-robin at block granularity,
    per-bank request order preserved, a block's lines streamed consecutively,
  * bank occupancy = tRP+tRCD per activate (row miss), bursts at bus rate,
  * channel bus serializes bursts; CAS latency pipelines onto completion.

This module is a deliberate straight-line Python transcription of that spec
(dict/list bookkeeping, explicit queues) — structurally unlike the vmapped
``lax.scan`` engine — so agreement between the two is meaningful. The Fig. 3
benchmarks report the EONSim-vs-reference execution-time error, mirroring the
paper's sim-vs-hardware metric.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List

import numpy as np

from .dram import DramModel, DramResult


def golden_dram(lines: np.ndarray, model: DramModel) -> DramResult:
    lines = np.asarray(lines, dtype=np.int64).reshape(-1)
    n = lines.size
    if n == 0:
        return DramResult(0.0, 0.0, 0, 0, 0)

    ch_a, bk_a, row_a = model.decompose(lines)
    blk_a = lines // model.lines_per_block

    bus_cyc = model.line_bytes / model.chan_bytes_per_cycle
    act = model.t_rp + model.t_rcd

    finish = 0.0
    total_lat = 0.0
    row_hits = 0

    for c in range(model.channels):
        idx = np.nonzero(ch_a == c)[0]
        if idx.size == 0:
            continue
        # build per-bank queues of blocks; each block is a list of accesses
        bank_blocks: List[List[List[int]]] = [[] for _ in range(model.banks_per_channel)]
        for i in idx:
            b = int(bk_a[i])
            q = bank_blocks[b]
            if q and blk_a[q[-1][-1]] == blk_a[i]:
                q[-1].append(int(i))
            else:
                q.append([int(i)])

        open_row = [-1] * model.banks_per_channel
        bank_free = [0.0] * model.banks_per_channel
        bus_free = 0.0
        ptr = [0] * model.banks_per_channel
        remaining = sum(len(q) for q in bank_blocks)
        b = 0
        while remaining:
            # round-robin: next bank with a pending block
            while ptr[b] >= len(bank_blocks[b]):
                b = (b + 1) % model.banks_per_channel
            block = bank_blocks[b][ptr[b]]
            ptr[b] += 1
            remaining -= 1
            for i in block:
                r = int(row_a[i])
                hit = open_row[b] == r
                occ = 0.0 if hit else act
                bank_avail = bank_free[b] + occ
                start_xfer = max(bank_avail, bus_free)
                done = start_xfer + bus_cyc
                open_row[b] = r
                bank_free[b] = done
                bus_free = done
                total_lat += done + model.t_cas
                row_hits += int(hit)
                finish = max(finish, done + model.t_cas)
            b = (b + 1) % model.banks_per_channel

    return DramResult(
        finish_cycle=finish + model.base_latency,
        total_latency_cycles=total_lat + model.base_latency * n,
        row_hits=row_hits,
        row_misses=n - row_hits,
        accesses=n,
    )
