"""Workload descriptions for EONSim.

The paper's "workload configuration" input (Sec. III):
  * matrix operations in generalized MNK format (M x K input @ N x K weight)
  * embedding vector operations: vector dim, #tables, rows/table, pooling
    factor, vector op (sum/mean/concat), batching hyper-parameters.
"""
from __future__ import annotations

import enum
import math
from dataclasses import dataclass, field
from typing import Sequence


class VectorOp(str, enum.Enum):
    SUM = "sum"          # embedding bag sum-pooling (DLRM)
    MEAN = "mean"
    CONCAT = "concat"    # no reduction (pure gather, e.g. LM token embedding)
    DOT = "dot"          # similarity scoring (RAG retrieval)


@dataclass(frozen=True)
class MatrixOpSpec:
    """One GEMM in MNK form: (M x K) @ (K x N) -> (M x N)."""

    m: int
    n: int
    k: int
    name: str = "gemm"
    dtype_bytes: int = 2     # bf16 weights/activations by default
    count: int = 1           # repeated instances (e.g. per-layer)

    @property
    def flops(self) -> int:
        return 2 * self.m * self.n * self.k * self.count

    @property
    def input_bytes(self) -> int:
        return self.m * self.k * self.dtype_bytes

    @property
    def weight_bytes(self) -> int:
        return self.k * self.n * self.dtype_bytes

    @property
    def output_bytes(self) -> int:
        return self.m * self.n * self.dtype_bytes


@dataclass(frozen=True)
class EmbeddingOpSpec:
    """One embedding vector operation (paper Fig. 1).

    ``lookups_per_sample`` is the pooling factor: indices gathered per sample
    per table, reduced with ``vector_op``.
    """

    num_tables: int
    rows_per_table: int
    dim: int
    lookups_per_sample: int
    vector_op: VectorOp = VectorOp.SUM
    dtype_bytes: int = 4     # DLRM uses fp32 embedding vectors
    name: str = "embedding"

    @property
    def vector_bytes(self) -> int:
        return self.dim * self.dtype_bytes

    @property
    def table_bytes(self) -> int:
        return self.rows_per_table * self.vector_bytes

    @property
    def total_bytes(self) -> int:
        return self.num_tables * self.table_bytes

    def lookups_per_batch(self, batch_size: int) -> int:
        return batch_size * self.num_tables * self.lookups_per_sample

    def gathered_bytes(self, batch_size: int) -> int:
        return self.lookups_per_batch(batch_size) * self.vector_bytes

    def reduction_flops(self, batch_size: int) -> int:
        """Vector-wise arithmetic after the gather (stage 3 of Fig. 1)."""
        if self.vector_op in (VectorOp.SUM, VectorOp.MEAN):
            per_bag = (self.lookups_per_sample - 1) * self.dim
            return batch_size * self.num_tables * max(per_bag, 0)
        if self.vector_op == VectorOp.DOT:
            return batch_size * self.num_tables * self.lookups_per_sample * 2 * self.dim
        return 0


@dataclass(frozen=True)
class Workload:
    """A full inference/training step: matrix ops + embedding ops + batching."""

    name: str
    matrix_ops: Sequence[MatrixOpSpec] = ()
    embedding_ops: Sequence[EmbeddingOpSpec] = ()
    batch_size: int = 32
    num_batches: int = 1

    @property
    def matrix_flops(self) -> int:
        return sum(op.flops for op in self.matrix_ops)


def dlrm_rmc2_small(
    num_tables: int = 60,
    rows_per_table: int = 1_000_000,
    dim: int = 128,
    lookups: int = 120,
    batch_size: int = 32,
    num_batches: int = 1,
) -> Workload:
    """Paper Table I: DLRM-RMC2-small.

    60 embedding tables, 1M rows/table, 128-dim vectors, 120 lookups/table,
    bottom MLP 256-128-128, top MLP 128-64-1.
    """
    bottom_dims = [256, 128, 128]
    top_dims = [128, 64, 1]

    def mlp_ops(dims, in_dim, prefix):
        ops = []
        d = in_dim
        for i, out in enumerate(dims):
            ops.append(
                MatrixOpSpec(m=batch_size, n=out, k=d, name=f"{prefix}{i}", dtype_bytes=4)
            )
            d = out
        return ops

    # Dense features: 13 continuous inputs -> bottom MLP; interaction output
    # feeds the top MLP (dot-interaction of #tables+1 vectors of dim 128).
    n_vec = num_tables + 1
    interact_dim = n_vec * (n_vec - 1) // 2 + dim
    matrix_ops = (
        mlp_ops(bottom_dims, 13, "bottom_mlp")
        + [
            MatrixOpSpec(
                m=batch_size * n_vec, n=n_vec, k=dim, name="interaction", dtype_bytes=4
            )
        ]
        + mlp_ops(top_dims, interact_dim, "top_mlp")
    )
    embedding = EmbeddingOpSpec(
        num_tables=num_tables,
        rows_per_table=rows_per_table,
        dim=dim,
        lookups_per_sample=lookups,
        vector_op=VectorOp.SUM,
        dtype_bytes=4,
        name="dlrm_embedding",
    )
    return Workload(
        name=f"dlrm_rmc2_small_t{num_tables}_b{batch_size}",
        matrix_ops=tuple(matrix_ops),
        embedding_ops=(embedding,),
        batch_size=batch_size,
        num_batches=num_batches,
    )
