"""Hardware configuration for EONSim.

Mirrors the paper's three input categories (Sec. III, "Simulation input"):
  * accelerator-level parameters  (clock, #cores, memory hierarchy)
  * core settings                 (vector / matrix units)
  * memory system parameters      (capacity, latency, bandwidth, granularity)

All timing inside the simulator is in *core cycles*; helpers convert to
seconds through ``clock_ghz``.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from dataclasses import dataclass, field


class OnChipPolicy(str, enum.Enum):
    """On-chip memory management policy (paper Sec. III / IV)."""

    SPM = "spm"            # scratchpad staging, double-buffered (TPU baseline)
    LRU = "lru"            # cache mode, LRU replacement
    SRRIP = "srrip"        # cache mode, SRRIP replacement (MTIA LLC-like)
    FIFO = "fifo"          # cache mode, FIFO replacement
    PINNING = "pinning"    # "Profiling": pin hottest vectors up to capacity


class Dataflow(str, enum.Enum):
    WS = "ws"              # weight stationary
    OS = "os"              # output stationary


class Topology(str, enum.Enum):
    """Multi-core on-chip memory topology.

    PRIVATE — each core owns an ``OnChipMemory`` of the configured size and
    classifies only its own lookup shard (ONNXim-style per-core scratchpad).
    SHARED  — one last-level on-chip memory of the configured size serves the
    interleaved lookup stream of every core (MTIA LLC-like).
    """

    PRIVATE = "private"
    SHARED = "shared"


class LookupSharding(str, enum.Enum):
    """How embedding lookups are distributed across cores (trace.py)."""

    BATCH = "batch"            # round-robin over batch samples (data parallel)
    TABLE_HASH = "table_hash"  # hash table_id -> core (model parallel)


# DRAM channel-affinity modes (NUMA-style routing of embedding miss traffic):
#   "symmetric" — every request may use every channel (classic interleaved
#                 DRAM; the default and the historical engine behaviour).
#   "per_core"  — channels partition into ``num_cores`` strided groups and
#                 core c's requests route ONLY to group c's channels (private
#                 memory channels per core, ONNXim/TensorDIMM-style NUMA).
#                 Routing is by REQUESTER: a row touched by two cores is
#                 homed in both cores' groups, i.e. the model assumes
#                 per-core-private replicas of shared data (free of storage/
#                 coherence cost). Pair it with table_hash sharding, where
#                 requester == owner and nothing is shared; for a single-copy
#                 home under batch sharding use "per_table" instead.
#   "per_table" — requests route to the channel group owned by their TABLE
#                 (hash(table_id) -> group, the same hash as table_hash
#                 lookup sharding), regardless of the issuing core — the
#                 single-copy data-home placement.
# Affinity changes WHERE miss traffic lands, never how much of it there is —
# classification is upstream and untouched. The degenerate "symmetric" mode
# is bitwise identical to the pre-placement engine (test-enforced).
CHANNEL_AFFINITIES = ("symmetric", "per_core", "per_table")

# Embedding-row placement within the affine channel group:
#   "interleave"    — block-granular striping across the group's channels
#                     (the classic layout; identity under "symmetric").
#   "table_rank"    — TensorDIMM-style per-rank table placement: each table
#                     is homed to ONE rank (modelled as a bank index) of its
#                     group's channels; its blocks stripe across the group's
#                     channels but stay within that rank, maximizing per-table
#                     row-buffer locality and isolating tables from each
#                     other's row conflicts.
#   "hot_replicate" — "table_rank" for cold rows + the hottest vectors
#                     replicated across every (channel, rank) of the group so
#                     hot traffic stripes at full width (TensorDIMM's hot-
#                     embedding replication); the hot set is profiled from
#                     the trace deterministically.
PLACEMENTS = ("interleave", "table_rank", "hot_replicate")


# Cache-engine backends for the simulator's set-associative classification
# (memory/cache.py):
#   "scan"         — vmapped lax.scan engine (the sequential reference).
#   "pallas"       — VMEM-resident Pallas scan kernel (kernels/cache_scan.py;
#                    interpret mode off-TPU).
#   "stack"        — analytic engines (the default, fastest for DSE sweeps):
#                    LRU via the stack-distance engine (memory/stack.py; one
#                    sort-based distance pass per (stream, num_sets)
#                    classifies EVERY associativity), srrip/fifo via the
#                    compressed per-set engines (memory/rrip.py; shared
#                    presort per (stream, num_sets), short batched per-set
#                    scans). No policy runs a full-trace sequential scan.
#   "stack_pallas" — like "stack", but the LRU distance pass runs the Pallas
#                    kernel (kernels/stack_distance.py), VMEM recency state;
#                    identical to "stack" for srrip/fifo.
# Every backend is bit-exact against the golden model — the knob trades
# execution strategy, never results.
CACHE_BACKENDS = ("scan", "pallas", "stack", "stack_pallas")

# TLB replacement policies the analytic translation engine supports
# (memory/tlb.py): LRU via the stack-distance engine, FIFO via the
# compressed per-set engine — the same machinery as the on-chip cache.
TLB_REPLACEMENTS = ("lru", "fifo")


@dataclass(frozen=True)
class TranslationConfig:
    """NeuMMU-style address-translation stage (PAPERS.md, arXiv:1911.06859).

    Embedding gathers are the worst case for NPU address translation —
    irregular, data-dependent, TLB-hostile — so the simulator models a
    central MMU at the memory-controller side of the hierarchy: every
    off-chip miss line is translated through a set-associative L1 TLB
    (``entries`` x ``ways`` over ``page_bytes`` pages), optionally backed
    by a unified L2 TLB; L1 misses pay the L2 lookup, L2 misses pay a full
    ``walk_latency_cycles`` page-table walk. Translation is a *pure trace
    transform* between row classification and DRAM request construction
    (the ``trace.PlacementMap`` mold), so it composes untouched with every
    cache backend, placement policy, cluster topology, and the serving
    path. ``HardwareConfig.translation = None`` (the default) is the exact
    identity — differential-enforced, like every prior axis.

    Build through ``HardwareConfig.with_translation`` for the same
    validation-at-construction posture as the other axes.
    """

    entries: int = 64                 # L1 TLB entries
    ways: int = 4                     # L1 associativity
    page_bytes: int = 4096            # translation granularity
    walk_latency_cycles: int = 100    # full page-table walk (charged per walk)
    l2_entries: int = 0               # 0 = no L2 TLB
    l2_ways: int = 8
    l2_latency_cycles: int = 8        # L2 lookup, charged per L1 miss
    replacement: str = "lru"

    def __post_init__(self) -> None:
        if self.entries < 1:
            raise ValueError(f"TLB entries must be >= 1, got {self.entries}")
        if self.ways < 1:
            raise ValueError(f"TLB ways must be >= 1, got {self.ways}")
        if self.entries % self.ways:
            raise ValueError(
                f"TLB entries ({self.entries}) must be a multiple of "
                f"ways ({self.ways})")
        if self.page_bytes < 1 or (self.page_bytes & (self.page_bytes - 1)):
            raise ValueError(
                f"page_bytes must be a power of two, got {self.page_bytes}")
        if self.walk_latency_cycles < 0:
            raise ValueError("walk_latency_cycles must be >= 0")
        if self.l2_entries < 0:
            raise ValueError("l2_entries must be >= 0")
        if self.l2_entries:
            if self.l2_ways < 1:
                raise ValueError(f"l2_ways must be >= 1, got {self.l2_ways}")
            if self.l2_entries % self.l2_ways:
                raise ValueError(
                    f"l2_entries ({self.l2_entries}) must be a multiple of "
                    f"l2_ways ({self.l2_ways})")
        if self.l2_latency_cycles < 0:
            raise ValueError("l2_latency_cycles must be >= 0")
        if self.replacement not in TLB_REPLACEMENTS:
            raise ValueError(
                f"unknown TLB replacement {self.replacement!r}; "
                f"options: {TLB_REPLACEMENTS}")

    @property
    def num_sets(self) -> int:
        return max(1, self.entries // self.ways)

    @property
    def l2_num_sets(self) -> int:
        return max(1, self.l2_entries // self.l2_ways) if self.l2_entries else 0

    @property
    def reach_bytes(self) -> int:
        """Address span one full L1 TLB maps (entries x page size)."""
        return self.entries * self.page_bytes

    @property
    def miss_latency_cycles(self) -> int:
        """Cycles an L1-missing, fully-cold translation costs (the L2
        lookup when an L2 exists, plus the page walk)."""
        return self.walk_latency_cycles + (
            self.l2_latency_cycles if self.l2_entries else 0)

    @property
    def key(self) -> tuple:
        """Canonical value tuple (sweep memo keys / checkpoint
        fingerprints); ``from_key`` inverts it."""
        return (
            int(self.entries), int(self.ways), int(self.page_bytes),
            int(self.walk_latency_cycles), int(self.l2_entries),
            int(self.l2_ways), int(self.l2_latency_cycles),
            str(self.replacement),
        )

    @classmethod
    def from_key(cls, key: tuple) -> "TranslationConfig":
        return cls(*key)


@dataclass(frozen=True)
class MatrixUnit:
    """Systolic array description (SCALE-Sim-compatible)."""

    rows: int = 256
    cols: int = 256
    dataflow: Dataflow = Dataflow.WS

    @property
    def macs(self) -> int:
        return self.rows * self.cols


@dataclass(frozen=True)
class VectorUnit:
    """TPU-style VPU: ``lanes`` ALUs x ``sublanes`` (8x128 on TPU)."""

    lanes: int = 128
    sublanes: int = 8
    ops_per_cycle_per_lane: int = 1

    @property
    def throughput(self) -> int:
        """Elementwise ops per cycle."""
        return self.lanes * self.sublanes * self.ops_per_cycle_per_lane


@dataclass(frozen=True)
class OnChipMemory:
    """Local (per-core) on-chip memory."""

    capacity_bytes: int = 128 * 1024 * 1024   # 128 MB (TPUv6e local buffer)
    line_bytes: int = 64                      # access granularity
    ways: int = 16                            # associativity in cache mode
    latency_cycles: int = 8
    # on-chip SRAM streams far faster than HBM (~7.7 TB/s at 0.94 GHz)
    read_bw_bytes_per_cycle: int = 8192
    write_bw_bytes_per_cycle: int = 8192
    policy: OnChipPolicy = OnChipPolicy.SPM
    # Per-table policy mix: ((table_id, policy_name), ...) pairs; tables not
    # listed fall back to ``policy``. Kept as a sorted tuple so the config
    # stays hashable (sweep memoization keys include it). Build through
    # ``HardwareConfig.with_policy_mix`` rather than by hand.
    policy_mix: "tuple[tuple[int, str], ...] | None" = None

    @property
    def num_lines(self) -> int:
        return self.capacity_bytes // self.line_bytes

    @property
    def num_sets(self) -> int:
        return max(1, self.num_lines // self.ways)


@dataclass(frozen=True)
class OffChipMemory:
    """Off-chip (HBM/DRAM) parameters — DRAMSim-lite inputs."""

    capacity_bytes: int = 32 * (1 << 30)      # 32 GB (TPUv6e)
    bandwidth_gbps: float = 1600.0            # GB/s aggregate
    channels: int = 16
    banks_per_channel: int = 8
    row_bytes: int = 2048                     # row-buffer size
    interleave_bytes: int = 512               # channel-interleave granularity
    t_cas_cycles: int = 22                    # row-hit latency (core cycles)
    t_rcd_cycles: int = 22
    t_rp_cycles: int = 22
    base_latency_cycles: int = 120            # controller + interconnect overhead

    def bytes_per_cycle(self, clock_ghz: float) -> float:
        return self.bandwidth_gbps / clock_ghz  # GB/s / Gcycle/s = B/cycle

    def channel_bytes_per_cycle(self, clock_ghz: float) -> float:
        return self.bytes_per_cycle(clock_ghz) / self.channels


@dataclass(frozen=True)
class HardwareConfig:
    """Full accelerator description."""

    name: str = "tpuv6e"
    clock_ghz: float = 0.94                   # TPUv6e core clock ~940 MHz
    num_cores: int = 1
    topology: Topology = Topology.PRIVATE
    lookup_sharding: LookupSharding = LookupSharding.BATCH
    matrix_unit: MatrixUnit = field(default_factory=MatrixUnit)
    vector_unit: VectorUnit = field(default_factory=VectorUnit)
    # PRIVATE topology: ``onchip`` is each core's private memory.
    # SHARED topology: ``onchip`` is the one shared last-level memory.
    onchip: OnChipMemory = field(default_factory=OnChipMemory)
    offchip: OffChipMemory = field(default_factory=OffChipMemory)
    # NUMA placement axes (see CHANNEL_AFFINITIES / PLACEMENTS): how embedding
    # miss traffic is routed across DRAM channels and where rows are homed.
    # The defaults reproduce the historical symmetric interleaved engine
    # bitwise. Build through ``with_placement`` for validation.
    channel_affinity: str = "symmetric"
    placement: str = "interleave"
    # Simulator-engine knob (not a hardware parameter): which cache-engine
    # backend classifies set-associative accesses. See CACHE_BACKENDS. The
    # default "stack" classifies every policy analytically (stack-distance
    # passes for LRU, compressed per-set engines for srrip/fifo) — results
    # are bit-exact across all backends.
    cache_backend: str = "stack"
    # Address-translation stage between row classification and DRAM request
    # construction (see TranslationConfig). None — the default — skips
    # translation entirely and is bitwise identical to the pre-translation
    # engine (differential-enforced). Build through ``with_translation``.
    translation: "TranslationConfig | None" = None

    def cycles_to_seconds(self, cycles: float) -> float:
        return cycles / (self.clock_ghz * 1e9)

    def seconds_to_cycles(self, seconds: float) -> float:
        return seconds * self.clock_ghz * 1e9

    def replace(self, **kw) -> "HardwareConfig":
        return dataclasses.replace(self, **kw)

    def with_onchip(self, **onchip_kw) -> "HardwareConfig":
        """Replace on-chip memory parameters (capacity, ways, policy, ...).

        Unknown keys raise ``ValueError`` up front with the valid field list —
        cluster-level knobs (``num_cores``, ``topology``, ...) live on
        ``HardwareConfig`` itself, an easy mix-up once topology is in play.
        """
        valid = {f.name for f in dataclasses.fields(OnChipMemory)}
        unknown = set(onchip_kw) - valid
        if unknown:
            top_level = {f.name for f in dataclasses.fields(HardwareConfig)}
            hint = ""
            misplaced = sorted(unknown & top_level)
            if misplaced:
                hint = (
                    f"; {misplaced} are HardwareConfig fields — use"
                    " .replace()/.with_cluster() instead"
                )
            raise ValueError(
                f"unknown OnChipMemory parameter(s) {sorted(unknown)};"
                f" valid: {sorted(valid)}{hint}"
            )
        return dataclasses.replace(
            self, onchip=dataclasses.replace(self.onchip, **onchip_kw)
        )

    def with_policy(self, policy: OnChipPolicy, **onchip_kw) -> "HardwareConfig":
        return self.with_onchip(policy=OnChipPolicy(policy), **onchip_kw)

    def with_cluster(
        self,
        num_cores: int,
        topology: "Topology | str" = None,
        lookup_sharding: "LookupSharding | str" = None,
    ) -> "HardwareConfig":
        """Replace the core-cluster topology (count, on-chip sharing, sharding)."""
        if num_cores < 1:
            raise ValueError(f"num_cores must be >= 1, got {num_cores}")
        kw = {"num_cores": int(num_cores)}
        if topology is not None:
            kw["topology"] = Topology(topology)
        if lookup_sharding is not None:
            kw["lookup_sharding"] = LookupSharding(lookup_sharding)
        return dataclasses.replace(self, **kw)

    def with_placement(
        self,
        channel_affinity: "str | None" = None,
        placement: "str | None" = None,
    ) -> "HardwareConfig":
        """Select the DRAM channel-affinity and row-placement modes.

        ``channel_affinity`` routes requests to channel groups (see
        ``CHANNEL_AFFINITIES``); ``placement`` homes rows within the group
        (see ``PLACEMENTS``). ``per_core`` affinity requires ``channels`` to
        split evenly over ``num_cores`` — checked when the memory system is
        built, since the cluster shape may change after this call. The
        default ``symmetric``/``interleave`` pair is bitwise identical to the
        pre-placement engine (test-enforced).
        """
        kw = {}
        if channel_affinity is not None:
            if channel_affinity not in CHANNEL_AFFINITIES:
                raise ValueError(
                    f"unknown channel affinity {channel_affinity!r}; "
                    f"options: {CHANNEL_AFFINITIES}"
                )
            kw["channel_affinity"] = channel_affinity
        if placement is not None:
            if placement not in PLACEMENTS:
                raise ValueError(
                    f"unknown placement {placement!r}; options: {PLACEMENTS}"
                )
            kw["placement"] = placement
        return dataclasses.replace(self, **kw)

    def with_cache_backend(self, backend: str) -> "HardwareConfig":
        """Select the cache-engine backend (see ``CACHE_BACKENDS``).

        Results are bit-exact across backends (test-enforced); this only
        chooses how set-associative classification executes. The "stack"
        variants cover every policy analytically (stack distances for LRU,
        compressed per-set engines for srrip/fifo); "stack_pallas" differs
        from "stack" only in LRU's distance pass.
        """
        if backend not in CACHE_BACKENDS:
            raise ValueError(
                f"unknown cache backend {backend!r}; options: {CACHE_BACKENDS}"
            )
        return dataclasses.replace(self, cache_backend=backend)

    def with_translation(
        self, translation: "TranslationConfig | None" = None, **tlb_kw
    ) -> "HardwareConfig":
        """Attach (or clear) the address-translation stage.

        Either pass a ready ``TranslationConfig``, or keyword fields to
        build one (``with_translation(entries=128, page_bytes=4096)``);
        ``with_translation(None)`` with no keywords clears the stage back
        to the exact-identity default. Unknown keys raise with the valid
        field list, pointing misplaced ``HardwareConfig`` fields at the
        right builder — the ``with_onchip`` idiom.
        """
        if translation is not None and tlb_kw:
            raise ValueError(
                "pass either a TranslationConfig or keyword fields, not both")
        if translation is None and tlb_kw:
            valid = {f.name for f in dataclasses.fields(TranslationConfig)}
            unknown = set(tlb_kw) - valid
            if unknown:
                top_level = {f.name for f in dataclasses.fields(HardwareConfig)}
                hint = ""
                misplaced = sorted(unknown & top_level)
                if misplaced:
                    hint = (
                        f"; {misplaced} are HardwareConfig fields — use"
                        " .replace() instead"
                    )
                raise ValueError(
                    f"unknown TranslationConfig parameter(s) {sorted(unknown)};"
                    f" valid: {sorted(valid)}{hint}"
                )
            translation = TranslationConfig(**tlb_kw)
        return dataclasses.replace(self, translation=translation)

    def with_policy_mix(
        self, mix: "dict[int, OnChipPolicy | str] | None"
    ) -> "HardwareConfig":
        """Assign on-chip policies per table id; unlisted tables keep
        ``onchip.policy``. ``None`` clears the mix."""
        if mix is None:
            return self.with_onchip(policy_mix=None)
        norm = tuple(
            sorted((int(t), OnChipPolicy(p).value) for t, p in mix.items())
        )
        if len({t for t, _ in norm}) != len(norm):
            raise ValueError("duplicate table ids in policy mix")
        return self.with_onchip(policy_mix=norm)


def tpuv6e() -> HardwareConfig:
    """Paper Table I: TPUv6e configuration used for validation."""
    return HardwareConfig(
        name="tpuv6e",
        clock_ghz=0.94,
        num_cores=1,
        matrix_unit=MatrixUnit(rows=256, cols=256, dataflow=Dataflow.WS),
        vector_unit=VectorUnit(lanes=128, sublanes=8),
        onchip=OnChipMemory(
            capacity_bytes=128 * 1024 * 1024,
            line_bytes=64,
            ways=16,
            latency_cycles=8,
            read_bw_bytes_per_cycle=8192,
            write_bw_bytes_per_cycle=8192,
            policy=OnChipPolicy.SPM,
        ),
        offchip=OffChipMemory(
            capacity_bytes=32 * (1 << 30),
            bandwidth_gbps=1600.0,
        ),
    )


def tpu_v5e_chip() -> HardwareConfig:
    """TPU v5e single chip — the roofline target of the training framework.

    197 TFLOP/s bf16, 819 GB/s HBM, 16 GB HBM (used by benchmarks/roofline.py,
    kept here so all hardware constants live in one module).
    """
    return HardwareConfig(
        name="tpuv5e",
        clock_ghz=0.94,
        num_cores=1,
        matrix_unit=MatrixUnit(rows=128, cols=128, dataflow=Dataflow.WS),
        vector_unit=VectorUnit(lanes=128, sublanes=8),
        onchip=OnChipMemory(capacity_bytes=128 * 1024 * 1024),
        offchip=OffChipMemory(capacity_bytes=16 * (1 << 30), bandwidth_gbps=819.0),
    )


# Roofline constants for the v5e target (single source of truth).
V5E_PEAK_BF16_FLOPS = 197e12          # per chip
V5E_HBM_BW = 819e9                    # bytes/s per chip
V5E_ICI_BW = 50e9                     # bytes/s per link
V5E_HBM_BYTES = 16 * (1 << 30)
