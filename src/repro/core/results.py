"""Simulation result containers + CSV/JSON emit (paper "Simulation output").

"EONSim outputs both overall and per-batch results. Each result consists of
various metrics, including execution time, the on-chip and off-chip memory
access ratio, and the operation count for each memory and vector operation."
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional


@dataclass
class BatchResult:
    batch_index: int
    embedding_cycles: float = 0.0
    matrix_cycles: float = 0.0
    total_cycles: float = 0.0
    onchip_reads: int = 0
    onchip_writes: int = 0
    offchip_reads: int = 0
    vector_ops: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    dram_row_hits: int = 0
    dram_row_misses: int = 0

    @property
    def onchip_accesses(self) -> int:
        return self.onchip_reads + self.onchip_writes

    @property
    def onchip_ratio(self) -> float:
        total = self.onchip_accesses + self.offchip_reads
        return self.onchip_accesses / max(total, 1)


@dataclass
class SimResult:
    workload: str
    hardware: str
    policy: str
    batches: List[BatchResult] = field(default_factory=list)
    energy_pj: float = 0.0
    clock_ghz: float = 1.0
    num_cores: int = 1
    topology: str = "private"

    # ---- aggregates -------------------------------------------------------
    @property
    def total_cycles(self) -> float:
        return sum(b.total_cycles for b in self.batches)

    @property
    def total_seconds(self) -> float:
        return self.total_cycles / (self.clock_ghz * 1e9)

    @property
    def embedding_cycles(self) -> float:
        return sum(b.embedding_cycles for b in self.batches)

    @property
    def matrix_cycles(self) -> float:
        return sum(b.matrix_cycles for b in self.batches)

    @property
    def onchip_reads(self) -> int:
        return sum(b.onchip_reads for b in self.batches)

    @property
    def onchip_writes(self) -> int:
        return sum(b.onchip_writes for b in self.batches)

    @property
    def onchip_accesses(self) -> int:
        return sum(b.onchip_accesses for b in self.batches)

    @property
    def offchip_reads(self) -> int:
        return sum(b.offchip_reads for b in self.batches)

    @property
    def onchip_ratio(self) -> float:
        total = self.onchip_accesses + self.offchip_reads
        return self.onchip_accesses / max(total, 1)

    @property
    def cache_hits(self) -> int:
        return sum(b.cache_hits for b in self.batches)

    @property
    def cache_misses(self) -> int:
        return sum(b.cache_misses for b in self.batches)

    def summary(self) -> Dict:
        return {
            "workload": self.workload,
            "hardware": self.hardware,
            "policy": self.policy,
            "num_cores": self.num_cores,
            "topology": self.topology,
            "total_cycles": self.total_cycles,
            "total_seconds": self.total_seconds,
            "embedding_cycles": self.embedding_cycles,
            "matrix_cycles": self.matrix_cycles,
            "onchip_reads": self.onchip_reads,
            "onchip_writes": self.onchip_writes,
            "offchip_reads": self.offchip_reads,
            "onchip_ratio": self.onchip_ratio,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "energy_pj": self.energy_pj,
            "num_batches": len(self.batches),
        }

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "summary": self.summary(),
            "batches": [dataclasses.asdict(b) for b in self.batches],
        }
        text = json.dumps(payload, indent=2)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def diff(self, other: "SimResult") -> Dict[str, tuple]:
        """Field-by-field comparison of summaries + per-batch records.

        Returns ``{field: (self_value, other_value)}`` for every mismatching
        field — empty when the two results are bit-exact. Used by the DSE
        sweep's parity tests against independent ``simulate()`` runs.
        """
        mismatches: Dict[str, tuple] = {}
        a, b = self.summary(), other.summary()
        for k in a:
            if a[k] != b[k]:
                mismatches[k] = (a[k], b[k])
        if len(self.batches) != len(other.batches):
            mismatches["num_batch_records"] = (len(self.batches), len(other.batches))
            return mismatches
        for i, (ba, bb) in enumerate(zip(self.batches, other.batches)):
            da, db = dataclasses.asdict(ba), dataclasses.asdict(bb)
            for k in da:
                if da[k] != db[k]:
                    mismatches[f"batch{i}.{k}"] = (da[k], db[k])
        return mismatches

    @staticmethod
    def csv_header() -> str:
        return (
            "workload,hardware,policy,total_cycles,total_seconds,"
            "onchip_accesses,offchip_reads,onchip_ratio,cache_hits,cache_misses,energy_pj"
        )

    def to_csv_row(self) -> str:
        s = self.summary()
        return (
            f'{s["workload"]},{s["hardware"]},{s["policy"]},{s["total_cycles"]:.0f},'
            f'{s["total_seconds"]:.6e},{self.onchip_accesses},{s["offchip_reads"]},'
            f'{s["onchip_ratio"]:.4f},{s["cache_hits"]},{s["cache_misses"]},{s["energy_pj"]:.3e}'
        )
