"""Simulation result containers + CSV/JSON emit (paper "Simulation output").

"EONSim outputs both overall and per-batch results. Each result consists of
various metrics, including execution time, the on-chip and off-chip memory
access ratio, and the operation count for each memory and vector operation."
"""
from __future__ import annotations

import dataclasses
import json
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class BatchResult:
    batch_index: int
    embedding_cycles: float = 0.0
    matrix_cycles: float = 0.0
    total_cycles: float = 0.0
    onchip_reads: int = 0
    onchip_writes: int = 0
    offchip_reads: int = 0
    vector_ops: int = 0
    cache_hits: int = 0
    cache_misses: int = 0
    dram_row_hits: int = 0
    dram_row_misses: int = 0
    # Address-translation detail (all zero when hw.translation is None).
    tlb_hits: int = 0
    tlb_misses: int = 0
    tlb_walks: int = 0
    translation_cycles: float = 0.0

    @property
    def onchip_accesses(self) -> int:
        return self.onchip_reads + self.onchip_writes

    @property
    def onchip_ratio(self) -> float:
        total = self.onchip_accesses + self.offchip_reads
        return self.onchip_accesses / max(total, 1)


@dataclass
class SimResult:
    workload: str
    hardware: str
    policy: str
    batches: List[BatchResult] = field(default_factory=list)
    energy_pj: float = 0.0
    clock_ghz: float = 1.0
    num_cores: int = 1
    topology: str = "private"

    # ---- aggregates -------------------------------------------------------
    @property
    def total_cycles(self) -> float:
        return sum(b.total_cycles for b in self.batches)

    @property
    def total_seconds(self) -> float:
        return self.total_cycles / (self.clock_ghz * 1e9)

    @property
    def embedding_cycles(self) -> float:
        return sum(b.embedding_cycles for b in self.batches)

    @property
    def matrix_cycles(self) -> float:
        return sum(b.matrix_cycles for b in self.batches)

    @property
    def onchip_reads(self) -> int:
        return sum(b.onchip_reads for b in self.batches)

    @property
    def onchip_writes(self) -> int:
        return sum(b.onchip_writes for b in self.batches)

    @property
    def onchip_accesses(self) -> int:
        return sum(b.onchip_accesses for b in self.batches)

    @property
    def offchip_reads(self) -> int:
        return sum(b.offchip_reads for b in self.batches)

    @property
    def onchip_ratio(self) -> float:
        total = self.onchip_accesses + self.offchip_reads
        return self.onchip_accesses / max(total, 1)

    @property
    def cache_hits(self) -> int:
        return sum(b.cache_hits for b in self.batches)

    @property
    def cache_misses(self) -> int:
        return sum(b.cache_misses for b in self.batches)

    @property
    def tlb_hits(self) -> int:
        return sum(b.tlb_hits for b in self.batches)

    @property
    def tlb_misses(self) -> int:
        return sum(b.tlb_misses for b in self.batches)

    @property
    def tlb_walks(self) -> int:
        return sum(b.tlb_walks for b in self.batches)

    @property
    def translation_cycles(self) -> float:
        return sum(b.translation_cycles for b in self.batches)

    def summary(self) -> Dict:
        return {
            "workload": self.workload,
            "hardware": self.hardware,
            "policy": self.policy,
            "num_cores": self.num_cores,
            "topology": self.topology,
            "total_cycles": self.total_cycles,
            "total_seconds": self.total_seconds,
            "embedding_cycles": self.embedding_cycles,
            "matrix_cycles": self.matrix_cycles,
            "onchip_reads": self.onchip_reads,
            "onchip_writes": self.onchip_writes,
            "offchip_reads": self.offchip_reads,
            "onchip_ratio": self.onchip_ratio,
            "cache_hits": self.cache_hits,
            "cache_misses": self.cache_misses,
            "tlb_hits": self.tlb_hits,
            "tlb_misses": self.tlb_misses,
            "tlb_walks": self.tlb_walks,
            "translation_cycles": self.translation_cycles,
            "energy_pj": self.energy_pj,
            "num_batches": len(self.batches),
        }

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "summary": self.summary(),
            "batches": [dataclasses.asdict(b) for b in self.batches],
        }
        text = json.dumps(payload, indent=2)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def diff(self, other: "SimResult") -> Dict[str, tuple]:
        """Field-by-field comparison of summaries + per-batch records.

        Returns ``{field: (self_value, other_value)}`` for every mismatching
        field — empty when the two results are bit-exact. Used by the DSE
        sweep's parity tests against independent ``simulate()`` runs.
        """
        mismatches: Dict[str, tuple] = {}
        a, b = self.summary(), other.summary()
        for k in a:
            if a[k] != b[k]:
                mismatches[k] = (a[k], b[k])
        if len(self.batches) != len(other.batches):
            mismatches["num_batch_records"] = (len(self.batches), len(other.batches))
            return mismatches
        for i, (ba, bb) in enumerate(zip(self.batches, other.batches)):
            da, db = dataclasses.asdict(ba), dataclasses.asdict(bb)
            for k in da:
                if da[k] != db[k]:
                    mismatches[f"batch{i}.{k}"] = (da[k], db[k])
        return mismatches

    @staticmethod
    def csv_header() -> str:
        return (
            "workload,hardware,policy,total_cycles,total_seconds,"
            "onchip_accesses,offchip_reads,onchip_ratio,cache_hits,cache_misses,energy_pj"
        )

    def to_csv_row(self) -> str:
        s = self.summary()
        return (
            f'{s["workload"]},{s["hardware"]},{s["policy"]},{s["total_cycles"]:.0f},'
            f'{s["total_seconds"]:.6e},{self.onchip_accesses},{s["offchip_reads"]},'
            f'{s["onchip_ratio"]:.4f},{s["cache_hits"]},{s["cache_misses"]},{s["energy_pj"]:.3e}'
        )


@dataclass
class ServingResult:
    """One serving scenario's outcome on one hardware config.

    Produced by ``serving.scheduler.simulate_serving``. Deterministic: the
    same scenario + hardware + seed reproduces every field bitwise, latency
    arrays included — ``diff()`` returning ``{}`` is the reproducibility
    assertion used by tests and the serving-smoke CI job.

    ``batch_stats`` is the identity surface: with all robustness policies
    off it is exactly the ``List[EmbeddingBatchStats]`` the plain
    fixed-trace ``simulate_embedding`` path yields for the same lowered
    ``ConcatTrace`` (differential-enforced). Latency/queue/service arrays
    are in completion order, one entry per completed request, in cycles.
    """

    scenario: str
    hardware: str
    policy: str
    clock_ghz: float
    offered: int                  # requests submitted (first attempts)
    completed: int                # requests served to completion
    shed: int                     # admission-control rejections (all attempts)
    timed_out: int                # deadline abandonments while queued
    retries: int                  # client re-submissions scheduled
    abandoned: int                # attempts failed with no retry budget left
    degraded_batches: int
    dropped_cold_rows: int        # lookups truncated by hot_rows_only
    bypassed_lookups: int         # lookups routed around the cache
    num_batches: int
    makespan_cycles: int          # first arrival -> last batch completion
    goodput: float                # in-deadline completions / offered
    latency_cycles: np.ndarray    # int64, completion order
    queue_cycles: np.ndarray      # int64, served attempt's queueing delay
    service_cycles: np.ndarray    # int64, served batch's service time
    batch_stats: List = field(default_factory=list)
    batch_service_cycles: np.ndarray = field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))
    batch_start_cycles: np.ndarray = field(
        default_factory=lambda: np.zeros(0, dtype=np.int64))

    # ---- latency distribution --------------------------------------------
    def latency_percentile(self, q: float) -> float:
        if self.latency_cycles.size == 0:
            return float("nan")
        return float(np.percentile(self.latency_cycles, q))

    @property
    def p50_cycles(self) -> float:
        return self.latency_percentile(50.0)

    @property
    def p95_cycles(self) -> float:
        return self.latency_percentile(95.0)

    @property
    def p99_cycles(self) -> float:
        return self.latency_percentile(99.0)

    @property
    def mean_queue_cycles(self) -> float:
        if self.queue_cycles.size == 0:
            return float("nan")
        return float(self.queue_cycles.mean())

    @property
    def mean_service_cycles(self) -> float:
        if self.service_cycles.size == 0:
            return float("nan")
        return float(self.service_cycles.mean())

    # ---- throughput -------------------------------------------------------
    # The scheduler never emits makespan_cycles == 0 (it clamps to >= 1),
    # but externally-constructed / journal-replayed results can carry it —
    # nan, like the other empty-distribution properties, not a raise.
    @property
    def sustained_qps_per_mcycle(self) -> float:
        """Completed requests per million cycles — clock-independent."""
        if self.makespan_cycles == 0:
            return float("nan")
        return self.completed / (self.makespan_cycles / 1e6)

    @property
    def sustained_qps(self) -> float:
        """Completed requests per wall second at ``clock_ghz``."""
        if self.makespan_cycles == 0:
            return float("nan")
        return self.completed / (self.makespan_cycles / (self.clock_ghz * 1e9))

    @property
    def total_cycles(self) -> float:
        """Makespan, under the name ``SweepResult.best``/``speedup_over``
        read off every entry result."""
        return float(self.makespan_cycles)

    def cycles_to_us(self, cycles: float) -> float:
        return cycles / (self.clock_ghz * 1e3)

    # ---- emit -------------------------------------------------------------
    def summary(self) -> Dict:
        return {
            "scenario": self.scenario,
            "hardware": self.hardware,
            "policy": self.policy,
            "offered": self.offered,
            "completed": self.completed,
            "shed": self.shed,
            "timed_out": self.timed_out,
            "retries": self.retries,
            "abandoned": self.abandoned,
            "degraded_batches": self.degraded_batches,
            "dropped_cold_rows": self.dropped_cold_rows,
            "bypassed_lookups": self.bypassed_lookups,
            "num_batches": self.num_batches,
            "makespan_cycles": self.makespan_cycles,
            "total_cycles": self.total_cycles,
            "goodput": self.goodput,
            "p50_cycles": self.p50_cycles,
            "p95_cycles": self.p95_cycles,
            "p99_cycles": self.p99_cycles,
            "mean_queue_cycles": self.mean_queue_cycles,
            "mean_service_cycles": self.mean_service_cycles,
            "sustained_qps_per_mcycle": self.sustained_qps_per_mcycle,
            "sustained_qps": self.sustained_qps,
        }

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "summary": self.summary(),
            "latency_cycles": self.latency_cycles.tolist(),
            "queue_cycles": self.queue_cycles.tolist(),
            "service_cycles": self.service_cycles.tolist(),
            "batch_service_cycles": self.batch_service_cycles.tolist(),
            "batch_start_cycles": self.batch_start_cycles.tolist(),
        }
        text = json.dumps(payload, indent=2)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text

    def diff(self, other: "ServingResult") -> Dict[str, tuple]:
        """Bitwise comparison: summary fields, per-request arrays, and the
        per-batch memory-system stats. Empty dict == identical results."""
        mismatches: Dict[str, tuple] = {}
        a, b = self.summary(), other.summary()
        for k in a:
            av, bv = a[k], b[k]
            same = (av == bv) or (
                isinstance(av, float) and isinstance(bv, float)
                and np.isnan(av) and np.isnan(bv))
            if not same:
                mismatches[k] = (av, bv)
        for name in ("latency_cycles", "queue_cycles", "service_cycles",
                     "batch_service_cycles", "batch_start_cycles"):
            xa, xb = getattr(self, name), getattr(other, name)
            if xa.shape != xb.shape or not np.array_equal(xa, xb):
                mismatches[name] = (xa.tolist(), xb.tolist())
        if len(self.batch_stats) != len(other.batch_stats):
            mismatches["num_batch_stats"] = (
                len(self.batch_stats), len(other.batch_stats))
            return mismatches
        for i, (sa, sb) in enumerate(zip(self.batch_stats, other.batch_stats)):
            da, db = dataclasses.asdict(sa), dataclasses.asdict(sb)
            if da != db:
                mismatches[f"batch_stats{i}"] = (da, db)
        return mismatches
