"""Request-level traffic generation for serving simulation.

The paper (and the rest of this repo up to now) evaluates fixed embedding
traces: a workload IS a trace. Production DLRM serving is a *stream of
requests* — Poisson/diurnal/bursty arrivals, per-request table subsets and
lookup counts, and popularity that drifts over the day. This module generates
such streams, fully seeded and deterministic, and lowers admitted request
batches onto the existing ``FullTrace``/``ConcatTrace`` per-batch-boundary
seam so the unmodified memory system provides service times.

Layering (see docs/architecture.md "Serving under stress")::

    TrafficConfig -> generate_requests() -> [Request...]      (this module)
        -> serving.scheduler (admission/batching/policies)
        -> lower_batch() -> FullTrace per served batch        (this module)
        -> ConcatTrace -> MemorySystem.simulate_embedding     (untouched)

Determinism contract: every sampled quantity is drawn from a
``np.random.default_rng`` seeded by an integer tuple derived from
``(cfg.seed, request id, ...)`` — no global RNG state, no wall clock, no
str-hashing (PYTHONHASHSEED-proof), so the same config always yields the
same byte-identical stream, including each request's row ids (a retried
request re-submits the *same* rows, as a real client would).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from .trace import FullTrace, zipf_probs
from .workload import EmbeddingOpSpec

__all__ = [
    "ARRIVAL_PATTERNS",
    "BatchLowering",
    "Request",
    "TrafficConfig",
    "drift_exponents",
    "generate_arrivals",
    "generate_requests",
    "hot_table_set",
    "lower_batch",
]

ARRIVAL_PATTERNS = ("poisson", "diurnal", "bursty")

# Sub-stream tags mixed into rng seeds so the arrival process, per-request
# shape, and per-request rows never share a stream (adding a knob to one can
# never silently reshuffle another).
_ARRIVAL_TAG = 0xA221
_SHAPE_TAG = 0x517A
_ROWS_TAG = 0xB0B
_PERM_TAG = 0x9E12


@dataclass(frozen=True)
class TrafficConfig:
    """One seeded request-traffic scenario (the arrival half of a serving
    scenario; the robustness-policy half lives in ``serving.scheduler``).

    * ``pattern`` — ``poisson`` (memoryless gaps), ``diurnal`` (Poisson with
      a sinusoidally modulated rate: rush hour vs. night), ``bursty``
      (on/off bursts of ``burst_len`` back-to-back requests).
    * ``mean_gap_cycles`` — mean inter-arrival gap; 1/rate in cycles, the
      same unit the memory system charges service time in, so overload is
      just ``mean_gap_cycles < service_per_request``.
    * ``tables_per_request`` / ``lookups_per_table`` — per-request shape:
      each request touches a seeded subset of the op's tables (``None`` =
      all of them) with that many pooled lookups per touched table.
    * ``zipf_s`` + ``zipf_drift`` — popularity skew at stream start, and a
      linear drift of the exponent across the stream (popularity sharpens
      or flattens over the "day"). The drifting exponent is *quantized to
      drift epochs* (see ``drift_exponents``): every request in an epoch
      shares one exponent, so the per-exponent CDF cache stays bounded by
      the epoch count instead of growing one entry per request.
    * ``drift_period`` — every that-many requests the hot-id permutation is
      re-drawn (which rows are hot rotates, the cache's working set moves)
      and, when drifting, the Zipf exponent steps to its next value; 0 keeps
      one permutation for the whole stream (a drifting exponent then steps
      on a fixed ``_DRIFT_GRID``-epoch grid).
    """

    pattern: str = "poisson"
    mean_gap_cycles: float = 2_000.0
    num_requests: int = 256
    seed: int = 0
    tables_per_request: Optional[int] = None
    lookups_per_table: Optional[int] = None
    zipf_s: float = 0.8
    zipf_drift: float = 0.0
    drift_period: int = 0
    diurnal_period_cycles: float = 250_000.0
    diurnal_amplitude: float = 0.5
    burst_len: int = 8
    burst_gap_scale: float = 0.1

    def __post_init__(self) -> None:
        if self.pattern not in ARRIVAL_PATTERNS:
            raise ValueError(
                f"unknown arrival pattern {self.pattern!r}; "
                f"options: {ARRIVAL_PATTERNS}")
        if self.num_requests < 1:
            raise ValueError("num_requests must be >= 1")
        if self.mean_gap_cycles <= 0:
            raise ValueError("mean_gap_cycles must be > 0")

    @property
    def key(self) -> tuple:
        """Canonical value tuple (memo keys / checkpoint fingerprints)."""
        return (
            "traffic", self.pattern, float(self.mean_gap_cycles),
            int(self.num_requests), int(self.seed),
            self.tables_per_request, self.lookups_per_table,
            float(self.zipf_s), float(self.zipf_drift),
            int(self.drift_period), float(self.diurnal_period_cycles),
            float(self.diurnal_amplitude), int(self.burst_len),
            float(self.burst_gap_scale),
        )


@dataclass(frozen=True)
class Request:
    """One inference request: arrival instant + its exact lookup payload.

    ``ranks`` carries each lookup's popularity rank (0 = hottest) alongside
    the row id, so graceful degradation ("hot rows only") can truncate a
    request without re-deriving popularity — and do it identically on
    replay.
    """

    rid: int
    arrival: int                 # cycles
    table_ids: np.ndarray        # int32 (T_r,) touched tables, sorted
    rows: np.ndarray             # int64 (T_r, L) row ids per touched table
    ranks: np.ndarray            # int64 (T_r, L) popularity rank per lookup

    @property
    def num_lookups(self) -> int:
        return int(self.rows.size)


# --------------------------------------------------------------------------
# Arrival processes
# --------------------------------------------------------------------------

def generate_arrivals(cfg: TrafficConfig) -> np.ndarray:
    """int64 (num_requests,) sorted arrival cycles — deterministic in cfg."""
    rng = np.random.default_rng((cfg.seed, _ARRIVAL_TAG))
    n = cfg.num_requests
    if cfg.pattern == "poisson":
        gaps = rng.exponential(cfg.mean_gap_cycles, size=n)
    elif cfg.pattern == "bursty":
        # On/off: bursts of burst_len back-to-back requests (gap shrunk by
        # burst_gap_scale) separated by long idle gaps sized to keep the
        # configured mean rate.
        u = rng.exponential(1.0, size=n)
        L = max(1, int(cfg.burst_len))
        head = (np.arange(n) % L) == 0
        idle = cfg.mean_gap_cycles * (
            L - (L - 1) * cfg.burst_gap_scale
        )
        gaps = np.where(head, u * idle,
                        u * cfg.mean_gap_cycles * cfg.burst_gap_scale)
    else:  # diurnal — inhomogeneous Poisson, rate modulated by a sinusoid.
        u = rng.exponential(1.0, size=n)
        gaps = np.empty(n, dtype=np.float64)
        t = 0.0
        base_rate = 1.0 / cfg.mean_gap_cycles
        for i in range(n):
            mod = 1.0 + cfg.diurnal_amplitude * math.sin(
                2.0 * math.pi * t / max(cfg.diurnal_period_cycles, 1e-9)
            )
            rate = max(base_rate * mod, 1e-12)
            g = u[i] / rate
            gaps[i] = g
            t += g
    return np.floor(np.cumsum(gaps)).astype(np.int64)


# --------------------------------------------------------------------------
# Request payload generation (table subsets, Zipf rows with drift)
# --------------------------------------------------------------------------

def _zipf_cdf(num_rows: int, s: float, cache: Dict[float, np.ndarray]) -> np.ndarray:
    cdf = cache.get(s)
    if cdf is None:
        cdf = cache[s] = np.cumsum(zipf_probs(num_rows, s))
    return cdf


# Epoch grid for a drifting exponent when drift_period is 0 (no explicit
# epoch length configured): the stream is cut into this many equal epochs.
_DRIFT_GRID = 64


def drift_exponents(cfg: TrafficConfig) -> np.ndarray:
    """float64 (num_requests,) — each request's Zipf exponent.

    With ``zipf_drift == 0`` every entry is exactly ``cfg.zipf_s`` (the
    generated stream is bitwise identical to a drift-free config; test-
    enforced). With drift, the linear schedule ``zipf_s + zipf_drift *
    (i / (n-1))`` is evaluated at each drift epoch's *first* request and
    held constant across the epoch (epoch length = ``drift_period``, or an
    ``n/_DRIFT_GRID`` grid when no period is configured). Distinct values
    are therefore bounded by the epoch count — which is what keeps the
    per-exponent CDF cache in ``generate_requests`` bounded and actually
    hitting, instead of recomputing an O(rows_per_table) cumsum per request.
    """
    n = cfg.num_requests
    if cfg.zipf_drift == 0.0:
        return np.full(n, float(cfg.zipf_s))
    period = cfg.drift_period if cfg.drift_period > 0 else max(
        1, -(-n // _DRIFT_GRID))
    i = np.arange(n, dtype=np.int64)
    epoch_start = (i // period) * period
    return cfg.zipf_s + cfg.zipf_drift * (epoch_start / max(n - 1, 1))


def _epoch_perm(
    seed: int, epoch: int, table: int, num_rows: int,
    cache: Dict[Tuple[int, int], np.ndarray],
) -> np.ndarray:
    """Popularity-rank -> row-id permutation for (epoch, table). Re-drawn per
    drift epoch so the hot set rotates; per table so tables have independent
    hot sets (same posture as ``expand_trace``)."""
    perm = cache.get((epoch, table))
    if perm is None:
        prng = np.random.default_rng((seed, _PERM_TAG, epoch, table))
        perm = cache[(epoch, table)] = prng.permutation(num_rows)
    return perm


def generate_requests(
    spec: EmbeddingOpSpec, cfg: TrafficConfig
) -> List[Request]:
    """The full seeded request stream for one embedding op.

    Deterministic in ``(spec, cfg)``: arrivals from ``generate_arrivals``,
    per-request table subset + rows from per-request seeded sub-streams. A
    request's rows are a pure function of ``(cfg.seed, rid)`` — a retry
    re-submits identical rows.
    """
    arrivals = generate_arrivals(cfg)
    n = cfg.num_requests
    # `is None` (not falsy-or): an explicit 0 must hit the range error below,
    # not silently mean "unset".
    tpr = (spec.num_tables if cfg.tables_per_request is None
           else cfg.tables_per_request)
    if not (1 <= tpr <= spec.num_tables):
        raise ValueError(
            f"tables_per_request={tpr} outside [1, {spec.num_tables}]")
    lpt = (spec.lookups_per_sample if cfg.lookups_per_table is None
           else cfg.lookups_per_table)
    if lpt < 1:
        raise ValueError("lookups_per_table must be >= 1")

    cdf_cache: Dict[float, np.ndarray] = {}
    perm_cache: Dict[Tuple[int, int], np.ndarray] = {}
    exponents = drift_exponents(cfg)
    out: List[Request] = []
    for i in range(n):
        s_i = float(exponents[i])
        epoch = (i // cfg.drift_period) if cfg.drift_period > 0 else 0
        cdf = _zipf_cdf(spec.rows_per_table, s_i, cdf_cache)
        rng = np.random.default_rng((cfg.seed, _SHAPE_TAG, i))
        if tpr == spec.num_tables:
            tabs = np.arange(spec.num_tables, dtype=np.int32)
        else:
            tabs = np.sort(rng.choice(
                spec.num_tables, size=tpr, replace=False
            )).astype(np.int32)
        rrng = np.random.default_rng((cfg.seed, _ROWS_TAG, i))
        u = rrng.random((tpr, lpt))
        # cdf[-1] can sit a few ulps below 1.0; clamp so a u in that sliver
        # maps to the coldest rank instead of indexing past the table.
        ranks = np.minimum(
            np.searchsorted(cdf, u, side="right").astype(np.int64),
            spec.rows_per_table - 1,
        )
        rows = np.empty_like(ranks)
        for j, t in enumerate(tabs):
            rows[j] = _epoch_perm(
                cfg.seed, epoch, int(t), spec.rows_per_table, perm_cache
            )[ranks[j]]
        out.append(Request(rid=i, arrival=int(arrivals[i]),
                           table_ids=tabs, rows=rows, ranks=ranks))
    return out


def hot_table_set(
    requests: Sequence[Request], spec: EmbeddingOpSpec, keep_fraction: float
) -> np.ndarray:
    """bool (num_tables,) — the "hot" tables the cache keeps serving under
    ``cache_bypass`` degradation: the top ``ceil(num_tables*keep_fraction)``
    tables by total offered lookups over the whole stream (ties break toward
    the lower table id, so the set is deterministic in the stream)."""
    counts = np.zeros(spec.num_tables, dtype=np.int64)
    for r in requests:
        np.add.at(counts, r.table_ids.astype(np.int64), r.rows.shape[1])
    k = max(1, min(spec.num_tables,
                   int(math.ceil(spec.num_tables * keep_fraction))))
    order = np.lexsort((np.arange(spec.num_tables), -counts))
    hot = np.zeros(spec.num_tables, dtype=bool)
    hot[order[:k]] = True
    return hot


# --------------------------------------------------------------------------
# Lowering: a batch of requests -> FullTrace (the ConcatTrace seam)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class BatchLowering:
    """One served batch lowered onto the trace seam, plus what degradation
    removed from it (the scheduler charges the bypass penalty and reports
    the drop counters from these)."""

    full: FullTrace
    lookups: int            # lookups actually in the trace
    dropped_cold_rows: int  # hot_rows_only truncation victims
    bypassed_lookups: int   # cache_bypass lookups routed around the cache


def lower_batch(
    requests: Sequence[Request],
    spec: EmbeddingOpSpec,
    hot_rank_limit: Optional[int] = None,
    bypass_tables: Optional[np.ndarray] = None,
) -> BatchLowering:
    """Lower one admitted batch (one request per batch slot) to a FullTrace.

    Lookup order is batch-major like ``expand_trace``: request 0's tables in
    ascending order, then request 1, ... — the order an embedding-bag kernel
    walks a ragged batch. With both degradation arguments ``None`` the
    lowering is the exact identity on the requests' payloads (no lookup
    added, dropped, or reordered) — the all-policies-off serving path feeds
    these traces to ``simulate_embedding`` unchanged (differential-enforced).

    ``hot_rank_limit`` keeps only lookups with popularity rank below it
    (hot-rows-only truncated pooling). ``bypass_tables`` (bool mask over
    table ids) removes those tables' lookups from the *cached* stream; the
    scheduler charges them a flat DRAM-bypass cost instead.
    """
    if not requests:
        raise ValueError("lower_batch needs at least one request")
    tab_parts: List[np.ndarray] = []
    row_parts: List[np.ndarray] = []
    dropped = 0
    bypassed = 0
    for r in requests:
        tabs = np.repeat(r.table_ids.astype(np.int32), r.rows.shape[1])
        rows = r.rows.reshape(-1)
        keep = np.ones(rows.size, dtype=bool)
        if hot_rank_limit is not None:
            cold = r.ranks.reshape(-1) >= hot_rank_limit
            dropped += int(np.count_nonzero(keep & cold))
            keep &= ~cold
        if bypass_tables is not None:
            by = bypass_tables[tabs]
            bypassed += int(np.count_nonzero(keep & by))
            keep &= ~by
        tab_parts.append(tabs[keep])
        row_parts.append(rows[keep])
    table_ids = (np.concatenate(tab_parts) if tab_parts
                 else np.empty(0, dtype=np.int32))
    row_ids = (np.concatenate(row_parts) if row_parts
               else np.empty(0, dtype=np.int64))
    full = FullTrace(
        table_ids=table_ids.astype(np.int32),
        row_ids=row_ids.astype(np.int64),
        batch_size=len(requests),
        num_tables=spec.num_tables,
        lookups_per_sample=max(
            1, (requests[0].rows.shape[1] if requests else 1)
        ),
    )
    return BatchLowering(
        full=full,
        lookups=int(row_ids.size),
        dropped_cold_rows=dropped,
        bypassed_lookups=bypassed,
    )
