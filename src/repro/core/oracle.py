"""Independent TPUv6e timing oracle — the "measured hardware" proxy.

The paper validates EONSim against wall-clock TPUv6e measurements (Fig. 3).
No TPUv6e exists in this container, so the validation benchmarks compare
EONSim against THIS model: a closed-form, vector-granular timing model of the
same TPUv6e configuration, written as a separate code path from the engine
(no event scan, no cache machinery, aggregate bandwidth reasoning — the way a
performance engineer would hand-model the chip). Agreement between two
independently-built models of the same machine is the strongest validation
available offline; the residual disagreement is reported as the validation
error, mirroring the paper's sim-vs-hardware metric.

TPUv6e embedding path (paper Sec. IV): single core, no global buffer,
scratchpad staging, "fetching all vectors from off-chip memory regardless of
hotness" — i.e. every lookup is an HBM gather.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import numpy as np

from .hardware import HardwareConfig
from .workload import EmbeddingOpSpec, MatrixOpSpec, Workload


@dataclass
class OracleResult:
    embedding_cycles: float
    matrix_cycles: float
    onchip_accesses: int
    offchip_accesses: int

    @property
    def total_cycles(self) -> float:
        return self.embedding_cycles + self.matrix_cycles


def _embedding_cycles(spec: EmbeddingOpSpec, batch_size: int, hw: HardwareConfig) -> float:
    """Closed-form gather time: random vector gathers from HBM.

    A vector spans ``ceil(vec/interleave)`` interleave blocks, each one row
    activate on some bank plus line bursts on that channel's bus; random
    gathers make essentially every block a fresh activate. Per channel the
    bound is max(bus occupancy, activate serialization over banks).
    """
    line = hw.onchip.line_bytes
    off = hw.offchip
    lpv = math.ceil(spec.vector_bytes / line)
    blocks_per_vec = max(1, math.ceil(spec.vector_bytes / off.interleave_bytes))
    n_vec = spec.lookups_per_batch(batch_size)
    n_lines = n_vec * lpv
    n_blocks = n_vec * blocks_per_vec

    bus_cyc = line / off.channel_bytes_per_cycle(hw.clock_ghz)
    act = off.t_rp_cycles + off.t_rcd_cycles
    lines_per_chan = n_lines / off.channels
    blocks_per_bank = n_blocks / (off.channels * off.banks_per_channel)
    lines_per_bank = n_lines / (off.channels * off.banks_per_channel)
    bus_bound = lines_per_chan * bus_cyc
    bank_bound = blocks_per_bank * act + lines_per_bank * bus_cyc
    mem = max(bus_bound, bank_bound) + off.base_latency_cycles + off.t_cas_cycles

    pool_flops = spec.reduction_flops(batch_size)
    compute = pool_flops / max(hw.vector_unit.throughput, 1)
    return max(mem, compute)


def _matrix_cycles(op: MatrixOpSpec, hw: HardwareConfig) -> float:
    """Roofline max(compute, memory) per GEMM — deliberately simpler than the
    engine's systolic fold model."""
    mu = hw.matrix_unit
    peak_macs = mu.rows * mu.cols
    compute = op.flops / 2 / peak_macs
    d = op.input_bytes + op.weight_bytes + op.output_bytes
    mem = d / hw.offchip.bytes_per_cycle(hw.clock_ghz) + hw.offchip.base_latency_cycles
    return max(compute, mem) * op.count


def oracle_run(workload: Workload, hw: HardwareConfig) -> OracleResult:
    """TPUv6e-proxy execution time for the workload (per the SPM config)."""
    emb = sum(
        _embedding_cycles(spec, workload.batch_size, hw)
        for spec in workload.embedding_ops
    ) * workload.num_batches
    mat = sum(_matrix_cycles(op, hw) for op in workload.matrix_ops) * workload.num_batches

    line = hw.onchip.line_bytes
    onchip = 0
    offchip = 0
    for spec in workload.embedding_ops:
        lpv = math.ceil(spec.vector_bytes / line)
        n_lines = spec.lookups_per_batch(workload.batch_size) * lpv * workload.num_batches
        offchip += n_lines          # every vector fetched from HBM
        onchip += 2 * n_lines       # staged write + consumed read
    for op in workload.matrix_ops:
        d_in = op.input_bytes + op.weight_bytes
        d_out = op.output_bytes
        offchip += math.ceil((d_in + d_out) / line) * op.count * workload.num_batches
        onchip += (
            math.ceil(d_in / line) + math.ceil((d_in + d_out) / line)
        ) * op.count * workload.num_batches
    return OracleResult(
        embedding_cycles=emb,
        matrix_cycles=mat,
        onchip_accesses=onchip,
        offchip_accesses=offchip,
    )
