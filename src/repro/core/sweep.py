"""Batched design-space-exploration (DSE) sweep engine.

EONSim's stated purpose is "to enable flexible exploration and design of
emerging NPU architectures". A DSE study evaluates a *grid* of memory-system
configurations — on-chip policy x capacity x associativity x workload x reuse
level — and calling ``simulate()`` per point repeats all the
hardware-independent work N times. ``sweep()`` evaluates the whole grid in
one pass while staying **bit-exact** with independent ``simulate()`` calls
(tests enforce this per config):

  * **Trace sharing** — index-trace generation + multi-table expansion +
    concatenation (``EmbeddingTrace``) depend only on (workload, seed,
    zipf_s), so they are built once per (workload, reuse level) and shared by
    every (policy, capacity, ways) point. The derived vector-id stream and
    line-address trace are cached inside the ``EmbeddingTrace`` too.
  * **Matrix-model sharing** — the analytical matrix model is independent of
    the swept on-chip parameters (policy/capacity/ways), so it runs once per
    workload.
  * **Compiled-scan reuse** — the cache engine buckets scan lengths to powers
    of two and the segmented DRAM scan pads (segment, channel) slots the same
    way, so JAX jit caches are shared across grid points with the same
    (ways, policy) shape signature instead of recompiling per config.
  * **Vmapped scan batching** — all distinct single-core grid points of one
    cache-engine policy classify through ``classify_embedding_many``: their
    set-group sub-scans are bucketed by padded shape and each bucket runs as
    ONE vmapped dispatch instead of one dispatch per (config, group)
    (``batch_scans=False`` falls back to per-config scans; results are
    bit-exact either way).
  * **Analytic classification sharing** — under the default
    ``cache_backend="stack"`` every cache-engine policy classifies
    analytically: LRU from one stack-distance pass per (stream, num_sets)
    covering EVERY associativity in the grid (Mattson inclusion), srrip/fifo
    from shared compressed per-set passes (``memory.rrip``) batched across
    configs — no sequential scan on the sweep path at all.
  * **Placement-invariant classification** — the NUMA axes
    (``channel_affinity`` / ``placement``) only remap miss-line addresses on
    the way to DRAM, so grid points differing only in those axes share ONE
    classification (``classify_for_pending``) and fan out per-placement DRAM
    requests from it (``pending_from``); configs whose placement transform
    is provably the identity for the topology collapse onto the base-grid
    memo entry outright.
  * **Degenerate memo-key canonicalization** — grid points whose swept
    parameters provably cannot change classification collapse onto one memo
    key: SPM reads neither capacity nor ways (``sensitive_params = ()``),
    PINNING never reads ways, and a PINNING capacity large enough to pin the
    slice's whole line footprint is canonicalized to a saturation marker so
    every such capacity shares one classification + DRAM timing
    (``MemoryPolicy.capacity_saturates``; collapse-is-bitwise test-enforced).
  * **Cross-config DRAM batching** — classification and DRAM timing are
    decoupled (``PendingEmbedding``): every memo key's miss-trace dispatch
    of a (workload, zipf) slice runs through ONE ``dram_timing_many`` call,
    bit-exact vs per-key dispatch (``batch_dram=False`` is that reference
    path).

The grid also spans the CoreCluster axes: ``num_cores`` and ``topologies``
(private per-core on-chip vs shared LLC) sweep through the multi-core
MemorySystem with shared-DRAM contention — and the NUMA placement axes
``channel_affinities`` / ``placements`` (symmetric | per_core | per_table x
interleave | table_rank | hot_replicate), which participate in the memo keys
and ride the same batched ``dram_timing_many`` dispatch (placement is pure
address remapping upstream of DRAM timing) — plus the address-translation
axis ``translations`` (``TranslationConfig`` | None): translation is a pure
charge on the classified miss stream, so translation siblings share ONE
classification, ``translation=None`` keys exactly like the base grid, and
TLBs whose reach saturates the slice's page footprint collapse onto one
first-touch-only memo key (``memory.tlb.translation_saturated``).

Scaling the sweep itself (the "week-long sweeps that survive preemption"
posture — see docs/architecture.md "Scaling the DSE"):

  * **Device sharding** (``devices=``) — the memo-key space partitions into
    shards (whole class-key groups, so placement siblings stay co-located
    with their shared classification); each shard runs its own batched
    stack-distance passes and ``dram_timing_many`` dispatch pinned to one
    JAX device, concurrently with the others, and the per-key stats gather
    back into the single result. Because every batching layer is bit-exact
    regardless of batch composition, the sharded sweep is bitwise identical
    to the single-device path (differential-enforced).
  * **Checkpointed resumability** (``checkpoint=``) — completed memo keys
    journal to a ``SweepCheckpoint`` (``core.sweep_ckpt``) in cadence-sized
    rounds; a killed sweep resumes by restoring journaled keys and
    re-evaluating only the remainder, and the resumed ``SweepResult`` is
    bitwise identical to an uninterrupted run (differential-enforced).
  * **Explicit config lists** (``configs=``) — the search driver
    (``core.search``) evaluates arbitrary subsets of the grid through the
    same memoized engine; ``grid_configs()`` exposes the exhaustive list.

Typical use (the paper's Fig. 4 case study is one call — see
``examples/fig4_sweep.py``)::

    result = sweep(
        workload,
        base_hw=tpuv6e(),
        policies=("spm", "lru", "srrip", "pinning"),
        capacities=(1 << 20, 4 << 20, 16 << 20),
        ways=(8, 16),
    )
    best = result.best("total_cycles")
"""
from __future__ import annotations

import itertools
import json
import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .energy import EnergyTable
from .faults import (
    FaultInjector,
    FaultPlan,
    FaultTelemetry,
    FaultTolerance,
    ShardEvaluationError,
)
from .engine import (
    assemble_result,
    build_embedding_traces,
    summarize_matrix_ops,
)
from .hardware import (
    HardwareConfig,
    OnChipPolicy,
    Topology,
    TranslationConfig,
    tpuv6e,
)
from .memory.dram import dram_timing_many
from .memory.policies import available_policies
from .memory.tlb import translation_saturated
from .memory.system import (
    MemorySystem,
    classify_embedding_many,
    memory_system_for,
)
from .results import SimResult
from .sweep_ckpt import SweepCheckpoint
from .workload import Workload

DEFAULT_POLICIES = ("spm", "lru", "srrip", "fifo", "pinning")

# Canonical memo-key marker for a capacity that saturates classification
# (``MemoryPolicy.capacity_saturates`` + capacity >= the slice's whole line
# footprint): every such capacity classifies identically, so they share one
# key instead of re-timing byte-identical stats per capacity.
_CAP_SATURATED = "cap_saturated"

# Canonical memo-key marker for a saturated TLB (reach >= the slice's page
# footprint in every set): the charge collapses to first-touch-only walks,
# identical for EVERY saturated geometry — see ``memory.tlb.
# translation_saturated``. Key carries the two parameters the collapsed
# charge still depends on: (marker, page_bytes, miss_latency_cycles).
_TLB_SATURATED = "tlb_sat"


def _tr_key(tr: "TranslationConfig | None") -> tuple:
    """Canonical translation-axis key: ``()`` for off (kept a tuple, not
    None, so combo lists stay sortable in checkpoint fingerprints), else
    the config's primitive 8-tuple."""
    if tr is None:
        return ()
    if not isinstance(tr, TranslationConfig):
        raise TypeError(
            f"translations entries must be TranslationConfig or None, "
            f"got {type(tr).__name__}")
    return tr.key


def _tr_from_key(trk: tuple) -> Optional[TranslationConfig]:
    return None if not trk else TranslationConfig.from_key(trk)


@dataclass(frozen=True)
class SweepConfig:
    """One grid point of the design space."""

    policy: str
    capacity_bytes: int
    ways: int
    workload: str
    zipf_s: float
    num_cores: int = 1
    topology: str = "private"
    channel_affinity: str = "symmetric"
    placement: str = "interleave"
    # Address-translation layer (None = virtual==physical, the exact
    # pre-translation engine; see ``hardware.TranslationConfig``).
    translation: Optional[TranslationConfig] = None
    # Serving-scenario name when this grid point came from a scenario sweep
    # (``sweep(scenarios=...)``); "" on plain fixed-trace sweeps.
    scenario: str = ""

    @property
    def label(self) -> str:
        cap_mb = self.capacity_bytes / (1 << 20)
        base = f"{self.workload}/{self.policy}/{cap_mb:g}MB/{self.ways}w/z{self.zipf_s:g}"
        if self.num_cores != 1 or self.topology != "private":
            base += f"/{self.num_cores}c-{self.topology}"
        if self.channel_affinity != "symmetric" or self.placement != "interleave":
            base += f"/{self.channel_affinity}-{self.placement}"
        if self.translation is not None:
            t = self.translation
            base += f"/tlb:{t.entries}e{t.ways}w-{t.page_bytes}p"
            if t.l2_entries:
                base += f"+l2:{t.l2_entries}e"
        if self.scenario:
            base += f"/sv:{self.scenario}"
        return base


@dataclass
class SweepEntry:
    config: SweepConfig
    result: SimResult
    # The (workload, zipf)-scoped memo key this entry's embedding stats came
    # from — engine metadata (search groups by it; differential comparisons
    # ignore it), NOT part of the row() record.
    memo_key: Optional[tuple] = None

    def row(self) -> Dict:
        """Flat record: config fields + result summary (JSON/CSV friendly)."""
        d = dict(asdict(self.config))
        # Keep the record flat: the translation axis serializes to its
        # canonical key string ("" when off), not a nested dict.
        tr = self.config.translation
        d["translation"] = "" if tr is None else ":".join(map(str, tr.key))
        d.update(self.result.summary())
        return d


@dataclass
class SweepResult:
    entries: List[SweepEntry] = field(default_factory=list)
    wall_seconds: float = 0.0
    # Engine metadata (how the grid was evaluated — never affects entries):
    device_count: int = 1          # distinct JAX devices the sweep ran on
    sharded: bool = False          # memo-key space partitioned across devices
    distinct_memo_keys: int = 0    # classification+DRAM evaluations performed
    resumed_keys: int = 0          # memo keys restored from a checkpoint
    # How the sweep survived (or didn't need to survive) faults: retry /
    # failover / degraded-device counters + per-shard wall/retry stats.
    # All-zero on a fault-free run; never affects entries.
    telemetry: FaultTelemetry = field(default_factory=FaultTelemetry)

    @property
    def num_configs(self) -> int:
        return len(self.entries)

    def best(self, metric: str = "total_cycles", minimize: bool = True) -> SweepEntry:
        """Grid point optimizing a ``SimResult`` summary metric."""
        if not self.entries:
            raise ValueError("empty sweep")
        key = lambda e: e.result.summary()[metric]
        return min(self.entries, key=key) if minimize else max(self.entries, key=key)

    def rows(self) -> List[Dict]:
        return [e.row() for e in self.entries]

    def speedup_over(self, baseline_policy: str = "spm") -> List[Dict]:
        """Per-config speedup vs the same-(workload, capacity, ways, zipf)
        grid point under ``baseline_policy`` (the paper's Fig. 4b metric)."""
        base: Dict[tuple, float] = {}
        for e in self.entries:
            c = e.config
            if c.policy == baseline_policy:
                base[(c.workload, c.capacity_bytes, c.ways, c.zipf_s,
                      c.num_cores, c.topology, c.channel_affinity,
                      c.placement, _tr_key(c.translation),
                      c.scenario)] = e.result.total_cycles
        out = []
        for e in self.entries:
            c = e.config
            ref = base.get((c.workload, c.capacity_bytes, c.ways, c.zipf_s,
                            c.num_cores, c.topology, c.channel_affinity,
                            c.placement, _tr_key(c.translation), c.scenario))
            if ref is None:
                continue
            r = e.row()
            r[f"speedup_vs_{baseline_policy}"] = ref / max(e.result.total_cycles, 1e-12)
            out.append(r)
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "num_configs": self.num_configs,
            "wall_seconds": self.wall_seconds,
            "device_count": self.device_count,
            "sharded": self.sharded,
            "distinct_memo_keys": self.distinct_memo_keys,
            "resumed_keys": self.resumed_keys,
            "fault_telemetry": self.telemetry.to_dict(),
            "rows": self.rows(),
        }
        text = json.dumps(payload, indent=2)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text


def _as_tuple(x, default):
    if x is None:
        return tuple(default)
    if isinstance(x, (str, bytes)) or not isinstance(x, (list, tuple)):
        return (x,)
    return tuple(x)


def _resolve_axes(
    base_hw: HardwareConfig,
    policies,
    capacities,
    ways,
    num_cores,
    topologies,
    channel_affinities,
    placements,
    translations=None,
) -> Tuple[tuple, ...]:
    """Normalize + validate the eight hardware axes (shared by ``sweep`` and
    ``grid_configs`` so the exhaustive list can never drift from the engine).

    The translation axis is carried as canonical key tuples (``()`` = off),
    so combos stay hashable/sortable for memo keys and checkpoint
    fingerprints; entries must be ``TranslationConfig`` or ``None``."""
    pol_names = tuple(
        p.value if isinstance(p, OnChipPolicy) else str(p)
        for p in _as_tuple(policies, DEFAULT_POLICIES)
    )
    unknown = set(pol_names) - set(available_policies())
    if unknown:
        raise ValueError(f"unregistered policies: {sorted(unknown)}")
    caps = _as_tuple(capacities, (base_hw.onchip.capacity_bytes,))
    ways_t = _as_tuple(ways, (base_hw.onchip.ways,))
    cores_t = tuple(int(c) for c in _as_tuple(num_cores, (base_hw.num_cores,)))
    topo_t = tuple(
        Topology(t).value for t in _as_tuple(topologies, (base_hw.topology.value,))
    )
    aff_t = tuple(
        str(a) for a in _as_tuple(channel_affinities, (base_hw.channel_affinity,))
    )
    plc_t = tuple(str(p) for p in _as_tuple(placements, (base_hw.placement,)))
    tr_t = tuple(
        _tr_key(t) for t in _as_tuple(translations, (base_hw.translation,))
    )
    return pol_names, caps, ways_t, cores_t, topo_t, aff_t, plc_t, tr_t


def grid_configs(
    workloads: Union[Workload, Sequence[Workload]],
    base_hw: Optional[HardwareConfig] = None,
    policies: Sequence[Union[str, OnChipPolicy]] = DEFAULT_POLICIES,
    capacities: Optional[Sequence[int]] = None,
    ways: Optional[Sequence[int]] = None,
    zipf_s: Union[float, Sequence[float]] = 0.8,
    num_cores: Optional[Sequence[int]] = None,
    topologies: Optional[Sequence[Union[str, Topology]]] = None,
    channel_affinities: Optional[Sequence[str]] = None,
    placements: Optional[Sequence[str]] = None,
    translations: Optional[Sequence[Optional[TranslationConfig]]] = None,
) -> List[SweepConfig]:
    """The exhaustive ``SweepConfig`` list ``sweep()`` evaluates for these
    axes, in sweep entry order — ``sweep(wls, hw, configs=grid_configs(...))``
    is bitwise identical to the axes call (test-enforced). The search driver
    builds its starting population from this."""
    base_hw = base_hw or tpuv6e()
    wls = _as_tuple(workloads, ())
    if not wls:
        raise ValueError("need at least one workload")
    axes = _resolve_axes(base_hw, policies, capacities, ways, num_cores,
                         topologies, channel_affinities, placements,
                         translations)
    zipfs = _as_tuple(zipf_s, (0.8,))
    return [
        SweepConfig(
            policy=pol, capacity_bytes=cap, ways=w, workload=wl.name,
            zipf_s=z, num_cores=nc, topology=topo,
            channel_affinity=aff, placement=plc,
            translation=_tr_from_key(trk),
        )
        for wl in wls
        for z in zipfs
        for pol, cap, w, nc, topo, aff, plc, trk in itertools.product(*axes)
    ]


# --------------------------------------------------------------------------
# Slice planning: (workload, zipf) slices of the grid
# --------------------------------------------------------------------------

# One slice = every grid point sharing (workload, zipf): they share traces,
# the matrix summary, and the memo-key space. ``combos`` are the eight
# hardware-axis values per grid point (the last a canonical translation key
# tuple, ``()`` = off); ``indices`` the entries' positions in the final
# result (so an explicit ``configs`` list keeps its order).
_Combo = Tuple[str, int, int, int, str, str, str, tuple]


@dataclass
class _Slice:
    workload: Workload
    zipf_s: float
    combos: List[_Combo]
    indices: List[int]

    @property
    def slice_id(self) -> tuple:
        return (self.workload.name, float(self.zipf_s))


def _slices_from_axes(wls, zipfs, axes) -> List[_Slice]:
    combos = list(itertools.product(*axes))
    out, pos = [], 0
    for wl in wls:
        for z in zipfs:
            out.append(_Slice(wl, float(z), list(combos),
                              list(range(pos, pos + len(combos)))))
            pos += len(combos)
    return out


def _slices_from_configs(wls, configs: Sequence[SweepConfig]) -> List[_Slice]:
    by_name: Dict[str, Workload] = {}
    for wl in wls:
        if wl.name in by_name and by_name[wl.name] is not wl:
            raise ValueError(f"ambiguous workload name {wl.name!r}")
        by_name[wl.name] = wl
    unknown_pols = {c.policy for c in configs} - set(available_policies())
    if unknown_pols:
        raise ValueError(f"unregistered policies: {sorted(unknown_pols)}")
    slices: Dict[tuple, _Slice] = {}
    for i, c in enumerate(configs):
        wl = by_name.get(c.workload)
        if wl is None:
            raise ValueError(
                f"config references unknown workload {c.workload!r}; "
                f"known: {sorted(by_name)}"
            )
        sid = (c.workload, float(c.zipf_s))
        sl = slices.get(sid)
        if sl is None:
            sl = slices[sid] = _Slice(wl, float(c.zipf_s), [], [])
        sl.combos.append((c.policy, c.capacity_bytes, c.ways, c.num_cores,
                          Topology(c.topology).value, str(c.channel_affinity),
                          str(c.placement), _tr_key(c.translation)))
        sl.indices.append(i)
    return list(slices.values())


# --------------------------------------------------------------------------
# Memo-key grid construction (per slice)
# --------------------------------------------------------------------------

def _capacity_saturated(etraces, hw: HardwareConfig) -> bool:
    """True when ``hw``'s capacity covers every etrace's whole line footprint
    — a ``capacity_saturates`` policy then classifies identically for ANY
    capacity at or above it (PINNING pins all unique lines: every access
    hits, setup writes equal the footprint), so such capacities share one
    canonical memo key. Per-core shards only shrink the footprint, so the
    collapse holds for every cluster shape."""
    cap_units = hw.onchip.num_lines
    line = hw.onchip.line_bytes
    return all(et.unique_line_count(line) <= cap_units for et in etraces)


def _build_grid(base_hw: HardwareConfig, combos: Sequence[_Combo], etraces):
    """Resolve each combo to (hw, memo key); dedupe keys into ``pending``.

    The memo key splits into the placement-INVARIANT class key
    (classification + stats assembly never read the NUMA axes) plus the
    canonicalized placement axes. Classification runs once per class key;
    DRAM timing once per full key.
    """
    grid = []                        # (combo..., hw, key)
    pending: Dict[tuple, tuple] = {}  # key -> (ms, class_key)
    # Placement-collapse preconditions for this (workload, zipf) slice: a
    # single rank and a single table make the table_rank transform provably
    # equal to plain interleave for EVERY op (PlacementMap.effective_placement
    # — the transform itself dispatches on the same rule, so the collapse is
    # bitwise).
    plc_collapses = (
        base_hw.offchip.banks_per_channel == 1
        and all(et.spec.num_tables == 1 for et in etraces)
    )
    sat_memo: Dict[int, bool] = {}      # capacity -> footprint saturation
    tr_sat_memo: Dict[tuple, bool] = {}  # translation key -> TLB saturation
    line = base_hw.onchip.line_bytes
    for pol, cap, w, nc, topo, aff, plc, trk in combos:
        hw = base_hw.with_policy(
            OnChipPolicy(pol), capacity_bytes=cap, ways=w
        ).with_cluster(nc, topo).with_placement(aff, plc).with_translation(
            _tr_from_key(trk))
        ms = memory_system_for(hw)
        class_key = (pol, nc, topo, hw.lookup_sharding.value,
                     hw.onchip.policy_mix)
        # Canonicalize the sensitive parameters: a saturating policy's
        # capacity collapses to one marker once it covers the slice's whole
        # footprint (provably identical classification — test-enforced).
        sens = []
        for p in ms.policy.sensitive_params:
            v = getattr(hw.onchip, p)
            if (
                p == "capacity_bytes"
                and ms.policy.capacity_saturates
                and not hw.onchip.policy_mix
            ):
                sat = sat_memo.get(cap)
                if sat is None:
                    sat = sat_memo[cap] = _capacity_saturated(etraces, hw)
                if sat:
                    v = _CAP_SATURATED
            sens.append(v)
        class_key += tuple(sens)
        if ms.policy.uses_cache_engine:
            # Backends are bit-exact, but memoization must not hand a
            # "pallas" grid point stats computed by "scan" — the knob
            # is part of what the config requests.
            class_key += (hw.cache_backend,)
        if hw.onchip.policy_mix:
            # Mix groups may read parameters the default policy does
            # not (e.g. pinned tables under an SPM default).
            class_key += (cap, w)
        # Canonicalize the placement axes: with one core every affinity
        # collapses to a single channel group, and a degenerate table_rank
        # collapses to interleave — keying such points apart would re-time
        # provably identical DRAM traffic (e.g. the base-grid entry).
        key_aff = "symmetric" if nc == 1 else aff
        key_plc = plc
        if key_plc == "table_rank" and plc_collapses:
            key_plc = "interleave"
        # Canonicalize the translation axis: a TLB whose every set covers
        # the slice's page footprint never takes a non-compulsory miss, so
        # its charge collapses to first-touch-only walks — identical for
        # every saturated geometry sharing (page_bytes,
        # miss_latency_cycles). Checked against the FULL address trace's
        # unique pages, so it holds for any classified miss subsequence
        # (i.e. every policy/capacity of the slice) — see ``memory.tlb.
        # translation_saturated`` (collapse-is-bitwise test-enforced).
        key_tr = trk
        if trk:
            tcfg = hw.translation
            sat = tr_sat_memo.get(trk)
            if sat is None:
                sat = tr_sat_memo[trk] = all(
                    translation_saturated(
                        et.unique_pages(line, tcfg.page_bytes), tcfg)
                    for et in etraces)
            if sat:
                key_tr = (_TLB_SATURATED, tcfg.page_bytes,
                          tcfg.miss_latency_cycles)
        key = class_key + (key_aff, key_plc, key_tr)
        grid.append((pol, cap, w, nc, topo, aff, plc, trk, hw, key))
        if key not in pending:
            pending[key] = (ms, class_key)
    return grid, pending


# --------------------------------------------------------------------------
# Memo-key evaluation (classification + batched DRAM timing)
# --------------------------------------------------------------------------

def _evaluate_keys(
    etraces, items: Dict[tuple, tuple], batch_scans: bool, batch_dram: bool
) -> Dict[tuple, list]:
    """Evaluate a subset of memo keys: shared classification per class key,
    placement fan-out per full key, ONE batched DRAM dispatch for the lot.

    Self-contained in ``items`` — the sharded sweep calls it once per shard
    and the checkpointed sweep once per cadence round; results are bit-exact
    regardless of how the key space is split (every batching layer is
    composition-invariant, test-enforced).
    """
    class_systems: Dict[tuple, object] = {}
    for key, (ms, ck) in items.items():
        class_systems.setdefault(ck, ms)

    # Batched classification: distinct single-core cache-engine class keys of
    # ONE policy share a vmapped dispatch per scan shape — and, under the
    # stack backend, one analytic pass per (stream, num_sets)
    # (classify_embedding_many); everything else classifies per class key.
    # DRAM timing is deferred throughout.
    classified: Dict[tuple, list] = {}  # class_key -> per-etrace
    by_policy: Dict[str, list] = {}
    for ck, ms in class_systems.items():
        if (
            batch_scans
            and isinstance(ms, MemorySystem)
            and ms.policy.uses_cache_engine
            and not ms.hw.onchip.policy_mix
        ):
            by_policy.setdefault(ms.policy.name, []).append((ck, ms))
    for batch in by_policy.values():
        if len(batch) < 2:
            continue
        cks = [k for k, _ in batch]
        systems = [m for _, m in batch]
        per_ck = [[] for _ in systems]
        for et in etraces:
            for i, cs in enumerate(classify_embedding_many(systems, et)):
                per_ck[i].append(cs)
        for ck, css in zip(cks, per_ck):
            classified[ck] = css
    for ck, ms in class_systems.items():
        if ck not in classified:
            classified[ck] = [ms.classify_for_pending(et) for et in etraces]

    # Placement fan-out: every full key packages ITS OWN placement transform
    # of the shared classification into a deferred DRAM request — so
    # placement siblings ride the same size-bucketed dram_timing_many
    # dispatch as the base grid.
    prepared: Dict[tuple, list] = {
        key: [
            ms.pending_from(et, cl)
            for et, cl in zip(etraces, classified[ck])
        ]
        for key, (ms, ck) in items.items()
    }

    # Cross-memo-key DRAM batching: every deferred miss-trace dispatch of
    # this key subset — all policies, geometries, and cluster shapes — runs
    # through ONE dram_timing_many call. Per-request results are bitwise
    # identical to unbatched dispatch (batch_dram=False is that reference
    # path; test-enforced).
    key_order = list(prepared)
    all_pending = [p for k in key_order for p in prepared[k]]
    outs = iter(dram_timing_many(
        [p.request for p in all_pending], batch=batch_dram
    ))
    return {k: [p.finalize(*next(outs)) for p in prepared[k]] for k in key_order}


def _chunks(items: Dict[tuple, tuple], cadence: Optional[int]):
    """Split the todo keys into cadence-sized rounds (insertion order)."""
    keys = list(items)
    if not cadence or cadence <= 0 or cadence >= len(keys):
        if keys:
            yield items
        return
    for i in range(0, len(keys), cadence):
        yield {k: items[k] for k in keys[i:i + cadence]}


def _prewarm_traces(etraces, base_hw: HardwareConfig, combos) -> None:
    """Materialize the lazily cached derived streams BEFORE shard threads
    start, so concurrent workers never duplicate the (deterministic but
    expensive) trace work. Line geometry is grid-invariant (``with_policy``
    never touches ``line_bytes``)."""
    line = base_hw.onchip.line_bytes
    any_hot = any(c[6] == "hot_replicate" for c in combos)
    for et in etraces:
        et.lookup_batch
        et.vec_ids
        et.address_trace(line)
        if any_hot:
            et.hot_vec_ids


def sweep(
    workloads: Union[Workload, Sequence[Workload]],
    base_hw: Optional[HardwareConfig] = None,
    policies: Sequence[Union[str, OnChipPolicy]] = DEFAULT_POLICIES,
    capacities: Optional[Sequence[int]] = None,
    ways: Optional[Sequence[int]] = None,
    zipf_s: Union[float, Sequence[float]] = 0.8,
    seed: int = 0,
    index_trace: Optional[np.ndarray] = None,
    energy_table: EnergyTable = EnergyTable(),
    num_cores: Optional[Sequence[int]] = None,
    topologies: Optional[Sequence[Union[str, Topology]]] = None,
    channel_affinities: Optional[Sequence[str]] = None,
    placements: Optional[Sequence[str]] = None,
    translations: Optional[Sequence[Optional[TranslationConfig]]] = None,
    batch_scans: bool = True,
    batch_dram: bool = True,
    configs: Optional[Sequence[SweepConfig]] = None,
    devices=None,
    checkpoint: Union[SweepCheckpoint, str, None] = None,
    fault_tolerance: Optional[FaultTolerance] = None,
    fault_plan: Optional[FaultPlan] = None,
    fault_telemetry: Optional[FaultTelemetry] = None,
    scenarios: Optional[Sequence] = None,
) -> SweepResult:
    """Evaluate the (workload x zipf x policy x capacity x ways x num_cores
    x topology x channel_affinity x placement x translation) grid.

    Every grid point's ``SimResult`` is bit-exact against
    ``simulate(workload, base_hw.with_policy(policy, capacity_bytes=...,
    ways=...).with_cluster(num_cores, topology).with_placement(affinity,
    placement).with_translation(translation), seed=seed, zipf_s=z)`` — the
    sweep only removes redundant work, never changes the model.

    ``translations`` sweeps the address-translation layer
    (``TranslationConfig`` entries; ``None`` = translation off, the exact
    pre-translation engine). Translation is a pure charge on the classified
    miss stream, so translation siblings share one classification, and two
    memo-key collapses apply: ``None`` keys exactly like the base grid, and
    any TLB whose reach saturates the slice's page footprint collapses to a
    first-touch-only marker (bitwise — test-enforced).

    ``configs`` replaces the axis grid with an explicit ``SweepConfig`` list
    (entry order preserved; the search driver's evaluation path).

    ``devices`` shards the memo-key space: an int takes that many shards over
    the local JAX devices (cycled when fewer exist), a device sequence pins
    one shard per device. Shards evaluate concurrently (one thread per
    shard, jit dispatch pinned via ``jax.default_device``) and results are
    bitwise identical to the unsharded path.

    ``checkpoint`` (a ``SweepCheckpoint`` or journal path) makes the sweep
    restartable: memo keys journal in ``cadence``-sized rounds, a resumed
    sweep restores finished keys and is bitwise identical to an
    uninterrupted run.

    ``fault_tolerance`` (default ``FaultTolerance()``) sets the recovery
    policy for sharded execution: transient retries with seeded backoff,
    the per-shard heartbeat watchdog (``shard_timeout_s``), and failover of
    crashed/hung shards onto surviving devices — every recovery path
    bitwise identical to the fault-free run (``strict=True`` raises
    instead of degrading). ``fault_plan`` injects a deterministic fault
    schedule (tests / chaos CI only — see ``core.faults``); ``fault_
    telemetry`` supplies the counter sink (pass one in to read telemetry
    even when the sweep raises), otherwise a fresh ``FaultTelemetry`` is
    created. Either way the counters land on ``SweepResult.telemetry``.

    ``scenarios`` (a ``serving.scheduler.ServingScenario`` list) switches
    the sweep to *serving* mode: each grid point is (hardware axes x
    scenario), every entry's result a ``ServingResult`` from the
    closed-loop request-level simulator (traffic pattern x robustness
    policy as first-class DSE axes). Serving sweeps ride the same
    sharding/checkpointing/fault-tolerance machinery — memo keys are
    (hardware combo, scenario key); journaled per-batch stats reconstruct
    the ``ServingResult`` bitwise through a replay of the deterministic
    scheduler. ``zipf_s``/``seed``/``index_trace`` do not apply (each
    scenario's ``TrafficConfig`` carries its own popularity model + seed).
    """
    base_hw = base_hw or tpuv6e()
    wls = _as_tuple(workloads, ())
    if not wls:
        raise ValueError("need at least one workload")

    if scenarios is not None:
        if configs is not None:
            raise ValueError("scenarios= and configs= cannot be combined")
        if index_trace is not None:
            raise ValueError(
                "scenarios= generates request-driven traces; index_trace= "
                "does not apply to serving sweeps")
        axes = _resolve_axes(base_hw, policies, capacities, ways, num_cores,
                             topologies, channel_affinities, placements,
                             translations)
        return _sweep_serving(
            wls, base_hw, axes, tuple(scenarios),
            devices=devices, checkpoint=checkpoint,
            fault_tolerance=fault_tolerance, fault_plan=fault_plan,
            fault_telemetry=fault_telemetry,
        )

    if configs is not None:
        slices = _slices_from_configs(wls, list(configs))
        num_entries = len(configs)
    else:
        axes = _resolve_axes(base_hw, policies, capacities, ways, num_cores,
                             topologies, channel_affinities, placements,
                             translations)
        zipfs = _as_tuple(zipf_s, (0.8,))
        slices = _slices_from_axes(wls, zipfs, axes)
        num_entries = sum(len(s.combos) for s in slices)

    shard_plan = None
    if devices is not None:
        from ..distributed.sweep_shard import resolve_shard_plan
        shard_plan = resolve_shard_plan(devices)

    tol = fault_tolerance if fault_tolerance is not None else FaultTolerance()
    telemetry = (fault_telemetry if fault_telemetry is not None
                 else FaultTelemetry())
    injector: Optional[FaultInjector] = None
    if fault_plan is not None:
        if shard_plan is None and fault_plan.has_shard_events():
            raise ValueError(
                "fault_plan schedules shard events but the sweep is not "
                "sharded — pass devices= so the plan's shard coordinates "
                "mean something")
        if fault_plan.has_kind("hang") and tol.shard_timeout_s is None:
            raise ValueError(
                "fault_plan injects hangs but no watchdog is armed — set "
                "FaultTolerance.shard_timeout_s or the sweep deadlocks")
        injector = FaultInjector(fault_plan, telemetry)

    ckpt: Optional[SweepCheckpoint] = None
    if checkpoint is not None:
        ckpt = (checkpoint if isinstance(checkpoint, SweepCheckpoint)
                else SweepCheckpoint(checkpoint))
        ckpt.open(_fingerprint(wls, base_hw, seed, slices, index_trace,
                               energy_table))
        ckpt.fault_injector = injector

    t0 = time.perf_counter()
    out = SweepResult()
    out.telemetry = telemetry
    if shard_plan is not None:
        out.sharded = True
        out.device_count = shard_plan.distinct_devices
    entries: List[Optional[SweepEntry]] = [None] * num_entries
    matrix_memo: Dict[int, object] = {}
    try:
        for sl in slices:
            wl, z = sl.workload, sl.zipf_s
            # Matrix side ignores the swept on-chip parameters — once per
            # workload.
            matrix = matrix_memo.get(id(wl))
            if matrix is None:
                matrix = matrix_memo[id(wl)] = summarize_matrix_ops(wl, base_hw)
            # Traces depend only on (workload, seed, zipf) — shared across
            # every grid point below.
            etraces = build_embedding_traces(wl, index_trace, seed, z)
            grid, pending = _build_grid(base_hw, sl.combos, etraces)
            out.distinct_memo_keys += len(pending)

            # Restore journaled keys; only the remainder is (re)evaluated.
            stats_memo: Dict[tuple, list] = {}
            if ckpt is not None:
                for key in pending:
                    restored = ckpt.lookup(sl.slice_id, key)
                    if restored is not None:
                        stats_memo[key] = restored
                out.resumed_keys += len(stats_memo)
            todo = {k: v for k, v in pending.items() if k not in stats_memo}

            if shard_plan is not None and todo:
                _prewarm_traces(etraces, base_hw, sl.combos)
            cadence = ckpt.cadence if ckpt is not None else None
            for round_items in _chunks(todo, cadence):
                if injector is not None:
                    injector.begin_round()
                # Single-key rounds normally skip sharding (thread overhead
                # for nothing), but an armed injector forces the supervised
                # path so (shard, round) coordinates stay meaningful.
                if shard_plan is not None and (
                    len(round_items) > 1 or injector is not None
                ):
                    from ..distributed.sweep_shard import evaluate_sharded
                    try:
                        results = evaluate_sharded(
                            round_items, shard_plan,
                            lambda sub: _evaluate_keys(
                                etraces, sub, batch_scans, batch_dram
                            ),
                            tolerance=tol,
                            injector=injector,
                            telemetry=telemetry,
                        )
                    except ShardEvaluationError as exc:
                        # Completed sibling-shard results are journaled
                        # before the fatal error propagates, so a rerun
                        # resumes past the surviving work.
                        if ckpt is not None and exc.completed:
                            ckpt.record(sl.slice_id, exc.completed)
                        raise
                else:
                    results = _evaluate_keys(
                        etraces, round_items, batch_scans, batch_dram
                    )
                stats_memo.update(results)
                if ckpt is not None:
                    ckpt.record(sl.slice_id, results)

            for idx, (pol, cap, w, nc, topo, aff, plc, trk, hw, key) in zip(
                sl.indices, grid
            ):
                res = assemble_result(
                    wl, hw, matrix, stats_memo[key], energy_table
                )
                entries[idx] = SweepEntry(
                    config=SweepConfig(
                        policy=pol,
                        capacity_bytes=cap,
                        ways=w,
                        workload=wl.name,
                        zipf_s=z,
                        num_cores=nc,
                        topology=topo,
                        channel_affinity=aff,
                        placement=plc,
                        translation=_tr_from_key(trk),
                    ),
                    result=res,
                    memo_key=sl.slice_id + key,
                )
        out.entries = [e for e in entries if e is not None]
        if ckpt is not None:
            ckpt.mark_complete(len(out.entries))
    finally:
        if ckpt is not None and not isinstance(checkpoint, SweepCheckpoint):
            ckpt.close()
    out.wall_seconds = time.perf_counter() - t0
    return out


def _fingerprint(wls, base_hw, seed, slices, index_trace, energy_table) -> Dict:
    """Everything that determines sweep RESULTS (not how they are computed:
    batching, sharding, and cadence are bit-exact and excluded) — a resumed
    checkpoint must match it exactly."""
    import hashlib

    it_digest = None
    if index_trace is not None:
        it_digest = hashlib.sha256(
            np.ascontiguousarray(index_trace).tobytes()
        ).hexdigest()
    return {
        "workloads": sorted(repr(wl) for wl in wls),
        "base_hw": repr(base_hw),
        "seed": int(seed),
        "slices": [
            [sl.slice_id[0], sl.slice_id[1], sorted(map(list, set(sl.combos)))]
            for sl in slices
        ],
        "index_trace": it_digest,
        "energy_table": repr(energy_table),
    }


# --------------------------------------------------------------------------
# Serving-scenario sweeps (traffic pattern x robustness policy axes)
# --------------------------------------------------------------------------

def _serving_fingerprint(wls, base_hw, combos, scenarios) -> Dict:
    """Everything that determines serving-sweep RESULTS: workloads, base
    hardware, the hardware-combo grid, and each scenario's full key (traffic
    + robustness policy + batch geometry). Sharding/cadence excluded — the
    scheduler is deterministic and replay is bitwise."""
    return {
        "mode": "serving",
        "workloads": sorted(repr(wl) for wl in wls),
        "base_hw": repr(base_hw),
        "combos": sorted(map(list, set(combos))),
        "scenarios": [list(s.key) for s in scenarios],
    }


def _sweep_serving(
    wls,
    base_hw: HardwareConfig,
    axes,
    scenarios,
    devices=None,
    checkpoint: Union[SweepCheckpoint, str, None] = None,
    fault_tolerance: Optional[FaultTolerance] = None,
    fault_plan: Optional[FaultPlan] = None,
    fault_telemetry: Optional[FaultTelemetry] = None,
) -> SweepResult:
    """Serving-mode sweep driver: (hardware combo x scenario) grid over the
    closed-loop request-level simulator.

    Memo keys are (combo..., scenario.key) — no canonicalization: serving
    traces are schedule-dependent, so the fixed-trace collapses
    (capacity saturation, placement identity) are not provably safe here.
    The shard group key is the hardware combo, co-locating one config's
    scenarios on a shard. The journal stores each key's per-batch
    ``EmbeddingBatchStats`` (the existing checkpoint schema, outer list of
    length 1); restored keys reconstruct their ``ServingResult`` bitwise by
    replaying the deterministic scheduler against the recorded stats."""
    from ..serving.scheduler import ReplayOracle, simulate_serving
    from .requests import generate_requests

    names = [s.name for s in scenarios]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate scenario names: {sorted(names)}")
    combos = list(itertools.product(*axes))

    shard_plan = None
    if devices is not None:
        from ..distributed.sweep_shard import resolve_shard_plan
        shard_plan = resolve_shard_plan(devices)

    tol = fault_tolerance if fault_tolerance is not None else FaultTolerance()
    telemetry = (fault_telemetry if fault_telemetry is not None
                 else FaultTelemetry())
    injector: Optional[FaultInjector] = None
    if fault_plan is not None:
        if shard_plan is None and fault_plan.has_shard_events():
            raise ValueError(
                "fault_plan schedules shard events but the sweep is not "
                "sharded — pass devices= so the plan's shard coordinates "
                "mean something")
        if fault_plan.has_kind("hang") and tol.shard_timeout_s is None:
            raise ValueError(
                "fault_plan injects hangs but no watchdog is armed — set "
                "FaultTolerance.shard_timeout_s or the sweep deadlocks")
        injector = FaultInjector(fault_plan, telemetry)

    ckpt: Optional[SweepCheckpoint] = None
    if checkpoint is not None:
        ckpt = (checkpoint if isinstance(checkpoint, SweepCheckpoint)
                else SweepCheckpoint(checkpoint))
        ckpt.open(_serving_fingerprint(wls, base_hw, combos, scenarios))
        ckpt.fault_injector = injector

    t0 = time.perf_counter()
    out = SweepResult()
    out.telemetry = telemetry
    if shard_plan is not None:
        out.sharded = True
        out.device_count = shard_plan.distinct_devices

    def _eval_serving(sub: Dict[tuple, tuple]) -> Dict[tuple, list]:
        res = {}
        for key, (payload, _gk) in sub.items():
            ms, spec, sc, reqs = payload
            res[key] = [simulate_serving(ms, spec, sc,
                                         requests=reqs).batch_stats]
        return res

    try:
        for wl in wls:
            if not wl.embedding_ops:
                raise ValueError(
                    f"workload {wl.name!r} has no embedding op to serve")
            spec = wl.embedding_ops[0]
            slice_id = (wl.name, "__serving__")
            # One request stream per distinct traffic config, shared by
            # every hardware combo (and every policy over that traffic) —
            # generated up front so shard threads never duplicate it.
            streams = {}
            for sc in scenarios:
                if sc.traffic.key not in streams:
                    streams[sc.traffic.key] = generate_requests(spec,
                                                                sc.traffic)

            grid = []                         # (combo, hw, ms, scenario, key)
            pending: Dict[tuple, tuple] = {}  # key -> (payload, group_key)
            for combo in combos:
                pol, cap, w, nc, topo, aff, plc, trk = combo
                hw = base_hw.with_policy(
                    OnChipPolicy(pol), capacity_bytes=cap, ways=w
                ).with_cluster(nc, topo).with_placement(aff, plc) \
                 .with_translation(_tr_from_key(trk))
                ms = memory_system_for(hw)
                for sc in scenarios:
                    key = combo + (sc.key,)
                    grid.append((combo, hw, ms, sc, key))
                    if key not in pending:
                        pending[key] = (
                            (ms, spec, sc, streams[sc.traffic.key]), combo)
            out.distinct_memo_keys += len(pending)

            stats_memo: Dict[tuple, list] = {}
            if ckpt is not None:
                for key in pending:
                    restored = ckpt.lookup(slice_id, key)
                    if restored is not None:
                        stats_memo[key] = restored
                out.resumed_keys += len(stats_memo)
            todo = {k: v for k, v in pending.items() if k not in stats_memo}

            cadence = ckpt.cadence if ckpt is not None else None
            for round_items in _chunks(todo, cadence):
                if injector is not None:
                    injector.begin_round()
                if shard_plan is not None and (
                    len(round_items) > 1 or injector is not None
                ):
                    from ..distributed.sweep_shard import evaluate_sharded
                    try:
                        results = evaluate_sharded(
                            round_items, shard_plan, _eval_serving,
                            tolerance=tol,
                            injector=injector,
                            telemetry=telemetry,
                        )
                    except ShardEvaluationError as exc:
                        if ckpt is not None and exc.completed:
                            ckpt.record(slice_id, exc.completed)
                        raise
                else:
                    results = _eval_serving(round_items)
                stats_memo.update(results)
                if ckpt is not None:
                    ckpt.record(slice_id, results)

            # Entry assembly: replay the deterministic scheduler against
            # each key's recorded stats — identical whether the stats were
            # just evaluated or restored from the journal.
            for combo, hw, ms, sc, key in grid:
                pol, cap, w, nc, topo, aff, plc, trk = combo
                res = simulate_serving(
                    ms, spec, sc, requests=streams[sc.traffic.key],
                    oracle=ReplayOracle(stats_memo[key][0]),
                )
                out.entries.append(SweepEntry(
                    config=SweepConfig(
                        policy=pol, capacity_bytes=cap, ways=w,
                        workload=wl.name, zipf_s=float(sc.traffic.zipf_s),
                        num_cores=nc, topology=topo, channel_affinity=aff,
                        placement=plc, translation=_tr_from_key(trk),
                        scenario=sc.name,
                    ),
                    result=res,
                    memo_key=slice_id + key,
                ))
        if ckpt is not None:
            ckpt.mark_complete(len(out.entries))
    finally:
        if ckpt is not None and not isinstance(checkpoint, SweepCheckpoint):
            ckpt.close()
    out.wall_seconds = time.perf_counter() - t0
    return out
