"""Batched design-space-exploration (DSE) sweep engine.

EONSim's stated purpose is "to enable flexible exploration and design of
emerging NPU architectures". A DSE study evaluates a *grid* of memory-system
configurations — on-chip policy x capacity x associativity x workload x reuse
level — and calling ``simulate()`` per point repeats all the
hardware-independent work N times. ``sweep()`` evaluates the whole grid in
one pass while staying **bit-exact** with independent ``simulate()`` calls
(tests enforce this per config):

  * **Trace sharing** — index-trace generation + multi-table expansion +
    concatenation (``EmbeddingTrace``) depend only on (workload, seed,
    zipf_s), so they are built once per (workload, reuse level) and shared by
    every (policy, capacity, ways) point. The derived vector-id stream and
    line-address trace are cached inside the ``EmbeddingTrace`` too.
  * **Matrix-model sharing** — the analytical matrix model is independent of
    the swept on-chip parameters (policy/capacity/ways), so it runs once per
    workload.
  * **Compiled-scan reuse** — the cache engine buckets scan lengths to powers
    of two and the segmented DRAM scan pads (segment, channel) slots the same
    way, so JAX jit caches are shared across grid points with the same
    (ways, policy) shape signature instead of recompiling per config.
  * **Vmapped scan batching** — all distinct single-core grid points of one
    cache-engine policy classify through ``classify_embedding_many``: their
    set-group sub-scans are bucketed by padded shape and each bucket runs as
    ONE vmapped dispatch instead of one dispatch per (config, group)
    (``batch_scans=False`` falls back to per-config scans; results are
    bit-exact either way).
  * **Analytic classification sharing** — under the default
    ``cache_backend="stack"`` every cache-engine policy classifies
    analytically: LRU from one stack-distance pass per (stream, num_sets)
    covering EVERY associativity in the grid (Mattson inclusion), srrip/fifo
    from shared compressed per-set passes (``memory.rrip``) batched across
    configs — no sequential scan on the sweep path at all.
  * **Placement-invariant classification** — the NUMA axes
    (``channel_affinity`` / ``placement``) only remap miss-line addresses on
    the way to DRAM, so grid points differing only in those axes share ONE
    classification (``classify_for_pending``) and fan out per-placement DRAM
    requests from it (``pending_from``); configs whose placement transform
    is provably the identity for the topology collapse onto the base-grid
    memo entry outright.
  * **Cross-config DRAM batching** — classification and DRAM timing are
    decoupled (``PendingEmbedding``): every memo key's miss-trace dispatch
    of a (workload, zipf) slice runs through ONE ``dram_timing_many`` call,
    bit-exact vs per-key dispatch (``batch_dram=False`` is that reference
    path).

The grid also spans the CoreCluster axes: ``num_cores`` and ``topologies``
(private per-core on-chip vs shared LLC) sweep through the multi-core
MemorySystem with shared-DRAM contention — and the NUMA placement axes
``channel_affinities`` / ``placements`` (symmetric | per_core | per_table x
interleave | table_rank | hot_replicate), which participate in the memo keys
and ride the same batched ``dram_timing_many`` dispatch (placement is pure
address remapping upstream of DRAM timing).

Typical use (the paper's Fig. 4 case study is one call — see
``examples/fig4_sweep.py``)::

    result = sweep(
        workload,
        base_hw=tpuv6e(),
        policies=("spm", "lru", "srrip", "pinning"),
        capacities=(1 << 20, 4 << 20, 16 << 20),
        ways=(8, 16),
    )
    best = result.best("total_cycles")
"""
from __future__ import annotations

import itertools
import json
import time
from dataclasses import asdict, dataclass, field
from typing import Dict, List, Optional, Sequence, Union

import numpy as np

from .energy import EnergyTable
from .engine import (
    assemble_result,
    build_embedding_traces,
    summarize_matrix_ops,
)
from .hardware import HardwareConfig, OnChipPolicy, Topology, tpuv6e
from .memory.dram import dram_timing_many
from .memory.policies import available_policies
from .memory.system import (
    MemorySystem,
    classify_embedding_many,
    memory_system_for,
)
from .results import SimResult
from .workload import Workload

DEFAULT_POLICIES = ("spm", "lru", "srrip", "fifo", "pinning")


@dataclass(frozen=True)
class SweepConfig:
    """One grid point of the design space."""

    policy: str
    capacity_bytes: int
    ways: int
    workload: str
    zipf_s: float
    num_cores: int = 1
    topology: str = "private"
    channel_affinity: str = "symmetric"
    placement: str = "interleave"

    @property
    def label(self) -> str:
        cap_mb = self.capacity_bytes / (1 << 20)
        base = f"{self.workload}/{self.policy}/{cap_mb:g}MB/{self.ways}w/z{self.zipf_s:g}"
        if self.num_cores != 1 or self.topology != "private":
            base += f"/{self.num_cores}c-{self.topology}"
        if self.channel_affinity != "symmetric" or self.placement != "interleave":
            base += f"/{self.channel_affinity}-{self.placement}"
        return base


@dataclass
class SweepEntry:
    config: SweepConfig
    result: SimResult

    def row(self) -> Dict:
        """Flat record: config fields + result summary (JSON/CSV friendly)."""
        d = dict(asdict(self.config))
        d.update(self.result.summary())
        return d


@dataclass
class SweepResult:
    entries: List[SweepEntry] = field(default_factory=list)
    wall_seconds: float = 0.0

    @property
    def num_configs(self) -> int:
        return len(self.entries)

    def best(self, metric: str = "total_cycles", minimize: bool = True) -> SweepEntry:
        """Grid point optimizing a ``SimResult`` summary metric."""
        if not self.entries:
            raise ValueError("empty sweep")
        key = lambda e: e.result.summary()[metric]
        return min(self.entries, key=key) if minimize else max(self.entries, key=key)

    def rows(self) -> List[Dict]:
        return [e.row() for e in self.entries]

    def speedup_over(self, baseline_policy: str = "spm") -> List[Dict]:
        """Per-config speedup vs the same-(workload, capacity, ways, zipf)
        grid point under ``baseline_policy`` (the paper's Fig. 4b metric)."""
        base: Dict[tuple, float] = {}
        for e in self.entries:
            c = e.config
            if c.policy == baseline_policy:
                base[(c.workload, c.capacity_bytes, c.ways, c.zipf_s,
                      c.num_cores, c.topology, c.channel_affinity,
                      c.placement)] = e.result.total_cycles
        out = []
        for e in self.entries:
            c = e.config
            ref = base.get((c.workload, c.capacity_bytes, c.ways, c.zipf_s,
                            c.num_cores, c.topology, c.channel_affinity,
                            c.placement))
            if ref is None:
                continue
            r = e.row()
            r[f"speedup_vs_{baseline_policy}"] = ref / max(e.result.total_cycles, 1e-12)
            out.append(r)
        return out

    def to_json(self, path: Optional[str] = None) -> str:
        payload = {
            "num_configs": self.num_configs,
            "wall_seconds": self.wall_seconds,
            "rows": self.rows(),
        }
        text = json.dumps(payload, indent=2)
        if path:
            with open(path, "w") as f:
                f.write(text)
        return text


def _as_tuple(x, default):
    if x is None:
        return tuple(default)
    if isinstance(x, (str, bytes)) or not isinstance(x, (list, tuple)):
        return (x,)
    return tuple(x)


def sweep(
    workloads: Union[Workload, Sequence[Workload]],
    base_hw: Optional[HardwareConfig] = None,
    policies: Sequence[Union[str, OnChipPolicy]] = DEFAULT_POLICIES,
    capacities: Optional[Sequence[int]] = None,
    ways: Optional[Sequence[int]] = None,
    zipf_s: Union[float, Sequence[float]] = 0.8,
    seed: int = 0,
    index_trace: Optional[np.ndarray] = None,
    energy_table: EnergyTable = EnergyTable(),
    num_cores: Optional[Sequence[int]] = None,
    topologies: Optional[Sequence[Union[str, Topology]]] = None,
    channel_affinities: Optional[Sequence[str]] = None,
    placements: Optional[Sequence[str]] = None,
    batch_scans: bool = True,
    batch_dram: bool = True,
) -> SweepResult:
    """Evaluate the (workload x zipf x policy x capacity x ways x num_cores
    x topology x channel_affinity x placement) grid.

    Every grid point's ``SimResult`` is bit-exact against
    ``simulate(workload, base_hw.with_policy(policy, capacity_bytes=...,
    ways=...).with_cluster(num_cores, topology).with_placement(affinity,
    placement), seed=seed, zipf_s=z)`` — the sweep only removes redundant
    work, never changes the model.
    """
    base_hw = base_hw or tpuv6e()
    wls = _as_tuple(workloads, ())
    if not wls:
        raise ValueError("need at least one workload")
    pol_names = tuple(
        p.value if isinstance(p, OnChipPolicy) else str(p)
        for p in _as_tuple(policies, DEFAULT_POLICIES)
    )
    unknown = set(pol_names) - set(available_policies())
    if unknown:
        raise ValueError(f"unregistered policies: {sorted(unknown)}")
    caps = _as_tuple(capacities, (base_hw.onchip.capacity_bytes,))
    ways_t = _as_tuple(ways, (base_hw.onchip.ways,))
    zipfs = _as_tuple(zipf_s, (0.8,))
    cores_t = tuple(int(c) for c in _as_tuple(num_cores, (base_hw.num_cores,)))
    topo_t = tuple(
        Topology(t).value for t in _as_tuple(topologies, (base_hw.topology.value,))
    )
    aff_t = tuple(
        str(a) for a in _as_tuple(channel_affinities, (base_hw.channel_affinity,))
    )
    plc_t = tuple(str(p) for p in _as_tuple(placements, (base_hw.placement,)))

    t0 = time.perf_counter()
    out = SweepResult()
    for wl in wls:
        # Matrix side ignores the swept on-chip parameters — once per workload.
        matrix = summarize_matrix_ops(wl, base_hw)
        for z in zipfs:
            # Traces depend only on (workload, seed, zipf) — shared across
            # every grid point below.
            etraces = build_embedding_traces(wl, index_trace, seed, z)
            # Grid points that agree on every parameter the policy actually
            # reads (MemoryPolicy.sensitive_params) plus the cluster shape
            # produce byte-identical embedding stats — e.g. single-core SPM
            # is capacity/ways-invariant, PINNING ways-invariant — so
            # classification + DRAM run once per key.
            stats_memo: Dict[tuple, list] = {}
            grid = []
            pending: Dict[tuple, tuple] = {}    # key -> (ms, class_key)
            class_systems: Dict[tuple, object] = {}  # class_key -> system
            # Placement-collapse preconditions for this (workload, zipf)
            # slice: a single rank and a single table make the table_rank
            # transform provably equal to plain interleave for EVERY op
            # (PlacementMap.effective_placement — the transform itself
            # dispatches on the same rule, so the collapse is bitwise).
            plc_collapses = (
                base_hw.offchip.banks_per_channel == 1
                and all(et.spec.num_tables == 1 for et in etraces)
            )
            for pol, cap, w, nc, topo, aff, plc in itertools.product(
                pol_names, caps, ways_t, cores_t, topo_t, aff_t, plc_t
            ):
                hw = base_hw.with_policy(
                    OnChipPolicy(pol), capacity_bytes=cap, ways=w
                ).with_cluster(nc, topo).with_placement(aff, plc)
                ms = memory_system_for(hw)
                # The memo key splits into the placement-INVARIANT class key
                # (classification + stats assembly never read the NUMA axes)
                # plus the canonicalized placement axes. Classification runs
                # once per class key; DRAM timing once per full key.
                class_key = (pol, nc, topo, hw.lookup_sharding.value,
                             hw.onchip.policy_mix)
                class_key += tuple(
                    getattr(hw.onchip, p) for p in ms.policy.sensitive_params
                )
                if ms.policy.uses_cache_engine:
                    # Backends are bit-exact, but memoization must not hand a
                    # "pallas" grid point stats computed by "scan" — the knob
                    # is part of what the config requests.
                    class_key += (hw.cache_backend,)
                if hw.onchip.policy_mix:
                    # Mix groups may read parameters the default policy does
                    # not (e.g. pinned tables under an SPM default).
                    class_key += (cap, w)
                # Canonicalize the placement axes: with one core every
                # affinity collapses to a single channel group, and a
                # degenerate table_rank collapses to interleave — keying
                # such points apart would re-time provably identical DRAM
                # traffic (e.g. the base-grid entry).
                key_aff = "symmetric" if nc == 1 else aff
                key_plc = plc
                if key_plc == "table_rank" and plc_collapses:
                    key_plc = "interleave"
                key = class_key + (key_aff, key_plc)
                grid.append((pol, cap, w, nc, topo, aff, plc, hw, key))
                if key not in pending:
                    pending[key] = (ms, class_key)
                    class_systems.setdefault(class_key, ms)

            # Batched classification: distinct single-core cache-engine class
            # keys of ONE policy share a vmapped dispatch per scan shape —
            # and, under the stack backend, one analytic pass per
            # (stream, num_sets) (classify_embedding_many); everything else
            # classifies per class key. DRAM timing is deferred throughout.
            classified: Dict[tuple, list] = {}  # class_key -> per-etrace
            by_policy: Dict[str, list] = {}
            for ck, ms in class_systems.items():
                if (
                    batch_scans
                    and isinstance(ms, MemorySystem)
                    and ms.policy.uses_cache_engine
                    and not ms.hw.onchip.policy_mix
                ):
                    by_policy.setdefault(ms.policy.name, []).append((ck, ms))
            for batch in by_policy.values():
                if len(batch) < 2:
                    continue
                cks = [k for k, _ in batch]
                systems = [m for _, m in batch]
                per_ck = [[] for _ in systems]
                for et in etraces:
                    for i, cs in enumerate(
                        classify_embedding_many(systems, et)
                    ):
                        per_ck[i].append(cs)
                for ck, css in zip(cks, per_ck):
                    classified[ck] = css
            for ck, ms in class_systems.items():
                if ck not in classified:
                    classified[ck] = [
                        ms.classify_for_pending(et) for et in etraces
                    ]

            # Placement fan-out: every full key packages ITS OWN placement
            # transform of the shared classification into a deferred DRAM
            # request — so placement siblings ride the same size-bucketed
            # dram_timing_many dispatch as the base grid.
            prepared: Dict[tuple, list] = {
                key: [
                    ms.pending_from(et, cl)
                    for et, cl in zip(etraces, classified[ck])
                ]
                for key, (ms, ck) in pending.items()
            }

            # Cross-memo-key DRAM batching: every deferred miss-trace dispatch
            # of this (workload, zipf) slice — all policies, geometries, and
            # cluster shapes — runs through ONE dram_timing_many call.
            # Per-request results are bitwise identical to unbatched dispatch
            # (batch_dram=False is that reference path; test-enforced).
            key_order = list(prepared)
            all_pending = [p for k in key_order for p in prepared[k]]
            outs = iter(dram_timing_many(
                [p.request for p in all_pending], batch=batch_dram
            ))
            for k in key_order:
                stats_memo[k] = [p.finalize(*next(outs)) for p in prepared[k]]

            for pol, cap, w, nc, topo, aff, plc, hw, key in grid:
                res = assemble_result(
                    wl, hw, matrix, stats_memo[key], energy_table
                )
                out.entries.append(SweepEntry(
                    config=SweepConfig(
                        policy=pol,
                        capacity_bytes=cap,
                        ways=w,
                        workload=wl.name,
                        zipf_s=z,
                        num_cores=nc,
                        topology=topo,
                        channel_affinity=aff,
                        placement=plc,
                    ),
                    result=res,
                ))
    out.wall_seconds = time.perf_counter() - t0
    return out
