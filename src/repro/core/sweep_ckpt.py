"""Journaled sweep checkpoints: kill-and-resume with bitwise-identical results.

A long DSE sweep (the ROADMAP's "week-long sweeps that survive preemption")
must not lose finished work to a kill. ``SweepCheckpoint`` journals each
completed memo key's embedding stats to an append-only file in
cadence-sized rounds; a restarted ``sweep(..., checkpoint=...)`` restores
journaled keys and evaluates only the remainder. The resumed ``SweepResult``
is **bitwise identical** to an uninterrupted run (differential-enforced),
which constrains the format:

  * **Exact numeric round-trip** — stats fields can hold numpy scalars from
    the device pipeline (e.g. f32 finish-cycle chains), and downstream
    arithmetic (``assemble_result``) is dtype-sensitive. Floats journal via
    JSON ``repr`` (exact for every finite double; f32 embeds exactly in
    f64), numpy scalars additionally carry a dtype tag and restore as the
    same ``np.dtype`` scalar.
  * **Torn-write detection** — each journal line is ``payload \t crc32 \n``.
    On open, the journal replays until the FIRST invalid line (bad CRC,
    truncated tail, malformed JSON) and truncates the file there: the keys
    on the torn tail are simply re-evaluated, never silently skipped or
    half-restored. (Same posture as ``checkpoint.manager``'s sha256-verified
    torn-checkpoint rejection, adapted to an append-only journal.)
  * **Fingerprint guard** — the header pins a sha256 over everything that
    determines sweep *results* (workloads, base hardware, seed, grid,
    index trace, energy table — not the batching/sharding knobs, which are
    bit-exact). Resuming against a different sweep spec raises instead of
    mixing incompatible stats.
  * **Concurrent-writer guard** — an append-only journal written by two
    processes interleaves frames from different rounds and neither writer
    knows. ``open()`` takes a PID lockfile (``<path>.lock``) and raises
    ``CheckpointLockedError`` while another *live* process holds it; locks
    left by dead processes (a killed sweep) are taken over automatically,
    so kill-and-resume needs no manual cleanup.

The journal is engine-level (memo keys, not ``SweepEntry`` rows) so a
resumed sweep re-derives entries through the exact same assembly path as a
fresh one — including memo-key collapses added later in the run.
"""
from __future__ import annotations

import json
import os
import time
import zlib
from dataclasses import fields
from typing import Dict, List, Optional

import numpy as np

from .faults import CheckpointLockedError, InjectedKill
from .memory.system import CoreBatchStats, EmbeddingBatchStats

_VERSION = 1


def _pid_alive(pid: int) -> bool:
    """Best-effort liveness probe (same host; signal 0)."""
    if pid <= 0:
        return False
    try:
        os.kill(pid, 0)
    except ProcessLookupError:
        return False
    except OSError:
        # EPERM etc.: the process exists but isn't ours.
        return True
    return True


# --------------------------------------------------------------------------
# Exact-round-trip serialization
# --------------------------------------------------------------------------

def _enc_num(v):
    """Encode one numeric field preserving its exact type and bits."""
    if isinstance(v, np.generic):
        # Dtype tag -> restore as the same numpy scalar. .item() is exact
        # (f32 -> f64 embed; ints exact), repr round-trips the double.
        return {"__np__": v.dtype.str, "v": v.item()}
    return v


def _dec_num(v):
    if isinstance(v, dict) and "__np__" in v:
        return np.dtype(v["__np__"]).type(v["v"])
    return v


def _enc_stats(stats: List[List[EmbeddingBatchStats]]) -> list:
    out = []
    for per_batch in stats:
        rows = []
        for s in per_batch:
            d = {f.name: _enc_num(getattr(s, f.name))
                 for f in fields(EmbeddingBatchStats) if f.name != "per_core"}
            if s.per_core is not None:
                d["per_core"] = [
                    {f.name: _enc_num(getattr(c, f.name))
                     for f in fields(CoreBatchStats)}
                    for c in s.per_core
                ]
            rows.append(d)
        out.append(rows)
    return out


def _dec_stats(data: list) -> List[List[EmbeddingBatchStats]]:
    out = []
    for rows in data:
        per_batch = []
        for d in rows:
            per_core = None
            if "per_core" in d:
                per_core = [
                    CoreBatchStats(**{k: _dec_num(v) for k, v in c.items()})
                    for c in d["per_core"]
                ]
            kw = {k: _dec_num(v) for k, v in d.items() if k != "per_core"}
            per_batch.append(EmbeddingBatchStats(per_core=per_core, **kw))
        out.append(per_batch)
    return out


def _canon(obj):
    """Memo keys / fingerprints -> a canonical JSON-able value. Tuples become
    lists, numpy scalars their items; anything non-primitive falls back to
    ``repr`` (only equality between runs of the same spec matters)."""
    if isinstance(obj, (tuple, list)):
        return [_canon(x) for x in obj]
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in sorted(obj.items(), key=lambda kv: str(kv[0]))}
    if isinstance(obj, np.generic):
        obj = obj.item()
    if obj is None or isinstance(obj, (bool, int, float, str)):
        return obj
    return repr(obj)


def _key_str(slice_id: tuple, key: tuple) -> str:
    return json.dumps(_canon([list(slice_id), list(key)]),
                      separators=(",", ":"), sort_keys=True)


def fingerprint_digest(desc: Dict) -> str:
    import hashlib

    text = json.dumps(_canon(desc), separators=(",", ":"), sort_keys=True)
    return hashlib.sha256(text.encode()).hexdigest()


# --------------------------------------------------------------------------
# The journal
# --------------------------------------------------------------------------

class SweepCheckpoint:
    """Append-only, CRC-framed, fingerprint-guarded memo-key journal.

    Usage (``sweep()`` drives all of this when given ``checkpoint=``)::

        ckpt = SweepCheckpoint("results/sweep.ckpt", cadence=16)
        result = sweep(wls, hw, ..., checkpoint=ckpt)   # journals as it goes
        # ... kill at any point; rerun the same call to resume ...
    """

    def __init__(self, path: str, cadence: int = 16):
        self.path = str(path)
        # Memo keys per journal flush round: small -> finer resume
        # granularity, large -> fewer fsync-free appends. Rounds also bound
        # the shard dispatch size, so cadence trades resumability against
        # batching width.
        self.cadence = int(cadence)
        self._fh = None
        self._restored: Dict[str, List[List[EmbeddingBatchStats]]] = {}
        self.completed_entries: Optional[int] = None
        self._lock_owned = False
        # Test-only torn-write injection hook; sweep() installs its
        # FaultInjector here when given a fault_plan (None in production).
        self.fault_injector = None

    # -- concurrent-writer lockfile ---------------------------------------

    @property
    def lock_path(self) -> str:
        return self.path + ".lock"

    def _lock_holder(self) -> Optional[int]:
        try:
            with open(self.lock_path, "rb") as f:
                return int(json.loads(f.read().decode()).get("pid", -1))
        except (OSError, ValueError, json.JSONDecodeError,
                UnicodeDecodeError, AttributeError):
            return None

    def _acquire_lock(self) -> None:
        """Take ``<path>.lock`` via O_EXCL creation. A lock held by a live
        foreign process raises ``CheckpointLockedError`` (two writers would
        interleave appends). Stale locks — dead PID, unreadable payload, or
        our own PID (a prior open in this process that never closed, e.g. a
        killed-and-resumed sweep holding the same instance) — are taken
        over; O_EXCL arbitrates takeover races."""
        if self._lock_owned:
            return
        d = os.path.dirname(self.path)
        if d:
            os.makedirs(d, exist_ok=True)
        payload = json.dumps({
            "pid": os.getpid(),
            "path": os.path.abspath(self.path),
            "time": time.time(),
        }).encode()
        for _ in range(16):
            try:
                fd = os.open(self.lock_path,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY, 0o644)
            except FileExistsError:
                pid = self._lock_holder()
                if pid is not None and pid != os.getpid() and _pid_alive(pid):
                    raise CheckpointLockedError(
                        f"checkpoint journal {self.path} is locked by live "
                        f"process {pid} ({self.lock_path}); two concurrent "
                        "writers would interleave appends — wait for it, or "
                        "remove the lockfile if you are certain it is stale")
                try:
                    os.unlink(self.lock_path)
                except FileNotFoundError:
                    pass
                continue
            try:
                os.write(fd, payload)
            finally:
                os.close(fd)
            self._lock_owned = True
            return
        raise CheckpointLockedError(
            f"could not acquire {self.lock_path} after repeated takeovers")

    def _release_lock(self) -> None:
        if self._lock_owned:
            self._lock_owned = False
            try:
                os.unlink(self.lock_path)
            except FileNotFoundError:
                pass

    # -- framing ----------------------------------------------------------

    @staticmethod
    def _frame(record: Dict) -> bytes:
        payload = json.dumps(record, separators=(",", ":"), sort_keys=True)
        crc = zlib.crc32(payload.encode()) & 0xFFFFFFFF
        return f"{payload}\t{crc:08x}\n".encode()

    @staticmethod
    def _parse_line(raw: bytes) -> Optional[Dict]:
        """One journal line -> record, or None when invalid/torn."""
        if not raw.endswith(b"\n"):
            return None                      # torn tail (no terminator)
        body = raw[:-1]
        sep = body.rfind(b"\t")
        if sep < 0:
            return None
        payload, crc_hex = body[:sep], body[sep + 1:]
        try:
            if zlib.crc32(payload) & 0xFFFFFFFF != int(crc_hex, 16):
                return None
        except ValueError:
            return None
        try:
            rec = json.loads(payload.decode())
        except (json.JSONDecodeError, UnicodeDecodeError):
            return None
        return rec if isinstance(rec, dict) else None

    # -- lifecycle --------------------------------------------------------

    def open(self, fingerprint_desc: Dict) -> None:
        """Replay the journal (if any), validate the fingerprint, truncate
        any torn tail, and open for appending. Idempotent: re-opening (e.g.
        one ``SweepCheckpoint`` instance across several ``sweep()`` calls)
        re-replays from disk. Raises ``CheckpointLockedError`` while another
        live process holds the journal's lockfile."""
        self.close()
        self._acquire_lock()
        try:
            self._open_locked(fingerprint_desc)
        except BaseException:
            # open() is called before sweep()'s try/finally: failing here
            # (fingerprint mismatch, IO error) must not leave a lock that
            # only process death would clear.
            self._release_lock()
            raise

    def _open_locked(self, fingerprint_desc: Dict) -> None:
        digest = fingerprint_digest(fingerprint_desc)
        self._restored.clear()
        self.completed_entries = None
        valid_bytes = 0
        have_header = False
        if os.path.exists(self.path):
            with open(self.path, "rb") as f:
                for raw in f:
                    rec = self._parse_line(raw)
                    if rec is None:
                        break                 # torn/corrupt: drop this + rest
                    if not have_header:
                        if rec.get("kind") != "header":
                            break
                        if rec.get("version") != _VERSION:
                            break             # unknown format: start over
                        if rec.get("fingerprint") != digest:
                            raise ValueError(
                                "checkpoint fingerprint mismatch: "
                                f"{self.path} was written by a different "
                                "sweep spec (workloads/hardware/seed/grid); "
                                "delete it or point at a fresh path"
                            )
                        have_header = True
                    elif rec.get("kind") == "key":
                        try:
                            stats = _dec_stats(rec["stats"])
                        except (KeyError, TypeError, ValueError):
                            break             # undecodable: treat as torn
                        self._restored[rec["k"]] = stats
                    elif rec.get("kind") == "complete":
                        self.completed_entries = rec.get("entries")
                    valid_bytes += len(raw)
        if have_header:
            # Keep the valid prefix; any torn tail is re-evaluated.
            if os.path.getsize(self.path) != valid_bytes:
                with open(self.path, "r+b") as f:
                    f.truncate(valid_bytes)
            self._fh = open(self.path, "ab")
        else:
            d = os.path.dirname(self.path)
            if d:
                os.makedirs(d, exist_ok=True)
            self._fh = open(self.path, "wb")
            self._fh.write(self._frame({
                "kind": "header", "version": _VERSION, "fingerprint": digest,
            }))
            self._fh.flush()
            os.fsync(self._fh.fileno())

    @property
    def restored_count(self) -> int:
        return len(self._restored)

    def lookup(self, slice_id: tuple, key: tuple):
        return self._restored.get(_key_str(slice_id, key))

    def record(self, slice_id: tuple, results: Dict[tuple, list]) -> None:
        """Journal one evaluation round (``sweep()`` calls this per cadence
        chunk). Flushed to the OS per round so a process kill loses at most
        the round in flight; fsync waits for ``mark_complete``/``close``."""
        if self._fh is None:
            raise RuntimeError("checkpoint not open")
        inj = self.fault_injector
        tear = inj is not None and results and inj.maybe_tear()
        items = list(results.items())
        for i, (key, stats) in enumerate(items):
            ks = _key_str(slice_id, key)
            frame = self._frame({
                "kind": "key", "k": ks, "stats": _enc_stats(stats),
            })
            if tear and i == len(items) - 1:
                # Injected torn write: half of the final frame reaches the
                # OS, then the "process" dies — exactly what a SIGKILL
                # mid-append leaves behind. Replay must truncate here and
                # re-evaluate this key (InjectedKill subclasses
                # KeyboardInterrupt so nothing downstream absorbs it).
                self._fh.write(frame[: max(1, len(frame) // 2)])
                self._fh.flush()
                raise InjectedKill(
                    f"injected torn journal write at {self.path}")
            self._fh.write(frame)
            self._restored[ks] = stats
        self._fh.flush()

    def mark_complete(self, num_entries: int) -> None:
        if self._fh is None:
            raise RuntimeError("checkpoint not open")
        self.completed_entries = int(num_entries)
        self._fh.write(self._frame({
            "kind": "complete", "entries": int(num_entries),
        }))
        self._fh.flush()
        os.fsync(self._fh.fileno())

    def close(self) -> None:
        if self._fh is not None:
            self._fh.flush()
            os.fsync(self._fh.fileno())
            self._fh.close()
            self._fh = None
        self._release_lock()

    def __enter__(self) -> "SweepCheckpoint":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
