"""Successive-halving Pareto search over the DSE grid.

Exhaustive sweeps stop scaling around 10^3 configs even at ~23 ms/config;
most of that work evaluates configs nowhere near the frontier. ``search()``
prunes with cheap **low-fidelity** passes before spending full evaluations:

  * **Fidelity = trace batches.** A workload subsampled to its first k
    batches (``dataclasses.replace(wl, num_batches=k)``) runs the identical
    engine on a shorter trace — the relative ordering of configs is highly
    stable in k because classification is trace-driven, while cost scales
    ~linearly with k. The ladder grows k by ``eta`` per rung up to the full
    workload.
  * **Successive halving by memo-key group.** Each rung evaluates the
    surviving population through the memoized ``sweep(configs=...)`` engine
    (so degenerate configs still collapse), groups entries by memo key
    (group members are byte-identical by construction), and keeps the best
    ``1/eta`` of groups — ALWAYS including every currently non-dominated
    group, so a frontier config can only be pruned by a rung that already
    sees it dominated.
  * **Exact final rung.** Survivors re-evaluate at full fidelity; the
    returned front is computed from those exact results. On the 24-config
    reference grid the driver recovers the exhaustive Pareto front in
    ``(total_cycles, energy_pj)`` within <=50% of the exhaustive full-
    fidelity evaluations (test-enforced; low-fidelity rungs are the cheap
    part and are reported separately).

The driver composes with the rest of the scaling layer: ``devices=`` shards
every rung's sweep and ``checkpoint_dir=`` journals each rung to its own
``SweepCheckpoint`` file, so a killed search resumes rung-by-rung.
"""
from __future__ import annotations

import dataclasses
import math
import os
import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple, Union

from .hardware import HardwareConfig, tpuv6e
from .sweep import SweepConfig, SweepEntry, SweepResult, grid_configs, sweep
from .workload import Workload

__all__ = ["SearchResult", "pareto_front", "nondominated_ranks", "search"]

DEFAULT_OBJECTIVES = ("total_cycles", "energy_pj")


def _objective_point(entry: SweepEntry, objectives: Sequence[str]) -> Tuple[float, ...]:
    summ = entry.result.summary()
    return tuple(float(summ[o]) for o in objectives)


def _dominates(a: Sequence[float], b: Sequence[float]) -> bool:
    """a Pareto-dominates b (minimization): <= everywhere, < somewhere."""
    return all(x <= y for x, y in zip(a, b)) and any(x < y for x, y in zip(a, b))


def pareto_front(
    entries: Sequence[SweepEntry],
    objectives: Sequence[str] = DEFAULT_OBJECTIVES,
) -> List[SweepEntry]:
    """Non-dominated entries (minimization; ties all stay on the front),
    in input order."""
    pts = [_objective_point(e, objectives) for e in entries]
    return [
        e for i, e in enumerate(entries)
        if not any(_dominates(pts[j], pts[i]) for j in range(len(entries)) if j != i)
    ]


def nondominated_ranks(points: Sequence[Tuple[float, ...]]) -> List[int]:
    """Non-dominated sorting rank per point (0 = frontier, 1 = frontier
    after removing rank 0, ...). O(n^2) peeling — populations here are
    config grids, not GA swarms."""
    n = len(points)
    ranks = [-1] * n
    remaining = set(range(n))
    r = 0
    while remaining:
        front = [
            i for i in remaining
            if not any(_dominates(points[j], points[i])
                       for j in remaining if j != i)
        ]
        for i in front:
            ranks[i] = r
        remaining -= set(front)
        r += 1
    return ranks


@dataclass
class RungReport:
    num_batches: int          # fidelity of this rung (trace batches)
    configs: int              # population entering the rung
    groups: int               # distinct memo-key groups seen
    kept_groups: int          # groups surviving to the next rung
    wall_seconds: float = 0.0


@dataclass
class SearchResult:
    pareto: List[SweepEntry] = field(default_factory=list)
    population: List[SweepEntry] = field(default_factory=list)  # final full-fidelity survivors
    objectives: Tuple[str, ...] = DEFAULT_OBJECTIVES
    full_evals: int = 0       # distinct full-fidelity memo keys evaluated
    low_fidelity_evals: int = 0
    rungs: List[RungReport] = field(default_factory=list)
    wall_seconds: float = 0.0

    def front_labels(self) -> List[str]:
        return sorted(e.config.label for e in self.pareto)


def _group_by_memo_key(entries: Sequence[SweepEntry]) -> Dict[tuple, List[SweepEntry]]:
    groups: Dict[tuple, List[SweepEntry]] = {}
    for e in entries:
        groups.setdefault(e.memo_key, []).append(e)
    return groups


def _fidelity_workloads(wls: Sequence[Workload], k: int) -> List[Workload]:
    """Subsample every workload to its first k trace batches (same names, so
    the population's configs resolve unchanged)."""
    return [dataclasses.replace(wl, num_batches=min(k, wl.num_batches))
            for wl in wls]


def search(
    workloads: Union[Workload, Sequence[Workload]],
    base_hw: Optional[HardwareConfig] = None,
    *,
    configs: Optional[Sequence[SweepConfig]] = None,
    objectives: Sequence[str] = DEFAULT_OBJECTIVES,
    eta: int = 2,
    min_batches: int = 1,
    seed: int = 0,
    zipf_s=0.8,
    devices=None,
    checkpoint_dir: Optional[str] = None,
    fault_tolerance=None,
    fault_plan=None,
    **grid_axes,
) -> SearchResult:
    """Find the exact Pareto front in ``objectives`` over the config grid.

    ``configs`` gives the starting population explicitly; otherwise it is
    ``grid_configs(workloads, base_hw, zipf_s=zipf_s, **grid_axes)`` (the
    same axes ``sweep()`` takes: policies/capacities/ways/num_cores/...).

    The front is exact for the survivors by construction (final rung runs
    full fidelity); recovery of the full grid's front is a property of the
    pruning schedule, enforced on the reference grid by tests.

    ``fault_tolerance`` applies to every rung's sweep; rung-level recovery
    composes with per-rung checkpoints — a crashed rung resumes from its
    own journal, shard failures within a rung fail over and stay bitwise.
    ``fault_plan`` (tests/chaos only) is handed to each rung's sweep with a
    fresh injector, so its (shard, round) coordinates are *per rung*, not
    global across the search.
    """
    base_hw = base_hw or tpuv6e()
    wls: List[Workload] = list(workloads) if isinstance(
        workloads, (list, tuple)) else [workloads]
    if configs is None:
        configs = grid_configs(wls, base_hw, zipf_s=zipf_s, **grid_axes)
    population = list(configs)
    if not population:
        raise ValueError("empty search population")
    if eta < 2:
        raise ValueError(f"eta must be >= 2, got {eta}")

    full_batches = max(wl.num_batches for wl in wls)

    def run_rung(k: int, pop: Sequence[SweepConfig], tag: str) -> SweepResult:
        ckpt = None
        if checkpoint_dir is not None:
            ckpt = os.path.join(checkpoint_dir, f"search_{tag}.ckpt")
        return sweep(
            _fidelity_workloads(wls, k), base_hw, configs=pop, seed=seed,
            devices=devices, checkpoint=ckpt,
            fault_tolerance=fault_tolerance, fault_plan=fault_plan,
        )

    t0 = time.perf_counter()
    out = SearchResult(objectives=tuple(objectives))
    k = max(1, int(min_batches))
    while k < full_batches and len(population) > 1:
        rt0 = time.perf_counter()
        sr = run_rung(k, population, f"rung{k}")
        out.low_fidelity_evals += sr.distinct_memo_keys
        groups = _group_by_memo_key(sr.entries)
        gkeys = list(groups)
        pts = [_objective_point(groups[g][0], objectives) for g in gkeys]
        ranks = nondominated_ranks(pts)
        # Keep the best 1/eta of groups — and never prune a group that is
        # non-dominated at this fidelity (rank 0): the frontier must lose
        # only to observed domination, not to the budget.
        order = sorted(
            range(len(gkeys)),
            key=lambda i: (ranks[i], pts[i], groups[gkeys[i]][0].config.label),
        )
        keep = max(
            math.ceil(len(gkeys) / eta),
            sum(1 for r in ranks if r == 0),
        )
        kept = set(order[:keep])
        population = [
            e.config
            for i in kept
            for e in groups[gkeys[i]]
        ]
        # Deterministic population order (groups can interleave in `kept`).
        population.sort(key=lambda c: c.label)
        out.rungs.append(RungReport(
            num_batches=k, configs=sr.num_configs, groups=len(gkeys),
            kept_groups=len(kept),
            wall_seconds=time.perf_counter() - rt0,
        ))
        k *= eta

    # Final rung: exact, full-fidelity evaluation of the survivors.
    rt0 = time.perf_counter()
    sr = run_rung(full_batches, population, "final")
    out.full_evals = sr.distinct_memo_keys
    out.population = list(sr.entries)
    out.pareto = pareto_front(sr.entries, objectives)
    out.rungs.append(RungReport(
        num_batches=full_batches, configs=sr.num_configs,
        groups=sr.distinct_memo_keys, kept_groups=sr.distinct_memo_keys,
        wall_seconds=time.perf_counter() - rt0,
    ))
    out.wall_seconds = time.perf_counter() - t0
    return out
