"""EONSim core: the paper's contribution — an NPU simulator that models both
matrix and embedding vector operations over a configurable memory hierarchy."""

from .hardware import (
    CHANNEL_AFFINITIES,
    Dataflow,
    HardwareConfig,
    LookupSharding,
    MatrixUnit,
    OffChipMemory,
    OnChipMemory,
    OnChipPolicy,
    PLACEMENTS,
    Topology,
    VectorUnit,
    tpuv6e,
)
from .workload import (
    EmbeddingOpSpec,
    MatrixOpSpec,
    VectorOp,
    Workload,
    dlrm_rmc2_small,
)
from .engine import simulate, simulate_embedding_op
from .memory import (
    MemoryPolicy,
    MemorySystem,
    MultiCoreMemorySystem,
    available_policies,
    get_policy,
    memory_system_for,
    register_policy,
)
from .requests import (
    ARRIVAL_PATTERNS,
    Request,
    TrafficConfig,
    generate_arrivals,
    generate_requests,
)
from .results import BatchResult, ServingResult, SimResult
from .faults import (
    CheckpointLockedError,
    FaultEvent,
    FaultPlan,
    FaultTelemetry,
    FaultTolerance,
    FaultToleranceExhausted,
    ShardEvaluationError,
)
from .sweep import SweepConfig, SweepEntry, SweepResult, grid_configs, sweep
from .sweep_ckpt import SweepCheckpoint
from .search import SearchResult, pareto_front, search

__all__ = [
    "CHANNEL_AFFINITIES",
    "Dataflow",
    "HardwareConfig",
    "LookupSharding",
    "PLACEMENTS",
    "Topology",
    "MatrixUnit",
    "OffChipMemory",
    "OnChipMemory",
    "OnChipPolicy",
    "VectorUnit",
    "tpuv6e",
    "EmbeddingOpSpec",
    "MatrixOpSpec",
    "VectorOp",
    "Workload",
    "dlrm_rmc2_small",
    "simulate",
    "simulate_embedding_op",
    "ARRIVAL_PATTERNS",
    "Request",
    "TrafficConfig",
    "generate_arrivals",
    "generate_requests",
    "BatchResult",
    "ServingResult",
    "SimResult",
    "MemoryPolicy",
    "MemorySystem",
    "MultiCoreMemorySystem",
    "available_policies",
    "get_policy",
    "memory_system_for",
    "register_policy",
    "CheckpointLockedError",
    "FaultEvent",
    "FaultPlan",
    "FaultTelemetry",
    "FaultTolerance",
    "FaultToleranceExhausted",
    "ShardEvaluationError",
    "SweepConfig",
    "SweepEntry",
    "SweepResult",
    "SweepCheckpoint",
    "SearchResult",
    "grid_configs",
    "pareto_front",
    "search",
    "sweep",
]
