"""Lightweight per-stage wall-time accounting for the simulation hot path.

The DSE sweep's perf work needs to know where a config's milliseconds go:
trace generation, on-chip classification, the cache scan itself, DRAM
timing, or host<->device synchronization. This module is the single owner
of that attribution: hot-path stages wrap themselves in ``stage(name)`` and
a profiling session (``collect()``) accumulates exclusive wall time per
stage. When no session is active the wrappers cost one global read and a
``None`` check — nothing is timed, so ``simulate()``/``sweep()`` keep their
normal performance.

Stages nest: time spent inside an inner ``stage`` is attributed to the
inner stage only (exclusive accounting), so ``classify`` does not
double-count the ``cache_scan`` dispatch it contains, and ``host_sync``
blocks (device-result extraction) subtract cleanly from whichever stage
they interrupt.

Canonical stage names used by the memory pipeline:

  * ``trace_gen``   — index-trace generation + expansion + translation
  * ``classify``    — policy classification driver (stream prep, accounting)
  * ``cache_scan``  — set-associative cache engine dispatch (scan or Pallas)
  * ``dram``        — DRAM timing (FR-FCFS ordering + event scan)
  * ``host_sync``   — blocking device->host result extraction (np.asarray
                      of JAX arrays; the cost the device-resident pipeline
                      is designed to keep out of the inner loop)
  * ``fault_wait``  — fault-tolerance stalls: retry backoff sleeps in the
                      sharded sweep's workers (core.faults). Separated out
                      so an injected-fault run's breakdown shows recovery
                      overhead as waiting, not as inflated engine stages.
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager
from typing import Dict, Iterator, List, Optional

__all__ = ["stage", "collect", "is_active", "StageProfile"]


class StageProfile:
    """Accumulated exclusive seconds per stage for one profiling session.

    Thread-safe: the sharded sweep runs stages on several worker threads at
    once, so nesting state lives per thread (a shared stack would attribute
    one thread's children to another's parent frame) and the accumulator
    takes a lock. Concurrent stages both count their own wall time — the
    breakdown is attribution, not a partition of the session's wall clock.
    """

    def __init__(self) -> None:
        self.seconds: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._local = threading.local()  # .stack: [name, started, child_s]

    def _stack(self) -> List[list]:
        st = getattr(self._local, "stack", None)
        if st is None:
            st = self._local.stack = []
        return st

    def _add(self, name: str, seconds: float) -> None:
        with self._lock:
            self.seconds[name] = self.seconds.get(name, 0.0) + seconds

    def breakdown(self, total_seconds: Optional[float] = None) -> Dict[str, float]:
        """Stage -> seconds, with ``other`` filling up to ``total_seconds``."""
        out = dict(sorted(self.seconds.items(), key=lambda kv: -kv[1]))
        if total_seconds is not None:
            out["other"] = max(0.0, total_seconds - sum(self.seconds.values()))
        return out


_active: Optional[StageProfile] = None


def is_active() -> bool:
    """True while a ``collect()`` session is open.

    Hot-path code uses this to force device computations to complete inside
    their own stage (``jax.block_until_ready``) so that asynchronous-dispatch
    wait time is attributed to the compute stage, not to the ``host_sync``
    extraction that would otherwise absorb it. Never true in production, so
    the extra synchronization only exists while profiling.
    """
    return _active is not None


@contextmanager
def stage(name: str) -> Iterator[None]:
    """Attribute the enclosed wall time to ``name`` (exclusive of children)."""
    prof = _active
    if prof is None:
        yield
        return
    stack = prof._stack()
    stack.append([name, time.perf_counter(), 0.0])
    try:
        yield
    finally:
        frame = stack.pop()
        elapsed = time.perf_counter() - frame[1]
        prof._add(name, elapsed - frame[2])
        if stack:
            stack[-1][2] += elapsed


@contextmanager
def collect() -> Iterator[StageProfile]:
    """Open a profiling session; hot-path ``stage`` blocks report into it."""
    global _active
    prev = _active
    prof = StageProfile()
    _active = prof
    try:
        yield prof
    finally:
        _active = prev
