"""Deterministic fault injection + fault-tolerance policy for the sweep engine.

Long multi-device DSE campaigns fail in infrastructure, not in math: a worker
hangs, a jit dispatch throws transiently, a journal append is torn by a kill.
This module owns everything the execution layer needs to survive those faults
*deterministically*:

  * **`FaultPlan`** — a seeded, replayable schedule of injected faults, pure
    data: each `FaultEvent` names a kind (worker crash, transient eval
    exception, hung shard, torn journal write), a (shard, round) coordinate,
    and a fire count. Threaded through ``sweep(fault_plan=...)`` for tests
    and chaos CI only — production sweeps never construct one.
  * **`FaultInjector`** — the runtime for one sweep call: consumes the
    plan's events as (shard, round) coordinates come up, thread-safe, and
    records what actually fired (``.fired``) so a chaos run is auditable.
    Re-running the same plan against the same sweep fires the same events —
    replayable by construction (no wall-clock, no unseeded randomness).
  * **`FaultTolerance`** — the *policy* knobs of the recovery machinery:
    retry budget + exponential backoff with seeded jitter, the per-shard
    heartbeat watchdog timeout, and ``strict`` (raise instead of degrading).
    The default instance is what production sweeps run under.
  * **`FaultTelemetry`** — thread-safe counters for retries, failovers,
    hung/crashed shards, lost devices, torn writes, and per-shard
    wall/retry/key stats; recorded on ``SweepResult`` and in ``to_json``.
  * **`classify_exception`** — the transient / crash / fatal / kill
    taxonomy the supervisor dispatches on (see below).

The invariant all of this preserves: **any fault schedule that leaves at
least one live device yields a bitwise-identical ``SweepResult``** to the
fault-free sweep. Recovery only re-partitions *which worker evaluates which
memo keys* — and every batching layer underneath is bit-exact regardless of
batch composition — so retried, failed-over, and resumed evaluations produce
the same bits (differential-enforced in ``tests/test_faults.py``).

Exception taxonomy (``classify_exception``):

  * ``"transient"`` — worth retrying in place: ``TransientEvalError``
    subclasses (the injector's transient events), ``OSError`` (filesystem /
    RPC blips), and runtime errors whose message carries a transient status
    (RESOURCE_EXHAUSTED, DEADLINE_EXCEEDED, UNAVAILABLE, ABORTED).
  * ``"crash"`` — the worker (or its device) is gone: retrying in place is
    pointless, fail the shard over to the survivors.
  * ``"kill"`` — process-level interruption (``KeyboardInterrupt``,
    ``SystemExit``, the injector's ``InjectedKill``): propagate untouched.
  * ``"fatal"`` — everything else is a *bug*, not an infrastructure fault:
    wrapped with shard context (``ShardEvaluationError``) and raised,
    preserving completed sibling-shard results on the exception.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

__all__ = [
    "FAULT_KINDS",
    "FaultEvent",
    "FaultPlan",
    "FaultInjector",
    "FaultTolerance",
    "FaultTelemetry",
    "TransientEvalError",
    "InjectedTransientError",
    "InjectedWorkerCrash",
    "InjectedFatalError",
    "InjectedHang",
    "InjectedKill",
    "ShardEvaluationError",
    "FaultToleranceExhausted",
    "CheckpointLockedError",
    "classify_exception",
    "backoff_seconds",
]

FAULT_KINDS = ("transient", "crash", "hang", "fatal", "torn_write")

# Status substrings that mark a runtime error as transient (XLA / gRPC style
# status codes surface in the message text across jax versions).
_TRANSIENT_PATTERNS = (
    "RESOURCE_EXHAUSTED", "DEADLINE_EXCEEDED", "UNAVAILABLE", "ABORTED",
)
# ... and as a dead worker/device (retry-in-place is pointless; fail over).
_CRASH_PATTERNS = ("DATA_LOSS", "device lost", "worker crashed")


# --------------------------------------------------------------------------
# Exceptions
# --------------------------------------------------------------------------

class TransientEvalError(RuntimeError):
    """Base class for errors the retry loop should absorb."""


class InjectedTransientError(TransientEvalError):
    """Injected transient evaluation failure (retried with backoff)."""


class InjectedWorkerCrash(RuntimeError):
    """Injected worker death (the shard fails over to survivors)."""


class InjectedFatalError(RuntimeError):
    """Injected non-recoverable bug (wrapped + raised, never failed over)."""


class InjectedHang(RuntimeError):
    """Raised by a hung worker AFTER the watchdog abandons it, so the
    injected hang's thread exits instead of leaking."""


class InjectedKill(KeyboardInterrupt):
    """Injected process death (e.g. mid-journal-append). Subclasses
    ``KeyboardInterrupt`` so no ``except Exception`` recovery path can
    swallow it — it behaves like a SIGINT/SIGKILL would."""


class CheckpointLockedError(RuntimeError):
    """A live process holds the checkpoint journal's lockfile."""


class FaultToleranceExhausted(RuntimeError):
    """No surviving shard/device can take the remaining memo keys."""


class ShardEvaluationError(RuntimeError):
    """A shard's evaluation failed in a way fault tolerance does not absorb
    (a fatal error, or any failure under ``strict=True``).

    Carries full context instead of a bare worker re-raise: the shard index,
    its device, the memo keys and class-key groups it owned, the original
    cause, and — crucially — ``completed``: every sibling shard's finished
    results, so callers (and the checkpoint journal) never discard
    surviving work because one shard died.
    """

    def __init__(
        self,
        shard: int,
        device: str,
        keys: Sequence[tuple],
        class_groups: Sequence[str],
        completed: Dict[tuple, list],
        cause: Optional[BaseException],
        prefix: Optional[str] = None,
    ) -> None:
        self.shard = int(shard)
        self.device = str(device)
        self.keys = list(keys)
        self.class_groups = list(class_groups)
        self.completed = dict(completed)
        self.cause = cause
        head = prefix or "shard evaluation failed"
        shown = ", ".join(self.class_groups[:3])
        if len(self.class_groups) > 3:
            shown += ", ..."
        super().__init__(
            f"{head}: shard {self.shard} on {self.device} owned "
            f"{len(self.keys)} memo keys in {len(self.class_groups)} "
            f"class-key groups [{shown}]: {cause!r}; "
            f"{len(self.completed)} completed sibling-shard keys are "
            "preserved on this exception (and journaled when checkpointed)"
        )


def classify_exception(exc: BaseException) -> str:
    """``"transient"`` / ``"crash"`` / ``"kill"`` / ``"fatal"`` — the
    taxonomy the shard supervisor dispatches on (see module docstring)."""
    if isinstance(exc, (KeyboardInterrupt, SystemExit)):
        return "kill"
    if isinstance(exc, TransientEvalError):
        return "transient"
    if isinstance(exc, (InjectedWorkerCrash, InjectedHang)):
        return "crash"
    if isinstance(exc, OSError):
        return "transient"
    msg = str(exc)
    if any(p in msg for p in _CRASH_PATTERNS):
        return "crash"
    if any(p in msg for p in _TRANSIENT_PATTERNS):
        return "transient"
    return "fatal"


# --------------------------------------------------------------------------
# Fault plans (pure data, seeded, replayable)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault: fire ``count`` times at (shard, round).

    ``round`` counts evaluation rounds globally across the sweep (one per
    cadence chunk per slice, in order). ``shard`` is the shard index in the
    ``ShardPlan`` — stable across failover, so a plan targeting shard 2
    keeps targeting shard 2 even after shard 1 died. ``torn_write`` events
    ignore ``shard`` (the journal append happens on the driver)."""

    kind: str
    shard: int = 0
    round: int = 0
    count: int = 1

    def __post_init__(self) -> None:
        if self.kind not in FAULT_KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; "
                             f"one of {FAULT_KINDS}")
        if self.count < 1 or self.shard < 0 or self.round < 0:
            raise ValueError(f"invalid fault event: {self}")


@dataclass(frozen=True)
class FaultPlan:
    """A deterministic, replayable fault schedule — pure data.

    Thread through ``sweep(fault_plan=...)`` (tests / chaos CI only). The
    same plan against the same sweep spec fires the same events in the same
    places; recovery is then exercised end-to-end and the result is asserted
    bitwise identical to the fault-free run."""

    events: Tuple[FaultEvent, ...] = ()
    seed: int = 0

    def __post_init__(self) -> None:
        object.__setattr__(self, "events", tuple(self.events))

    def has_kind(self, kind: str) -> bool:
        return any(e.kind == kind for e in self.events)

    def has_shard_events(self) -> bool:
        """True when any event targets a shard worker (everything except
        ``torn_write``, which fires on the driver's journal append)."""
        return any(e.kind != "torn_write" for e in self.events)

    @classmethod
    def chaos(
        cls,
        seed: int,
        num_shards: int,
        num_rounds: int = 1,
        events: int = 3,
        kinds: Sequence[str] = ("transient", "crash", "hang"),
    ) -> "FaultPlan":
        """Seeded random schedule for chaos tests. Guarantees the invariant
        precondition — at least one shard survives every round — by capping
        lethal events (crash/hang) at ``num_shards - 1`` per round; an
        over-budget draw degrades to a transient instead."""
        if num_shards < 1 or num_rounds < 1:
            raise ValueError("need >= 1 shard and >= 1 round")
        rng = random.Random(seed)
        lethal_per_round: Dict[int, int] = {}
        out: List[FaultEvent] = []
        for _ in range(events):
            kind = rng.choice(tuple(kinds))
            shard = rng.randrange(num_shards)
            rnd = rng.randrange(num_rounds)
            if kind in ("crash", "hang"):
                if lethal_per_round.get(rnd, 0) >= num_shards - 1:
                    kind = "transient"
                else:
                    lethal_per_round[rnd] = lethal_per_round.get(rnd, 0) + 1
            count = rng.choice((1, 2)) if kind == "transient" else 1
            out.append(FaultEvent(kind=kind, shard=shard, round=rnd,
                                  count=count))
        return cls(events=tuple(out), seed=seed)


class FaultInjector:
    """Runtime state for one sweep call over a ``FaultPlan``.

    ``begin_round()`` advances the global round counter (the sweep calls it
    once per evaluation round); ``fire(shard, cancel)`` raises/blocks when a
    matching event has count left; ``maybe_tear()`` consumes a ``torn_write``
    event for the current round. All methods are thread-safe. ``fired``
    records (kind, shard, round) in fire order for auditing."""

    def __init__(self, plan: FaultPlan, telemetry: "FaultTelemetry" = None):
        self.plan = plan
        self.telemetry = telemetry
        self._lock = threading.Lock()
        self._remaining = [e.count for e in plan.events]
        self._round = -1
        self.fired: List[Tuple[str, int, int]] = []

    @property
    def current_round(self) -> int:
        return self._round

    def begin_round(self) -> int:
        with self._lock:
            self._round += 1
            return self._round

    def _take(self, shard: Optional[int], torn: bool) -> Optional[FaultEvent]:
        with self._lock:
            for i, ev in enumerate(self.plan.events):
                if self._remaining[i] <= 0 or ev.round != self._round:
                    continue
                if torn != (ev.kind == "torn_write"):
                    continue
                if not torn and ev.shard != shard:
                    continue
                self._remaining[i] -= 1
                self.fired.append((ev.kind, ev.shard, self._round))
                return ev
        return None

    def fire(self, shard: int, cancel_event=None) -> None:
        """Raise/block per the plan for (shard, current round). Called by
        each shard worker at every evaluation attempt; a no-op when nothing
        is scheduled (or everything scheduled already fired)."""
        ev = self._take(shard, torn=False)
        if ev is None:
            return
        where = f"(shard {shard}, round {self._round})"
        if ev.kind == "transient":
            raise InjectedTransientError(f"injected transient failure {where}")
        if ev.kind == "crash":
            raise InjectedWorkerCrash(f"injected worker crash {where}")
        if ev.kind == "fatal":
            raise InjectedFatalError(f"injected fatal error {where}")
        # hang: stop heartbeating until the watchdog abandons this shard
        # (sets the cancel event), then exit the thread via InjectedHang so
        # the test's hung worker does not leak past the sweep.
        if cancel_event is not None:
            cancel_event.wait()
        raise InjectedHang(f"injected hang abandoned by watchdog {where}")

    def maybe_tear(self) -> bool:
        """Consume a ``torn_write`` event for the current round (the journal
        ``record`` path asks before appending)."""
        ev = self._take(None, torn=True)
        if ev is not None and self.telemetry is not None:
            self.telemetry.note_torn_write()
        return ev is not None


# --------------------------------------------------------------------------
# Fault-tolerance policy (retry / backoff / watchdog / strictness)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class FaultTolerance:
    """Recovery policy for sharded sweep execution.

    * ``max_retries`` transient failures per shard attempt retry in place,
      sleeping ``backoff_base_s * backoff_factor**(attempt-1)`` scaled by a
      seeded jitter in ``[1, 1 + jitter_frac)`` — deterministic in
      ``(seed, shard, attempt)``, so two runs of the same plan back off
      identically (replayability; also decorrelates shards).
    * ``shard_timeout_s`` arms the per-shard heartbeat watchdog: a shard
      whose heartbeat (refreshed at every evaluation attempt) goes stale for
      longer is abandoned and its memo keys fail over to the surviving
      shards. ``None`` (default) disarms it — an unbounded evaluation is
      indistinguishable from a hang, so the bound must be chosen by the
      caller who knows the workload scale.
    * ``strict=True`` turns graceful degradation (shrink the plan, finish
      the sweep) into an immediate ``ShardEvaluationError`` — for callers
      who prefer a loud failure over a slower success.
    * ``max_failover_rounds`` bounds re-partitioning (default: the shard
      count), so a fault that follows the keys cannot livelock the sweep.
    """

    max_retries: int = 2
    backoff_base_s: float = 0.05
    backoff_factor: float = 2.0
    jitter_frac: float = 0.25
    seed: int = 0
    shard_timeout_s: Optional[float] = None
    watchdog_poll_s: float = 0.02
    strict: bool = False
    max_failover_rounds: Optional[int] = None

    def __post_init__(self) -> None:
        if self.max_retries < 0 or self.backoff_base_s < 0:
            raise ValueError(f"invalid retry policy: {self}")
        if self.backoff_factor < 1.0 or self.jitter_frac < 0:
            raise ValueError(f"invalid backoff policy: {self}")
        if self.shard_timeout_s is not None and self.shard_timeout_s <= 0:
            raise ValueError("shard_timeout_s must be positive (or None)")


def backoff_seconds(tol: FaultTolerance, shard: int, attempt: int) -> float:
    """Delay before retry ``attempt`` (1-based) on ``shard``: exponential in
    the attempt, jittered by a PRNG seeded from (policy seed, shard,
    attempt) — fully deterministic, no global random state."""
    base = tol.backoff_base_s * (tol.backoff_factor ** (attempt - 1))
    # Deterministic integer mix (no str hashing: PYTHONHASHSEED-proof).
    mixed = (int(tol.seed) * 1_000_003 + int(shard)) * 1_000_003 + int(attempt)
    rng = random.Random(mixed)
    return base * (1.0 + tol.jitter_frac * rng.random())


# --------------------------------------------------------------------------
# Failure telemetry
# --------------------------------------------------------------------------

class FaultTelemetry:
    """Thread-safe counters describing how a sweep survived its faults.

    Recorded on ``SweepResult.telemetry`` and serialized by
    ``SweepResult.to_json`` (``fault_telemetry``). Fault-free sweeps report
    all-zero counters — CI asserts that, so spurious retries/failovers in
    the production path are themselves a test failure."""

    COUNTER_FIELDS = (
        "retries", "transient_errors", "worker_crashes", "hung_shards",
        "retries_exhausted", "failed_shards", "failovers", "failover_keys",
        "lost_devices", "torn_writes",
    )

    def __init__(self) -> None:
        self._lock = threading.Lock()
        for name in self.COUNTER_FIELDS:
            setattr(self, name, 0)
        # shard index -> {"device", "keys", "wall_s", "retries",
        #                 "failures": [kind, ...]} (accumulated over rounds)
        self.shards: Dict[int, Dict[str, object]] = {}

    def _shard(self, shard: int) -> Dict[str, object]:
        rec = self.shards.get(shard)
        if rec is None:
            rec = self.shards[shard] = {
                "device": None, "keys": 0, "wall_s": 0.0, "retries": 0,
                "failures": [],
            }
        return rec

    def note_retry(self, shard: int) -> None:
        with self._lock:
            self.retries += 1
            self._shard(shard)["retries"] += 1

    def note_transient(self, shard: int) -> None:
        with self._lock:
            self.transient_errors += 1

    def note_shard(self, shard: int, device: str, keys: int,
                   wall_s: float) -> None:
        """One shard completed one supervision wave successfully (per-shard
        retry counts accumulate separately via ``note_retry``)."""
        with self._lock:
            rec = self._shard(shard)
            rec["device"] = device
            rec["keys"] = int(rec["keys"]) + int(keys)
            rec["wall_s"] = round(float(rec["wall_s"]) + float(wall_s), 6)

    def note_shard_failure(self, shard: int, kind: str,
                           device: str = None) -> None:
        with self._lock:
            self.failed_shards += 1
            if kind == "crash":
                self.worker_crashes += 1
            elif kind == "hang":
                self.hung_shards += 1
            elif kind == "transient":
                self.retries_exhausted += 1
            rec = self._shard(shard)
            if device is not None:
                rec["device"] = device
            rec["failures"] = list(rec["failures"]) + [kind]

    def note_failover(self, keys: int, survivors: int) -> None:
        with self._lock:
            self.failovers += 1
            self.failover_keys += int(keys)

    def note_lost_devices(self, n: int) -> None:
        with self._lock:
            self.lost_devices += int(n)

    def note_torn_write(self) -> None:
        with self._lock:
            self.torn_writes += 1

    @property
    def any_faults(self) -> bool:
        return any(getattr(self, f) for f in self.COUNTER_FIELDS)

    def brief(self) -> Dict[str, int]:
        """Counters only (no per-shard detail) — the benchmark perf row."""
        with self._lock:
            return {f: int(getattr(self, f)) for f in self.COUNTER_FIELDS}

    def to_dict(self) -> Dict[str, object]:
        with self._lock:
            out: Dict[str, object] = {
                f: int(getattr(self, f)) for f in self.COUNTER_FIELDS
            }
            out["shards"] = {
                str(i): dict(rec) for i, rec in sorted(self.shards.items())
            }
            return out
