"""Accelergy-style energy estimation (paper Sec. III, "Simulation output").

"We integrate an Accelergy-based energy estimator into EONSim to estimate
energy consumption according to the hardware configuration and operation
counts."

Accelergy's methodology: energy = sum over components of
(action count x per-action energy). Per-action energies below are embedded
(no external tool offline) from published 7nm-class accelerator + HBM2e
numbers (Accelergy/Timeloop tables, ~0.5-4 pJ on-chip, ~3.9 pJ/bit DRAM);
absolute values are configuration inputs, not model outputs.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict

from .hardware import HardwareConfig


@dataclass(frozen=True)
class EnergyTable:
    """Per-action energies in pJ."""

    mac_bf16: float = 0.8                 # one MAC in the systolic array
    vector_op: float = 0.2                # one VPU lane-op
    onchip_read_per_byte: float = 0.05    # SRAM read, large array
    onchip_write_per_byte: float = 0.06
    offchip_per_byte: float = 31.2        # HBM2e ~3.9 pJ/bit
    leakage_pj_per_cycle: float = 50.0
    # One full page-table walk (NeuMMU-style translation stage): a few
    # dependent DRAM/cache accesses by the walker. TLB *lookups* ride the
    # SRAM numbers above and are not billed separately.
    tlb_walk_pj: float = 120.0


@dataclass
class EnergyBreakdown:
    compute_pj: float = 0.0
    vector_pj: float = 0.0
    onchip_pj: float = 0.0
    offchip_pj: float = 0.0
    leakage_pj: float = 0.0
    translation_pj: float = 0.0   # page-table walks (0.0 without translation)

    @property
    def total_pj(self) -> float:
        return (
            self.compute_pj
            + self.vector_pj
            + self.onchip_pj
            + self.offchip_pj
            + self.leakage_pj
            + self.translation_pj
        )

    def as_dict(self) -> Dict[str, float]:
        return {
            "compute_pj": self.compute_pj,
            "vector_pj": self.vector_pj,
            "onchip_pj": self.onchip_pj,
            "offchip_pj": self.offchip_pj,
            "leakage_pj": self.leakage_pj,
            "translation_pj": self.translation_pj,
            "total_pj": self.total_pj,
        }


def estimate_energy(
    hw: HardwareConfig,
    *,
    macs: float,
    vector_ops: float,
    onchip_read_bytes: float,
    onchip_write_bytes: float,
    offchip_bytes: float,
    total_cycles: float,
    tlb_walks: float = 0.0,
    table: EnergyTable = EnergyTable(),
) -> EnergyBreakdown:
    return EnergyBreakdown(
        compute_pj=macs * table.mac_bf16,
        vector_pj=vector_ops * table.vector_op,
        onchip_pj=(
            onchip_read_bytes * table.onchip_read_per_byte
            + onchip_write_bytes * table.onchip_write_per_byte
        ),
        offchip_pj=offchip_bytes * table.offchip_per_byte,
        leakage_pj=total_cycles * table.leakage_pj_per_cycle,
        translation_pj=tlb_walks * table.tlb_walk_pj,
    )
