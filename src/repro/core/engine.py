"""EONSim simulation driver (paper Fig. 2 "Simulation" stage).

Pipeline per the paper:
  index trace  ->  full trace (workload config)  ->  address trace (memory
  config)  ->  on-chip policy classification (hits / miss trace)  ->  DRAM
  timing for misses  ->  per-batch timing + access counts + energy.

The embedding memory path (classification, lane transform, segmented DRAM
timing, per-batch attribution) lives in ``memory.system.MemorySystem``; this
module drives it, runs the analytical matrix model, and assembles results.
Matrix ops run through the analytical model (matrix_model.py) and are summed
with embedding time per batch (DLRM: embedding gather/pool feeds interaction
and the top MLP — dependent stages, so times add).

On-chip state persists across inference batches: the policy simulation runs
once over the concatenated multi-batch trace and timing/counts are attributed
per batch afterwards.

The trace-building / matrix-summary / result-assembly stages are exposed
separately so the DSE sweep engine (``sweep.py``) can share generated traces
and matrix results across many configurations while staying bit-exact with
independent ``simulate()`` calls.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List, Optional

import numpy as np

from .energy import EnergyTable, estimate_energy
from .hardware import HardwareConfig
from .matrix_model import simulate_matrix_op
from .profiling import stage
from .memory.system import (  # re-exported for back-compat
    EmbeddingBatchStats,
    EmbeddingTrace,
    MemorySystem,
    MultiCoreMemorySystem,
    lane_geometry,
    memory_system_for,
)
from .results import BatchResult, SimResult
from .trace import FullTrace, expand_trace, generate_zipf_trace
from .workload import EmbeddingOpSpec, Workload

__all__ = [
    "EmbeddingBatchStats",
    "EmbeddingTrace",
    "MatrixSummary",
    "MultiCoreMemorySystem",
    "assemble_result",
    "build_embedding_traces",
    "lane_geometry",
    "memory_system_for",
    "simulate",
    "simulate_embedding_op",
    "summarize_matrix_ops",
]


def simulate_embedding_op(
    spec: EmbeddingOpSpec,
    traces: List[FullTrace],
    hw: HardwareConfig,
    pinned_lines: Optional[np.ndarray] = None,
) -> List[EmbeddingBatchStats]:
    """Simulate one embedding op over ``len(traces)`` inference batches.

    Returns per-batch stats; on-chip state persists across batches (the
    policy runs once over the concatenated trace). Multi-core hardware
    configurations route through the CoreCluster pipeline transparently.
    """
    ms = memory_system_for(hw)
    return ms.simulate_embedding(EmbeddingTrace(spec, traces), pinned_lines=pinned_lines)


# --------------------------------------------------------------------------
# Matrix side (analytical, identical per batch)
# --------------------------------------------------------------------------

@dataclass(frozen=True)
class MatrixSummary:
    """Per-batch matrix-op aggregates (analytical model, batch-invariant)."""

    cycles: float
    onchip_reads: int
    onchip_writes: int
    dram_lines: int
    macs_per_batch: float


def summarize_matrix_ops(workload: Workload, hw: HardwareConfig) -> MatrixSummary:
    results = [simulate_matrix_op(op, hw) for op in workload.matrix_ops]
    return MatrixSummary(
        cycles=sum(r.total_cycles for r in results),
        onchip_reads=sum(r.onchip_reads for r in results),
        onchip_writes=sum(r.onchip_writes for r in results),
        dram_lines=sum(
            math.ceil(r.dram_bytes / hw.onchip.line_bytes) for r in results
        ),
        macs_per_batch=sum(r.flops for r in results) / 2,
    )


# --------------------------------------------------------------------------
# Trace building (hardware-independent; shared across sweep configs)
# --------------------------------------------------------------------------

def build_embedding_traces(
    workload: Workload,
    index_trace: Optional[np.ndarray] = None,
    seed: int = 0,
    zipf_s: float = 0.8,
) -> List[EmbeddingTrace]:
    """Build one multi-batch ``EmbeddingTrace`` per embedding op spec.

    Deterministic in ``(workload, index_trace, seed, zipf_s)`` and independent
    of the hardware config — the basis for trace sharing across a DSE sweep.
    """
    with stage("trace_gen"):
        etraces: List[EmbeddingTrace] = []
        for spec in workload.embedding_ops:
            traces = []
            for bi in range(workload.num_batches):
                if index_trace is None:
                    n_acc = spec.lookups_per_batch(workload.batch_size)
                    it = generate_zipf_trace(
                        n_acc, spec.rows_per_table, s=zipf_s, seed=seed + bi
                    )
                else:
                    it = index_trace
                traces.append(
                    expand_trace(it, spec, workload.batch_size, seed=seed + bi)
                )
            etraces.append(EmbeddingTrace(spec, traces))
        return etraces


# --------------------------------------------------------------------------
# Result assembly
# --------------------------------------------------------------------------

def assemble_result(
    workload: Workload,
    hw: HardwareConfig,
    matrix: MatrixSummary,
    per_spec_stats: List[List[EmbeddingBatchStats]],
    energy_table: EnergyTable = EnergyTable(),
) -> SimResult:
    result = SimResult(
        workload=workload.name,
        hardware=hw.name,
        policy=hw.onchip.policy.value,
        clock_ghz=hw.clock_ghz,
        num_cores=hw.num_cores,
        topology=hw.topology.value,
    )
    total_vec_ops = 0.0
    for bi in range(workload.num_batches):
        br = BatchResult(batch_index=bi)
        br.matrix_cycles = matrix.cycles
        br.onchip_reads = matrix.onchip_reads
        br.onchip_writes = matrix.onchip_writes
        br.offchip_reads = matrix.dram_lines
        for spec, stats in zip(workload.embedding_ops, per_spec_stats):
            s = stats[bi]
            br.embedding_cycles += s.cycles
            br.onchip_reads += s.onchip_reads
            br.onchip_writes += s.onchip_writes
            br.offchip_reads += s.offchip_reads
            br.cache_hits += s.cache_hits
            br.cache_misses += s.cache_misses
            br.dram_row_hits += s.dram_row_hits
            br.dram_row_misses += s.dram_row_misses
            br.tlb_hits += s.tlb_hits
            br.tlb_misses += s.tlb_misses
            br.tlb_walks += s.tlb_walks
            br.translation_cycles += s.translation_cycles
            br.vector_ops += int(spec.reduction_flops(workload.batch_size))
        br.total_cycles = br.embedding_cycles + matrix.cycles
        total_vec_ops += br.vector_ops
        result.batches.append(br)

    line = hw.onchip.line_bytes
    energy = estimate_energy(
        hw,
        macs=matrix.macs_per_batch * workload.num_batches,
        vector_ops=total_vec_ops,
        onchip_read_bytes=result.onchip_reads * line,
        onchip_write_bytes=result.onchip_writes * line,
        offchip_bytes=result.offchip_reads * line,
        total_cycles=result.total_cycles,
        tlb_walks=float(result.tlb_walks),
        table=energy_table,
    )
    result.energy_pj = energy.total_pj
    return result


# --------------------------------------------------------------------------
# Full-workload simulation
# --------------------------------------------------------------------------

def simulate(
    workload: Workload,
    hw: HardwareConfig,
    index_trace: Optional[np.ndarray] = None,
    seed: int = 0,
    energy_table: EnergyTable = EnergyTable(),
    zipf_s: float = 0.8,
) -> SimResult:
    """Run a full EONSim simulation: all batches, matrix + embedding ops."""
    matrix = summarize_matrix_ops(workload, hw)
    etraces = build_embedding_traces(workload, index_trace, seed, zipf_s)
    ms = memory_system_for(hw)
    per_spec_stats = [ms.simulate_embedding(et) for et in etraces]
    return assemble_result(workload, hw, matrix, per_spec_stats, energy_table)
