"""EONSim simulation driver (paper Fig. 2 "Simulation" stage).

Pipeline per the paper:
  index trace  ->  full trace (workload config)  ->  address trace (memory
  config)  ->  on-chip policy classification (hits / miss trace)  ->  DRAM
  timing for misses  ->  per-batch timing + access counts + energy.

Matrix ops run through the analytical model (matrix_model.py) and are summed
with embedding time per batch (DLRM: embedding gather/pool feeds interaction
and the top MLP — dependent stages, so times add).

On-chip state persists across inference batches: the policy simulation runs
once over the concatenated multi-batch trace and timing/counts are attributed
per batch afterwards.

Performance note (the paper stresses *fast and accurate*): when the cache
geometry satisfies ``num_sets % lines_per_vector == 0`` and vectors are
line-aligned, the line-level set-associative cache decomposes into
``lines_per_vector`` independent "lane" sub-caches that each observe the same
vector-granular stream. Simulating ONE lane at vector granularity and scaling
counts is then *bit-exact* vs line-level simulation (tests enforce this) and
cuts scan length by lines_per_vector (8x for DLRM's 512 B vectors / 64 B
lines).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np

from .energy import EnergyTable, estimate_energy
from .hardware import HardwareConfig, OnChipPolicy
from .matrix_model import simulate_matrix_op
from .memory.cache import CacheGeometry, simulate_cache
from .memory.dram import DramModel, dram_timing
from .memory.policies import profile_hot_lines, run_policy
from .results import BatchResult, SimResult
from .trace import FullTrace, expand_trace, generate_zipf_trace, translate
from .workload import EmbeddingOpSpec, Workload

_CACHE_POLICIES = (OnChipPolicy.LRU, OnChipPolicy.SRRIP, OnChipPolicy.FIFO)


# --------------------------------------------------------------------------
# Lane-decomposition fast path
# --------------------------------------------------------------------------

def lane_geometry(hw: HardwareConfig, spec: EmbeddingOpSpec) -> Optional[CacheGeometry]:
    """Vector-granular lane geometry when the decomposition is exact."""
    line = hw.onchip.line_bytes
    if spec.vector_bytes % line != 0:
        return None
    lpv = spec.vector_bytes // line
    full_geom = CacheGeometry.from_capacity(hw.onchip.capacity_bytes, line, hw.onchip.ways)
    if lpv <= 1 or full_geom.num_sets % lpv != 0:
        return None
    return CacheGeometry(
        num_sets=full_geom.num_sets // lpv,
        ways=full_geom.ways,
        line_bytes=spec.vector_bytes,
    )


# --------------------------------------------------------------------------
# Embedding-op simulation (multi-batch, persistent on-chip state)
# --------------------------------------------------------------------------

@dataclass
class EmbeddingBatchStats:
    cycles: float = 0.0
    vector_cycles: float = 0.0
    dram_cycles: float = 0.0
    onchip_cycles: float = 0.0
    onchip_reads: int = 0
    onchip_writes: int = 0
    offchip_reads: int = 0
    cache_hits: int = 0          # line-granular
    cache_misses: int = 0
    dram_row_hits: int = 0
    dram_row_misses: int = 0


def _vector_compute_cycles(spec: EmbeddingOpSpec, batch_size: int, hw: HardwareConfig) -> float:
    """Stage-3 vector arithmetic (Fig. 1): pooling on the VPU."""
    flops = spec.reduction_flops(batch_size)
    return flops / max(hw.vector_unit.throughput, 1)


def simulate_embedding_op(
    spec: EmbeddingOpSpec,
    traces: List[FullTrace],
    hw: HardwareConfig,
    pinned_lines: Optional[np.ndarray] = None,
) -> List[EmbeddingBatchStats]:
    """Simulate one embedding op over ``len(traces)`` inference batches.

    Returns per-batch stats; on-chip state persists across batches (the
    policy runs once over the concatenated trace).
    """
    line = hw.onchip.line_bytes
    policy = hw.onchip.policy
    lpv = max(1, -(-spec.vector_bytes // line))
    num_batches = len(traces)

    n_per_batch = [len(t) for t in traces]
    lookup_batch = np.repeat(np.arange(num_batches), n_per_batch)
    table_ids = np.concatenate([t.table_ids for t in traces])
    row_ids = np.concatenate([t.row_ids for t in traces])
    n_lookups = row_ids.size

    lane = lane_geometry(hw, spec)
    use_lane = lane is not None and policy in _CACHE_POLICIES

    if use_lane:
        vec_ids = table_ids.astype(np.int64) * spec.rows_per_table + row_ids
        res = simulate_cache(vec_ids, lane, policy=policy.value)
        hits_lookup = res.hits
        hit_lines = np.bincount(lookup_batch[hits_lookup], minlength=num_batches) * lpv
        miss_lines_ct = np.bincount(lookup_batch[~hits_lookup], minlength=num_batches) * lpv
        onchip_reads = np.bincount(lookup_batch, minlength=num_batches) * lpv
        onchip_writes = miss_lines_ct.copy()
        offchip_reads = miss_lines_ct.copy()
        # expand vector misses back to line addresses for DRAM timing
        base = (
            table_ids.astype(np.int64)[~hits_lookup] * spec.table_bytes
            + row_ids[~hits_lookup] * spec.vector_bytes
        ) // line
        miss_lines_all = (base[:, None] + np.arange(lpv)[None, :]).reshape(-1)
        miss_line_batch = np.repeat(lookup_batch[~hits_lookup], lpv)
        pinned_count = 0
    else:
        concat = FullTrace(
            table_ids=table_ids,
            row_ids=row_ids,
            batch_size=n_lookups
            // max(traces[0].num_tables * traces[0].lookups_per_sample, 1),
            num_tables=traces[0].num_tables,
            lookups_per_sample=traces[0].lookups_per_sample,
        )
        atrace = translate(concat, spec, line)
        if policy == OnChipPolicy.PINNING and pinned_lines is None:
            pinned_lines = profile_hot_lines(atrace.lines, hw.onchip.num_lines)
        out = run_policy(atrace, hw, pinned_lines)
        line_batch = np.repeat(lookup_batch, lpv)
        hit_lines = np.bincount(line_batch[out.hits], minlength=num_batches)
        miss_lines_ct = np.bincount(line_batch[~out.hits], minlength=num_batches)
        onchip_reads = np.bincount(line_batch, minlength=num_batches)
        onchip_writes = miss_lines_ct.copy()
        offchip_reads = miss_lines_ct.copy()
        miss_lines_all = out.miss_lines
        miss_line_batch = line_batch[~out.hits]
        pinned_count = len(pinned_lines) if (
            policy == OnChipPolicy.PINNING and pinned_lines is not None
        ) else 0

    dram = DramModel.from_hardware(hw)
    onchip_bw = max(hw.onchip.read_bw_bytes_per_cycle, 1)

    stats: List[EmbeddingBatchStats] = []
    for b in range(num_batches):
        s = EmbeddingBatchStats()
        miss_b = miss_lines_all[miss_line_batch == b]
        d = dram_timing(miss_b, dram)
        s.dram_cycles = d.finish_cycle
        s.dram_row_hits = d.row_hits
        s.dram_row_misses = d.row_misses
        s.onchip_reads = int(onchip_reads[b])
        s.onchip_writes = int(onchip_writes[b]) + (pinned_count if b == 0 else 0)
        s.offchip_reads = int(offchip_reads[b])
        s.cache_hits = int(hit_lines[b])
        s.cache_misses = int(miss_lines_ct[b])
        s.onchip_cycles = s.onchip_reads * line / onchip_bw + hw.onchip.latency_cycles
        s.vector_cycles = _vector_compute_cycles(spec, traces[b].batch_size, hw)
        # on-chip service, off-chip service and pooling overlap in a
        # double-buffered stream; the slowest stage bounds the batch.
        s.cycles = max(s.onchip_cycles, s.dram_cycles, s.vector_cycles)
        stats.append(s)
    return stats


# --------------------------------------------------------------------------
# Full-workload simulation
# --------------------------------------------------------------------------

def simulate(
    workload: Workload,
    hw: HardwareConfig,
    index_trace: Optional[np.ndarray] = None,
    seed: int = 0,
    energy_table: EnergyTable = EnergyTable(),
    zipf_s: float = 0.8,
) -> SimResult:
    """Run a full EONSim simulation: all batches, matrix + embedding ops."""
    result = SimResult(
        workload=workload.name,
        hardware=hw.name,
        policy=hw.onchip.policy.value,
        clock_ghz=hw.clock_ghz,
    )

    # Matrix side: analytical, identical per batch.
    matrix_results = [simulate_matrix_op(op, hw) for op in workload.matrix_ops]
    matrix_cycles = sum(r.total_cycles for r in matrix_results)
    matrix_onchip_r = sum(r.onchip_reads for r in matrix_results)
    matrix_onchip_w = sum(r.onchip_writes for r in matrix_results)
    matrix_dram_lines = sum(
        math.ceil(r.dram_bytes / hw.onchip.line_bytes) for r in matrix_results
    )
    macs_per_batch = sum(r.flops for r in matrix_results) / 2

    # Embedding side: per spec, build per-batch traces then simulate with
    # persistent on-chip state.
    per_spec_stats: List[List[EmbeddingBatchStats]] = []
    for spec in workload.embedding_ops:
        traces = []
        for bi in range(workload.num_batches):
            if index_trace is None:
                n_acc = spec.lookups_per_batch(workload.batch_size)
                it = generate_zipf_trace(n_acc, spec.rows_per_table, s=zipf_s, seed=seed + bi)
            else:
                it = index_trace
            traces.append(expand_trace(it, spec, workload.batch_size, seed=seed + bi))
        per_spec_stats.append(simulate_embedding_op(spec, traces, hw))

    total_vec_ops = 0.0
    for bi in range(workload.num_batches):
        br = BatchResult(batch_index=bi)
        br.matrix_cycles = matrix_cycles
        br.onchip_reads = matrix_onchip_r
        br.onchip_writes = matrix_onchip_w
        br.offchip_reads = matrix_dram_lines
        for spec, stats in zip(workload.embedding_ops, per_spec_stats):
            s = stats[bi]
            br.embedding_cycles += s.cycles
            br.onchip_reads += s.onchip_reads
            br.onchip_writes += s.onchip_writes
            br.offchip_reads += s.offchip_reads
            br.cache_hits += s.cache_hits
            br.cache_misses += s.cache_misses
            br.dram_row_hits += s.dram_row_hits
            br.dram_row_misses += s.dram_row_misses
            br.vector_ops += int(spec.reduction_flops(workload.batch_size))
        br.total_cycles = br.embedding_cycles + matrix_cycles
        total_vec_ops += br.vector_ops
        result.batches.append(br)

    line = hw.onchip.line_bytes
    energy = estimate_energy(
        hw,
        macs=macs_per_batch * workload.num_batches,
        vector_ops=total_vec_ops,
        onchip_read_bytes=result.onchip_reads * line,
        onchip_write_bytes=result.onchip_writes * line,
        offchip_bytes=result.offchip_reads * line,
        total_cycles=result.total_cycles,
        table=energy_table,
    )
    result.energy_pj = energy.total_pj
    return result
