"""Map assigned LM architectures onto EONSim workloads (beyond-paper).

The paper's pipeline consumes (matrix ops in MNK form) + (embedding ops with
index traces). Any of the 10 assigned archs maps onto that interface:

  * the vocab-embedding lookup is EXACTLY the paper's operation — one table,
    ``vocab`` rows, d_model-dim vectors, one lookup per token, CONCAT pooling,
    with a Zipf token distribution (real token streams are Zipfian);
  * every projection / FFN / logits matmul is an MNK matrix op (MoE counts
    top-k active experts at the routed capacity);
  * attention score/AV products are MNK ops with M = tokens, N = seq.

This lets the simulator answer paper-style questions (SPM vs cache vs pinned
on-chip management) for LM token-embedding traffic — see
benchmarks/lm_npu_study.py.
"""
from __future__ import annotations

import math
from typing import List

from ..models.config import ArchConfig, ShapeConfig
from .workload import EmbeddingOpSpec, MatrixOpSpec, VectorOp, Workload


def _attn_matrix_ops(cfg: ArchConfig, tokens: int, seq: int, causal_frac: float = 0.5):
    dh = cfg.attn_head_dim
    ops = []
    if cfg.mla is not None:
        m = cfg.mla
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        ops += [
            MatrixOpSpec(tokens, cfg.n_heads * qd, cfg.d_model, "mla_wq"),
            MatrixOpSpec(tokens, m.kv_lora_rank + m.qk_rope_head_dim, cfg.d_model, "mla_dkv"),
            MatrixOpSpec(tokens, cfg.n_heads * m.qk_nope_head_dim, m.kv_lora_rank, "mla_uk"),
            MatrixOpSpec(tokens, cfg.n_heads * m.v_head_dim, m.kv_lora_rank, "mla_uv"),
            MatrixOpSpec(tokens, cfg.d_model, cfg.n_heads * m.v_head_dim, "mla_wo"),
        ]
        score_k = qd
        v_dim = m.v_head_dim
        heads = cfg.n_heads
    else:
        ops += [
            MatrixOpSpec(tokens, cfg.n_heads * dh, cfg.d_model, "wq"),
            MatrixOpSpec(tokens, cfg.n_kv_heads * dh, cfg.d_model, "wk"),
            MatrixOpSpec(tokens, cfg.n_kv_heads * dh, cfg.d_model, "wv"),
            MatrixOpSpec(tokens, cfg.d_model, cfg.n_heads * dh, "wo"),
        ]
        score_k = dh
        v_dim = dh
        heads = cfg.n_heads
    eff = max(int(seq * causal_frac), 1)
    ops += [
        MatrixOpSpec(tokens * heads, eff, score_k, "qk"),
        MatrixOpSpec(tokens * heads, v_dim, eff, "av"),
    ]
    return ops


def _ffn_matrix_ops(cfg: ArchConfig, tokens: int) -> List[MatrixOpSpec]:
    ops = []
    if cfg.moe is not None:
        m = cfg.moe
        routed = tokens * m.top_k
        ops.append(MatrixOpSpec(tokens, m.num_experts, cfg.d_model, "router"))
        for nm in ("moe_wg", "moe_wu"):
            ops.append(MatrixOpSpec(routed, m.d_ff_expert, cfg.d_model, nm))
        ops.append(MatrixOpSpec(routed, cfg.d_model, m.d_ff_expert, "moe_wd"))
        if m.num_shared_experts:
            f = m.d_ff_shared or m.d_ff_expert * m.num_shared_experts
            ops += [
                MatrixOpSpec(tokens, f, cfg.d_model, "sh_wg"),
                MatrixOpSpec(tokens, f, cfg.d_model, "sh_wu"),
                MatrixOpSpec(tokens, cfg.d_model, f, "sh_wd"),
            ]
    if cfg.d_ff:
        mult = 2 if cfg.mlp_type == "gelu" else 3
        names = ["w1", "w2"] if mult == 2 else ["wg", "wu"]
        for nm in names:
            ops.append(MatrixOpSpec(tokens, cfg.d_ff, cfg.d_model, nm))
        ops.append(MatrixOpSpec(tokens, cfg.d_model, cfg.d_ff, "wd"))
    return ops


def _ssm_matrix_ops(cfg: ArchConfig, tokens: int) -> List[MatrixOpSpec]:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    N = s.state_dim
    return [
        MatrixOpSpec(tokens, di, cfg.d_model, "in_z"),
        MatrixOpSpec(tokens, di + 2 * N, cfg.d_model, "in_xbc"),
        MatrixOpSpec(tokens, H, cfg.d_model, "in_dt"),
        # SSD state ops ~ 2 * tokens * di * N (outer products + contractions)
        MatrixOpSpec(tokens, N, di, "ssd_state", count=2),
        MatrixOpSpec(tokens, cfg.d_model, di, "out_proj"),
    ]


def lm_workload(cfg: ArchConfig, shape: ShapeConfig, num_batches: int = 1) -> Workload:
    """EONSim workload for one (arch x shape) cell."""
    B = shape.global_batch
    S = shape.seq_len if shape.kind != "decode" else 1
    tokens = B * S
    train_mult = 3 if shape.is_train else 1      # fwd + bwd ~ 2x fwd

    mat: List[MatrixOpSpec] = []
    n_layers = cfg.n_layers
    if cfg.family == "ssm":
        per_layer = _ssm_matrix_ops(cfg, tokens)
    elif cfg.family == "hybrid":
        per_layer = _ssm_matrix_ops(cfg, tokens)
        shared = _attn_matrix_ops(cfg, tokens, shape.seq_len)
        f = cfg.hybrid.shared_d_ff or 4 * cfg.d_model
        shared += [
            MatrixOpSpec(tokens, f, cfg.d_model, "sh_wg"),
            MatrixOpSpec(tokens, f, cfg.d_model, "sh_wu"),
            MatrixOpSpec(tokens, cfg.d_model, f, "sh_wd"),
        ]
        n_apps = cfg.n_layers // cfg.hybrid.attn_every
        mat += [
            MatrixOpSpec(op.m, op.n, op.k, f"shared_{op.name}", count=op.count * n_apps * train_mult)
            for op in shared
        ]
    elif cfg.family == "audio":
        enc_tokens = B * cfg.encdec.encoder_seq
        enc = _attn_matrix_ops(cfg, enc_tokens, cfg.encdec.encoder_seq, 1.0)
        enc += _ffn_matrix_ops(cfg, enc_tokens)
        mat += [
            MatrixOpSpec(op.m, op.n, op.k, f"enc_{op.name}",
                         count=op.count * cfg.encdec.encoder_layers * train_mult)
            for op in enc
        ]
        per_layer = _attn_matrix_ops(cfg, tokens, shape.seq_len) * 2  # self+cross
        per_layer += _ffn_matrix_ops(cfg, tokens)
    else:
        per_layer = _attn_matrix_ops(cfg, tokens, shape.seq_len)
        per_layer += _ffn_matrix_ops(cfg, tokens)

    mat += [
        MatrixOpSpec(op.m, op.n, op.k, op.name, count=op.count * n_layers * train_mult)
        for op in per_layer
    ]
    mat.append(MatrixOpSpec(tokens, cfg.vocab, cfg.d_model, "logits", count=train_mult))

    emb = EmbeddingOpSpec(
        num_tables=1,
        rows_per_table=cfg.vocab,
        dim=cfg.d_model,
        lookups_per_sample=S,
        vector_op=VectorOp.CONCAT,
        dtype_bytes=2,
        name="token_embedding",
    )
    return Workload(
        name=f"{cfg.name}_{shape.name}",
        matrix_ops=tuple(mat),
        embedding_ops=(emb,),
        batch_size=B,
        num_batches=num_batches,
    )
