"""Analytical matrix-operation model (paper Sec. III, "Simulation flow").

"For matrix operations, EONSim integrates an analytical performance model
from prior work [SCALE-Sim, LLMCompass]. This approach combines a
SCALE-Sim-based model for computation cycles with an analytical model for
memory operation cycles. The memory model calculates the data transfer time
T = D/B + L."

Compute cycles follow SCALE-Sim's systolic-array timing:

  Weight-stationary (R x C array, GEMM (M,K)@(K,N)):
    folds = ceil(K/R) * ceil(N/C); per fold a K_t x N_t weight tile loads in
    K_t cycles, then M activations stream through with pipeline skew:
      t_fold = K_t + M + K_t + C_t - 2   (fill + stream + drain)

  Output-stationary:
    folds = ceil(M/R) * ceil(N/C); K streams:
      t_fold = K + R_t + C_t - 2  (+ R_t drain for accumulator read-out)

Memory cycles use T = D/B + L per tile, double-buffered against compute
(max(compute, memory) steady state + prologue) — the paper's SPM baseline
for matrix tiles.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

from .hardware import Dataflow, HardwareConfig
from .memory.dram import bulk_transfer_cycles
from .workload import MatrixOpSpec


@dataclass(frozen=True)
class MatrixOpResult:
    name: str
    compute_cycles: float
    memory_cycles: float
    total_cycles: float
    flops: int
    dram_bytes: int
    onchip_reads: int            # line-granular on-chip reads (operands)
    onchip_writes: int           # line-granular on-chip writes (fills + outputs)

    @property
    def utilization(self) -> float:
        """Achieved MAC utilization vs ideal (flops / (2*macs*cycles))."""
        return self.flops / max(self.total_cycles, 1e-9)


def _ws_fold_cycles(k_t: int, c_t: int, m: int) -> float:
    # fill K_t rows of weights, stream M rows with K_t+C_t-2 skew/drain
    return k_t + m + k_t + c_t - 2


def _os_fold_cycles(r_t: int, c_t: int, k: int) -> float:
    return k + r_t + c_t - 2 + r_t


def matrix_compute_cycles(op: MatrixOpSpec, hw: HardwareConfig) -> float:
    mu = hw.matrix_unit
    R, C = mu.rows, mu.cols
    M, N, K = op.m, op.n, op.k
    if mu.dataflow == Dataflow.WS:
        folds_k = math.ceil(K / R)
        folds_n = math.ceil(N / C)
        # last-fold tiles may be ragged; model exactly by summing edge tiles
        total = 0.0
        for ik in range(folds_k):
            k_t = min(R, K - ik * R)
            for in_ in range(folds_n):
                c_t = min(C, N - in_ * C)
                total += _ws_fold_cycles(k_t, c_t, M)
        return total * op.count
    else:  # OS
        folds_m = math.ceil(M / R)
        folds_n = math.ceil(N / C)
        total = 0.0
        for im in range(folds_m):
            r_t = min(R, M - im * R)
            for in_ in range(folds_n):
                c_t = min(C, N - in_ * C)
                total += _os_fold_cycles(r_t, c_t, K)
        return total * op.count


def matrix_memory_cycles(op: MatrixOpSpec, hw: HardwareConfig) -> float:
    """T = D/B + L per operand tile, summed (weights + inputs + outputs)."""
    d_total = op.input_bytes + op.weight_bytes + op.output_bytes
    return bulk_transfer_cycles(d_total, hw) * op.count


def simulate_matrix_op(op: MatrixOpSpec, hw: HardwareConfig) -> MatrixOpResult:
    comp = matrix_compute_cycles(op, hw)
    mem = matrix_memory_cycles(op, hw)
    # Double buffering overlaps tile fetch with compute: steady state is
    # bounded by the slower of the two; the first tile fetch is exposed.
    mu = hw.matrix_unit
    folds = max(
        1,
        math.ceil(op.k / mu.rows) * math.ceil(op.n / mu.cols)
        if mu.dataflow == Dataflow.WS
        else math.ceil(op.m / mu.rows) * math.ceil(op.n / mu.cols),
    )
    prologue = mem / max(folds, 1)  # first tile's fetch is not hidden
    total = prologue + max(comp, mem)
    line = hw.onchip.line_bytes
    d_in = op.input_bytes + op.weight_bytes
    d_out = op.output_bytes
    return MatrixOpResult(
        name=op.name,
        compute_cycles=comp,
        memory_cycles=mem,
        total_cycles=total,
        flops=op.flops,
        dram_bytes=(d_in + d_out) * op.count,
        onchip_reads=math.ceil(d_in / line) * op.count,
        onchip_writes=math.ceil((d_in + d_out) / line) * op.count,
    )
