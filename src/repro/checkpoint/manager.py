"""Fault-tolerant checkpointing.

Design (1000-node posture, implemented single-host):
  * every leaf saved as .npy inside a staging dir; metadata (tree structure,
    step, per-leaf sha256) in msgpack; ATOMIC publish via os.rename — a died
    writer never corrupts the latest checkpoint;
  * async save on a background thread (training continues; ``wait()`` joins);
  * keep-N garbage collection;
  * restore onto an ARBITRARY mesh: leaves are device_put with the target
    sharding (cross-topology resharding — the elastic-scaling path);
  * integrity: checksums verified on load, torn checkpoints rejected.

On a real cluster each host writes its data-parallel shard (process-local
leaves) — the layout here keeps one file per leaf so that extension is a
naming change, not a format change.
"""
from __future__ import annotations

import hashlib
import json
import os
import shutil
import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional

import jax
import ml_dtypes
import numpy as np

import msgpack

# numpy round-trips for non-native dtypes (bf16 etc.): stored as a raw view,
# dtype recorded in metadata and restored via .view()
_VIEW_DTYPES = {
    "bfloat16": (np.uint16, ml_dtypes.bfloat16),
    "float8_e4m3fn": (np.uint8, ml_dtypes.float8_e4m3fn),
    "float8_e5m2": (np.uint8, ml_dtypes.float8_e5m2),
}


@dataclass(frozen=True)
class CheckpointConfig:
    directory: str
    keep: int = 3
    async_save: bool = True
    verify_on_load: bool = True


def _flatten_with_paths(tree: Any):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        parts = []
        for p in path:
            if hasattr(p, "key"):
                parts.append(str(p.key))
            elif hasattr(p, "idx"):
                parts.append(str(p.idx))
            elif hasattr(p, "name"):       # GetAttrKey (NamedTuple fields)
                parts.append(str(p.name))
            else:
                parts.append(str(p))
        out.append(("/".join(parts), leaf))
    return out, treedef


class CheckpointManager:
    def __init__(self, cfg: CheckpointConfig):
        self.cfg = cfg
        os.makedirs(cfg.directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._error: Optional[BaseException] = None

    # ---- save -------------------------------------------------------------
    def save(self, step: int, tree: Any, extra: Optional[Dict] = None):
        # materialize on host BEFORE going async (training may mutate buffers)
        host_tree = jax.tree.map(lambda x: np.asarray(x), tree)
        if self.cfg.async_save:
            self.wait()
            self._thread = threading.Thread(
                target=self._write, args=(step, host_tree, extra or {}), daemon=True
            )
            self._thread.start()
        else:
            self._write(step, host_tree, extra or {})

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            err, self._error = self._error, None
            raise err

    def _write(self, step: int, host_tree: Any, extra: Dict):
        stage = None
        try:
            leaves, treedef = _flatten_with_paths(host_tree)
            stage = os.path.join(self.cfg.directory, f".tmp_step_{step}_{os.getpid()}")
            final = os.path.join(self.cfg.directory, f"step_{step:010d}")
            os.makedirs(stage, exist_ok=True)
            meta = {"step": step, "extra": extra, "leaves": [], "treedef": str(treedef)}
            for i, (name, leaf) in enumerate(leaves):
                fn = f"leaf_{i:05d}.npy"
                path = os.path.join(stage, fn)
                if str(leaf.dtype) in _VIEW_DTYPES:
                    np.save(path, leaf.view(_VIEW_DTYPES[str(leaf.dtype)][0]))
                else:
                    np.save(path, leaf)
                with open(path, "rb") as f:
                    digest = hashlib.sha256(f.read()).hexdigest()
                meta["leaves"].append(
                    {"name": name, "file": fn, "sha256": digest,
                     "dtype": str(leaf.dtype), "shape": list(leaf.shape)}
                )
            with open(os.path.join(stage, "META.msgpack"), "wb") as f:
                f.write(msgpack.packb(meta))
            if os.path.exists(final):
                shutil.rmtree(final)
            os.rename(stage, final)      # atomic publish
            self._gc()
        except BaseException as e:       # surfaced on next wait()
            self._error = e
            if stage is not None:
                shutil.rmtree(stage, ignore_errors=True)

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: -self.cfg.keep] if self.cfg.keep > 0 else []:
            shutil.rmtree(os.path.join(self.cfg.directory, f"step_{s:010d}"),
                          ignore_errors=True)

    # ---- restore ----------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for d in os.listdir(self.cfg.directory):
            if d.startswith("step_") and os.path.isdir(os.path.join(self.cfg.directory, d)):
                if os.path.exists(os.path.join(self.cfg.directory, d, "META.msgpack")):
                    out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(
        self,
        step: Optional[int] = None,
        *,
        target_tree: Any = None,
        shardings: Any = None,
    ):
        """Load step (default latest). With ``target_tree`` (same structure)
        the arrays are unflattened into it; with ``shardings`` every leaf is
        device_put onto the target mesh (cross-topology resharding)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.cfg.directory}")
        d = os.path.join(self.cfg.directory, f"step_{step:010d}")
        with open(os.path.join(d, "META.msgpack"), "rb") as f:
            meta = msgpack.unpackb(f.read())
        arrays = []
        for leaf_meta in meta["leaves"]:
            path = os.path.join(d, leaf_meta["file"])
            with open(path, "rb") as f:
                raw = f.read()
            if self.cfg.verify_on_load:
                if hashlib.sha256(raw).hexdigest() != leaf_meta["sha256"]:
                    raise IOError(f"checksum mismatch in {path} — torn checkpoint")
            arr = np.load(path)
            if leaf_meta["dtype"] in _VIEW_DTYPES:
                arr = arr.view(_VIEW_DTYPES[leaf_meta["dtype"]][1])
            arrays.append(arr)
        if target_tree is None:
            return {"step": meta["step"], "extra": meta["extra"], "leaves": arrays,
                    "names": [l["name"] for l in meta["leaves"]]}
        flat, treedef = jax.tree_util.tree_flatten(target_tree)
        assert len(flat) == len(arrays), (len(flat), len(arrays))
        if shardings is not None:
            shard_flat = jax.tree_util.tree_flatten(shardings)[0]
            arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_flat)]
        else:
            arrays = [jax.numpy.asarray(a) for a in arrays]
        return meta["step"], meta["extra"], jax.tree_util.tree_unflatten(treedef, arrays)
