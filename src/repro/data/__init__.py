from .lm import LMDataConfig, lm_batch, lm_batch_iterator
from .dlrm_data import DLRMDataConfig, dlrm_batch

__all__ = [
    "LMDataConfig",
    "lm_batch",
    "lm_batch_iterator",
    "DLRMDataConfig",
    "dlrm_batch",
]
