"""Deterministic synthetic LM data pipeline.

Token streams follow a Zipf unigram distribution with a short Markov
"phrase" structure — enough signal that a real LM's loss falls well below
the unigram entropy (tests assert this), while staying fully offline and
reproducible. Batches are a pure function of (seed, step): restart-safe by
construction (checkpoint stores only the step), and each host can slice its
shard without coordination (SPMD loading).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class LMDataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    zipf_s: float = 1.1
    phrase_len: int = 8        # deterministic continuation run length
    seed: int = 0


def _zipf_cdf(vocab: int, s: float) -> np.ndarray:
    p = 1.0 / np.power(np.arange(1, vocab + 1, dtype=np.float64), s)
    p /= p.sum()
    return np.cumsum(p)


_CDF_CACHE: Dict = {}


def lm_batch(cfg: LMDataConfig, step: int) -> Dict[str, np.ndarray]:
    """Batch for ``step``: tokens (B, S+1) -> inputs/labels are shifted views."""
    key = (cfg.vocab, cfg.zipf_s)
    if key not in _CDF_CACHE:
        _CDF_CACHE[key] = _zipf_cdf(cfg.vocab, cfg.zipf_s)
    cdf = _CDF_CACHE[key]

    rng = np.random.default_rng((cfg.seed, step))
    B, S = cfg.global_batch, cfg.seq_len
    n_phrases = -(-(S + 1) // cfg.phrase_len)
    starts = np.searchsorted(cdf, rng.random((B, n_phrases))).astype(np.int64)
    # phrase structure: token t+1 = (t * 31 + 7) % vocab within a phrase —
    # deterministic continuations a model can learn.
    offs = np.arange(cfg.phrase_len, dtype=np.int64)
    toks = starts[..., None]
    seq = [toks]
    cur = toks
    for _ in range(cfg.phrase_len - 1):
        cur = (cur * 31 + 7) % cfg.vocab
        seq.append(cur)
    full = np.concatenate(seq, axis=-1).reshape(B, -1)[:, : S + 1]
    return {
        "tokens": full[:, :-1].astype(np.int32),
        "labels": full[:, 1:].astype(np.int32),
    }


def lm_batch_iterator(cfg: LMDataConfig, start_step: int = 0) -> Iterator[Dict[str, np.ndarray]]:
    step = start_step
    while True:
        yield lm_batch(cfg, step)
        step += 1


def host_shard(batch: Dict[str, np.ndarray], host_id: int, num_hosts: int):
    """Slice this host's rows (SPMD data loading)."""
    out = {}
    for k, v in batch.items():
        per = v.shape[0] // num_hosts
        out[k] = v[host_id * per : (host_id + 1) * per]
    return out
