"""Deterministic synthetic DLRM click-log pipeline (paper's workload).

Sparse indices follow the same Zipf machinery as core.trace (the simulator
and the runtime consume the *same* access distributions — the point of the
paper's hardware-agnostic traces). Labels correlate with hot-feature overlap
so training has signal.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict

import numpy as np

from ..core.trace import generate_zipf_trace


@dataclass(frozen=True)
class DLRMDataConfig:
    num_tables: int
    rows_per_table: int
    lookups_per_table: int
    dense_features: int = 13
    batch_size: int = 32
    zipf_s: float = 1.0
    seed: int = 0


def dlrm_batch(cfg: DLRMDataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = np.random.default_rng((cfg.seed, step))
    B, T, L = cfg.batch_size, cfg.num_tables, cfg.lookups_per_table
    dense = rng.standard_normal((B, cfg.dense_features)).astype(np.float32)
    idx = generate_zipf_trace(
        B * T * L, cfg.rows_per_table, cfg.zipf_s, seed=int(rng.integers(1 << 31))
    ).reshape(B, T, L)
    # label: clicks correlate with the first dense feature and with how
    # "hot" the accessed rows are — a learnable but non-trivial signal
    hotness = 1.0 / (1.0 + idx.astype(np.float64).mean(axis=(1, 2)) / cfg.rows_per_table)
    z = (hotness - hotness.mean()) / (hotness.std() + 1e-9)
    prob = 1 / (1 + np.exp(-(2.5 * dense[:, 0] + 1.0 * z)))
    labels = (rng.random(B) < prob).astype(np.float32)
    return {"dense": dense, "sparse": idx.astype(np.int32), "labels": labels}
