"""Mamba2 (SSD) decoder-only LM — attention-free family."""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from ..distributed.sharding import activation_constraint, fsdp_unshard

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def init_layer(key, cfg: ArchConfig) -> Params:
    return {
        "norm": L.init_rmsnorm(cfg.d_model),
        "mixer": L.init_mamba2(key, cfg, _dtype(cfg)),
    }


def init_lm(key, cfg: ArchConfig) -> Params:
    ks = jax.random.split(key, 3 + cfg.n_layers)
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(jnp.stack(ks[3:]))
    p = {
        "embed": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, _dtype(cfg)),
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.init_lm_head(ks[1], cfg.d_model, cfg.vocab, _dtype(cfg))
    return p


def _apply_layer(cfg, p, x, *, ssm_state=None, conv_state=None, use_pallas=False):
    h = L.rmsnorm(p["norm"], x, cfg.norm_eps)
    y, new_ssm, new_conv = L.mamba2_block(
        p["mixer"], h, cfg,
        ssm_state=ssm_state, conv_state=conv_state, use_pallas=use_pallas,
    )
    return x + y, new_ssm, new_conv


def final_hidden(params, tokens, cfg, *, use_pallas=False, remat=True):
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))

    def body(x, layer_p):
        y, _, _ = _apply_layer(cfg, fsdp_unshard(layer_p), x, use_pallas=use_pallas)
        return y, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params, tokens, cfg, *, use_pallas=False, remat=True):
    x = final_hidden(params, tokens, cfg, use_pallas=use_pallas, remat=remat)
    from .transformer import hidden_to_logits

    return hidden_to_logits(params, x, cfg)


# --------------------------------------------------------------------------
# Serving: constant-size state cache (the sub-quadratic long_500k story)
# --------------------------------------------------------------------------

def init_state_cache(cfg: ArchConfig, batch: int) -> Tuple[jax.Array, jax.Array]:
    s = cfg.ssm
    H = s.num_heads(cfg.d_model)
    di = s.d_inner(cfg.d_model)
    conv_ch = di + 2 * s.state_dim
    ssm = jnp.zeros((cfg.n_layers, batch, H, s.head_dim, s.state_dim), jnp.float32)
    conv = jnp.zeros((cfg.n_layers, batch, s.conv_width - 1, conv_ch), _dtype(cfg))
    return ssm, conv


def prefill_with_state(params, tokens, cfg, *, use_pallas=False):
    """Parallel (chunked-SSD) prompt pass that also extracts per-layer
    (ssm_state, conv_state) so decode can continue — O(S) instead of the
    sequential recurrence."""
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))

    def body(x, layer_p):
        layer_p = fsdp_unshard(layer_p)
        h = L.rmsnorm(layer_p["norm"], x, cfg.norm_eps)
        y, st, cv = L.mamba2_block(
            layer_p["mixer"], h, cfg, use_pallas=use_pallas, return_final_state=True
        )
        return x + y, (st, cv)

    x, (ssm_states, conv_states) = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    from .transformer import hidden_to_logits

    logits = hidden_to_logits(params, x[:, -1:], cfg)
    return logits, (ssm_states, conv_states.astype(_dtype(cfg)))


def decode_step(params, tokens, cache_index, caches, cfg, *, use_pallas=False):
    """Decode with O(1) state (cache_index kept for interface parity)."""
    ssm_c, conv_c = caches
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))

    def body(x, inp):
        layer_p, st, cv = inp
        layer_p = fsdp_unshard(layer_p)
        y, new_st, new_cv = _apply_layer(
            cfg, layer_p, x, ssm_state=st, conv_state=cv, use_pallas=use_pallas
        )
        return y, (new_st, new_cv)

    x, (new_ssm, new_conv) = jax.lax.scan(body, x, (params["layers"], ssm_c, conv_c))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    from .transformer import hidden_to_logits

    return hidden_to_logits(params, x, cfg), (new_ssm, new_conv)
