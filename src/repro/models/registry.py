"""Architecture registry: name -> config + family dispatch + param counting."""
from __future__ import annotations

import importlib
from typing import Any, Callable, Dict

from .config import ArchConfig

ARCH_IDS = (
    "arctic_480b",
    "deepseek_v2_lite_16b",
    "chameleon_34b",
    "zamba2_2p7b",
    "granite_34b",
    "command_r_plus_104b",
    "granite_20b",
    "stablelm_3b",
    "whisper_base",
    "mamba2_130m",
)

_ALIASES = {
    "arctic-480b": "arctic_480b",
    "deepseek-v2-lite-16b": "deepseek_v2_lite_16b",
    "chameleon-34b": "chameleon_34b",
    "zamba2-2.7b": "zamba2_2p7b",
    "granite-34b": "granite_34b",
    "command-r-plus-104b": "command_r_plus_104b",
    "granite-20b": "granite_20b",
    "stablelm-3b": "stablelm_3b",
    "whisper-base": "whisper_base",
    "mamba2-130m": "mamba2_130m",
}


def normalize(name: str) -> str:
    return _ALIASES.get(name, name.replace("-", "_").replace(".", "p"))


def get_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.config()


def get_smoke_config(name: str) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{normalize(name)}")
    return mod.smoke()


def family_module(cfg: ArchConfig):
    from . import hybrid, mamba, transformer, whisper

    if cfg.family == "audio":
        return whisper
    if cfg.family == "hybrid":
        return hybrid
    if cfg.family == "ssm":
        return mamba
    return transformer  # dense | moe | vlm


# --------------------------------------------------------------------------
# Parameter counting (analytic — used for roofline MODEL_FLOPS = 6 N D)
# --------------------------------------------------------------------------

def _attn_params(cfg: ArchConfig) -> int:
    if cfg.mla is not None:
        m = cfg.mla
        qd = m.qk_nope_head_dim + m.qk_rope_head_dim
        return (
            cfg.d_model * cfg.n_heads * qd
            + cfg.d_model * (m.kv_lora_rank + m.qk_rope_head_dim)
            + m.kv_lora_rank * cfg.n_heads * m.qk_nope_head_dim
            + m.kv_lora_rank * cfg.n_heads * m.v_head_dim
            + cfg.n_heads * m.v_head_dim * cfg.d_model
        )
    dh = cfg.attn_head_dim
    return cfg.d_model * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * dh * cfg.d_model


def _dense_mlp_params(cfg: ArchConfig, d_ff: int) -> int:
    mult = 2 if cfg.mlp_type == "gelu" else 3
    return mult * cfg.d_model * d_ff


def _moe_params(cfg: ArchConfig, active_only: bool) -> int:
    m = cfg.moe
    n_exp = m.top_k if active_only else m.num_experts
    total = cfg.d_model * m.num_experts                  # router
    total += n_exp * 3 * cfg.d_model * m.d_ff_expert     # routed experts (swiglu)
    if m.num_shared_experts:
        f_sh = m.d_ff_shared or m.d_ff_expert * m.num_shared_experts
        total += 3 * cfg.d_model * f_sh
    return total


def _ssm_params(cfg: ArchConfig) -> int:
    s = cfg.ssm
    di = s.d_inner(cfg.d_model)
    H = s.num_heads(cfg.d_model)
    N = s.state_dim
    conv_ch = di + 2 * N
    return (
        cfg.d_model * (di + conv_ch + H)     # split z | xBC | dt projections
        + s.conv_width * conv_ch + conv_ch
        + 3 * H
        + di
        + di * cfg.d_model
    )


def param_count(cfg: ArchConfig, active_only: bool = False) -> int:
    D, V = cfg.d_model, cfg.vocab
    embed = V * D * (1 if cfg.tie_embeddings else 2)

    if cfg.family == "audio":
        enc = cfg.encdec.encoder_layers * (_attn_params(cfg) + _dense_mlp_params(cfg, cfg.d_ff) + 4 * D)
        dec = cfg.n_layers * (2 * _attn_params(cfg) + _dense_mlp_params(cfg, cfg.d_ff) + 6 * D)
        return V * D + 4096 * D + enc + dec + 4 * D

    if cfg.family == "ssm":
        per_layer = _ssm_params(cfg) + D
        return embed + cfg.n_layers * per_layer + D

    if cfg.family == "hybrid":
        per_layer = _ssm_params(cfg) + D
        f_sh = cfg.hybrid.shared_d_ff or 4 * D
        shared = _attn_params(cfg) + 3 * D * f_sh + 2 * D
        return embed + cfg.n_layers * per_layer + shared + D

    # dense / moe / vlm
    per_layer = _attn_params(cfg) + 2 * D
    if cfg.moe is not None:
        per_layer += _moe_params(cfg, active_only)
        if cfg.d_ff:
            per_layer += _dense_mlp_params(cfg, cfg.d_ff)
    else:
        per_layer += _dense_mlp_params(cfg, cfg.d_ff)
    return embed + cfg.n_layers * per_layer + D
