"""Generic decoder-only LM covering the dense / moe / vlm(early-fusion)
families: GQA or MLA attention + SwiGLU or MoE FFN, scan-over-layers with
optional remat, KV-cache prefill/decode.

Early-fusion VLM (chameleon) is structurally this model: VQ image tokens are
ordinary vocabulary entries (the modality frontend is a stub per the brief).
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from ..distributed.sharding import activation_constraint, fsdp_unshard

Params = Dict[str, Any]


def _dtype(cfg: ArchConfig):
    return jnp.dtype(cfg.dtype)


def init_layer(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 4)
    p: Params = {"norm1": L.init_rmsnorm(cfg.d_model), "norm2": L.init_rmsnorm(cfg.d_model)}
    if cfg.mla is not None:
        p["attn"] = L.init_mla(ks[0], cfg, dt)
    else:
        p["attn"] = L.init_attention(ks[0], cfg, dt)
    def _mlp(k):
        if cfg.mlp_type == "gelu":
            return L.init_gelu_mlp(k, cfg.d_model, cfg.d_ff, dt)
        return L.init_swiglu(k, cfg.d_model, cfg.d_ff, dt)

    if cfg.moe is not None:
        p["moe"] = L.init_moe(ks[1], cfg, dt)
        if cfg.d_ff:  # e.g. arctic: dense residual MLP in parallel with MoE
            p["mlp"] = _mlp(ks[2])
    else:
        p["mlp"] = _mlp(ks[2])
    return p


def init_lm(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    ks = jax.random.split(key, 3 + cfg.n_layers)
    layer_keys = jnp.stack(ks[3:])
    stacked = jax.vmap(lambda k: init_layer(k, cfg))(layer_keys)
    p = {
        "embed": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, dt),
        "layers": stacked,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.init_lm_head(ks[1], cfg.d_model, cfg.vocab, dt)
    return p


def _apply_layer(
    cfg: ArchConfig,
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    *,
    kv_cache=None,
    cache_index=None,
    use_pallas: bool = False,
    prefill: bool = False,
) -> Tuple[jax.Array, Any]:
    h = L.rmsnorm(p["norm1"], x, cfg.norm_eps)
    if cfg.mla is not None:
        attn_out, new_cache = L.mla_attention(
            p["attn"], h, cfg, positions=positions,
            kv_cache=kv_cache, cache_index=cache_index, use_pallas=use_pallas,
            prefill=prefill,
        )
    else:
        attn_out, new_cache = L.attention(
            p["attn"], h, cfg, positions=positions,
            kv_cache=kv_cache, cache_index=cache_index, use_pallas=use_pallas,
            prefill=prefill,
        )
    x = x + attn_out
    h = L.rmsnorm(p["norm2"], x, cfg.norm_eps)
    dense_mlp = L.gelu_mlp if cfg.mlp_type == "gelu" else L.swiglu
    if cfg.moe is not None:
        ff = L.moe(p["moe"], h, cfg)
        if "mlp" in p:
            ff = ff + dense_mlp(p["mlp"], h)
    else:
        ff = dense_mlp(p["mlp"], h)
    return x + ff, new_cache


def forward(
    params: Params,
    tokens: jax.Array,          # (B, S) int32
    cfg: ArchConfig,
    *,
    use_pallas: bool = False,
    remat: bool = True,
) -> jax.Array:                 # (B, S, vocab) logits
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))
    positions = jnp.arange(tokens.shape[1])

    def body(x, layer_p):
        layer_p = fsdp_unshard(layer_p)   # gather FSDP shards per layer
        y, _ = _apply_layer(cfg, layer_p, x, positions, use_pallas=use_pallas)
        return y, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hidden_to_logits(params, x, cfg)


def hidden_to_logits(params: Params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    if cfg.tie_embeddings:
        return x @ fsdp_unshard(params["embed"])["table"].T
    return L.lm_logits(fsdp_unshard({"head": params["head"]})["head"], x)


def final_hidden(
    params: Params,
    tokens: jax.Array,
    cfg: ArchConfig,
    *,
    use_pallas: bool = False,
    remat: bool = True,
) -> jax.Array:
    """Hidden states after final norm (loss computed separately, chunked)."""
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))
    positions = jnp.arange(tokens.shape[1])

    def body(x, layer_p):
        layer_p = fsdp_unshard(layer_p)
        y, _ = _apply_layer(cfg, layer_p, x, positions, use_pallas=use_pallas)
        return y, None

    if remat:
        body = jax.checkpoint(body, prevent_cse=False)
    x, _ = jax.lax.scan(body, x, params["layers"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


# --------------------------------------------------------------------------
# Serving: KV cache prefill / decode
# --------------------------------------------------------------------------

def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int) -> Any:
    dt = _dtype(cfg)
    Ll = cfg.n_layers
    if cfg.mla is not None:
        m = cfg.mla
        width = m.kv_lora_rank + m.qk_rope_head_dim
        return jnp.zeros((Ll, batch, max_seq, width), dtype=dt)
    dh = cfg.attn_head_dim
    shape = (Ll, batch, cfg.n_kv_heads, max_seq, dh)
    return (jnp.zeros(shape, dtype=dt), jnp.zeros(shape, dtype=dt))


def _scan_cached(params, x, cfg, caches, cache_index, positions, use_pallas,
                 prefill=False):
    if cfg.mla is not None:
        def body(x, inp):
            layer_p, cache = inp
            y, new_cache = _apply_layer(
                cfg, fsdp_unshard(layer_p), x, positions,
                kv_cache=cache, cache_index=cache_index, use_pallas=use_pallas,
                prefill=prefill,
            )
            return y, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["layers"], caches))
    else:
        def body(x, inp):
            layer_p, ck, cv = inp
            y, new_cache = _apply_layer(
                cfg, fsdp_unshard(layer_p), x, positions,
                kv_cache=(ck, cv), cache_index=cache_index, use_pallas=use_pallas,
                prefill=prefill,
            )
            return y, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["layers"], *caches))
    return x, new_caches


def decode_step(
    params: Params,
    tokens: jax.Array,          # (B, S_new) usually S_new = 1
    cache_index: jax.Array,     # scalar int32: current length
    caches: Any,
    cfg: ArchConfig,
    *,
    use_pallas: bool = False,
    prefill: bool = False,
) -> Tuple[jax.Array, Any]:
    """One decode step against a KV cache of ``max_seq`` capacity."""
    B, Sn = tokens.shape
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))
    positions = cache_index + jnp.arange(Sn)
    x, new_caches = _scan_cached(
        params, x, cfg, caches, cache_index, positions, use_pallas, prefill=prefill
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    return hidden_to_logits(params, x, cfg), new_caches


def prefill(
    params: Params,
    tokens: jax.Array,          # (B, S)
    caches: Any,
    cfg: ArchConfig,
    *,
    use_pallas: bool = False,
) -> Tuple[jax.Array, Any]:
    """Prefill the cache with a full prompt; returns last-token logits.
    Attention runs flash over the prompt (cache starts empty)."""
    logits, caches = decode_step(
        params, tokens, jnp.int32(0), caches, cfg, use_pallas=use_pallas,
        prefill=True,
    )
    return logits[:, -1:], caches
