"""Zamba2-style hybrid: Mamba2 backbone + ONE shared attention+MLP block
applied every ``attn_every`` layers (the shared block's parameters are reused
at every application — Zamba2's signature weight-sharing trick)."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from . import mamba
from .config import ArchConfig
from ..distributed.sharding import activation_constraint, fsdp_unshard

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def _check(cfg: ArchConfig):
    assert cfg.hybrid is not None and cfg.ssm is not None
    assert cfg.n_layers % cfg.hybrid.attn_every == 0, (
        cfg.n_layers, cfg.hybrid.attn_every
    )


def init_lm(key, cfg: ArchConfig) -> Params:
    _check(cfg)
    G = cfg.n_layers // cfg.hybrid.attn_every
    E = cfg.hybrid.attn_every
    ks = jax.random.split(key, 6)
    layer_keys = jax.random.split(ks[2], G * E).reshape(G, E, 2)
    stacked = jax.vmap(jax.vmap(lambda k: mamba.init_layer(k, cfg)))(layer_keys)
    d_ff = cfg.hybrid.shared_d_ff or 4 * cfg.d_model
    shared = {
        "norm1": L.init_rmsnorm(cfg.d_model),
        "attn": L.init_attention(ks[3], cfg, _dtype(cfg)),
        "norm2": L.init_rmsnorm(cfg.d_model),
        "mlp": L.init_swiglu(ks[4], cfg.d_model, d_ff, _dtype(cfg)),
    }
    p = {
        "embed": L.init_embedding(ks[0], cfg.vocab, cfg.d_model, _dtype(cfg)),
        "groups": stacked,
        "shared": shared,
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        p["head"] = L.init_lm_head(ks[1], cfg.d_model, cfg.vocab, _dtype(cfg))
    return p


def _shared_block(cfg, shared, x, positions, *, kv_cache=None, cache_index=None,
                  use_pallas=False, prefill=False):
    h = L.rmsnorm(shared["norm1"], x, cfg.norm_eps)
    attn_out, new_cache = L.attention(
        shared["attn"], h, cfg, positions=positions,
        kv_cache=kv_cache, cache_index=cache_index, use_pallas=use_pallas,
        prefill=prefill,
    )
    x = x + attn_out
    h = L.rmsnorm(shared["norm2"], x, cfg.norm_eps)
    return x + L.swiglu(shared["mlp"], h), new_cache


def final_hidden(params, tokens, cfg, *, use_pallas=False, remat=True):
    _check(cfg)
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))
    positions = jnp.arange(tokens.shape[1])
    shared = params["shared"]

    def group_body(x, group_p):
        def inner(x, lp):
            y, _, _ = mamba._apply_layer(cfg, fsdp_unshard(lp), x, use_pallas=use_pallas)
            return y, None

        x, _ = jax.lax.scan(inner, x, group_p)
        x, _ = _shared_block(cfg, fsdp_unshard(shared), x, positions,
                             use_pallas=use_pallas)
        return x, None

    if remat:
        group_body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = jax.lax.scan(group_body, x, params["groups"])
    return L.rmsnorm(params["final_norm"], x, cfg.norm_eps)


def forward(params, tokens, cfg, *, use_pallas=False, remat=True):
    x = final_hidden(params, tokens, cfg, use_pallas=use_pallas, remat=remat)
    from .transformer import hidden_to_logits

    return hidden_to_logits(params, x, cfg)


# --------------------------------------------------------------------------
# Serving: SSM states per mamba layer + KV cache per shared-block application
# --------------------------------------------------------------------------

def init_state_cache(cfg: ArchConfig, batch: int, max_seq: int):
    _check(cfg)
    G = cfg.n_layers // cfg.hybrid.attn_every
    E = cfg.hybrid.attn_every
    s = cfg.ssm
    H = s.num_heads(cfg.d_model)
    di = s.d_inner(cfg.d_model)
    conv_ch = di + 2 * s.state_dim
    ssm = jnp.zeros((G, E, batch, H, s.head_dim, s.state_dim), jnp.float32)
    conv = jnp.zeros((G, E, batch, s.conv_width - 1, conv_ch), _dtype(cfg))
    dh = cfg.attn_head_dim
    kv = (
        jnp.zeros((G, batch, cfg.n_kv_heads, max_seq, dh), _dtype(cfg)),
        jnp.zeros((G, batch, cfg.n_kv_heads, max_seq, dh), _dtype(cfg)),
    )
    return ssm, conv, kv


def prefill_with_state(params, tokens, cfg, *, use_pallas=False, max_seq=None):
    """Parallel prompt pass: chunked SSD for the mamba layers + flash for the
    shared attention (whose kv land at cache position 0)."""
    _check(cfg)
    B, S = tokens.shape
    max_seq = max_seq or S
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))
    positions = jnp.arange(S)
    shared = params["shared"]
    dh = cfg.attn_head_dim
    kv0 = (
        jnp.zeros((B, cfg.n_kv_heads, max_seq, dh), _dtype(cfg)),
        jnp.zeros((B, cfg.n_kv_heads, max_seq, dh), _dtype(cfg)),
    )

    def group_body(x, group_p):
        def inner(x, lp):
            lp = fsdp_unshard(lp)
            h = L.rmsnorm(lp["norm"], x, cfg.norm_eps)
            y, st, cv = L.mamba2_block(
                lp["mixer"], h, cfg, use_pallas=use_pallas, return_final_state=True
            )
            return x + y, (st, cv)

        x, (st_g, cv_g) = jax.lax.scan(inner, x, group_p)
        x, new_kv = _shared_block(
            cfg, fsdp_unshard(shared), x, positions,
            kv_cache=kv0, cache_index=jnp.int32(0), use_pallas=use_pallas,
            prefill=True,
        )
        return x, (st_g, cv_g, *new_kv)

    x, (ssm, conv, kv_k, kv_v) = jax.lax.scan(group_body, x, params["groups"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    from .transformer import hidden_to_logits

    logits = hidden_to_logits(params, x[:, -1:], cfg)
    return logits, (ssm, conv.astype(_dtype(cfg)), (kv_k, kv_v))


def decode_step(params, tokens, cache_index, caches, cfg, *, use_pallas=False):
    _check(cfg)
    ssm_c, conv_c, (kv_k, kv_v) = caches
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))
    positions = cache_index + jnp.arange(tokens.shape[1])
    shared = params["shared"]

    def group_body(x, inp):
        group_p, st_g, cv_g, ck, cv = inp

        def inner(x, lp_state):
            lp, st, conv_st = lp_state
            y, new_st, new_cv = mamba._apply_layer(
                cfg, fsdp_unshard(lp), x, ssm_state=st, conv_state=conv_st,
                use_pallas=use_pallas
            )
            return y, (new_st, new_cv)

        x, (new_st_g, new_cv_g) = jax.lax.scan(inner, x, (group_p, st_g, cv_g))
        x, new_kv = _shared_block(
            cfg, fsdp_unshard(shared), x, positions,
            kv_cache=(ck, cv), cache_index=cache_index, use_pallas=use_pallas,
        )
        return x, (new_st_g, new_cv_g, *new_kv)

    x, (new_ssm, new_conv, new_k, new_v) = jax.lax.scan(
        group_body, x, (params["groups"], ssm_c, conv_c, kv_k, kv_v)
    )
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    from .transformer import hidden_to_logits

    return hidden_to_logits(params, x, cfg), (new_ssm, new_conv, (new_k, new_v))
