"""DLRM — the paper's evaluation workload (Table I: DLRM-RMC2-small).

Bottom MLP over dense features, embedding-bag lookups over T tables (the
paper's operation — optionally through the Pallas kernels, including the
hot-pinned VMEM path), dot-product feature interaction, top MLP.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..kernels import ops
from . import layers as L

Params = Dict[str, Any]


@dataclass(frozen=True)
class DLRMConfig:
    num_tables: int = 60
    rows_per_table: int = 1_000_000
    dim: int = 128
    lookups_per_table: int = 120
    dense_features: int = 13
    bottom_mlp: Tuple[int, ...] = (256, 128, 128)
    top_mlp: Tuple[int, ...] = (128, 64, 1)
    dtype: str = "float32"

    def __post_init__(self):
        assert self.bottom_mlp[-1] == self.dim, (
            "dot-interaction requires bottom_mlp[-1] == embedding dim",
            self.bottom_mlp, self.dim,
        )

    @property
    def n_vectors(self) -> int:
        return self.num_tables + 1  # + bottom-MLP output


def smoke_config() -> DLRMConfig:
    return DLRMConfig(num_tables=4, rows_per_table=1000, dim=32,
                      lookups_per_table=8, bottom_mlp=(64, 32), top_mlp=(32, 1))


def _mlp_init(key, dims, in_dim, dtype):
    ks = jax.random.split(key, len(dims))
    ws, d = [], in_dim
    for k, out in zip(ks, dims):
        ws.append({"w": L._dense_init(k, (d, out), dtype=dtype),
                   "b": jnp.zeros((out,), dtype=dtype)})
        d = out
    return ws


def _mlp_apply(ws, x, final_linear=True):
    for i, p in enumerate(ws):
        x = x @ p["w"] + p["b"]
        if i < len(ws) - 1 or not final_linear:
            x = jax.nn.relu(x)
    return x


def init(key, cfg: DLRMConfig) -> Params:
    dt = jnp.dtype(cfg.dtype)
    ks = jax.random.split(key, 4)
    table = L._dense_init(
        ks[0], (cfg.num_tables * cfg.rows_per_table, cfg.dim), scale=0.01, dtype=dt
    )
    n = cfg.n_vectors
    interact_dim = n * (n - 1) // 2 + cfg.bottom_mlp[-1]
    return {
        "tables": table,
        "bottom": _mlp_init(ks[1], cfg.bottom_mlp, cfg.dense_features, dt),
        "top": _mlp_init(ks[2], cfg.top_mlp, interact_dim, dt),
    }


def interact(dense_vec: jax.Array, emb: jax.Array) -> jax.Array:
    """Dot-product interaction. dense_vec (B, D), emb (B, T, D)."""
    allv = jnp.concatenate([dense_vec[:, None, :], emb], axis=1)  # (B, n, D)
    z = jnp.einsum("bnd,bmd->bnm", allv, allv)
    n = allv.shape[1]
    iu, ju = jnp.triu_indices(n, k=1)
    return z[:, iu, ju]                                           # (B, n(n-1)/2)


def forward(
    params: Params,
    dense: jax.Array,        # (B, 13)
    sparse: jax.Array,       # (B, T, L) int32 per-table row ids
    cfg: DLRMConfig,
    *,
    use_pallas: bool = False,
    pinned: Optional[Dict[str, jax.Array]] = None,
) -> jax.Array:              # (B,) logit
    bot = _mlp_apply(params["bottom"], dense)                     # (B, D_b)
    if pinned is not None:
        emb = ops.embedding_bag_pinned(
            params["tables"], pinned["hot_table"], sparse,
            pinned["positions"], pinned["mask"], cfg.rows_per_table,
            use_pallas=use_pallas,
        )
    else:
        emb = ops.embedding_bag(
            params["tables"], sparse, cfg.rows_per_table, use_pallas=use_pallas
        )                                                         # (B, T, D)
    feat = jnp.concatenate([bot, interact(bot, emb)], axis=1)
    return _mlp_apply(params["top"], feat)[:, 0]


def bce_loss(logit: jax.Array, label: jax.Array) -> jax.Array:
    z = logit.astype(jnp.float32)
    return jnp.mean(jnp.maximum(z, 0) - z * label + jnp.log1p(jnp.exp(-jnp.abs(z))))
