"""Core layers for the model zoo — functional JAX (params are pytrees).

Covers every assigned family: GQA/MQA attention, DeepSeek MLA, SwiGLU and
GELU MLPs, sort-based capacity MoE (GShard-style without the (T,E,C) one-hot
blowup), Mamba2/SSD blocks, RMS/LayerNorm, RoPE.

All ``init_*`` take an rng key and return a dict; all ``apply`` functions are
pure. Matmul-heavy paths accept ``use_pallas`` to route through the Pallas
kernels (interpret mode on CPU) or the jnp reference (the XLA path the
dry-run lowers).
"""
from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..kernels import ops
from .config import ArchConfig, MLAConfig, MoEConfig, SSMConfig

Params = Dict[str, Any]


def _dense_init(key, shape, scale: Optional[float] = None, dtype=jnp.float32):
    if scale is None:
        scale = 1.0 / math.sqrt(shape[0])
    return (jax.random.normal(key, shape, dtype=jnp.float32) * scale).astype(dtype)


# --------------------------------------------------------------------------
# Norms
# --------------------------------------------------------------------------

def init_rmsnorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype)}


def rmsnorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)).astype(x.dtype)


def init_layernorm(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype=dtype), "bias": jnp.zeros((d,), dtype=dtype)}


def layernorm(p: Params, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


# --------------------------------------------------------------------------
# RoPE
# --------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, d); positions: (S,) or broadcastable."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                       # (d/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# --------------------------------------------------------------------------
# GQA attention
# --------------------------------------------------------------------------

def init_attention(key, cfg: ArchConfig, dtype) -> Params:
    D, Hq, Hkv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.attn_head_dim
    ks = jax.random.split(key, 4)
    return {
        "wq": _dense_init(ks[0], (D, Hq * dh), dtype=dtype),
        "wk": _dense_init(ks[1], (D, Hkv * dh), dtype=dtype),
        "wv": _dense_init(ks[2], (D, Hkv * dh), dtype=dtype),
        "wo": _dense_init(ks[3], (Hq * dh, D), dtype=dtype),
    }


def attention(
    p: Params,
    x: jax.Array,                  # (B, S, D)
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    kv_cache: Optional[Tuple[jax.Array, jax.Array]] = None,
    cache_index: Optional[jax.Array] = None,
    use_pallas: bool = False,
    use_rope: bool = True,
    prefill: bool = False,
) -> Tuple[jax.Array, Optional[Tuple[jax.Array, jax.Array]]]:
    """Returns (out, new_kv_cache). With a cache, x is the new-token slice.

    ``prefill=True`` (static): the cache is empty and x is the full prompt —
    attention runs causal-flash over the new tokens only (never materializing
    (S, S_max) scores) and k/v are written at position 0.
    """
    B, S, D = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.attn_head_dim
    if positions is None:
        positions = jnp.arange(S)

    q = (x @ p["wq"]).reshape(B, S, Hq, dh).transpose(0, 2, 1, 3)
    k = (x @ p["wk"]).reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    v = (x @ p["wv"]).reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    if use_rope:
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rope_theta)
    if S > 1:
        from ..distributed.sharding import shard_attention_q

        q = shard_attention_q(q)

    new_cache = None
    if kv_cache is not None:
        ck, cv = kv_cache                       # (B, Hkv, S_max, dh)
        ck = jax.lax.dynamic_update_slice(ck, k.astype(ck.dtype), (0, 0, cache_index, 0))
        cv = jax.lax.dynamic_update_slice(cv, v.astype(cv.dtype), (0, 0, cache_index, 0))
        new_cache = (ck, cv)
        if prefill:
            out = ops.flash_attention(q, k, v, causal=causal, use_pallas=use_pallas)
        elif use_pallas and S == 1:
            out = ops.decode_attention(
                q[:, :, 0], ck, cv, cache_index + S
            )[:, :, None, :]
        else:
            out = _decode_attention(q, ck, cv, cache_index + S, Hq // Hkv)
    else:
        out = ops.flash_attention(q, k, v, causal=causal, use_pallas=use_pallas)

    out = out.transpose(0, 2, 1, 3).reshape(B, S, Hq * dh)
    return out @ p["wo"], new_cache


def _decode_attention(q, ck, cv, valid_len, group: int) -> jax.Array:
    """Full-cache attention with length masking (decode path).

    q: (B, Hq, S_new, dh); cache: (B, Hkv, S_max, dh). kv stay in cache dtype
    (f32 accumulation via preferred_element_type) so the GQA head expansion
    is a bf16 transient, not an f32 copy of the whole cache.
    """
    B, Hq, Sn, dh = q.shape
    kf = jnp.repeat(ck, group, axis=1)
    vf = jnp.repeat(cv, group, axis=1)
    s = jnp.einsum(
        "bhqd,bhkd->bhqk", q, kf, preferred_element_type=jnp.float32
    ) / math.sqrt(dh)
    span = jnp.arange(ck.shape[2])
    s = jnp.where(span[None, None, None, :] < valid_len, s, -1e30)
    pmax = jnp.max(s, axis=-1, keepdims=True)
    w = jnp.exp(s - pmax)
    out = jnp.einsum(
        "bhqk,bhkd->bhqd", w.astype(vf.dtype), vf,
        preferred_element_type=jnp.float32,
    ) / jnp.sum(w, axis=-1, keepdims=True)
    return out.astype(q.dtype)


# --------------------------------------------------------------------------
# MLA attention (DeepSeek-V2): latent KV compression
# --------------------------------------------------------------------------

def init_mla(key, cfg: ArchConfig, dtype) -> Params:
    m: MLAConfig = cfg.mla
    D, H = cfg.d_model, cfg.n_heads
    qd = m.qk_nope_head_dim + m.qk_rope_head_dim
    ks = jax.random.split(key, 5)
    return {
        "wq": _dense_init(ks[0], (D, H * qd), dtype=dtype),
        "w_dkv": _dense_init(ks[1], (D, m.kv_lora_rank + m.qk_rope_head_dim), dtype=dtype),
        "w_uk": _dense_init(ks[2], (m.kv_lora_rank, H * m.qk_nope_head_dim), dtype=dtype),
        "w_uv": _dense_init(ks[3], (m.kv_lora_rank, H * m.v_head_dim), dtype=dtype),
        "wo": _dense_init(ks[4], (H * m.v_head_dim, D), dtype=dtype),
    }


def mla_attention(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    *,
    positions: Optional[jax.Array] = None,
    causal: bool = True,
    kv_cache: Optional[jax.Array] = None,   # latent cache (B, S_max, r + rope)
    cache_index: Optional[jax.Array] = None,
    use_pallas: bool = False,
    prefill: bool = False,
) -> Tuple[jax.Array, Optional[jax.Array]]:
    m: MLAConfig = cfg.mla
    B, S, D = x.shape
    H = cfg.n_heads
    r = m.kv_lora_rank
    if positions is None:
        positions = jnp.arange(S)

    q = (x @ p["wq"]).reshape(B, S, H, -1).transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., : m.qk_nope_head_dim], q[..., m.qk_nope_head_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    latent = x @ p["w_dkv"]                           # (B, S, r + rope)
    kv_l, k_rope = latent[..., :r], latent[..., r:]
    k_rope = apply_rope(k_rope[:, None], positions, cfg.rope_theta)  # (B,1,S,rope)

    new_cache = None
    if kv_cache is not None:
        lat_new = jnp.concatenate([kv_l, k_rope[:, 0]], axis=-1)
        kv_cache = jax.lax.dynamic_update_slice(
            kv_cache, lat_new.astype(kv_cache.dtype), (0, cache_index, 0)
        )
        new_cache = kv_cache
        if not prefill:
            # DECODE: weight-absorbed latent attention (DeepSeek's "matrix
            # absorption"). The naive path recomputes per-head K/V from the
            # whole latent cache every step (~1000x the useful FLOPs at 32k
            # context, EXPERIMENTS.md §Perf); absorbing w_uk into the query
            # and deferring w_uv past the softmax runs attention directly in
            # the (r+rope)-dim latent space:
            #   score = (q_nope W_uk^T) . latent  +  q_rope . k_rope
            #   out   = (softmax . latent) W_uv
            out = _mla_absorbed_decode(
                p, q_nope, q_rope, kv_cache, cache_index + S, m, H
            )
            out = out.transpose(0, 2, 1, 3).reshape(B, S, H * m.v_head_dim)
            return out @ p["wo"], new_cache

    k_nope = (kv_l @ p["w_uk"]).reshape(B, -1, H, m.qk_nope_head_dim).transpose(0, 2, 1, 3)
    vv = (kv_l @ p["w_uv"]).reshape(B, -1, H, m.v_head_dim).transpose(0, 2, 1, 3)
    k = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (*k_nope.shape[:3], m.qk_rope_head_dim))], axis=-1)
    qq = jnp.concatenate([q_nope, q_rope], axis=-1)

    out = ops.flash_attention(
        qq, k, vv, causal=causal,
        use_pallas=use_pallas and m.v_head_dim == qq.shape[-1],
    )

    out = out.transpose(0, 2, 1, 3).reshape(B, S, H * m.v_head_dim)
    return out @ p["wo"], new_cache


def _mla_absorbed_decode(p, q_nope, q_rope, latent_cache, valid_len, m, H):
    """q_nope/q_rope: (B, H, Sn, .); latent_cache: (B, S_max, r + rope)."""
    r = m.kv_lora_rank
    lat = latent_cache[..., :r]                              # (B, S, r)
    k_rope = latent_cache[..., r:]                           # (B, S, rope)
    w_uk = p["w_uk"].reshape(r, H, m.qk_nope_head_dim)       # (r, H, n)
    q_lat = jnp.einsum("bhqn,rhn->bhqr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))             # (B, H, Sn, r)
    s = jnp.einsum("bhqr,bsr->bhqs", q_lat, lat.astype(jnp.float32))
    s = s + jnp.einsum("bhqp,bsp->bhqs", q_rope.astype(jnp.float32),
                       k_rope.astype(jnp.float32))
    s = s / math.sqrt(m.qk_nope_head_dim + m.qk_rope_head_dim)
    span = jnp.arange(lat.shape[1])
    s = jnp.where(span[None, None, None, :] < valid_len, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    ctx = jnp.einsum("bhqs,bsr->bhqr", w, lat.astype(jnp.float32))
    w_uv = p["w_uv"].reshape(r, H, m.v_head_dim)
    return jnp.einsum("bhqr,rhv->bhqv", ctx,
                      w_uv.astype(jnp.float32)).astype(q_nope.dtype)


def _full_attention(q, k, v, *, causal: bool) -> jax.Array:
    B, H, S, dh = q.shape
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(dh)
    if causal:
        Sk = k.shape[2]
        mask = jnp.tril(jnp.ones((S, Sk), dtype=bool), k=Sk - S)
        s = jnp.where(mask[None, None], s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def _mla_masked_attention(q, k, v, valid_len) -> jax.Array:
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32))
    s = s / math.sqrt(q.shape[-1])
    span = jnp.arange(k.shape[2])
    s = jnp.where(span[None, None, None, :] < valid_len, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


# --------------------------------------------------------------------------
# MLPs
# --------------------------------------------------------------------------

def init_swiglu(key, d: int, f: int, dtype) -> Params:
    ks = jax.random.split(key, 3)
    return {
        "wg": _dense_init(ks[0], (d, f), dtype=dtype),
        "wu": _dense_init(ks[1], (d, f), dtype=dtype),
        "wd": _dense_init(ks[2], (f, d), dtype=dtype),
    }


def swiglu(p: Params, x: jax.Array) -> jax.Array:
    return (jax.nn.silu(x @ p["wg"]) * (x @ p["wu"])) @ p["wd"]


def init_gelu_mlp(key, d: int, f: int, dtype) -> Params:
    ks = jax.random.split(key, 2)
    return {
        "w1": _dense_init(ks[0], (d, f), dtype=dtype),
        "b1": jnp.zeros((f,), dtype=dtype),
        "w2": _dense_init(ks[1], (f, d), dtype=dtype),
        "b2": jnp.zeros((d,), dtype=dtype),
    }


def gelu_mlp(p: Params, x: jax.Array) -> jax.Array:
    return jax.nn.gelu(x @ p["w1"] + p["b1"]) @ p["w2"] + p["b2"]


# --------------------------------------------------------------------------
# Mixture of Experts (sort-based capacity dispatch)
# --------------------------------------------------------------------------

def init_moe(key, cfg: ArchConfig, dtype) -> Params:
    m: MoEConfig = cfg.moe
    D = cfg.d_model
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (D, m.num_experts), dtype=jnp.float32),
        "wg": _dense_init(ks[1], (m.num_experts, D, m.d_ff_expert), dtype=dtype),
        "wu": _dense_init(ks[2], (m.num_experts, D, m.d_ff_expert), dtype=dtype),
        "wd": _dense_init(ks[3], (m.num_experts, m.d_ff_expert, D), dtype=dtype),
    }
    if m.num_shared_experts:
        f_sh = m.d_ff_shared or m.d_ff_expert * m.num_shared_experts
        p["shared"] = init_swiglu(ks[4], D, f_sh, dtype)
    return p


def _rank_within_group(ids: jax.Array, iota: jax.Array) -> jax.Array:
    """Position of each element within its (sorted) id group. Batched over
    leading dims (operates on the last axis)."""
    first = jnp.concatenate(
        [jnp.ones((*ids.shape[:-1], 1), bool), ids[..., 1:] != ids[..., :-1]], axis=-1
    )
    start = jax.lax.associative_scan(
        jnp.maximum, jnp.where(first, iota, 0), axis=-1
    )
    return iota - start


def moe(
    p: Params,
    x: jax.Array,                 # (B, S, D)
    cfg: ArchConfig,
    *,
    capacity_factor: Optional[float] = None,
) -> jax.Array:
    """Sort-based capacity MoE with group-local dispatch.

    Tokens are split into ``dispatch_groups`` groups (aligned with the
    data-parallel shards so the routing sort never crosses devices), routed
    top-k, sorted by expert within the group, packed into a (G, E, C, D)
    buffer (overflow dropped — GShard capacity semantics), run through the
    expert FFNs as one batched einsum (experts sharded over the model axis =
    EP; the token->expert reshard lowers to all-to-all-class collectives),
    and combined back with the gate weights. Avoids the (T, E, C) one-hot
    dispatch blowup.
    """
    m: MoEConfig = cfg.moe
    B, S, D = x.shape
    T = B * S
    E, K = m.num_experts, m.top_k
    cf = capacity_factor if capacity_factor is not None else m.capacity_factor
    G = m.dispatch_groups if T % m.dispatch_groups == 0 else 1
    Tg = T // G
    C = max(1, int(Tg * K * cf) // E)

    from ..distributed.sharding import constrain

    # Dispatch groups ride the data axis; without explicit constraints the
    # scatter/gather pair below defeats GSPMD propagation and the expert
    # einsums replicate all groups on every data shard (16x compute bloat,
    # EXPERIMENTS.md §Perf iteration 4).
    xg = constrain(x.reshape(G, Tg, D), "dp", None, None)
    logits = (xg.astype(jnp.float32) @ p["router"])           # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_w, gate_e = jax.lax.top_k(probs, K)                  # (G, Tg, K)
    gate_w = gate_w / jnp.clip(gate_w.sum(-1, keepdims=True), 1e-9)

    a_expert = gate_e.reshape(G, Tg * K)
    a_token = jnp.broadcast_to(
        jnp.repeat(jnp.arange(Tg), K)[None], (G, Tg * K)
    )
    a_gate = gate_w.reshape(G, Tg * K)

    order = jnp.argsort(a_expert, axis=-1)                    # per-group sort
    se = jnp.take_along_axis(a_expert, order, axis=-1)
    st = jnp.take_along_axis(a_token, order, axis=-1)
    sg = jnp.take_along_axis(a_gate, order, axis=-1)
    iota = jnp.broadcast_to(jnp.arange(Tg * K)[None], se.shape)
    rank = _rank_within_group(se, iota)

    keep = rank < C
    slot = constrain(se * C + jnp.minimum(rank, C - 1), "dp", None)  # (G, Tg*K)
    vals = jnp.where(
        keep[..., None], jnp.take_along_axis(xg, st[..., None], axis=1), 0
    )
    vals = constrain(vals, "dp", None, None)
    buf = jax.vmap(lambda s, v: jnp.zeros((E * C, D), x.dtype).at[s].add(v))(
        slot, vals
    )                                                          # (G, E*C, D)
    buf = constrain(buf, "dp", None, None)

    h = constrain(buf.reshape(G, E, C, D), "dp", "model", None, None)
    act = jax.nn.silu(jnp.einsum("gecd,edf->gecf", h, p["wg"])) * jnp.einsum(
        "gecd,edf->gecf", h, p["wu"]
    )
    act = constrain(act, "dp", "model", None, None)
    out_buf = jnp.einsum("gecf,efd->gecd", act, p["wd"]).reshape(G, E * C, D)
    out_buf = constrain(out_buf, "dp", None, None)

    contrib = jnp.take_along_axis(out_buf, slot[..., None], axis=1)
    contrib = contrib * (sg * keep)[..., None].astype(out_buf.dtype)
    out = jax.vmap(lambda t, c: jnp.zeros((Tg, D), x.dtype).at[t].add(c))(
        st, contrib.astype(x.dtype)
    )
    out = constrain(out, "dp", None, None).reshape(B, S, D)

    if "shared" in p:
        out = out + swiglu(p["shared"], x)
    return out


# --------------------------------------------------------------------------
# Mamba2 / SSD block
# --------------------------------------------------------------------------

def init_mamba2(key, cfg: ArchConfig, dtype) -> Params:
    s: SSMConfig = cfg.ssm
    D = cfg.d_model
    di = s.d_inner(D)
    H = s.num_heads(D)
    N = s.state_dim
    conv_ch = di + 2 * N
    ks = jax.random.split(key, 6)
    # The projection is split (z | xBC | dt) rather than fused so each piece
    # shards cleanly over the model axis (the fused 2*di+2N+H width is not
    # divisible by typical TP degrees).
    return {
        "in_z": _dense_init(ks[0], (D, di), dtype=dtype),
        "in_xbc": _dense_init(ks[1], (D, conv_ch), dtype=dtype),
        "in_dt": _dense_init(ks[2], (D, H), dtype=dtype),
        "conv_w": _dense_init(ks[3], (s.conv_width, conv_ch), scale=0.5, dtype=dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype=dtype),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, H)).astype(jnp.float32),
        "dt_bias": jnp.zeros((H,), dtype=jnp.float32),
        "d_skip": jnp.ones((H,), dtype=jnp.float32),
        "norm": init_rmsnorm(di, dtype=dtype),
        "out_proj": _dense_init(ks[4], (di, D), dtype=dtype),
    }


def _causal_conv1d(x: jax.Array, w: jax.Array, b: jax.Array,
                   state: Optional[jax.Array] = None) -> Tuple[jax.Array, jax.Array]:
    """Depthwise causal conv. x: (B, S, Ch), w: (W, Ch). Returns (y, new_state)
    where state carries the last W-1 inputs for decode."""
    W = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[2]), dtype=x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+W-1, Ch)
    y = sum(xp[:, i : i + x.shape[1], :] * w[i] for i in range(W))
    new_state = xp[:, -(W - 1):, :] if W > 1 else jnp.zeros_like(pad)
    return jax.nn.silu(y + b), new_state


def mamba2_block(
    p: Params,
    x: jax.Array,                  # (B, S, D)
    cfg: ArchConfig,
    *,
    ssm_state: Optional[jax.Array] = None,   # (B, H, P, N) decode carry
    conv_state: Optional[jax.Array] = None,  # (B, W-1, Ch)
    use_pallas: bool = False,
    return_final_state: bool = False,        # prefill: parallel scan + state out
) -> Tuple[jax.Array, Optional[jax.Array], Optional[jax.Array]]:
    s: SSMConfig = cfg.ssm
    B, S, D = x.shape
    di = s.d_inner(D)
    H = s.num_heads(D)
    N, P = s.state_dim, s.head_dim

    z = x @ p["in_z"]
    xbc = x @ p["in_xbc"]
    dt_raw = x @ p["in_dt"]

    xbc, new_conv = _causal_conv1d(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs = xbc[..., :di]
    Bm = xbc[..., di : di + N]
    Cm = xbc[..., di + N :]

    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["a_log"])                                          # (H,)

    xh = xs.reshape(B, S, H, P).transpose(0, 2, 1, 3)                 # (B,H,S,P)
    dt_h = dt.transpose(0, 2, 1)                                      # (B,H,S)
    adt = A[None, :, None] * dt_h

    if ssm_state is None:
        y = ops.mamba2_ssd(
            xh, adt, dt_h, Bm, Cm, chunk=s.chunk, use_pallas=use_pallas
        )                                                             # (B,H,S,P)
        new_state = None
        if return_final_state:
            from . import config as _c  # noqa: F401 (doc anchor)
            from ..kernels import ref as kref

            new_state = kref.mamba2_final_state(xh, adt, dt_h, Bm)
    else:
        y, new_state = _ssd_decode_step(xh, adt, dt_h, Bm, Cm, ssm_state)

    y = y + p["d_skip"][None, :, None, None] * xh.astype(jnp.float32)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rmsnorm(p["norm"], y, cfg.norm_eps)
    return y @ p["out_proj"], new_state, new_conv


def _ssd_decode_step(xh, adt, dt_h, Bm, Cm, state):
    """Sequential steps over the (short) new-token window, carrying state."""
    Bsz, H, S, P = xh.shape

    def step(st, t):
        decay = jnp.exp(adt[:, :, t])[..., None, None]
        outer = (dt_h[:, :, t, None, None] * xh[:, :, t, :, None]) * Bm[:, None, t, None, :]
        st = decay * st + outer
        y_t = jnp.einsum("bhpn,bn->bhp", st, Cm[:, t])
        return st, y_t

    state, ys = jax.lax.scan(step, state.astype(jnp.float32), jnp.arange(S))
    return jnp.moveaxis(ys, 0, 2), state  # (B,H,S,P), (B,H,P,N)


# --------------------------------------------------------------------------
# Embedding / logits
# --------------------------------------------------------------------------

def init_embedding(key, vocab: int, d: int, dtype) -> Params:
    return {"table": _dense_init(key, (vocab, d), scale=0.02, dtype=dtype)}


def embed(p: Params, tokens: jax.Array, *, use_pallas: bool = False) -> jax.Array:
    if use_pallas:
        return ops.embedding_gather(p["table"], tokens)
    return p["table"][tokens]


def init_lm_head(key, d: int, vocab: int, dtype) -> Params:
    return {"w": _dense_init(key, (d, vocab), dtype=dtype)}


def lm_logits(p: Params, x: jax.Array) -> jax.Array:
    return x @ p["w"]
