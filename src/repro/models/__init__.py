from .config import (
    ALL_SHAPES,
    SHAPES_BY_NAME,
    ArchConfig,
    ShapeConfig,
    shapes_for,
)
from .registry import ARCH_IDS, get_config, get_smoke_config, family_module, param_count

__all__ = [
    "ALL_SHAPES",
    "SHAPES_BY_NAME",
    "ArchConfig",
    "ShapeConfig",
    "shapes_for",
    "ARCH_IDS",
    "get_config",
    "get_smoke_config",
    "family_module",
    "param_count",
]
