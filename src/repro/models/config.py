"""Architecture configuration — one dataclass covering all assigned families.

Every assigned architecture is expressed as an ``ArchConfig`` in
``repro/configs/<id>.py``; reduced variants (``smoke()``) instantiate the same
family at toy scale for CPU tests.
"""
from __future__ import annotations

import dataclasses
import math
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_ff_expert: int
    num_shared_experts: int = 0
    d_ff_shared: int = 0
    capacity_factor: float = 1.25
    router_dtype: str = "float32"
    # dispatch groups: routing sort/pack runs independently per group so the
    # sort stays shard-local under GSPMD (set = data-parallel degree)
    dispatch_groups: int = 16


@dataclass(frozen=True)
class MLAConfig:
    """DeepSeek multi-head latent attention."""

    kv_lora_rank: int = 512
    q_lora_rank: int = 0            # 0 = full-rank q projection
    qk_nope_head_dim: int = 128
    qk_rope_head_dim: int = 64
    v_head_dim: int = 128


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD block parameters."""

    state_dim: int = 128            # N
    head_dim: int = 64              # P
    expand: int = 2                 # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 128

    def d_inner(self, d_model: int) -> int:
        return self.expand * d_model

    def num_heads(self, d_model: int) -> int:
        return self.d_inner(d_model) // self.head_dim


@dataclass(frozen=True)
class HybridConfig:
    """Zamba2-style: mamba backbone + shared attention block every K layers."""

    attn_every: int = 6             # one shared attn+mlp block per 6 mamba layers
    shared_d_ff: int = 0            # 0 -> 4 * d_model


@dataclass(frozen=True)
class EncDecConfig:
    """Whisper-style encoder-decoder (frontend stubbed)."""

    encoder_layers: int = 6
    encoder_seq: int = 1500         # frames after conv frontend (stub input)


@dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                     # dense | moe | hybrid | ssm | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int                    # 0 for attention-free archs
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0               # 0 -> d_model // n_heads
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    hybrid: Optional[HybridConfig] = None
    encdec: Optional[EncDecConfig] = None
    norm_eps: float = 1e-5
    rope_theta: float = 10000.0
    tie_embeddings: bool = False
    mlp_type: str = "swiglu"        # swiglu | gelu (2-matrix, gpt-bigcode style)
    dtype: str = "bfloat16"
    # attention-free archs (mamba2) set n_heads = 0
    notes: str = ""

    @property
    def attn_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def is_attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic sequence mixing (long_500k eligibility)."""
        return self.family in ("ssm", "hybrid")

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs generate tokens (whisper = enc-dec)

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (for roofline MODEL_FLOPS) -------------------
    def param_count(self) -> int:
        from . import registry  # local import to avoid cycle

        return registry.param_count(self)

    def active_param_count(self) -> int:
        from . import registry

        return registry.param_count(self, active_only=True)


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input shape."""

    name: str
    seq_len: int
    global_batch: int
    kind: str                       # train | prefill | decode

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


TRAIN_4K = ShapeConfig("train_4k", 4096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524288, 1, "decode")

ALL_SHAPES = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)
SHAPES_BY_NAME = {s.name: s for s in ALL_SHAPES}


def shapes_for(cfg: ArchConfig) -> Tuple[ShapeConfig, ...]:
    """Assigned shapes minus documented skips (DESIGN.md §4):
    long_500k only for sub-quadratic archs."""
    out = [TRAIN_4K, PREFILL_32K, DECODE_32K]
    if cfg.supports_long_context:
        out.append(LONG_500K)
    return tuple(out)
