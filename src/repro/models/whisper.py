"""Whisper-style encoder-decoder backbone (audio family).

Per the brief, the conv/mel frontend is a STUB: ``input_specs`` supplies
precomputed frame embeddings (B, S_enc, d_model). The transformer backbone is
real: pre-LN encoder (bidirectional self-attn + GELU MLP) and decoder (causal
self-attn + cross-attn + GELU MLP), sinusoidal encoder positions, learned
decoder positions."""
from __future__ import annotations

from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp

from . import layers as L
from .config import ArchConfig
from ..distributed.sharding import activation_constraint, fsdp_unshard

Params = Dict[str, Any]


def _dtype(cfg):
    return jnp.dtype(cfg.dtype)


def sinusoids(length: int, channels: int) -> jax.Array:
    log_timescale = jnp.log(10000.0) / (channels // 2 - 1)
    inv = jnp.exp(-log_timescale * jnp.arange(channels // 2))
    scaled = jnp.arange(length)[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(scaled), jnp.cos(scaled)], axis=1)


def init_cross_attention(key, cfg: ArchConfig, dtype) -> Params:
    return L.init_attention(key, cfg, dtype)


def cross_attention(p, x, enc_kv, cfg) -> jax.Array:
    """x: (B, S_dec, D); enc_kv: precomputed (k, v) (B, Hkv, S_enc, dh)."""
    from ..kernels import ops

    B, S, D = x.shape
    Hq, Hkv, dh = cfg.n_heads, cfg.n_kv_heads, cfg.attn_head_dim
    q = (x @ p["wq"]).reshape(B, S, Hq, dh).transpose(0, 2, 1, 3)
    k, v = enc_kv
    out = ops.flash_attention(q, k, v, causal=False, use_pallas=False)
    return out.transpose(0, 2, 1, 3).reshape(B, S, Hq * dh) @ p["wo"]


def encode_kv(p, enc_out, cfg) -> Tuple[jax.Array, jax.Array]:
    B, S, D = enc_out.shape
    Hkv, dh = cfg.n_kv_heads, cfg.attn_head_dim
    k = (enc_out @ p["wk"]).reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    v = (enc_out @ p["wv"]).reshape(B, S, Hkv, dh).transpose(0, 2, 1, 3)
    return k, v


def init_model(key, cfg: ArchConfig) -> Params:
    dt = _dtype(cfg)
    enc_layers = cfg.encdec.encoder_layers
    ks = jax.random.split(key, 8)

    def enc_layer(k):
        k1, k2 = jax.random.split(k)
        return {
            "norm1": L.init_layernorm(cfg.d_model),
            "attn": L.init_attention(k1, cfg, dt),
            "norm2": L.init_layernorm(cfg.d_model),
            "mlp": L.init_gelu_mlp(k2, cfg.d_model, cfg.d_ff, dt),
        }

    def dec_layer(k):
        k1, k2, k3 = jax.random.split(k, 3)
        return {
            "norm1": L.init_layernorm(cfg.d_model),
            "self_attn": L.init_attention(k1, cfg, dt),
            "norm2": L.init_layernorm(cfg.d_model),
            "cross_attn": init_cross_attention(k2, cfg, dt),
            "norm3": L.init_layernorm(cfg.d_model),
            "mlp": L.init_gelu_mlp(k3, cfg.d_model, cfg.d_ff, dt),
        }

    enc_keys = jnp.stack(jax.random.split(ks[0], enc_layers))
    dec_keys = jnp.stack(jax.random.split(ks[1], cfg.n_layers))
    return {
        "embed": L.init_embedding(ks[2], cfg.vocab, cfg.d_model, dt),
        "pos_dec": L._dense_init(ks[3], (4096, cfg.d_model), scale=0.01, dtype=dt),
        "enc_layers": jax.vmap(enc_layer)(enc_keys),
        "enc_norm": L.init_layernorm(cfg.d_model),
        "dec_layers": jax.vmap(dec_layer)(dec_keys),
        "dec_norm": L.init_layernorm(cfg.d_model),
    }


def encode(params, frames, cfg, *, use_pallas=False):
    """frames: (B, S_enc, D) stub embeddings -> encoder states."""
    x = frames + sinusoids(frames.shape[1], cfg.d_model).astype(frames.dtype)

    def body(x, p):
        p = fsdp_unshard(p)
        h = L.layernorm(p["norm1"], x, cfg.norm_eps)
        a, _ = L.attention(p["attn"], h, cfg, causal=False,
                           use_pallas=use_pallas, use_rope=False)
        x = x + a
        h = L.layernorm(p["norm2"], x, cfg.norm_eps)
        return x + L.gelu_mlp(p["mlp"], h), None

    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return L.layernorm(params["enc_norm"], x, cfg.norm_eps)


def decode_hidden(params, tokens, enc_out, cfg, *, positions=None,
                  kv_caches=None, cache_index=None, use_pallas=False,
                  prefill=False):
    B, S = tokens.shape
    if positions is None:
        positions = jnp.arange(S)
    x = activation_constraint(L.embed(params["embed"], tokens, use_pallas=use_pallas))
    x = x + params["pos_dec"][positions]

    def body(x, inp):
        if kv_caches is None:
            p = inp
            cache = None
        else:
            p, ck, cv = inp
            cache = (ck, cv)
        p = fsdp_unshard(p)
        h = L.layernorm(p["norm1"], x, cfg.norm_eps)
        a, new_cache = L.attention(
            p["self_attn"], h, cfg, positions=positions, causal=True,
            kv_cache=cache, cache_index=cache_index,
            use_pallas=use_pallas, use_rope=False, prefill=prefill,
        )
        x = x + a
        h = L.layernorm(p["norm2"], x, cfg.norm_eps)
        enc_kv = encode_kv(p["cross_attn"], enc_out, cfg)
        x = x + cross_attention(p["cross_attn"], h, enc_kv, cfg)
        h = L.layernorm(p["norm3"], x, cfg.norm_eps)
        x = x + L.gelu_mlp(p["mlp"], h)
        if cache is None:
            return x, None
        return x, new_cache

    if kv_caches is None:
        x, _ = jax.lax.scan(body, x, params["dec_layers"])
        new_caches = None
    else:
        x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], *kv_caches))
    x = L.layernorm(params["dec_norm"], x, cfg.norm_eps)
    return x, new_caches


def forward(params, tokens, frames, cfg, *, use_pallas=False, remat=True):
    """Full enc-dec forward -> decoder logits (tied embeddings, Whisper-style)."""
    enc_out = encode(params, frames, cfg, use_pallas=use_pallas)
    x, _ = decode_hidden(params, tokens, enc_out, cfg, use_pallas=use_pallas)
    return x @ params["embed"]["table"].T


def init_kv_cache(cfg: ArchConfig, batch: int, max_seq: int):
    dh = cfg.attn_head_dim
    shape = (cfg.n_layers, batch, cfg.n_kv_heads, max_seq, dh)
    dt = _dtype(cfg)
    return (jnp.zeros(shape, dt), jnp.zeros(shape, dt))


def decode_step(params, tokens, cache_index, caches, enc_out, cfg, *,
                use_pallas=False, prefill=False):
    positions = cache_index + jnp.arange(tokens.shape[1])
    x, new_caches = decode_hidden(
        params, tokens, enc_out, cfg, positions=positions,
        kv_caches=caches, cache_index=cache_index, use_pallas=use_pallas,
        prefill=prefill,
    )
    return x @ params["embed"]["table"].T, new_caches
