"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def embedding_bag_ref(table: jax.Array, indices: jax.Array) -> jax.Array:
    """table (T*R, D), indices (B, T, L) pre-offset -> (B, T, D) sum-pool."""
    gathered = table[indices]                 # (B, T, L, D)
    return gathered.astype(jnp.float32).sum(axis=2).astype(table.dtype)


def embedding_gather_ref(table: jax.Array, indices: jax.Array) -> jax.Array:
    return table[indices]


def embedding_bag_pinned_ref(
    hot_table: jax.Array,     # (H, D)
    positions: jax.Array,     # (B, T, L) position in hot table (0 if cold)
    mask: jax.Array,          # (B, T, L) 1 = hot
) -> jax.Array:
    rows = hot_table[positions].astype(jnp.float32)          # (B, T, L, D)
    rows = rows * mask[..., None].astype(jnp.float32)
    return rows.sum(axis=2).astype(hot_table.dtype)


def flash_attention_ref(
    q: jax.Array,   # (B, Hq, S, d)
    k: jax.Array,   # (B, Hkv, S, d)
    v: jax.Array,
    *,
    causal: bool = True,
    sm_scale: float | None = None,
) -> jax.Array:
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    group = Hq // Hkv
    kf = jnp.repeat(k, group, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, group, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), kf) * sm_scale
    if causal:
        mask = jnp.tril(jnp.ones((S, S), dtype=bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, vf).astype(q.dtype)


def chunked_attention(
    q: jax.Array,   # (B, Hq, S, dq)
    k: jax.Array,   # (B, Hkv, Sk, dq)
    v: jax.Array,   # (B, Hkv, Sk, dv)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    k_block: int = 512,
) -> jax.Array:
    """Online-softmax attention as a lax.scan over kv blocks — the XLA path
    for long prefill (never materializes (S, Sk) scores). Supports GQA
    without repeating kv, and dv != dq (MLA)."""
    B, Hq, S, dq = q.shape
    Hkv, Sk = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    G = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(dq)
    k_block = min(k_block, Sk)
    pad = (-Sk) % k_block
    if pad:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nkb = (Sk + pad) // k_block
    kb = k.reshape(B, Hkv, nkb, k_block, dq).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(B, Hkv, nkb, k_block, dv).transpose(2, 0, 1, 3, 4)

    rows = jnp.arange(S)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, ib = inp
        # GQA: expand kv per block only (cheap; keeps the q head dim intact
        # so head sharding propagates cleanly under GSPMD)
        kc = jnp.repeat(kc, G, axis=1)                 # (B, Hq, kb, dq)
        vc = jnp.repeat(vc, G, axis=1)
        s = jnp.einsum(
            "bhsd,bhtd->bhst", q, kc, preferred_element_type=jnp.float32
        ) * sm_scale
        cols = ib * k_block + jnp.arange(k_block)
        mask = cols[None, :] < Sk
        if causal:
            mask = mask & (rows[:, None] >= cols[None, :])
        s = jnp.where(mask[None, None], s, -1e30)
        m_new = jnp.maximum(m, s.max(-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m - m_new)
        l = l * alpha + p.sum(-1, keepdims=True)
        acc = acc * alpha + jnp.einsum(
            "bhst,bhtd->bhsd", p.astype(v.dtype), vc,
            preferred_element_type=jnp.float32,
        )
        return (m_new, l, acc), None

    m0 = jnp.full((B, Hq, S, 1), -1e30, jnp.float32)
    l0 = jnp.zeros((B, Hq, S, 1), jnp.float32)
    a0 = jnp.zeros((B, Hq, S, dv), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, jnp.arange(nkb)))
    out = acc / jnp.maximum(l, 1e-30)
    return out.astype(q.dtype)


def decode_attention_ref(
    q: jax.Array,          # (B, Hq, dh)
    k: jax.Array,          # (B, Hkv, S, dh)
    v: jax.Array,
    valid_len: jax.Array,  # () int32
) -> jax.Array:            # (B, Hq, dh)
    B, Hq, dh = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    G = Hq // Hkv
    kf = jnp.repeat(k, G, axis=1).astype(jnp.float32)
    vf = jnp.repeat(v, G, axis=1).astype(jnp.float32)
    s = jnp.einsum("bhd,bhkd->bhk", q.astype(jnp.float32), kf) / math.sqrt(dh)
    s = jnp.where(jnp.arange(S)[None, None, :] < valid_len, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhk,bhkd->bhd", w, vf).astype(q.dtype)


def mamba2_final_state(
    x: jax.Array,    # (B, H, S, P)
    adt: jax.Array,  # (B, H, S)
    dt: jax.Array,   # (B, H, S)
    Bm: jax.Array,   # (B, S, N)
) -> jax.Array:      # (B, H, P, N) — state after the full sequence
    cum = jnp.cumsum(adt.astype(jnp.float32), axis=-1)
    w = jnp.exp(cum[..., -1:] - cum) * dt.astype(jnp.float32)     # (B,H,S)
    return jnp.einsum("bhs,bhsp,bsn->bhpn", w, x.astype(jnp.float32),
                      Bm.astype(jnp.float32))


def mamba2_ssd_ref(
    x: jax.Array,    # (B, H, S, P)
    adt: jax.Array,  # (B, H, S)
    dt: jax.Array,   # (B, H, S)
    Bm: jax.Array,   # (B, S, N)
    C: jax.Array,    # (B, S, N)
) -> jax.Array:      # (B, H, S, P)
    """Exact sequential recurrence (lax.scan over time)."""
    Bsz, H, S, P = x.shape
    N = Bm.shape[-1]

    def step(state, inp):
        x_t, adt_t, dt_t, b_t, c_t = inp
        # state (B, H, P, N)
        decay = jnp.exp(adt_t)[..., None, None]               # (B, H, 1, 1)
        outer = (dt_t[..., None, None] * x_t[..., :, None]) * b_t[:, None, None, :]
        state = decay * state + outer
        y_t = jnp.einsum("bhpn,bn->bhp", state, c_t)
        return state, y_t

    xs = (
        jnp.moveaxis(x, 2, 0).astype(jnp.float32),
        jnp.moveaxis(adt, 2, 0).astype(jnp.float32),
        jnp.moveaxis(dt, 2, 0).astype(jnp.float32),
        jnp.moveaxis(Bm, 1, 0).astype(jnp.float32),
        jnp.moveaxis(C, 1, 0).astype(jnp.float32),
    )
    state0 = jnp.zeros((Bsz, H, P, N), dtype=jnp.float32)
    _, ys = jax.lax.scan(step, state0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)
