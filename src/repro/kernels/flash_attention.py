"""Pallas TPU flash attention (tiled online softmax).

Used by the LM-family architectures for training and prefill. GQA is handled
structurally: the kv BlockSpec index_map maps query head h to kv head
h // group_size, so grouped kv heads are never materialized.

Grid: (batch, q_heads, num_q_blocks, num_kv_blocks); the kv axis iterates
fastest, with running (max, sum, acc) state in VMEM scratch — the standard
TPU flash schedule. Causal masking skips fully-masked kv blocks via pl.when
and masks the diagonal block with iota comparisons.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 128
DEFAULT_BLOCK_K = 128
_NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref, out_ref,
    acc_ref, m_ref, l_ref,
    *, causal: bool, sm_scale: float, block_q: int, block_k: int,
):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # With causal masking, blocks strictly above the diagonal contribute
    # nothing; skip their math entirely.
    run = True
    if causal:
        run = ik * block_k <= (iq + 1) * block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (Bq, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (Bk, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (Bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * sm_scale                                  # (Bq, Bk)
        if causal:
            rows = iq * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            cols = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(rows >= cols, s, _NEG_INF)

        m_prev = m_ref[:, :1]                         # (Bq, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                        # (Bq, Bk)
        alpha = jnp.exp(m_prev - m_new)               # (Bq, 1)
        l_new = alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )
        m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
        l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ik == nk - 1)
    def _done():
        out_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30))[
            None, None
        ].astype(out_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,          # (B, Hq, S, d)
    k: jax.Array,          # (B, Hkv, S, d)
    v: jax.Array,          # (B, Hkv, S, d)
    *,
    causal: bool = True,
    sm_scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_k: int = DEFAULT_BLOCK_K,
    interpret: bool = True,
) -> jax.Array:
    B, Hq, S, d = q.shape
    Hkv = k.shape[1]
    assert Hq % Hkv == 0, (Hq, Hkv)
    group = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(d)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    nq = pl.cdiv(S, block_q)
    nk = pl.cdiv(S, block_k)

    kernel = functools.partial(
        _flash_kernel,
        causal=causal,
        sm_scale=sm_scale,
        block_q=block_q,
        block_k=block_k,
    )
    return pl.pallas_call(
        kernel,
        grid=(B, Hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h // group, ik, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda b, h, iq, ik: (b, h // group, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, d), lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, d), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
            pltpu.VMEM((block_q, 128), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
