"""Pallas set-associative cache-scan kernel (the simulator's hot loop).

``cache.py`` simulates the paper's on-chip cache by scanning the address
trace with a ``(tags, meta)`` carry. This module is the Pallas realization of
that loop (``HardwareConfig.cache_backend="pallas"``): one kernel instance
per set-group sub-trace keeps the whole ``(group_sets, ways)`` tag + metadata
state in VMEM scratch and walks the padded sub-trace in-kernel, so the state
never round-trips through HBM between accesses and the grid dimension
processes the length-bucketed sub-traces of many configs in one launch.

Replacement semantics are copied access-for-access from ``cache._step``
(ChampSim LRU / SRRIP / FIFO) with one mechanical difference: way selection
uses first-match masks (``cumsum == 1``) instead of argmax/argmin, which tie-
break identically (lowest way index). Integer state only, so the kernel is
bit-exact against ``golden.GoldenCache`` — enforced by the differential fuzz
tests in ``tests/test_cache_pallas.py``.

Off-TPU the kernel runs in interpret mode (default automatically selected),
so CPU CI exercises the exact kernel program end to end. VMEM scratch is
``(group_sets, ways)`` int32; with the default 32-set groups and 16 ways the
state is 4 KB — far under the VMEM budget, the point of set-group
partitioning. (On real TPU hardware the ``ways`` axis sits below the 128-lane
tile width; interpret mode does not care, and the compiled path pads lanes.)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

MAX_RRPV = 3  # 2-bit SRRIP (mirrors cache.MAX_RRPV)

_POLICY_IDS = {"lru": 0, "srrip": 1, "fifo": 2}


def _first_true(mask: jax.Array) -> jax.Array:
    """Mask selecting the first True along the last axis (argmax tie-break)."""
    return mask & (jnp.cumsum(mask.astype(jnp.int32), axis=-1) == 1)


def _cache_scan_kernel(
    policy_id: int,
    num_sets: int,
    ways: int,
    s_ref,        # (1, L) int32 local set index per access
    t_ref,        # (1, L) int32 tag per access
    v_ref,        # (1, L) int32 1 = real access, 0 = padding
    hit_ref,      # (1, L) int32 out: on-chip hit
    evict_ref,    # (1, L) int32 out: eviction performed
    tags_ref,     # VMEM (num_sets, ways) int32 scratch: line tags, -1 invalid
    meta_ref,     # VMEM (num_sets, ways) int32 scratch: LRU/FIFO ts or RRPV
):
    L = s_ref.shape[1]
    tags_ref[...] = jnp.full((num_sets, ways), -1, dtype=jnp.int32)
    if policy_id == _POLICY_IDS["srrip"]:
        meta_ref[...] = jnp.full((num_sets, ways), MAX_RRPV, dtype=jnp.int32)
    else:
        meta_ref[...] = jnp.full((num_sets, ways), -1, dtype=jnp.int32)

    def body(i, t):
        s = s_ref[0, i]
        tag = t_ref[0, i]
        valid = v_ref[0, i] != 0

        row_tags = pl.load(tags_ref, (pl.dslice(s, 1), slice(None)))  # (1, W)
        row_meta = pl.load(meta_ref, (pl.dslice(s, 1), slice(None)))

        hit_vec = row_tags == tag
        hit = jnp.any(hit_vec)
        hit_mask = _first_true(hit_vec)
        invalid_vec = row_tags < 0

        if policy_id == _POLICY_IDS["srrip"]:
            # Age the set until some way reaches MAX_RRPV (persists).
            inc = jnp.maximum(0, MAX_RRPV - jnp.max(row_meta))
            aged = row_meta + inc
            victim_mask = _first_true(aged == MAX_RRPV)
            new_meta_hit = jnp.where(hit_mask, 0, row_meta)
            new_meta_miss = jnp.where(victim_mask, MAX_RRPV - 1, aged)
        else:
            # Invalid ways carry -1 < any timestamp, so the first minimum is
            # the first invalid way when one exists (ChampSim behaviour).
            masked = jnp.where(invalid_vec, -1, row_meta)
            victim_mask = _first_true(masked == jnp.min(masked))
            if policy_id == _POLICY_IDS["lru"]:
                new_meta_hit = jnp.where(hit_mask, t, row_meta)
            else:  # fifo: hits do not touch metadata
                new_meta_hit = row_meta
            new_meta_miss = jnp.where(victim_mask, t, row_meta)

        evict = valid & ~hit & jnp.any(victim_mask & (row_tags >= 0))
        new_meta = jnp.where(hit, new_meta_hit, new_meta_miss)
        new_tags = jnp.where(hit, row_tags, jnp.where(victim_mask, tag, row_tags))

        # Padding accesses leave the state untouched and report miss.
        new_tags = jnp.where(valid, new_tags, row_tags)
        new_meta = jnp.where(valid, new_meta, row_meta)
        pl.store(tags_ref, (pl.dslice(s, 1), slice(None)), new_tags)
        pl.store(meta_ref, (pl.dslice(s, 1), slice(None)), new_meta)

        pl.store(
            hit_ref, (slice(0, 1), pl.dslice(i, 1)),
            (hit & valid).astype(jnp.int32).reshape(1, 1),
        )
        pl.store(
            evict_ref, (slice(0, 1), pl.dslice(i, 1)),
            evict.astype(jnp.int32).reshape(1, 1),
        )
        return t + jnp.int32(1)

    jax.lax.fori_loop(0, L, body, jnp.int32(0))


@functools.lru_cache(maxsize=None)
def _build_cache_scan(
    policy: str, num_sets: int, ways: int, B: int, L: int, interpret: bool
):
    """Memoized pallas_call for one (policy, geometry, batch shape).

    The bucketed sweep re-dispatches identical shapes many times; building
    the kernel closure once per shape keeps tracing (and on TPU,
    compilation) out of the steady-state path, matching the jitted scan
    backend's cost profile.
    """
    kernel = functools.partial(
        _cache_scan_kernel, _POLICY_IDS[policy], num_sets, ways
    )
    row = pl.BlockSpec((1, L), lambda b: (b, 0))
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[row, row, row],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((B, L), jnp.int32),
            jax.ShapeDtypeStruct((B, L), jnp.int32),
        ],
        scratch_shapes=[
            pltpu.VMEM((num_sets, ways), jnp.int32),
            pltpu.VMEM((num_sets, ways), jnp.int32),
        ],
        interpret=interpret,
    )


def cache_scan_groups(
    sets: jax.Array,      # (B, L) int32 local set index
    tags: jax.Array,      # (B, L) int32 tag
    valid: jax.Array,     # (B, L) bool
    num_sets: int,
    ways: int,
    policy: str = "lru",
    interpret: "bool | None" = None,
):
    """Run B padded set-group sub-traces through the Pallas cache kernel.

    Same contract as ``cache._simulate_many`` (per-access hit/evict arrays,
    device-resident); grid dimension = sub-trace batch. ``interpret=None``
    auto-selects interpret mode off-TPU so the kernel runs everywhere.
    """
    if policy not in _POLICY_IDS:
        raise ValueError(f"unknown policy {policy!r}; options: {sorted(_POLICY_IDS)}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, L = sets.shape
    call = _build_cache_scan(
        policy, int(num_sets), int(ways), int(B), int(L), bool(interpret)
    )
    hits, evicts = call(
        sets.astype(jnp.int32), tags.astype(jnp.int32), valid.astype(jnp.int32)
    )
    return hits.astype(bool), evicts.astype(bool)
