"""Public jit'd wrappers around the Pallas kernels.

Handle padding (lane-width alignment), dtype policy, hot/cold index-stream
splitting for the pinned embedding path, and the kernel/reference dispatch:
``use_pallas=True`` runs the Pallas kernel (interpret mode on CPU, compiled
on TPU); ``use_pallas=False`` runs the pure-jnp reference (the XLA path the
dry-run lowers — identical math, tested allclose).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import ref
from .decode_attention import decode_attention_kernel
from .embedding_bag import (
    embedding_bag_kernel,
    embedding_gather_kernel,
    vmem_gather_pool_kernel,
)
from .flash_attention import flash_attention_kernel
from .mamba2_ssd import mamba2_ssd_kernel

LANE = 128


def _pad_dim(x: jax.Array, axis: int, multiple: int) -> Tuple[jax.Array, int]:
    size = x.shape[axis]
    target = -(-size // multiple) * multiple
    if target == size:
        return x, size
    pad = [(0, 0)] * x.ndim
    pad[axis] = (0, target - size)
    return jnp.pad(x, pad), size


# --------------------------------------------------------------------------
# Embedding ops (the paper's operation)
# --------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("rows_per_table", "use_pallas", "interpret"))
def embedding_bag(
    table: jax.Array,       # (T*R, D)
    indices: jax.Array,     # (B, T, L) int32 per-table row ids (NOT offset)
    rows_per_table: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:             # (B, T, D)
    T = indices.shape[1]
    offset = (jnp.arange(T, dtype=jnp.int32) * rows_per_table)[None, :, None]
    flat_idx = indices.astype(jnp.int32) + offset
    if not use_pallas:
        return ref.embedding_bag_ref(table, flat_idx)
    tbl, d0 = _pad_dim(table, 1, LANE)
    out = embedding_bag_kernel(tbl, flat_idx, rows_per_table, interpret=interpret)
    return out[..., :d0]


@functools.partial(jax.jit, static_argnames=("use_pallas", "interpret"))
def embedding_gather(
    table: jax.Array,       # (R, D)
    indices: jax.Array,     # (...,) int32
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:             # (..., D)
    shape = indices.shape
    flat = indices.reshape(-1).astype(jnp.int32)
    if not use_pallas:
        out = ref.embedding_gather_ref(table, flat)
    else:
        tbl, d0 = _pad_dim(table, 1, LANE)
        out = embedding_gather_kernel(tbl, flat, interpret=interpret)[:, :d0]
    return out.reshape(*shape, table.shape[1])


def split_hot_cold(
    indices: np.ndarray,    # (B, T, L) per-table row ids
    hot_ids: np.ndarray,    # (n_hot,) sorted GLOBAL ids (t * rows + r)
    rows_per_table: int,
) -> Tuple[np.ndarray, np.ndarray]:
    """Host-side prep for the pinned path: position-in-hot-table (or 0) and a
    hot mask, per lookup. Mirrors core.memory.policies pinning semantics."""
    t_ids = np.arange(indices.shape[1], dtype=np.int64)[None, :, None]
    glob = t_ids * rows_per_table + indices.astype(np.int64)
    pos = np.searchsorted(hot_ids, glob)
    pos = np.clip(pos, 0, max(len(hot_ids) - 1, 0))
    is_hot = len(hot_ids) > 0
    mask = (hot_ids[pos] == glob) if is_hot else np.zeros_like(glob, dtype=bool)
    return pos.astype(np.int32), mask.astype(np.int32)


@functools.partial(jax.jit, static_argnames=("rows_per_table", "use_pallas", "interpret"))
def embedding_bag_pinned(
    table: jax.Array,       # (T*R, D) full table in HBM
    hot_table: jax.Array,   # (H, D) VMEM-pinned hot rows (= table[hot_ids])
    indices: jax.Array,     # (B, T, L) per-table row ids
    positions: jax.Array,   # (B, T, L) position in hot_table
    mask: jax.Array,        # (B, T, L) 1 = hot
    rows_per_table: int,
    *,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    """Paper's Profiling policy on TPU: hot lookups never touch HBM.

    Hot contributions come from the VMEM-resident hot table; cold lookups are
    redirected to row 0 with a zero multiplier... handled by routing the cold
    stream through the DMA-gather bag kernel with hot lookups masked to a
    repeat of the first cold index (DMA'd but multiplied by zero — on real
    TPU the index stream would be compacted host-side; the simulator counts
    only cold traffic either way).
    """
    T = indices.shape[1]
    offset = (jnp.arange(T, dtype=jnp.int32) * rows_per_table)[None, :, None]
    flat_idx = indices.astype(jnp.int32) + offset
    cold_mask = 1 - mask
    if not use_pallas:
        hot = ref.embedding_bag_pinned_ref(hot_table, positions, mask)
        cold_rows = table[flat_idx].astype(jnp.float32)
        cold = (cold_rows * cold_mask[..., None]).sum(axis=2).astype(table.dtype)
        return hot + cold

    tbl, d0 = _pad_dim(table, 1, LANE)
    htbl, _ = _pad_dim(hot_table, 1, LANE)
    hot = vmem_gather_pool_kernel(htbl, positions.astype(jnp.int32),
                                  mask.astype(jnp.int32), interpret=interpret)
    # cold stream: mask hot lookups to index 0 and subtract their contribution
    # by zero-weighting via a second masked VMEM pass is wasteful; instead
    # gather cold rows with the bag kernel on a masked index stream and
    # correct: bag(all) - bag(hot-as-cold) == bag(cold). Simpler: weight trick
    # below — gather rows for cold indices only (hot ones point at row 0) and
    # zero them with the mask in a vector pass.
    cold_idx = jnp.where(mask == 1, 0, flat_idx)
    cold_all = embedding_gather_kernel(
        tbl, cold_idx.reshape(-1).astype(jnp.int32), interpret=interpret
    ).reshape(*cold_idx.shape, -1)
    cold = (cold_all.astype(jnp.float32) * cold_mask[..., None]).sum(axis=2)
    return (hot.astype(jnp.float32) + cold)[..., :d0].astype(table.dtype)


# --------------------------------------------------------------------------
# Attention / SSD
# --------------------------------------------------------------------------

@functools.partial(
    jax.jit,
    static_argnames=("causal", "block_q", "block_k", "use_pallas", "interpret"),
)
def flash_attention(
    q: jax.Array, k: jax.Array, v: jax.Array,
    *,
    causal: bool = True,
    block_q: int = 128,
    block_k: int = 128,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    S = q.shape[2]
    same_d = q.shape[-1] == v.shape[-1]
    if not use_pallas or not same_d:
        if S > 2048 or q.shape[-1] != v.shape[-1]:
            return ref.chunked_attention(q, k, v, causal=causal)
        return ref.flash_attention_ref(q, k, v, causal=causal)
    if S % min(block_q, S) or S % min(block_k, S):
        return ref.flash_attention_ref(q, k, v, causal=causal)  # ragged fallback
    return flash_attention_kernel(
        q, k, v, causal=causal, block_q=block_q, block_k=block_k, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("block_k", "use_pallas", "interpret"))
def decode_attention(
    q: jax.Array,          # (B, Hq, dh)
    k: jax.Array,          # (B, Hkv, S_max, dh)
    v: jax.Array,
    valid_len: jax.Array,  # () int32
    *,
    block_k: int = 512,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    if not use_pallas:
        return ref.decode_attention_ref(q, k, v, valid_len)
    S = k.shape[2]
    if S % min(block_k, S):
        return ref.decode_attention_ref(q, k, v, valid_len)
    return decode_attention_kernel(
        q, k, v, valid_len, block_k=block_k, interpret=interpret
    )


@functools.partial(jax.jit, static_argnames=("chunk", "use_pallas", "interpret"))
def mamba2_ssd(
    x: jax.Array,    # (B, H, S, P)
    adt: jax.Array,  # (B, H, S)
    dt: jax.Array,   # (B, H, S)
    Bm: jax.Array,   # (B, S, N)
    C: jax.Array,    # (B, S, N)
    *,
    chunk: int = 128,
    use_pallas: bool = True,
    interpret: bool = True,
) -> jax.Array:
    if not use_pallas:
        return ref.mamba2_ssd_ref(x, adt, dt, Bm, C)
    S = x.shape[2]
    c = min(chunk, S)
    if S % c:
        return ref.mamba2_ssd_ref(x, adt, dt, Bm, C)
    return mamba2_ssd_kernel(x, adt, dt, Bm, C, chunk=c, interpret=interpret)
