"""Pallas TPU kernel for the Mamba2 SSD (state-space duality) scan.

Chunked SSD: the sequence is processed in chunks of ``chunk`` steps; within a
chunk the recurrence is expanded into attention-like matmuls (MXU-friendly),
while a (P, N) state carried in VMEM scratch propagates across chunks
(grid iterates chunks sequentially — Pallas TPU guarantees sequential grid
order, which the carried scratch state relies on).

Semantics (per batch b, head h; ngroups = 1):
    state_t = exp(A_h dt_t) * state_{t-1} + dt_t * x_t ⊗ B_t
    y_t     = state_t @ C_t

Inputs are pre-arranged by ops.py: x (B,H,S,P), adt = A*dt (B,H,S),
dt (B,H,S), Bm (B,S,N), C (B,S,N).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, adt_ref, dt_ref, b_ref, c_ref, out_ref, state_ref):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    xc = x_ref[0, 0].astype(jnp.float32)        # (Q, P)
    a = adt_ref[0, 0].astype(jnp.float32)       # (Q,)
    dt = dt_ref[0, 0].astype(jnp.float32)       # (Q,)
    Bc = b_ref[0].astype(jnp.float32)           # (Q, N)
    Cc = c_ref[0].astype(jnp.float32)           # (Q, N)

    cum = jnp.cumsum(a)                         # (Q,) inclusive
    # intra-chunk: y[i] += sum_{j<=i} exp(cum i - cum j) dt[j] (C_i.B_j) x[j]
    diff = cum[:, None] - cum[None, :]
    mask = jax.lax.broadcasted_iota(jnp.int32, diff.shape, 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, diff.shape, 1)
    decay = jnp.where(mask, jnp.exp(diff), 0.0)
    scores = jax.lax.dot_general(
        Cc, Bc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * decay * dt[None, :]
    y = jax.lax.dot_general(
        scores, xc, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )                                           # (Q, P)

    # inter-chunk: y[i] += exp(cum i) * C_i @ state^T
    state = state_ref[...]                      # (P, N)
    y += jnp.exp(cum)[:, None] * jax.lax.dot_general(
        Cc, state, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    # state update: S <- exp(cum[-1]) S + x^T (exp(cum[-1]-cum) dt ⊙ B)
    w = jnp.exp(cum[-1] - cum) * dt             # (Q,)
    state_ref[...] = state * jnp.exp(cum[-1]) + jax.lax.dot_general(
        xc, w[:, None] * Bc, (((0,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    out_ref[...] = y[None, None].astype(out_ref.dtype)


def mamba2_ssd_kernel(
    x: jax.Array,      # (B, H, S, P)
    adt: jax.Array,    # (B, H, S)  A_h * dt  (negative)
    dt: jax.Array,     # (B, H, S)
    Bm: jax.Array,     # (B, S, N)
    C: jax.Array,      # (B, S, N)
    *,
    chunk: int = DEFAULT_CHUNK,
    interpret: bool = True,
) -> jax.Array:        # (B, H, S, P)
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    nc = S // chunk

    return pl.pallas_call(
        _ssd_kernel,
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, c: (b, h, c)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, c: (b, c, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
        out_shape=jax.ShapeDtypeStruct(x.shape, x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, adt, dt, Bm, C)
