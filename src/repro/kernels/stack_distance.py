"""Pallas LRU stack-distance kernel (the TPU variant of the distance pass).

``memory/stack.py`` computes exact LRU stack distances analytically (argsorts
and prefix sums). This kernel is the VMEM-resident realization of the same
distance pass for ``cache_backend="stack_pallas"``: per set-group sub-trace
it keeps a *recency-ordered* tag list (way 0 = MRU) in VMEM scratch and walks
the padded sub-trace in-kernel. For every access the position of its tag in
the recency list IS the stack distance (capped at ``ways`` — larger distances
are indistinguishable from a miss for every associativity this state covers);
updating is one rotate-insert toward MRU, no timestamps.

This is a deliberately different *shape* of implementation from both the
``(tags, meta)`` cache-scan kernel and the analytic engine — agreement across
the three (and ``GoldenCache``) is therefore meaningful, and is enforced by
the differential fuzz tests in ``tests/test_cache_stack.py``. Off-TPU the
kernel runs in interpret mode so CPU CI exercises the exact kernel program.

Outputs: per-access capped distance (int32; hit for W ways iff ``dist < W``
with ``W <= ways``) and the eviction flag (miss with a full set).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _stack_distance_kernel(
    num_sets: int,
    ways: int,
    s_ref,        # (1, L) int32 local set index per access
    t_ref,        # (1, L) int32 tag per access
    v_ref,        # (1, L) int32 1 = real access, 0 = padding
    dist_ref,     # (1, L) int32 out: stack distance, capped at ways
    evict_ref,    # (1, L) int32 out: eviction performed
    tags_ref,     # VMEM (num_sets, ways) int32 scratch: recency list, -1 empty
):
    L = s_ref.shape[1]
    tags_ref[...] = jnp.full((num_sets, ways), -1, dtype=jnp.int32)
    way_idx = jax.lax.broadcasted_iota(jnp.int32, (1, ways), 1)

    def body(i, _):
        s = s_ref[0, i]
        tag = t_ref[0, i]
        valid = v_ref[0, i] != 0

        row = pl.load(tags_ref, (pl.dslice(s, 1), slice(None)))   # (1, W)
        hit_vec = row == tag
        found = jnp.any(hit_vec)
        # Position of the tag in the recency list = capped stack distance.
        pos = jnp.sum(
            jnp.where(hit_vec, way_idx, 0), dtype=jnp.int32
        )
        dist = jnp.where(found, pos, jnp.int32(ways))

        # Rotate-insert toward MRU: ways [1, limit] take their left
        # neighbour, way 0 takes the tag; ways beyond the hit position (or
        # everything on a miss, dropping the LRU way) stay put.
        limit = jnp.where(found, pos, jnp.int32(ways - 1))
        rolled = jnp.roll(row, 1, axis=1)
        new_row = jnp.where(
            way_idx == 0, tag, jnp.where(way_idx <= limit, rolled, row)
        )
        evict = valid & ~found & (row[0, ways - 1] >= 0)
        new_row = jnp.where(valid, new_row, row)
        pl.store(tags_ref, (pl.dslice(s, 1), slice(None)), new_row)

        pl.store(
            dist_ref, (slice(0, 1), pl.dslice(i, 1)),
            jnp.where(valid, dist, jnp.int32(ways)).reshape(1, 1),
        )
        pl.store(
            evict_ref, (slice(0, 1), pl.dslice(i, 1)),
            evict.astype(jnp.int32).reshape(1, 1),
        )
        return 0

    jax.lax.fori_loop(0, L, body, 0)


@functools.lru_cache(maxsize=None)
def _build_stack_distance(
    num_sets: int, ways: int, B: int, L: int, interpret: bool
):
    """Memoized pallas_call per (geometry, batch shape) — bucketed sweeps
    re-dispatch identical shapes, so the kernel closure is built once."""
    kernel = functools.partial(_stack_distance_kernel, num_sets, ways)
    row = pl.BlockSpec((1, L), lambda b: (b, 0))
    return pl.pallas_call(
        kernel,
        grid=(B,),
        in_specs=[row, row, row],
        out_specs=[row, row],
        out_shape=[
            jax.ShapeDtypeStruct((B, L), jnp.int32),
            jax.ShapeDtypeStruct((B, L), jnp.int32),
        ],
        scratch_shapes=[pltpu.VMEM((num_sets, ways), jnp.int32)],
        interpret=interpret,
    )


def stack_distance_groups(
    sets: jax.Array,      # (B, L) int32 local set index
    tags: jax.Array,      # (B, L) int32 tag
    valid: jax.Array,     # (B, L) bool
    num_sets: int,
    ways: int,
    interpret: "bool | None" = None,
):
    """Run B padded set-group sub-traces through the distance kernel.

    Returns device-resident ``(dist, evict)``: int32 distances capped at
    ``ways`` (hit for W-way LRU iff ``dist < W``) and bool eviction flags.
    ``interpret=None`` auto-selects interpret mode off-TPU.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    B, L = sets.shape
    call = _build_stack_distance(
        int(num_sets), int(ways), int(B), int(L), bool(interpret)
    )
    dist, evict = call(
        sets.astype(jnp.int32), tags.astype(jnp.int32), valid.astype(jnp.int32)
    )
    return dist, evict.astype(bool)
