"""Pallas TPU kernel for GQA decode attention (the serving hot loop).

One new token attends over a (B, Hkv, S_max, dh) KV cache with ``valid_len``
entries populated. Grid (B, Hkv, num_kv_blocks): kv blocks stream through
VMEM with online-softmax state in scratch; the G = Hq/Hkv query heads of a
kv group are processed together so grouped heads never materialize. The
valid length arrives via scalar prefetch and masks the tail block.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_K = 512
_NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   acc_ref, m_ref, l_ref, *, block_k: int, sm_scale: float):
    ik = pl.program_id(2)
    nk = pl.num_programs(2)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)                  # (G, dh)
    k = k_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
    v = v_ref[0, 0].astype(jnp.float32)                  # (bk, dh)
    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * sm_scale                                         # (G, bk)
    span = ik * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    s = jnp.where(span < len_ref[0], s, _NEG_INF)

    m_prev = m_ref[:, :1]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = jnp.broadcast_to(
        alpha * l_ref[:, :1] + jnp.sum(p, axis=1, keepdims=True), l_ref.shape
    )
    acc_ref[...] = acc_ref[...] * alpha + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)

    @pl.when(ik == nk - 1)
    def _done():
        o_ref[...] = (acc_ref[...] / jnp.maximum(l_ref[:, :1], 1e-30))[
            None, None
        ].astype(o_ref.dtype)


def decode_attention_kernel(
    q: jax.Array,          # (B, Hq, dh) one new token per sequence
    k: jax.Array,          # (B, Hkv, S_max, dh)
    v: jax.Array,          # (B, Hkv, S_max, dh)
    valid_len: jax.Array,  # () int32 — populated cache length
    *,
    block_k: int = DEFAULT_BLOCK_K,
    sm_scale: float | None = None,
    interpret: bool = True,
) -> jax.Array:            # (B, Hq, dh)
    B, Hq, dh = q.shape
    Hkv, S = k.shape[1], k.shape[2]
    assert Hq % Hkv == 0
    G = Hq // Hkv
    if sm_scale is None:
        sm_scale = 1.0 / math.sqrt(dh)
    block_k = min(block_k, S)
    nk = pl.cdiv(S, block_k)

    qg = q.reshape(B, Hkv, G, dh)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, Hkv, nk),
        in_specs=[
            pl.BlockSpec((1, 1, G, dh), lambda b, h, ik, L: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b, h, ik, L: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, dh), lambda b, h, ik, L: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, G, dh), lambda b, h, ik, L: (b, h, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((G, dh), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
            pltpu.VMEM((G, 128), jnp.float32),
        ],
    )
    kernel = functools.partial(_decode_kernel, block_k=block_k, sm_scale=sm_scale)
    out = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, Hkv, G, dh), q.dtype),
        interpret=interpret,
    )(valid_len.reshape(1).astype(jnp.int32), qg, k, v)
    return out.reshape(B, Hq, dh)
