"""Pallas TPU kernels for embedding vector operations — THE paper op (Fig. 1).

Three kernels:

  * ``embedding_bag_kernel``   — gather + sum-pool: for each (sample, table)
    bag, DMA ``L`` dynamically-indexed rows from the HBM-resident table into
    VMEM (scalar-prefetched indices drive the BlockSpec index_map — the DMA
    engine does the gather) and accumulate in an f32 VMEM scratch.
  * ``embedding_gather_kernel`` — pure gather (VectorOp.CONCAT): one row per
    grid step, e.g. LM token embedding.
  * ``vmem_gather_pool_kernel`` — gather + pool from a table that is entirely
    VMEM-resident. This is the TPU realization of the paper's "Profiling"
    pinning policy: the hot rows live in VMEM and are served without touching
    HBM; ``ops.embedding_bag_pinned`` splits the index stream into hot/cold
    and routes the cold remainder through ``embedding_bag_kernel``.

TPU adaptation (DESIGN.md §3): NPU simulators model the gather as cache/SPM
traffic; on a real TPU the idiomatic equivalent is index-driven DMA from HBM
with explicit VMEM residency for the hot set. BlockSpecs are (1, D) rows with
D padded to a multiple of 128 (lane width) by the ops wrapper.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


# --------------------------------------------------------------------------
# gather + sum-pool (embedding bag)
# --------------------------------------------------------------------------

def _bag_kernel(idx_ref, row_ref, out_ref, acc_ref):
    """Grid (B, T, L). ``row_ref`` is the (1, D) table row DMA'd for this
    (b, t, l) by the index_map; accumulate over l in f32."""
    l = pl.program_id(2)

    @pl.when(l == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += row_ref[...].astype(jnp.float32)

    @pl.when(l == pl.num_programs(2) - 1)
    def _done():
        out_ref[...] = acc_ref[...][None].astype(out_ref.dtype)


def embedding_bag_kernel(
    table: jax.Array,     # (T * R, D)  stacked tables, D % 128 == 0
    indices: jax.Array,   # (B, T, L) int32, already offset by t * R
    rows_per_table: int,
    *,
    interpret: bool = True,
) -> jax.Array:           # (B, T, D) pooled sums
    B, T, L = indices.shape
    D = table.shape[1]

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(B, T, L),
        in_specs=[
            pl.BlockSpec((1, D), lambda b, t, l, idx_ref: (idx_ref[b, t, l], 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, t, l, idx_ref: (b, t, 0)),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    return pl.pallas_call(
        _bag_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), table.dtype),
        interpret=interpret,
    )(indices, table)


# --------------------------------------------------------------------------
# pure gather (token embedding)
# --------------------------------------------------------------------------

def _gather_kernel(idx_ref, row_ref, out_ref):
    out_ref[...] = row_ref[...]


def embedding_gather_kernel(
    table: jax.Array,     # (R, D), D % 128 == 0
    indices: jax.Array,   # (N,) int32
    *,
    interpret: bool = True,
) -> jax.Array:           # (N, D)
    (N,) = indices.shape
    D = table.shape[1]
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(N,),
        in_specs=[pl.BlockSpec((1, D), lambda i, idx_ref: (idx_ref[i], 0))],
        out_specs=pl.BlockSpec((1, D), lambda i, idx_ref: (i, 0)),
    )
    return pl.pallas_call(
        _gather_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((N, D), table.dtype),
        interpret=interpret,
    )(indices, table)


# --------------------------------------------------------------------------
# VMEM-resident hot-table gather + pool (paper's Profiling policy on TPU)
# --------------------------------------------------------------------------

def _vmem_pool_kernel(idx_ref, mask_ref, hot_ref, out_ref, acc_ref):
    """Grid (B, T). The whole hot table is one VMEM operand; gather rows with
    dynamic slices, masking lookups that were not hot (mask==0)."""
    b = pl.program_id(0)
    t = pl.program_id(1)
    L = idx_ref.shape[2]

    def body(l, acc):
        pos = idx_ref[b, t, l]
        m = mask_ref[b, t, l].astype(jnp.float32)
        row = hot_ref[pl.dslice(pos, 1), :].astype(jnp.float32)
        return acc + m * row

    acc = jnp.zeros_like(acc_ref)
    acc = jax.lax.fori_loop(0, L, body, acc)
    out_ref[...] = acc[None].astype(out_ref.dtype)


def vmem_gather_pool_kernel(
    hot_table: jax.Array,   # (H, D) VMEM-resident hot rows
    positions: jax.Array,   # (B, T, L) int32 position in hot_table (0 if cold)
    mask: jax.Array,        # (B, T, L) int32 1 = hot lookup, 0 = cold
    *,
    interpret: bool = True,
) -> jax.Array:             # (B, T, D) pooled hot contributions
    B, T, L = positions.shape
    H, D = hot_table.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, T),
        in_specs=[pl.BlockSpec((H, D), lambda b, t, *_: (0, 0))],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, t, *_: (b, t, 0)),
        scratch_shapes=[pltpu.VMEM((1, D), jnp.float32)],
    )
    return pl.pallas_call(
        _vmem_pool_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, T, D), hot_table.dtype),
        interpret=interpret,
    )(positions, mask, hot_table)
