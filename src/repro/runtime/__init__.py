from .fault import FailureDetector, FaultConfig
from .elastic import plan_mesh_shape, ElasticPlan, plan_elastic
from .straggler import StragglerPolicy, StragglerReport

__all__ = [
    "FailureDetector",
    "FaultConfig",
    "plan_mesh_shape",
    "ElasticPlan",
    "plan_elastic",
    "StragglerPolicy",
    "StragglerReport",
]
