"""Straggler mitigation for synchronous data parallelism.

Policy engine (hardware-agnostic, driven by observed per-host step times):

  * detect: host slower than ``threshold x median`` over a sliding window;
  * mitigate:
      - "rebalance": shrink the straggler's microbatch share (returned as a
        per-host microbatch allocation the launcher applies);
      - "drop": exclude the straggler's gradient contribution this step
        (gradient scale adjusts — bounded staleness, like backup workers);
  * escalate: persistent stragglers are reported for eviction (feeds the
    FailureDetector -> elastic replan path).
"""
from __future__ import annotations

import collections
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import numpy as np


@dataclass
class StragglerReport:
    stragglers: List[str]
    persistent: List[str]
    microbatch_shares: Dict[str, float]
    grad_scale: float                 # 1 / participating fraction


@dataclass
class StragglerPolicy:
    threshold: float = 1.5
    window: int = 8
    persistent_after: int = 3         # windows flagged before eviction advice
    mode: str = "rebalance"           # rebalance | drop

    _history: Dict[str, Deque[float]] = field(default_factory=dict)
    _flags: Dict[str, int] = field(default_factory=dict)

    def observe(self, step_times: Dict[str, float]) -> StragglerReport:
        for h, t in step_times.items():
            self._history.setdefault(h, collections.deque(maxlen=self.window)).append(t)

        med = {h: float(np.median(d)) for h, d in self._history.items()}
        global_med = float(np.median(list(med.values())))
        stragglers = [h for h, m in med.items() if m > self.threshold * global_med]

        for h in list(self._flags):
            if h not in stragglers:
                self._flags[h] = 0
        for h in stragglers:
            self._flags[h] = self._flags.get(h, 0) + 1
        persistent = [h for h, c in self._flags.items() if c >= self.persistent_after]

        hosts = list(self._history)
        shares = {h: 1.0 for h in hosts}
        grad_scale = 1.0
        if stragglers:
            if self.mode == "rebalance":
                # give the straggler work proportional to its relative speed
                for h in stragglers:
                    shares[h] = max(0.25, global_med / med[h])
                total = sum(shares.values())
                shares = {h: s * len(hosts) / total for h, s in shares.items()}
            else:  # drop
                for h in stragglers:
                    shares[h] = 0.0
                live = sum(1 for s in shares.values() if s > 0)
                grad_scale = len(hosts) / max(live, 1)
        return StragglerReport(
            stragglers=stragglers,
            persistent=persistent,
            microbatch_shares=shares,
            grad_scale=grad_scale,
        )
