"""Failure detection — heartbeat table + injected-failure harness.

On a real cluster each host heartbeats a coordination service; here the
detector is the same state machine driven by test-injected clocks, so the
train loop's react-path (checkpoint -> replan mesh -> restore) is exercised
end-to-end on CPU.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Set


@dataclass(frozen=True)
class FaultConfig:
    heartbeat_timeout_s: float = 30.0
    min_healthy_fraction: float = 0.75   # below this: halt instead of shrink


class FailureDetector:
    def __init__(self, hosts: List[str], cfg: FaultConfig, clock: Callable[[], float] = time.monotonic):
        self.cfg = cfg
        self.clock = clock
        self.last_seen: Dict[str, float] = {h: clock() for h in hosts}
        self.failed: Set[str] = set()

    def heartbeat(self, host: str):
        if host in self.failed:
            return  # rejoin handled by elastic replan, not silent resurrection
        self.last_seen[host] = self.clock()

    def inject_failure(self, host: str):
        """Test hook: drop a host immediately."""
        self.last_seen[host] = -float("inf")

    def poll(self) -> Set[str]:
        """Returns newly-failed hosts since last poll."""
        now = self.clock()
        newly = {
            h for h, t in self.last_seen.items()
            if h not in self.failed and now - t > self.cfg.heartbeat_timeout_s
        }
        self.failed |= newly
        return newly

    @property
    def healthy(self) -> List[str]:
        return [h for h in self.last_seen if h not in self.failed]

    def should_halt(self) -> bool:
        total = len(self.last_seen)
        return len(self.healthy) < self.cfg.min_healthy_fraction * total
