"""Elastic scaling: replan the mesh for a changed device count and reshard.

Policy: preserve the model axis (TP degree is baked into per-layer math and
memory footprints); shrink/grow the data axis to the largest multiple that
fits the surviving devices. Restore flows through CheckpointManager.restore
with the new mesh's shardings — parameters land sharded for the new topology
without a full re-init.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import numpy as np
from jax.sharding import Mesh


@dataclass(frozen=True)
class ElasticPlan:
    mesh_shape: Tuple[int, ...]
    axis_names: Tuple[str, ...]
    dropped_devices: int
    global_batch_scale: float      # new_data_degree / old_data_degree


def plan_mesh_shape(
    n_devices: int,
    model_degree: int,
    *,
    pods: int = 1,
) -> Tuple[int, ...]:
    """Largest (pods, data, model) grid fitting n_devices with fixed model."""
    if model_degree <= 0:
        raise ValueError("model_degree must be positive")
    per_pod = n_devices // max(pods, 1)
    data = per_pod // model_degree
    if data < 1:
        # degenerate: shrink model degree to the largest power-of-two that fits
        m = model_degree
        while m > 1 and n_devices // m < 1:
            m //= 2
        return (1, max(n_devices // m, 1), m)
    return (pods, data, model_degree) if pods > 1 else (data, model_degree)


def plan_elastic(
    old_mesh_shape: Tuple[int, ...],
    axis_names: Tuple[str, ...],
    surviving_devices: int,
) -> ElasticPlan:
    axes = dict(zip(axis_names, old_mesh_shape))
    model = axes.get("model", 1)
    pods = axes.get("pod", 1)
    old_data = axes.get("data", 1)

    # try to keep the pod axis; drop it if a whole pod died
    for p in range(pods, 0, -1):
        shape = plan_mesh_shape(surviving_devices, model, pods=p)
        data = shape[-2] if len(shape) >= 2 else 1
        if data >= 1 and int(np.prod(shape)) <= surviving_devices:
            names = ("pod", "data", "model") if len(shape) == 3 else ("data", "model")
            used = int(np.prod(shape))
            return ElasticPlan(
                mesh_shape=shape,
                axis_names=names,
                dropped_devices=surviving_devices - used,
                global_batch_scale=(shape[-2] * (shape[0] if len(shape) == 3 else 1))
                / (old_data * pods),
            )
    raise RuntimeError("no viable mesh for surviving devices")


def build_mesh(plan: ElasticPlan, devices=None) -> Mesh:
    devices = devices if devices is not None else jax.devices()
    n = int(np.prod(plan.mesh_shape))
    grid = np.asarray(devices[:n]).reshape(plan.mesh_shape)
    return Mesh(grid, plan.axis_names)
