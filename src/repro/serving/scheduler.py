"""Closed-loop continuous-batching scheduler with robustness policies.

This is the serving layer on top of ``MultiCoreMemorySystem``: requests from
``core.requests`` arrive over simulated time, are admitted into fixed batch
slots, and each served batch's *service time* comes from the unmodified
memory system (``simulate_embedding`` over the lowered ``ConcatTrace``, with
on-chip state persisting across batches exactly like the fixed-trace path).
Queueing delay vs. service time, tail latency, and goodput fall out of the
timeline; the robustness policy set decides what happens when the memory
system saturates:

* **Admission control / load shedding** — an arrival finding the queue at or
  above ``admission_watermark`` is shed on the spot (429 semantics).
* **Deadlines / timeout abandonment** — a queued request whose per-attempt
  deadline passes before its batch starts is abandoned (the client hung up).
* **Seeded client retries** — shed or timed-out requests re-submit after
  exponential backoff with seeded jitter (deterministic in
  ``(seed, rid, attempt)`` — the same idiom as ``core.faults.backoff_
  seconds``), so retry storms and metastable overload are *reproducible*.

**Clock monotonicity guarantee.** The simulated clock ``now`` never moves
backwards (regression-tested under deadline+retry storms): every event the
loop schedules — arrivals, batch starts, and in particular *retries of
timed-out requests* — is stamped at or after the clock at the instant it is
scheduled. A timed-out attempt's backoff still counts from its deadline
(the instant the client gave up), but the resubmission is clamped to the
pruning clock: ``max(deadline + backoff, clock)``. Without the clamp a
short backoff could land the retry *before* the batch-formation instant
that pruned it, rewinding ``now`` when the heap entry popped and corrupting
every subsequent ``enqueued``/admission decision. Pass ``event_log=`` to
``simulate_serving`` to capture the clock trace the regression test
asserts over.
* **Graceful degradation** — under queue pressure a batch is served
  degraded: ``hot_rows_only`` truncates pooling to the hottest rows;
  ``cache_bypass`` routes cold tables around the on-chip cache (no
  pollution) at a flat per-line DRAM cost.

**Identity guarantee** (differential-enforced in tests/test_serving_sim.py):
with every policy off, the scheduler's served batches are exactly the
request stream chunked into ``batch_slots`` in arrival order, and its
per-batch stats are the output of ONE ``simulate_embedding`` call over that
lowered ConcatTrace — bit-for-bit the plain fixed-trace path. Policies
"off" means ``RobustnessPolicy()`` defaults; each knob's off spelling
leaves zero trace of that policy's machinery.

**Batching discipline.** The server fills ``batch_slots`` slots from the
FIFO queue and launches when the batch is full — or, when no future arrival
remains, launches the final partial batch. Under load (the regime the
robustness policies exist for) this coincides with "serve whatever is
queued"; in the all-off case it makes batch *composition* independent of
service times, which is what lets the steady-state path run as one batched
``simulate_embedding`` call (the perf-smoke gate holds it within 10% of the
plain sweep wall).

**Closed loop.** With policies armed, composition depends on simulated time
(sheds happen at arrival instants, timeouts at batch formation), so batches
are simulated sequentially: each launch extends the served ConcatTrace and
re-runs ``simulate_embedding`` over the prefix — exact (classification and
DRAM timing are prefix-causal: a batch's stats never depend on later
batches; test-enforced) at O(batches²) trace cost, which is the price of
schedule-dependent traces. A ``ReplayOracle`` substitutes recorded per-batch
stats for the simulation, which is how checkpointed sweeps reconstruct a
``ServingResult`` from journaled stats bitwise.
"""
from __future__ import annotations

import heapq
import math
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from ..core.memory.system import EmbeddingBatchStats, EmbeddingTrace
from ..core.requests import (
    BatchLowering,
    Request,
    TrafficConfig,
    generate_requests,
    hot_table_set,
    lower_batch,
)
from ..core.results import ServingResult
from ..core.trace import ConcatTrace, FullTrace
from ..core.workload import EmbeddingOpSpec

__all__ = [
    "DEGRADE_MODES",
    "ReplayOracle",
    "RobustnessPolicy",
    "ServingScenario",
    "simulate_serving",
]

DEGRADE_MODES = ("hot_rows_only", "cache_bypass")

_RETRY_TAG = 0x4E7B


@dataclass(frozen=True)
class RobustnessPolicy:
    """The sweepable robustness policy set. Every default is the OFF
    spelling; ``RobustnessPolicy()`` is differential-proven identical to the
    plain fixed-trace path."""

    admission_watermark: Optional[int] = None   # queue depth; None = off
    deadline_cycles: Optional[int] = None       # per-attempt; None = off
    max_retries: int = 0                        # client retries; 0 = off
    retry_backoff_cycles: float = 4_096.0
    retry_backoff_factor: float = 2.0
    retry_jitter_frac: float = 0.5
    retry_seed: int = 0
    degrade_mode: Optional[str] = None          # None = off
    degrade_watermark: int = 1                  # queue depth arming degrade
    hot_fraction: float = 0.1                   # hot_rows_only keep fraction
    bypass_keep_tables: float = 0.5             # cache_bypass hot-table frac
    bypass_line_cycles: float = 40.0            # flat DRAM cost per bypassed line

    def __post_init__(self) -> None:
        if self.degrade_mode is not None and self.degrade_mode not in DEGRADE_MODES:
            raise ValueError(
                f"unknown degrade_mode {self.degrade_mode!r}; "
                f"options: {DEGRADE_MODES} or None")
        if self.max_retries < 0:
            raise ValueError("max_retries must be >= 0")

    @property
    def all_off(self) -> bool:
        return (self.admission_watermark is None
                and self.deadline_cycles is None
                and self.max_retries == 0
                and self.degrade_mode is None)

    @property
    def key(self) -> tuple:
        return (
            "policy", self.admission_watermark, self.deadline_cycles,
            int(self.max_retries), float(self.retry_backoff_cycles),
            float(self.retry_backoff_factor), float(self.retry_jitter_frac),
            int(self.retry_seed), self.degrade_mode,
            int(self.degrade_watermark), float(self.hot_fraction),
            float(self.bypass_keep_tables), float(self.bypass_line_cycles),
        )


@dataclass(frozen=True)
class ServingScenario:
    """One sweepable serving scenario: traffic pattern x robustness policy
    x batch geometry. ``sweep(scenarios=[...])`` puts these next to the
    hardware axes."""

    name: str
    traffic: TrafficConfig
    policy: RobustnessPolicy = RobustnessPolicy()
    batch_slots: int = 8

    def __post_init__(self) -> None:
        if self.batch_slots < 1:
            raise ValueError("batch_slots must be >= 1")

    @property
    def key(self) -> tuple:
        return ("scenario", self.name, self.traffic.key, self.policy.key,
                int(self.batch_slots))


def _retry_backoff(policy: RobustnessPolicy, rid: int, attempt: int) -> int:
    """Cycles before retry ``attempt`` (1-based) of request ``rid`` —
    exponential with seeded jitter, deterministic in (seed, rid, attempt)
    and PYTHONHASHSEED-proof (integer-tuple rng seed, the ``core.faults``
    backoff idiom lifted to simulated cycles)."""
    base = policy.retry_backoff_cycles * (
        policy.retry_backoff_factor ** (attempt - 1)
    )
    rng = np.random.default_rng(
        (int(policy.retry_seed), _RETRY_TAG, int(rid), int(attempt))
    )
    return max(1, int(math.ceil(
        base * (1.0 + policy.retry_jitter_frac * float(rng.random()))
    )))


# --------------------------------------------------------------------------
# Service oracles
# --------------------------------------------------------------------------

class _SimOracle:
    """Live oracle: each served batch extends the concat and re-simulates the
    prefix with persistent on-chip state — the last batch's stats are exact
    (prefix-causality of classification + segmented DRAM timing)."""

    def __init__(self, ms, spec: EmbeddingOpSpec):
        self.ms = ms
        self.spec = spec
        self._traces: List[FullTrace] = []

    def service(self, full: FullTrace) -> EmbeddingBatchStats:
        self._traces.append(full)
        et = EmbeddingTrace.from_concat(
            self.spec, ConcatTrace.from_traces(self._traces)
        )
        return self.ms.simulate_embedding(et)[-1]


class ReplayOracle:
    """Replay oracle: substitutes recorded per-batch stats for simulation.

    The scheduler is deterministic given its oracle responses, so replaying
    journaled stats reproduces the original compositions — and therefore
    the original ``ServingResult`` — bitwise. ``finish()`` asserts the log
    was consumed exactly (a composition drift would desynchronize it)."""

    def __init__(self, stats: Sequence[EmbeddingBatchStats]):
        self._stats = list(stats)
        self._pos = 0

    def service(self, full: FullTrace) -> EmbeddingBatchStats:
        if self._pos >= len(self._stats):
            raise RuntimeError(
                "replay oracle exhausted: recorded serving log has "
                f"{len(self._stats)} batches but the scheduler composed more "
                "— the scenario/hardware does not match the recording")
        s = self._stats[self._pos]
        self._pos += 1
        return s

    def finish(self) -> None:
        if self._pos != len(self._stats):
            raise RuntimeError(
                f"replay oracle undrained: {len(self._stats) - self._pos} "
                "recorded batches unused — the scenario/hardware does not "
                "match the recording")


# --------------------------------------------------------------------------
# The scheduler
# --------------------------------------------------------------------------

@dataclass
class _QItem:
    req: Request
    attempt: int        # 0 = first submission
    enqueued: int       # this attempt's arrival cycle
    deadline: Optional[int]


def _service_cycles(stats: EmbeddingBatchStats) -> int:
    """Integer service cycles for timeline arithmetic (ceil of the batch's
    simulated cycles; the raw stats keep full precision for the identity
    surface)."""
    return max(1, int(math.ceil(float(stats.cycles))))


def simulate_serving(
    ms,
    spec: EmbeddingOpSpec,
    scenario: ServingScenario,
    requests: Optional[Sequence[Request]] = None,
    oracle=None,
    event_log: Optional[List[int]] = None,
) -> ServingResult:
    """Run one serving scenario against one memory system; returns the
    ``ServingResult`` (deterministic: same arguments => bitwise-identical
    result, including latency arrays and shed/timeout/retry counts).

    ``requests`` overrides stream generation (the sweep pre-generates one
    stream per scenario and shares it across hardware configs).  ``oracle``
    overrides the service-time source (``ReplayOracle`` for checkpoint
    reconstruction); default is live simulation through ``ms``.
    ``event_log``, when given, receives every value the simulated clock
    takes, in order — the monotonicity regression surface (see the module
    docstring's clock guarantee).
    """
    policy = scenario.policy
    traffic = scenario.traffic
    B = scenario.batch_slots
    if requests is None:
        requests = generate_requests(spec, traffic)
    offered = len(requests)

    hot_rank_limit = None
    bypass_tables = None
    bypass_line_cost = 0.0
    if policy.degrade_mode == "hot_rows_only":
        hot_rank_limit = max(
            1, int(spec.rows_per_table * policy.hot_fraction))
    elif policy.degrade_mode == "cache_bypass":
        bypass_tables = ~hot_table_set(requests, spec,
                                       policy.bypass_keep_tables)
        lines_per_vec = -(-spec.vector_bytes // ms.hw.onchip.line_bytes)
        bypass_line_cost = policy.bypass_line_cycles * lines_per_vec

    # -- all-policies-off fast path: composition is timing-free ------------
    if oracle is None and policy.all_off:
        lowered = [
            lower_batch(requests[i:i + B], spec)
            for i in range(0, offered, B)
        ]
        et = EmbeddingTrace.from_concat(
            spec, ConcatTrace.from_traces([bl.full for bl in lowered])
        )
        oracle = ReplayOracle(ms.simulate_embedding(et))

    if oracle is None:
        oracle = _SimOracle(ms, spec)

    # -- event loop ---------------------------------------------------------
    # Arrival heap entries: (time, seq, qitem-fields). seq breaks time ties
    # deterministically (original submissions before retries scheduled for
    # the same instant keep stream order).
    heap: List[Tuple[int, int, Request, int]] = []
    seq = 0
    for r in requests:
        heap.append((r.arrival, seq, r, 0))
        seq += 1
    heapq.heapify(heap)

    queue: List[_QItem] = []
    server_free = 0
    now = 0

    shed = timed_out = retries = abandoned = 0
    degraded_batches = dropped_rows = bypassed_lookups = 0
    batch_stats: List[EmbeddingBatchStats] = []
    batch_service: List[int] = []
    batch_starts: List[int] = []
    # per completed request (completion order): rid, first arrival, queue
    # delay of the served attempt, service cycles, completion cycle
    completions: List[Tuple[int, int, int, int, int]] = []
    first_arrival: Dict[int, int] = {r.rid: r.arrival for r in requests}
    last_finish = 0

    def fail_attempt(
        item_req: Request, attempt: int, at: int, clock: int, kind: str
    ):
        """Shed/timeout bookkeeping + client retry scheduling.

        ``at`` is when the attempt failed (the deadline for timeouts, the
        arrival for sheds); backoff counts from there. ``clock`` is the
        simulated time at which the failure is being processed — a timeout
        is only *observed* at the prune instant, which can be well past the
        deadline, so the resubmission is clamped to ``clock`` to keep the
        event heap (and thus ``now``) monotone.
        """
        nonlocal shed, timed_out, retries, abandoned, seq
        if kind == "shed":
            shed += 1
        else:
            timed_out += 1
        if attempt < policy.max_retries:
            retries += 1
            back = _retry_backoff(policy, item_req.rid, attempt + 1)
            heapq.heappush(
                heap, (max(at + back, clock), seq, item_req, attempt + 1)
            )
            seq += 1
        else:
            abandoned += 1

    def prune_expired(at: int) -> None:
        if policy.deadline_cycles is None:
            return
        kept: List[_QItem] = []
        for it in queue:
            if it.deadline is not None and it.deadline <= at:
                fail_attempt(it.req, it.attempt, it.deadline, at, "timeout")
            else:
                kept.append(it)
        queue[:] = kept

    while heap or queue:
        can_launch = bool(queue) and (len(queue) >= B or not heap)
        start = max(now, server_free) if can_launch else None
        if can_launch and not (heap and heap[0][0] <= start):
            prune_expired(start)
            if not (queue and (len(queue) >= B or not heap)):
                continue          # timeouts shrank the batch; wait for more
            take, queue[:] = queue[:B], queue[B:]
            degrade = (
                policy.degrade_mode is not None
                and len(queue) >= policy.degrade_watermark
            )
            bl: BatchLowering = lower_batch(
                [it.req for it in take], spec,
                hot_rank_limit=hot_rank_limit if degrade else None,
                bypass_tables=bypass_tables if degrade else None,
            )
            stats = oracle.service(bl.full)
            service = _service_cycles(stats)
            if degrade:
                degraded_batches += 1
                dropped_rows += bl.dropped_cold_rows
                bypassed_lookups += bl.bypassed_lookups
                service += int(math.ceil(
                    bl.bypassed_lookups * bypass_line_cost))
            finish = start + service
            batch_stats.append(stats)
            batch_service.append(service)
            batch_starts.append(start)
            for it in take:
                completions.append((
                    it.req.rid, first_arrival[it.req.rid],
                    start - it.enqueued, service, finish,
                ))
            last_finish = max(last_finish, finish)
            server_free = finish
            now = start
            if event_log is not None:
                event_log.append(now)
        else:
            t_a, _, req, attempt = heapq.heappop(heap)
            now = t_a
            if event_log is not None:
                event_log.append(now)
            prune_expired(now)
            if (policy.admission_watermark is not None
                    and len(queue) >= policy.admission_watermark):
                fail_attempt(req, attempt, now, now, "shed")
                continue
            ddl = (now + policy.deadline_cycles
                   if policy.deadline_cycles is not None else None)
            queue.append(_QItem(req=req, attempt=attempt,
                                enqueued=now, deadline=ddl))

    if isinstance(oracle, ReplayOracle):
        oracle.finish()

    # -- result assembly ----------------------------------------------------
    n_done = len(completions)
    lat = np.empty(n_done, dtype=np.int64)
    qd = np.empty(n_done, dtype=np.int64)
    sv = np.empty(n_done, dtype=np.int64)
    in_deadline = 0
    for i, (rid, arr0, qdelay, service, finish) in enumerate(completions):
        lat[i] = finish - arr0
        qd[i] = qdelay
        sv[i] = service
        if (policy.deadline_cycles is None
                or finish - arr0 <= policy.deadline_cycles):
            in_deadline += 1
    t0 = min((r.arrival for r in requests), default=0)
    makespan = max(last_finish - t0, 1)
    return ServingResult(
        scenario=scenario.name,
        hardware=ms.hw.name,
        policy=ms.hw.onchip.policy.value,
        clock_ghz=float(ms.hw.clock_ghz),
        offered=offered,
        completed=n_done,
        shed=shed,
        timed_out=timed_out,
        retries=retries,
        abandoned=abandoned,
        degraded_batches=degraded_batches,
        dropped_cold_rows=dropped_rows,
        bypassed_lookups=bypassed_lookups,
        num_batches=len(batch_stats),
        makespan_cycles=int(makespan),
        goodput=in_deadline / max(offered, 1),
        latency_cycles=lat,
        queue_cycles=qd,
        service_cycles=sv,
        batch_stats=batch_stats,
        batch_service_cycles=np.asarray(batch_service, dtype=np.int64),
        batch_start_cycles=np.asarray(batch_starts, dtype=np.int64),
    )
