from .engine import ServeConfig, build_prefill, build_serve_step, init_cache, ServingEngine

__all__ = [
    "ServeConfig",
    "build_prefill",
    "build_serve_step",
    "init_cache",
    "ServingEngine",
]
