from .engine import ServeConfig, build_prefill, build_serve_step, init_cache, ServingEngine
from .scheduler import (
    DEGRADE_MODES,
    ReplayOracle,
    RobustnessPolicy,
    ServingScenario,
    simulate_serving,
)

__all__ = [
    "ServeConfig",
    "build_prefill",
    "build_serve_step",
    "init_cache",
    "ServingEngine",
    "DEGRADE_MODES",
    "ReplayOracle",
    "RobustnessPolicy",
    "ServingScenario",
    "simulate_serving",
]
