"""Serving: cache init, prefill/decode step builders, and a small batched
serving engine (continuous-batching-lite: fixed slots, per-slot lengths,
finished slots refilled from a queue).

The decode step for each family:
  * dense / moe / vlm:   GQA or MLA KV cache, one einsum-attention step
  * ssm (mamba2):        O(1) carried state — the long_500k story
  * hybrid (zamba2):     SSM states + KV caches for the shared attn blocks
  * audio (whisper):     decoder self-KV + precomputed encoder output
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..models import family_module
from ..models.config import ArchConfig


@dataclass(frozen=True)
class ServeConfig:
    batch: int
    max_seq: int
    use_pallas: bool = False


def init_cache(cfg: ArchConfig, scfg: ServeConfig):
    mod = family_module(cfg)
    if cfg.family == "ssm":
        return mod.init_state_cache(cfg, scfg.batch)
    if cfg.family == "hybrid":
        return mod.init_state_cache(cfg, scfg.batch, scfg.max_seq)
    if cfg.family == "audio":
        return mod.init_kv_cache(cfg, scfg.batch, scfg.max_seq)
    from ..models import transformer

    return transformer.init_kv_cache(cfg, scfg.batch, scfg.max_seq)


def build_serve_step(cfg: ArchConfig, scfg: ServeConfig) -> Callable:
    """Returns decode_step(params, tokens(B,1), cache_index, caches[, enc_out])."""
    mod = family_module(cfg)

    if cfg.family == "audio":
        def step(params, tokens, cache_index, caches, enc_out):
            # decoder positions are clamped to the learned table (whisper's
            # 4k positions; 32k decode shapes are out-of-spec, DESIGN.md §4)
            return mod.decode_step(
                params, tokens, cache_index % 4096, caches, enc_out, cfg,
                use_pallas=scfg.use_pallas,
            )
        return step

    def step(params, tokens, cache_index, caches):
        return mod.decode_step(
            params, tokens, cache_index, caches, cfg, use_pallas=scfg.use_pallas
        )

    return step


def build_prefill(cfg: ArchConfig, scfg: ServeConfig) -> Callable:
    mod = family_module(cfg)

    if cfg.family == "audio":
        def prefill(params, tokens, caches, enc_out):
            logits, caches = mod.decode_step(
                params, tokens, jnp.int32(0), caches, enc_out, cfg,
                use_pallas=scfg.use_pallas, prefill=True,
            )
            return logits[:, -1:], caches
        return prefill

    if cfg.family == "ssm":
        def prefill(params, tokens, caches):
            # parallel chunked-SSD prompt pass; caches arg ignored (rebuilt)
            return mod.prefill_with_state(params, tokens, cfg, use_pallas=scfg.use_pallas)
        return prefill

    if cfg.family == "hybrid":
        def prefill(params, tokens, caches):
            return mod.prefill_with_state(
                params, tokens, cfg, use_pallas=scfg.use_pallas, max_seq=scfg.max_seq
            )
        return prefill

    from ..models import transformer

    def prefill(params, tokens, caches):
        return transformer.prefill(params, tokens, caches, cfg, use_pallas=scfg.use_pallas)

    return prefill


class ServingEngine:
    """Batched greedy decoding with slot refill (continuous-batching-lite)."""

    def __init__(self, cfg: ArchConfig, params, scfg: ServeConfig):
        self.cfg = cfg
        self.params = params
        self.scfg = scfg
        self.prefill = jax.jit(build_prefill(cfg, scfg))
        self.step = jax.jit(build_serve_step(cfg, scfg))

    def generate(
        self,
        prompts: np.ndarray,        # (B, S_prompt) int32
        max_new_tokens: int = 16,
        enc_out: Optional[jax.Array] = None,
    ) -> np.ndarray:
        B, Sp = prompts.shape
        assert B == self.scfg.batch
        caches = init_cache(self.cfg, self.scfg)
        args = (enc_out,) if self.cfg.family == "audio" else ()
        logits, caches = self.prefill(self.params, jnp.asarray(prompts), caches, *args)
        out = []
        tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
        pos = jnp.int32(Sp)
        for _ in range(max_new_tokens):
            out.append(np.asarray(tok))
            logits, caches = self.step(self.params, tok, pos, caches, *args)
            tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
            pos = pos + 1
        return np.concatenate(out, axis=1)
