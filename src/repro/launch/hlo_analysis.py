"""Post-SPMD HLO static analysis for the roofline.

XLA's ``compiled.cost_analysis()`` counts ``while`` (lax.scan) bodies ONCE
(measured: a 4-step scanned matmul reports 1/4 the FLOPs of its unrolled
equivalent), which would corrupt every scan-over-layers roofline. This module
walks the compiled per-device HLO text instead:

  * per-computation symbol tables resolve operand shapes (HLO operand lists
    carry names, not types),
  * the computation call graph (fusion ``calls=``, while ``body=`` /
    ``condition=``) is evaluated with while bodies multiplied by their trip
    count (``backend_config known_trip_count``; unknown trips counted and
    reported),
  * dot FLOPs computed exactly from result shape x contraction size (dnums),
  * elementwise FLOPs counted 1/element,
  * collective operand bytes per kind (all-gather / all-reduce /
    reduce-scatter / all-to-all / collective-permute),
  * byte traffic: operands+result of computation-scope ops (fusion internals
    are on-chip by construction) — an HBM-traffic estimate, documented as
    such.

Validated against unrolled-vs-scanned equivalence in tests.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s4": 1, "u4": 1,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8,
    "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

COLLECTIVES = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum",
    "exponential", "rsqrt", "sqrt", "tanh", "logistic", "log", "negate",
    "power", "compare", "select",
}

_SHAPE_RE = re.compile(
    r"(pred|s4|u4|s8|u8|s16|u16|bf16|f16|s32|u32|f32|s64|u64|f64|c64|c128|"
    r"f8e4m3fn|f8e5m2)\[([\d,]*)\]"
)
_HEADER_RE = re.compile(r"^\s*(ENTRY\s+)?%?([\w\.\-]+)\s*\((.*)\)\s*->\s*.*\{\s*$")
_LINE_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w\.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\]{},.]+))\s+([\w\-]+)\("
)
_CALLS_RE = re.compile(r"(?:calls|to_apply)=%?([\w\.\-]+)")
_WHILE_RE = re.compile(r"condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_TRIP_RE = re.compile(r'known_trip_count"?\s*[=:]\s*\{\s*\\?"?n\\?"?\s*[=:]\s*\\?"?(\d+)')
_NAME_RE = re.compile(r"%?([\w\.\-]+)")


def _prod(xs) -> int:
    n = 1
    for x in xs:
        n *= x
    return n


def _dims(s: str) -> List[int]:
    return [int(d) for d in s.split(",")] if s else []


def _shapes_in(text: str) -> List[Tuple[str, List[int]]]:
    return [(m.group(1), _dims(m.group(2))) for m in _SHAPE_RE.finditer(text)]


def _shape_bytes_list(shapes) -> int:
    return sum(_prod(d) * _DTYPE_BYTES.get(t, 4) for t, d in shapes)


def _operand_section(line: str, op: str) -> str:
    start = line.index(op + "(") + len(op) + 1
    depth = 1
    i = start
    while i < len(line) and depth:
        if line[i] == "(":
            depth += 1
        elif line[i] == ")":
            depth -= 1
        i += 1
    return line[start : i - 1]


@dataclass
class CompCost:
    flops: float = 0.0
    bytes: float = 0.0
    coll: Dict[str, float] = field(default_factory=lambda: defaultdict(float))
    children: List[Tuple[str, float]] = field(default_factory=list)  # (name, mult)


def parse_hlo(hlo_text: str):
    comps: Dict[str, CompCost] = {}
    cur: Optional[CompCost] = None
    symbols: Dict[str, List[Tuple[str, List[int]]]] = {}
    entry: Optional[str] = None

    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        h = _HEADER_RE.match(line)
        if h:
            name = h.group(2)
            cur = comps.setdefault(name, CompCost())
            symbols = {}
            if h.group(1):
                entry = name
            # parameters: "p1: f32[4,8], p2: (f32[2], s32[])"
            for pm in re.finditer(r"([\w\.\-]+)\s*:\s*((?:\([^)]*\)|[\w\[\],]+))", h.group(3)):
                symbols[pm.group(1)] = _shapes_in(pm.group(2))
            continue
        if cur is None:
            continue
        m = _LINE_RE.match(line)
        if not m:
            continue
        res_name, res_type, op = m.group(1), m.group(2), m.group(3)
        res_shapes = _shapes_in(res_type)
        symbols[res_name] = res_shapes

        def operand_shapes():
            sec = _operand_section(line, op)
            out = []
            for nm in _NAME_RE.finditer(sec):
                s = symbols.get(nm.group(1))
                if s:
                    out.append(s)
            return out

        if op == "while":
            w = _WHILE_RE.search(line)
            t = _TRIP_RE.search(line)
            trip = float(t.group(1)) if t else -1.0
            if w:
                cur.children.append((w.group(2), trip))
                cur.children.append((w.group(1), trip + 1 if trip > 0 else -1.0))
            continue

        if op in ("fusion", "call", "map", "reduce", "reduce-window", "sort",
                  "scatter", "select-and-scatter"):
            c = _CALLS_RE.search(line)
            if c:
                cur.children.append((c.group(1), 1.0))
        if op == "conditional":
            for c in re.finditer(
                r"(?:true_computation|false_computation)=%?([\w\.\-]+)", line
            ):
                cur.children.append((c.group(1), 1.0))
            bm = re.search(r"branch_computations=\{([^}]*)\}", line)
            if bm:
                for nm in _NAME_RE.finditer(bm.group(1)):
                    cur.children.append((nm.group(1), 1.0))

        ops_shapes = None
        if op == "dot":
            ops_shapes = operand_shapes()
            result_elems = _prod(res_shapes[0][1]) if res_shapes else 0
            k = 1
            cm = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", line)
            if ops_shapes and cm and ops_shapes[0]:
                lhs_dims = ops_shapes[0][0][1]
                contracting = _dims(cm.group(1))
                try:
                    k = _prod(lhs_dims[d] for d in contracting) if contracting else 1
                except IndexError:
                    k = 1
            cur.flops += 2.0 * result_elems * k
        elif op == "convolution":
            ops_shapes = operand_shapes()
            if res_shapes and len(ops_shapes) >= 2:
                res = res_shapes[0][1]
                rhs = ops_shapes[1][0][1]
                cur.flops += 2.0 * _prod(res) * max(_prod(rhs) // max(res[-1], 1), 1)
        elif op in _ELEMENTWISE:
            if res_shapes:
                cur.flops += _prod(res_shapes[0][1])

        kind = op.replace("-start", "")
        if kind in COLLECTIVES and not op.endswith("-done"):
            osh = operand_shapes()
            total = sum(_shape_bytes_list(s) for s in osh)
            if total == 0 and res_shapes:       # unresolved operands: use result
                total = _shape_bytes_list(res_shapes)
            cur.coll[kind] += total

        # byte traffic at computation scope (fusion internals excluded)
        if op not in ("tuple", "get-tuple-element", "parameter", "constant",
                      "bitcast", "after-all", "partition-id", "replica-id"):
            if ops_shapes is None:
                ops_shapes = operand_shapes()
            cur.bytes += _shape_bytes_list(res_shapes)
            cur.bytes += sum(_shape_bytes_list(s) for s in ops_shapes)

    return comps, entry


@dataclass
class HloCost:
    flops: float
    bytes: float
    collectives: Dict[str, float]
    unknown_trip_loops: int

    @property
    def collective_bytes(self) -> float:
        return sum(self.collectives.values())


def analyze(hlo_text: str, default_trip: float = 1.0) -> HloCost:
    comps, entry = parse_hlo(hlo_text)
    memo: Dict[str, Tuple[float, float, Dict[str, float]]] = {}
    unknown = [0]

    def total(name: str, stack=()) -> Tuple[float, float, Dict[str, float]]:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return (0.0, 0.0, {})
        c = comps[name]
        f, b = c.flops, c.bytes
        coll = defaultdict(float, c.coll)
        for child, mult in c.children:
            if mult < 0:
                unknown[0] += 1
                mult = default_trip
            cf, cb, cc = total(child, stack + (name,))
            f += mult * cf
            b += mult * cb
            for k, v in cc.items():
                coll[k] += mult * v
        memo[name] = (f, b, dict(coll))
        return memo[name]

    if entry is None:
        return HloCost(0.0, 0.0, {}, 0)
    f, b, coll = total(entry)
    return HloCost(flops=f, bytes=b, collectives=coll, unknown_trip_loops=unknown[0])


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Trip-count-aware collective operand bytes per kind."""
    return {k: int(v) for k, v in analyze(hlo_text).collectives.items()}
