"""Serving launcher: batched greedy decoding on a (smoke) model.

    PYTHONPATH=src python -m repro.launch.serve --arch stablelm_3b --smoke \
        --batch 4 --prompt-len 16 --new-tokens 16
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.models import family_module, get_config, get_smoke_config
from repro.serving import ServeConfig, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    mod = family_module(cfg)
    key = jax.random.PRNGKey(0)
    params = mod.init_model(key, cfg) if cfg.family == "audio" else mod.init_lm(key, cfg)

    scfg = ServeConfig(batch=args.batch, max_seq=args.prompt_len + args.new_tokens + 8)
    engine = ServingEngine(cfg, params, scfg)

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab, size=(args.batch, args.prompt_len), dtype=np.int32
    )
    enc_out = None
    if cfg.family == "audio":
        enc_out = mod.encode(
            params,
            jnp.zeros((args.batch, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16),
            cfg,
        )
    t0 = time.time()
    out = engine.generate(prompts, max_new_tokens=args.new_tokens, enc_out=enc_out)
    dt = time.time() - t0
    total = args.batch * args.new_tokens
    print(f"generated {out.shape} in {dt:.2f}s -> {total/dt:.1f} tok/s")
    print(out[:, :8])


if __name__ == "__main__":
    main()
