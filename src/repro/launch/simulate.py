"""EONSim CLI — run the simulator on a workload.

    PYTHONPATH=src python -m repro.launch.simulate --workload dlrm \
        --tables 60 --rows 1000000 --batch 32 --policy lru
    PYTHONPATH=src python -m repro.launch.simulate --workload lm \
        --arch command_r_plus_104b --shape decode_32k --policy pinning
"""
from __future__ import annotations

import argparse
import json

import numpy as np

from repro.core import OnChipPolicy, dlrm_rmc2_small, simulate, tpuv6e
from repro.core.lm_mapper import lm_workload
from repro.core.trace import REUSE_LEVELS, generate_zipf_trace
from repro.models import SHAPES_BY_NAME, get_config


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--workload", default="dlrm", choices=["dlrm", "lm"])
    ap.add_argument("--policy", default="spm",
                    choices=[p.value for p in OnChipPolicy])
    ap.add_argument("--tables", type=int, default=60)
    ap.add_argument("--rows", type=int, default=1_000_000)
    ap.add_argument("--lookups", type=int, default=120)
    ap.add_argument("--batch", type=int, default=32)
    ap.add_argument("--num-batches", type=int, default=1)
    ap.add_argument("--zipf", type=float, default=REUSE_LEVELS["reuse_mid"])
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--shape", default="decode_32k")
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args()

    hw = tpuv6e().with_policy(OnChipPolicy(args.policy))
    if args.workload == "dlrm":
        wl = dlrm_rmc2_small(
            num_tables=args.tables, rows_per_table=args.rows,
            lookups=args.lookups, batch_size=args.batch,
            num_batches=args.num_batches,
        )
    else:
        cfg = get_config(args.arch)
        wl = lm_workload(cfg, SHAPES_BY_NAME[args.shape], num_batches=args.num_batches)

    res = simulate(wl, hw, zipf_s=args.zipf)
    if args.json:
        print(res.to_json())
    else:
        s = res.summary()
        for k, v in s.items():
            print(f"{k:20s} {v}")


if __name__ == "__main__":
    main()
