"""Production mesh factories.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state): (16, 16) data x model single-pod, (2, 16, 16)
pod x data x model multi-pod — TPU v5e pods of 256 chips.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_degree: int = 1):
    """Whatever this process actually has (CPU tests: 1 device)."""
    n = len(jax.devices())
    data = max(1, n // model_degree)
    return jax.make_mesh((data, min(model_degree, n)), ("data", "model"))
