import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production meshes and record memory / cost / collective analysis.

    PYTHONPATH=src python -m repro.launch.dryrun --all
    PYTHONPATH=src python -m repro.launch.dryrun --arch arctic_480b --shape train_4k --mesh pod

The first two lines above MUST run before any jax import (jax locks the
device count at first init); 512 placeholder CPU devices back both the
(16,16) single-pod and (2,16,16) multi-pod meshes.
"""

import argparse
import dataclasses
import functools
import json
import time
import traceback
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import (
    ARCH_IDS,
    SHAPES_BY_NAME,
    family_module,
    get_config,
    param_count,
    shapes_for,
)
from repro.models.config import ArchConfig, ShapeConfig
from repro.distributed import batch_spec, kv_cache_spec, param_specs, tree_shardings
from repro.distributed.sharding import greedy_spec
from repro.training import AdamWConfig, TrainConfig, build_train_step, init_state
from repro.serving import ServeConfig, build_prefill, build_serve_step, init_cache
from repro.launch.mesh import make_production_mesh
from repro.launch import hlo_analysis

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..", "results", "dryrun")


# --------------------------------------------------------------------------
# per-cell configuration
# --------------------------------------------------------------------------

def _dp_degree(mesh) -> int:
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return axes.get("data", 1) * axes.get("pod", 1)


def pick_microbatches(B: int, S: int, dp: int, target_tokens: int = 4096) -> int:
    """Smallest microbatch count whose per-device-per-microbatch token count
    is <= target, with (B/mb) still divisible by the DP degree."""
    tokens_per_dev = B * S // dp
    cands = [m for m in range(1, B + 1) if B % m == 0 and (B // m) % dp == 0]
    for m in sorted(cands):
        if tokens_per_dev // m <= target_tokens:
            return m
    return max(cands) if cands else 1


def train_config(cfg: ArchConfig, shape: ShapeConfig, mesh) -> TrainConfig:
    dp = _dp_degree(mesh)
    mb = pick_microbatches(shape.global_batch, shape.seq_len, dp)
    quant = param_count(cfg) > 2e11      # 8-bit moments for the 480B arch
    return TrainConfig(
        adamw=AdamWConfig(quantize_state=quant),
        microbatches=mb,
        remat=True,
        loss_chunk=512,
    )


def _arch_for_mesh(cfg: ArchConfig, mesh) -> ArchConfig:
    """Align MoE dispatch groups with the DP degree of the target mesh."""
    if cfg.moe is not None:
        dp = _dp_degree(mesh)
        cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, dispatch_groups=dp))
    return cfg


# --------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins — no allocation)
# --------------------------------------------------------------------------

def sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Model-input stand-ins for one cell (tokens/labels for training, the
    request batch + caches for serving)."""
    B, S = shape.global_batch, shape.seq_len
    if shape.kind == "train":
        out = {"tokens": sds((B, S), jnp.int32), "labels": sds((B, S), jnp.int32)}
        if cfg.family == "audio":
            out["frames"] = sds((B, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
        return out
    if shape.kind == "prefill":
        out = {"tokens": sds((B, S), jnp.int32)}
        if cfg.family == "audio":
            out["enc_out"] = sds((B, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
        return out
    # decode: one new token against a cache of S
    out = {"tokens": sds((B, 1), jnp.int32), "cache_index": sds((), jnp.int32)}
    if cfg.family == "audio":
        out["enc_out"] = sds((B, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
    return out


def serve_cache_specs(cfg: ArchConfig, shape: ShapeConfig, mesh, cache_shapes):
    """PartitionSpecs for the family-specific cache pytree."""
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = ("pod", "data") if "pod" in axes else "data"

    if cfg.family == "ssm":
        ssm_s, conv_s = cache_shapes
        return (
            greedy_spec(ssm_s.shape, mesh, [(1, dp), (2, "model"), (3, "model")]),
            greedy_spec(conv_s.shape, mesh, [(1, dp), (3, "model")]),
        )
    if cfg.family == "hybrid":
        ssm_s, conv_s, (k_s, v_s) = cache_shapes
        kv = greedy_spec(
            k_s.shape, mesh, [(1, dp), (2, "model"), (3, "data"), (4, "model")]
        )
        return (
            greedy_spec(ssm_s.shape, mesh, [(2, dp), (3, "model"), (4, "model")]),
            greedy_spec(conv_s.shape, mesh, [(2, dp), (4, "model")]),
            (kv, kv),
        )
    if cfg.mla is not None:
        lat = cache_shapes
        return greedy_spec(lat.shape, mesh, [(1, dp), (2, "model")])
    # GQA / MQA / audio: (L, B, Hkv, S, dh)
    k_s, v_s = cache_shapes
    kv = greedy_spec(
        k_s.shape, mesh, [(1, dp), (2, "model"), (3, "data"), (4, "model")]
    )
    return (kv, kv)


def logits_spec(cfg: ArchConfig, B: int, mesh):
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = ("pod", "data") if "pod" in axes else "data"
    dp_size = np.prod([axes[a] for a in (dp if isinstance(dp, tuple) else (dp,))])
    b_ax = dp if B % dp_size == 0 else None
    v_ax = "model" if cfg.vocab % axes["model"] == 0 else None
    return P(b_ax, None, v_ax)


# --------------------------------------------------------------------------
# cell builders: (fn, arg_shapes, in_shardings, out_shardings)
# --------------------------------------------------------------------------

def build_train_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    tcfg = train_config(cfg, shape, mesh)
    step_fn = build_train_step(cfg, tcfg)

    key = jax.random.PRNGKey(0)
    state_shapes = jax.eval_shape(functools.partial(init_state, key, cfg, tcfg))
    state_specs = param_specs(state_shapes, mesh)

    binputs = input_specs(cfg, shape)
    bspec = batch_spec(shape, mesh)
    batch_specs_tree = {k: bspec if v.ndim == 2 else P(bspec[0], None, None)
                        for k, v in binputs.items()}

    metrics_shapes = jax.eval_shape(step_fn, state_shapes, binputs)[1]
    metrics_specs = jax.tree.map(lambda _: P(), metrics_shapes)

    in_sh = (tree_shardings(mesh, state_specs), tree_shardings(mesh, batch_specs_tree))
    out_sh = (tree_shardings(mesh, state_specs), tree_shardings(mesh, metrics_specs))
    return step_fn, (state_shapes, binputs), in_sh, out_sh


def build_serve_cell(cfg: ArchConfig, shape: ShapeConfig, mesh):
    B, S = shape.global_batch, shape.seq_len
    scfg = ServeConfig(batch=B, max_seq=S, use_pallas=False)
    mod = family_module(cfg)

    params_shapes = jax.eval_shape(
        functools.partial(
            mod.init_model if cfg.family == "audio" else mod.init_lm,
            jax.random.PRNGKey(0), cfg,
        )
    )
    p_specs = param_specs(params_shapes, mesh)
    cache_shapes = jax.eval_shape(functools.partial(init_cache, cfg, scfg))
    c_specs = serve_cache_specs(cfg, shape, mesh, cache_shapes)
    l_spec = logits_spec(cfg, B, mesh)

    ins = input_specs(cfg, shape)
    axes = dict(zip(mesh.axis_names, mesh.devices.shape))
    dp = ("pod", "data") if "pod" in axes else "data"
    dp_size = int(np.prod([axes[a] for a in (dp if isinstance(dp, tuple) else (dp,))]))
    tok_spec = P(dp if B % dp_size == 0 else None, None)
    enc_spec = P(dp if B % dp_size == 0 else None, None, None)

    if shape.kind == "prefill":
        fn = build_prefill(cfg, scfg)
        args = [params_shapes, ins["tokens"], cache_shapes]
        in_specs = [p_specs, tok_spec, c_specs]
        if cfg.family == "audio":
            args.append(ins["enc_out"])
            in_specs.append(enc_spec)
        out_specs = (l_spec, c_specs)
    else:
        fn = build_serve_step(cfg, scfg)
        args = [params_shapes, ins["tokens"], ins["cache_index"], cache_shapes]
        in_specs = [p_specs, tok_spec, P(), c_specs]
        if cfg.family == "audio":
            args.append(ins["enc_out"])
            in_specs.append(enc_spec)
        out_specs = (l_spec, c_specs)

    in_sh = tuple(tree_shardings(mesh, s) for s in in_specs)
    out_sh = tree_shardings(mesh, out_specs)
    return fn, tuple(args), in_sh, out_sh


# --------------------------------------------------------------------------
# run one cell
# --------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_kind: str, save: bool = True,
             force: bool = False) -> Dict[str, Any]:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    out_path = os.path.join(RESULTS_DIR, f"{arch}__{shape_name}__{mesh_kind}.json")
    if save and not force and os.path.exists(out_path):
        with open(out_path) as f:
            return json.load(f)

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    cfg = _arch_for_mesh(get_config(arch), mesh)
    shape = SHAPES_BY_NAME[shape_name]
    n_chips = int(np.prod(mesh.devices.shape))

    rec: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "mesh_shape": list(mesh.devices.shape), "chips": n_chips,
        "params": param_count(cfg), "active_params": param_count(cfg, True),
        "status": "ok",
    }
    t0 = time.time()
    try:
        # set_mesh (not the bare Mesh context) exposes the abstract mesh at
        # trace time, which distributed.sharding.fsdp_unshard relies on.
        with jax.sharding.set_mesh(mesh):
            if shape.kind == "train":
                fn, args, in_sh, out_sh = build_train_cell(cfg, shape, mesh)
            else:
                fn, args, in_sh, out_sh = build_serve_cell(cfg, shape, mesh)
            jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh)
            lowered = jitted.lower(*args)
            rec["lower_s"] = time.time() - t0
            t1 = time.time()
            compiled = lowered.compile()
            rec["compile_s"] = time.time() - t1

            try:
                ma = compiled.memory_analysis()
                rec["memory"] = {
                    "argument_bytes": getattr(ma, "argument_size_in_bytes", None),
                    "output_bytes": getattr(ma, "output_size_in_bytes", None),
                    "temp_bytes": getattr(ma, "temp_size_in_bytes", None),
                    "generated_code_bytes": getattr(ma, "generated_code_size_in_bytes", None),
                }
                print(f"[{arch}/{shape_name}/{mesh_kind}] memory_analysis:", rec["memory"])
            except Exception as e:  # pragma: no cover
                rec["memory"] = {"error": str(e)}

            try:
                ca = compiled.cost_analysis()
                ca = ca[0] if isinstance(ca, (list, tuple)) else ca
                rec["xla_cost"] = {
                    "flops": float(ca.get("flops", -1)),
                    "bytes_accessed": float(ca.get("bytes accessed", -1)),
                }
                print(f"[{arch}/{shape_name}/{mesh_kind}] cost_analysis:", rec["xla_cost"])
            except Exception as e:  # pragma: no cover
                rec["xla_cost"] = {"error": str(e)}

            txt = compiled.as_text()
            hc = hlo_analysis.analyze(txt)
            rec["hlo"] = {
                "flops_per_device": hc.flops,
                "bytes_per_device": hc.bytes,
                "collective_bytes_per_device": hc.collectives,
                "unknown_trip_loops": hc.unknown_trip_loops,
                "hlo_chars": len(txt),
            }
    except Exception as e:
        rec["status"] = "error"
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
        print(f"[{arch}/{shape_name}/{mesh_kind}] FAILED: {rec['error']}")
    rec["total_s"] = time.time() - t0

    if save:
        with open(out_path, "w") as f:
            json.dump(rec, f, indent=2)
    return rec


def all_cells():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in shapes_for(cfg):
            yield arch, shape.name


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--mesh", default="pod", choices=["pod", "multipod", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--list", action="store_true")
    args = ap.parse_args()

    if args.list:
        for a, s in all_cells():
            print(f"{a} {s}")
        return

    meshes = ["pod", "multipod"] if args.mesh == "both" else [args.mesh]
    cells = list(all_cells()) if args.all else [(args.arch, args.shape)]
    n_ok = n_fail = 0
    for arch, shape in cells:
        for mk in meshes:
            rec = run_cell(arch, shape, mk, force=args.force)
            ok = rec["status"] == "ok"
            n_ok += ok
            n_fail += not ok
            print(
                f"{'OK  ' if ok else 'FAIL'} {arch:24s} {shape:12s} {mk:8s} "
                f"compile={rec.get('compile_s', 0):6.1f}s"
            )
    print(f"\n{n_ok} ok, {n_fail} failed")
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
