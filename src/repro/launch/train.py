"""Training launcher with checkpoint/restart, failure handling, straggler
policy and elastic replanning.

    PYTHONPATH=src python -m repro.launch.train --arch stablelm_3b --smoke \
        --steps 50 --checkpoint-dir /tmp/ckpt --resume auto

On this CPU container it runs reduced configs end-to-end; on a cluster the
same loop runs per host with the production mesh (the mesh/batch plumbing is
identical — devices come from the platform).
"""
from __future__ import annotations

import argparse
import os
import signal
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.data import LMDataConfig, lm_batch
from repro.models import family_module, get_config, get_smoke_config
from repro.runtime import FailureDetector, FaultConfig, StragglerPolicy
from repro.training import AdamWConfig, CompressionConfig, TrainConfig, build_train_step, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="stablelm_3b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compress", default="none", choices=["none", "int8", "topk"])
    ap.add_argument("--quantize-opt", action="store_true")
    ap.add_argument("--checkpoint-dir", default="")
    ap.add_argument("--save-every", type=int, default=20)
    ap.add_argument("--resume", default="none", choices=["none", "auto"])
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=args.lr, warmup_steps=10, total_steps=args.steps,
                          quantize_state=args.quantize_opt),
        compression=CompressionConfig(kind=args.compress),
        microbatches=args.microbatches,
        loss_chunk=min(512, args.seq),
    )

    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(build_train_step(cfg, tcfg))
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)

    mgr = None
    start = 0
    if args.checkpoint_dir:
        mgr = CheckpointManager(CheckpointConfig(directory=args.checkpoint_dir))
        if args.resume == "auto" and mgr.latest_step() is not None:
            start, _, state = mgr.restore(target_tree=state)
            print(f"resumed from step {start}")

    detector = FailureDetector(["host0"], FaultConfig())
    straggler = StragglerPolicy()

    def _save_and_exit(signum, frame):  # preemption: checkpoint then exit
        if mgr is not None:
            mgr.save(int(state["step"]), state)
            mgr.wait()
        raise SystemExit(0)

    signal.signal(signal.SIGTERM, _save_and_exit)

    t_last = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()}
        if cfg.family == "audio":
            batch["frames"] = jnp.zeros(
                (args.batch, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16
            )
        state, metrics = step_fn(state, batch)
        detector.heartbeat("host0")
        dt = time.time() - t_last
        t_last = time.time()
        straggler.observe({"host0": dt})
        if i % args.log_every == 0 or i == args.steps - 1:
            print(
                f"step {i:5d} loss={float(metrics['loss']):.4f} "
                f"lr={float(metrics['lr']):.2e} gnorm={float(metrics['grad_norm']):.2f} "
                f"{dt*1e3:.0f} ms"
            )
        if mgr is not None and (i + 1) % args.save_every == 0:
            mgr.save(i + 1, state)
    if mgr is not None:
        mgr.save(args.steps, state)
        mgr.wait()
    print("done")


if __name__ == "__main__":
    main()
