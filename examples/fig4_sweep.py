"""Paper Fig. 4b/4c policy comparison as ONE DSE sweep call.

The original benchmark (benchmarks/fig4_onchip_policies.py) runs 12
independent ``simulate()`` calls (4 policies x 3 reuse datasets). With the
MemorySystem + sweep engine the whole study is a single ``sweep()`` over the
(policy x reuse-level) grid — traces are generated once per reuse level and
shared by every policy, and the result is bit-exact with the independent
calls.

Run:  PYTHONPATH=src python examples/fig4_sweep.py
"""
from __future__ import annotations

from repro.core import OnChipPolicy, dlrm_rmc2_small, sweep, tpuv6e
from repro.core.trace import REUSE_LEVELS

TABLES, ROWS, BATCH = 8, 250_000, 96
CAPACITY = 4 * 1024 * 1024     # ~5-10% of the accessed-unique bytes (paper regime)


def main() -> None:
    wl = dlrm_rmc2_small(num_tables=TABLES, rows_per_table=ROWS, batch_size=BATCH)
    sr = sweep(
        wl,
        tpuv6e().with_policy(OnChipPolicy.SPM, capacity_bytes=CAPACITY),
        policies=("spm", "lru", "srrip", "pinning"),
        capacities=(CAPACITY,),
        ways=(16,),
        zipf_s=tuple(REUSE_LEVELS.values()),   # reuse_high / mid / low axis
        seed=0,
    )
    level_of_z = {z: name for name, z in REUSE_LEVELS.items()}

    print(f"# Fig. 4 policy case study: {sr.num_configs} configs, "
          f"{sr.wall_seconds:.1f}s in one sweep() call")
    print(f"{'dataset':<12} {'policy':<8} {'speedup_vs_spm':>14} {'onchip_ratio':>13}")
    for row in sr.speedup_over("spm"):
        level = level_of_z[row["zipf_s"]]
        print(f"{level:<12} {row['policy']:<8} "
              f"{row['speedup_vs_spm']:>14.3f} {row['onchip_ratio']:>13.3f}")

    best = sr.best("total_cycles")
    print(f"\nbest config: {best.config.label} "
          f"({best.result.total_cycles:.0f} cycles)")


if __name__ == "__main__":
    main()
