"""NUMA channel affinity: table_hash sharding finally REDUCES contention.

Before the placement layer, `table_hash` lookup sharding only partitioned
work — every core still hit every DRAM channel, so cores' miss bursts
interleaved inside the same banks and buses and the shared-DRAM finish barely
moved. With `channel_affinity="per_core"`, each core's misses route only to
its private channel group; combined with `table_hash` sharding (each table
lives on exactly one core) a table's DRAM traffic stays on its owner's
channels — the TensorDIMM-style placement the ROADMAP called for.

This example sweeps the (channel_affinity x placement) grid over a balanced
all-miss (SPM) DLRM workload — 6 tables hash evenly onto 2 cores — and shows
at least one configuration where `per_core` affinity STRICTLY lowers the
contended embedding cycles vs the `symmetric` baseline (asserted; this is
the PR's acceptance demo).

Run:   PYTHONPATH=src python examples/placement_contention.py [--smoke]
"""
from __future__ import annotations

import sys

from repro.core import OnChipPolicy, dlrm_rmc2_small, sweep, tpuv6e

# 6 tables hash evenly onto 2 cores (3 + 3): per-core DRAM load is balanced,
# so the symmetric-vs-per_core gap is pure contention, not load imbalance.
TABLES, CORES = 6, 2
ZIPF_S = 1.05            # skewed reuse (paper's Reuse-High regime)


def run(smoke: bool = False):
    rows, batch, lookups = (20_000, 32, 8) if smoke else (100_000, 64, 16)
    wl = dlrm_rmc2_small(num_tables=TABLES, rows_per_table=rows,
                         lookups=lookups, batch_size=batch)
    base = tpuv6e().with_policy(OnChipPolicy.SPM).with_cluster(
        CORES, "private", "table_hash")
    sr = sweep(
        wl, base, policies=("spm",), zipf_s=ZIPF_S, seed=0,
        channel_affinities=("symmetric", "per_core", "per_table"),
        placements=("interleave", "table_rank", "hot_replicate"),
    )
    return wl, sr


def main() -> None:
    smoke = "--smoke" in sys.argv
    wl, sr = run(smoke)

    by_cfg = {
        (e.config.channel_affinity, e.config.placement): e.result
        for e in sr.entries
    }
    sym = by_cfg[("symmetric", "interleave")]
    print(f"# NUMA placement vs shared-DRAM contention — {wl.name}, "
          f"{TABLES} tables table_hash-sharded over {CORES} cores, SPM, "
          f"Zipf s={ZIPF_S}")
    print(f"{'affinity':<10} {'placement':<14} {'embed_cycles':>13} "
          f"{'vs_symmetric':>12} {'row_hit_rate':>12}")
    for (aff, plc), r in sorted(by_cfg.items()):
        hits = sum(b.dram_row_hits for b in r.batches)
        total = hits + sum(b.dram_row_misses for b in r.batches)
        print(f"{aff:<10} {plc:<14} {r.embedding_cycles:>13.0f} "
              f"{sym.embedding_cycles / max(r.embedding_cycles, 1e-9):>12.3f} "
              f"{hits / max(total, 1):>12.3f}")

    pc = by_cfg[("per_core", "interleave")]
    gain = sym.embedding_cycles / max(pc.embedding_cycles, 1e-9)
    print(f"\n# per_core affinity + table_hash sharding: {gain:.3f}x lower "
          "contended embedding cycles than symmetric (same traffic, private "
          "channel groups — sharding now reduces contention, not just work)")
    # Acceptance contract: >= 1 config where per_core STRICTLY wins.
    assert pc.embedding_cycles < sym.embedding_cycles, (
        pc.embedding_cycles, sym.embedding_cycles)
    if smoke:
        print("# smoke OK")


if __name__ == "__main__":
    main()
