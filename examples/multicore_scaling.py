"""Core-count scaling for DLRM: shared-LLC vs private per-core on-chip.

Sweeps a DLRM embedding workload across ``num_cores`` under both CoreCluster
topologies at EQUAL TOTAL on-chip silicon — private cores split the budget
(``TOTAL / n`` each) while the shared LLC keeps all of it — over a skewed
(Zipf) index trace. The divergence this reproduces: under skew, private
on-chip memories replicate the same hot vectors in every core (batch-sharded
lookups hit the same hot rows everywhere), so per-core effective capacity
shrinks as cores grow; one shared LLC keeps a single copy of the hot set and
holds its hit rate. Both topologies contend for the same DRAM channels
(``dram_timing_contended``), so the miss-rate gap turns into a cycle gap.

Run:   PYTHONPATH=src python examples/multicore_scaling.py [--smoke]
"""
from __future__ import annotations

import sys

from repro.core import OnChipPolicy, dlrm_rmc2_small, simulate, tpuv6e

CORES = (1, 2, 4, 8)
ZIPF_S = 1.05            # skewed reuse (paper's Reuse-High regime)


def run(smoke: bool = False):
    if smoke:
        tables, rows, batch, lookups, total_cap, cores = 4, 20_000, 16, 8, 1 << 20, (1, 4)
    else:
        tables, rows, batch, lookups, total_cap, cores = 8, 250_000, 64, 32, 8 << 20, CORES
    wl = dlrm_rmc2_small(
        num_tables=tables, rows_per_table=rows, lookups=lookups, batch_size=batch
    )
    base = tpuv6e().with_policy(OnChipPolicy.LRU, ways=16)

    results = {}
    for topo in ("private", "shared"):
        for n in cores:
            cap = total_cap // n if topo == "private" else total_cap
            hw = base.with_onchip(capacity_bytes=cap).with_cluster(n, topo)
            results[(topo, n)] = simulate(wl, hw, seed=0, zipf_s=ZIPF_S)
    return wl, cores, total_cap, results


def main() -> None:
    smoke = "--smoke" in sys.argv
    wl, cores, total_cap, results = run(smoke)

    print(f"# DLRM multi-core scaling — {wl.name}, equal total on-chip "
          f"{total_cap / (1 << 20):g} MB, LRU, Zipf s={ZIPF_S}")
    print(f"{'topology':<9} {'cores':>5} {'embed_cycles':>13} "
          f"{'speedup_vs_1c':>13} {'hit_rate':>9} {'offchip':>10}")
    for topo in ("private", "shared"):
        ref = results[(topo, cores[0])].embedding_cycles
        for n in cores:
            r = results[(topo, n)]
            hr = r.cache_hits / max(r.cache_hits + r.cache_misses, 1)
            print(f"{topo:<9} {n:>5} {r.embedding_cycles:>13.0f} "
                  f"{ref / max(r.embedding_cycles, 1e-9):>13.2f} "
                  f"{hr:>9.3f} {r.offchip_reads:>10}")

    n_max = cores[-1]
    gap = (results[("private", n_max)].embedding_cycles
           / max(results[("shared", n_max)].embedding_cycles, 1e-9))
    print(f"\n# at {n_max} cores, shared LLC is {gap:.2f}x faster on the "
          f"embedding path (private replicates the hot set per core)")
    if smoke:
        # CI smoke contract: both topologies simulated at multi-core, and
        # access totals conserved across the topology axis.
        a = results[("private", n_max)]
        b = results[("shared", n_max)]
        tot = lambda r: r.cache_hits + r.cache_misses
        assert tot(a) == tot(b), (tot(a), tot(b))
        print("# smoke OK")


if __name__ == "__main__":
    main()
