"""Quickstart: simulate DLRM inference on a TPUv6e-class NPU with EONSim and
compare on-chip memory management policies (the paper's core workflow).

    PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import OnChipPolicy, dlrm_rmc2_small, simulate, tpuv6e
from repro.core.trace import REUSE_LEVELS

# A reduced DLRM-RMC2-small (Table I geometry, container-sized scale).
workload = dlrm_rmc2_small(num_tables=8, rows_per_table=250_000, batch_size=64)

print(f"workload: {workload.name}")
print(f"{'policy':10s} {'cycles':>12s} {'ms':>8s} {'on-chip%':>9s} {'hit%':>6s}")
base = None
for policy in OnChipPolicy:
    hw = tpuv6e().with_policy(policy, capacity_bytes=4 * 1024 * 1024)
    res = simulate(workload, hw, seed=0, zipf_s=REUSE_LEVELS["reuse_high"])
    hit = res.cache_hits / max(res.cache_hits + res.cache_misses, 1)
    if policy == OnChipPolicy.SPM:
        base = res.total_cycles
    speed = f"  ({base / res.total_cycles:.2f}x vs SPM)" if base else ""
    print(
        f"{policy.value:10s} {res.total_cycles:12.0f} {res.total_seconds*1e3:8.3f} "
        f"{res.onchip_ratio*100:8.1f}% {hit*100:5.1f}%{speed}"
    )
