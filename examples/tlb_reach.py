"""TLB reach vs embedding-table footprint: the translation-stall figure.

Embedding gathers are the pathological case for NPU address translation
(NeuMMU, arXiv:1911.06859): the page working set of a Zipf-distributed
gather stream routinely exceeds any affordable TLB reach, so every scaled-up
table turns L1 TLB misses into page-table walks on the DRAM critical path.
This study sweeps the ``translations=`` axis over a ladder of TLB sizes for
several table scales and reports, per grid point, the fraction of embedding
cycles lost to translation:

    lost = 1 - cycles(no translation) / cycles(TLB)

One ``sweep()`` call per table scale — translation siblings share one
classification, and the oversized top rung collapses onto the saturated
(first-touch-only) memo key, so the ladder costs barely more than a single
simulation.

Run:   PYTHONPATH=src python examples/tlb_reach.py [--smoke]
"""
from __future__ import annotations

import sys

from repro.core import TranslationConfig, dlrm_rmc2_small, sweep, tpuv6e

# L1 TLB ladder: 4-way, 4KB pages -> reach = entries * 4KB.
TLB_ENTRIES = (16, 64, 256, 1024, 4096)


def run(smoke: bool = False):
    scales = (1_000, 10_000) if smoke else (1_000, 10_000, 100_000)
    batches = 2 if smoke else 8
    base_hw = tpuv6e()
    translations = [None] + [
        TranslationConfig(entries=e, ways=4, page_bytes=4096)
        for e in TLB_ENTRIES
    ]
    results = []
    for rows in scales:
        wl = dlrm_rmc2_small(num_tables=8, rows_per_table=rows, dim=128,
                             lookups=8, batch_size=32, num_batches=batches)
        sr = sweep(wl, base_hw, policies=("lru",),
                   translations=translations, seed=0)
        base = next(e for e in sr.entries if e.config.translation is None)
        for e in sr.entries:
            if e.config.translation is None:
                continue
            lost = 1.0 - base.result.total_cycles / e.result.total_cycles
            results.append(dict(
                rows=rows,
                entries=e.config.translation.entries,
                reach_kb=e.config.translation.reach_bytes // 1024,
                walks=e.result.summary()["tlb_walks"],
                lost=lost,
            ))
    return results


def main() -> None:
    smoke = "--smoke" in sys.argv
    results = run(smoke)

    print("# Embedding cycles lost to address translation vs TLB reach")
    print(f"{'rows/table':>10} {'tlb_entries':>11} {'reach_KB':>9} "
          f"{'walks':>9} {'cycles_lost':>12}")
    for r in results:
        print(f"{r['rows']:>10} {r['entries']:>11} {r['reach_kb']:>9} "
              f"{r['walks']:>9} {r['lost']:>11.1%}")

    # Larger TLBs never lose MORE cycles on the same workload.
    by_rows = {}
    for r in results:
        by_rows.setdefault(r["rows"], []).append(r)
    for rows, rs in by_rows.items():
        rs.sort(key=lambda r: r["entries"])
        for a, b in zip(rs, rs[1:]):
            assert b["walks"] <= a["walks"], (rows, a, b)

    if smoke:
        # CI smoke contract: translation charges showed up, and growing the
        # TLB monotonically recovered cycles.
        assert all(r["walks"] > 0 for r in results)
        assert all(0.0 < r["lost"] < 1.0 for r in results)
        print("# smoke OK")


if __name__ == "__main__":
    main()
