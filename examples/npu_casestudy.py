"""The paper's Fig. 4 case study as a script: on-chip memory management
policies across reuse levels, plus the beyond-paper LM token-embedding study.

    PYTHONPATH=src python examples/npu_casestudy.py
"""
import sys

sys.path.insert(0, ".")

from benchmarks import fig4_onchip_policies, lm_npu_study

print("== Fig 4a: EONSim cache vs ChampSim-semantics golden ==")
for r in fig4_onchip_policies.run_fig4a():
    print(f"  {r['dataset']:12s} {r['policy']:6s} identical={r['identical']} "
          f"(hits {r['sim_hits']} vs {r['champ_hits']})")

print("\n== Fig 4b/4c: policy speedups over SPM ==")
for r in fig4_onchip_policies.run_fig4bc():
    print(f"  {r['dataset']:12s} {r['policy']:8s} speedup={r['speedup_vs_spm']:.2f}x "
          f"on-chip={r['onchip_ratio']:.3f}")

print("\n== Beyond-paper: LM token-embedding traffic (decode_32k) ==")
for r in lm_npu_study.run():
    print(f"  {r['arch']:24s} {r['policy']:8s} "
          f"embed_speedup={r['embed_speedup_vs_spm']:.2f}x "
          f"on-chip={r['onchip_ratio']:.3f}")
