"""Serve DLRM with batched requests, running the real model (Pallas
embedding-bag kernels, incl. the hot-pinned VMEM path) NEXT TO the EONSim
prediction for the same trace — the simulator/runtime pairing the framework
is built around.

    PYTHONPATH=src python examples/dlrm_serve.py
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import OnChipPolicy, dlrm_rmc2_small, simulate, tpuv6e
from repro.core.trace import REUSE_LEVELS
from repro.data.dlrm_data import DLRMDataConfig, dlrm_batch
from repro.kernels import ops
from repro.models import dlrm

CFG = dlrm.DLRMConfig(num_tables=4, rows_per_table=5000, dim=64,
                      lookups_per_table=16,
                      bottom_mlp=(128, 64), top_mlp=(64, 1))

params = dlrm.init(jax.random.PRNGKey(0), CFG)
dcfg = DLRMDataConfig(num_tables=CFG.num_tables, rows_per_table=CFG.rows_per_table,
                      lookups_per_table=CFG.lookups_per_table, batch_size=32,
                      zipf_s=REUSE_LEVELS["reuse_high"])

# --- real model serving: plain vs hot-pinned embedding path ----------------
batch = dlrm_batch(dcfg, 0)
dense = jnp.asarray(batch["dense"])
sparse = jnp.asarray(batch["sparse"])

scores_plain = dlrm.forward(params, dense, sparse, CFG, use_pallas=True)

# profile hot rows (as the paper's Profiling policy would) and pin them
glob = (np.arange(CFG.num_tables)[None, :, None] * CFG.rows_per_table
        + batch["sparse"]).reshape(-1)
uniq, counts = np.unique(glob, return_counts=True)
hot_ids = np.sort(uniq[np.argsort(-counts)][:256]).astype(np.int64)
pos, mask = ops.split_hot_cold(batch["sparse"], hot_ids, CFG.rows_per_table)
pinned = {
    "hot_table": params["tables"][jnp.asarray(hot_ids)],
    "positions": jnp.asarray(pos),
    "mask": jnp.asarray(mask),
}
scores_pinned = dlrm.forward(params, dense, sparse, CFG, use_pallas=True,
                             pinned=pinned)
print("plain vs pinned max diff:",
      float(jnp.max(jnp.abs(scores_plain - scores_pinned))))
print("hot fraction of lookups:", float(mask.mean()))

# --- EONSim prediction for the same configuration ---------------------------
wl = dlrm_rmc2_small(num_tables=CFG.num_tables, rows_per_table=CFG.rows_per_table,
                     dim=CFG.dim, lookups=CFG.lookups_per_table, batch_size=32)
for policy in (OnChipPolicy.SPM, OnChipPolicy.PINNING):
    hw = tpuv6e().with_policy(policy, capacity_bytes=256 * 1024)
    res = simulate(wl, hw, seed=0, zipf_s=dcfg.zipf_s)
    print(f"EONSim[{policy.value:8s}]: {res.total_cycles:10.0f} cycles, "
          f"on-chip ratio {res.onchip_ratio:.3f}")
