"""Serve DLRM under a request-arrival stream: the real model (Pallas
embedding-bag kernels, incl. the hot-pinned VMEM path) runs one admitted
batch for correctness, then the EONSim request-level serving simulator
drives the same configuration closed-loop — Poisson arrivals, continuous
batching, robustness policies — and prints the latency distribution.

    PYTHONPATH=src python examples/dlrm_serve.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    OnChipPolicy,
    TrafficConfig,
    tpuv6e,
)
from repro.core.memory.system import MultiCoreMemorySystem
from repro.core.requests import generate_requests, lower_batch
from repro.core.trace import REUSE_LEVELS
from repro.core.workload import EmbeddingOpSpec
from repro.data.dlrm_data import DLRMDataConfig, dlrm_batch
from repro.kernels import ops
from repro.models import dlrm
from repro.serving import RobustnessPolicy, ServingScenario, simulate_serving

CFG = dlrm.DLRMConfig(num_tables=4, rows_per_table=5000, dim=64,
                      lookups_per_table=16,
                      bottom_mlp=(128, 64), top_mlp=(64, 1))
SPEC = EmbeddingOpSpec(num_tables=CFG.num_tables,
                       rows_per_table=CFG.rows_per_table, dim=CFG.dim,
                       lookups_per_sample=CFG.lookups_per_table,
                       dtype_bytes=4)

params = dlrm.init(jax.random.PRNGKey(0), CFG)
dcfg = DLRMDataConfig(num_tables=CFG.num_tables, rows_per_table=CFG.rows_per_table,
                      lookups_per_table=CFG.lookups_per_table, batch_size=32,
                      zipf_s=REUSE_LEVELS["reuse_high"])

# --- real model serving: plain vs hot-pinned embedding path ----------------
batch = dlrm_batch(dcfg, 0)
dense = jnp.asarray(batch["dense"])
sparse = jnp.asarray(batch["sparse"])

scores_plain = dlrm.forward(params, dense, sparse, CFG, use_pallas=True)

# profile hot rows (as the paper's Profiling policy would) and pin them
glob = (np.arange(CFG.num_tables)[None, :, None] * CFG.rows_per_table
        + batch["sparse"]).reshape(-1)
uniq, counts = np.unique(glob, return_counts=True)
hot_ids = np.sort(uniq[np.argsort(-counts)][:256]).astype(np.int64)
pos, mask = ops.split_hot_cold(batch["sparse"], hot_ids, CFG.rows_per_table)
pinned = {
    "hot_table": params["tables"][jnp.asarray(hot_ids)],
    "positions": jnp.asarray(pos),
    "mask": jnp.asarray(mask),
}
scores_pinned = dlrm.forward(params, dense, sparse, CFG, use_pallas=True,
                             pinned=pinned)
print("plain vs pinned max diff:",
      float(jnp.max(jnp.abs(scores_plain - scores_pinned))))
print("hot fraction of lookups:", float(mask.mean()))

# --- request-level serving simulation ---------------------------------------
# A seeded Poisson request stream with popularity drift, served closed-loop:
# continuous batching over the simulated memory system, once per on-chip
# policy, steady-state and overload-with-robustness-policies side by side.
TRAFFIC = {
    "steady": TrafficConfig(pattern="poisson", mean_gap_cycles=3_000.0,
                            num_requests=128, seed=42,
                            zipf_s=dcfg.zipf_s, zipf_drift=0.3,
                            drift_period=32),
    "overload": TrafficConfig(pattern="bursty", mean_gap_cycles=120.0,
                              num_requests=128, seed=42, burst_len=16,
                              zipf_s=dcfg.zipf_s),
}
ROBUST = RobustnessPolicy(admission_watermark=24, deadline_cycles=2_000_000,
                          max_retries=1, degrade_mode="hot_rows_only",
                          degrade_watermark=12, hot_fraction=0.1)
SCENARIOS = [
    ServingScenario(name="steady", traffic=TRAFFIC["steady"], batch_slots=8),
    ServingScenario(name="overload+robust", traffic=TRAFFIC["overload"],
                    policy=ROBUST, batch_slots=8),
]

for policy in (OnChipPolicy.SPM, OnChipPolicy.PINNING):
    hw = tpuv6e().with_policy(policy, capacity_bytes=256 * 1024)
    ms = MultiCoreMemorySystem.from_hardware(hw)
    print(f"\n=== EONSim serving [{policy.value}] ===")
    for sc in SCENARIOS:
        res = simulate_serving(ms, SPEC, sc)
        us = res.cycles_to_us
        print(f"[{sc.name:16s}] offered {res.offered:4d}  "
              f"completed {res.completed:4d}  shed {res.shed:3d}  "
              f"timeout {res.timed_out:3d}  retries {res.retries:3d}  "
              f"degraded batches {res.degraded_batches:3d}")
        print(f"{'':18s} latency p50/p95/p99 "
              f"{us(res.p50_cycles):8.1f}/{us(res.p95_cycles):8.1f}/"
              f"{us(res.p99_cycles):8.1f} us   "
              f"queue/service {us(res.mean_queue_cycles):7.1f}/"
              f"{us(res.mean_service_cycles):7.1f} us")
        print(f"{'':18s} sustained {res.sustained_qps:,.0f} req/s   "
              f"goodput {res.goodput:.3f}")
        # latency histogram over completed requests
        if res.latency_cycles.size:
            edges = np.percentile(res.latency_cycles,
                                  [0, 25, 50, 75, 90, 99, 100])
            counts, _ = np.histogram(res.latency_cycles, bins=np.unique(edges))
            bars = " ".join(f"{int(c):3d}" for c in counts)
            print(f"{'':18s} latency histogram (p0..p100 bins): {bars}")
