"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps on
the synthetic pipeline, with checkpointing and restart.

    PYTHONPATH=src python examples/train_100m.py --steps 300
    PYTHONPATH=src python examples/train_100m.py --steps 30      # quick look

Loss should fall from ~10.4 (ln 32768 ~ uniform) toward the phrase-structure
entropy of the synthetic stream (< 3) within a few hundred steps.
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.data import LMDataConfig, lm_batch
from repro.models import family_module, get_smoke_config, param_count
from repro.training import AdamWConfig, TrainConfig, build_train_step, init_state


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--checkpoint-dir", default="/tmp/repro_100m_ckpt")
    args = ap.parse_args()

    # ~100M params: stablelm family at d=640, 10 layers, 32k vocab
    cfg = get_smoke_config("stablelm_3b").replace(
        d_model=640, n_heads=10, n_kv_heads=10, head_dim=64,
        d_ff=1728, n_layers=10, vocab=32768,
    )
    n = param_count(cfg)
    print(f"training {cfg.name}-derived LM: {n/1e6:.0f}M params")

    tcfg = TrainConfig(
        adamw=AdamWConfig(lr=6e-4, warmup_steps=30, total_steps=args.steps),
        loss_chunk=64,
    )
    state = init_state(jax.random.PRNGKey(0), cfg, tcfg)
    step_fn = jax.jit(build_train_step(cfg, tcfg))
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=args.seq, global_batch=args.batch)
    mgr = CheckpointManager(CheckpointConfig(directory=args.checkpoint_dir))

    start = 0
    if mgr.latest_step() is not None:
        start, _, state = mgr.restore(target_tree=state)
        print(f"resumed from step {start}")

    t0 = time.time()
    for i in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()}
        state, m = step_fn(state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            print(f"step {i:4d} loss={float(m['loss']):.3f} "
                  f"({(time.time()-t0)/(i-start+1):.1f}s/step)")
        if (i + 1) % 50 == 0:
            mgr.save(i + 1, state)
    mgr.save(args.steps, state)
    mgr.wait()
    print("done; checkpoints in", args.checkpoint_dir)


if __name__ == "__main__":
    main()
