"""Beyond-paper case study: DRAM channel-interleave granularity vs embedding
gather throughput.

EONSim exposes the controller's interleave granularity as a config knob
(hardware.OffChipMemory.interleave_bytes). Fine interleave (64 B) spreads a
512 B embedding vector across 8 channels — 8 row activates per vector; coarse
interleave (>=512 B) keeps the vector in ONE row — 1 activate + streamed
bursts. The sweep quantifies the trade: coarse wins for vector gathers until
it starts serializing on single channels (load imbalance at very coarse
granularity). Exactly the kind of next-generation-NPU design question the
paper positions EONSim for.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List

import numpy as np

from repro.core import tpuv6e
from repro.core.memory.dram import DramModel, simulate_dram
from repro.core.trace import generate_zipf_trace


def run() -> List[Dict]:
    rows = []
    rng = np.random.default_rng(0)
    # 20k random 512 B vector gathers (8 lines each)
    v = generate_zipf_trace(20_000, 1_000_000, 1.0, seed=1)
    lines = (v[:, None] * 8 + np.arange(8)[None, :]).reshape(-1)

    base_cycles = None
    for interleave in (64, 128, 256, 512, 1024, 2048):
        hw = tpuv6e()
        hw = hw.replace(offchip=dataclasses.replace(hw.offchip,
                                                    interleave_bytes=interleave))
        dm = DramModel.from_hardware(hw)
        d = simulate_dram(lines, dm)
        if base_cycles is None:
            base_cycles = d.finish_cycle
        gbps = lines.size * 64 / hw.cycles_to_seconds(d.finish_cycle) / 1e9
        rows.append({
            "interleave_bytes": interleave,
            "finish_cycles": d.finish_cycle,
            "row_hit_rate": d.row_hit_rate,
            "achieved_gbps": gbps,
            "speedup_vs_64B": base_cycles / d.finish_cycle,
        })
    return rows
