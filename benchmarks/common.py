"""Shared benchmark plumbing."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")


def save_rows(name: str, rows: List[Dict]) -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    with open(path, "w") as f:
        json.dump(rows, f, indent=2)
    return path


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeat
    return out, dt * 1e6  # us
