"""Shared benchmark plumbing."""
from __future__ import annotations

import json
import os
import time
from typing import Callable, Dict, List

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "bench")
REPO_ROOT = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def save_rows(name: str, rows: List[Dict], repo_root: bool = False) -> str:
    """Save benchmark rows under results/bench/ (gitignored).

    ``repo_root=True`` additionally writes ``<repo>/<name>.json`` — the
    checked-in copy that tracks the perf trajectory across PRs (and that CI
    uploads per run).
    """
    os.makedirs(RESULTS_DIR, exist_ok=True)
    path = os.path.join(RESULTS_DIR, f"{name}.json")
    text = json.dumps(rows, indent=2)
    with open(path, "w") as f:
        f.write(text)
    if repo_root:
        with open(os.path.join(REPO_ROOT, f"{name}.json"), "w") as f:
            f.write(text)
    return path


def timed(fn: Callable, *args, repeat: int = 1, **kw):
    t0 = time.time()
    out = None
    for _ in range(repeat):
        out = fn(*args, **kw)
    dt = (time.time() - t0) / repeat
    return out, dt * 1e6  # us
