"""Roofline analysis from the dry-run artifacts (brief deliverable (g)).

Reads results/dryrun/<arch>__<shape>__<mesh>.json (produced by
``python -m repro.launch.dryrun --all``) and derives per cell:

    compute term    = HLO_FLOPs_per_device / peak_FLOP/s_per_chip
    memory term     = HLO_bytes_per_device / HBM_bw_per_chip
    collective term = collective_bytes_per_device / ICI_link_bw

(per-device numerators == the brief's global/chips formulation). HLO terms
come from the trip-count-aware static analyzer (launch/hlo_analysis.py);
XLA's own cost_analysis undercounts lax.scan bodies and is reported alongside
for reference.

MODEL_FLOPS = 6·N_active·tokens (train) or 2·N_active·tokens (inference);
the useful-compute ratio MODEL_FLOPS / HLO_FLOPs flags remat/dispatch waste.
"""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List, Optional

from repro.core.hardware import V5E_HBM_BW, V5E_ICI_BW, V5E_PEAK_BF16_FLOPS
from repro.models import SHAPES_BY_NAME

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def analytic_hbm_bytes(rec: Dict) -> float:
    """Per-device HBM traffic model for one step of this cell.

    Derived from the compiled cell's structure (sharding layout, microbatch
    count, remat policy) with an explicit traffic model — op-granular byte
    counts from the weakly-fused CPU module systematically overcount what a
    fused TPU module moves through HBM (EXPERIMENTS.md §Roofline method):

      * weights: per microbatch, the FSDP all-gather materializes the TP
        shard (2N/model_deg bytes): 1 write + reads for fwd, dgrad, wgrad,
        and the remat re-forward (train) => 5x; inference: 1 write + 1 read;
      * activations (train): ~6x L x tokens_dev x d_model x 2B — layer-
        boundary saves (fwd write, bwd read) + remat recompute traffic;
      * optimizer: params + moments read/write once per step (int8 moments
        for the quantized archs);
      * KV cache: decode reads the whole per-device cache per step, prefill
        writes it once;
      * logits/CE: chunked, vocab-sharded (3 passes with recompute).
    """
    from repro.models import get_config, param_count as _pc
    from repro.models.registry import normalize

    cfg = get_config(rec["arch"])
    shape = SHAPES_BY_NAME[rec["shape"]]
    chips = rec["chips"]
    mesh_shape = rec["mesh_shape"]
    model_deg = mesh_shape[-1]
    dp = chips // model_deg
    N = rec["params"]
    B, S = shape.global_batch, shape.seq_len
    d = cfg.d_model
    L = cfg.n_layers
    vocab_dev = cfg.vocab / model_deg if cfg.vocab % model_deg == 0 else cfg.vocab

    w_dev = 2.0 * N / model_deg
    quant = N > 2e11

    def kv_bytes_total() -> float:
        if cfg.family == "ssm":
            ssm = cfg.ssm
            H = ssm.num_heads(d)
            return B * (H * ssm.head_dim * ssm.state_dim * 4 + 3 * (2 * d + 2 * ssm.state_dim) * 2) * L
        if cfg.family == "hybrid":
            n_apps = L // cfg.hybrid.attn_every
            ssm = cfg.ssm
            H = ssm.num_heads(d)
            ssm_b = B * L * H * ssm.head_dim * ssm.state_dim * 4
            kv_b = 2 * n_apps * B * cfg.n_kv_heads * S * cfg.attn_head_dim * 2
            return ssm_b + kv_b
        if cfg.mla is not None:
            return B * S * (cfg.mla.kv_lora_rank + cfg.mla.qk_rope_head_dim) * 2 * L
        return 2 * L * B * cfg.n_kv_heads * S * cfg.attn_head_dim * 2

    if shape.kind == "train":
        from repro.launch.dryrun import pick_microbatches

        mb = pick_microbatches(B, S, dp)
        tokens_dev = B * S / dp
        weights = 5.0 * w_dev * mb
        acts = 6.0 * L * tokens_dev * d * 2.0
        mom = 2 if quant else 8
        optim = (N / chips) * (2 * 2 + mom)      # param r/w (bf16) + moments
        logits = 3.0 * tokens_dev * vocab_dev * 2.0
        return weights + acts + optim + logits
    if shape.kind == "prefill":
        tokens_dev = B * S / dp
        return 3.0 * w_dev + 2.0 * L * tokens_dev * d * 2.0 + kv_bytes_total() / chips
    # decode
    return 2.0 * w_dev + kv_bytes_total() / chips + (B / dp) * vocab_dev * 2.0

_MITIGATION = {
    "compute": "raise MXU efficiency: bigger microbatches, fewer remat "
               "recomputes, fuse small projections",
    "memory": "cut HBM traffic: better fusion/layout, keep KV/activations "
              "bf16, shard the dominant resident tensor further",
    "collective": "reshard to shrink the dominant collective or overlap it "
                  "(ring collective-matmul, all-gather->reduce-scatter swap)",
}


def model_flops_per_device(rec: Dict) -> float:
    shape = SHAPES_BY_NAME[rec["shape"]]
    n_active = rec["active_params"]
    chips = rec["chips"]
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    return 2.0 * n_active * shape.global_batch / chips  # decode: 1 new token


def analyze_record(rec: Dict) -> Optional[Dict]:
    if rec.get("status") != "ok" or "hlo" not in rec:
        return None
    h = rec["hlo"]
    f = h["flops_per_device"]
    coll = sum(h["collective_bytes_per_device"].values())
    # Memory term: XLA's bytes-accessed (post-fusion, so on-chip elementwise
    # chains don't count as HBM traffic) corrected for the scan-body
    # undercount by the flops ratio (hlo_flops counts trips, xla_flops does
    # not; loop bodies dominate both). The analyzer's op-level byte sum is
    # kept as an upper bound in `bytes_upper_bound`.
    xla = rec.get("xla_cost", {})
    xla_b = xla.get("bytes_accessed") or 0
    xla_f = xla.get("flops") or 0
    b_upper = xla_b * max(1.0, f / xla_f) if (xla_b > 0 and xla_f > 0) else h["bytes_per_device"]
    b = analytic_hbm_bytes(rec)
    t_comp = f / V5E_PEAK_BF16_FLOPS
    t_mem = b / V5E_HBM_BW
    t_coll = coll / V5E_ICI_BW
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    bottleneck = max(terms, key=terms.get)
    mf = model_flops_per_device(rec)
    step = max(terms.values())
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "chips": rec["chips"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "bottleneck": bottleneck,
        "model_flops_per_dev": mf,
        "hlo_flops_per_dev": f,
        "hbm_bytes_per_dev": b,
        "t_memory_xla_corrected_s": b_upper / V5E_HBM_BW,
        "bytes_upper_bound": h["bytes_per_device"],
        "useful_ratio": mf / f if f else 0.0,
        "mfu_projected": (mf / V5E_PEAK_BF16_FLOPS) / step if step else 0.0,
        "collectives": h["collective_bytes_per_device"],
        "mitigation": _MITIGATION[bottleneck],
        "memory_analysis": rec.get("memory"),
        "xla_flops_per_dev": rec.get("xla_cost", {}).get("flops"),
    }


def load_all(mesh: str = "pod") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(DRYRUN_DIR, f"*__{mesh}.json"))):
        with open(path) as f:
            rec = json.load(f)
        r = analyze_record(rec)
        if r:
            rows.append(r)
    return rows


def run() -> List[Dict]:
    rows = load_all("pod")
    if not rows:
        return [{"note": "no dryrun artifacts found — run "
                         "`python -m repro.launch.dryrun --all` first"}]
    return rows


def markdown_table(rows: List[Dict]) -> str:
    hdr = ("| arch | shape | t_comp (ms) | t_mem (ms) | t_coll (ms) | "
           "bottleneck | useful | MFU proj |\n|---|---|---|---|---|---|---|---|")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"{r['bottleneck']} | {r['useful_ratio']:.2f} | "
            f"{r['mfu_projected']*100:.1f}% |"
        )
    return "\n".join(lines)


if __name__ == "__main__":
    rows = run()
    print(markdown_table(rows))
