"""Paper Fig. 3a/3b/3c — DLRM inference validation.

The paper compares EONSim against measured TPUv6e runs while sweeping the
number of embedding tables (30-60) and the batch size (32-2048), and
validates on-chip/off-chip access counts. Offline we compare against:

  * the event-granular sequential reference (golden_dram — the TPUv6e proxy,
    DESIGN.md §6) for execution time, and
  * the closed-form analytic counts for memory accesses (exact for SPM).

We additionally report the closed-form ORACLE time gap — large (tens of %),
which is the paper's core thesis: analytical models miss data-dependent
memory behavior; detailed memory simulation is required.

Scale note: rows/table reduced 1M -> 250k and max batch 2048 -> 512 to keep
the pure-Python reference tractable on this container; the simulated
configuration is otherwise Table I.
"""
from __future__ import annotations

import time
from typing import Dict, List

import numpy as np

from repro.core import dlrm_rmc2_small, simulate, tpuv6e
from repro.core.memory.dram import DramModel
from repro.core.memory.golden_dram import golden_dram
from repro.core.oracle import oracle_run
from repro.core.trace import expand_trace, generate_zipf_trace, translate

ROWS = 250_000
ZIPF = 0.8


def _reference_cycles(wl, hw, seed=0) -> float:
    """TPUv6e-proxy: sequential event-granular DRAM reference on the same
    trace (SPM: every line goes off-chip) + the same overlap model."""
    spec = wl.embedding_ops[0]
    n_acc = spec.lookups_per_batch(wl.batch_size)
    it = generate_zipf_trace(n_acc, spec.rows_per_table, s=ZIPF, seed=seed)
    full = expand_trace(it, spec, wl.batch_size, seed=seed)
    at = translate(full, spec, hw.onchip.line_bytes)
    dm = DramModel.from_hardware(hw)
    d = golden_dram(at.lines, dm)
    onchip_bw = hw.onchip.read_bw_bytes_per_cycle
    onchip = len(at) * hw.onchip.line_bytes / onchip_bw + hw.onchip.latency_cycles
    vec = spec.reduction_flops(wl.batch_size) / hw.vector_unit.throughput
    emb = max(d.finish_cycle, onchip, vec)
    from repro.core.matrix_model import simulate_matrix_op

    mat = sum(simulate_matrix_op(op, hw).total_cycles for op in wl.matrix_ops)
    return emb + mat


def run() -> List[Dict]:
    hw = tpuv6e()
    rows: List[Dict] = []

    # Fig 3a: table sweep at batch 32
    for tables in (30, 40, 50, 60):
        wl = dlrm_rmc2_small(num_tables=tables, rows_per_table=ROWS, batch_size=32)
        t0 = time.time()
        res = simulate(wl, hw, seed=0, zipf_s=ZIPF)
        sim_us = (time.time() - t0) * 1e6
        ref = _reference_cycles(wl, hw)
        orc = oracle_run(wl, hw)
        rows.append({
            "figure": "3a", "tables": tables, "batch": 32,
            "sim_cycles": res.total_cycles, "ref_cycles": ref,
            "oracle_cycles": orc.total_cycles,
            "time_err_pct": 100 * abs(res.total_cycles - ref) / ref,
            "oracle_gap_pct": 100 * abs(res.total_cycles - orc.total_cycles)
            / orc.total_cycles,
            "sim_wall_us": sim_us,
        })

    # Fig 3b: batch sweep at 16 tables (runtime-bounded, see module docstring)
    for batch in (32, 64, 128, 256, 512):
        wl = dlrm_rmc2_small(num_tables=16, rows_per_table=ROWS, batch_size=batch)
        t0 = time.time()
        res = simulate(wl, hw, seed=0, zipf_s=ZIPF)
        sim_us = (time.time() - t0) * 1e6
        ref = _reference_cycles(wl, hw)
        rows.append({
            "figure": "3b", "tables": 16, "batch": batch,
            "sim_cycles": res.total_cycles, "ref_cycles": ref,
            "time_err_pct": 100 * abs(res.total_cycles - ref) / ref,
            "sim_wall_us": sim_us,
        })

    # Fig 3c: access counts vs analytic (exact expectation under SPM)
    for tables, batch in ((30, 32), (60, 32), (16, 256)):
        wl = dlrm_rmc2_small(num_tables=tables, rows_per_table=ROWS, batch_size=batch)
        res = simulate(wl, hw, seed=0, zipf_s=ZIPF)
        orc = oracle_run(wl, hw)
        rows.append({
            "figure": "3c", "tables": tables, "batch": batch,
            "sim_onchip": res.onchip_accesses, "ref_onchip": orc.onchip_accesses,
            "sim_offchip": res.offchip_reads, "ref_offchip": orc.offchip_accesses,
            "onchip_err_pct": 100 * abs(res.onchip_accesses - orc.onchip_accesses)
            / orc.onchip_accesses,
            "offchip_err_pct": 100 * abs(res.offchip_reads - orc.offchip_accesses)
            / orc.offchip_accesses,
        })
    return rows
