"""DSE sweep benchmark: grid evaluation throughput + sharing speedup.

Runs a (policy x capacity x ways) grid through ``sweep()`` in one pass, then
times a sample of the same configs as independent ``simulate()`` calls to
measure the benefit of sharing traces / matrix results / compiled scans, and
re-times the sweep with ``batch_scans=False`` to isolate the vmapped
same-policy scan-batching win. Emits one ``kind=perf`` record plus one row
per grid point, saved BOTH under results/bench/ and as BENCH_sweep.json at
the repo root — the root copy is checked in (and uploaded by CI every run)
so the per-config perf trajectory is tracked across PRs.

``--profile`` re-times the sweep inside a stage-profiling session
(``repro.core.profiling``) and adds a per-stage wall-time breakdown to the
perf record — trace gen / classify / cache scan / DRAM / host sync — so the
next perf PR starts from data instead of guesses.

A separate NUMA placement-axes slice (channel_affinity x placement on a
2-core table_hash cluster) is timed into ``placement_per_config_ms`` without
touching the historical perf-gate grid.
"""
from __future__ import annotations

import time
from typing import Dict, List

from repro.core import OnChipPolicy, dlrm_rmc2_small, simulate, sweep, tpuv6e
from repro.core import profiling

TABLES, ROWS, BATCH = 4, 100_000, 48
POLICIES = ("spm", "lru", "srrip", "pinning")
CAPACITIES = (1 << 20, 4 << 20, 16 << 20)
WAYS = (8, 16)
ZIPF = 1.0
N_INDEPENDENT_SAMPLE = 6

# The placement-axes slice grid: shared with scripts/perf_smoke.py (imported,
# not copied, so the ratio gate measures exactly what the benchmark reports).
PLACEMENT_TABLES = 6
PLACEMENT_AXES = dict(
    policies=("spm", "lru"), zipf_s=ZIPF, seed=0,
    channel_affinities=("symmetric", "per_core", "per_table"),
    placements=("interleave", "table_rank", "hot_replicate"),
)


def run(profile: bool = False) -> List[Dict]:
    wl = dlrm_rmc2_small(num_tables=TABLES, rows_per_table=ROWS, batch_size=BATCH,
                         num_batches=2)
    base_hw = tpuv6e()

    # Warm pass compiles every scan shape; the timed pass measures steady state
    # (the regime a DSE study with hundreds of points actually lives in).
    sweep(wl, base_hw, policies=POLICIES, capacities=CAPACITIES, ways=WAYS,
          zipf_s=ZIPF, seed=0)
    from repro.core.memory import stack as _stack

    dp0 = _stack.distance_pass_count()
    sr = sweep(wl, base_hw, policies=POLICIES, capacities=CAPACITIES,
               ways=WAYS, zipf_s=ZIPF, seed=0)
    stack_passes = _stack.distance_pass_count() - dp0
    prof = None
    if profile:
        # Separate profiled pass: an active session adds per-stage
        # synchronization (block_until_ready inside the compute stages), so
        # the headline per_config_ms above measures the production path and
        # the breakdown below attributes a dedicated run.
        with profiling.collect() as prof:
            t_prof = time.perf_counter()
            sweep(wl, base_hw, policies=POLICIES, capacities=CAPACITIES,
                  ways=WAYS, zipf_s=ZIPF, seed=0)
            profiled_wall = time.perf_counter() - t_prof

    # Same grid with per-config scans (no vmapped batching): isolates the
    # batched-classification speedup from trace/matrix sharing.
    sweep(wl, base_hw, policies=POLICIES, capacities=CAPACITIES, ways=WAYS,
          zipf_s=ZIPF, seed=0, batch_scans=False)
    sr_nb = sweep(wl, base_hw, policies=POLICIES, capacities=CAPACITIES,
                  ways=WAYS, zipf_s=ZIPF, seed=0, batch_scans=False)

    # NUMA placement-axes slice: the (affinity x placement) grid on a
    # 2-core table_hash cluster, timed separately so the headline
    # per_config_ms (the perf-gate number) keeps its historical grid.
    wl_p = dlrm_rmc2_small(num_tables=PLACEMENT_TABLES, rows_per_table=ROWS,
                           batch_size=BATCH, num_batches=2)
    hw_p = base_hw.with_cluster(2, "private", "table_hash")
    placement_axes = PLACEMENT_AXES
    sweep(wl_p, hw_p, **placement_axes)          # warm
    # Best-of-2: the placement slice feeds a ratio gate (perf_smoke) and
    # single-shot walls on small shared runners carry ~20% scheduler noise,
    # enough to flip the gate without any code change.
    sr_p = min(
        (sweep(wl_p, hw_p, **placement_axes) for _ in range(2)),
        key=lambda s: s.wall_seconds,
    )

    sample = sr.entries[:: max(1, len(sr.entries) // N_INDEPENDENT_SAMPLE)]
    t0 = time.perf_counter()
    for e in sample:
        c = e.config
        hw = base_hw.with_policy(
            OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes, ways=c.ways
        )
        ref = simulate(wl, hw, seed=0, zipf_s=c.zipf_s)
        mism = e.result.diff(ref)
        assert not mism, (c.label, mism)
    t_indep = time.perf_counter() - t0
    est_independent_s = t_indep / len(sample) * sr.num_configs

    best = sr.best("total_cycles")
    perf_row: Dict = {
        "kind": "perf",
        "configs": sr.num_configs,
        "sweep_s": sr.wall_seconds,
        "per_config_ms": sr.wall_seconds / sr.num_configs * 1e3,
        "est_independent_s": est_independent_s,
        "speedup_vs_independent": est_independent_s / max(sr.wall_seconds, 1e-9),
        "unbatched_sweep_s": sr_nb.wall_seconds,
        "batched_scan_speedup": sr_nb.wall_seconds / max(sr.wall_seconds, 1e-9),
        "cache_backend": base_hw.cache_backend,
        "stack_distance_passes": stack_passes,
        "placement_configs": sr_p.num_configs,
        "placement_per_config_ms": sr_p.wall_seconds / sr_p.num_configs * 1e3,
        "bitexact_sample": len(sample),
        "best_config": best.config.label,
        "best_total_cycles": best.result.total_cycles,
    }
    if profile:
        breakdown = prof.breakdown(total_seconds=profiled_wall)
        perf_row["stage_seconds"] = {k: round(v, 4) for k, v in breakdown.items()}
        perf_row["stage_ms_per_config"] = {
            k: round(v / sr.num_configs * 1e3, 3) for k, v in breakdown.items()
        }
    rows: List[Dict] = [perf_row]
    rows.extend(
        {"kind": "config", **r} for r in sr.speedup_over("spm")
    )
    return rows


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", action="store_true",
                    help="add a per-stage wall-time breakdown to the perf row")
    args = ap.parse_args()

    bench_rows = run(profile=args.profile)
    path = common.save_rows("BENCH_sweep", bench_rows, repo_root=True)
    perf = next(r for r in bench_rows if r["kind"] == "perf")
    print(f"saved {path}")
    print(f"configs={perf['configs']} sweep_s={perf['sweep_s']:.2f} "
          f"per_config_ms={perf['per_config_ms']:.1f} "
          f"speedup_vs_independent={perf['speedup_vs_independent']:.2f} "
          f"batched_scan_speedup={perf['batched_scan_speedup']:.2f}")
    if args.profile:
        for k, v in perf["stage_ms_per_config"].items():
            print(f"  stage {k:<12s} {v:8.2f} ms/config")
