"""DSE sweep benchmark: grid evaluation throughput + sharing speedup.

Runs a (policy x capacity x ways) grid through ``sweep()`` in one pass, then
times a sample of the same configs as independent ``simulate()`` calls to
measure the benefit of sharing traces / matrix results / compiled scans, and
re-times the sweep with ``batch_scans=False`` to isolate the vmapped
same-policy scan-batching win. Emits one ``kind=perf`` record plus one row
per grid point, saved BOTH under results/bench/ and as BENCH_sweep.json at
the repo root — the root copy is checked in (and uploaded by CI every run)
so the per-config perf trajectory is tracked across PRs.

Every timed slice is best-of-2 (single-shot walls on small shared runners
carry ~20% scheduler noise, enough to fake a regression), and the perf row
records ``device_count`` / ``host_cpus`` / ``sharded`` so trajectories from
different runners stay comparable.

``--profile`` re-times the sweep inside a stage-profiling session
(``repro.core.profiling``) and adds a per-stage wall-time breakdown to the
perf record — trace gen / classify / cache scan / DRAM / host sync — so the
next perf PR starts from data instead of guesses.

A separate NUMA placement-axes slice (channel_affinity x placement on a
2-core table_hash cluster) is timed into ``placement_per_config_ms`` without
touching the historical perf-gate grid, and a serving-scenario slice sweeps
the closed-loop request-level scheduler (steady vs overload-with-robustness
traffic as first-class axes) into ``kind=serving`` rows — per-(hardware x
scenario) p50/p95/p99 latency, goodput and shed/timeout/retry counters.

The **sharded probe** measures the device-sharded sweep: a subprocess under
``XLA_FLAGS=--xla_force_host_platform_device_count=8`` (so the parent's
numbers keep the real single-device runtime) runs a 96-config grid unsharded
and sharded over 8 host devices, asserts bitwise equality, and reports
``sharded_speedup`` into the perf row. Host "devices" are threads over the
same cores, so the speedup ceiling is ``host_cpus`` — the recorded
``host_cpus`` makes a 1-core CI runner's ~1x honest rather than alarming.
"""
from __future__ import annotations

import json
import os
import subprocess
import sys
import time
from typing import Dict, List, Optional

from repro.core import (
    OnChipPolicy,
    TrafficConfig,
    dlrm_rmc2_small,
    simulate,
    sweep,
    tpuv6e,
)
from repro.core import profiling
from repro.serving import RobustnessPolicy, ServingScenario

TABLES, ROWS, BATCH = 4, 100_000, 48
POLICIES = ("spm", "lru", "srrip", "pinning")
CAPACITIES = (1 << 20, 4 << 20, 16 << 20)
WAYS = (8, 16)
ZIPF = 1.0
N_INDEPENDENT_SAMPLE = 6

# The placement-axes slice grid: shared with scripts/perf_smoke.py (imported,
# not copied, so the ratio gate measures exactly what the benchmark reports).
PLACEMENT_TABLES = 6
PLACEMENT_AXES = dict(
    policies=("spm", "lru"), zipf_s=ZIPF, seed=0,
    channel_affinities=("symmetric", "per_core", "per_table"),
    placements=("interleave", "table_rank", "hot_replicate"),
)

# The sharded probe's grid: the perf-gate grid widened by zipf x cores to
# 96 configs (4 x 3 x 2 x 2 x 2) so the shard partition has enough memo-key
# groups to spread across 8 devices.
SHARDED_AXES = dict(
    policies=POLICIES, capacities=CAPACITIES, ways=WAYS,
    zipf_s=(0.8, 1.0), num_cores=(1, 2), seed=0,
)
SHARDED_DEVICES = 8
_PROBE_MARKER = "SHARDED_PROBE_JSON:"

# Serving-scenario slice: the closed-loop request-level scheduler as DSE
# axes (traffic pattern x robustness policy) over the perf-gate policies.
# Each (hardware x scenario) point emits a ``kind=serving`` row carrying the
# latency distribution (p50/p95/p99), goodput and the shed/timeout/retry
# counters — the serving trajectory tracked in BENCH_sweep.json.
SERVING_TABLES, SERVING_ROWS = 4, 20_000
SERVING_AXES = dict(policies=POLICIES, capacities=(1 << 20,), ways=(8,))
SERVING_SCENARIOS = (
    ServingScenario(
        name="steady",
        traffic=TrafficConfig(pattern="poisson", mean_gap_cycles=1_500.0,
                              num_requests=64, seed=7, zipf_s=ZIPF),
        batch_slots=8,
    ),
    ServingScenario(
        name="overload_storm",
        traffic=TrafficConfig(pattern="bursty", mean_gap_cycles=60.0,
                              num_requests=96, seed=23, burst_len=12,
                              zipf_s=ZIPF),
        policy=RobustnessPolicy(admission_watermark=14,
                                deadline_cycles=40_000, max_retries=2,
                                retry_backoff_cycles=3_000.0,
                                degrade_mode="hot_rows_only",
                                degrade_watermark=4, hot_fraction=0.1),
        batch_slots=8,
    ),
)


def _best_of(n: int, fn):
    """Best-of-n wall clock: returns the fastest run's result."""
    return min((fn() for _ in range(n)), key=lambda s: s.wall_seconds)


def run(profile: bool = False) -> List[Dict]:
    wl = dlrm_rmc2_small(num_tables=TABLES, rows_per_table=ROWS, batch_size=BATCH,
                         num_batches=2)
    base_hw = tpuv6e()

    def base_grid(**kw):
        return sweep(wl, base_hw, policies=POLICIES, capacities=CAPACITIES,
                     ways=WAYS, zipf_s=ZIPF, seed=0, **kw)

    # Warm pass compiles every scan shape; the timed passes measure steady
    # state (the regime a DSE study with hundreds of points actually lives
    # in). Best-of-2 like the placement slice — the perf gate compares these
    # numbers across runners.
    base_grid()
    from repro.core.memory import stack as _stack

    dp0 = _stack.distance_pass_count()
    sr = base_grid()
    stack_passes = _stack.distance_pass_count() - dp0
    sr = min(sr, base_grid(), key=lambda s: s.wall_seconds)
    prof = None
    if profile:
        # Separate profiled pass: an active session adds per-stage
        # synchronization (block_until_ready inside the compute stages), so
        # the headline per_config_ms above measures the production path and
        # the breakdown below attributes a dedicated run.
        with profiling.collect() as prof:
            t_prof = time.perf_counter()
            base_grid()
            profiled_wall = time.perf_counter() - t_prof

    # Same grid with per-config scans (no vmapped batching): isolates the
    # batched-classification speedup from trace/matrix sharing.
    base_grid(batch_scans=False)
    sr_nb = _best_of(2, lambda: base_grid(batch_scans=False))

    # NUMA placement-axes slice: the (affinity x placement) grid on a
    # 2-core table_hash cluster, timed separately so the headline
    # per_config_ms (the perf-gate number) keeps its historical grid.
    wl_p = dlrm_rmc2_small(num_tables=PLACEMENT_TABLES, rows_per_table=ROWS,
                           batch_size=BATCH, num_batches=2)
    hw_p = base_hw.with_cluster(2, "private", "table_hash")
    placement_axes = PLACEMENT_AXES
    sweep(wl_p, hw_p, **placement_axes)          # warm
    sr_p = _best_of(2, lambda: sweep(wl_p, hw_p, **placement_axes))

    sample = sr.entries[:: max(1, len(sr.entries) // N_INDEPENDENT_SAMPLE)]
    t0 = time.perf_counter()
    for e in sample:
        c = e.config
        hw = base_hw.with_policy(
            OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes, ways=c.ways
        )
        ref = simulate(wl, hw, seed=0, zipf_s=c.zipf_s)
        mism = e.result.diff(ref)
        assert not mism, (c.label, mism)
    t_indep = time.perf_counter() - t0
    est_independent_s = t_indep / len(sample) * sr.num_configs

    # Serving slice: steady + overload-with-robustness scenarios swept as
    # first-class axes; timed separately (best-of-2 like the other slices)
    # so the headline per_config_ms keeps its historical fixed-trace grid.
    wl_s = dlrm_rmc2_small(num_tables=SERVING_TABLES,
                           rows_per_table=SERVING_ROWS, batch_size=BATCH,
                           num_batches=2)
    sweep(wl_s, base_hw, scenarios=SERVING_SCENARIOS, **SERVING_AXES)  # warm
    sr_s = _best_of(2, lambda: sweep(wl_s, base_hw,
                                     scenarios=SERVING_SCENARIOS,
                                     **SERVING_AXES))
    best_p99 = sr_s.best("p99_cycles")

    best = sr.best("total_cycles")
    perf_row: Dict = {
        "kind": "perf",
        "configs": sr.num_configs,
        "sweep_s": sr.wall_seconds,
        "per_config_ms": sr.wall_seconds / sr.num_configs * 1e3,
        "est_independent_s": est_independent_s,
        "speedup_vs_independent": est_independent_s / max(sr.wall_seconds, 1e-9),
        "unbatched_sweep_s": sr_nb.wall_seconds,
        "batched_scan_speedup": sr_nb.wall_seconds / max(sr.wall_seconds, 1e-9),
        "cache_backend": base_hw.cache_backend,
        "stack_distance_passes": stack_passes,
        "distinct_memo_keys": sr.distinct_memo_keys,
        # Runner context: the headline grid runs unsharded on one device, and
        # cross-runner trajectory comparisons need to know both.
        "sharded": sr.sharded,
        "device_count": sr.device_count,
        "host_cpus": os.cpu_count() or 1,
        "placement_configs": sr_p.num_configs,
        "placement_per_config_ms": sr_p.wall_seconds / sr_p.num_configs * 1e3,
        "bitexact_sample": len(sample),
        "best_config": best.config.label,
        "best_total_cycles": best.result.total_cycles,
        "serving_configs": sr_s.num_configs,
        "serving_per_config_ms": sr_s.wall_seconds / sr_s.num_configs * 1e3,
        "best_serving_p99_config": best_p99.config.label,
        "best_serving_p99_cycles": best_p99.result.p99_cycles,
        # Failure telemetry (core.faults): all-zero on this fault-free run —
        # nonzero counters in a perf trajectory mean the runner degraded
        # (retries/failovers) and its walls are not comparable.
        "fault_telemetry": sr.telemetry.brief(),
    }
    if profile:
        breakdown = prof.breakdown(total_seconds=profiled_wall)
        perf_row["stage_seconds"] = {k: round(v, 4) for k, v in breakdown.items()}
        perf_row["stage_ms_per_config"] = {
            k: round(v / sr.num_configs * 1e3, 3) for k, v in breakdown.items()
        }
    rows: List[Dict] = [perf_row]
    rows.extend(
        {"kind": "config", **r} for r in sr.speedup_over("spm")
    )
    rows.extend({"kind": "serving", **e.row()} for e in sr_s.entries)
    return rows


def sharded_probe() -> Dict:
    """The 96-config grid, unsharded vs sharded over the forced host devices
    (run this under ``XLA_FLAGS=--xla_force_host_platform_device_count=8``)
    — asserts bitwise equality, reports the wall-clock ratio."""
    import jax

    wl = dlrm_rmc2_small(num_tables=TABLES, rows_per_table=ROWS,
                         batch_size=BATCH, num_batches=2)
    base_hw = tpuv6e()
    sweep(wl, base_hw, **SHARDED_AXES)                       # warm
    ref = _best_of(2, lambda: sweep(wl, base_hw, **SHARDED_AXES))
    sweep(wl, base_hw, devices=SHARDED_DEVICES, **SHARDED_AXES)   # warm
    sh = _best_of(
        2, lambda: sweep(wl, base_hw, devices=SHARDED_DEVICES, **SHARDED_AXES)
    )
    for a, b in zip(ref.entries, sh.entries):
        assert a.config == b.config
        mism = a.result.diff(b.result)
        assert not mism, (a.config.label, mism)
    # The probe runs fault-free: any retry/failover here is a bug in the
    # supervision layer, not runner noise.
    assert not sh.telemetry.any_faults, sh.telemetry.to_dict()
    return {
        "sharded_fault_telemetry": sh.telemetry.brief(),
        "sharded_configs": sh.num_configs,
        "sharded_distinct_memo_keys": sh.distinct_memo_keys,
        "sharded_device_count": sh.device_count,
        "sharded_bitexact": True,
        "sharded_unsharded_s": ref.wall_seconds,
        "sharded_sweep_s": sh.wall_seconds,
        "sharded_speedup": ref.wall_seconds / max(sh.wall_seconds, 1e-9),
        "sharded_per_config_ms": sh.wall_seconds / sh.num_configs * 1e3,
        "host_devices": len(jax.devices()),
    }


def run_sharded_subprocess() -> Optional[Dict]:
    """Run the sharded probe in a child process with 8 forced host devices —
    XLA device topology is fixed at backend init, so the parent process
    (whose headline numbers must reflect the real device) cannot host it.
    Returns None (with a note) if the child fails; the benchmark's other
    rows still save."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={SHARDED_DEVICES}"
    ).strip()
    repo_root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(repo_root, "src"),
                    env.get("PYTHONPATH", "")) if p
    )
    try:
        proc = subprocess.run(
            [sys.executable, "-m", "benchmarks.dse_sweep", "--sharded-probe"],
            cwd=repo_root, env=env, capture_output=True, text=True,
            timeout=1800,
        )
    except (OSError, subprocess.TimeoutExpired) as exc:
        print(f"sharded probe failed to run: {exc}", file=sys.stderr)
        return None
    for line in proc.stdout.splitlines():
        if line.startswith(_PROBE_MARKER):
            return json.loads(line[len(_PROBE_MARKER):])
    print("sharded probe produced no result:\n"
          f"{proc.stdout}\n{proc.stderr}", file=sys.stderr)
    return None


if __name__ == "__main__":
    import argparse

    from benchmarks import common

    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--profile", action="store_true",
                    help="add a per-stage wall-time breakdown to the perf row")
    ap.add_argument("--no-sharded", action="store_true",
                    help="skip the sharded-sweep probe subprocess")
    ap.add_argument("--sharded-probe", action="store_true",
                    help=argparse.SUPPRESS)   # internal: child-process mode
    args = ap.parse_args()

    if args.sharded_probe:
        print(_PROBE_MARKER + json.dumps(sharded_probe()))
        sys.exit(0)

    bench_rows = run(profile=args.profile)
    perf = next(r for r in bench_rows if r["kind"] == "perf")
    if not args.no_sharded:
        probe = run_sharded_subprocess()
        if probe is not None:
            perf.update(probe)
    path = common.save_rows("BENCH_sweep", bench_rows, repo_root=True)
    print(f"saved {path}")
    print(f"configs={perf['configs']} sweep_s={perf['sweep_s']:.2f} "
          f"per_config_ms={perf['per_config_ms']:.1f} "
          f"speedup_vs_independent={perf['speedup_vs_independent']:.2f} "
          f"batched_scan_speedup={perf['batched_scan_speedup']:.2f}")
    print(f"serving: {perf['serving_configs']} (hw x scenario) points, "
          f"{perf['serving_per_config_ms']:.1f} ms/config, best p99 "
          f"{perf['best_serving_p99_cycles']:,.0f} cyc "
          f"@ {perf['best_serving_p99_config']}")
    if "sharded_speedup" in perf:
        print(f"sharded: {perf['sharded_configs']} configs on "
              f"{perf['sharded_device_count']} host devices "
              f"(host_cpus={perf['host_cpus']}) "
              f"speedup={perf['sharded_speedup']:.2f}x bitexact=True")
    if args.profile:
        for k, v in perf["stage_ms_per_config"].items():
            print(f"  stage {k:<12s} {v:8.2f} ms/config")
