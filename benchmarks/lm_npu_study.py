"""Beyond-paper case study: EONSim applied to the assigned LM architectures.

Token-embedding traffic of LM serving is the paper's operation with an LM
workload: we sweep on-chip policies over the vocab-gather trace of selected
archs (largest table: command-r-plus's 256k x 12288; plus a small and an MoE
arch) and report predicted speedups of hot-token pinning — the simulator-side
counterpart of kernels/embedding_bag.py's VMEM-pinned fast path.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import OnChipPolicy, simulate, tpuv6e
from repro.core.lm_mapper import lm_workload
from repro.core.trace import REUSE_LEVELS
from repro.models import SHAPES_BY_NAME, get_config

ARCHS = ["command_r_plus_104b", "stablelm_3b", "deepseek_v2_lite_16b"]


def run() -> List[Dict]:
    rows = []
    shape = SHAPES_BY_NAME["decode_32k"]
    for arch in ARCHS:
        cfg = get_config(arch)
        # steady-state: several decode steps so hot tokens re-hit across steps
        wl = lm_workload(cfg, shape, num_batches=8)
        base = simulate(wl, tpuv6e(), seed=0, zipf_s=REUSE_LEVELS["reuse_high"])
        for policy in (OnChipPolicy.LRU, OnChipPolicy.PINNING):
            res = simulate(wl, tpuv6e().with_policy(policy), seed=0,
                           zipf_s=REUSE_LEVELS["reuse_high"])
            rows.append({
                "arch": arch, "shape": shape.name, "policy": policy.value,
                "embed_speedup_vs_spm": base.embedding_cycles
                / max(res.embedding_cycles, 1e-9),
                "total_speedup_vs_spm": base.total_cycles / res.total_cycles,
                "onchip_ratio": res.onchip_ratio,
            })
    return rows
