"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference.

Wall times on CPU interpret mode are NOT TPU projections — the deliverable is
the op inventory + achieved-FLOP accounting; TPU-side performance is covered
by the roofline analysis of the lowered programs.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _t(fn, *a, repeat=3, **k):
    fn(*a, **k).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(repeat):
        out = fn(*a, **k)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / repeat * 1e6


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # embedding bag (paper op) — interpret-mode grid kept small (B*T*L steps
    # execute as Python in interpret mode)
    T, R, D, B, L = 4, 5000, 128, 4, 8
    table = jnp.asarray(rng.standard_normal((T * R, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, R, (B, T, L)), jnp.int32)
    us_ref = _t(ops.embedding_bag, table, idx, R, use_pallas=False)
    gathered_bytes = B * T * L * D * 4
    rows.append({"kernel": "embedding_bag", "variant": "xla", "us": us_ref,
                 "gathered_mb": gathered_bytes / 1e6})
    us_pal = _t(ops.embedding_bag, table, idx, R, use_pallas=True, repeat=1)
    rows.append({"kernel": "embedding_bag", "variant": "pallas-interpret",
                 "us": us_pal, "gathered_mb": gathered_bytes / 1e6})

    # flash attention
    q = jnp.asarray(rng.standard_normal((2, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 512, 64)), jnp.float32)
    fl = 4 * 2 * 8 * 512 * 512 * 64
    rows.append({"kernel": "flash_attention", "variant": "xla",
                 "us": _t(ops.flash_attention, q, k, v, use_pallas=False),
                 "gflop": fl / 1e9})
    rows.append({"kernel": "flash_attention", "variant": "pallas-interpret",
                 "us": _t(ops.flash_attention, q, k, v, use_pallas=True, repeat=1),
                 "gflop": fl / 1e9})

    # mamba2 ssd
    Bs, H, S, P, N = 2, 8, 512, 64, 64
    x = jnp.asarray(rng.standard_normal((Bs, H, S, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (Bs, H, S)), jnp.float32)
    adt = -jnp.exp(jnp.asarray(rng.standard_normal((H,)), jnp.float32))[None, :, None] * dt
    Bm = jnp.asarray(rng.standard_normal((Bs, S, N)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bs, S, N)) * 0.3, jnp.float32)
    rows.append({"kernel": "mamba2_ssd", "variant": "xla-chunked",
                 "us": _t(ops.mamba2_ssd, x, adt, dt, Bm, C, use_pallas=False)})
    rows.append({"kernel": "mamba2_ssd", "variant": "pallas-interpret",
                 "us": _t(ops.mamba2_ssd, x, adt, dt, Bm, C, use_pallas=True, repeat=1)})
    return rows
