"""Kernel microbenchmarks: Pallas (interpret) vs jnp reference.

Wall times on CPU interpret mode are NOT TPU projections — the deliverable is
the op inventory + achieved-FLOP accounting; TPU-side performance is covered
by the roofline analysis of the lowered programs.

``run_cache_scan()`` benchmarks the simulator's own hot loop — the set-
associative cache scan — across its three implementations (vmapped lax.scan
engine, Pallas kernel in interpret mode, sequential GoldenCache) in
accesses/second. ``run_stack_distance()`` benchmarks the analytic LRU
stack-distance engine (numpy host twin, device-resident jnp pass, Pallas
distance kernel) against the scan backend across trace lengths and set
counts, asserting bit-exact agreement in-line. ``run_rrip_engines()`` does
the same for srrip/fifo through the compressed per-set analytic engines
(``memory/rrip.py``), so all three sweep-default cache backends are tracked
per-PR. All save into BENCH_cache_kernel.json, uploaded with the CI
artifacts.
"""
from __future__ import annotations

import time
from typing import Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import ops


def _t(fn, *a, repeat=3, **k):
    fn(*a, **k).block_until_ready()  # compile
    t0 = time.time()
    for _ in range(repeat):
        out = fn(*a, **k)
    jax.tree.leaves(out)[0].block_until_ready()
    return (time.time() - t0) / repeat * 1e6


def run() -> List[Dict]:
    rng = np.random.default_rng(0)
    rows = []

    # embedding bag (paper op) — interpret-mode grid kept small (B*T*L steps
    # execute as Python in interpret mode)
    T, R, D, B, L = 4, 5000, 128, 4, 8
    table = jnp.asarray(rng.standard_normal((T * R, D)), jnp.float32)
    idx = jnp.asarray(rng.integers(0, R, (B, T, L)), jnp.int32)
    us_ref = _t(ops.embedding_bag, table, idx, R, use_pallas=False)
    gathered_bytes = B * T * L * D * 4
    rows.append({"kernel": "embedding_bag", "variant": "xla", "us": us_ref,
                 "gathered_mb": gathered_bytes / 1e6})
    us_pal = _t(ops.embedding_bag, table, idx, R, use_pallas=True, repeat=1)
    rows.append({"kernel": "embedding_bag", "variant": "pallas-interpret",
                 "us": us_pal, "gathered_mb": gathered_bytes / 1e6})

    # flash attention
    q = jnp.asarray(rng.standard_normal((2, 8, 512, 64)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 2, 512, 64)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 2, 512, 64)), jnp.float32)
    fl = 4 * 2 * 8 * 512 * 512 * 64
    rows.append({"kernel": "flash_attention", "variant": "xla",
                 "us": _t(ops.flash_attention, q, k, v, use_pallas=False),
                 "gflop": fl / 1e9})
    rows.append({"kernel": "flash_attention", "variant": "pallas-interpret",
                 "us": _t(ops.flash_attention, q, k, v, use_pallas=True, repeat=1),
                 "gflop": fl / 1e9})

    # mamba2 ssd
    Bs, H, S, P, N = 2, 8, 512, 64, 64
    x = jnp.asarray(rng.standard_normal((Bs, H, S, P)) * 0.5, jnp.float32)
    dt = jnp.asarray(rng.uniform(0.001, 0.1, (Bs, H, S)), jnp.float32)
    adt = -jnp.exp(jnp.asarray(rng.standard_normal((H,)), jnp.float32))[None, :, None] * dt
    Bm = jnp.asarray(rng.standard_normal((Bs, S, N)) * 0.3, jnp.float32)
    C = jnp.asarray(rng.standard_normal((Bs, S, N)) * 0.3, jnp.float32)
    rows.append({"kernel": "mamba2_ssd", "variant": "xla-chunked",
                 "us": _t(ops.mamba2_ssd, x, adt, dt, Bm, C, use_pallas=False)})
    rows.append({"kernel": "mamba2_ssd", "variant": "pallas-interpret",
                 "us": _t(ops.mamba2_ssd, x, adt, dt, Bm, C, use_pallas=True, repeat=1)})
    return rows


def run_cache_scan() -> List[Dict]:
    """Cache-scan engine microbenchmark (acc/s): lax.scan vs Pallas vs golden.

    The lax.scan variant is measured in the regime the DSE sweep actually
    runs: a *batch* of independent streams whose set-group sub-scans fuse
    into vmapped dispatches (``simulate_cache_many``). The Pallas variant
    runs in interpret mode off-TPU (every access executes as Python), so its
    acc/s is a correctness-path datapoint, not a TPU projection; the golden
    Python model is the sequential reference everything must agree with.
    """
    from repro.core.memory.cache import CacheGeometry, simulate_cache_many
    from repro.core.memory.golden import GoldenCache

    rng = np.random.default_rng(0)
    geom = CacheGeometry(num_sets=512, ways=8, line_bytes=64)
    n_streams, n_scan = 8, 32768     # sweep-like: many configs, one dispatch
    n_pallas = 2048                  # interpret mode walks accesses in Python
    streams = [rng.integers(0, 40_000, size=n_scan).astype(np.int64)
               for _ in range(n_streams)]
    geoms = [geom] * n_streams

    rows: List[Dict] = []
    for policy in ("lru", "srrip", "fifo"):
        simulate_cache_many(streams, geoms, policy, backend="scan")  # compile
        t0 = time.time()
        res_scan = simulate_cache_many(streams, geoms, policy, backend="scan")
        dt_scan = time.time() - t0
        total = n_streams * n_scan
        rows.append({
            "kernel": "cache_scan", "variant": "lax-scan-batched",
            "policy": policy, "accesses": total, "us": dt_scan * 1e6,
            "macc_per_s": total / dt_scan / 1e6,
        })

        sub = streams[0][:n_pallas]
        t0 = time.time()
        res_pal = simulate_cache_many([sub], [geom], policy, backend="pallas")
        dt_pal = time.time() - t0
        rows.append({
            "kernel": "cache_scan", "variant": "pallas-interpret",
            "policy": policy, "accesses": n_pallas, "us": dt_pal * 1e6,
            "macc_per_s": n_pallas / dt_pal / 1e6,
        })

        t0 = time.time()
        gold = GoldenCache(geom, policy)
        gold_hits = gold.run(sub)
        dt_gold = time.time() - t0
        rows.append({
            "kernel": "cache_scan", "variant": "golden-python",
            "policy": policy, "accesses": n_pallas, "us": dt_gold * 1e6,
            "macc_per_s": n_pallas / dt_gold / 1e6,
        })

        # the benchmark doubles as an end-to-end agreement check
        assert np.array_equal(res_scan[0].hits[:n_pallas], gold_hits)
        assert np.array_equal(np.asarray(res_pal[0].hits), gold_hits)
    return rows


def run_stack_distance() -> List[Dict]:
    """Stack-distance engine microbench (acc/s) vs the scan/pallas backends.

    Sweeps trace length x set count for LRU — the regime where the analytic
    stack pass replaces the sequential scan — measuring classification of a
    4-point ways axis per backend so the stack engine's one-pass-per-
    (stream, num_sets) sharing shows up as throughput rather than a special
    case. The numpy and jnp engines are both timed; the Pallas distance
    kernel runs interpret mode off-TPU (correctness datapoint, small sizes).
    Every variant is asserted equal to the scan backend in-line.
    """
    from repro.core.memory import stack as stack_mod
    from repro.core.memory.cache import CacheGeometry, simulate_cache_many
    from repro.core.memory.stack import classify_lru_stack_many

    rng = np.random.default_rng(0)
    ways_axis = (2, 4, 8, 16)
    rows: List[Dict] = []
    for n, sets in ((8192, 64), (8192, 512), (32768, 512), (32768, 2048)):
        stream = rng.integers(0, n, size=n).astype(np.int64)
        geoms = [CacheGeometry(num_sets=sets, ways=w, line_bytes=64)
                 for w in ways_axis]
        streams = [stream] * len(geoms)
        total = n * len(geoms)

        ref = simulate_cache_many(streams, geoms, "lru", backend="scan")
        t0 = time.time()
        simulate_cache_many(streams, geoms, "lru", backend="scan")
        dt_scan = time.time() - t0
        rows.append({"kernel": "stack_distance", "variant": "scan-backend",
                     "n": n, "sets": sets, "us": dt_scan * 1e6,
                     "macc_per_s": total / dt_scan / 1e6})

        for engine in ("np", "jnp"):
            classify_lru_stack_many(streams, geoms, engine=engine)  # warm
            dp0 = stack_mod.distance_pass_count()
            t0 = time.time()
            got = classify_lru_stack_many(streams, geoms, engine=engine)
            dt = time.time() - t0
            assert stack_mod.distance_pass_count() - dp0 == 1  # shared pass
            for r, (h, ev) in zip(ref, got):
                assert np.array_equal(r.hits, h) and r.num_evictions == ev
            rows.append({"kernel": "stack_distance", "variant": f"stack-{engine}",
                         "n": n, "sets": sets, "us": dt * 1e6,
                         "macc_per_s": total / dt / 1e6})

    # Pallas distance kernel: interpret mode walks accesses in Python — keep
    # the size small; this is the exactness datapoint, not a TPU projection.
    n_pal, sets_pal = 2048, 16
    stream = rng.integers(0, 3000, size=n_pal).astype(np.int64)
    geom = CacheGeometry(num_sets=sets_pal, ways=8, line_bytes=64)
    ref = simulate_cache_many([stream], [geom], "lru", backend="scan")
    t0 = time.time()
    got = simulate_cache_many([stream], [geom], "lru", backend="stack_pallas")
    dt = time.time() - t0
    assert np.array_equal(ref[0].hits, got[0].hits)
    rows.append({"kernel": "stack_distance", "variant": "stack-pallas-interpret",
                 "n": n_pal, "sets": sets_pal, "us": dt * 1e6,
                 "macc_per_s": n_pal / dt / 1e6})
    return rows


def run_rrip_engines() -> List[Dict]:
    """SRRIP/FIFO analytic engines (acc/s) vs the sequential scan backend.

    Same shape as ``run_stack_distance()`` but for the two non-LRU policies,
    which classify through the compressed per-set engines in
    ``memory/rrip.py`` when the sweep routes them to the ``stack`` backend.
    A 4-point ways axis per (trace, set count) makes the one-presort-per-
    (stream, num_sets) sharing show up as throughput; every row is asserted
    bit-exact against the scan backend in-line.
    """
    from repro.core.memory import rrip as rrip_mod
    from repro.core.memory.cache import CacheGeometry, simulate_cache_many

    rng = np.random.default_rng(0)
    ways_axis = (2, 4, 8, 16)
    rows: List[Dict] = []
    for policy in ("srrip", "fifo"):
        for n, sets in ((8192, 64), (8192, 512), (32768, 512)):
            stream = rng.integers(0, n, size=n).astype(np.int64)
            geoms = [CacheGeometry(num_sets=sets, ways=w, line_bytes=64)
                     for w in ways_axis]
            streams = [stream] * len(geoms)
            total = n * len(geoms)

            ref = simulate_cache_many(streams, geoms, policy, backend="scan")
            t0 = time.time()
            simulate_cache_many(streams, geoms, policy, backend="scan")
            dt_scan = time.time() - t0
            rows.append({"kernel": "rrip_engine", "variant": "scan-backend",
                         "policy": policy, "n": n, "sets": sets,
                         "us": dt_scan * 1e6,
                         "macc_per_s": total / dt_scan / 1e6})

            simulate_cache_many(streams, geoms, policy, backend="stack")  # warm
            ap0 = rrip_mod.analytic_pass_count()
            t0 = time.time()
            got = simulate_cache_many(streams, geoms, policy, backend="stack")
            dt = time.time() - t0
            assert rrip_mod.analytic_pass_count() - ap0 == 1  # shared presort
            for r, g in zip(ref, got):
                assert np.array_equal(r.hits, g.hits)
                assert r.num_evictions == g.num_evictions
            rows.append({"kernel": "rrip_engine", "variant": "analytic",
                         "policy": policy, "n": n, "sets": sets,
                         "us": dt * 1e6, "macc_per_s": total / dt / 1e6})
    return rows


if __name__ == "__main__":
    from benchmarks import common

    cache_rows = run_cache_scan() + run_stack_distance() + run_rrip_engines()
    path = common.save_rows("BENCH_cache_kernel", cache_rows)
    print(f"saved {path}")
    for r in cache_rows:
        label = r.get("policy") or f"{r['n']}x{r['sets']}s"
        print(f"  {label:<12s} {r['variant']:<22s} "
              f"{r['macc_per_s']:8.3f} Macc/s ({r.get('accesses', r.get('n'))} accesses)")
