"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows (+ saves JSON under
results/bench/). Paper artifacts: Fig 3a/3b (DLRM time validation),
Fig 3c (access counts), Fig 4a (cache vs ChampSim-golden), Fig 4b/4c
(on-chip policy case study). Framework artifacts: kernel microbench,
LM NPU study (beyond-paper), roofline summary (reads dry-run output).
"""
from __future__ import annotations

import sys
import time


def _emit(name: str, us: float, derived: str):
    print(f"{name},{us:.1f},{derived}")


def main() -> None:
    from benchmarks import (
        assoc_study,
        common,
        dse_sweep,
        fig3_dlrm_validation,
        fig4_onchip_policies,
        interleave_study,
        kernel_bench,
        lm_npu_study,
        roofline,
    )

    print("name,us_per_call,derived")

    t0 = time.time()
    rows3 = fig3_dlrm_validation.run()
    common.save_rows("fig3_dlrm_validation", rows3)
    errs_a = [r["time_err_pct"] for r in rows3 if r["figure"] == "3a"]
    errs_b = [r["time_err_pct"] for r in rows3 if r["figure"] == "3b"]
    errs_on = [r["onchip_err_pct"] for r in rows3 if r["figure"] == "3c"]
    errs_off = [r["offchip_err_pct"] for r in rows3 if r["figure"] == "3c"]
    gap = [r["oracle_gap_pct"] for r in rows3 if "oracle_gap_pct" in r]
    _emit("fig3a_table_sweep_avg_time_err_pct", (time.time() - t0) * 1e6,
          f"{sum(errs_a)/len(errs_a):.2f}")
    _emit("fig3b_batch_sweep_avg_time_err_pct", 0,
          f"{sum(errs_b)/len(errs_b):.2f}")
    _emit("fig3c_onchip_count_err_pct", 0, f"{sum(errs_on)/len(errs_on):.2f}")
    _emit("fig3c_offchip_count_err_pct", 0, f"{sum(errs_off)/len(errs_off):.2f}")
    _emit("fig3_analytical_oracle_gap_pct", 0, f"{sum(gap)/len(gap):.1f}")

    t0 = time.time()
    rows4 = fig4_onchip_policies.run()
    common.save_rows("fig4_onchip_policies", rows4)
    ident = all(r["identical"] for r in rows4 if r["figure"] == "4a")
    _emit("fig4a_cache_vs_champsim_identical", (time.time() - t0) * 1e6, str(ident))
    for r in rows4:
        if r["figure"] == "4b/4c":
            _emit(f"fig4b_speedup_{r['dataset']}_{r['policy']}", 0,
                  f"{r['speedup_vs_spm']:.3f}")
            _emit(f"fig4c_onchip_ratio_{r['dataset']}_{r['policy']}", 0,
                  f"{r['onchip_ratio']:.3f}")

    t0 = time.time()
    rows_sw = dse_sweep.run()
    common.save_rows("BENCH_sweep", rows_sw, repo_root=True)
    for r in rows_sw:
        if r["kind"] == "perf":
            _emit("dse_sweep_per_config_ms", (time.time() - t0) * 1e6,
                  f"{r['per_config_ms']:.1f}")
            _emit("dse_sweep_speedup_vs_independent", 0,
                  f"{r['speedup_vs_independent']:.2f}")
            _emit("dse_sweep_configs", 0, str(r["configs"]))

    t0 = time.time()
    rowsk = kernel_bench.run()
    common.save_rows("kernel_bench", rowsk)
    for r in rowsk:
        _emit(f"kernel_{r['kernel']}_{r['variant']}", r["us"], "us_per_call")

    rowsc = kernel_bench.run_cache_scan() + kernel_bench.run_stack_distance()
    common.save_rows("BENCH_cache_kernel", rowsc)
    for r in rowsc:
        label = r.get("policy") or f"{r['n']}x{r['sets']}s"
        _emit(f"{r['kernel']}_{label}_{r['variant']}", r["us"],
              f"{r['macc_per_s']:.3f}Macc/s")

    t0 = time.time()
    rowsl = lm_npu_study.run()
    common.save_rows("lm_npu_study", rowsl)
    for r in rowsl:
        _emit(f"lm_study_{r['arch']}_{r['policy']}", 0,
              f"embed_speedup={r['embed_speedup_vs_spm']:.2f}")

    rowsa = assoc_study.run()
    common.save_rows("assoc_study", rowsa)
    for r in rowsa:
        _emit(f"assoc_{r['sweep']}_{r['ways']}w_{r['capacity_mb']}MB", 0,
              f"hit_rate={r['hit_rate']:.3f}")

    rowsi = interleave_study.run()
    common.save_rows("interleave_study", rowsi)
    for r in rowsi:
        _emit(f"interleave_{r['interleave_bytes']}B", 0,
              f"speedup={r['speedup_vs_64B']:.2f};rowhit={r['row_hit_rate']:.3f};"
              f"GBps={r['achieved_gbps']:.0f}")

    rowsr = roofline.run()
    common.save_rows("roofline", rowsr)
    for r in rowsr:
        if "arch" in r:
            _emit(f"roofline_{r['arch']}_{r['shape']}", 0,
                  f"bottleneck={r['bottleneck']};mfu={r['mfu_projected']*100:.1f}%")
    print(f"# done in {time.time() - t0:.0f}s (roofline section)", file=sys.stderr)


if __name__ == "__main__":
    main()
