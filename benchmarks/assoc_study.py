"""Beyond-paper case study: cache geometry exploration (associativity and
capacity) for embedding working sets — the "architecture exploration"
use-case the paper positions EONSim for (next-gen NPUs with cache-mode
on-chip memory, MTIA-style).
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core.memory.cache import CacheGeometry, simulate_cache
from repro.core.trace import REUSE_LEVELS, generate_zipf_trace


def run() -> List[Dict]:
    rows = []
    # vector-granular stream: 400k accesses over 250k vectors, paper-mid reuse
    tr = generate_zipf_trace(400_000, 250_000, REUSE_LEVELS["reuse_mid"], seed=2)

    cap = 8 * 1024 * 1024
    for ways in (1, 2, 4, 8, 16, 32):
        g = CacheGeometry.from_capacity(cap, 512, ways)
        r = simulate_cache(tr, g, "lru")
        rows.append({"sweep": "ways", "ways": ways, "capacity_mb": cap >> 20,
                     "hit_rate": r.hit_rate})

    for cap_mb in (1, 2, 4, 8, 16, 32):
        g = CacheGeometry.from_capacity(cap_mb << 20, 512, 16)
        r = simulate_cache(tr, g, "lru")
        rows.append({"sweep": "capacity", "ways": 16, "capacity_mb": cap_mb,
                     "hit_rate": r.hit_rate})
    return rows
