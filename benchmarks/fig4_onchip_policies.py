"""Paper Fig. 4a/4b/4c — on-chip memory management case study.

  4a: EONSim cache hits/misses vs ChampSim-semantics golden model (LRU,
      SRRIP) — the paper reports *identical* counts; so do we (bit-exact).
  4b: speedup of LRU / SRRIP / Profiling-pinning over the SPM baseline on
      Reuse-High / Mid / Low datasets (Zipf exponents calibrated to the
      paper's "4% / ~20% / 46% of vectors dominate").
  4c: on-chip memory access ratio per policy/dataset.

Scale note: tables 60 -> 8, rows 1M -> 250k, and on-chip capacity 128 MB ->
4 MB keep the capacity-to-working-set ratio in the paper's regime (~5-10% of
the accessed-unique bytes fit on-chip) at container-tractable trace lengths.
"""
from __future__ import annotations

from typing import Dict, List

from repro.core import OnChipPolicy, dlrm_rmc2_small, sweep, tpuv6e
from repro.core.memory.cache import CacheGeometry, simulate_cache
from repro.core.memory.golden import GoldenCache
from repro.core.trace import REUSE_LEVELS, reuse_trace

TABLES, ROWS, BATCH = 8, 250_000, 96
CAPACITY = 4 * 1024 * 1024     # scaled with the workload (module docstring)


def run_fig4a() -> List[Dict]:
    rows = []
    geom = CacheGeometry.from_capacity(32 * 1024 * 1024, 512, 16)  # vector-granular
    for level in ("reuse_high", "reuse_mid", "reuse_low"):
        tr = reuse_trace(level, 400_000, ROWS, seed=0)
        for policy in ("lru", "srrip"):
            ours = simulate_cache(tr, geom, policy)
            gold = GoldenCache(geom, policy)
            gold.run(tr)
            rows.append({
                "figure": "4a", "dataset": level, "policy": policy,
                "sim_hits": ours.num_hits, "champ_hits": gold.num_hits,
                "sim_misses": ours.num_misses, "champ_misses": gold.num_misses,
                "identical": bool(
                    ours.num_hits == gold.num_hits
                    and ours.num_misses == gold.num_misses
                ),
            })
    return rows


def run_fig4bc() -> List[Dict]:
    """Fig. 4b/4c as ONE ``sweep()`` over the (policy x reuse-level) grid.

    Replaces the historical per-(policy, dataset) ``simulate()`` loop: traces
    are generated once per reuse level and shared by every policy, and each
    grid point stays bit-exact with an independent run (tests enforce the
    sweep-level guarantee).
    """
    wl = dlrm_rmc2_small(num_tables=TABLES, rows_per_table=ROWS, batch_size=BATCH)
    sr = sweep(
        wl,
        tpuv6e().with_policy(OnChipPolicy.SPM, capacity_bytes=CAPACITY),
        policies=("spm", "lru", "srrip", "pinning"),
        capacities=(CAPACITY,),
        ways=(16,),
        zipf_s=tuple(REUSE_LEVELS.values()),
        seed=0,
    )
    level_of_z = {z: name for name, z in REUSE_LEVELS.items()}
    spm = {
        e.config.zipf_s: e.result
        for e in sr.entries
        if e.config.policy == "spm"
    }
    rows = []
    for e in sr.entries:
        c, res = e.config, e.result
        if c.policy == "spm":
            continue
        base = spm[c.zipf_s]
        rows.append({
            "figure": "4b/4c", "dataset": level_of_z[c.zipf_s], "policy": c.policy,
            "speedup_vs_spm": base.total_cycles / res.total_cycles,
            "onchip_ratio": res.onchip_ratio,
            "spm_onchip_ratio": base.onchip_ratio,
            "cache_hit_rate": res.cache_hits
            / max(res.cache_hits + res.cache_misses, 1),
        })
    return rows


def run() -> List[Dict]:
    return run_fig4a() + run_fig4bc()
