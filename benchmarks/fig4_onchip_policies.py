"""Paper Fig. 4a/4b/4c — on-chip memory management case study.

  4a: EONSim cache hits/misses vs ChampSim-semantics golden model (LRU,
      SRRIP) — the paper reports *identical* counts; so do we (bit-exact).
  4b: speedup of LRU / SRRIP / Profiling-pinning over the SPM baseline on
      Reuse-High / Mid / Low datasets (Zipf exponents calibrated to the
      paper's "4% / ~20% / 46% of vectors dominate").
  4c: on-chip memory access ratio per policy/dataset.

Scale note: tables 60 -> 8, rows 1M -> 250k, and on-chip capacity 128 MB ->
4 MB keep the capacity-to-working-set ratio in the paper's regime (~5-10% of
the accessed-unique bytes fit on-chip) at container-tractable trace lengths.
"""
from __future__ import annotations

from typing import Dict, List

import numpy as np

from repro.core import OnChipPolicy, dlrm_rmc2_small, simulate, tpuv6e
from repro.core.memory.cache import CacheGeometry, simulate_cache
from repro.core.memory.golden import GoldenCache
from repro.core.trace import REUSE_LEVELS, reuse_trace

TABLES, ROWS, BATCH = 8, 250_000, 96
CAPACITY = 4 * 1024 * 1024     # scaled with the workload (module docstring)


def run_fig4a() -> List[Dict]:
    rows = []
    geom = CacheGeometry.from_capacity(32 * 1024 * 1024, 512, 16)  # vector-granular
    for level in ("reuse_high", "reuse_mid", "reuse_low"):
        tr = reuse_trace(level, 400_000, ROWS, seed=0)
        for policy in ("lru", "srrip"):
            ours = simulate_cache(tr, geom, policy)
            gold = GoldenCache(geom, policy)
            gold.run(tr)
            rows.append({
                "figure": "4a", "dataset": level, "policy": policy,
                "sim_hits": ours.num_hits, "champ_hits": gold.num_hits,
                "sim_misses": ours.num_misses, "champ_misses": gold.num_misses,
                "identical": bool(
                    ours.num_hits == gold.num_hits
                    and ours.num_misses == gold.num_misses
                ),
            })
    return rows


def run_fig4bc() -> List[Dict]:
    rows = []
    for level in ("reuse_high", "reuse_mid", "reuse_low"):
        z = REUSE_LEVELS[level]
        wl = dlrm_rmc2_small(num_tables=TABLES, rows_per_table=ROWS, batch_size=BATCH)
        base = simulate(
            wl, tpuv6e().with_policy(OnChipPolicy.SPM, capacity_bytes=CAPACITY),
            seed=0, zipf_s=z,
        )
        for policy in (OnChipPolicy.LRU, OnChipPolicy.SRRIP, OnChipPolicy.PINNING):
            res = simulate(
                wl, tpuv6e().with_policy(policy, capacity_bytes=CAPACITY),
                seed=0, zipf_s=z,
            )
            rows.append({
                "figure": "4b/4c", "dataset": level, "policy": policy.value,
                "speedup_vs_spm": base.total_cycles / res.total_cycles,
                "onchip_ratio": res.onchip_ratio,
                "spm_onchip_ratio": base.onchip_ratio,
                "cache_hit_rate": res.cache_hits
                / max(res.cache_hits + res.cache_misses, 1),
            })
    return rows


def run() -> List[Dict]:
    return run_fig4a() + run_fig4bc()
