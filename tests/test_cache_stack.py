"""Stack-distance cache backend + cross-config DRAM batcher: differential
fuzz vs the ChampSim-semantics golden model and bit-exactness guarantees.

The ``stack``/``stack_pallas`` backends are advertised as pure execution-
strategy knobs: every hit/miss, eviction, DRAM row-hit, and finish-cycle
count must be bitwise identical to the scan backend and ``GoldenCache`` —
including adversarial geometries (1 set, 1 way, non-power-of-two ways) and
the Mattson sharing property (every ways value of a grid classified from ONE
distance pass). Likewise ``dram_timing_many`` must equal per-request
dispatch, including the multi-core contended path.
"""
import logging

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from differential import assert_bitwise_equal_results, golden_pair
from repro.core import dlrm_rmc2_small, simulate, sweep, tpuv6e
from repro.core.hardware import OnChipPolicy
from repro.core.memory import stack as stack_mod
from repro.core.memory.cache import (
    CacheGeometry,
    simulate_cache,
    simulate_cache_many,
)
from repro.core.memory.dram import (
    DramModel,
    DramRequest,
    dram_timing_many,
    dram_timing_single,
)
from repro.core.memory.golden import GoldenCache
from repro.core.memory.stack import (
    classify_lru_stack_many,
    distance_pass_count,
    stack_distances_jnp,
    stack_distances_np,
)

GEOMETRIES = [
    (1, 1, 6), (1, 4, 30), (3, 2, 50), (7, 5, 200), (32, 16, 4000),
    (8, 3, 120), (33, 7, 500),          # non-pow2 ways / sets
]


@pytest.mark.parametrize("backend", ["stack", "stack_pallas"])
@pytest.mark.parametrize("sets,ways,space", GEOMETRIES)
def test_stack_bit_exact_vs_golden(backend, sets, ways, space, rng):
    lines = rng.integers(0, space, size=300)
    geom = CacheGeometry(num_sets=sets, ways=ways, line_bytes=64)
    ours = simulate_cache(lines, geom, "lru", backend=backend)
    gold = GoldenCache(geom, "lru")
    gold_hits = gold.run(lines)
    assert np.array_equal(ours.hits, gold_hits)
    assert ours.num_hits == gold.num_hits
    assert ours.num_misses == gold.num_misses
    assert ours.num_evictions == gold.num_evictions


@settings(max_examples=20, deadline=None)
@given(
    sets=st.sampled_from([1, 2, 3, 5, 8, 33, 128]),
    ways=st.sampled_from([1, 2, 3, 4, 7, 16]),
    n=st.integers(1, 400),
    space=st.integers(1, 900),
    seed=st.integers(0, 2**31 - 1),
)
def test_stack_bit_exact_property(sets, ways, n, space, seed):
    lines = np.random.default_rng(seed).integers(0, space, size=n)
    geom = CacheGeometry(num_sets=sets, ways=ways, line_bytes=64)
    ours = simulate_cache(lines, geom, "lru", backend="stack")
    gold = GoldenCache(geom, "lru")
    gold_hits = gold.run(lines)
    assert np.array_equal(ours.hits, gold_hits)
    assert ours.num_evictions == gold.num_evictions


def test_stack_jnp_engine_matches_numpy(rng):
    """The device-resident jnp pass equals the numpy host twin bitwise."""
    for sets in (1, 3, 64):
        lines = rng.integers(0, 5000, size=777).astype(np.int32)
        d_np, b_np = stack_distances_np(lines, sets)
        d_j, b_j = stack_distances_jnp(lines, sets)
        assert np.array_equal(d_np, d_j)
        assert np.array_equal(b_np, b_j)


def test_stack_jnp_engine_end_to_end(rng):
    """classify_lru_stack_many(engine="jnp") equals the numpy engine."""
    stream = rng.integers(0, 3000, size=2000).astype(np.int64)
    geoms = [CacheGeometry(num_sets=s, ways=w, line_bytes=64)
             for s, w in ((16, 4), (16, 8), (64, 3))]
    a = classify_lru_stack_many([stream] * len(geoms), geoms, engine="np")
    b = classify_lru_stack_many([stream] * len(geoms), geoms, engine="jnp")
    for (ha, ea), (hb, eb) in zip(a, b):
        assert np.array_equal(ha, hb)
        assert ea == eb


def test_one_distance_pass_classifies_every_ways(rng):
    """Mattson sharing: all ways values of one (stream, num_sets) classify
    from ONE distance pass, each bit-exact vs an independent golden run."""
    stream = rng.integers(0, 4000, size=3000).astype(np.int64)
    ways_axis = (1, 2, 3, 4, 7, 8, 16)
    geoms = [CacheGeometry(num_sets=32, ways=w, line_bytes=64)
             for w in ways_axis]
    before = distance_pass_count()
    results = simulate_cache_many([stream] * len(geoms), geoms, "lru",
                                  backend="stack")
    assert distance_pass_count() - before == 1       # shared pass
    for geom, res in zip(geoms, results):
        gold = GoldenCache(geom, "lru")
        gold_hits = gold.run(stream)
        assert np.array_equal(res.hits, gold_hits)
        assert res.num_evictions == gold.num_evictions
    # Mattson inclusion: hits grow monotonically with associativity.
    for a, b in zip(results, results[1:]):
        assert not np.any(a.hits & ~b.hits)


def test_stack_backend_analytic_for_non_stack_policies(rng):
    """srrip/fifo under the stack variants run the analytic per-set engines
    (no sequential full-trace scan) and stay bit-exact vs scan."""
    lines = rng.integers(0, 600, size=400)
    geom = CacheGeometry(num_sets=8, ways=4, line_bytes=64)
    for policy in ("srrip", "fifo"):
        for backend in ("stack", "stack_pallas"):
            got = simulate_cache(lines, geom, policy, backend=backend)
            ref = simulate_cache(lines, geom, policy, backend="scan")
            assert np.array_equal(got.hits, ref.hits), (policy, backend)
            assert got.num_evictions == ref.num_evictions


def test_stack_backend_selection_and_no_fallback_warning(caplog):
    """Every policy resolves to an analytic engine under "stack" (the
    srrip/fifo stack->scan fallback — and its warning — is retired);
    "stack_pallas" differs from "stack" only for LRU's distance pass."""
    from repro.core.memory.cache import _effective_backend

    assert _effective_backend("lru", "stack") == "stack"
    assert _effective_backend("lru", "stack_pallas") == "stack_pallas"
    assert _effective_backend("srrip", "stack") == "stack"
    assert _effective_backend("fifo", "stack") == "stack"
    assert _effective_backend("srrip", "stack_pallas") == "stack"
    assert _effective_backend("fifo", "stack_pallas") == "stack"
    assert _effective_backend("fifo", "scan") == "scan"
    assert _effective_backend("srrip", "pallas") == "pallas"

    logger = "repro.core.memory.cache"
    rng = np.random.default_rng(5)
    lines = rng.integers(0, 300, size=256)
    geom = CacheGeometry(num_sets=8, ways=4, line_bytes=64)
    with caplog.at_level(logging.WARNING, logger=logger):
        for policy in ("srrip", "fifo", "lru"):
            simulate_cache(lines, geom, policy, backend="stack")
    assert not [r for r in caplog.records if r.name == logger]


def test_analytic_engines_share_presort_across_ways(rng):
    """rrip sharing: all ways values of one (stream, num_sets) classify from
    ONE compression presort, each bit-exact vs an independent golden run."""
    from repro.core.memory.rrip import analytic_pass_count

    stream = rng.integers(0, 4000, size=3000).astype(np.int64)
    ways_axis = (1, 2, 3, 4, 7, 8, 16)
    geoms = [CacheGeometry(num_sets=32, ways=w, line_bytes=64)
             for w in ways_axis]
    for policy in ("srrip", "fifo"):
        before = analytic_pass_count()
        results = simulate_cache_many([stream] * len(geoms), geoms, policy,
                                      backend="stack")
        assert analytic_pass_count() - before == 1       # shared presort
        for geom, res in zip(geoms, results):
            gold = GoldenCache(geom, policy)
            gold_hits = gold.run(stream)
            assert np.array_equal(res.hits, gold_hits), (policy, geom.ways)
            assert res.num_evictions == gold.num_evictions


@pytest.mark.parametrize("policy", ["srrip", "fifo"])
def test_analytic_engine_corpus_differential(policy):
    """tests/differential.py lock: the analytic srrip/fifo engines are
    bitwise identical to the scan engine across the seeded trace corpus."""
    geoms = [CacheGeometry(num_sets=64, ways=4, line_bytes=64),
             CacheGeometry(num_sets=128, ways=8, line_bytes=64)]

    def classify(backend):
        def run(et):
            stream = et.address_trace(64).lines
            return simulate_cache_many([stream] * len(geoms), geoms,
                                       policy, backend=backend)
        return run

    golden_pair(classify("stack"), classify("scan"),
                label=f"analytic-{policy}")()


def test_sweep_grid_stack_vs_scan_and_independent_simulate():
    """Every grid point under the stack backend equals both the scan-backend
    sweep and an independent simulate() run, bit for bit."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                         lookups=4, batch_size=8, num_batches=2)
    grid = dict(policies=("spm", "lru", "srrip", "fifo"),
                capacities=(1 << 16, 1 << 17), ways=(2, 4),
                zipf_s=0.9, seed=0)
    hw_stack = tpuv6e().with_cache_backend("stack")
    got = sweep(wl, hw_stack, **grid)
    ref = sweep(wl, tpuv6e().with_cache_backend("scan"), **grid)
    assert got.num_configs == ref.num_configs
    for a, b in zip(got.entries, ref.entries):
        assert_bitwise_equal_results(a.result, b.result, label=a.config.label)
    for e in got.entries[:: max(1, got.num_configs // 5)]:
        c = e.config
        hw = hw_stack.with_policy(
            OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes, ways=c.ways
        )
        ind = simulate(wl, hw, seed=0, zipf_s=c.zipf_s)
        assert_bitwise_equal_results(e.result, ind, label=c.label)


def _mk_request(rng, model, nv, num_segments, num_sources, lpv=8):
    base = rng.integers(0, 100_000, size=nv).astype(np.int64) * lpv
    lines = (base[:, None] + np.arange(lpv)[None, :]).reshape(-1)
    seg = np.sort(rng.integers(0, num_segments, size=nv))
    seg = np.repeat(seg, lpv)
    src = np.repeat(rng.integers(0, num_sources, size=nv), lpv)
    return DramRequest(lines, seg, src, num_segments, num_sources, model)


def test_dram_batcher_bit_exact_vs_unbatched(rng):
    """Cross-memo-key batching: every request's DramResults and per-source
    finish matrix equal its unbatched dispatch — including multi-core
    contended requests and empty traces."""
    model = DramModel.from_hardware(tpuv6e())
    reqs = [
        _mk_request(rng, model, 700, 2, 1),
        _mk_request(rng, model, 45, 3, 1),
        _mk_request(rng, model, 400, 2, 4),     # multi-core contended
        DramRequest(np.zeros(0, np.int64), np.zeros(0, np.int64),
                    np.zeros(0, np.int64), 2, 1, model),
        _mk_request(rng, model, 300, 2, 2),
    ]
    batched = dram_timing_many(reqs, batch=True)
    for req, (res_b, fin_b) in zip(reqs, batched):
        res_u, fin_u = dram_timing_single(req)
        assert fin_b.shape == fin_u.shape == (req.num_segments, req.num_sources)
        assert_bitwise_equal_results((res_b, fin_b), (res_u, fin_u))


def test_sweep_batch_dram_flag_bit_exact():
    """batch_dram=False is the unbatched reference path; results identical —
    across single-core AND multi-core cluster grid points."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=1500, dim=128,
                         lookups=4, batch_size=8, num_batches=2)
    grid = dict(policies=("spm", "lru"), capacities=(1 << 16,), ways=(2,),
                zipf_s=0.9, seed=0, num_cores=(1, 2),
                topologies=("private", "shared"))
    a = sweep(wl, tpuv6e(), batch_dram=True, **grid)
    b = sweep(wl, tpuv6e(), batch_dram=False, **grid)
    assert a.num_configs == b.num_configs
    assert_bitwise_equal_results(a, b)


def test_stack_memo_distinguishes_aliasing_views(rng):
    """Two views sharing (pointer, size, dtype) but different strides must
    not share a distance pass."""
    a = rng.integers(0, 50, size=1000).astype(np.int64)
    geom = CacheGeometry(num_sets=4, ways=2, line_bytes=64)
    views = [a[:500], a[::2]]
    got = classify_lru_stack_many(views, [geom, geom])
    for v, (h, ev) in zip(views, got):
        gold = GoldenCache(geom, "lru")
        gold_hits = gold.run(np.ascontiguousarray(v))
        assert np.array_equal(h, gold_hits)
        assert ev == gold.num_evictions


def test_inversion_block_size_keeps_histogram_linear():
    """The radix block grows with n so the (chunk, bucket) histogram stays
    O(n) elements — large traces must not allocate quadratic tables."""
    from repro.core.memory.stack import _block_size

    for n in (1, 100, 46080, 1 << 20, 1 << 24):
        bs = _block_size(n)
        assert bs >= 128 and bs & (bs - 1) == 0
        blocks = -(-n // bs)
        assert blocks * blocks <= max(16 * n, 128 * 128)
    # and the count stays exact at a non-default block size
    rng = np.random.default_rng(3)
    v = rng.permutation(3000).astype(np.int32)
    from repro.core.memory.stack import _inv_prev_larger_np

    ref = _inv_prev_larger_np(v, bs=128)
    for bs in (256, 512):
        assert np.array_equal(_inv_prev_larger_np(v, bs=bs), ref)


def test_stack_rejects_out_of_range_lines():
    geom = CacheGeometry(num_sets=4, ways=2, line_bytes=64)
    with pytest.raises(ValueError, match="int32"):
        simulate_cache(np.array([2**40]), geom, "lru", backend="stack")


def test_stack_empty_and_single_access():
    geom = CacheGeometry(num_sets=4, ways=2, line_bytes=64)
    res = simulate_cache(np.zeros(0, dtype=np.int64), geom, "lru",
                         backend="stack")
    assert res.accesses == 0 and res.num_evictions == 0
    res1 = simulate_cache(np.array([5]), geom, "lru", backend="stack")
    assert res1.num_misses == 1 and not res1.hits[0]


def test_multicore_cluster_stack_backend_bit_exact():
    """Cluster topologies under the stack backend equal the scan backend
    (shared-LLC classification + contended DRAM downstream of it)."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=1500, dim=128,
                         lookups=4, batch_size=8, num_batches=2)
    base = tpuv6e().with_policy("lru", capacity_bytes=1 << 16, ways=2)
    for cores, topo in ((2, "shared"), (2, "private")):
        hw = base.with_cluster(cores, topo)
        got = simulate(wl, hw.with_cache_backend("stack"), seed=0, zipf_s=0.9)
        ref = simulate(wl, hw.with_cache_backend("scan"), seed=0, zipf_s=0.9)
        assert_bitwise_equal_results(got, ref, label=f"{cores}c-{topo}")
