"""Serving correctness: prefill+decode == full forward for every family;
engine generation determinism."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import family_module, get_smoke_config
from repro.models import transformer as T
from repro.serving import ServeConfig, ServingEngine

KEY = jax.random.PRNGKey(0)

# bf16 params + bf16 kv caches with f32 accumulation: |logit| ~ 5-10 gives
# ~0.04-0.08 representable steps; tolerances sized to bf16, not to luck
TOL = {
    "stablelm_3b": 8e-2, "granite_34b": 8e-2, "command_r_plus_104b": 8e-2,
    "chameleon_34b": 8e-2, "arctic_480b": 8e-2, "deepseek_v2_lite_16b": 8e-2,
    "mamba2_130m": 8e-2, "zamba2_2p7b": 8e-2, "whisper_base": 8e-2,
}


@pytest.mark.parametrize("arch", list(TOL))
def test_prefill_decode_matches_forward(arch):
    cfg = get_smoke_config(arch)
    mod = family_module(cfg)
    B, S = 2, 24
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "audio":
        params = mod.init_model(KEY, cfg)
        frames = jax.random.normal(KEY, (B, cfg.encdec.encoder_seq, cfg.d_model),
                                   dtype=jnp.bfloat16)
        full = mod.forward(params, tokens, frames, cfg)
        enc = mod.encode(params, frames, cfg)
        caches = mod.init_kv_cache(cfg, B, 64)
        _, caches = mod.decode_step(params, tokens[:, :S - 1], jnp.int32(0),
                                    caches, enc, cfg, prefill=True)
        last, _ = mod.decode_step(params, tokens[:, S - 1:], jnp.int32(S - 1),
                                  caches, enc, cfg)
    else:
        params = mod.init_lm(KEY, cfg)
        full = mod.forward(params, tokens, cfg)
        if cfg.family == "ssm":
            _, caches = mod.prefill_with_state(params, tokens[:, :S - 1], cfg)
            last, _ = mod.decode_step(params, tokens[:, S - 1:], jnp.int32(S - 1),
                                      caches, cfg)
        elif cfg.family == "hybrid":
            _, caches = mod.prefill_with_state(params, tokens[:, :S - 1], cfg,
                                               max_seq=64)
            last, _ = mod.decode_step(params, tokens[:, S - 1:], jnp.int32(S - 1),
                                      caches, cfg)
        else:
            caches = T.init_kv_cache(cfg, B, 64)
            _, caches = T.prefill(params, tokens[:, :S - 1], caches, cfg)
            last, _ = T.decode_step(params, tokens[:, S - 1:], jnp.int32(S - 1),
                                    caches, cfg)
    err = np.max(np.abs(np.asarray(last[:, -1], np.float32)
                        - np.asarray(full[:, -1], np.float32)))
    assert err < TOL[arch], err


@pytest.mark.parametrize("arch", ["stablelm_3b", "mamba2_130m"])
def test_engine_generates_deterministically(arch):
    cfg = get_smoke_config(arch)
    mod = family_module(cfg)
    params = mod.init_lm(KEY, cfg)
    scfg = ServeConfig(batch=2, max_seq=48)
    engine = ServingEngine(cfg, params, scfg)
    prompts = np.random.default_rng(0).integers(0, cfg.vocab, (2, 8), dtype=np.int32)
    a = engine.generate(prompts, max_new_tokens=8)
    b = engine.generate(prompts, max_new_tokens=8)
    assert a.shape == (2, 8)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < cfg.vocab
