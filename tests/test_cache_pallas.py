"""Pallas cache-scan kernel: differential fuzz vs the ChampSim-semantics
golden model, plus backend-equivalence checks through the policy layer.

The Pallas kernel (kernels/cache_scan.py) must be bit-exact with
``GoldenCache`` for every policy and for adversarial geometries — 1 set,
1 way, non-power-of-two set counts — because ``cache_backend="pallas"`` is
advertised as a pure execution-strategy knob that can never change results.
Interpret mode executes each access as Python, so the fuzz sizes stay small.
"""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.memory.cache import CacheGeometry, simulate_cache
from repro.core.memory.golden import GoldenCache
from repro.kernels.cache_scan import cache_scan_groups

POLICIES = ["lru", "srrip", "fifo"]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize(
    "sets,ways,space",
    [(1, 1, 6), (1, 4, 30), (3, 2, 50), (7, 5, 200), (32, 16, 4000)],
)
def test_pallas_bit_exact_vs_golden(policy, sets, ways, space, rng):
    lines = rng.integers(0, space, size=300)
    geom = CacheGeometry(num_sets=sets, ways=ways, line_bytes=64)
    ours = simulate_cache(lines, geom, policy, backend="pallas")
    gold = GoldenCache(geom, policy)
    gold_hits = gold.run(lines)
    assert np.array_equal(ours.hits, gold_hits)
    assert ours.num_hits == gold.num_hits
    assert ours.num_misses == gold.num_misses
    assert ours.num_evictions == gold.num_evictions


@settings(max_examples=15, deadline=None)
@given(
    policy=st.sampled_from(POLICIES),
    sets=st.sampled_from([1, 2, 3, 5, 8, 33]),
    ways=st.sampled_from([1, 2, 4, 7]),
    n=st.integers(20, 150),
    space=st.integers(4, 600),
    seed=st.integers(0, 2**31 - 1),
)
def test_pallas_bit_exact_property(policy, sets, ways, n, space, seed):
    lines = np.random.default_rng(seed).integers(0, space, size=n)
    geom = CacheGeometry(num_sets=sets, ways=ways, line_bytes=64)
    ours = simulate_cache(lines, geom, policy, backend="pallas")
    gold_hits = GoldenCache(geom, policy).run(lines)
    assert np.array_equal(ours.hits, gold_hits)


@pytest.mark.parametrize("policy", POLICIES)
def test_pallas_matches_scan_backend(policy, rng):
    """The two backends are interchangeable through the public surface."""
    lines = rng.integers(0, 2000, size=400)
    geom = CacheGeometry(num_sets=16, ways=4, line_bytes=64)
    scan = simulate_cache(lines, geom, policy, backend="scan")
    pal = simulate_cache(lines, geom, policy, backend="pallas")
    assert np.array_equal(scan.hits, pal.hits)
    assert scan.num_evictions == pal.num_evictions


@pytest.mark.parametrize("policy", POLICIES)
def test_pallas_batched_groups_match_scan(policy, rng):
    """Direct kernel call with a padded batch of sub-traces (the bucketed
    layout the cache engine dispatches): per-row results must match the
    golden-checked scan engine, and the padded tail must stay inert."""
    import jax.numpy as jnp

    from repro.core.memory.cache import _simulate_many

    S, W, B, L = 4, 2, 3, 64
    s_b = rng.integers(0, S, size=(B, L)).astype(np.int32)
    t_b = rng.integers(0, 500, size=(B, L)).astype(np.int32)
    v_b = np.ones((B, L), dtype=bool)
    v_b[:, 50:] = False              # padded tail must not touch state
    hits, evicts = cache_scan_groups(s_b, t_b, v_b, S, W, policy)
    hits, evicts = np.asarray(hits), np.asarray(evicts)
    assert not hits[:, 50:].any()
    assert not evicts[:, 50:].any()
    h_ref, e_ref = _simulate_many(
        jnp.asarray(s_b), jnp.asarray(t_b), jnp.asarray(v_b), S, W, policy
    )
    assert np.array_equal(hits, np.asarray(h_ref))
    assert np.array_equal(evicts, np.asarray(e_ref))
