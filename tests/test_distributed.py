"""Sharding rules, collective matmul, DLRM model, data pipeline, HLO
analyzer, matrix model, energy, oracle, lm_mapper."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.core import MatrixOpSpec, tpuv6e
from repro.core.energy import estimate_energy
from repro.core.matrix_model import matrix_compute_cycles, simulate_matrix_op
from repro.distributed import batch_spec, param_specs
from repro.distributed.collective_matmul import psum_matmul, ring_matmul
from repro.distributed.sharding import greedy_spec
from repro.launch.hlo_analysis import analyze
from repro.models import get_smoke_config, family_module
from repro.models.config import SHAPES_BY_NAME

KEY = jax.random.PRNGKey(0)


# --------------------------------------------------------------------------
# sharding rules
# --------------------------------------------------------------------------

def _fake_mesh_16x16():
    # abstract mesh for spec computation only (no allocation happens)
    import types
    m = types.SimpleNamespace()
    m.axis_names = ("data", "model")
    m.devices = np.empty((16, 16), dtype=object)
    return m


def test_param_specs_2d_fsdp_tp():
    cfg = get_smoke_config("stablelm_3b").replace(
        d_model=256, n_heads=16, n_kv_heads=16, head_dim=16, d_ff=512, vocab=4096
    )
    mod = family_module(cfg)
    shapes = jax.eval_shape(lambda: mod.init_lm(KEY, cfg))
    specs = param_specs(shapes, _fake_mesh_16x16())
    # stacked layers: leading None then (data, model) for up-proj
    assert specs["layers"]["attn"]["wq"] == P(None, "data", "model")
    assert specs["layers"]["attn"]["wo"] == P(None, "model", "data")
    assert specs["layers"]["mlp"]["wd"] == P(None, "model", "data")
    assert specs["embed"]["table"] == P("model", "data")
    assert specs["head"]["w"] == P("data", "model")
    assert specs["final_norm"]["scale"] == P(None)


def test_param_specs_divisibility_fallback():
    cfg = get_smoke_config("stablelm_3b")  # tiny dims not divisible by 16
    mod = family_module(cfg)
    shapes = jax.eval_shape(lambda: mod.init_lm(KEY, cfg))
    specs = param_specs(shapes, _fake_mesh_16x16())
    wq = specs["layers"]["attn"]["wq"]
    assert all(ax in (None, "data", "model") for ax in wq)


def test_moe_expert_specs():
    cfg = get_smoke_config("arctic_480b").replace(d_model=256, d_ff=512)
    import dataclasses
    cfg = cfg.replace(moe=dataclasses.replace(cfg.moe, num_experts=32, d_ff_expert=512))
    mod = family_module(cfg)
    shapes = jax.eval_shape(lambda: mod.init_lm(KEY, cfg))
    specs = param_specs(shapes, _fake_mesh_16x16())
    assert specs["layers"]["moe"]["wg"] == P(None, "model", "data", None)
    assert specs["layers"]["moe"]["wd"] == P(None, "model", None, "data")


def test_batch_spec_modes():
    mesh = _fake_mesh_16x16()
    assert batch_spec(SHAPES_BY_NAME["train_4k"], mesh) == P("data", None)
    # long_500k: batch=1 -> sequence parallelism
    assert batch_spec(SHAPES_BY_NAME["long_500k"], mesh) == P(None, "data")


def test_greedy_spec():
    mesh = _fake_mesh_16x16()
    s = greedy_spec((24, 128, 80, 64, 64), mesh,
                    [(1, "data"), (2, "model"), (3, "model")])
    assert s == P(None, "data", "model", None, None)
    s2 = greedy_spec((4, 2, 7, 13), mesh, [(2, "data"), (3, "model")])
    assert s2 == P(None, None, None, None)


# --------------------------------------------------------------------------
# collective matmul (1-device mesh: semantics, not speed)
# --------------------------------------------------------------------------

def test_ring_matmul_matches_psum(rng):
    mesh = jax.make_mesh((1,), ("model",))
    x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
    w = jnp.asarray(rng.standard_normal((16, 32)), jnp.float32)
    a = ring_matmul(x, w, mesh, axis="model")
    b = psum_matmul(x, w, mesh, axis="model")
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(x @ w), atol=1e-5)


# --------------------------------------------------------------------------
# HLO analyzer
# --------------------------------------------------------------------------

def test_hlo_analyzer_trip_counts():
    D = 64
    w = jnp.ones((4, D, D), jnp.float32)
    x = jnp.ones((8, D), jnp.float32)

    def scanned(x, w):
        return jax.lax.scan(lambda c, wi: (c @ wi, None), x, w)[0]

    def unrolled(x, w):
        for i in range(4):
            x = x @ w[i]
        return x

    fs = analyze(jax.jit(scanned).lower(x, w).compile().as_text()).flops
    fu = analyze(jax.jit(unrolled).lower(x, w).compile().as_text()).flops
    true = 4 * 2 * 8 * D * D
    assert abs(fs - true) / true < 0.05
    assert abs(fu - true) / true < 0.05


def test_hlo_analyzer_collectives():
    from repro.distributed.collective_matmul import _shard_map

    mesh = jax.make_mesh((1,), ("data",))

    def f(x):
        return _shard_map(lambda a: jax.lax.psum(a @ a.T, "data"), mesh=mesh,
                          in_specs=P("data", None), out_specs=P(None, None))(x)

    c = analyze(jax.jit(f).lower(jnp.ones((8, 64))).compile().as_text())
    assert c.collectives.get("all-reduce", 0) == 8 * 8 * 4


# --------------------------------------------------------------------------
# analytical matrix model / energy / dlrm / data
# --------------------------------------------------------------------------

def test_matrix_model_hand_computed():
    hw = tpuv6e()
    # single fold WS: K_t=256 fills, M=64 streams, C_t=256 drain
    op = MatrixOpSpec(m=64, n=256, k=256)
    cycles = matrix_compute_cycles(op, hw)
    assert cycles == 256 + 64 + 256 + 256 - 2
    # two folds along K
    op2 = MatrixOpSpec(m=64, n=256, k=512)
    assert matrix_compute_cycles(op2, hw) == 2 * cycles


def test_matrix_model_invariants():
    """The WS fold model charges weight fills as array-occupied cycles, so
    compute >= fill time always; totals overlap double-buffered memory; and
    streaming more rows amortizes the fill (higher utilization)."""
    hw = tpuv6e()
    tall = simulate_matrix_op(MatrixOpSpec(m=8192, n=256, k=256), hw)
    fat = simulate_matrix_op(MatrixOpSpec(m=8, n=256, k=256), hw)
    for r in (tall, fat):
        assert r.total_cycles >= max(r.compute_cycles, r.memory_cycles)
    # utilization = flops/cycle: tall amortizes the 256-cycle weight fill
    assert tall.utilization > fat.utilization * 4


def test_energy_monotone():
    hw = tpuv6e()
    e1 = estimate_energy(hw, macs=1e9, vector_ops=1e6, onchip_read_bytes=1e8,
                         onchip_write_bytes=1e8, offchip_bytes=1e9, total_cycles=1e6)
    e2 = estimate_energy(hw, macs=1e9, vector_ops=1e6, onchip_read_bytes=1e8,
                         onchip_write_bytes=1e8, offchip_bytes=2e9, total_cycles=1e6)
    assert e2.total_pj > e1.total_pj
    assert e2.offchip_pj == 2 * e1.offchip_pj


def test_dlrm_forward_and_loss(rng):
    from repro.models import dlrm

    cfg = dlrm.smoke_config()
    params = dlrm.init(KEY, cfg)
    B = 8
    dense = jnp.asarray(rng.standard_normal((B, cfg.dense_features)), jnp.float32)
    sparse = jnp.asarray(
        rng.integers(0, cfg.rows_per_table, (B, cfg.num_tables, cfg.lookups_per_table)),
        jnp.int32,
    )
    out = dlrm.forward(params, dense, sparse, cfg)
    assert out.shape == (B,)
    loss = dlrm.bce_loss(out, jnp.ones(B))
    assert np.isfinite(float(loss))
    # pallas path agrees
    out_p = dlrm.forward(params, dense, sparse, cfg, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out_p), atol=1e-4)


def test_lm_data_pipeline_deterministic_and_learnable():
    from repro.data import LMDataConfig, lm_batch

    cfg = LMDataConfig(vocab=256, seq_len=32, global_batch=4, seed=1)
    a, b = lm_batch(cfg, 5), lm_batch(cfg, 5)
    assert np.array_equal(a["tokens"], b["tokens"])
    c = lm_batch(cfg, 6)
    assert not np.array_equal(a["tokens"], c["tokens"])
    assert a["tokens"].shape == (4, 32)
    np.testing.assert_array_equal(a["labels"][:, :-1], a["tokens"][:, 1:])


def test_lm_mapper_produces_sane_workload():
    from repro.core.lm_mapper import lm_workload
    from repro.models import get_config

    cfg = get_config("stablelm_3b")
    wl = lm_workload(cfg, SHAPES_BY_NAME["train_4k"])
    # 6ND rule: mapper matrix flops within 2x of 6 * params * tokens
    six_nd = 6 * 2.8e9 * 256 * 4096
    assert 0.4 < wl.matrix_flops / six_nd < 2.5
    assert wl.embedding_ops[0].rows_per_table == cfg.vocab
