"""Graceful hypothesis fallback so the suite collects everywhere.

Prefer the real ``hypothesis`` (pinned in requirements-dev.txt). When it is
not installed, provide a minimal deterministic stand-in: ``@given`` runs
``max_examples`` seeded pseudo-random draws of each strategy instead of
hypothesis's adaptive search. Weaker shrinking/coverage, but the property
tests still execute — import failure no longer takes down collection of the
whole module (tier-1 requirement).

Only the strategy surface the test-suite uses is implemented:
``st.integers(lo, hi)`` and ``st.sampled_from(seq)``.
"""
from __future__ import annotations

try:
    from hypothesis import given, settings, strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import zlib

    import numpy as np

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def example(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = lo, hi

        def example(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def example(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class st:  # noqa: N801 — mirrors `strategies as st`
        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

    def given(**strategies):
        def deco(fn):
            def wrapper():
                n = getattr(wrapper, "_max_examples", 20)
                # Stable per-test seed: same draws on every run/machine.
                rng = np.random.default_rng(zlib.crc32(fn.__name__.encode()))
                for _ in range(n):
                    fn(**{k: s.example(rng) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            # Zero-arg signature so pytest doesn't treat the strategy
            # parameters as fixtures.
            wrapper.__signature__ = inspect.Signature()
            wrapper._max_examples = 20
            return wrapper

        return deco

    def settings(max_examples=20, **_ignored):
        def deco(fn):
            if hasattr(fn, "_max_examples"):
                fn._max_examples = max_examples
            return fn

        return deco
