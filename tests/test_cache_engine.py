"""Cache engine: bit-exactness vs the ChampSim-semantics golden model
(reproduces the paper's Fig. 4a claim of identical hit/miss counts) +
property tests on cache invariants."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.memory.cache import CacheGeometry, simulate_cache
from repro.core.memory.golden import GoldenCache

POLICIES = ["lru", "srrip", "fifo"]


@pytest.mark.parametrize("policy", POLICIES)
@pytest.mark.parametrize(
    "sets,ways,space",
    [(4, 2, 64), (16, 4, 800), (1, 8, 40), (8, 16, 4096), (64, 4, 3000), (128, 8, 50000)],
)
def test_bit_exact_vs_golden(policy, sets, ways, space, rng):
    lines = rng.integers(0, space, size=3000)
    geom = CacheGeometry(num_sets=sets, ways=ways, line_bytes=64)
    ours = simulate_cache(lines, geom, policy)
    gold = GoldenCache(geom, policy)
    gold_hits = gold.run(lines)
    assert np.array_equal(ours.hits, gold_hits)
    assert ours.num_hits == gold.num_hits
    assert ours.num_misses == gold.num_misses
    assert ours.num_evictions == gold.num_evictions


@settings(max_examples=25, deadline=None)
@given(
    policy=st.sampled_from(POLICIES),
    sets=st.sampled_from([1, 2, 8, 32, 64]),
    ways=st.sampled_from([1, 2, 4, 16]),
    n=st.integers(50, 400),
    space=st.integers(8, 2000),
    seed=st.integers(0, 2**31 - 1),
)
def test_bit_exact_property(policy, sets, ways, n, space, seed):
    lines = np.random.default_rng(seed).integers(0, space, size=n)
    geom = CacheGeometry(num_sets=sets, ways=ways, line_bytes=64)
    ours = simulate_cache(lines, geom, policy)
    gold_hits = GoldenCache(geom, policy).run(lines)
    assert np.array_equal(ours.hits, gold_hits)


@settings(max_examples=15, deadline=None)
@given(
    n=st.integers(100, 500),
    space=st.integers(10, 500),
    seed=st.integers(0, 2**31 - 1),
)
def test_lru_inclusion_property(n, space, seed):
    """Fully-associative LRU inclusion: every hit at capacity C is a hit at
    capacity 2C (stack property of LRU)."""
    lines = np.random.default_rng(seed).integers(0, space, size=n)
    small = simulate_cache(lines, CacheGeometry(1, 16, 64), "lru")
    big = simulate_cache(lines, CacheGeometry(1, 32, 64), "lru")
    assert not np.any(small.hits & ~big.hits)


def test_first_access_always_misses(rng):
    lines = rng.permutation(200)  # all distinct
    res = simulate_cache(lines, CacheGeometry(8, 4, 64), "lru")
    assert res.num_hits == 0


def test_repeat_within_capacity_hits():
    lines = np.tile(np.arange(16), 4)  # 16 distinct lines, 4 passes
    res = simulate_cache(lines, CacheGeometry(4, 8, 64), "lru")
    # 32 lines capacity >= 16 distinct: everything after pass 1 hits
    assert res.num_misses == 16
    assert res.num_hits == 48


def test_hits_bounded_by_accesses(rng):
    lines = rng.integers(0, 100, size=500)
    res = simulate_cache(lines, CacheGeometry(8, 2, 64), "srrip")
    assert 0 <= res.num_hits <= 500
    assert res.num_hits + res.num_misses == 500
