"""DSE sweep engine: per-config bit-exactness vs independent simulate(),
grid coverage, helpers."""
import numpy as np
import pytest

from repro.core import (
    OnChipPolicy,
    dlrm_rmc2_small,
    simulate,
    sweep,
    tpuv6e,
)

POLICIES = ("spm", "lru", "srrip", "pinning")
CAPACITIES = (1 << 16, 1 << 17, 1 << 18)
WAYS = (4, 8)


@pytest.fixture(scope="module")
def small_wl():
    return dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                           lookups=4, batch_size=8, num_batches=2)


@pytest.fixture(scope="module")
def grid_result(small_wl):
    return sweep(small_wl, tpuv6e(), policies=POLICIES, capacities=CAPACITIES,
                 ways=WAYS, zipf_s=0.9, seed=0)


def test_sweep_covers_full_grid(grid_result, small_wl):
    assert grid_result.num_configs == len(POLICIES) * len(CAPACITIES) * len(WAYS)
    seen = {(e.config.policy, e.config.capacity_bytes, e.config.ways)
            for e in grid_result.entries}
    assert len(seen) == grid_result.num_configs
    assert all(e.config.workload == small_wl.name for e in grid_result.entries)


def test_sweep_bitexact_vs_independent_simulate(grid_result, small_wl):
    """Acceptance criterion: every one of the >=24 grid points is bit-exact
    against an independent simulate() run with the same seed."""
    assert grid_result.num_configs >= 24
    for e in grid_result.entries:
        c = e.config
        hw = tpuv6e().with_policy(OnChipPolicy(c.policy),
                                  capacity_bytes=c.capacity_bytes, ways=c.ways)
        ref = simulate(small_wl, hw, seed=0, zipf_s=c.zipf_s)
        assert not e.result.diff(ref), (c.label, e.result.diff(ref))


def test_sweep_best_and_rows(grid_result):
    best = grid_result.best("total_cycles")
    assert all(best.result.total_cycles <= e.result.total_cycles
               for e in grid_result.entries)
    rows = grid_result.rows()
    assert len(rows) == grid_result.num_configs
    assert {"policy", "capacity_bytes", "ways", "total_cycles"} <= set(rows[0])


def test_sweep_speedup_over_baseline(grid_result):
    rows = grid_result.speedup_over("spm")
    assert len(rows) == grid_result.num_configs  # spm present at every point
    for r in rows:
        if r["policy"] == "spm":
            assert r["speedup_vs_spm"] == pytest.approx(1.0)


def test_sweep_zipf_axis(small_wl):
    sr = sweep(small_wl, tpuv6e(), policies=("spm", "lru"),
               capacities=(1 << 17,), ways=(8,), zipf_s=(0.7, 1.1), seed=0)
    assert sr.num_configs == 4
    assert {e.config.zipf_s for e in sr.entries} == {0.7, 1.1}
    # higher skew -> more reuse -> LRU hit rate improves
    lru = {e.config.zipf_s: e.result for e in sr.entries if e.config.policy == "lru"}
    hr = lambda r: r.cache_hits / max(r.cache_hits + r.cache_misses, 1)
    assert hr(lru[1.1]) > hr(lru[0.7])


def test_sweep_rejects_unknown_policy(small_wl):
    with pytest.raises(ValueError, match="unregistered"):
        sweep(small_wl, tpuv6e(), policies=("spm", "mru"))


def test_sweep_json_roundtrip(grid_result, tmp_path):
    import json
    p = tmp_path / "sweep.json"
    grid_result.to_json(str(p))
    payload = json.loads(p.read_text())
    assert payload["num_configs"] == grid_result.num_configs
    assert len(payload["rows"]) == grid_result.num_configs
