"""Request-level serving simulation: generator determinism, the all-off
identity vs the plain fixed-trace path, inert-policy identities, reproducible
overload (shed/timeout/retry-storm/degradation), and scenario-sweep
composition with sharding/checkpointing/fault-injection."""
import dataclasses
import os

import numpy as np
import pytest

from differential import assert_bitwise_equal_results
from repro.core import (
    FaultEvent,
    FaultPlan,
    FaultTelemetry,
    TrafficConfig,
    Workload,
    generate_arrivals,
    generate_requests,
    sweep,
    tpuv6e,
)
from repro.core.memory.system import EmbeddingTrace, MultiCoreMemorySystem
from repro.core.requests import hot_table_set, lower_batch
from repro.core.trace import ConcatTrace
from repro.core.workload import EmbeddingOpSpec
from repro.serving import (
    ReplayOracle,
    RobustnessPolicy,
    ServingScenario,
    simulate_serving,
)

SPEC = EmbeddingOpSpec(
    num_tables=4, rows_per_table=1000, dim=32, lookups_per_sample=4,
    dtype_bytes=4,
)
WL = Workload(name="serve_wl", embedding_ops=(SPEC,))
HW = tpuv6e()

STEADY = TrafficConfig(pattern="poisson", mean_gap_cycles=700.0,
                       num_requests=48, seed=11)
# Arrival rate far above service capacity: the overload regime every
# robustness policy exists for.
OVERLOAD = TrafficConfig(pattern="bursty", mean_gap_cycles=40.0,
                         num_requests=80, seed=23, burst_len=10)


def _ms():
    return MultiCoreMemorySystem.from_hardware(HW)


def _serve(scenario, **kw):
    return simulate_serving(_ms(), SPEC, scenario, **kw)


# --------------------------------------------------------------------------
# Request generators
# --------------------------------------------------------------------------

class TestGenerators:
    @pytest.mark.parametrize("pattern", ["poisson", "diurnal", "bursty"])
    def test_arrivals_sorted_deterministic(self, pattern):
        cfg = TrafficConfig(pattern=pattern, mean_gap_cycles=100.0,
                            num_requests=64, seed=3)
        a, b = generate_arrivals(cfg), generate_arrivals(cfg)
        assert np.array_equal(a, b)
        assert a.dtype == np.int64
        assert np.all(np.diff(a) >= 0)
        assert a[0] >= 0
        c = generate_arrivals(dataclasses.replace(cfg, seed=4))
        assert not np.array_equal(a, c)

    def test_requests_deterministic_and_in_range(self):
        cfg = TrafficConfig(num_requests=32, seed=5, tables_per_request=2,
                            lookups_per_table=3, zipf_drift=0.6,
                            drift_period=8)
        r1, r2 = generate_requests(SPEC, cfg), generate_requests(SPEC, cfg)
        assert len(r1) == 32
        for a, b in zip(r1, r2):
            assert a.rid == b.rid and a.arrival == b.arrival
            assert np.array_equal(a.table_ids, b.table_ids)
            assert np.array_equal(a.rows, b.rows)
            assert np.array_equal(a.ranks, b.ranks)
            assert a.rows.shape == (2, 3)
            assert a.rows.min() >= 0 and a.rows.max() < SPEC.rows_per_table
            assert np.array_equal(a.table_ids, np.sort(a.table_ids))

    def test_popularity_drift_rotates_hot_rows(self):
        """drift_period re-draws the rank->row permutation: the same rank
        maps to different rows across epochs."""
        cfg = TrafficConfig(num_requests=32, seed=7, drift_period=16,
                            zipf_s=1.2)
        reqs = generate_requests(SPEC, cfg)
        # epoch 0 = requests [0,16), epoch 1 = [16,32); compare the row that
        # rank 0 maps to in each (rank 0 occurs often under zipf 1.2)
        def rank0_rows(rs):
            out = set()
            for r in rs:
                hit = r.ranks == 0
                out.update(r.rows[hit].tolist())
            return out
        e0, e1 = rank0_rows(reqs[:16]), rank0_rows(reqs[16:])
        assert e0 and e1 and e0 != e1

    def test_hot_table_set_deterministic(self):
        cfg = TrafficConfig(num_requests=24, seed=9, tables_per_request=2)
        reqs = generate_requests(SPEC, cfg)
        h1 = hot_table_set(reqs, SPEC, 0.5)
        h2 = hot_table_set(reqs, SPEC, 0.5)
        assert np.array_equal(h1, h2)
        assert h1.sum() == 2

    def test_traffic_validation(self):
        with pytest.raises(ValueError):
            TrafficConfig(pattern="lunar")
        with pytest.raises(ValueError):
            TrafficConfig(num_requests=0)
        with pytest.raises(ValueError):
            generate_requests(SPEC, TrafficConfig(tables_per_request=99))


# --------------------------------------------------------------------------
# Identity: policies off == plain fixed-trace path
# --------------------------------------------------------------------------

class TestIdentity:
    def test_all_off_equals_plain_simulate_embedding(self):
        """The whole point: with every policy off, the serving simulator's
        per-batch stats ARE one plain ``simulate_embedding`` call over the
        arrival-order lowered ConcatTrace — bitwise."""
        sc = ServingScenario(name="steady", traffic=STEADY, batch_slots=8)
        res = _serve(sc)
        reqs = generate_requests(SPEC, STEADY)
        lowered = [lower_batch(reqs[i:i + 8], SPEC)
                   for i in range(0, len(reqs), 8)]
        plain = _ms().simulate_embedding(EmbeddingTrace.from_concat(
            SPEC, ConcatTrace.from_traces([b.full for b in lowered])))
        assert_bitwise_equal_results(res.batch_stats, plain,
                                     "all-off vs plain")
        assert res.offered == res.completed == len(reqs)
        assert res.shed == res.timed_out == res.retries == 0
        assert res.degraded_batches == 0
        assert res.goodput == 1.0

    @pytest.mark.parametrize("policy", [
        RobustnessPolicy(admission_watermark=10**9),
        RobustnessPolicy(deadline_cycles=10**12),
        RobustnessPolicy(max_retries=3),
        RobustnessPolicy(degrade_mode="hot_rows_only",
                         degrade_watermark=10**9),
        RobustnessPolicy(degrade_mode="cache_bypass",
                         degrade_watermark=10**9),
    ])
    def test_inert_policy_is_identity(self, policy):
        """A policy that is armed but never triggers leaves no trace: the
        sequential closed-loop path lands bitwise on the all-off fast path
        (this is also the prefix-causality proof — the sequential oracle
        re-simulates growing prefixes and must reproduce the one-shot
        batched stats exactly)."""
        base = _serve(ServingScenario(name="s", traffic=STEADY,
                                      batch_slots=8))
        got = _serve(ServingScenario(name="s", traffic=STEADY, policy=policy,
                                     batch_slots=8))
        assert_bitwise_equal_results(base, got, "inert policy")

    def test_partial_final_batch(self):
        """Request count not divisible by batch_slots: the final partial
        batch is served, nothing lost."""
        cfg = dataclasses.replace(STEADY, num_requests=21)
        res = _serve(ServingScenario(name="p", traffic=cfg, batch_slots=8))
        assert res.completed == 21
        assert res.num_batches == 3


# --------------------------------------------------------------------------
# Reproducible overload
# --------------------------------------------------------------------------

STORM_POLICY = RobustnessPolicy(
    admission_watermark=12, deadline_cycles=25_000, max_retries=2,
    retry_backoff_cycles=2_000.0,
)


class TestOverload:
    def test_overload_triggers_all_counters(self):
        sc = ServingScenario(name="storm", traffic=OVERLOAD,
                             policy=STORM_POLICY, batch_slots=8)
        res = _serve(sc)
        assert res.shed > 0
        assert res.retries > 0
        # conservation: every failed attempt either retries or abandons
        assert res.shed + res.timed_out == res.retries + res.abandoned
        assert res.makespan_cycles > 0

    def test_retry_storm_bitwise_reproducible(self):
        sc = ServingScenario(name="storm", traffic=OVERLOAD,
                             policy=STORM_POLICY, batch_slots=8)
        assert_bitwise_equal_results(_serve(sc), _serve(sc), "retry storm")

    @pytest.mark.parametrize("mode", ["hot_rows_only", "cache_bypass"])
    def test_degradation_bitwise_reproducible(self, mode):
        pol = RobustnessPolicy(degrade_mode=mode, degrade_watermark=2,
                               hot_fraction=0.2, bypass_keep_tables=0.5)
        sc = ServingScenario(name="deg", traffic=OVERLOAD, policy=pol,
                             batch_slots=8)
        a, b = _serve(sc), _serve(sc)
        assert_bitwise_equal_results(a, b, f"degradation {mode}")
        assert a.degraded_batches > 0
        if mode == "hot_rows_only":
            assert a.dropped_cold_rows > 0
        else:
            assert a.bypassed_lookups > 0
        # degradation sheds work, it never sheds requests
        assert a.completed == a.offered

    def test_deadline_timeouts_fire(self):
        pol = RobustnessPolicy(deadline_cycles=1_500)
        sc = ServingScenario(name="ddl", traffic=OVERLOAD, policy=pol,
                             batch_slots=8)
        res = _serve(sc)
        assert res.timed_out > 0
        assert res.completed + res.timed_out == res.offered
        assert res.goodput < 1.0

    def test_policy_validation(self):
        with pytest.raises(ValueError):
            RobustnessPolicy(degrade_mode="pray")
        with pytest.raises(ValueError):
            RobustnessPolicy(max_retries=-1)
        with pytest.raises(ValueError):
            ServingScenario(name="x", traffic=STEADY, batch_slots=0)


# --------------------------------------------------------------------------
# Replay oracle (checkpoint reconstruction seam)
# --------------------------------------------------------------------------

class TestReplay:
    def test_replay_reconstructs_bitwise(self):
        sc = ServingScenario(name="storm", traffic=OVERLOAD,
                             policy=STORM_POLICY, batch_slots=8)
        live = _serve(sc)
        replayed = _serve(sc, oracle=ReplayOracle(live.batch_stats))
        assert_bitwise_equal_results(live, replayed, "replay")

    def test_replay_misuse_raises(self):
        sc = ServingScenario(name="s", traffic=STEADY, batch_slots=8)
        live = _serve(sc)
        with pytest.raises(RuntimeError, match="exhausted"):
            _serve(sc, oracle=ReplayOracle(live.batch_stats[:-1]))
        with pytest.raises(RuntimeError, match="undrained"):
            _serve(sc, oracle=ReplayOracle(live.batch_stats
                                           + live.batch_stats[-1:]))


# --------------------------------------------------------------------------
# Scenario axis in sweep(): sharding / checkpoint / fault composition
# --------------------------------------------------------------------------

SCENARIOS = [
    ServingScenario(name="steady", traffic=STEADY, batch_slots=8),
    ServingScenario(name="storm", traffic=OVERLOAD, policy=STORM_POLICY,
                    batch_slots=8),
]
GRID = dict(policies=("spm", "lru"), capacities=(1 << 20,), ways=(8,),
            scenarios=SCENARIOS)


class TestServingSweep:
    def test_sweep_matches_direct_simulation(self):
        res = sweep(WL, HW, **GRID)
        assert res.num_configs == 4
        for e in res.entries:
            assert e.config.scenario in ("steady", "storm")
            assert e.config.label.endswith(f"/sv:{e.config.scenario}")
            sc = next(s for s in SCENARIOS if s.name == e.config.scenario)
            hw = HW.with_policy(e.config.policy,
                                capacity_bytes=e.config.capacity_bytes,
                                ways=e.config.ways)
            direct = simulate_serving(
                MultiCoreMemorySystem.from_hardware(hw), SPEC, sc)
            assert_bitwise_equal_results(e.result, direct,
                                         f"sweep parity {e.config.label}")
        # serving metrics surface through the generic row/best machinery
        row = res.entries[0].row()
        for k in ("p50_cycles", "p95_cycles", "p99_cycles", "goodput",
                  "shed", "sustained_qps"):
            assert k in row
        assert res.best("p99_cycles") in res.entries

    def test_sweep_sharded_bitwise(self):
        ref = sweep(WL, HW, **GRID)
        got = sweep(WL, HW, devices=2, **GRID)
        assert got.sharded
        assert_bitwise_equal_results(ref, got, "sharded serving sweep")

    def test_sweep_checkpoint_resume_bitwise(self, tmp_path):
        path = str(tmp_path / "serving.ckpt")
        ref = sweep(WL, HW, **GRID)
        first = sweep(WL, HW, checkpoint=path, **GRID)
        resumed = sweep(WL, HW, checkpoint=path, **GRID)
        assert resumed.resumed_keys == resumed.distinct_memo_keys == 4
        assert_bitwise_equal_results(ref, first, "ckpt first run")
        assert_bitwise_equal_results(ref, resumed, "ckpt resume")

    def test_sweep_fault_injection_bitwise(self):
        ref = sweep(WL, HW, **GRID)
        tele = FaultTelemetry()
        plan = FaultPlan(events=(FaultEvent("crash", shard=1, round=0),))
        got = sweep(WL, HW, devices=2, fault_plan=plan, fault_telemetry=tele,
                    **GRID)
        assert_bitwise_equal_results(ref, got, "serving crash failover")
        assert tele.worker_crashes == 1
        assert tele.failovers == 1

    def test_sweep_rejects_bad_combinations(self):
        with pytest.raises(ValueError, match="configs"):
            sweep(WL, HW, configs=[], **GRID)
        with pytest.raises(ValueError, match="index_trace"):
            sweep(WL, HW, index_trace=np.arange(8), **GRID)
        dup = [SCENARIOS[0], SCENARIOS[0]]
        with pytest.raises(ValueError, match="duplicate"):
            sweep(WL, HW, policies=("spm",), scenarios=dup)
