"""Checkpoint manager (atomic, async, integrity, keep-N, restore) + fault
detection / elastic replanning / straggler policy."""
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointConfig, CheckpointManager
from repro.models import get_smoke_config
from repro.runtime import (
    FailureDetector,
    FaultConfig,
    StragglerPolicy,
    plan_elastic,
    plan_mesh_shape,
)
from repro.training import AdamWConfig, TrainConfig, build_train_step, init_state

KEY = jax.random.PRNGKey(0)


def _state():
    cfg = get_smoke_config("stablelm_3b")
    tcfg = TrainConfig(adamw=AdamWConfig(), loss_chunk=16)
    return cfg, tcfg, init_state(KEY, cfg, tcfg)


def test_checkpoint_roundtrip(tmp_path):
    cfg, tcfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))
    mgr.save(7, state, extra={"note": "x"})
    step, extra, restored = mgr.restore(target_tree=state)
    assert step == 7 and extra["note"] == "x"
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_async_and_keepn(tmp_path):
    cfg, tcfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), keep=2))
    for s in (1, 2, 3, 4):
        mgr.save(s, state)
        mgr.wait()
    assert mgr.all_steps() == [3, 4]


def test_checkpoint_detects_corruption(tmp_path):
    cfg, tcfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))
    mgr.save(1, state)
    d = os.path.join(str(tmp_path), "step_0000000001")
    victim = [f for f in os.listdir(d) if f.endswith(".npy")][0]
    with open(os.path.join(d, victim), "r+b") as f:
        f.seek(200)
        f.write(b"\xde\xad\xbe\xef")
    with pytest.raises(IOError):
        mgr.restore(target_tree=state)


def test_checkpoint_resume_training(tmp_path):
    """Train 5 steps, checkpoint, train 5 more; restart from ckpt must land
    on the same loss trajectory (restart-safe data pipeline + state)."""
    from repro.data import LMDataConfig, lm_batch

    cfg, tcfg, state = _state()
    step_fn = jax.jit(build_train_step(cfg, tcfg))
    dcfg = LMDataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))

    for i in range(5):
        state, _ = step_fn(state, {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()})
    mgr.save(5, state)
    cont = []
    for i in range(5, 10):
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()})
        cont.append(float(m["loss"]))

    _, _, restored = mgr.restore(target_tree=init_state(KEY, cfg, tcfg))
    re_losses = []
    for i in range(5, 10):
        restored, m = step_fn(restored, {k: jnp.asarray(v) for k, v in lm_batch(dcfg, i).items()})
        re_losses.append(float(m["loss"]))
    np.testing.assert_allclose(cont, re_losses, rtol=1e-5)


# --------------------------------------------------------------------------
# fault detection + elastic + straggler
# --------------------------------------------------------------------------

def test_failure_detector_flags_dead_host():
    clock = [0.0]
    det = FailureDetector([f"h{i}" for i in range(8)],
                          FaultConfig(heartbeat_timeout_s=10), clock=lambda: clock[0])
    clock[0] = 5.0
    for i in range(8):
        if i != 3:
            det.heartbeat(f"h{i}")
    clock[0] = 12.0    # h3 last seen at 0 (>10s ago); others at 5 (7s ago)
    dead = det.poll()
    assert dead == {"h3"}
    assert not det.should_halt()
    assert len(det.healthy) == 7


def test_failure_detector_halts_below_quorum():
    clock = [0.0]
    det = FailureDetector(["a", "b", "c", "d"],
                          FaultConfig(heartbeat_timeout_s=1, min_healthy_fraction=0.75),
                          clock=lambda: clock[0])
    det.inject_failure("a")
    det.inject_failure("b")
    det.poll()
    assert det.should_halt()


def test_plan_mesh_shape():
    assert plan_mesh_shape(256, 16) == (16, 16)
    assert plan_mesh_shape(512, 16, pods=2) == (2, 16, 16)
    assert plan_mesh_shape(240, 16) == (15, 16)      # lost a host: shrink data
    assert plan_mesh_shape(8, 16) == (1, 1, 8)       # degenerate: shrink model


def test_plan_elastic_preserves_model_axis():
    plan = plan_elastic((16, 16), ("data", "model"), surviving_devices=240)
    assert plan.mesh_shape == (15, 16)
    assert plan.axis_names == ("data", "model")
    assert plan.dropped_devices == 0
    plan2 = plan_elastic((2, 16, 16), ("pod", "data", "model"), 256 + 240)
    assert plan2.mesh_shape[-1] == 16


def test_elastic_restore_across_topology(tmp_path):
    """Checkpoint written under one topology restores onto another (the
    elastic-scaling path; single real device, shardings still exercised)."""
    cfg, tcfg, state = _state()
    mgr = CheckpointManager(CheckpointConfig(directory=str(tmp_path), async_save=False))
    mgr.save(3, state)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    from repro.distributed import param_specs, tree_shardings

    shapes = jax.eval_shape(lambda: state)
    specs = param_specs(shapes, mesh)
    sh = tree_shardings(mesh, specs)
    step, _, restored = mgr.restore(target_tree=state, shardings=sh)
    assert step == 3
    leaf = jax.tree.leaves(restored)[0]
    assert leaf.sharding is not None


def test_straggler_policy_rebalances():
    pol = StragglerPolicy(threshold=1.5, window=4)
    rep = None
    for _ in range(4):
        rep = pol.observe({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 2.5})
    assert rep.stragglers == ["h3"]
    assert rep.microbatch_shares["h3"] < 1.0
    assert rep.persistent == ["h3"]


def test_straggler_policy_drop_mode():
    pol = StragglerPolicy(threshold=1.5, window=4, mode="drop")
    for _ in range(4):
        rep = pol.observe({"h0": 1.0, "h1": 1.0, "h2": 1.0, "h3": 3.0})
    assert rep.microbatch_shares["h3"] == 0.0
    assert abs(rep.grad_scale - 4 / 3) < 1e-9


def test_straggler_recovers():
    pol = StragglerPolicy(threshold=1.5, window=3)
    for _ in range(3):
        pol.observe({"h0": 1.0, "h1": 3.0})
    for _ in range(6):
        rep = pol.observe({"h0": 1.0, "h1": 1.0})
    assert rep.stragglers == []
    assert rep.persistent == []
