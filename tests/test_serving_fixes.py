"""Serving-path correctness fixes (the PR's satellite bugfixes).

* scheduler clock monotonicity: a retry scheduled from an expired deadline
  can never rewind the event timeline (``max(deadline + backoff, now)``);
* falsy-zero traffic knobs: an explicit ``0`` for ``tables_per_request`` /
  ``lookups_per_table`` is a validation error, not "unset";
* drifting-Zipf exponent quantization: the per-exponent CDF cache stays
  bounded by the epoch count, and ``zipf_drift=0`` produces exponents
  bitwise equal to the drift-free config;
* ``ServingResult.summary()`` on a zero-makespan result reports NaN QPS
  instead of raising.
"""
import dataclasses

import numpy as np
import pytest

from repro.core import TrafficConfig
from repro.core.requests import drift_exponents, generate_requests
from repro.core import requests as requests_mod
from repro.core.memory.system import MultiCoreMemorySystem
from repro.core.results import ServingResult
from repro.core.workload import EmbeddingOpSpec
from repro.serving import RobustnessPolicy, ServingScenario, simulate_serving
from repro.core import tpuv6e

SPEC = EmbeddingOpSpec(
    num_tables=4, rows_per_table=1000, dim=32, lookups_per_sample=4,
    dtype_bytes=4,
)

# Arrivals far above capacity + tight deadline + retry budget: every failed
# attempt reschedules from an already-expired deadline — the exact shape
# that used to rewind the clock.
STORM = ServingScenario(
    name="ddl_storm",
    traffic=TrafficConfig(pattern="bursty", mean_gap_cycles=10.0,
                          num_requests=120, seed=23, burst_len=16),
    policy=RobustnessPolicy(deadline_cycles=300, max_retries=3,
                            retry_backoff_cycles=50.0),
    batch_slots=4,
)


def _serve(scenario, **kw):
    ms = MultiCoreMemorySystem.from_hardware(tpuv6e())
    return simulate_serving(ms, SPEC, scenario, **kw)


class TestRetryMonotonicity:
    def test_event_timeline_never_rewinds(self):
        log = []
        res = _serve(STORM, event_log=log)
        # the regression shape actually fired: timeouts AND retries occurred
        assert res.timed_out > 0 and res.retries > 0
        assert len(log) > 0
        diffs = np.diff(np.asarray(log, dtype=np.int64))
        assert (diffs >= 0).all(), f"clock rewound at {np.argmin(diffs)}"

    def test_storm_still_bitwise_reproducible(self):
        a, b = _serve(STORM), _serve(STORM)
        assert not a.diff(b)

    def test_conservation_under_storm(self):
        res = _serve(STORM)
        # attempt-level: every failed attempt either retries or abandons
        assert res.shed + res.timed_out == res.retries + res.abandoned
        # request-level: completions + final abandonments cover the offer
        assert res.completed + res.abandoned == res.offered
        assert 0 < res.completed < res.offered


class TestFalsyZeroValidation:
    def test_zero_tables_per_request_raises(self):
        cfg = TrafficConfig(num_requests=4, tables_per_request=0)
        with pytest.raises(ValueError, match="tables_per_request"):
            generate_requests(SPEC, cfg)

    def test_zero_lookups_per_table_raises(self):
        cfg = TrafficConfig(num_requests=4, lookups_per_table=0)
        with pytest.raises(ValueError, match="lookups_per_table"):
            generate_requests(SPEC, cfg)

    def test_none_still_means_spec_defaults(self):
        cfg = TrafficConfig(num_requests=4)
        reqs = generate_requests(SPEC, cfg)
        assert reqs[0].rows.shape == (SPEC.num_tables,
                                      SPEC.lookups_per_sample)


class TestDriftQuantization:
    def test_zero_drift_is_exact_base_exponent(self):
        cfg = TrafficConfig(num_requests=50, zipf_s=0.9, zipf_drift=0.0,
                            drift_period=7)
        assert np.array_equal(drift_exponents(cfg),
                              np.full(50, 0.9))

    def test_distinct_exponents_bounded_by_epochs(self):
        cfg = TrafficConfig(num_requests=100, zipf_s=0.8, zipf_drift=0.5,
                            drift_period=5)
        exps = drift_exponents(cfg)
        assert len(np.unique(exps)) <= 20
        assert (np.diff(exps) >= 0).all()          # positive drift sharpens
        # constant within each epoch, stepping at epoch boundaries
        assert (exps[:5] == exps[0]).all() and exps[5] != exps[0]

    def test_no_period_uses_fixed_grid(self):
        cfg = TrafficConfig(num_requests=10_000, zipf_s=0.8, zipf_drift=0.5,
                            drift_period=0)
        assert len(np.unique(drift_exponents(cfg))) <= requests_mod._DRIFT_GRID

    def test_cdf_cache_stays_bounded(self, monkeypatch):
        """One zipf_probs cumsum per distinct exponent — not per request."""
        calls = []
        real = requests_mod.zipf_probs
        monkeypatch.setattr(requests_mod, "zipf_probs",
                            lambda n, s: calls.append(s) or real(n, s))
        cfg = TrafficConfig(num_requests=96, zipf_s=0.8, zipf_drift=0.5,
                            drift_period=8)
        generate_requests(SPEC, cfg)
        assert len(calls) == len(set(calls)) <= 12

    def test_drifting_stream_deterministic(self):
        cfg = TrafficConfig(num_requests=40, zipf_drift=0.4, drift_period=8)
        r1, r2 = generate_requests(SPEC, cfg), generate_requests(SPEC, cfg)
        for a, b in zip(r1, r2):
            assert np.array_equal(a.rows, b.rows)


class TestZeroMakespanGuard:
    def _result(self, makespan):
        return ServingResult(
            scenario="s", hardware="h", policy="p", clock_ghz=1.0,
            offered=0, completed=0, shed=0, timed_out=0, retries=0,
            abandoned=0, degraded_batches=0, dropped_cold_rows=0,
            bypassed_lookups=0, num_batches=0, makespan_cycles=makespan,
            goodput=0.0,
            latency_cycles=np.zeros(0, dtype=np.int64),
            queue_cycles=np.zeros(0, dtype=np.int64),
            service_cycles=np.zeros(0, dtype=np.int64),
        )

    def test_summary_does_not_raise(self):
        s = self._result(0).summary()
        assert np.isnan(s["sustained_qps"])
        assert np.isnan(s["sustained_qps_per_mcycle"])

    def test_nonzero_makespan_unaffected(self):
        r = dataclasses.replace(self._result(1_000_000), completed=10)
        assert r.sustained_qps_per_mcycle == pytest.approx(10.0)
