"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps (interpret=True)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(rng, shape, dtype):
    x = rng.standard_normal(shape).astype(np.float32)
    return jnp.asarray(x, dtype=dtype)


# --------------------------------------------------------------------------
# embedding bag / gather (the paper op)
# --------------------------------------------------------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("T,R,D,B,L", [
    (1, 16, 32, 2, 1),
    (3, 50, 96, 4, 7),
    (2, 128, 128, 8, 12),
    (4, 64, 200, 2, 5),     # D not lane-aligned -> padding path
])
def test_embedding_bag_sweep(T, R, D, B, L, dtype, rng):
    table = _rand(rng, (T * R, D), dtype)
    idx = jnp.asarray(rng.integers(0, R, size=(B, T, L)), jnp.int32)
    out_k = ops.embedding_bag(table, idx, R, use_pallas=True)
    out_r = ops.embedding_bag(table, idx, R, use_pallas=False)
    tol = 1e-5 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        np.asarray(out_k, np.float32), np.asarray(out_r, np.float32),
        atol=tol, rtol=tol,
    )


@pytest.mark.parametrize("shape", [(7,), (3, 5), (2, 3, 4)])
def test_embedding_gather_sweep(shape, rng):
    table = _rand(rng, (64, 48), jnp.float32)
    idx = jnp.asarray(rng.integers(0, 64, size=shape), jnp.int32)
    k = ops.embedding_gather(table, idx, use_pallas=True)
    r = ops.embedding_gather(table, idx, use_pallas=False)
    np.testing.assert_allclose(np.asarray(k), np.asarray(r))


def test_embedding_bag_pinned_equals_plain(rng):
    """Hot-pinned path (paper's Profiling policy on TPU) == plain bag."""
    T, R, D, B, L = 3, 40, 64, 4, 6
    table = _rand(rng, (T * R, D), jnp.float32)
    idx = jnp.asarray(rng.integers(0, R, size=(B, T, L)), jnp.int32)
    hot_ids = np.sort(rng.choice(T * R, size=25, replace=False)).astype(np.int64)
    pos, mask = ops.split_hot_cold(np.asarray(idx), hot_ids, R)
    hot_table = table[jnp.asarray(hot_ids)]
    plain = ops.embedding_bag(table, idx, R, use_pallas=False)
    for up in (True, False):
        pinned = ops.embedding_bag_pinned(
            table, hot_table, idx, jnp.asarray(pos), jnp.asarray(mask), R,
            use_pallas=up,
        )
        np.testing.assert_allclose(
            np.asarray(pinned), np.asarray(plain), atol=1e-4, rtol=1e-4
        )


def test_split_hot_cold_mask_semantics(rng):
    idx = rng.integers(0, 100, size=(2, 3, 4))
    hot = np.array([5, 105, 250])           # global ids (t*R + r), R=100
    pos, mask = ops.split_hot_cold(idx, hot, 100)
    glob = np.arange(3)[None, :, None] * 100 + idx
    assert np.array_equal(mask.astype(bool), np.isin(glob, hot))


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------

@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("B,Hq,Hkv,S,d", [
    (1, 2, 2, 128, 32),
    (2, 8, 2, 256, 64),     # GQA
    (1, 4, 1, 384, 64),     # MQA, ragged block (384 = 3*128)
    (2, 4, 4, 256, 128),
])
def test_flash_attention_sweep(B, Hq, Hkv, S, d, causal, rng):
    q = _rand(rng, (B, Hq, S, d), jnp.float32)
    k = _rand(rng, (B, Hkv, S, d), jnp.float32)
    v = _rand(rng, (B, Hkv, S, d), jnp.float32)
    out_k = ops.flash_attention(q, k, v, causal=causal, use_pallas=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=2e-5, rtol=2e-5)


def test_flash_attention_bf16(rng):
    q = _rand(rng, (1, 2, 128, 64), jnp.bfloat16)
    k = _rand(rng, (1, 2, 128, 64), jnp.bfloat16)
    v = _rand(rng, (1, 2, 128, 64), jnp.bfloat16)
    out_k = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    out_r = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=3e-2)


@pytest.mark.parametrize("causal", [True, False])
def test_chunked_attention_matches_full(causal, rng):
    """The long-context XLA path (scan online-softmax) == full softmax, incl.
    GQA and dv != dq (MLA shapes)."""
    q = _rand(rng, (2, 6, 96, 48), jnp.float32)
    k = _rand(rng, (2, 2, 96, 48), jnp.float32)
    v = _rand(rng, (2, 2, 96, 32), jnp.float32)    # dv != dq
    out_c = ref.chunked_attention(q, k, v, causal=causal, k_block=32)
    # reference via repeat + full softmax
    kf = jnp.repeat(k, 3, axis=1)
    vf = jnp.repeat(v, 3, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q, kf) / np.sqrt(48)
    if causal:
        mask = jnp.tril(jnp.ones((96, 96), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    out_f = jnp.einsum("bhqk,bhkd->bhqd", jax.nn.softmax(s, -1), vf)
    np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_f),
                               atol=2e-5, rtol=2e-5)


# --------------------------------------------------------------------------
# mamba2 SSD
# --------------------------------------------------------------------------

@pytest.mark.parametrize("B,H,S,P,N,chunk", [
    (1, 2, 64, 16, 32, 16),
    (2, 4, 256, 32, 64, 64),
    (1, 3, 128, 64, 128, 128),
])
def test_mamba2_ssd_sweep(B, H, S, P, N, chunk, rng):
    x = _rand(rng, (B, H, S, P), jnp.float32) * 0.5
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, H, S)), jnp.float32)
    A = -jnp.exp(_rand(rng, (H,), jnp.float32))
    adt = A[None, :, None] * dt
    Bm = _rand(rng, (B, S, N), jnp.float32) * 0.3
    C = _rand(rng, (B, S, N), jnp.float32) * 0.3
    yk = ops.mamba2_ssd(x, adt, dt, Bm, C, chunk=chunk, use_pallas=True)
    yr = ref.mamba2_ssd_ref(x, adt, dt, Bm, C)
    np.testing.assert_allclose(np.asarray(yk), np.asarray(yr),
                               atol=2e-4, rtol=2e-3)


def test_mamba2_final_state_matches_sequential(rng):
    B, H, S, P, N = 2, 3, 96, 16, 32
    x = _rand(rng, (B, H, S, P), jnp.float32) * 0.5
    dt = jnp.asarray(rng.uniform(0.001, 0.1, size=(B, H, S)), jnp.float32)
    A = -jnp.exp(_rand(rng, (H,), jnp.float32))
    adt = A[None, :, None] * dt
    Bm = _rand(rng, (B, S, N), jnp.float32) * 0.3

    closed = ref.mamba2_final_state(x, adt, dt, Bm)
    # sequential recurrence
    state = np.zeros((B, H, P, N), np.float32)
    xn, adtn, dtn, Bn = map(np.asarray, (x, adt, dt, Bm))
    for t in range(S):
        decay = np.exp(adtn[:, :, t])[..., None, None]
        outer = dtn[:, :, t, None, None] * xn[:, :, t, :, None] * Bn[:, None, t, None, :]
        state = decay * state + outer
    np.testing.assert_allclose(np.asarray(closed), state, atol=1e-4, rtol=1e-3)
