"""Per-architecture smoke tests: reduced same-family configs, one forward +
one train step on CPU, asserting output shapes and no NaNs (brief req. (f))."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import ARCH_IDS, family_module, get_config, get_smoke_config, param_count
from repro.training import AdamWConfig, TrainConfig, build_train_step, init_state

KEY = jax.random.PRNGKey(0)

TARGET_PARAMS = {
    "arctic_480b": 480e9, "deepseek_v2_lite_16b": 16e9, "chameleon_34b": 34e9,
    "zamba2_2p7b": 2.7e9, "granite_34b": 34e9, "command_r_plus_104b": 104e9,
    "granite_20b": 20e9, "stablelm_3b": 3e9, "whisper_base": 74e6,
    "mamba2_130m": 130e6,
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_param_count(arch):
    n = param_count(get_config(arch))
    assert 0.85 < n / TARGET_PARAMS[arch] < 1.20, (arch, n)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward(arch):
    cfg = get_smoke_config(arch)
    mod = family_module(cfg)
    B, S = 2, 32
    tokens = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    if cfg.family == "audio":
        params = mod.init_model(KEY, cfg)
        frames = jax.random.normal(
            KEY, (B, cfg.encdec.encoder_seq, cfg.d_model), dtype=jnp.bfloat16
        )
        logits = mod.forward(params, tokens, frames, cfg)
    else:
        params = mod.init_lm(KEY, cfg)
        logits = mod.forward(params, tokens, cfg)
    assert logits.shape == (B, S, cfg.vocab)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_train_step(arch):
    cfg = get_smoke_config(arch)
    tcfg = TrainConfig(adamw=AdamWConfig(lr=1e-3), loss_chunk=16, microbatches=1)
    state = init_state(KEY, cfg, tcfg)
    step = jax.jit(build_train_step(cfg, tcfg))
    B, S = 2, 32
    batch = {
        "tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
        "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
    }
    if cfg.family == "audio":
        batch["frames"] = jnp.zeros((B, cfg.encdec.encoder_seq, cfg.d_model), jnp.bfloat16)
    new_state, metrics = step(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    assert int(new_state["step"]) == 1
    # params actually changed
    delta = jax.tree.leaves(
        jax.tree.map(
            lambda a, b: float(jnp.abs(a.astype(jnp.float32) - b.astype(jnp.float32)).max()),
            state["params"], new_state["params"],
        )
    )
    assert max(delta) > 0
