"""Reusable differential/property test helpers.

Four PRs of bit-exact rewrites (lane transform, multi-core delegation,
chunked DRAM, stack-distance backend, cross-config batching) each hand-rolled
the same comparison loops: zip two result lists, ``dataclasses.asdict`` both
sides, compare field by field. This module is the single owner of that
pattern:

* ``assert_bitwise_equal_results(a, b)`` — recursively asserts two result
  structures are *bitwise identical*: ``SimResult``/``SweepResult`` (via
  their own diff surface), dataclasses (``DramResult``,
  ``EmbeddingBatchStats``, ...), numpy arrays (exact ``array_equal``),
  dicts/sequences, and scalars (exact ``==`` — never a tolerance).
* ``trace_corpus(...)`` — a seeded, deterministic ``EmbeddingTrace`` corpus
  (heterogeneous batch lengths included) shared by differential tests.
* ``golden_pair(engine, reference)`` — fixture factory: returns a runner
  that evaluates any (engine, reference) callable pair over the corpus and
  asserts bitwise equality per trace.

Every "backend/optimization X is bit-exact vs reference Y" guarantee in the
suite should go through this layer so a new engine inherits the comparison
semantics instead of re-deriving them.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional, Sequence

import numpy as np

from repro.core.memory.system import EmbeddingTrace
from repro.core.trace import expand_trace, generate_zipf_trace
from repro.core.workload import EmbeddingOpSpec


def _fail(path: str, msg: str) -> None:
    raise AssertionError(f"bitwise mismatch at {path or '<root>'}: {msg}")


def assert_bitwise_equal_results(a, b, label: str = "") -> None:
    """Assert two result structures are bitwise identical (no tolerances)."""
    _assert_equal(a, b, label)


def _assert_equal(a, b, path: str) -> None:
    # SimResult / anything exposing its own structured diff
    if hasattr(a, "diff") and callable(a.diff) and type(a) is type(b):
        mism = a.diff(b)
        if mism:
            _fail(path, f"{type(a).__name__}.diff: {mism}")
        return
    # SweepResult-shaped: compare configs + per-entry results, not wall time
    if hasattr(a, "entries") and hasattr(b, "entries"):
        ea, eb = a.entries, b.entries
        if len(ea) != len(eb):
            _fail(path, f"entry counts differ: {len(ea)} vs {len(eb)}")
        for x, y in zip(ea, eb):
            if x.config != y.config:
                _fail(path, f"configs differ: {x.config} vs {y.config}")
            _assert_equal(x.result, y.result, f"{path}[{x.config.label}]")
        return
    if isinstance(a, np.ndarray) or isinstance(b, np.ndarray):
        aa, bb = np.asarray(a), np.asarray(b)
        # bitwise semantics: NaN == NaN (identical bit patterns must pass)
        eq_nan = (np.issubdtype(aa.dtype, np.inexact)
                  and np.issubdtype(bb.dtype, np.inexact))
        if not np.array_equal(aa, bb, equal_nan=eq_nan):
            _fail(path, f"arrays differ: {a!r} vs {b!r}")
        return
    if dataclasses.is_dataclass(a) and not isinstance(a, type):
        if type(a) is not type(b):
            _fail(path, f"types differ: {type(a).__name__} vs {type(b).__name__}")
        for f in dataclasses.fields(a):
            _assert_equal(
                getattr(a, f.name), getattr(b, f.name), f"{path}.{f.name}"
            )
        return
    if isinstance(a, dict) and isinstance(b, dict):
        if set(a) != set(b):
            _fail(path, f"keys differ: {sorted(a)} vs {sorted(b)}")
        for k in a:
            _assert_equal(a[k], b[k], f"{path}[{k!r}]")
        return
    if isinstance(a, (list, tuple)) and isinstance(b, (list, tuple)):
        if len(a) != len(b):
            _fail(path, f"lengths differ: {len(a)} vs {len(b)}")
        for i, (x, y) in enumerate(zip(a, b)):
            _assert_equal(x, y, f"{path}[{i}]")
        return
    if a != b:
        # bitwise semantics: two NaN scalars are equal (same bit meaning)
        if isinstance(a, float) and isinstance(b, float) \
                and a != a and b != b:
            return
        _fail(path, f"{a!r} != {b!r}")


DEFAULT_SPEC = EmbeddingOpSpec(
    num_tables=3, rows_per_table=3000, dim=128, lookups_per_sample=6,
    dtype_bytes=4,
)


def make_etrace(
    spec: EmbeddingOpSpec,
    batch_sizes: Sequence[int],
    seed: int = 0,
    zipf_s: float = 1.0,
) -> EmbeddingTrace:
    """One seeded multi-batch EmbeddingTrace (deterministic in arguments)."""
    traces = []
    for bi, bsz in enumerate(batch_sizes):
        it = generate_zipf_trace(
            bsz * spec.num_tables * spec.lookups_per_sample,
            spec.rows_per_table, zipf_s, seed=seed + bi,
        )
        traces.append(expand_trace(it, spec, bsz, seed=seed + bi))
    return EmbeddingTrace(spec, traces)


def trace_corpus(
    spec: Optional[EmbeddingOpSpec] = None,
    batch_sets: Sequence[Sequence[int]] = ((8, 8), (5, 11, 2)),
    seeds: Sequence[int] = (0, 7),
    zipf_s: float = 1.0,
) -> "list[EmbeddingTrace]":
    """The seeded trace corpus differential tests share: every (batch-shape,
    seed) combination, heterogeneous per-batch lengths included."""
    spec = spec or DEFAULT_SPEC
    return [
        make_etrace(spec, bs, seed=s, zipf_s=zipf_s)
        for bs in batch_sets
        for s in seeds
    ]


def golden_pair(
    engine: Callable[[EmbeddingTrace], object],
    reference: Callable[[EmbeddingTrace], object],
    corpus: Optional[Sequence[EmbeddingTrace]] = None,
    label: str = "",
) -> Callable[[], None]:
    """Fixture factory: a runner asserting ``engine(trace)`` is bitwise
    identical to ``reference(trace)`` over the seeded corpus.

    ``engine``/``reference`` take one ``EmbeddingTrace`` and may return any
    structure ``assert_bitwise_equal_results`` understands (stats lists,
    ``DramResult`` tuples, ``SimResult``s, ...).
    """
    items = list(corpus) if corpus is not None else trace_corpus()

    def run() -> None:
        for i, et in enumerate(items):
            assert_bitwise_equal_results(
                engine(et), reference(et), label=f"{label}[trace {i}]"
            )

    return run
