"""Device-resident hot path: jnp ports vs numpy goldens, the chunked DRAM
engine, compiled-shape guarantees, the cache_backend knob end to end, and
the stage profiler.

The perf overhaul's contract is "same results, different execution": every
jnp port keeps its numpy original as the golden reference, the chunked DRAM
scan must agree with the explicit per-access reference ordering, and the
backend knob must be invisible in simulation outputs.
"""
import jax.numpy as jnp
import numpy as np
import pytest

from differential import assert_bitwise_equal_results
from repro.core import dlrm_rmc2_small, simulate, tpuv6e
from repro.core import profiling
from repro.core.hardware import CACHE_BACKENDS
from repro.core.memory.cache import _MIN_BUCKET, _bucket_len
from repro.core.memory.dram import (
    DramModel,
    _frfcfs_order,
    _frfcfs_order_ref,
    simulate_dram,
    simulate_dram_contended,
)
from repro.core.memory.policies import PolicyContext, get_policy
from repro.core.trace import (
    ConcatTrace,
    FullTrace,
    expand_trace,
    generate_zipf_trace,
    shard_lookup_cores,
    shard_lookup_cores_jnp,
    translate,
    translate_jnp,
)
from repro.core.workload import EmbeddingOpSpec


@pytest.fixture
def spec():
    return EmbeddingOpSpec(num_tables=5, rows_per_table=700, dim=64,
                           lookups_per_sample=3, dtype_bytes=4)


def _concat(spec, rng, batches=(4, 7)):
    traces = []
    for i, b in enumerate(batches):
        it = generate_zipf_trace(b * spec.num_tables * spec.lookups_per_sample,
                                 spec.rows_per_table, 0.9, seed=i)
        traces.append(expand_trace(it, spec, b, seed=i))
    return ConcatTrace.from_traces(traces)


# --------------------------------------------------------------------------
# jnp ports vs numpy goldens
# --------------------------------------------------------------------------

def test_translate_jnp_matches_numpy(spec, rng):
    concat = _concat(spec, rng)
    for line_bytes in (64, 128, 96):
        at = translate(concat, spec, line_bytes)
        got = np.asarray(translate_jnp(
            jnp.asarray(concat.table_ids), jnp.asarray(concat.row_ids),
            spec, line_bytes,
        ))
        assert np.array_equal(got, at.lines)


@pytest.mark.parametrize("mode", ["batch", "table_hash"])
@pytest.mark.parametrize("cores", [1, 2, 3, 8])
def test_shard_lookup_cores_jnp_matches_numpy(spec, rng, mode, cores):
    concat = _concat(spec, rng)
    ref = shard_lookup_cores(concat, cores, mode)
    got = np.asarray(shard_lookup_cores_jnp(concat, cores, mode))
    assert np.array_equal(got, ref)


def test_policy_classify_jnp_matches_numpy(rng):
    lines = rng.integers(0, 5000, size=2000).astype(np.int64)
    hw = tpuv6e().with_onchip(capacity_bytes=1 << 16)
    for name in ("spm", "pinning"):
        pol = get_policy(name)
        ctx = pol.prepare(lines, PolicyContext.from_hardware(hw))
        ref = pol.classify(lines, ctx)
        got = np.asarray(pol.classify_jnp(jnp.asarray(lines), ctx))
        assert np.array_equal(got, ref), name


# --------------------------------------------------------------------------
# DRAM: FR-FCFS fast ordering + chunked engine
# --------------------------------------------------------------------------

def test_frfcfs_fast_order_matches_reference(rng):
    dm = DramModel.from_hardware(tpuv6e())
    for trial in range(4):
        n = int(rng.integers(100, 5000))
        lines = rng.integers(0, 1_000_000, size=n)
        seg = np.sort(rng.integers(0, 3, size=n)) if trial % 2 else None
        ch, bk, _row = dm.decompose(lines)
        blk = lines // dm.lines_per_block
        fast = _frfcfs_order(ch, bk, blk, dm.banks_per_channel, dm.channels, seg=seg)
        ref = _frfcfs_order_ref(ch, bk, blk, dm.banks_per_channel, dm.channels, seg=seg)
        assert np.array_equal(fast, ref)


def test_chunked_dram_segment_independence(rng):
    """A segment timed inside a larger contended dispatch must match the
    same segment timed alone — including total latency, which is reduced on
    the host in original access order precisely to be layout-independent."""
    dm = DramModel.from_hardware(tpuv6e())
    v = rng.integers(0, 100_000, size=1500)
    lines = (v[:, None] * 8 + np.arange(8)[None, :]).reshape(-1)
    seg = np.sort(rng.integers(0, 3, size=lines.size))
    src = rng.integers(0, 2, size=lines.size)
    got, fin = simulate_dram_contended(lines, seg, src, 3, 2, dm)
    for s in range(3):
        ref = simulate_dram(lines[seg == s], dm)
        assert_bitwise_equal_results(got[s], ref, label=f"segment {s}")
        assert fin[s].max() + 0.0 == pytest.approx(got[s].finish_cycle)


# --------------------------------------------------------------------------
# Length bucketing: padding bound + compiled-shape count
# --------------------------------------------------------------------------

def test_bucket_len_padding_bound():
    """A sub-trace is never padded by more than 2x (above the floor)."""
    for n in list(range(1, 300)) + [1000, 4097, 100_000]:
        b = _bucket_len(n)
        assert b >= n
        assert b <= max(_MIN_BUCKET, 2 * n)


def test_bucket_len_compile_count_logarithmic():
    """O(log N) distinct padded shapes across every trace length up to N —
    the compiled-scan reuse guarantee the smaller floor must preserve."""
    N = 1 << 20
    distinct = {_bucket_len(n) for n in range(1, N + 1, 97)}
    import math
    assert len(distinct) <= math.ceil(math.log2(N / _MIN_BUCKET)) + 2


# --------------------------------------------------------------------------
# cache_backend knob end to end
# --------------------------------------------------------------------------

def test_cache_backend_bit_exact_end_to_end():
    """simulate() under every cache backend (Pallas variants in interpret
    mode on CPU) equals the scan backend for a cache-mode policy, bit for
    bit — the knob can never change results."""
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=300, batch_size=2,
                         num_batches=2)
    base = tpuv6e().with_policy("lru", capacity_bytes=1 << 14)
    assert set(CACHE_BACKENDS) == {"scan", "pallas", "stack", "stack_pallas"}
    ref = simulate(wl, base.with_cache_backend("scan"), seed=0, zipf_s=0.9)
    for backend in ("pallas", "stack", "stack_pallas"):
        got = simulate(wl, base.with_cache_backend(backend), seed=0, zipf_s=0.9)
        assert_bitwise_equal_results(got, ref, label=backend)


def test_cache_backend_validation():
    with pytest.raises(ValueError, match="cache backend"):
        tpuv6e().with_cache_backend("nope")


# --------------------------------------------------------------------------
# Stage profiler
# --------------------------------------------------------------------------

def test_profiling_stages_cover_hot_path():
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=400, batch_size=4,
                         num_batches=2)
    hw = tpuv6e().with_policy("lru", capacity_bytes=1 << 15)
    # Default (stack) backend: LRU classification shows up as the
    # stack_distance stage; the scan backend reports cache_scan instead.
    with profiling.collect() as prof:
        simulate(wl, hw, seed=0, zipf_s=0.9)
    got = prof.breakdown()
    for name in ("trace_gen", "classify", "stack_distance", "dram"):
        assert name in got, got
        assert got[name] >= 0.0
    with profiling.collect() as prof_scan:
        simulate(wl, hw.with_cache_backend("scan"), seed=0, zipf_s=0.9)
    got_scan = prof_scan.breakdown()
    for name in ("trace_gen", "classify", "cache_scan", "dram", "host_sync"):
        assert name in got_scan, got_scan
        assert got_scan[name] >= 0.0
    # exclusive accounting: stages don't double-count nested children
    assert sum(got_scan.values()) < 60.0


def test_profiling_disabled_reports_nothing():
    wl = dlrm_rmc2_small(num_tables=2, rows_per_table=400, batch_size=2,
                         num_batches=1)
    simulate(wl, tpuv6e(), seed=0)     # no collect() active: must not record
    with profiling.collect() as prof:
        pass
    assert prof.breakdown() == {}
