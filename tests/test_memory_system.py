"""MemorySystem layer: policy-registry golden parity, lane-transform
parity, segmented DRAM attribution, ConcatTrace boundaries."""
import dataclasses

import numpy as np
import pytest

from repro.core import OnChipPolicy, available_policies, get_policy, tpuv6e
from repro.core.memory.cache import CacheGeometry
from repro.core.memory.dram import (
    DramModel,
    dram_timing,
    dram_timing_segmented,
    simulate_dram,
    simulate_dram_segmented,
)
from repro.core.memory.golden import GoldenCache
from repro.core.memory.policies import (
    PolicyContext,
    profile_hot_lines,
    run_policy,
)
from repro.core.memory.system import EmbeddingTrace, MemorySystem, lane_geometry
from repro.core.trace import ConcatTrace, expand_trace, generate_zipf_trace, translate
from repro.core.workload import EmbeddingOpSpec


# --------------------------------------------------------------------------
# Registry + golden parity
# --------------------------------------------------------------------------

def test_registry_covers_all_hardware_policies():
    assert set(available_policies()) == {p.value for p in OnChipPolicy}
    for p in OnChipPolicy:
        assert get_policy(p).enum == p
        assert get_policy(p.value).name == p.value


def test_policy_sensitivity_declarations():
    """Sweep memoization contract: a policy may only omit a swept parameter
    its classification truly never reads."""
    assert get_policy("spm").sensitive_params == ()
    assert get_policy("pinning").sensitive_params == ("capacity_bytes",)
    for name in ("lru", "srrip", "fifo"):
        assert get_policy(name).sensitive_params == ("capacity_bytes", "ways")


def test_unknown_policy_raises():
    with pytest.raises(ValueError, match="unknown policy"):
        get_policy("mru")


@pytest.mark.parametrize("name", ["lru", "srrip", "fifo"])
def test_cache_policies_match_golden(name, rng):
    """Every registered cache policy classifies bit-exactly like the
    ChampSim-semantics golden model."""
    lines = rng.integers(0, 4000, size=2500)
    geom = CacheGeometry(num_sets=16, ways=4, line_bytes=64)
    ctx = PolicyContext(geometry=geom, capacity_units=geom.num_sets * geom.ways)
    out = get_policy(name).run(lines, ctx)
    gold_hits = GoldenCache(geom, name).run(lines)
    assert np.array_equal(out.hits, gold_hits)
    # shared accounting contract
    n, miss = lines.size, int((~gold_hits).sum())
    assert out.onchip_reads == n
    assert out.onchip_writes == miss
    assert out.offchip_reads == miss
    assert np.array_equal(out.miss_lines, lines[~gold_hits])


def test_spm_policy_semantics(rng):
    lines = rng.integers(0, 1000, size=500)
    ctx = PolicyContext(geometry=CacheGeometry(8, 4, 64), capacity_units=32)
    out = get_policy("spm").run(lines, ctx)
    assert not out.hits.any()
    assert out.onchip_reads == out.onchip_writes == out.offchip_reads == 500
    assert out.setup_writes == 0
    assert np.array_equal(out.miss_lines, lines)


def test_pinning_policy_semantics(rng):
    lines = rng.integers(0, 200, size=3000)
    cap = 32
    ctx = PolicyContext(geometry=CacheGeometry(8, 4, 64), capacity_units=cap)
    out = get_policy("pinning").run(lines, ctx)
    pinned = profile_hot_lines(lines, cap)
    expect_hits = np.isin(lines, pinned)
    assert np.array_equal(out.hits, expect_hits)
    assert out.setup_writes == len(pinned)
    miss = int((~expect_hits).sum())
    assert out.onchip_writes == miss + len(pinned)
    assert out.offchip_reads == miss


def test_run_policy_backcompat_matches_registry(rng):
    """Functional entry point is a thin wrapper over the registry."""
    hw = tpuv6e().with_policy(OnChipPolicy.LRU, capacity_bytes=1 << 18)
    spec = EmbeddingOpSpec(num_tables=2, rows_per_table=800, dim=64,
                           lookups_per_sample=5, dtype_bytes=4)
    tr = generate_zipf_trace(400, 800, 1.0, seed=2)
    at = translate(expand_trace(tr, spec, 40, seed=1), spec, hw.onchip.line_bytes)
    a = run_policy(at, hw)
    b = MemorySystem.from_hardware(hw).classify(at)
    assert np.array_equal(a.hits, b.hits)
    assert (a.onchip_reads, a.onchip_writes, a.offchip_reads) == (
        b.onchip_reads, b.onchip_writes, b.offchip_reads)


# --------------------------------------------------------------------------
# Lane transform parity
# --------------------------------------------------------------------------

def _etrace(spec, batch_sizes, seed=0):
    traces = []
    for bi, bsz in enumerate(batch_sizes):
        it = generate_zipf_trace(
            bsz * spec.num_tables * spec.lookups_per_sample,
            spec.rows_per_table, 1.0, seed=seed + bi)
        traces.append(expand_trace(it, spec, bsz, seed=seed + bi))
    return EmbeddingTrace(spec, traces)


@pytest.mark.parametrize("policy", [OnChipPolicy.SPM, OnChipPolicy.LRU,
                                    OnChipPolicy.SRRIP, OnChipPolicy.FIFO])
def test_lane_fastpath_matches_line_level(policy):
    """Regression: lane transform and line-level path produce identical
    per-batch hit/miss/read/write counts (and all other stats)."""
    hw = tpuv6e().with_policy(policy, capacity_bytes=1 << 20)
    spec = EmbeddingOpSpec(num_tables=3, rows_per_table=4000, dim=128,
                           lookups_per_sample=10, dtype_bytes=4)
    assert lane_geometry(hw, spec) is not None  # transform applies
    et = _etrace(spec, [16, 16])
    ms = MemorySystem.from_hardware(hw)
    lane_stats = ms.simulate_embedding(et, allow_lane=True)
    line_stats = ms.simulate_embedding(et, allow_lane=False)
    assert len(lane_stats) == len(line_stats) == 2
    for a, b in zip(lane_stats, line_stats):
        assert dataclasses.asdict(a) == dataclasses.asdict(b)


def test_pinning_never_uses_lane_transform():
    assert not get_policy("pinning").supports_lane_transform


# --------------------------------------------------------------------------
# Segmented DRAM attribution
# --------------------------------------------------------------------------

def test_segmented_dram_matches_per_batch_loop(rng):
    dm = DramModel.from_hardware(tpuv6e())
    lines = rng.integers(0, 300_000, size=9000)
    seg = np.sort(rng.integers(0, 4, size=9000))
    got = dram_timing_segmented(lines, seg, 4, dm)
    for s in range(4):
        ref = dram_timing(lines[seg == s], dm)
        assert got[s].finish_cycle == ref.finish_cycle
        assert got[s].total_latency_cycles == ref.total_latency_cycles
        assert got[s].row_hits == ref.row_hits
        assert got[s].row_misses == ref.row_misses
        assert got[s].accesses == ref.accesses


def test_segmented_dram_empty_segments(rng):
    dm = DramModel.from_hardware(tpuv6e())
    lines = rng.integers(0, 10_000, size=500)
    seg = np.full(500, 1, dtype=np.int64)   # segments 0 and 2 empty
    got = simulate_dram_segmented(lines, seg, 3, dm)
    assert got[0].accesses == 0 and got[0].finish_cycle == 0.0
    assert got[2].accesses == 0 and got[2].finish_cycle == 0.0
    ref = simulate_dram(lines, dm)
    assert got[1].finish_cycle == ref.finish_cycle
    assert got[1].row_hits == ref.row_hits


# --------------------------------------------------------------------------
# ConcatTrace boundaries (heterogeneous per-batch trace lengths)
# --------------------------------------------------------------------------

def test_concat_trace_true_boundaries():
    spec = EmbeddingOpSpec(num_tables=2, rows_per_table=500, dim=64,
                           lookups_per_sample=3, dtype_bytes=4)
    batch_sizes = [5, 11, 2]
    et = _etrace(spec, batch_sizes)
    ct = et.concat
    per_batch = [b * spec.num_tables * spec.lookups_per_sample for b in batch_sizes]
    assert ct.num_batches == 3
    assert ct.batch_sizes == tuple(batch_sizes)
    assert np.array_equal(ct.boundaries, np.concatenate(([0], np.cumsum(per_batch))))
    assert np.array_equal(ct.lookups_per_batch, per_batch)
    assert len(ct) == sum(per_batch)
    lb = ct.lookup_batch
    assert np.array_equal(np.bincount(lb, minlength=3), per_batch)


def test_heterogeneous_batches_attributed_exactly():
    """Per-batch counts follow the true boundaries, not a derived uniform
    batch size (the old concat computed batch_size by integer division)."""
    spec = EmbeddingOpSpec(num_tables=2, rows_per_table=500, dim=128,
                           lookups_per_sample=3, dtype_bytes=4)
    batch_sizes = [5, 11, 2]
    et = _etrace(spec, batch_sizes)
    lpv = spec.vector_bytes // 64
    hw = tpuv6e()  # SPM: per-batch counts are analytic
    stats = MemorySystem.from_hardware(hw).simulate_embedding(et)
    for s, bsz in zip(stats, batch_sizes):
        n_lines = bsz * spec.num_tables * spec.lookups_per_sample * lpv
        assert s.onchip_reads == n_lines
        assert s.offchip_reads == n_lines
        assert s.cache_misses == n_lines and s.cache_hits == 0
