"""Trace generation/translation properties + full-engine behaviour
(lane-decomposition exactness, engine-vs-oracle counts, policy case study)."""
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import OnChipPolicy, dlrm_rmc2_small, simulate, tpuv6e
from repro.core.engine import lane_geometry
from repro.core.memory.cache import CacheGeometry, simulate_cache
from repro.core.oracle import oracle_run
from repro.core.trace import (
    REUSE_LEVELS,
    dominance_fraction,
    expand_trace,
    generate_zipf_trace,
    reuse_trace,
    translate,
)
from repro.core.workload import EmbeddingOpSpec


def test_zipf_deterministic():
    a = generate_zipf_trace(1000, 5000, 1.0, seed=7)
    b = generate_zipf_trace(1000, 5000, 1.0, seed=7)
    assert np.array_equal(a, b)
    assert a.min() >= 0 and a.max() < 5000


def test_reuse_levels_match_paper():
    """Paper: Reuse High ~4% of vectors dominate, Low ~46%."""
    n = 1_000_000
    d_high = dominance_fraction(reuse_trace("reuse_high", n, n, 0), n)
    d_mid = dominance_fraction(reuse_trace("reuse_mid", n, n, 0), n)
    d_low = dominance_fraction(reuse_trace("reuse_low", n, n, 0), n)
    assert 0.02 < d_high < 0.07
    assert 0.12 < d_mid < 0.30
    assert 0.40 < d_low < 0.55
    assert d_high < d_mid < d_low


@settings(max_examples=20, deadline=None)
@given(
    tables=st.integers(1, 6),
    rows=st.integers(10, 500),
    dim=st.sampled_from([16, 64, 128]),
    lookups=st.integers(1, 10),
    batch=st.integers(1, 8),
)
def test_expand_translate_properties(tables, rows, dim, lookups, batch):
    spec = EmbeddingOpSpec(num_tables=tables, rows_per_table=rows, dim=dim,
                           lookups_per_sample=lookups, dtype_bytes=4)
    tr = generate_zipf_trace(batch * tables * lookups, rows, 0.9, seed=1)
    full = expand_trace(tr, spec, batch)
    assert len(full) == batch * tables * lookups
    assert full.row_ids.min() >= 0 and full.row_ids.max() < rows
    at = translate(full, spec, line_bytes=64)
    lpv = -(-dim * 4 // 64)
    assert len(at) == len(full) * lpv
    # addresses land inside the table region they belong to
    table_of_line = (at.lines * 64) // spec.table_bytes
    assert np.array_equal(table_of_line, np.repeat(full.table_ids, lpv))


def test_lane_decomposition_exact(rng):
    """Vector-granular lane sim == line-level sim (engine fast path)."""
    hw = tpuv6e().with_policy(OnChipPolicy.LRU, capacity_bytes=1 << 20)
    spec = EmbeddingOpSpec(num_tables=4, rows_per_table=5000, dim=128,
                           lookups_per_sample=20, dtype_bytes=4)
    tr = generate_zipf_trace(4 * 20 * 64, 5000, 1.0, seed=3)
    full = expand_trace(tr, spec, batch_size=64, seed=1)

    at = translate(full, spec, hw.onchip.line_bytes)
    geom = CacheGeometry.from_capacity(hw.onchip.capacity_bytes,
                                       hw.onchip.line_bytes, hw.onchip.ways)
    line_hits = simulate_cache(at.lines, geom, "lru").hits.reshape(len(full), -1)
    assert np.array_equal(line_hits.all(1), line_hits.any(1))  # lines move together

    lane = lane_geometry(hw, spec)
    vec_ids = full.table_ids.astype(np.int64) * spec.rows_per_table + full.row_ids
    vec_hits = simulate_cache(vec_ids, lane, "lru").hits
    assert np.array_equal(vec_hits, line_hits.all(1))


def test_engine_access_counts_match_oracle():
    """SPM access counts are analytic — engine must match exactly (paper's
    Fig. 3c metric)."""
    hw = tpuv6e()
    wl = dlrm_rmc2_small(num_tables=8, rows_per_table=50_000, batch_size=32)
    res = simulate(wl, hw, seed=0)
    orc = oracle_run(wl, hw)
    assert res.onchip_accesses == orc.onchip_accesses
    assert res.offchip_reads == orc.offchip_accesses


def test_engine_timing_same_regime_as_oracle():
    """Engine (detailed) vs independent closed-form oracle: same order of
    magnitude, with the engine slower (it models bank hotspots the closed
    form ignores). The tight quantitative validation is Fig. 3 (engine vs
    event-granular reference, <1% — see benchmarks); the gap HERE is the
    paper's motivating claim, reported as fig3_analytical_oracle_gap_pct."""
    hw = tpuv6e()
    wl = dlrm_rmc2_small(num_tables=8, rows_per_table=100_000, batch_size=32)
    res = simulate(wl, hw, seed=0, zipf_s=0.6)   # low skew: closest to oracle's
    orc = oracle_run(wl, hw)                     # uniform-access assumption
    ratio = res.total_cycles / orc.total_cycles
    assert 0.7 < ratio < 2.5, ratio


def test_policy_ordering_case_study():
    """Paper Fig. 4b ordering on a high-reuse trace:
    profiling >= cache(LRU) > SPM (in speedup over SPM)."""
    wl = dlrm_rmc2_small(num_tables=4, rows_per_table=100_000, batch_size=48)
    base = simulate(wl, tpuv6e(), seed=0, zipf_s=REUSE_LEVELS["reuse_high"])
    lru = simulate(wl, tpuv6e().with_policy(OnChipPolicy.LRU), seed=0,
                   zipf_s=REUSE_LEVELS["reuse_high"])
    pin = simulate(wl, tpuv6e().with_policy(OnChipPolicy.PINNING), seed=0,
                   zipf_s=REUSE_LEVELS["reuse_high"])
    assert lru.total_cycles < base.total_cycles
    assert pin.total_cycles <= lru.total_cycles * 1.05
    assert pin.onchip_ratio > base.onchip_ratio


def test_per_batch_results_emitted():
    wl = dlrm_rmc2_small(num_tables=4, rows_per_table=10_000, batch_size=16,
                         num_batches=3)
    res = simulate(wl, tpuv6e(), seed=0)
    assert len(res.batches) == 3
    assert all(b.total_cycles > 0 for b in res.batches)
    js = res.to_json()
    assert "batches" in js
