"""Address-translation layer (TLB hierarchy + page walks).

Covers the tentpole guarantees:

* the analytic TLB classifier is bitwise identical to the sequential golden
  reference (LRU via stack distances, FIFO via the compressed per-set
  engine, numpy and jnp engines alike);
* ``translation=None`` is the EXACT pre-translation engine — bitwise across
  cache backends, policies, placements, topologies, and serving;
* a translated config charges walk cycles per the model
  (``cycles = max(onchip, dram + translation, vector)``) and surfaces the
  counters through ``SimResult.summary()`` and the energy estimator;
* the ``translations=`` sweep axis is bitwise vs independent single-config
  simulation, collapses ``None`` and saturated-TLB keys, and composes with
  sharded / checkpointed / fault-plan / serving execution unchanged.
"""
import dataclasses

import numpy as np
import pytest

from differential import assert_bitwise_equal_results
from repro.core import (
    FaultEvent,
    FaultPlan,
    FaultTelemetry,
    OnChipPolicy,
    TrafficConfig,
    TranslationConfig,
    Workload,
    dlrm_rmc2_small,
    grid_configs,
    simulate,
    sweep,
    tpuv6e,
)
from repro.core.energy import EnergyTable
from repro.core.memory.system import MultiCoreMemorySystem, memory_system_for
from repro.core.memory.tlb import (
    charge_translation,
    classify_tlb,
    golden_tlb_hits,
    tlb_pages,
    translation_saturated,
)
from repro.core.workload import EmbeddingOpSpec
from repro.serving import ServingScenario, simulate_serving

TLB16 = TranslationConfig(entries=16, ways=4, page_bytes=4096)
TLB16_L2 = dataclasses.replace(TLB16, l2_entries=256, l2_ways=8,
                               l2_latency_cycles=8)
# Fully-associative with megabyte pages: reach >> any test footprint.
TLB_SAT_A = TranslationConfig(entries=1 << 16, ways=1 << 16,
                              page_bytes=1 << 20)
TLB_SAT_B = TranslationConfig(entries=1 << 17, ways=1 << 17,
                              page_bytes=1 << 20)


@pytest.fixture(scope="module")
def small_wl():
    return dlrm_rmc2_small(num_tables=2, rows_per_table=2000, dim=128,
                           lookups=4, batch_size=8, num_batches=2)


def _page_streams():
    rng = np.random.default_rng(7)
    return [
        rng.integers(0, 40, size=300),                  # heavy reuse
        rng.integers(0, 5000, size=400),                # sparse
        np.arange(64).repeat(3),                        # sequential
        np.zeros(10, dtype=np.int64),                   # degenerate
        rng.zipf(1.3, size=500) % 900,                  # skewed
    ]


# --------------------------------------------------------------------------
# Analytic classifier vs sequential golden
# --------------------------------------------------------------------------

class TestClassifier:
    @pytest.mark.parametrize("replacement", ["lru", "fifo"])
    @pytest.mark.parametrize("num_sets,ways", [(1, 4), (4, 4), (16, 2),
                                               (8, 1), (1, 64)])
    def test_analytic_matches_golden(self, replacement, num_sets, ways):
        for pages in _page_streams():
            want = golden_tlb_hits(pages, num_sets, ways, replacement)
            got = classify_tlb(pages, num_sets, ways, replacement)
            assert np.array_equal(got, want), (replacement, num_sets, ways)

    def test_engines_agree(self):
        for pages in _page_streams():
            a = classify_tlb(pages, 4, 4, "lru", engine="np")
            b = classify_tlb(pages, 4, 4, "lru", engine="jnp")
            assert np.array_equal(a, b)

    def test_empty_stream(self):
        assert classify_tlb(np.zeros(0, dtype=np.int64), 4, 4).size == 0

    def test_unknown_replacement_rejected(self):
        with pytest.raises(ValueError, match="replacement"):
            classify_tlb(np.arange(4), 2, 2, "rrip")

    def test_tlb_pages_mapping(self):
        lines = np.array([0, 1, 31, 32, 63, 64])
        # 4096B page / 128B line = 32 lines per page
        assert np.array_equal(tlb_pages(lines, 128, 4096),
                              [0, 0, 0, 1, 1, 2])
        with pytest.raises(ValueError, match="span"):
            tlb_pages(lines, 256, 128)

    def test_charge_accounting_identity(self):
        """hits + misses = accesses per batch; without an L2, walks = misses
        and cycles = walks * walk_latency."""
        rng = np.random.default_rng(3)
        lines = rng.integers(0, 4000, size=500)
        batch = np.sort(rng.integers(0, 3, size=500))
        ch = charge_translation(lines, batch, 3, 128, TLB16)
        assert np.array_equal(ch.hits + ch.misses,
                              np.bincount(batch, minlength=3))
        assert np.array_equal(ch.walks, ch.misses)
        assert np.array_equal(
            ch.cycles, ch.walks * float(TLB16.walk_latency_cycles))

    def test_l2_filters_walks(self):
        rng = np.random.default_rng(4)
        lines = rng.integers(0, 4000, size=800)
        batch = np.sort(rng.integers(0, 2, size=800))
        l1_only = charge_translation(lines, batch, 2, 128, TLB16)
        with_l2 = charge_translation(lines, batch, 2, 128, TLB16_L2)
        # same L1 -> same hit/miss split; the L2 can only remove walks
        assert np.array_equal(l1_only.misses, with_l2.misses)
        assert int(with_l2.walks.sum()) <= int(l1_only.walks.sum())

    def test_saturation_condition(self):
        cfg = TranslationConfig(entries=8, ways=2, page_bytes=4096)
        # pages 0..15 over 4 sets -> 4 distinct per set > 2 ways
        assert not translation_saturated(np.arange(16), cfg)
        # pages {0,1,2,3} -> 1 distinct per set
        assert translation_saturated(np.arange(4), cfg)
        assert translation_saturated(np.zeros(0, dtype=np.int64), cfg)


# --------------------------------------------------------------------------
# Config surface
# --------------------------------------------------------------------------

class TestConfig:
    def test_validation(self):
        with pytest.raises(ValueError, match="entries"):
            TranslationConfig(entries=0)
        with pytest.raises(ValueError, match="multiple"):
            TranslationConfig(entries=6, ways=4)
        with pytest.raises(ValueError, match="power of two"):
            TranslationConfig(page_bytes=3000)
        with pytest.raises(ValueError, match="replacement"):
            TranslationConfig(replacement="rrip")
        with pytest.raises(ValueError, match="l2"):
            TranslationConfig(l2_entries=10, l2_ways=4)

    def test_key_roundtrip(self):
        for cfg in (TLB16, TLB16_L2, TLB_SAT_A):
            assert TranslationConfig.from_key(cfg.key) == cfg

    def test_with_translation(self):
        hw = tpuv6e()
        assert hw.translation is None
        t = hw.with_translation(entries=32, ways=8)
        assert t.translation == TranslationConfig(entries=32, ways=8)
        assert t.with_translation(None).translation is None
        assert hw.with_translation(TLB16).translation is TLB16
        with pytest.raises(ValueError, match="either"):
            hw.with_translation(TLB16, entries=32)
        with pytest.raises(ValueError, match="unknown"):
            hw.with_translation(entires=32)

    def test_reach_and_miss_latency(self):
        assert TLB16.reach_bytes == 16 * 4096
        assert TLB16.miss_latency_cycles == TLB16.walk_latency_cycles
        assert TLB16_L2.miss_latency_cycles == (
            TLB16_L2.walk_latency_cycles + TLB16_L2.l2_latency_cycles)


# --------------------------------------------------------------------------
# translation=None is the exact identity (the bugfix contract)
# --------------------------------------------------------------------------

AXES_MATRIX = [
    dict(),                                             # single-core default
    dict(cache_backend="scan"),
    dict(num_cores=4, topology="shared"),
    dict(num_cores=2, topology="private",
         channel_affinity="per_core", placement="table_rank"),
    dict(num_cores=2, topology="private", placement="hot_replicate"),
]


def _hw_for(axes):
    hw = tpuv6e()
    if "cache_backend" in axes:
        hw = dataclasses.replace(hw, cache_backend=axes["cache_backend"])
    if "num_cores" in axes:
        hw = hw.with_cluster(axes["num_cores"], axes["topology"])
    if "channel_affinity" in axes or "placement" in axes:
        hw = hw.with_placement(axes.get("channel_affinity", "symmetric"),
                               axes.get("placement", "interleave"))
    return hw


class TestIdentity:
    @pytest.mark.parametrize("axes", AXES_MATRIX)
    def test_none_is_bitwise_identity(self, small_wl, axes):
        hw = _hw_for(axes)
        base = simulate(small_wl, hw, seed=0)
        off = simulate(small_wl, hw.with_translation(None), seed=0)
        assert_bitwise_equal_results(base, off, f"translation off {axes}")
        assert base.summary()["tlb_walks"] == 0
        assert base.summary()["translation_cycles"] == 0.0

    def test_none_is_identity_in_serving(self):
        spec = EmbeddingOpSpec(num_tables=4, rows_per_table=1000, dim=32,
                               lookups_per_sample=4, dtype_bytes=4)
        sc = ServingScenario(
            name="steady",
            traffic=TrafficConfig(pattern="poisson", mean_gap_cycles=700.0,
                                  num_requests=32, seed=11),
            batch_slots=8)
        base = simulate_serving(
            MultiCoreMemorySystem.from_hardware(tpuv6e()), spec, sc)
        off = simulate_serving(
            MultiCoreMemorySystem.from_hardware(
                tpuv6e().with_translation(None)), spec, sc)
        assert_bitwise_equal_results(base, off, "serving translation off")


# --------------------------------------------------------------------------
# Translated simulation semantics
# --------------------------------------------------------------------------

class TestTranslatedSim:
    def test_charges_extend_critical_path(self, small_wl):
        hw = tpuv6e()
        base = simulate(small_wl, hw, seed=0)
        tr = simulate(small_wl, hw.with_translation(TLB16), seed=0)
        s = tr.summary()
        assert s["tlb_walks"] > 0
        assert s["translation_cycles"] > 0.0
        assert tr.total_cycles >= base.total_cycles
        assert s["tlb_hits"] + s["tlb_misses"] == s["cache_misses"]
        # translation only charges cycles — the memory traffic is untouched
        assert s["cache_hits"] == base.summary()["cache_hits"]
        assert s["offchip_reads"] == base.summary()["offchip_reads"]

    def test_per_batch_max_composition(self, small_wl):
        hw = tpuv6e().with_translation(TLB16)
        ms = memory_system_for(hw)
        from repro.core.engine import build_embedding_traces
        for et in build_embedding_traces(small_wl, seed=0):
            for s in ms.simulate_embedding(et):
                assert s.cycles == max(s.onchip_cycles,
                                       s.dram_cycles + s.translation_cycles,
                                       s.vector_cycles)

    def test_multicore_central_mmu_matches_single(self, small_wl):
        """One MMU at the controller: the merged multi-core miss stream
        translates exactly like the single-core stream it equals."""
        hw1 = tpuv6e().with_translation(TLB16)
        hw4 = hw1.with_cluster(4, "shared")
        r1 = simulate(small_wl, hw1, seed=0)
        r4 = simulate(small_wl, hw4, seed=0)
        assert r1.summary()["tlb_walks"] > 0
        assert r4.summary()["tlb_walks"] == r1.summary()["tlb_walks"]

    def test_energy_bills_walks(self, small_wl):
        table = EnergyTable(tlb_walk_pj=500.0)
        hw = tpuv6e().with_translation(TLB16)
        base = simulate(small_wl, hw, seed=0, energy_table=EnergyTable())
        more = simulate(small_wl, hw, seed=0, energy_table=table)
        walks = base.summary()["tlb_walks"]
        assert more.energy_pj - base.energy_pj == pytest.approx(
            walks * (500.0 - EnergyTable().tlb_walk_pj))

    def test_bigger_tlb_fewer_walks(self, small_wl):
        hw = tpuv6e()
        walks = []
        for entries in (16, 64, 256):
            cfg = TranslationConfig(entries=entries, ways=4)
            walks.append(
                simulate(small_wl, hw.with_translation(cfg),
                         seed=0).summary()["tlb_walks"])
        assert walks[0] >= walks[1] >= walks[2]


# --------------------------------------------------------------------------
# translations= sweep axis
# --------------------------------------------------------------------------

TRANSLATIONS = (None, TLB16, TLB16_L2, TLB_SAT_A, TLB_SAT_B)
TR_GRID = dict(policies=("spm", "lru"), capacities=(1 << 17,), ways=(8,),
               zipf_s=0.9, seed=0, translations=TRANSLATIONS)


@pytest.fixture(scope="module")
def tr_sweep(small_wl):
    return sweep(small_wl, tpuv6e(), **TR_GRID)


class TestSweepAxis:
    def test_bitwise_vs_independent_simulate(self, tr_sweep, small_wl):
        assert tr_sweep.num_configs == 2 * len(TRANSLATIONS)
        for e in tr_sweep.entries:
            c = e.config
            hw = tpuv6e().with_policy(
                OnChipPolicy(c.policy), capacity_bytes=c.capacity_bytes,
                ways=c.ways).with_translation(c.translation)
            ref = simulate(small_wl, hw, seed=0, zipf_s=c.zipf_s)
            assert not e.result.diff(ref), (c.label, e.result.diff(ref))

    def test_memo_key_collapses(self, tr_sweep):
        """None shares the base key; both saturated TLBs share one
        first-touch key -> 4 distinct translation outcomes per policy."""
        assert tr_sweep.distinct_memo_keys == 2 * 4
        by = {(e.config.policy, e.config.translation): e.result
              for e in tr_sweep.entries}
        for pol in ("spm", "lru"):
            assert_bitwise_equal_results(
                by[(pol, TLB_SAT_A)], by[(pol, TLB_SAT_B)],
                f"saturated collapse {pol}")
            assert by[(pol, TLB_SAT_A)].summary()["tlb_misses"] == \
                by[(pol, TLB_SAT_A)].summary()["tlb_walks"]

    def test_grid_configs_matches_axes(self, tr_sweep, small_wl):
        cfgs = grid_configs(small_wl, tpuv6e(), policies=("spm", "lru"),
                            capacities=(1 << 17,), ways=(8,), zipf_s=0.9,
                            translations=TRANSLATIONS)
        assert [e.config for e in tr_sweep.entries] == cfgs
        got = sweep(small_wl, tpuv6e(), configs=cfgs, seed=0)
        assert_bitwise_equal_results(tr_sweep, got, "configs= path")

    def test_sharded_bitwise(self, tr_sweep, small_wl):
        got = sweep(small_wl, tpuv6e(), devices=2, **TR_GRID)
        assert got.sharded
        assert_bitwise_equal_results(tr_sweep, got, "sharded translations")

    def test_checkpoint_resume_bitwise(self, tr_sweep, small_wl, tmp_path):
        p = str(tmp_path / "tr.ckpt")
        first = sweep(small_wl, tpuv6e(), checkpoint=p, **TR_GRID)
        assert_bitwise_equal_results(tr_sweep, first, "ckpt first")
        resumed = sweep(small_wl, tpuv6e(), checkpoint=p, **TR_GRID)
        assert resumed.resumed_keys == resumed.distinct_memo_keys
        assert_bitwise_equal_results(tr_sweep, resumed, "ckpt resume")

    def test_fault_plan_bitwise(self, tr_sweep, small_wl):
        tele = FaultTelemetry()
        plan = FaultPlan(events=(FaultEvent("crash", shard=1, round=0),))
        got = sweep(small_wl, tpuv6e(), devices=2, fault_plan=plan,
                    fault_telemetry=tele, **TR_GRID)
        assert_bitwise_equal_results(tr_sweep, got, "crash failover")
        assert tele.worker_crashes == 1 and tele.failovers == 1

    def test_speedup_pairs_within_translation(self, tr_sweep):
        rows = tr_sweep.speedup_over("spm")
        assert len(rows) == tr_sweep.num_configs
        for r in rows:
            if r["policy"] == "spm":
                assert r["speedup_vs_spm"] == pytest.approx(1.0)

    def test_rows_stay_flat(self, tr_sweep):
        for r in tr_sweep.rows():
            assert isinstance(r["translation"], str)

    def test_bad_axis_entry_rejected(self, small_wl):
        with pytest.raises(TypeError, match="TranslationConfig"):
            sweep(small_wl, tpuv6e(), policies=("spm",),
                  translations=[(64, 4)])

    def test_serving_sweep_carries_translation(self):
        spec = EmbeddingOpSpec(num_tables=4, rows_per_table=1000, dim=32,
                               lookups_per_sample=4, dtype_bytes=4)
        wl = Workload(name="serve_tr", embedding_ops=(spec,))
        sc = ServingScenario(
            name="steady",
            traffic=TrafficConfig(pattern="poisson", mean_gap_cycles=700.0,
                                  num_requests=32, seed=11),
            batch_slots=8)
        res = sweep(wl, tpuv6e(), policies=("lru",), scenarios=[sc],
                    translations=(None, TLB16))
        assert res.num_configs == 2
        for e in res.entries:
            hw = tpuv6e().with_policy("lru").with_translation(
                e.config.translation)
            direct = simulate_serving(
                MultiCoreMemorySystem.from_hardware(hw), spec, sc)
            assert_bitwise_equal_results(e.result, direct,
                                         f"serving {e.config.label}")
        off, on = res.entries[0].result, res.entries[1].result
        assert on.makespan_cycles >= off.makespan_cycles
