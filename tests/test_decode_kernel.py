"""Decode-attention Pallas kernel: shape/dtype sweep vs oracle + integration
with the serving path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape).astype(np.float32), dtype=dtype)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,Hq,Hkv,S,dh,valid", [
    (2, 8, 2, 256, 64, 200),
    (1, 4, 4, 512, 128, 512),     # MHA, full cache
    (3, 6, 1, 128, 64, 1),        # MQA, single valid entry
    (2, 16, 8, 384, 64, 300),     # ragged block -> 128-block path
])
def test_decode_attention_sweep(B, Hq, Hkv, S, dh, valid, dtype, rng):
    q = _rand(rng, (B, Hq, dh), dtype)
    k = _rand(rng, (B, Hkv, S, dh), dtype)
    v = _rand(rng, (B, Hkv, S, dh), dtype)
    vl = jnp.int32(valid)
    out_k = ops.decode_attention(q, k, v, vl, block_k=128, use_pallas=True)
    out_r = ref.decode_attention_ref(q, k, v, vl)
    tol = 2e-5 if dtype == jnp.float32 else 4e-2
    np.testing.assert_allclose(np.asarray(out_k, np.float32),
                               np.asarray(out_r, np.float32), atol=tol, rtol=tol)


def test_decode_attention_masks_stale_cache(rng):
    """Entries beyond valid_len must not contribute."""
    B, H, S, dh = 1, 2, 64, 32
    q = _rand(rng, (B, H, dh), jnp.float32)
    k = _rand(rng, (B, H, S, dh), jnp.float32)
    v = _rand(rng, (B, H, S, dh), jnp.float32)
    poisoned_k = k.at[:, :, 10:].set(1e3)
    poisoned_v = v.at[:, :, 10:].set(1e3)
    a = ops.decode_attention(q, k, v, jnp.int32(10), block_k=16)
    b = ops.decode_attention(q, poisoned_k, poisoned_v, jnp.int32(10), block_k=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_serving_decode_with_pallas_kernel_matches_ref():
    """transformer decode_step(use_pallas=True) routes through the kernel and
    must agree with the jnp path."""
    from repro.models import get_smoke_config, family_module
    from repro.models import transformer as T

    cfg = get_smoke_config("chameleon_34b")
    mod = family_module(cfg)
    params = mod.init_lm(KEY, cfg)
    tokens = jax.random.randint(KEY, (2, 12), 0, cfg.vocab)
    caches = T.init_kv_cache(cfg, 2, 32)
    _, caches = T.prefill(params, tokens[:, :-1], caches, cfg)
    l_ref, _ = T.decode_step(params, tokens[:, -1:], jnp.int32(11), caches, cfg,
                             use_pallas=False)
    l_pal, _ = T.decode_step(params, tokens[:, -1:], jnp.int32(11), caches, cfg,
                             use_pallas=True)
    np.testing.assert_allclose(np.asarray(l_pal, np.float32),
                               np.asarray(l_ref, np.float32), atol=6e-2)
