"""ConcatTrace per-batch boundary edge cases feeding the serving scheduler,
plus the trace-construction index-validation guard (out-of-range indices
raise instead of silently wrapping)."""
import numpy as np
import pytest

from repro.core.memory.system import EmbeddingTrace
from repro.core.trace import (
    ConcatTrace,
    FullTrace,
    expand_trace,
    generate_zipf_trace,
    shard_trace,
    validate_indices,
)
from repro.core.workload import EmbeddingOpSpec

SPEC = EmbeddingOpSpec(
    num_tables=3, rows_per_table=500, dim=32, lookups_per_sample=4,
    dtype_bytes=4,
)


def _full(spec, batch_size, seed):
    it = generate_zipf_trace(
        batch_size * spec.num_tables * spec.lookups_per_sample,
        spec.rows_per_table, 1.0, seed=seed,
    )
    return expand_trace(it, spec, batch_size, seed=seed)


def _empty_batch(spec, batch_size=0):
    return FullTrace(
        table_ids=np.empty(0, dtype=np.int32),
        row_ids=np.empty(0, dtype=np.int64),
        batch_size=batch_size,
        num_tables=spec.num_tables,
        lookups_per_sample=spec.lookups_per_sample,
    )


# --------------------------------------------------------------------------
# Boundary edge cases
# --------------------------------------------------------------------------

class TestConcatBoundaries:
    def test_single_batch(self):
        f = _full(SPEC, 4, seed=0)
        ct = ConcatTrace.from_traces([f])
        assert ct.num_batches == 1
        assert ct.boundaries.tolist() == [0, len(f)]
        assert ct.lookups_per_batch.tolist() == [len(f)]
        assert np.array_equal(ct.lookup_batch, np.zeros(len(f), np.int64))

    def test_empty_trace_list_rejected(self):
        with pytest.raises(ValueError):
            ConcatTrace.from_traces([])

    def test_empty_batch_mid_stream(self):
        """A zero-lookup batch (e.g. every lookup degraded away) keeps its
        boundary slot: attribution stays per batch, no index drift."""
        a, e, b = _full(SPEC, 2, 0), _empty_batch(SPEC), _full(SPEC, 3, 1)
        ct = ConcatTrace.from_traces([a, e, b])
        assert ct.num_batches == 3
        assert ct.lookups_per_batch.tolist() == [len(a), 0, len(b)]
        assert ct.boundaries.tolist() == [0, len(a), len(a), len(a) + len(b)]
        # lookup_batch skips the empty batch but never mis-attributes
        assert np.array_equal(
            np.bincount(ct.lookup_batch, minlength=3),
            np.array([len(a), 0, len(b)]),
        )

    def test_all_batches_empty(self):
        ct = ConcatTrace.from_traces([_empty_batch(SPEC), _empty_batch(SPEC)])
        assert ct.num_batches == 2
        assert len(ct) == 0
        assert ct.lookups_per_batch.tolist() == [0, 0]

    def test_empty_batch_simulates(self):
        """The memory system attributes zero-lookup batches exact-zero stats
        without disturbing its neighbors (the scheduler can serve a fully
        degraded batch)."""
        from repro.core.hardware import tpuv6e
        from repro.core.memory.system import MultiCoreMemorySystem

        a, b = _full(SPEC, 2, 0), _full(SPEC, 3, 1)
        ms = MultiCoreMemorySystem.from_hardware(tpuv6e())
        with_empty = ms.simulate_embedding(EmbeddingTrace.from_concat(
            SPEC, ConcatTrace.from_traces([a, _empty_batch(SPEC), b])))
        without = ms.simulate_embedding(EmbeddingTrace.from_concat(
            SPEC, ConcatTrace.from_traces([a, b])))
        assert len(with_empty) == 3
        assert with_empty[1].cache_misses == 0
        assert with_empty[1].offchip_reads == 0
        import dataclasses
        assert (dataclasses.asdict(with_empty[0])
                == dataclasses.asdict(without[0]))

    @pytest.mark.parametrize("mode", ["batch", "table_hash"])
    def test_shard_preserves_batch_boundaries(self, mode):
        """Sharding keeps every batch's lookups inside that batch's slot on
        every core — per-batch totals across cores reconstruct the parent's
        boundary structure exactly, heterogeneous batch lengths included."""
        traces = [_full(SPEC, 5, 0), _full(SPEC, 2, 1), _full(SPEC, 7, 2)]
        ct = ConcatTrace.from_traces(traces)
        shards = shard_trace(ct, 2, mode=mode)
        assert len(shards) == 2
        for sh in shards:
            assert sh.concat.num_batches == ct.num_batches
        per_batch = np.zeros((2, ct.num_batches), dtype=np.int64)
        for c, sh in enumerate(shards):
            per_batch[c] = sh.concat.lookups_per_batch
            # every shard lookup maps back inside its batch's global range
            lb = sh.concat.lookup_batch
            gstart = ct.boundaries[:-1][lb]
            gend = ct.boundaries[1:][lb]
            assert np.all(sh.lookup_index >= gstart)
            assert np.all(sh.lookup_index < gend)
        assert np.array_equal(per_batch.sum(axis=0), ct.lookups_per_batch)

    @pytest.mark.parametrize("mode", ["batch", "table_hash"])
    def test_shard_empty_batch(self, mode):
        """An empty batch stays an empty batch on every core."""
        ct = ConcatTrace.from_traces(
            [_full(SPEC, 3, 0), _empty_batch(SPEC), _full(SPEC, 3, 1)])
        for sh in shard_trace(ct, 2, mode=mode):
            assert sh.concat.num_batches == 3
            assert sh.concat.lookups_per_batch[1] == 0


# --------------------------------------------------------------------------
# Index-validation guard (regression: no silent modulo wrap)
# --------------------------------------------------------------------------

class TestIndexValidation:
    def test_expand_trace_rejects_out_of_range(self):
        it = np.array([0, 1, SPEC.rows_per_table], dtype=np.int64)
        with pytest.raises(ValueError, match="out of range"):
            expand_trace(it, SPEC, batch_size=2)

    def test_expand_trace_rejects_negative(self):
        it = np.array([0, -1, 2], dtype=np.int64)
        with pytest.raises(ValueError, match="negative"):
            expand_trace(it, SPEC, batch_size=2)

    def test_expand_trace_accepts_full_range(self):
        it = np.array([0, SPEC.rows_per_table - 1], dtype=np.int64)
        ft = expand_trace(it, SPEC, batch_size=2)
        assert ft.row_ids.min() >= 0
        assert ft.row_ids.max() < SPEC.rows_per_table

    def test_embedding_trace_rejects_bad_rows(self):
        f = _full(SPEC, 2, 0)
        rows = f.row_ids.copy()
        rows[0] = SPEC.rows_per_table + 7
        bad = FullTrace(f.table_ids, rows, f.batch_size, f.num_tables,
                        f.lookups_per_sample)
        with pytest.raises(ValueError, match="out of range"):
            EmbeddingTrace(SPEC, [bad])
        with pytest.raises(ValueError, match="out of range"):
            EmbeddingTrace.from_concat(SPEC, ConcatTrace.from_traces([bad]))

    def test_embedding_trace_rejects_bad_table(self):
        f = _full(SPEC, 2, 0)
        tabs = f.table_ids.copy()
        tabs[0] = SPEC.num_tables
        bad = FullTrace(tabs, f.row_ids, f.batch_size, f.num_tables,
                        f.lookups_per_sample)
        with pytest.raises(ValueError, match="table id"):
            EmbeddingTrace(SPEC, [bad])

    def test_validate_indices_empty_ok(self):
        validate_indices(np.empty(0, dtype=np.int64), 10)
